"""Shared harness for the paper-reproduction benchmarks.

Protocol follows §IV of the paper with CPU-budget adaptations documented in
DESIGN.md §8: synthetic stand-ins at the paper's (d, N) — subsampled to
``SUBSAMPLE`` for the exact solves — J=10 circulant(1,2) topology, 50/50
per-node train/test split, RSE metric, penalty c selected on a validation
split from ``C_GRID`` (the stand-ins need weaker coupling than the paper's
{2^i N} grid; both documented).
"""
from __future__ import annotations

import time

import jax

# Paper-faithful numerics, same as tests/conftest.py: the exact KRR solves
# and round-count benchmarks are meaningless at float32 (tol=1e-6 targets
# sit below the f32 noise floor of solve_exact vs the iteration limit).
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import (DKLA, DKLAConfig, DeKRRConfig, DeKRRSolver, circulant,
                        dkla_ddrf_feature_map, rse, sample_rff,
                        select_features)
from repro.data.synthetic import (imbalanced_sizes, make_dataset, partition,
                                  train_test_split_nodes)

J = 10
TOPOLOGY = circulant(J, (1, 2))          # the paper's 10-node, 4-neighbor net
SIGMA = 1.0
LAM = 1e-6
SUBSAMPLE = 3000
C_GRID = (0.002, 0.01, 0.05)             # × N
SEEDS = 3

PAPER_DBAR = {                            # Tab. 2 D̄ per dataset
    "houses": 70, "air_quality": 80, "energy": 100,
    "twitter": 130, "toms_hardware": 150, "wave": 200,
}


def load_split(name: str, *, mode: str = "noniid_y", sizes=None, seed=0):
    ds = make_dataset(name, subsample=SUBSAMPLE, seed=seed)
    nodes = partition(ds, J, mode=mode, sizes=sizes, seed=seed)
    train, test = train_test_split_nodes(nodes, seed=seed)
    return ds, train, test


def _val_split(train, frac=0.25, seed=0):
    """Hold out a slice of each node's training data for c selection."""
    from repro.core import NodeData
    rng = np.random.default_rng(seed)
    tr, va = [], []
    for nd in train:
        n = nd.num_samples
        perm = rng.permutation(n)
        k = max(int(n * frac), 1)
        x = np.asarray(nd.x)
        y = np.asarray(nd.y)
        va.append(NodeData(x=jnp.asarray(x[:, perm[:k]]),
                           y=jnp.asarray(y[perm[:k]])))
        tr.append(NodeData(x=jnp.asarray(x[:, perm[k:]]),
                           y=jnp.asarray(y[perm[k:]])))
    return tr, va


def _network_rse(predict_fn, test):
    ys = jnp.concatenate([t.y for t in test])
    pred = jnp.concatenate([predict_fn(j, test[j].x)
                            for j in range(len(test))])
    return rse(pred, ys)


def run_dekrr_ddrf(ds, train, test, d_per_node, *, method="energy",
                   seed=0, candidate_ratio=20, c_grid=C_GRID):
    """Our algorithm with per-node DDRF; c selected on a validation split.
    Returns (test RSE, wall seconds)."""
    t0 = time.perf_counter()
    keys = jax.random.split(jax.random.PRNGKey(seed), J)
    if isinstance(d_per_node, int):
        d_per_node = [d_per_node] * J
    fmaps = [
        select_features(keys[j], ds.dim, d_per_node[j], SIGMA, train[j].x,
                        train[j].y, method=method,
                        candidate_ratio=candidate_ratio)
        for j in range(J)
    ]
    tr, va = _val_split(train, seed=seed)
    n = sum(t.num_samples for t in tr)
    best_c, best_v = None, np.inf
    for c in c_grid:
        solver = DeKRRSolver(TOPOLOGY, fmaps, tr,
                             DeKRRConfig(lam=LAM, c_nei=c * n))
        st = solver.solve_exact()
        v = _network_rse(lambda j, x: solver.predict(st.theta, x, node=j), va)
        if v < best_v:
            best_v, best_c = v, c
    n_full = sum(t.num_samples for t in train)
    solver = DeKRRSolver(TOPOLOGY, fmaps, train,
                         DeKRRConfig(lam=LAM, c_nei=best_c * n_full))
    st = solver.solve_exact()
    r = _network_rse(lambda j, x: solver.predict(st.theta, x, node=j), test)
    return r, time.perf_counter() - t0


def run_dkla(ds, train, test, d_feat, *, ddrf=False, seed=0,
             num_iters=400):
    """DKLA (plain shared RFF) or DKLA-DDRF (shared features selected on the
    biggest node). Returns (test RSE, wall seconds)."""
    t0 = time.perf_counter()
    if ddrf:
        fmap = dkla_ddrf_feature_map(
            jax.random.PRNGKey(seed), ds.dim, d_feat, SIGMA, train,
            method="energy")
    else:
        fmap = sample_rff(jax.random.PRNGKey(seed), ds.dim, d_feat, SIGMA)
    dkla = DKLA(TOPOLOGY, fmap, train, DKLAConfig(lam=LAM,
                                                  num_iters=num_iters))
    th = dkla.solve()
    r = _network_rse(lambda j, x: dkla.predict(th, x, node=j), test)
    return r, time.perf_counter() - t0


def mean_over_seeds(fn, seeds=SEEDS):
    vals = [fn(s) for s in range(seeds)]
    rs = [v[0] for v in vals]
    ts = [v[1] for v in vals]
    return float(np.mean(rs)), float(np.std(rs)), float(np.mean(ts))


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
