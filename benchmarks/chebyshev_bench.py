"""Beyond-paper optimization bench: communication rounds to reach the
Eq. 19 limit — plain stationary iteration (paper-faithful baseline) vs
Chebyshev semi-iteration (our accelerated variant, identical per-round
exchange). The paper's cost metric is rounds × Σ_j |N_j| D_j."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.core import DeKRRConfig, DeKRRSolver, select_features
from repro.core.acceleration import (estimate_spectral_interval,
                                     rounds_to_tolerance)
from repro.dist import comm_bytes_per_round, pack_problem


def run(dataset="houses", d_feat=30, fast=False):
    ds, train, test = C.load_split(dataset, mode="noniid_y")
    keys = jax.random.split(jax.random.PRNGKey(0), C.J)
    fmaps = [select_features(keys[j], ds.dim, d_feat, C.SIGMA, train[j].x,
                             train[j].y, method="energy")
             for j in range(C.J)]
    n = sum(t.num_samples for t in train)
    cgrid = (0.005,) if fast else (0.005, 0.05)
    for cfrac in cgrid:
        t0 = time.perf_counter()
        solver = DeKRRSolver(C.TOPOLOGY, fmaps, train,
                             DeKRRConfig(lam=C.LAM, c_nei=cfrac * n))
        packed = pack_problem(solver)
        exact = solver.solve_exact()
        dmax = packed.d.shape[1]
        theta_star = jnp.stack(
            [jnp.pad(t, (0, dmax - t.shape[0])) for t in exact.theta])
        lo, hi = estimate_spectral_interval(packed)
        plain, cheb = rounds_to_tolerance(packed, theta_star, tol=1e-6,
                                          mu_max=hi, mu_min=lo)
        bpr = comm_bytes_per_round(packed, "ppermute")
        C.csv_row(
            f"chebyshev/{dataset}/c{cfrac}N",
            (time.perf_counter() - t0) * 1e6,
            f"rho={solver.spectral_radius():.5f};rounds_plain={plain};"
            f"rounds_chebyshev={cheb};speedup={plain/max(cheb,1):.1f}x;"
            f"bytes_per_round={bpr};"
            f"total_comm_plain={plain*bpr};total_comm_cheb={cheb*bpr}")


if __name__ == "__main__":
    run()
