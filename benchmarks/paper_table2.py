"""Table 2: RSE of DKLA / DKLA-DDRF / DeKRR-DDRF on the six datasets under
the non-IID |y| split, at the paper's per-dataset D̄."""
from __future__ import annotations

from benchmarks import common as C


def run(datasets=None, fast=False):
    datasets = datasets or list(C.PAPER_DBAR)
    if fast:
        datasets = datasets[:2]
    rows = []
    for name in datasets:
        dbar = C.PAPER_DBAR[name]
        ds, train, test = C.load_split(name, mode="noniid_y")

        r_dkla, _, t_dkla = C.mean_over_seeds(
            lambda s: C.run_dkla(ds, train, test, dbar, seed=50 + s))
        r_dd, _, t_dd = C.mean_over_seeds(
            lambda s: C.run_dkla(ds, train, test, dbar, ddrf=True,
                                 seed=50 + s))
        r_ours, _, t_ours = C.mean_over_seeds(
            lambda s: C.run_dekrr_ddrf(ds, train, test, dbar, seed=s))

        imp = 100.0 * (r_dkla - r_ours) / max(r_dkla, 1e-12)
        rows.append((name, dbar, r_dkla, r_dd, r_ours, imp))
        C.csv_row(
            f"table2/{name}", t_ours * 1e6,
            f"D={dbar};DKLA={r_dkla:.4f};DKLA-DDRF={r_dd:.4f};"
            f"ours={r_ours:.4f};improvement={imp:.1f}%")
    mean_imp = sum(r[5] for r in rows) / len(rows)
    C.csv_row("table2/mean_improvement", 0.0,
              f"mean_rse_improvement_vs_DKLA={mean_imp:.1f}%"
              f";paper_claims=25.5%")
    return rows


if __name__ == "__main__":
    run()
