"""§III: objective descent under the Prop. 1 condition + spectral radius of
the Eq. 19 iteration map."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common as C
from repro.core import (DeKRRConfig, DeKRRSolver, prop1_required_c_self,
                        select_features)


def run(dataset="air_quality", d_feat=24, fast=False):
    ds, train, test = C.load_split(dataset, mode="noniid_y")
    keys = jax.random.split(jax.random.PRNGKey(0), C.J)
    fmaps = [select_features(keys[j], ds.dim, d_feat, C.SIGMA, train[j].x,
                             train[j].y, method="energy")
             for j in range(C.J)]
    n = sum(t.num_samples for t in train)

    base = DeKRRSolver(C.TOPOLOGY, fmaps, train,
                       DeKRRConfig(lam=C.LAM, c_nei=0.01 * n,
                                   c_self_ratio=1.0))
    req = prop1_required_c_self(base)
    ratio = float(np.max(req / (0.01 * n))) * 1.2 + 1.0

    t0 = time.perf_counter()
    solver = DeKRRSolver(C.TOPOLOGY, fmaps, train,
                         DeKRRConfig(lam=C.LAM, c_nei=0.01 * n,
                                     c_self_ratio=min(ratio, 50.0)))
    state = solver.init_state()
    objs = [float(solver.objective(state.theta))]
    iters = 10 if fast else 40
    for _ in range(iters):
        state = solver.step(state)
        objs.append(float(solver.objective(state.theta)))
    dt = time.perf_counter() - t0
    monotone = all(b <= a + 1e-12 for a, b in zip(objs, objs[1:]))
    rho = solver.spectral_radius()
    C.csv_row(
        f"convergence/{dataset}", dt / max(iters, 1) * 1e6,
        f"monotone={monotone};obj0={objs[0]:.6f};objK={objs[-1]:.6f};"
        f"spectral_radius={rho:.5f};prop1_ratio_used={min(ratio, 50.0):.1f}")
    return objs


if __name__ == "__main__":
    run()
