"""Fused Pallas round kernel vs XLA-vmap round: per-round latency across
packed-problem scales, emitting ``BENCH_step.json`` for the perf trajectory.

Grid: J ∈ {16, 64, 256} nodes × D_max ∈ {128, 512}, K = 4 circulant slots
(the paper's C_J(1, 2) degree), f32. On CPU the Pallas kernel executes in
interpret mode — per-block Python evaluation, bit-accurate but meaningless
for timing — so wall time is measured on the XLA-vmap path (the current
production round) and the fused kernel is reported twice: interpret-mode
wall (labelled as such) and the analytic TPU roofline (HBM-bound streaming
of the [J, D, D] blocks at `repro.launch.mesh.HBM_BANDWIDTH`, the same
model as `kernel_bench.py`). On a TPU backend both paths are timed for
real and `pallas_us` is the compiled kernel.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.dist import PackedProblem, step_batched
from repro.dist.dekrr_spmd import _circulant_slot_table
from repro.launch.mesh import HBM_BANDWIDTH, PEAK_FLOPS_BF16

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_step.json")

CASES = [
    # (J, D_max) at K = 4 — paper topology degree; D spans Tab. 2's D̄ and
    # the packed production target.
    (16, 128), (16, 512),
    (64, 128), (64, 512),
    (256, 128), (256, 512),
]
OFFSETS = (1, 2)


def _synthetic_packed(j_nodes: int, d_max: int,
                      dtype=jnp.float32) -> PackedProblem:
    """A random packed problem with the circulant C_J(1,2) slot layout
    (contraction spectra do not matter for latency, only shapes)."""
    key = jax.random.PRNGKey(j_nodes * 7919 + d_max)
    kg, kd, ks, kp = jax.random.split(key, 4)
    k_slots = 2 * len(OFFSETS)
    scale = 1.0 / d_max                      # keep iterates bounded
    nbr_idx = _circulant_slot_table(OFFSETS, j_nodes)
    return PackedProblem(
        g=jax.random.normal(kg, (j_nodes, d_max, d_max), dtype) * scale,
        d=jax.random.normal(kd, (j_nodes, d_max), dtype),
        s=jax.random.normal(ks, (j_nodes, d_max, d_max), dtype) * scale,
        p=jax.random.normal(
            kp, (j_nodes, k_slots, d_max, d_max), dtype) * scale,
        theta_mask=jnp.ones((j_nodes, d_max), dtype),
        nbr_idx=jnp.asarray(nbr_idx),
        nbr_mask=jnp.ones((j_nodes, k_slots), dtype),
        offsets=OFFSETS,
        node_dims=tuple([d_max] * j_nodes),
    )


def _time_step(packed, theta, backend: str, reps: int) -> float:
    step_batched(packed, theta, backend=backend).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        step_batched(packed, theta, backend=backend).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def analytic(j_nodes: int, d_max: int, k_slots: int = 4,
             dtype_bytes: int = 4):
    """Fused-kernel roofline: one HBM pass over the blocks, θ VMEM-resident."""
    flops = j_nodes * 2 * (2 + k_slots) * d_max * d_max
    hbm = (j_nodes * (2 + k_slots) * d_max * d_max       # G, S, P blocks
           + j_nodes * d_max * 3) * dtype_bytes          # d, θ in, θ out
    vmem = (j_nodes * d_max                              # θ table
            + (2 + k_slots) * d_max * d_max              # one node's blocks
            + 3 * d_max) * dtype_bytes
    t_roof = max(flops / PEAK_FLOPS_BF16, hbm / HBM_BANDWIDTH)
    return flops, hbm, vmem, t_roof


def run(fast: bool = False) -> None:
    on_tpu = jax.default_backend() == "tpu"
    cases = [(j, d) for j, d in CASES if j <= 64 and d <= 128] if fast \
        else CASES
    results = []
    for j_nodes, d_max in cases:
        packed = _synthetic_packed(j_nodes, d_max)
        theta = jnp.zeros_like(packed.d)
        xla_reps = 20 if d_max <= 128 else 5
        xla_us = _time_step(packed, theta, "xla", xla_reps)
        pallas_us = _time_step(packed, theta, "pallas", 1)

        k_slots = packed.num_slots
        flops, hbm, vmem, t_roof = analytic(j_nodes, d_max, k_slots)
        row = {
            "j_nodes": j_nodes, "d_max": d_max, "k_slots": k_slots,
            "dtype": "float32",
            "xla_us": round(xla_us, 1),
            "pallas_us": round(pallas_us, 1),
            "pallas_timing_is_interpret_mode": not on_tpu,
            "flops": flops, "hbm_bytes": hbm, "vmem_bytes": vmem,
            "tpu_roofline_us": round(t_roof * 1e6, 2),
            "fits_vmem": bool(vmem < 16 * 2**20),
        }
        results.append(row)
        C.csv_row(
            f"step/J{j_nodes}_D{d_max}", xla_us,
            f"pallas_us={row['pallas_us']};interp={not on_tpu};"
            f"tpu_roofline_us={row['tpu_roofline_us']};"
            f"vmem={vmem/2**20:.2f}MiB;fits_vmem={row['fits_vmem']}")
        del packed, theta

    payload = {
        "benchmark": "dekrr_step fused Pallas round vs XLA-vmap round",
        "backend": jax.default_backend(),
        "note": ("pallas_us is interpret-mode (Python per grid step) wall "
                 "time on non-TPU backends — compare trajectories on "
                 "xla_us and tpu_roofline_us there"),
        "cases": results,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"step/json,0.0,wrote={os.path.relpath(OUT_PATH, REPO_ROOT)}")


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
