"""Whole-solve latency: fused multi-round Pallas kernel vs per-round kernel
scan vs XLA scan, emitting ``BENCH_solve.json`` for the perf trajectory.

This is the benchmark `BENCH_step.json` cannot be: the per-round step bench
times one round in isolation, so the R kernel dispatches and the R θ
HBM-round-trips of a real solve — the costs the fused
`solve_batched(backend="pallas_fused")` path deletes — are invisible to
it. Here the unit is the full solve at the paper's round counts
(rounds ∈ {100, 1000}; ρ(M) ≈ 0.95–0.999 needs hundreds-to-thousands),
and each backend's ``round_dispatches`` is recorded next to its wall
time: R separate round invocations for the scan backends, one fused
pallas_call (per chunk) for "pallas_fused".

On CPU both Pallas paths execute in interpret mode — per-grid-step
evaluation, bit-accurate but meaningless for timing — so those columns
are honestly labeled placeholders (``pallas_timing_is_interpret_mode``):
measured at a capped round count (interpret wall is ~0.5 s/round at
J = 64 — a 1000-round interpret solve is pointless to sit through) and
scaled linearly to the nominal rounds, with the cap recorded in
``pallas_interpret_rounds_measured``. Wall time is measured for real on
the XLA scan, and the fused kernel is additionally reported as the
analytic TPU roofline (HBM-bound streaming of the [J, D, D] blocks at
`repro.launch.mesh.HBM_BANDWIDTH`, same model as `step_kernel_bench.py`
— identical per round for fused and per-round paths; what the fusion
removes is the per-dispatch overhead and θ traffic *between* rounds,
which a roofline by construction excludes). On a TPU backend all three
columns are real compiled timings over the full round count.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks import common as C
from benchmarks.step_kernel_bench import OFFSETS, _synthetic_packed, analytic
from repro.dist import solve_batched

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_solve.json")

CASES = [
    # (J, D_max, rounds) at K = 4 circulant slots — the paper topology
    # degree; round counts span the ρ(M) ≈ 0.95 → 0.999 operating range.
    (16, 128, 100), (16, 128, 1000),
    (64, 128, 100), (64, 128, 1000),
]
BACKENDS = ("xla", "pallas", "pallas_fused")


def _time_solve(packed, rounds: int, backend: str, reps: int) -> float:
    solve_batched(packed, rounds, backend=backend).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        solve_batched(packed, rounds, backend=backend).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def run(fast: bool = False) -> None:
    on_tpu = jax.default_backend() == "tpu"
    # interpret-mode placeholder columns: measure this many rounds and
    # scale linearly (compiled TPU timings use the full count)
    interp_cap = 10 if fast else 50
    cases = [(j, d, r) for j, d, r in CASES if j <= 16 and r <= 100] \
        if fast else CASES
    results = []
    for j_nodes, d_max, rounds in cases:
        packed = _synthetic_packed(j_nodes, d_max)
        k_slots = packed.num_slots

        times = {}
        for backend in BACKENDS:
            measured = rounds if (on_tpu or backend == "xla") \
                else min(rounds, interp_cap)
            # interpret-mode Pallas is slow; one rep is representative
            reps = 3 if (backend == "xla" and rounds <= 100) else 1
            times[backend] = (_time_solve(packed, measured, backend, reps)
                              * (rounds / measured))

        flops1, hbm1, _, t_roof1 = analytic(j_nodes, d_max, k_slots)
        vmem_fused = (2 * j_nodes * d_max                  # two θ tables
                      + 2 * (2 + k_slots) * d_max * d_max  # dbl-buf blocks
                      + 3 * d_max) * 4
        row = {
            "j_nodes": j_nodes, "d_max": d_max, "k_slots": k_slots,
            "rounds": rounds, "dtype": "float32",
            "xla_us": round(times["xla"], 1),
            "pallas_us": round(times["pallas"], 1),
            "pallas_fused_us": round(times["pallas_fused"], 1),
            "pallas_timing_is_interpret_mode": not on_tpu,
            "pallas_interpret_rounds_measured": (
                None if on_tpu else min(rounds, interp_cap)),
            # what the fusion is FOR: dispatch counts per solve
            "round_dispatches": {
                "xla": rounds, "pallas": rounds, "pallas_fused": 1},
            # θ words crossing HBM between rounds (zero once fused)
            "theta_hbm_bytes_between_rounds": {
                "per_round": 2 * rounds * j_nodes * d_max * 4,
                "pallas_fused": 0},
            "flops": rounds * flops1,
            "hbm_bytes": rounds * hbm1,
            "vmem_bytes": vmem_fused,
            "tpu_roofline_us": round(rounds * t_roof1 * 1e6, 2),
            "fits_vmem": bool(vmem_fused < 16 * 2**20),
        }
        results.append(row)
        C.csv_row(
            f"solve/J{j_nodes}_D{d_max}_R{rounds}", times["xla"],
            f"pallas_us={row['pallas_us']};"
            f"fused_us={row['pallas_fused_us']};interp={not on_tpu};"
            f"dispatches=1/{rounds};"
            f"tpu_roofline_us={row['tpu_roofline_us']};"
            f"vmem={vmem_fused/2**20:.2f}MiB")
        del packed

    payload = {
        "benchmark": ("dekrr_solve fused multi-round kernel vs per-round "
                      "kernel scan vs XLA scan (whole-solve latency)"),
        "backend": jax.default_backend(),
        "circulant_offsets": list(OFFSETS),
        "note": ("pallas_us / pallas_fused_us are interpret-mode (Python "
                 "per grid step) wall times on non-TPU backends, measured "
                 "over pallas_interpret_rounds_measured rounds and scaled "
                 "linearly — placeholders for the compiled columns; "
                 "compare trajectories on xla_us, round_dispatches and "
                 "tpu_roofline_us there"),
        "cases": results,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"solve/json,0.0,wrote={os.path.relpath(OUT_PATH, REPO_ROOT)}")


if __name__ == "__main__":
    run(fast="--fast" in sys.argv)
