"""Ablation: which data-dependent selection rule drives the gains?

Four per-node feature strategies at fixed D under the non-IID |y| split:
  plain-shared   — one RFF draw broadcast to all nodes (DKLA premise)
  plain-pernode  — independent RFF draws per node (flexibility alone)
  energy         — top-D by label-alignment score ([33]; the paper's choice)
  leverage       — top-D by ridge leverage ([35, 36])

All solved with the same DeKRR consensus (c from the validation grid), so
differences isolate the *selection rule*. plain-pernode vs plain-shared
isolates the value of per-node feature freedom; energy/leverage vs
plain-pernode isolates data dependence.
"""
from __future__ import annotations

import jax

from benchmarks import common as C
from repro.core import sample_rff


def run(datasets=("houses", "twitter"), d_feat=40, fast=False):
    if fast:
        datasets = datasets[:1]
    for name in datasets:
        ds, train, test = C.load_split(name, mode="noniid_y")
        results = {}
        for method in ("plain", "energy", "leverage"):
            r, sd, t = C.mean_over_seeds(
                lambda s: C.run_dekrr_ddrf(ds, train, test, d_feat,
                                           method=method, seed=s),
                seeds=2)
            key = "plain-pernode" if method == "plain" else method
            results[key] = r
        r_shared, _, _ = C.mean_over_seeds(
            lambda s: C.run_dkla(ds, train, test, d_feat, seed=40 + s),
            seeds=2)
        results["plain-shared(DKLA)"] = r_shared
        C.csv_row(
            f"ablation/ddrf/{name}", 0.0,
            ";".join(f"{k}={v:.4f}" for k, v in results.items())
            + f";D={d_feat}")


if __name__ == "__main__":
    run()
