"""Chebyshev acceleration benchmark, emitting ``BENCH_accel.json``.

Two claims are on the line after the β₁ = ½(c/d)² fix and the fused
single-dispatch Chebyshev kernel:

  * rounds-to-tolerance — the accelerated iteration must cross each
    tolerance in far fewer communication rounds than the plain
    stationary iteration (the paper's cost metric is rounds × bytes, so
    this IS the communication win), and
  * dispatch count — `chebyshev_solve_packed(backend="pallas_fused")`
    and the fused async chain must each compile to exactly ONE
    pallas_call per chunk (counted on the traced jaxpr with the same
    counter the J002 lint pins), killing the per-round dispatch floor.

Wall-clock per solve is recorded per backend for the perf trajectory;
off-TPU the Pallas columns run interpret mode and remain placeholders —
only the XLA column and the dispatch/round counts are meaningful on CPU
(same caveat as BENCH_step/BENCH_solve, see ROADMAP).
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.core import DeKRRConfig, DeKRRSolver, select_features
from repro.core.acceleration import (chebyshev_solve_packed,
                                     estimate_spectral_interval,
                                     rounds_to_tolerance)
from repro.dist import comm_bytes_per_round, pack_problem

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_accel.json")

BACKENDS = ("xla", "pallas", "pallas_fused")


def _build_problem(dataset: str, d_feat: int, cfrac: float):
    ds, train, _ = C.load_split(dataset, mode="noniid_y")
    keys = jax.random.split(jax.random.PRNGKey(0), C.J)
    fmaps = [select_features(keys[j], ds.dim, d_feat, C.SIGMA, train[j].x,
                             train[j].y, method="energy")
             for j in range(C.J)]
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(C.TOPOLOGY, fmaps, train,
                         DeKRRConfig(lam=C.LAM, c_nei=cfrac * n))
    return solver, pack_problem(solver)


def _dispatch_counts(num_iters: int) -> dict:
    """Per-backend pallas_call counts of the traced accelerated and fused
    async entry points — the same counter the J002 lint pins, run on the
    synthetic packed problem so tracing stays sub-second."""
    from repro.analysis import jaxpr_lint as JL
    from repro.dist.async_gossip import async_solve_batched

    packed = JL.synthetic_packed()
    key = jax.random.PRNGKey(0)
    out = {}
    for b in BACKENDS:
        cheb, cheb_exact = JL.count_pallas_dispatches(jax.make_jaxpr(
            lambda pk, b=b: chebyshev_solve_packed(
                pk, 0.9, 0.0, num_iters=num_iters, backend=b))(packed))
        asyn, asyn_exact = JL.count_pallas_dispatches(jax.make_jaxpr(
            lambda pk, k, b=b: async_solve_batched(
                pk, num_iters, k, backend=b))(packed, key))
        assert cheb_exact and asyn_exact
        out[b] = {"chebyshev_solve_packed": cheb,
                  "async_solve_batched": asyn}
    return out


def _time_solve(packed, hi, lo, num_iters, backend, reps=3):
    def call():
        return jax.block_until_ready(chebyshev_solve_packed(
            packed, hi, lo, num_iters=num_iters, backend=backend))

    call()                                     # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        call()
    return (time.perf_counter() - t0) / reps * 1e6


def run(dataset="houses", d_feat=30, fast=False) -> None:
    solver, packed = _build_problem(dataset, d_feat, cfrac=0.005)
    exact = solver.solve_exact()
    dmax = packed.d.shape[1]
    theta_star = jnp.stack(
        [jnp.pad(t, (0, dmax - t.shape[0])) for t in exact.theta])
    lo, hi = estimate_spectral_interval(packed)
    bpr = comm_bytes_per_round(packed, "ppermute")

    tols = (1e-4, 1e-6) if fast else (1e-3, 1e-4, 1e-5, 1e-6)
    ladder = []
    for tol in tols:
        plain, cheb = rounds_to_tolerance(packed, theta_star, tol=tol,
                                          mu_max=hi, mu_min=lo)
        ladder.append({"tol": tol, "rounds_plain": plain,
                       "rounds_chebyshev": cheb,
                       "speedup": round(plain / max(cheb, 1), 2),
                       "comm_plain_bytes": plain * bpr,
                       "comm_chebyshev_bytes": cheb * bpr})
        C.csv_row(f"accel/{dataset}/tol{tol:g}", 0.0,
                  f"rounds_plain={plain};rounds_chebyshev={cheb};"
                  f"speedup={plain / max(cheb, 1):.1f}x")

    num_iters = 10 if fast else 30
    dispatches = _dispatch_counts(num_iters)
    timings = {}
    for b in BACKENDS:
        us = _time_solve(packed, hi, lo, num_iters, b,
                         reps=1 if fast else 3)
        timings[b] = round(us, 1)
        C.csv_row(f"accel/solve{num_iters}/{b}", us,
                  f"dispatches={dispatches[b]['chebyshev_solve_packed']}")

    payload = {
        "benchmark": ("Chebyshev-accelerated DeKRR: rounds-to-tolerance "
                      "vs plain iteration, dispatch counts, per-backend "
                      "solve wall time"),
        "backend": jax.default_backend(),
        "dataset": dataset,
        "j_nodes": packed.num_nodes,
        "d_feat": d_feat,
        "spectral_interval": [float(lo), float(hi)],
        "bytes_per_round": bpr,
        "rounds_to_tolerance": ladder,
        "round_dispatches": dispatches,
        "solve_us": {"num_iters": num_iters, **timings},
        "note": ("round_dispatches counts pallas_call eqns on the traced "
                 "program (J002 contract: pallas_fused = 1 per chunk for "
                 "both the accelerated and the fused async path). Off-TPU "
                 "the pallas/pallas_fused wall-time columns run interpret "
                 "mode and are placeholders, not perf."),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"accel/json,0.0,wrote={os.path.relpath(OUT_PATH, REPO_ROOT)}")


if __name__ == "__main__":
    run(fast=("--fast" in sys.argv) or ("--smoke" in sys.argv))
