"""Times the `repro.analysis` static passes so lint cost stays visible.

The analysis CI job runs on every push — if the jaxpr pass (which traces
all 15+ batched solver entry points) or the conventions AST sweep creeps
from seconds into minutes, that is a regression in its own right even
though no solver numerics changed. Rows:

  analysis/conventions  AST lint over src/ + tests/ + benchmarks/
  analysis/jaxpr        trace + lint every batched entry point
  analysis/clean        1 iff both passes produced zero findings
"""
from __future__ import annotations

import os
import time

from benchmarks.common import csv_row

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(fast: bool = False) -> None:
    from repro.analysis import conventions
    from repro.analysis import jaxpr_lint

    t0 = time.perf_counter()
    paths = [os.path.join(REPO_ROOT, p)
             for p in ("src", "tests", "benchmarks")]
    conv = conventions.run_pass(paths, repo_root=REPO_ROOT)
    csv_row("analysis/conventions", (time.perf_counter() - t0) * 1e6,
            f"{len(conv)} findings")

    t0 = time.perf_counter()
    # SPMD entry points need a forced multi-device platform; auto-detect
    # keeps this runnable in the default 1-device CI session.
    jx = jaxpr_lint.run_pass()
    csv_row("analysis/jaxpr", (time.perf_counter() - t0) * 1e6,
            f"{len(jx)} findings")

    clean = int(not conv and not jx)
    csv_row("analysis/clean", 0.0, str(clean))
    if not clean:
        for f in conv + jx:
            print(f"#   {f.render()}")
        raise RuntimeError(f"{len(conv) + len(jx)} analysis finding(s)")


if __name__ == "__main__":
    run(fast=True)
