"""§II-C communication-cost model + the paper's headline efficiency claim:
at matched error, DeKRR-DDRF needs far fewer features per node than DKLA
(paper: D=20 vs D=100 on houses). Also measures per-iteration wall time of
the jitted batched runtime and its Σ|N_j|·D cost model."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common as C
from repro.core import DeKRRConfig, DeKRRSolver, select_features
from repro.dist import comm_bytes_per_round, pack_problem, solve_batched


def matched_error_features(dataset="houses", fast=False):
    ds, train, test = C.load_split(dataset, mode="noniid_y")
    # DKLA reference error at D=100
    r_ref, _, _ = C.mean_over_seeds(
        lambda s: C.run_dkla(ds, train, test, 100, seed=90 + s))
    grid = (10, 20, 40, 80) if not fast else (20,)
    d_needed = None
    for d in grid:
        r, _, _ = C.mean_over_seeds(
            lambda s: C.run_dekrr_ddrf(ds, train, test, d, seed=s), seeds=2)
        if r <= r_ref * 1.05:
            d_needed = d
            break
    C.csv_row(
        f"comm/matched_error/{dataset}", 0.0,
        f"DKLA_D=100;DKLA_RSE={r_ref:.4f};ours_D={d_needed};"
        f"comm_reduction={'%.1fx' % (100 / d_needed) if d_needed else 'n/a'};"
        f"paper_claims=5x(D100->D20)")
    return d_needed


def iteration_cost(dataset="houses", d_feat=32):
    ds, train, test = C.load_split(dataset, mode="noniid_y")
    keys = jax.random.split(jax.random.PRNGKey(0), C.J)
    fmaps = [select_features(keys[j], ds.dim, d_feat, C.SIGMA, train[j].x,
                             train[j].y, method="energy")
             for j in range(C.J)]
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(C.TOPOLOGY, fmaps, train,
                         DeKRRConfig(lam=C.LAM, c_nei=0.01 * n))
    packed = pack_problem(solver)
    # jitted batched iteration wall time
    solve_batched(packed, 10).block_until_ready()        # warmup
    t0 = time.perf_counter()
    reps, iters = 5, 100
    for _ in range(reps):
        solve_batched(packed, iters).block_until_ready()
    us = (time.perf_counter() - t0) / (reps * iters) * 1e6
    bytes_pp = comm_bytes_per_round(packed, "ppermute")
    bytes_ag = comm_bytes_per_round(packed, "allgather")
    C.csv_row(
        f"comm/iteration/{dataset}", us,
        f"D={d_feat};ppermute_bytes_per_round={bytes_pp};"
        f"allgather_bytes_per_round={bytes_ag};"
        f"cost_model=sum_j|N_j|D_j={C.J * 4 * d_feat}")


def run(fast=False):
    matched_error_features(fast=fast)
    iteration_cost()


if __name__ == "__main__":
    run()
