"""Kernel microbench: fused RFF+Gram oracle timing on CPU + the analytic
TPU roofline of the Pallas kernel (VMEM working set, arithmetic intensity).

interpret=True executes the kernel body in Python per block — useful for
correctness, meaningless for timing — so wall time is measured on the jnp
oracle and the TPU projection is analytic (documented)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.kernels.ref import rff_gram_ref
from repro.launch.mesh import HBM_BANDWIDTH, PEAK_FLOPS_BF16

CASES = [
    # (D_feat, d_in, N) — paper-scale Gram builds
    (100, 8, 10000),
    (200, 148, 30000),
    (512, 96, 30000),
]


def analytic(d_feat, d_in, n, block_n=1024, dtype_bytes=4):
    flops = 2 * d_feat * d_in * n + 2 * d_feat * d_feat * n  # proj + gram
    hbm = (d_in * n + d_feat * d_in + d_feat * d_feat) * dtype_bytes
    vmem = (d_feat * d_in + d_in * block_n + d_feat * block_n
            + d_feat * d_feat) * dtype_bytes
    intensity = flops / hbm
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = hbm / HBM_BANDWIDTH
    return flops, hbm, vmem, intensity, max(t_compute, t_memory)


def run(fast=False):
    cases = CASES[:1] if fast else CASES
    for d_feat, d_in, n in cases:
        key = jax.random.PRNGKey(0)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        omega = jax.random.normal(k1, (d_feat, d_in), jnp.float32)
        bias = jax.random.uniform(k2, (d_feat,), jnp.float32)
        x = jax.random.uniform(k3, (d_in, n), jnp.float32)
        y = jax.random.normal(k4, (n,), jnp.float32)
        scale = float(np.sqrt(2.0 / d_feat))

        f = jax.jit(lambda *a: rff_gram_ref(*a, scale=scale))
        f(omega, bias, x, y)[0].block_until_ready()
        t0 = time.perf_counter()
        reps = 10
        for _ in range(reps):
            f(omega, bias, x, y)[0].block_until_ready()
        us = (time.perf_counter() - t0) / reps * 1e6

        flops, hbm, vmem, ai, t_tpu = analytic(d_feat, d_in, n)
        C.csv_row(
            f"kernel/rff_gram/D{d_feat}_d{d_in}_N{n}", us,
            f"flops={flops:.2e};hbm_bytes={hbm:.2e};vmem={vmem/2**20:.2f}MiB;"
            f"arith_intensity={ai:.1f};tpu_roofline_us={t_tpu*1e6:.1f};"
            f"fits_vmem={vmem < 16 * 2**20}")


if __name__ == "__main__":
    run()
