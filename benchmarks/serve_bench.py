"""Production serving-tier benchmark, emitting ``BENCH_serve.json``.

Drives the replica serving stack (`repro.serve.DeKRRReplicaServer`: N
replicas off a `SnapshotRegistry`, continuous column-bucketed batching,
optional mixed-precision answers) with a Poisson OPEN-LOOP load
generator — arrivals are scheduled by an exponential clock independent
of service completions, so queueing shows up in the percentiles the way
a caller would see it — and reports the qps × p50/p99 × answer-error
frontier:

  * closed-loop capacity: the replica server vs two single-engine
    baselines — the same wave-batched engine (upper baseline; on a
    multi-core host replicas beat it by overlapping waves, on a 1-CPU
    host they tie) and the pre-continuous-batching serving discipline of
    one query answered at a time (`batch_size=1`, the "~620 qps single
    process" shape this PR replaces). The acceptance gate is
    replica_qps > sequential single-engine qps.
  * open-loop frontier: for each precision (fp64 ref, bf16, int8) and
    each offered load (fractions of measured capacity), the achieved
    qps, p50/p99 latency, and the answer-error columns — max measured
    |f_served − f_hi| against a full-precision reference serve of the
    same queries, max attached `StalenessBound.precision`, and the
    within-bound check. EVERY low-precision answer must be within its
    attached bound or the bench fails.

Timings are CPU/interpret-grade on the dev box (placeholders for TPU
numbers, like the other benches); the bound checks and frontier shape
are backend-independent.

Run directly with ``--smoke`` (reduced sizes; used by CI) or through
``python -m benchmarks.run --only serve``.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import numpy as np

from benchmarks import common as C
from repro.core import DeKRRConfig, DeKRRSolver, select_features
from repro.serve import DeKRRReplicaServer, DeKRRServeEngine, KernelQuery
from repro.stream import SnapshotRegistry, StreamConfig, StreamingDeKRR

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_serve.json")

LAM = 1e-3
TOL = 1e-8
BATCH = 16          # wave slots — small enough that waves keep forming
                    # under open-loop load instead of one giant batch
REPLICAS = 2


def _build_snapshot(subsample: int):
    """Solve the paper's J=10 circulant network once and freeze θ."""
    ds, train, test = C.load_split("air_quality")
    if subsample < C.SUBSAMPLE:
        from repro.core import NodeData
        train = [NodeData(x=t.x[:, :max(subsample // C.J, 8)],
                          y=t.y[:max(subsample // C.J, 8)])
                 for t in train]
    keys = jax.random.split(jax.random.PRNGKey(0), C.J)
    dims = [16 + 4 * (j % 3) for j in range(C.J)]
    fmaps = [select_features(keys[j], ds.dim, dims[j], C.SIGMA, train[j].x,
                             train[j].y, method="energy", candidate_ratio=5)
             for j in range(C.J)]
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(C.TOPOLOGY, fmaps, train,
                         DeKRRConfig(lam=LAM, c_nei=0.02 * n),
                         build_aux=False)
    rt = StreamingDeKRR(solver, StreamConfig(rounds_per_epoch=2000,
                                             tol=TOL))
    rt.solve()
    return rt.snapshot(), (ds, test)


def _queries(n: int, d: int, xs: np.ndarray, seed: int = 0):
    rng = np.random.default_rng(seed)
    cols = xs.shape[1]
    return [KernelQuery(uid=i, x=np.asarray(xs[:, i % cols])
                        + 0.01 * rng.normal(size=d))
            for i in range(n)]


def _closed_qps(run_fn, n: int, reps: int) -> float:
    best = 0.0
    for _ in range(reps):
        t0 = time.perf_counter()
        run_fn()
        best = max(best, n / (time.perf_counter() - t0))
    return best


def _open_loop(server: DeKRRReplicaServer, queries, rate: float,
               rng: np.random.Generator):
    """Poisson open-loop drive: submit each query at its exponential
    arrival time regardless of service progress, then drain."""
    server.latency.reset()
    server.start()
    t_next = time.perf_counter()
    try:
        for q in queries:
            t_next += rng.exponential(1.0 / rate)
            while True:
                dt = t_next - time.perf_counter()
                if dt <= 0:
                    break
                time.sleep(min(dt, 0.0005))
            server.submit(q)
    finally:
        server.stop()
    return server.report()


def run(fast: bool = False) -> None:
    snap, (ds, test) = _build_snapshot(600 if fast else 1500)
    reg = SnapshotRegistry()
    reg.publish(snap)
    xs = np.asarray(test[0].x)
    d = ds.dim
    n_cap = 200 if fast else 600
    n_open = 60 if fast else 300
    reps = 2 if fast else 3

    results: dict = {
        "benchmark": ("replica serving tier: closed-loop capacity vs "
                      "single-engine baselines, Poisson open-loop "
                      "qps x p50/p99 x answer-error frontier"),
        "backend": jax.default_backend(),
        "cpu_count": os.cpu_count(),
        "j_nodes": len(snap.feature_maps),
        "batch_size": BATCH,
        "replicas": REPLICAS,
        "staleness_residual": snap.staleness.residual,
    }

    # -- closed-loop capacity ---------------------------------------------
    # warm every pad bucket the runs will hit (full + tail waves) before
    # timing, so no compile lands inside a measured region
    warm = _queries(BATCH + BATCH // 2, d, xs, seed=9)
    eng_batched = DeKRRServeEngine(snap, batch_size=BATCH)
    eng_batched.run(list(warm))
    eng_seq = DeKRRServeEngine(snap, batch_size=1)
    eng_seq.run(list(warm[:4]))
    srv = DeKRRReplicaServer(reg, replicas=REPLICAS, batch_size=BATCH)
    srv.run(list(warm))

    seq_qps = _closed_qps(
        lambda: eng_seq.run(_queries(n_cap // 4, d, xs)), n_cap // 4, reps)
    batched_qps = _closed_qps(
        lambda: eng_batched.run(_queries(n_cap, d, xs)), n_cap, reps)
    replica_qps = _closed_qps(
        lambda: srv.run(_queries(n_cap, d, xs)), n_cap, reps)
    results["closed_loop"] = {
        "single_engine_sequential_qps": round(seq_qps, 1),
        "single_engine_batched_qps": round(batched_qps, 1),
        "replica_qps": round(replica_qps, 1),
        "speedup_vs_sequential": round(replica_qps / seq_qps, 2),
    }
    C.csv_row("serve/seq_baseline", 1e6 / seq_qps, f"qps={seq_qps:.1f}")
    C.csv_row("serve/batched_engine", 1e6 / batched_qps,
              f"qps={batched_qps:.1f}")
    C.csv_row("serve/replicas", 1e6 / replica_qps,
              f"qps={replica_qps:.1f};replicas={REPLICAS}")
    if replica_qps <= seq_qps:
        raise RuntimeError(
            f"multi-replica serving ({replica_qps:.1f} qps) must beat the "
            f"single-engine sequential baseline ({seq_qps:.1f} qps)")

    # -- full-precision reference answers for the error columns -----------
    ref_engine = DeKRRServeEngine(snap, batch_size=BATCH)

    # -- Poisson open-loop frontier ---------------------------------------
    frontier = []
    servers = {}
    for precision in (None, "bf16", "int8"):
        s = DeKRRReplicaServer(reg, replicas=REPLICAS, batch_size=BATCH,
                               precision=precision)
        s.run(list(_queries(BATCH + BATCH // 2, d, xs, seed=9)))  # warm
        servers[precision] = s
    for precision in (None, "bf16", "int8"):
        server = servers[precision]
        # each precision is driven relative to its OWN closed-loop
        # capacity (CPU bf16/int8 are emulated and far slower than the
        # TPU fast path; offered load must track the path under test)
        cap = _closed_qps(
            lambda: server.run(_queries(n_cap // 2, d, xs)),
            n_cap // 2, 1)
        for frac in (0.3, 0.6, 0.9):
            rate = max(frac * cap, 1.0)
            rng = np.random.default_rng(int(frac * 100))
            queries = _queries(n_open, d, xs, seed=int(frac * 100))
            rep = _open_loop(server, queries, rate, rng)
            row = {
                "precision": precision or "fp64",
                "capacity_qps": round(cap, 1),
                "offered_qps": round(rate, 1),
                "achieved_qps": round(rep.qps, 1),
                "count": rep.count,
                "p50_ms": round(rep.p50 * 1e3, 3),
                "p99_ms": round(rep.p99 * 1e3, 3),
            }
            if precision is not None:
                ref = ref_engine.run(
                    [KernelQuery(uid=q.uid, x=np.array(q.x))
                     for q in queries])
                errs = np.array([
                    np.max(np.abs(np.asarray(q.prediction, np.float64)
                                  - np.asarray(r.prediction, np.float64)))
                    for q, r in zip(queries, ref)])
                bounds = np.array([q.staleness.precision for q in queries])
                row["max_answer_error"] = float(errs.max())
                row["mean_answer_error"] = float(errs.mean())
                row["max_precision_bound"] = float(bounds.max())
                row["all_within_bound"] = bool((errs <= bounds).all())
                if not row["all_within_bound"]:
                    bad = int(np.argmax(errs - bounds))
                    raise RuntimeError(
                        f"{precision} answer uid {queries[bad].uid}: "
                        f"measured error {errs[bad]} exceeds attached "
                        f"precision bound {bounds[bad]}")
            else:
                row["max_answer_error"] = 0.0
            frontier.append(row)
            C.csv_row(
                f"serve/open_{row['precision']}_f{int(frac * 100)}",
                row["p99_ms"] * 1e3,
                f"qps={row['achieved_qps']};p50_ms={row['p50_ms']};"
                f"err={row['max_answer_error']:.2e}")
    results["frontier"] = frontier

    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"serve/json,0.0,wrote={os.path.relpath(OUT_PATH, REPO_ROOT)}")


if __name__ == "__main__":
    run(fast=("--fast" in sys.argv) or ("--smoke" in sys.argv))
