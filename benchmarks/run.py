"""Benchmark runner — one benchmark per paper table/figure plus the kernel
microbench, the §II-C communication-cost model, the §III convergence check
and the roofline aggregation. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Observability (repro.obs):

  * every suite runs inside a host-side span (``bench.<name>``) recorded
    into one `repro.obs.metrics.Registry`, exported as JSONL to
    ``--obs-jsonl`` (default ``BENCH_run.jsonl``) — render it with
    ``python -m repro.obs BENCH_run.jsonl``;
  * every ``BENCH_*.json`` artifact in the repo root is stamped with a
    run-provenance block (git sha, jax version, device kind, platform,
    interpret flag) after the suites finish;
  * ``--profile-dir DIR`` wraps the whole run in a ``jax.profiler``
    trace for TensorBoard/Perfetto inspection.
"""
import argparse
import glob
import os
import sys
import traceback

import jax

jax.config.update("jax_enable_x64", True)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced grids (CI budget)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--obs-jsonl", default=os.path.join(REPO_ROOT,
                                                        "BENCH_run.jsonl"),
                    help="telemetry JSONL output ('' disables)")
    ap.add_argument("--profile-dir", default=None,
                    help="write a jax.profiler trace to this directory")
    args = ap.parse_args()

    from benchmarks import (ablation_ddrf, accel_bench, analysis_bench,
                            async_gossip_bench, chebyshev_bench, comm_costs,
                            convergence_curve, kernel_bench, multiout_bench,
                            paper_fig1_noniid_y, paper_fig2_noniid_xnorm,
                            paper_fig3_imbalanced, paper_fig4_pernode,
                            paper_table2, roofline, serve_bench, solve_bench,
                            step_kernel_bench, stream_bench)
    from repro.obs.export import provenance, stamp_provenance, write_jsonl
    from repro.obs.metrics import Registry, perf_clock
    from repro.obs.spans import recording, span

    suites = {
        "table2": paper_table2.run,
        "fig1": paper_fig1_noniid_y.run,
        "fig2": paper_fig2_noniid_xnorm.run,
        "fig3": paper_fig3_imbalanced.run,
        "fig4": paper_fig4_pernode.run,
        "comm": comm_costs.run,
        "convergence": convergence_curve.run,
        "ablation": ablation_ddrf.run,
        "chebyshev": chebyshev_bench.run,
        "accel": accel_bench.run,
        "kernel": kernel_bench.run,
        "step": step_kernel_bench.run,
        "solve": solve_bench.run,
        "async": async_gossip_bench.run,
        "multiout": multiout_bench.run,
        "stream": stream_bench.run,
        "serve": serve_bench.run,
        "roofline": roofline.run,
        "analysis": analysis_bench.run,
    }
    registry = Registry(clock=perf_clock)
    if args.profile_dir:
        jax.profiler.start_trace(args.profile_dir)
    print("name,us_per_call,derived")
    failed = []
    with recording(registry):
        for name, fn in suites.items():
            if args.only and name != args.only:
                continue
            t0 = perf_clock()
            try:
                with span(f"bench.{name}", fast=bool(args.fast)):
                    fn(fast=args.fast)
            except Exception as e:  # noqa: BLE001 — run every suite
                failed.append((name, repr(e)))
                traceback.print_exc()
                registry.counter("bench.suites_failed").inc()
                print(f"{name}/FAILED,0.0,{e!r}")
            dt = perf_clock() - t0
            registry.counter("bench.suites_run").inc()
            registry.histogram("bench.suite_seconds").observe(dt)
            print(f"{name}/total,{dt*1e6:.0f},done", flush=True)
    if args.profile_dir:
        jax.profiler.stop_trace()
    prov = provenance(interpret=jax.default_backend() == "cpu",
                      extra={"fast": bool(args.fast), "only": args.only})
    stamped = [p for p in sorted(glob.glob(os.path.join(REPO_ROOT,
                                                        "BENCH_*.json")))
               if stamp_provenance(p, prov)]
    if stamped:
        print(f"stamped provenance into {len(stamped)} artifact(s)",
              file=sys.stderr)
    if args.obs_jsonl:
        write_jsonl(registry, args.obs_jsonl, prov)
        print(f"telemetry written to {args.obs_jsonl}", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
