"""Benchmark runner — one benchmark per paper table/figure plus the kernel
microbench, the §II-C communication-cost model, the §III convergence check
and the roofline aggregation. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]
"""
import argparse
import sys
import time
import traceback

import jax

jax.config.update("jax_enable_x64", True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced grids (CI budget)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (ablation_ddrf, accel_bench, analysis_bench,
                            async_gossip_bench, chebyshev_bench, comm_costs,
                            convergence_curve, kernel_bench, multiout_bench,
                            paper_fig1_noniid_y, paper_fig2_noniid_xnorm,
                            paper_fig3_imbalanced, paper_fig4_pernode,
                            paper_table2, roofline, serve_bench, solve_bench,
                            step_kernel_bench, stream_bench)

    suites = {
        "table2": paper_table2.run,
        "fig1": paper_fig1_noniid_y.run,
        "fig2": paper_fig2_noniid_xnorm.run,
        "fig3": paper_fig3_imbalanced.run,
        "fig4": paper_fig4_pernode.run,
        "comm": comm_costs.run,
        "convergence": convergence_curve.run,
        "ablation": ablation_ddrf.run,
        "chebyshev": chebyshev_bench.run,
        "accel": accel_bench.run,
        "kernel": kernel_bench.run,
        "step": step_kernel_bench.run,
        "solve": solve_bench.run,
        "async": async_gossip_bench.run,
        "multiout": multiout_bench.run,
        "stream": stream_bench.run,
        "serve": serve_bench.run,
        "roofline": roofline.run,
        "analysis": analysis_bench.run,
    }
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        t0 = time.perf_counter()
        try:
            fn(fast=args.fast)
        except Exception as e:  # noqa: BLE001 — run every suite
            failed.append((name, repr(e)))
            traceback.print_exc()
            print(f"{name}/FAILED,0.0,{e!r}")
        print(f"{name}/total,{(time.perf_counter()-t0)*1e6:.0f},done",
              flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
