"""Multi-output DeKRR: fused Dy-batched solve vs a per-output scalar loop,
emitting ``BENCH_multiout.json`` for the perf trajectory.

The Eq. 17 auxiliaries are label-free, so a Dy-output problem CAN be
solved as Dy independent scalar solves — that loop is the baseline this
bench prices. The fused path packs labels/θ as [J, D_max, Dy] and runs
ONE solve whose kernels carry Dy as extra flattened θ-table row blocks,
so the G/S/P operand traffic (the dominant term: (2+K)·D² per node per
round) is paid once instead of Dy times, and the dispatch count is
UNCHANGED — the per-output loop pays Dy× the dispatches.

Per backend × Dy the bench records:

  * fused_us / loop_us — wall time of the Dy-batched solve vs Dy scalar
    solves of the column-sliced problems (identical data; the two agree
    at rtol 1e-9 by tests/test_multioutput.py, asserted here too);
  * dispatches_fused / dispatches_loop — static pallas_call counts of the
    traced programs (the same `count_pallas_dispatches` counter the J002
    lint pins): fused keeps the scalar contract {xla: 0, pallas: R,
    pallas_fused: 1} at every Dy, the loop multiplies it by Dy.

On CPU the Pallas columns run in interpret mode — Python-evaluated kernel
bodies whose wall time means nothing — so they are labeled placeholders
(`*_us_placeholder`); the dispatch counts and the XLA timings are real
everywhere. Run on TPU to fill the kernel timing columns.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import DeKRRConfig, DeKRRSolver, NodeData, sample_rff
from repro.dist import pack_problem, solve_batched

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_multiout.json")

BACKENDS = ("xla", "pallas", "pallas_fused")


def _build_packs(dy: int, j_nodes: int, d_feat: int, n_per_node: int):
    """(fused Dy-output pack, per-output scalar packs) on identical data.

    Synthetic random draws: parity is exact algebra and the bench prices
    operand traffic, so dataset realism buys nothing here.
    """
    from repro.core import circulant

    topo = circulant(j_nodes, (1, 2))
    rng = np.random.default_rng(0)
    fmaps = [sample_rff(jax.random.PRNGKey(j), 4, d_feat, C.SIGMA)
             for j in range(j_nodes)]
    xs = [rng.normal(size=(4, n_per_node)) for _ in range(j_nodes)]
    ys = [rng.normal(size=(n_per_node, dy)) for _ in range(j_nodes)]

    def pack(cols):
        data = [NodeData(x=jnp.asarray(x),
                         y=jnp.asarray(y if cols is None else y[:, cols]))
                for x, y in zip(xs, ys)]
        solver = DeKRRSolver(topo, fmaps, data,
                             DeKRRConfig(lam=0.1, c_nei=1.0),
                             build_aux=False)
        return pack_problem(solver)

    return pack(None), [pack(o) for o in range(dy)]


def _dispatch_counts(rounds: int, dy: int) -> dict:
    """Static pallas_call counts (the J002 counter) of the fused Dy solve
    vs the per-output loop, traced on the synthetic packed problem."""
    from repro.analysis import jaxpr_lint as JL

    fused_pk = JL.synthetic_packed(dy=dy)
    scalar_pk = JL.synthetic_packed()
    out = {}
    for b in BACKENDS:
        fused, fused_exact = JL.count_pallas_dispatches(jax.make_jaxpr(
            lambda pk, b=b: solve_batched(pk, rounds,
                                          backend=b))(fused_pk))
        one, one_exact = JL.count_pallas_dispatches(jax.make_jaxpr(
            lambda pk, b=b: solve_batched(pk, rounds,
                                          backend=b))(scalar_pk))
        assert fused_exact and one_exact
        out[b] = {"fused": fused, "loop": dy * one}
    return out


def _time(fn, reps: int) -> float:
    jax.block_until_ready(fn())                 # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / reps * 1e6


def run(fast: bool = False) -> None:
    rounds = 10 if fast else 30
    j_nodes, d_feat = (6, 16) if fast else (10, 32)
    n_per_node = 40 if fast else 120
    dys = (1, 4) if fast else (1, 4, 8)
    reps = 1 if fast else 3
    interpret_mode = jax.default_backend() != "tpu"

    results = []
    for dy in dys:
        fused_pk, scalar_pks = _build_packs(dy, j_nodes, d_feat,
                                            n_per_node)
        dispatches = _dispatch_counts(rounds, dy)
        row = {"dy": dy, "backends": {}}
        for b in BACKENDS:
            th_fused = solve_batched(fused_pk, rounds, backend=b)
            th_loop = jnp.stack(
                [solve_batched(pk, rounds, backend=b)
                 for pk in scalar_pks], axis=2)
            np.testing.assert_allclose(np.asarray(th_fused),
                                       np.asarray(th_loop),
                                       rtol=1e-9, atol=1e-12)

            fused_us = _time(
                lambda b=b: solve_batched(fused_pk, rounds, backend=b),
                reps)
            loop_us = _time(
                lambda b=b: [solve_batched(pk, rounds, backend=b)
                             for pk in scalar_pks], reps)
            placeholder = interpret_mode and b != "xla"
            key = "us_placeholder" if placeholder else "us"
            row["backends"][b] = {
                f"fused_{key}": round(fused_us, 1),
                f"loop_{key}": round(loop_us, 1),
                "speedup": round(loop_us / max(fused_us, 1e-9), 2),
                "dispatches_fused": dispatches[b]["fused"],
                "dispatches_loop": dispatches[b]["loop"],
            }
            C.csv_row(
                f"multiout/dy{dy}/{b}", fused_us,
                f"loop_us={loop_us:.1f};"
                f"dispatches={dispatches[b]['fused']}"
                f"vs{dispatches[b]['loop']}"
                f"{';interpret-placeholder' if placeholder else ''}")
        results.append(row)

    payload = {
        "benchmark": ("multi-output DeKRR: fused Dy-batched solve vs "
                      "per-output scalar loop (identical data, rtol-1e-9 "
                      "parity asserted per row)"),
        "backend": jax.default_backend(),
        "interpret_mode": interpret_mode,
        "j_nodes": j_nodes,
        "d_feat": d_feat,
        "rounds": rounds,
        "note": ("dispatch counts are static pallas_call counts of the "
                 "traced programs (the J002 counter) — the fused path "
                 "keeps the scalar round_dispatches contract at every Dy, "
                 "the loop pays Dy× it. *_us_placeholder columns are "
                 "interpret-mode (CPU) wall times: kernel dispatch "
                 "semantics, meaningless absolute numbers — run on TPU "
                 "for real kernel timings."),
        "results": results,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"multiout/json,0.0,wrote={os.path.relpath(OUT_PATH, REPO_ROOT)}")


if __name__ == "__main__":
    run(fast=("--fast" in sys.argv) or ("--smoke" in sys.argv))
