"""Fig. 3: imbalanced data (N_j = (2j−1)N/100) on twitter — equal D_j vs
√N_j-proportional D_j at the same total communication budget."""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.data.synthetic import imbalanced_sizes, make_dataset, partition, \
    train_test_split_nodes

DBARS = (40, 100)


def sqrt_proportional_d(train, dbar):
    """D_j = √N_j · J·D̄ / Σ√N_j (paper §IV-B2), rounded, ≥4."""
    ns = np.array([t.num_samples for t in train], float)
    w = np.sqrt(ns)
    d = np.maximum((w * len(train) * dbar / w.sum()).round().astype(int), 4)
    return d.tolist()


def run(dataset="twitter", dbars=DBARS, fast=False):
    if fast:
        dbars = dbars[:1]
    ds = make_dataset(dataset, subsample=C.SUBSAMPLE, seed=0)
    sizes = imbalanced_sizes(ds.num_samples, C.J)
    nodes = partition(ds, C.J, mode="iid", sizes=sizes, seed=0)
    train, test = train_test_split_nodes(nodes, seed=0)

    out = []
    for dbar in dbars:
        r_dkla, _, _ = C.mean_over_seeds(
            lambda s: C.run_dkla(ds, train, test, dbar, seed=80 + s))
        r_dd, _, _ = C.mean_over_seeds(
            lambda s: C.run_dkla(ds, train, test, dbar, ddrf=True,
                                 seed=80 + s))
        r_eq, _, _ = C.mean_over_seeds(
            lambda s: C.run_dekrr_ddrf(ds, train, test, dbar, seed=s))
        d_var = sqrt_proportional_d(train, dbar)
        r_var, _, t = C.mean_over_seeds(
            lambda s: C.run_dekrr_ddrf(ds, train, test, d_var, seed=s))
        out.append((dbar, r_dkla, r_dd, r_eq, r_var))
        C.csv_row(
            f"fig3/{dataset}/D{dbar}", t * 1e6,
            f"DKLA={r_dkla:.4f};DKLA-DDRF={r_dd:.4f};ours-eq={r_eq:.4f};"
            f"ours-sqrtN={r_var:.4f};comm_budget_equal=True")
    return out


if __name__ == "__main__":
    run()
