"""Fig. 2: RSE vs D̄, non-IID by input norm ‖x‖₂."""
from __future__ import annotations

from benchmarks import common as C

DBARS = (20, 40, 80)


def run(datasets=("houses", "energy"), dbars=DBARS, fast=False):
    if fast:
        datasets, dbars = datasets[:1], dbars[:2]
    out = []
    for name in datasets:
        ds, train, test = C.load_split(name, mode="noniid_xnorm")
        for dbar in dbars:
            r_dkla, _, _ = C.mean_over_seeds(
                lambda s: C.run_dkla(ds, train, test, dbar, seed=70 + s))
            r_ours, sd, t = C.mean_over_seeds(
                lambda s: C.run_dekrr_ddrf(ds, train, test, dbar, seed=s))
            out.append((name, dbar, r_dkla, r_ours))
            C.csv_row(f"fig2/{name}/D{dbar}", t * 1e6,
                      f"DKLA={r_dkla:.4f};ours={r_ours:.4f};std={sd:.4f}")
    return out


if __name__ == "__main__":
    run()
