"""Fig. 4: per-node RSE under the imbalanced split (D̄=100). Shows the
big-data nodes (j=6..10) improving when D_j ∝ √N_j."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common as C
from benchmarks.paper_fig3_imbalanced import sqrt_proportional_d
from repro.core import DeKRRConfig, DeKRRSolver, rse, select_features
from repro.data.synthetic import imbalanced_sizes, make_dataset, partition, \
    train_test_split_nodes


def run(dataset="twitter", dbar=100, fast=False):
    if fast:
        dbar = 40
    ds = make_dataset(dataset, subsample=C.SUBSAMPLE, seed=0)
    sizes = imbalanced_sizes(ds.num_samples, C.J)
    nodes = partition(ds, C.J, mode="iid", sizes=sizes, seed=0)
    train, test = train_test_split_nodes(nodes, seed=0)
    n = sum(t.num_samples for t in train)

    def per_node_rse(d_per_node):
        keys = jax.random.split(jax.random.PRNGKey(0), C.J)
        fmaps = [select_features(keys[j], ds.dim, d_per_node[j], C.SIGMA,
                                 train[j].x, train[j].y, method="energy")
                 for j in range(C.J)]
        solver = DeKRRSolver(C.TOPOLOGY, fmaps, train,
                             DeKRRConfig(lam=C.LAM, c_nei=0.01 * n))
        st = solver.solve_exact()
        return [rse(solver.predict(st.theta, test[j].x, node=j), test[j].y)
                for j in range(C.J)]

    eq = per_node_rse([dbar] * C.J)
    var = per_node_rse(sqrt_proportional_d(train, dbar))
    big_eq = float(np.mean(eq[5:]))
    big_var = float(np.mean(var[5:]))
    C.csv_row(f"fig4/{dataset}", 0.0,
              f"per_node_eq={[round(v,3) for v in eq]};"
              f"per_node_sqrtN={[round(v,3) for v in var]};"
              f"bignode_eq={big_eq:.4f};bignode_sqrtN={big_var:.4f}")
    return eq, var


if __name__ == "__main__":
    run()
