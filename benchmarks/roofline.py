"""Aggregate the dry-run JSONs into the §Roofline table (no compilation —
reads experiments/dryrun/*.json produced by repro.launch.dryrun)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks import common as C

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def rows(mesh="16x16"):
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR,
                                              f"{mesh}_*.json"))):
        r = json.load(open(path))
        out.append(r)
    return out


def run(fast=False):
    found = rows()
    if not found:
        C.csv_row("roofline/missing", 0.0,
                  f"no dryrun artifacts in {DRYRUN_DIR}; "
                  "run: python -m repro.launch.dryrun --all")
        return
    for r in found:
        if r.get("status") == "skip":
            C.csv_row(f"roofline/{r['arch']}/{r['shape']}", 0.0,
                      f"plan=skip({r['plan']})")
            continue
        C.csv_row(
            f"roofline/{r['arch']}/{r['shape']}", 0.0,
            f"compute_ms={r['compute_s']*1e3:.2f};"
            f"memory_ms={r['memory_s']*1e3:.2f};"
            f"collective_ms={r['collective_s']*1e3:.2f};"
            f"dominant={r['dominant']};"
            f"mem_per_dev_GiB={(r['peak_memory_per_device'] or 0)/2**30:.2f};"
            f"useful_flops={r['useful_flops_ratio']:.2f}")


if __name__ == "__main__":
    run()
