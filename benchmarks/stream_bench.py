"""Streaming DeKRR runtime benchmark, emitting ``BENCH_stream.json``.

Four numbers characterize the online subsystem (`repro.stream` +
`repro.serve.dekrr`) on the paper's J = 10 circulant(1, 2) network:

  * ingest_us — wall time to fold one minibatch into the Eq. 17
    auxiliaries by rank-b Woodbury updates (per batch size b). The
    comparison column rebuild_us times the from-scratch
    `pack_problem` on the same accumulated data — the cost the
    incremental path avoids on EVERY minibatch.
  * refresh_ms — one drift-triggered DDRF re-selection + single-slot
    rebuild (featurizes the node's and neighbors' accumulated data; the
    rare event, so it is allowed to be ~rebuild-shaped for one node).
  * warm vs cold rounds-to-tol — the acceptance-criterion measurement:
    after a wave of ingests, the consensus continuation from the carried
    θ versus from zeros on the SAME packed operator, same tol. Warm must
    reach tol in measurably fewer rounds.
  * serve_qps — queries/second through `DeKRRServeEngine`'s wave
    batching (network-average answers, staleness bounds attached).

Timings are CPU/interpret-grade on the dev box (placeholders for TPU
numbers, like the other kernel benches); the ROUND COUNTS and exactness
are backend-independent.

Run directly with ``--smoke`` (reduced sizes; used by CI) or through
``python -m benchmarks.run --only stream``.
"""
from __future__ import annotations

import json
import os
import sys
import time

import jax
import numpy as np

from benchmarks import common as C
from repro.core import DeKRRConfig, DeKRRSolver, select_features
from repro.dist import pack_problem, solve_batched
from repro.serve import DeKRRServeEngine, KernelQuery
from repro.stream import StreamConfig, StreamingDeKRR, ingest as fold

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_stream.json")

LAM = 1e-3      # streaming bench keeps cond(A) moderate (same rationale
                # as tests/test_stream.py)
TOL = 1e-8


def _build_runtime(subsample: int) -> tuple[StreamingDeKRR, object]:
    ds, train, test = C.load_split("air_quality")
    if subsample < C.SUBSAMPLE:
        from repro.core import NodeData
        train = [NodeData(x=t.x[:, :max(subsample // C.J, 8)],
                          y=t.y[:max(subsample // C.J, 8)])
                 for t in train]
    keys = jax.random.split(jax.random.PRNGKey(0), C.J)
    dims = [16 + 4 * (j % 3) for j in range(C.J)]
    fmaps = [select_features(keys[j], ds.dim, dims[j], C.SIGMA, train[j].x,
                             train[j].y, method="energy", candidate_ratio=5)
             for j in range(C.J)]
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(C.TOPOLOGY, fmaps, train,
                         DeKRRConfig(lam=LAM, c_nei=0.02 * n),
                         build_aux=False)
    rt = StreamingDeKRR(solver, StreamConfig(rounds_per_epoch=2000,
                                             tol=TOL))
    return rt, (ds, test)


def _time_us(fn, reps: int) -> float:
    fn()                                    # warm up / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def run(fast: bool = False) -> None:
    reps = 3 if fast else 10
    rt, (ds, test) = _build_runtime(600 if fast else 2000)
    rng = np.random.default_rng(0)
    results: dict = {
        "benchmark": ("streaming DeKRR: Woodbury ingest, refresh latency, "
                      "warm vs cold rounds-to-tol, serve throughput"),
        "backend": jax.default_backend(),
        "j_nodes": rt.num_nodes,
        "d_max": rt.aux.max_features,
        "n_initial": rt.aux.n_live,
        "tol": TOL,
    }

    # -- ingest throughput: Woodbury fold vs from-scratch pack rebuild ----
    ingest_rows = []
    for b in (8, 32):
        xb = rng.normal(size=(ds.dim, b))
        yb = rng.normal(size=b)
        aux_probe = rt.aux

        def one_fold():
            jax.block_until_ready(fold(aux_probe, 0, xb, yb).binv)

        fold_us = _time_us(one_fold, reps)
        ingest_rows.append({"batch": b, "ingest_us": round(fold_us, 1),
                            "samples_per_sec":
                            round(b / (fold_us * 1e-6), 1)})
        C.csv_row(f"stream/ingest_b{b}", fold_us,
                  f"samples_per_sec={ingest_rows[-1]['samples_per_sec']}")

    ref = rt.reference_solver()

    def one_rebuild():
        jax.block_until_ready(pack_problem(ref).g)

    rebuild_us = _time_us(one_rebuild, max(1, reps // 3))
    results["ingest"] = ingest_rows
    results["rebuild_us"] = round(rebuild_us, 1)
    C.csv_row("stream/full_rebuild", rebuild_us, "pack_problem baseline")

    # -- refresh latency ---------------------------------------------------
    t0 = time.perf_counter()
    rt.refresh(1)
    refresh_ms = (time.perf_counter() - t0) * 1e3
    results["refresh_ms"] = round(refresh_ms, 2)
    C.csv_row("stream/refresh", refresh_ms * 1e3, "single-slot DDRF rebuild")

    # -- warm vs cold rounds-to-tol (the acceptance measurement) ----------
    cold0 = rt.solve()                       # from zeros: the cold baseline
    epochs = []
    for epoch in range(2 if fast else 4):
        for node in (0, 3, 7):
            rt.ingest(node, rng.normal(size=(ds.dim, 16)),
                      rng.normal(size=16))
        packed = rt.packed
        _, cold_rounds = solve_batched(packed, 2000, tol=TOL,
                                       chunk_rounds=1, return_rounds=True)
        warm = rt.solve()
        epochs.append({"epoch": epoch,
                       "warm_rounds": warm.rounds_run,
                       "cold_rounds": int(cold_rounds),
                       "residual": warm.residual})
        C.csv_row(f"stream/epoch{epoch}", 0.0,
                  f"warm_rounds={warm.rounds_run};"
                  f"cold_rounds={int(cold_rounds)}")
    results["initial_cold_rounds"] = cold0.rounds_run
    results["epochs"] = epochs
    warm_mean = float(np.mean([e["warm_rounds"] for e in epochs]))
    cold_mean = float(np.mean([e["cold_rounds"] for e in epochs]))
    results["warm_rounds_mean"] = warm_mean
    results["cold_rounds_mean"] = cold_mean
    results["rounds_saved_fraction"] = round(1.0 - warm_mean / cold_mean, 4)
    if warm_mean >= cold_mean:
        raise RuntimeError(
            f"warm-started solves must reach tol in fewer rounds than "
            f"cold starts (warm {warm_mean} vs cold {cold_mean})")

    # -- serve throughput --------------------------------------------------
    xs = np.asarray(test[0].x)
    n_q = 64 if fast else 256
    queries = [KernelQuery(uid=i, x=xs[:, i % xs.shape[1]])
               for i in range(n_q)]
    eng = DeKRRServeEngine(rt, batch_size=64)
    eng.run([KernelQuery(uid=-1, x=xs[:, 0])])   # warm up
    t0 = time.perf_counter()
    out = eng.run(queries)
    wall = time.perf_counter() - t0
    assert all(q.done and q.staleness is not None for q in out)
    results["serve"] = {
        "queries": n_q,
        "batch_size": 64,
        "qps": round(n_q / wall, 1),
        "staleness_residual": out[-1].staleness.residual,
    }
    C.csv_row("stream/serve", wall / n_q * 1e6,
              f"qps={results['serve']['qps']}")

    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=2)
        f.write("\n")
    print(f"stream/json,0.0,wrote={os.path.relpath(OUT_PATH, REPO_ROOT)}")


if __name__ == "__main__":
    run(fast=("--fast" in sys.argv) or ("--smoke" in sys.argv))
