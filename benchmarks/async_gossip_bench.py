"""Accuracy-vs-communication frontier of the async gossip DeKRR runtime,
emitting ``BENCH_async.json`` for the perf trajectory.

The async runtime's value proposition is not wall time — it is reaching a
given accuracy on FEWER transmitted bytes than the synchronous Jacobi
barrier. This bench traces that frontier on the paper's J = 10
circulant(1, 2) network: for each activation/censoring schedule it runs
the packed async solve to a ladder of round budgets and records, per
checkpoint,

  * rel_err — ‖θ_R − θ*‖₂ / ‖θ*‖₂ against the synchronous fixed point
    (solve_batched run far past convergence),
  * actual_bytes — deliveries observed on the wire (AsyncGossipStats;
    per-edge θ payloads at the packed width D_max),
  * expected_bytes — the §II-C model `comm_bytes_per_round(...,
    activation_prob, censor_fraction, gossip)` × rounds, evaluated at the
    schedule's p and the *observed* censor fraction so model and
    measurement are directly comparable.

The p = 1.0 uncensored row IS the synchronous Jacobi baseline (bit-for-bit
`solve_batched`, verified by the conformance suite); rows below it trade
per-round progress for cheaper rounds. Checkpoints re-run the solve from
round 0 with the same key — activation masks depend only on (key, round),
so every checkpoint is a prefix of the same trajectory, not a new draw.

All rows run the XLA backend: the frontier is a property of the iteration
and the wire, identical across backends by the conformance suite.
"""
from __future__ import annotations

import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import AsyncGossipConfig, DeKRRConfig, DeKRRSolver
from repro.dist import (async_solve_batched, comm_bytes_per_round,
                        pack_problem, solve_batched)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_async.json")

KEY = jax.random.PRNGKey(0)
# (label, config) — p = 1.0 uncensored first: the synchronous baseline.
SCHEDULES = [
    ("sync_p1.0", AsyncGossipConfig()),
    ("bernoulli_p0.5", AsyncGossipConfig(prob=0.5)),
    ("bernoulli_p0.25", AsyncGossipConfig(prob=0.25)),
    ("bernoulli_p1.0_censored",
     AsyncGossipConfig(censor_tau=2e-2, censor_decay=0.95)),
    ("bernoulli_p0.5_censored",
     AsyncGossipConfig(prob=0.5, censor_tau=2e-2, censor_decay=0.95)),
    ("edge_gossip", AsyncGossipConfig(gossip="edge")),
]


def _build_problem(subsample: int):
    ds, train, _ = C.load_split("air_quality")
    if subsample < C.SUBSAMPLE:
        from repro.core import NodeData
        train = [NodeData(x=t.x[:, :max(subsample // C.J, 8)],
                          y=t.y[:max(subsample // C.J, 8)])
                 for t in train]
    keys = jax.random.split(jax.random.PRNGKey(0), C.J)
    from repro.core import select_features
    dims = [16 + 4 * (j % 3) for j in range(C.J)]
    fmaps = [select_features(keys[j], ds.dim, dims[j], C.SIGMA, train[j].x,
                             train[j].y, method="energy", candidate_ratio=5)
             for j in range(C.J)]
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(C.TOPOLOGY, fmaps, train,
                         DeKRRConfig(lam=C.LAM, c_nei=0.02 * n),
                         build_aux=False)
    return pack_problem(solver)


def run(fast: bool = False) -> None:
    packed = _build_problem(600 if fast else 2000)
    d_max = packed.max_features
    itemsize = np.dtype(np.asarray(packed.d).dtype).itemsize
    checkpoints = (10, 25, 50) if fast else (25, 50, 100, 200, 400)
    theta_star = solve_batched(packed, 2 * checkpoints[-1] + 1000)
    star_norm = float(jnp.linalg.norm(theta_star))

    results = []
    for label, config in SCHEDULES:
        frontier = []
        for rounds in checkpoints:
            theta, stats = async_solve_batched(
                packed, rounds, KEY, config=config, return_stats=True)
            rel_err = float(jnp.linalg.norm(theta - theta_star)) / star_norm
            actual_bytes = int(stats.deliveries) * d_max * itemsize
            # observed censor fraction: transmissions suppressed among the
            # rounds' activations (edge gossip: 2 activations per round)
            expected_active = (2.0 * rounds if config.gossip == "edge"
                               else config.prob * packed.num_nodes * rounds)
            censor_frac = max(0.0, 1.0 - int(stats.broadcasts)
                              / max(expected_active, 1.0))
            expected_bytes = rounds * comm_bytes_per_round(
                packed, "ppermute", activation_prob=config.prob,
                censor_fraction=censor_frac, gossip=config.gossip)
            frontier.append({
                "rounds": rounds,
                "rel_err": rel_err,
                "broadcasts": int(stats.broadcasts),
                "deliveries": int(stats.deliveries),
                "actual_bytes": actual_bytes,
                "expected_bytes": round(float(expected_bytes), 1),
                "observed_censor_fraction": round(censor_frac, 4),
            })
        row = {
            "schedule": label,
            "gossip": config.gossip,
            "activation_prob": config.prob,
            "censor_tau": config.censor_tau,
            "censor_decay": config.censor_decay,
            "frontier": frontier,
        }
        results.append(row)
        last = frontier[-1]
        C.csv_row(
            f"async/{label}", 0.0,
            f"rounds={last['rounds']};rel_err={last['rel_err']:.3e};"
            f"actual_MB={last['actual_bytes'] / 2**20:.3f};"
            f"censor_frac={last['observed_censor_fraction']}")

    payload = {
        "benchmark": ("async gossip DeKRR accuracy-vs-communication "
                      "frontier (sync Jacobi = p1.0 uncensored baseline)"),
        "backend": jax.default_backend(),
        "j_nodes": packed.num_nodes,
        "d_max": d_max,
        "checkpoints": list(checkpoints),
        "note": ("rel_err is against the synchronous fixed point; "
                 "actual_bytes counts observed per-edge deliveries at the "
                 "packed width, expected_bytes the comm_bytes_per_round "
                 "model at the observed censor fraction. Checkpoints are "
                 "prefixes of one trajectory (masks depend only on "
                 "(key, round))."),
        "schedules": results,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"async/json,0.0,wrote={os.path.relpath(OUT_PATH, REPO_ROOT)}")


if __name__ == "__main__":
    run(fast=("--fast" in sys.argv) or ("--smoke" in sys.argv))
