import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf-iteration probe: compile one (arch × shape) pair with optional
config overrides and print the roofline terms plus the top collective /
memory contributors (trip-count-multiplied).

  PYTHONPATH=src python experiments/perf_probe.py --arch qwen1_5_0_5b \
      --shape train_4k [--set act_shard=none] [--top 12]
"""
import argparse
import dataclasses
import json
import time

import jax

from repro.configs import INPUT_SHAPES, get_arch
from repro.launch.hlo_analysis import analyze_hlo_text, model_flops_per_step
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import ShardingRules
from repro.launch.specs import input_specs
from repro.models.model import active_param_count


def parse_override(kv: str):
    k, v = kv.split("=", 1)
    if v == "none":
        return k, None
    if v in ("true", "false"):
        return k, v == "true"
    try:
        return k, int(v)
    except ValueError:
        pass
    try:
        return k, float(v)
    except ValueError:
        return k, eval(v)  # noqa: S307 — operator-provided tuples


def probe(arch, shape_name, overrides, multi_pod=False, top=12,
          json_out=None, policy="tp"):
    spec = get_arch(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules(mesh, policy=policy)
    pair = input_specs(spec, shape_name, rules)
    cfg = dataclasses.replace(pair["cfg"], **overrides) if overrides \
        else pair["cfg"]
    if overrides:
        # rebuild fn/args against the overridden config
        from repro.launch import specs as S
        from repro.models.model import Model
        from repro.train.step import (make_prefill, make_serve_step,
                                      make_train_step)
        shape = INPUT_SHAPES[shape_name]
        model = Model(cfg)
        import jax.numpy as jnp
        params_struct = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0)))
        p_shard = S.make_shardings(rules, rules.params_specs(params_struct))
        if shape.kind == "train":
            from repro.launch.specs import opt_config_for, train_batch_struct
            from repro.train.step import TrainState, train_state_init
            from jax.sharding import PartitionSpec as P
            opt_cfg = opt_config_for(cfg)
            state_struct = jax.eval_shape(
                lambda: train_state_init(cfg, opt_cfg, jax.random.PRNGKey(0)))
            p_specs = rules.params_specs(params_struct)
            state_shard = TrainState(
                params=p_shard,
                opt=S.make_shardings(rules, rules.opt_specs(None, p_specs)),
                step=S.make_shardings(rules, P()))
            batch_struct = train_batch_struct(spec, cfg, shape)
            b_shard = S.make_shardings(
                rules, rules.batch_specs(batch_struct, shape.global_batch))
            pair = dict(fn=make_train_step(cfg, opt_cfg,
                                           grad_specs=p_specs),
                        args=(state_struct, batch_struct),
                        in_shardings=(state_shard, b_shard),
                        out_shardings=(state_shard, None),
                        donate_argnums=(0,), cfg=cfg)
        elif shape.kind == "prefill":
            from repro.launch.specs import prefill_batch_struct
            batch_struct = prefill_batch_struct(spec, cfg, shape)
            b_shard = S.make_shardings(
                rules, rules.batch_specs(batch_struct, shape.global_batch))
            pair = dict(fn=make_prefill(cfg), args=(params_struct,
                                                    batch_struct),
                        in_shardings=(p_shard, b_shard),
                        donate_argnums=(), cfg=cfg)
        else:
            import jax.numpy as jnp
            from jax.sharding import PartitionSpec as P
            b = shape.global_batch
            cache_len = (cfg.sliding_window
                         if any(s.mixer == "swa" for s in cfg.slots)
                         else shape.seq_len)
            cache_struct = jax.eval_shape(
                lambda: model.init_cache(b, cache_len))
            c_shard = S.make_shardings(rules,
                                       rules.cache_specs(cache_struct, b))
            tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
            t_shard = S.make_shardings(
                rules, rules.batch_specs({"tokens": tok}, b))["tokens"]
            pair = dict(fn=make_serve_step(cfg),
                        args=(params_struct, cache_struct, tok,
                              jax.ShapeDtypeStruct((), jnp.int32)),
                        in_shardings=(p_shard, c_shard, t_shard,
                                      S.make_shardings(rules, P())),
                        donate_argnums=(1,), cfg=cfg)

    t0 = time.perf_counter()
    with mesh:
        kw = {}
        if pair.get("out_shardings") is not None:
            kw["out_shardings"] = pair["out_shardings"]
        compiled = jax.jit(
            pair["fn"], in_shardings=pair["in_shardings"],
            donate_argnums=pair["donate_argnums"], **kw,
        ).lower(*pair["args"]).compile()
    costs = analyze_hlo_text(compiled.as_text())
    mem = compiled.memory_analysis()
    peak = (mem.temp_size_in_bytes + mem.argument_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    from repro.launch.mesh import (HBM_BANDWIDTH, ICI_LINK_BANDWIDTH,
                                   PEAK_FLOPS_BF16)
    tot_coll = sum(costs.coll_bytes.values())
    print(f"\n=== {arch} × {shape_name} "
          f"{'(multi-pod)' if multi_pod else ''} overrides={overrides} ===")
    print(f"compile {time.perf_counter()-t0:.0f}s   "
          f"mem/dev {peak/2**30:.2f} GiB")
    print(f"compute    {costs.flops/PEAK_FLOPS_BF16*1e3:10.2f} ms"
          f"  ({costs.flops:.3e} flops/dev)")
    print(f"memory     {costs.hbm_bytes/HBM_BANDWIDTH*1e3:10.2f} ms"
          f"  ({costs.hbm_bytes:.3e} B/dev)")
    print(f"collective {tot_coll/ICI_LINK_BANDWIDTH*1e3:10.2f} ms"
          f"  ({tot_coll:.3e} B/dev)")
    print(f"by kind: " + "  ".join(
        f"{k}={v/2**30:.2f}GiB" for k, v in costs.coll_bytes.items() if v))
    print(f"\ntop collectives (bytes × trip-count):")
    for byts, kind, shp, m, meta in costs.top_collectives[:top]:
        print(f"  {byts/2**30:8.3f} GiB  {kind:18s} ×{int(m):4d}  {shp:42s}"
              f" {meta[-60:]}")
    print(f"\ntop memory ops:")
    for byts, op, shp, m in costs.top_memory_ops[:top]:
        print(f"  {byts/2**30:8.3f} GiB  {op:22s} ×{int(m):4d}  {shp}")
    if json_out:
        with open(json_out, "w") as f:
            json.dump({"peak": peak, "flops": costs.flops,
                       "hbm": costs.hbm_bytes, "coll": costs.coll_bytes},
                      f, default=float)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=12)
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--policy", default="tp", choices=["tp", "dp"])
    a = ap.parse_args()
    overrides = dict(parse_override(s) for s in a.set)
    probe(a.arch, a.shape, overrides, a.multi_pod, a.top, a.json_out,
          policy=a.policy)
