"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from the dryrun JSONs.

  PYTHONPATH=src python experiments/make_report.py > experiments/roofline.md
"""
import glob
import json
import os
import sys

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "dryrun")

ARCH_ORDER = [
    "qwen1_5_0_5b", "llava_next_mistral_7b", "hubert_xlarge", "granite_3_8b",
    "smollm_135m", "rwkv6_7b", "qwen1_5_32b", "deepseek_moe_16b",
    "jamba_1_5_large_398b", "phi3_5_moe_42b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh, dirname=None):
    out = {}
    for p in glob.glob(os.path.join(dirname or DRYRUN_DIR,
                                    f"{mesh}_*.json")):
        r = json.load(open(p))
        out[(r["arch"], r["shape"])] = r
    return out


def table(mesh, dirname=None, label=""):
    rows = load(mesh, dirname)
    print(f"\n### Mesh {mesh} ({'512' if mesh == '2x16x16' else '256'} "
          f"chips){label}\n")
    print("| arch | shape | plan | mem/dev GiB | compute ms | memory ms |"
          " collective ms | dominant | useful-FLOPs | 1-sentence lever |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = rows.get((a, s))
            if r is None:
                print(f"| {a} | {s} | MISSING | | | | | | | |")
                continue
            if r.get("status") == "skip":
                print(f"| {a} | {s} | skip (encoder-only) | — | — | — | — |"
                      f" — | — | — |")
                continue
            lever = {
                "collective": "reduce per-layer activation regathers /"
                              " FSDP prefetch overlap",
                "memory": "shard or shrink the dominant resident buffer"
                          " (KV cache / remat residuals)",
                "compute": "raise MXU utilization (larger tiles, fused"
                           " featurize)",
            }[r["dominant"]]
            print(f"| {a} | {s} | {r['plan']} |"
                  f" {(r['peak_memory_per_device'] or 0)/2**30:.1f} |"
                  f" {r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} |"
                  f" {r['collective_s']*1e3:.2f} | {r['dominant']} |"
                  f" {r['useful_flops_ratio']:.2f} | {lever} |")


if __name__ == "__main__":
    for mesh in ["16x16", "2x16x16"]:
        table(mesh, label=" — optimized (post-§Perf policies)")
    base = os.path.join(os.path.dirname(__file__), "dryrun_baseline")
    if os.path.isdir(base):
        for mesh in ["16x16", "2x16x16"]:
            table(mesh, dirname=base, label=" — BASELINE (pre-§Perf)")
