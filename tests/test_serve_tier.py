"""Serving-tier conformance: replicas, admission, precision bounds.

The production serving contracts pinned here:

  * answers are owned copies — mutating one query's prediction can never
    corrupt a wave sibling's (the aliasing regression);
  * malformed queries are rejected at ADMISSION with the offending uid
    named; malformed snapshots (mixed θ widths/dtypes) are rejected at
    construction with the per-node facts named;
  * N replicas off a `SnapshotRegistry` answer exactly like one engine
    (rtol 1e-9), and publishes are atomic under interleaved ingest/solve
    — every concurrent answer matches exactly one published θ;
  * every low-precision answer satisfies |f_lo − f_hi| ≤ the attached
    `StalenessBound.precision`, over a randomized sweep of maps, widths,
    outputs and precisions;
  * latency percentiles are deterministic functions of a seeded load
    trace under an injected clock;
  * the serve-wave VMEM working-set formula matches its docstring.
"""
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import cached_fmaps, cached_split
from repro.analysis.vmem import VmemBudgetError, estimate_serve_wave
from repro.core import DeKRRConfig, DeKRRSolver, circulant
from repro.core.rff import sample_rff
from repro.serve import (AdmissionQueue, DeKRRReplicaServer, DeKRRServeEngine,
                         KernelQuery, LatencyRecorder, pad_bucket)
from repro.stream import (ServeSnapshot, SnapshotRegistry, StalenessBound,
                          StreamingDeKRR)


def _snapshot(seed=0, j=3, d=5, freqs=16, dy=None,
              kinds=("cos_bias", "cos_bias", "cos_sin")) -> ServeSnapshot:
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    fmaps, thetas = [], []
    for i in range(j):
        key, k = jax.random.split(key)
        fm = sample_rff(k, d, freqs, 1.0, kind=kinds[i % len(kinds)])
        fmaps.append(fm)
        shape = (fm.num_features,) if dy is None else (fm.num_features, dy)
        thetas.append(jnp.asarray(rng.normal(size=shape)))
    return ServeSnapshot(feature_maps=tuple(fmaps), theta=tuple(thetas),
                         staleness=StalenessBound(1, 0, 0, 0.0))


class FakeClock:
    """Deterministic injectable clock: advances a fixed step per call."""

    def __init__(self, step=0.125):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


# --------------------------------------------------------------------------
# Shared admission machinery
# --------------------------------------------------------------------------
def test_pad_bucket():
    assert pad_bucket(0) == 8
    assert pad_bucket(1) == 8
    assert pad_bucket(8) == 8
    assert pad_bucket(9) == 16
    assert pad_bucket(100) == 128
    assert pad_bucket(3, min_bucket=2) == 4
    with pytest.raises(ValueError):
        pad_bucket(-1)


def test_admission_queue_fifo_and_budgets():
    q = AdmissionQueue()
    for uid, width in enumerate([1, 3, 2, 8, 1]):
        q.admit(uid, uid=uid, width=width, now=float(uid))
    assert len(q) == 5 and q.pending_columns == 15
    # slot budget only
    wave = q.take_wave(2)
    assert [e.uid for e in wave] == [0, 1]
    # column budget stops before uid 3 (2 + 8 > 4)
    wave = q.take_wave(8, max_columns=4)
    assert [e.uid for e in wave] == [2]
    # head-of-line wider than the budget is returned ALONE, not deadlocked
    wave = q.take_wave(8, max_columns=4)
    assert [e.uid for e in wave] == [3] and wave[0].width == 8
    assert [e.uid for e in q.take_wave(8)] == [4]
    assert q.take_wave(8) == []
    with pytest.raises(ValueError):
        q.admit(9, uid=9, width=0, now=0.0)


def test_latency_recorder_deterministic_report():
    rec = LatencyRecorder(FakeClock())
    for t_arr, t_done in [(0.0, 1.0), (0.5, 1.0), (1.0, 9.0)]:
        rec.record(t_arr, t_done)
    rep = rec.report()
    lat = np.array([1.0, 0.5, 8.0])
    assert rep.count == 3
    assert rep.p50 == pytest.approx(np.percentile(lat, 50))
    assert rep.p99 == pytest.approx(np.percentile(lat, 99))
    assert rep.qps == pytest.approx(3 / 9.0)
    with pytest.raises(ValueError):
        rec.record(2.0, 1.0)
    rec.reset()
    assert rec.report().count == 0


# --------------------------------------------------------------------------
# Snapshot registry + construction validation
# --------------------------------------------------------------------------
def test_snapshot_registry_versions():
    reg = SnapshotRegistry()
    assert reg.version == 0
    with pytest.raises(LookupError):
        reg.latest()
    snap_a, snap_b = _snapshot(0), _snapshot(1)
    assert reg.publish(snap_a) == 1
    assert reg.publish(snap_b) == 2
    ver, snap = reg.latest_versioned()
    assert ver == 2 and snap is snap_b
    with pytest.raises(TypeError):
        reg.publish("not a snapshot")


def test_snapshot_rejects_mixed_widths():
    snap = _snapshot()
    theta = list(snap.theta)
    theta[1] = theta[1][:, None].repeat(2, axis=1)      # node 1 → [D, 2]
    with pytest.raises(ValueError, match="widths"):
        ServeSnapshot(feature_maps=snap.feature_maps, theta=tuple(theta),
                      staleness=snap.staleness)
    # multi-output with two different Dy is just as malformed
    t2 = [t[:, None].repeat(2, axis=1) for t in snap.theta]
    t2[2] = t2[2][:, :1]
    with pytest.raises(ValueError, match="widths"):
        ServeSnapshot(feature_maps=snap.feature_maps, theta=tuple(t2),
                      staleness=snap.staleness)


def test_snapshot_rejects_mixed_dtypes():
    snap = _snapshot()
    theta = list(snap.theta)
    theta[2] = theta[2].astype(jnp.float32)             # lone f32 node
    with pytest.raises(ValueError, match="float32"):
        ServeSnapshot(feature_maps=snap.feature_maps, theta=tuple(theta),
                      staleness=snap.staleness)


def test_snapshot_rejects_feature_count_mismatch():
    snap = _snapshot()
    theta = list(snap.theta)
    theta[0] = theta[0][:-1]
    with pytest.raises(ValueError, match="num_features"):
        ServeSnapshot(feature_maps=snap.feature_maps, theta=tuple(theta),
                      staleness=snap.staleness)


# --------------------------------------------------------------------------
# Serve-path bugfix regressions
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dy", [None, 3])
def test_predictions_are_owned_copies(dy):
    """Aliasing regression: predictions in one wave must not share
    storage — mutating one answer leaves every sibling intact."""
    snap = _snapshot(dy=dy)
    rng = np.random.default_rng(5)
    xs = rng.normal(size=(5, 6))
    queries = [KernelQuery(uid=0, x=xs[:, :3]), KernelQuery(uid=1, x=xs[:, 3:]),
               KernelQuery(uid=2, x=xs[:, :3], node=1)]
    DeKRRServeEngine(snap, batch_size=64).run(queries)
    before = [np.array(q.prediction, copy=True) for q in queries]
    np.asarray(queries[0].prediction)[...] = 1e9
    for q, want in zip(queries[1:], before[1:]):
        np.testing.assert_array_equal(np.asarray(q.prediction), want)


def test_malformed_queries_rejected_at_admission_with_uid():
    snap = _snapshot()
    eng = DeKRRServeEngine(snap)
    with pytest.raises(ValueError, match="query 41.*input dim 4"):
        eng.run([KernelQuery(uid=41, x=np.zeros(4))])
    with pytest.raises(ValueError, match="query 42"):
        eng.run([KernelQuery(uid=42, x=np.zeros((5, 2, 2)))])
    with pytest.raises(ValueError, match="query 43.*node 7"):
        eng.run([KernelQuery(uid=43, x=np.zeros(5), node=7)])
    with pytest.raises(ValueError, match="query 44"):
        eng.run([KernelQuery(uid=44, x=np.zeros((5, 0)))])
    # a bad query is rejected before ANY query is answered
    good = KernelQuery(uid=0, x=np.zeros(5))
    with pytest.raises(ValueError, match="query 45"):
        eng.run([good, KernelQuery(uid=45, x=np.zeros(4))])
    assert not good.done


# --------------------------------------------------------------------------
# Replica serving
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dy", [None, 2])
def test_replica_parity_vs_single_engine(dy):
    """N replicas off a registry answer exactly like one engine over the
    same snapshot — mixed widths, node queries, several waves."""
    snap = _snapshot(seed=3, dy=dy)

    def queries():
        rng = np.random.default_rng(11)
        out = []
        for uid in range(17):
            width = int(rng.integers(1, 4)) if uid % 3 else 1
            x = rng.normal(size=(5, width)) if uid % 3 else rng.normal(size=5)
            node = 1 if uid % 5 == 0 else None
            out.append(KernelQuery(uid=uid, x=x, node=node))
        return out

    want = DeKRRServeEngine(snap, batch_size=4).run(queries())
    reg = SnapshotRegistry()
    reg.publish(snap)
    srv = DeKRRReplicaServer(reg, replicas=3, batch_size=4)
    got = srv.run(queries())
    for qw, qg in zip(want, got):
        np.testing.assert_allclose(np.asarray(qg.prediction),
                                   np.asarray(qw.prediction),
                                   rtol=1e-9, atol=1e-12)
        assert qg.staleness == qw.staleness and qg.done
    assert srv.report().count == 17 and srv.waves_served >= 5


def test_engine_serves_freshest_registry_snapshot():
    snap_a, snap_b = _snapshot(0), _snapshot(1)
    reg = SnapshotRegistry()
    reg.publish(snap_a)
    eng = DeKRRServeEngine(reg, batch_size=8)
    x = np.zeros(5)
    a = eng.run([KernelQuery(uid=0, x=x)])[0].prediction
    reg.publish(snap_b)
    b = eng.run([KernelQuery(uid=1, x=x)])[0].prediction
    want_b = DeKRRServeEngine(snap_b).run([KernelQuery(uid=2, x=x)])[0]
    assert a != b
    np.testing.assert_allclose(b, want_b.prediction, rtol=1e-12)


def test_publish_atomicity_under_interleaved_ingest_solve():
    """A solver thread ingests/solves/publishes while replicas answer:
    every answer must be consistent with exactly ONE published snapshot
    (its staleness identifies it; the prediction must match a clean
    serve of that same snapshot) — never a torn mix."""
    ds, train, _ = cached_split("air_quality", 3, subsample=60, seed=0)
    fmaps = cached_fmaps("air_quality", 3, (8, 8, 8), method="energy",
                         subsample=60, seed=0)
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(circulant(3, (1,)), fmaps, train,
                         DeKRRConfig(lam=1e-3, c_nei=0.02 * n),
                         build_aux=False)
    rt = StreamingDeKRR(solver)
    rt.solve()
    reg = SnapshotRegistry()
    published = {}

    def publish():
        snap = rt.snapshot()
        published[reg.publish(snap)] = snap

    publish()
    rng = np.random.default_rng(23)
    stop = threading.Event()

    def solver_loop():
        k = 0
        while not stop.is_set() and k < 6:
            rt.ingest(k % 3, rng.normal(size=(ds.dim, 8)),
                      rng.normal(size=8))
            rt.solve()
            publish()
            k += 1

    srv = DeKRRReplicaServer(reg, replicas=2, batch_size=2)
    writer = threading.Thread(target=solver_loop)
    writer.start()
    srv.start()
    queries = [KernelQuery(uid=i, x=rng.normal(size=ds.dim))
               for i in range(60)]
    try:
        for q in queries:
            srv.submit(q)
    finally:
        srv.stop()
        stop.set()
        writer.join()

    by_staleness = {snap.staleness: snap for snap in published.values()}
    assert len(by_staleness) == len(published)   # distinct versions
    for q in queries:
        assert q.done
        snap = by_staleness.get(q.staleness)
        assert snap is not None, \
            f"query {q.uid} answered from an unpublished snapshot"
        want = DeKRRServeEngine(snap).run(
            [KernelQuery(uid=q.uid, x=q.x)])[0].prediction
        np.testing.assert_allclose(q.prediction, want, rtol=1e-12,
                                   err_msg=f"query {q.uid} torn across "
                                           f"snapshots")


# --------------------------------------------------------------------------
# Mixed precision
# --------------------------------------------------------------------------
@pytest.mark.parametrize("precision", ["bf16", "int8"])
@pytest.mark.parametrize("dy", [None, 2])
def test_lowp_answers_within_attached_bound(precision, dy):
    """Randomized sweep: EVERY low-precision answer (mean and per-node,
    scalar and block queries) is within its attached precision bound,
    and full-precision answers attach precision == 0."""
    for seed in range(3):
        snap = _snapshot(seed=seed, dy=dy)

        def queries():
            rng = np.random.default_rng(100 + seed)
            out = []
            for uid in range(12):
                width = int(rng.integers(1, 5))
                x = 2.0 * rng.normal(size=(5, width))
                out.append(KernelQuery(
                    uid=uid, x=x,
                    node=int(uid % 3) if uid % 4 == 0 else None))
            return out

        hi = DeKRRServeEngine(snap, batch_size=5).run(queries())
        lo = DeKRRServeEngine(snap, batch_size=5,
                              precision=precision).run(queries())
        for qh, ql in zip(hi, lo):
            assert qh.staleness.precision == 0.0
            bound = ql.staleness.precision
            assert bound > 0.0
            err = np.max(np.abs(np.asarray(ql.prediction, dtype=np.float64)
                                - np.asarray(qh.prediction,
                                             dtype=np.float64)))
            assert err <= bound, (
                f"seed {seed} uid {ql.uid}: measured |f_lo - f_hi| = "
                f"{err} exceeds attached precision bound {bound}")
            # the bound is answer-scale, not vacuous
            scale = max(1.0, np.max(np.abs(np.asarray(qh.prediction))))
            assert bound < 1e3 * scale


def test_lowp_answers_are_close_and_bounded_on_replicas():
    snap = _snapshot(seed=7)
    reg = SnapshotRegistry()
    reg.publish(snap)
    rng = np.random.default_rng(8)
    xs = rng.normal(size=(5, 9))
    hi = DeKRRServeEngine(snap).run(
        [KernelQuery(uid=i, x=xs[:, i]) for i in range(9)])
    srv = DeKRRReplicaServer(reg, replicas=2, batch_size=3,
                             precision="bf16")
    lo = srv.run([KernelQuery(uid=i, x=xs[:, i]) for i in range(9)])
    for qh, ql in zip(hi, lo):
        err = abs(float(ql.prediction) - float(qh.prediction))
        assert err <= ql.staleness.precision
        # bf16 answers should still be decently accurate in absolute terms
        assert err < 0.1


# --------------------------------------------------------------------------
# Latency determinism
# --------------------------------------------------------------------------
def test_latency_percentiles_deterministic_under_seeded_trace():
    """Same seeded load trace + injected clock + one replica → the exact
    same LatencyReport, run after run."""
    def one_run():
        snap = _snapshot(seed=2)
        reg = SnapshotRegistry()
        reg.publish(snap)
        srv = DeKRRReplicaServer(reg, replicas=1, batch_size=4,
                                 clock=FakeClock())
        rng = np.random.default_rng(17)
        arrivals = np.cumsum(rng.exponential(0.01, size=20))
        queries = [KernelQuery(uid=i, x=rng.normal(size=5))
                   for i in range(20)]
        srv.run(queries, arrivals=arrivals)
        return srv.report()

    rep_a, rep_b = one_run(), one_run()
    assert rep_a == rep_b
    assert rep_a.count == 20
    assert rep_a.p99 >= rep_a.p50 > 0.0


def test_engine_latency_report_populated():
    snap = _snapshot()
    eng = DeKRRServeEngine(snap, batch_size=4)
    eng.run([KernelQuery(uid=i, x=np.zeros(5)) for i in range(9)])
    rep = eng.latency.report()
    assert rep.count == 9 and rep.p99 >= rep.p50 > 0.0 and rep.qps > 0.0


# --------------------------------------------------------------------------
# Serving-kernel working sets
# --------------------------------------------------------------------------
def test_estimate_serve_wave_matches_docstring():
    est = estimate_serve_wave(block_d=256, d_in=160, block_n=512,
                              d_feat=2048, dy=2)
    want = 256 * 160 + 256 + 160 * 512 + 256 * 512 + 2 * 2048 + 2 * 512
    assert est.elements == want
    assert est.bytes == want * 4
    assert est.bytes < 2**20 and est.fits       # the "< 1 MB" anchor
    assert "Bd*d + Bd + d*Bn + Bd*Bn + dy*D + dy*Bn" == est.formula
    # bf16 wave: half the bytes
    assert estimate_serve_wave(block_d=256, d_in=160, block_n=512,
                               d_feat=2048, dy=2, itemsize=2).bytes \
        == want * 2
    with pytest.raises(VmemBudgetError):
        estimate_serve_wave(block_d=2048, d_in=2048, block_n=2048,
                            d_feat=8192, dy=8).check()


def test_engine_rejects_bad_config():
    snap = _snapshot()
    with pytest.raises(ValueError, match="backend"):
        DeKRRServeEngine(snap, backend="tpu-v9")
    with pytest.raises(ValueError, match="precision"):
        DeKRRServeEngine(snap, precision="fp4")
    with pytest.raises(TypeError, match="SnapshotRegistry"):
        DeKRRReplicaServer(snap)
    reg = SnapshotRegistry()
    reg.publish(snap)
    with pytest.raises(ValueError, match="replicas"):
        DeKRRReplicaServer(reg, replicas=0)
