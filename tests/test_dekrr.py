"""System tests for the DeKRR-DDRF solver (Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import cached_fmaps, cached_split
from repro.core import (CentralizedKRR, DKLA, DKLAConfig, DeKRRConfig,
                        DeKRRSolver, circulant, rse, sample_rff,
                        select_features)
from repro.data.synthetic import pooled

SIGMA, LAM = 1.0, 1e-6
# the module-wide problem every `setup`-based test shares
DS_NAME, J, SUB = "houses", 6, 1200


@pytest.fixture(scope="module")
def setup():
    ds, train, test = cached_split(DS_NAME, J, subsample=SUB, seed=0)
    topo = circulant(J, (1, 2))
    return ds, topo, train, test


def _maps(ds, train, D, method="energy", seed=0):
    """Feature maps for the shared `setup` split (cached per (D, method))."""
    keys = jax.random.split(jax.random.PRNGKey(seed), len(train))
    if method == "shared":
        fm = sample_rff(keys[0], ds.dim, D, SIGMA)
        return [fm] * len(train)
    assert len(train) == J, "_maps is tied to the module's cached split"
    return cached_fmaps(DS_NAME, J, (D,) * J, sigma=SIGMA, method=method,
                        candidate_ratio=10, subsample=SUB, seed=seed,
                        split_seed=0)


def test_iteration_converges_to_exact_fixed_point(setup):
    ds, topo, train, test = setup
    fmaps = _maps(ds, train, 20)
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=LAM, c_nei=0.01 * n, num_iters=800))
    exact = solver.solve_exact()
    iterated = solver.solve()
    for a, b in zip(exact.theta, iterated.theta):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


def test_fixed_point_is_stationary_point_of_objective(setup):
    """∇L = 0 at the solve_exact() solution (finite-difference check)."""
    ds, topo, train, _ = setup
    fmaps = _maps(ds, train, 12)
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=LAM, c_nei=0.02 * n))
    theta = solver.solve_exact().theta
    obj0 = float(solver.objective(theta))
    rng = np.random.default_rng(0)
    for j in [0, len(theta) // 2]:
        for _ in range(3):
            pert = [t for t in theta]
            eps = jnp.asarray(rng.normal(size=theta[j].shape)) * 1e-4
            pert[j] = theta[j] + eps
            assert float(solver.objective(pert)) >= obj0 - 1e-12


def test_shared_features_match_dkla_solution(setup):
    """With identical features on all nodes, DeKRR's limit and DKLA's limit
    both solve (approximately) the same consensus problem."""
    ds, topo, train, test = setup
    fmaps = _maps(ds, train, 24, method="shared")
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=LAM, c_nei=0.5 * n))
    st = solver.solve_exact()
    dkla = DKLA(topo, fmaps[0], train, DKLAConfig(lam=LAM, num_iters=600))
    th_dkla = dkla.solve()
    ys = jnp.concatenate([t.y for t in test])
    pred_ours = jnp.concatenate(
        [solver.predict(st.theta, test[j].x, node=j) for j in range(len(test))])
    pred_dkla = jnp.concatenate(
        [dkla.predict(th_dkla, test[j].x, node=j) for j in range(len(test))])
    assert abs(rse(pred_ours, ys) - rse(pred_dkla, ys)) < 0.05


def test_consensus_tightens_with_penalty(setup):
    """Larger c ⇒ smaller cross-node decision-function disagreement."""
    ds, topo, train, _ = setup
    fmaps = _maps(ds, train, 16)
    n = sum(t.num_samples for t in train)
    xs = pooled(train).x[:, :200]

    def disagreement(c):
        solver = DeKRRSolver(topo, fmaps, train,
                             DeKRRConfig(lam=LAM, c_nei=c))
        theta = solver.solve_exact().theta
        preds = jnp.stack([solver.predict(theta, xs, node=j)
                           for j in range(len(train))])
        return float(jnp.mean(jnp.var(preds, axis=0)))

    d_small, d_big = disagreement(0.001 * n), disagreement(1.0 * n)
    assert d_big < d_small


def test_consensus_generalizes_starved_node_beyond_local_data(setup):
    """Consensus transfers information: the node whose local labels are
    nearly constant (last node under the non-IID |y| split) must still
    produce a decision function that generalizes to the *network's* test
    distribution — a purely local fit cannot."""
    ds, topo, train, test = setup
    fmaps = _maps(ds, train, 24)
    n = sum(t.num_samples for t in train)
    j_last = len(train) - 1
    te = pooled(test)

    # local-only ridge on the starved node
    from repro.core.rff import featurize
    z = featurize(fmaps[j_last], train[j_last].x)
    g = z @ z.T + LAM * z.shape[1] * jnp.eye(z.shape[0])
    th_local = jnp.linalg.solve(g, z @ train[j_last].y)
    rse_local = rse(th_local @ featurize(fmaps[j_last], te.x), te.y)

    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=LAM, c_nei=0.02 * n))
    theta = solver.solve_exact().theta
    rse_cons = rse(solver.predict(theta, te.x, node=j_last), te.y)
    assert rse_cons < rse_local


def test_variable_feature_counts_supported(setup):
    """The paper's headline flexibility: different D_j per node."""
    ds, topo, train, test = setup
    keys = jax.random.split(jax.random.PRNGKey(7), len(train))
    d_per_node = [8, 12, 16, 20, 24, 28]
    fmaps = [
        select_features(keys[j], ds.dim, d_per_node[j], SIGMA,
                        train[j].x, train[j].y, method="energy",
                        candidate_ratio=10)
        for j in range(len(train))
    ]
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=LAM, c_nei=0.02 * n))
    st = solver.solve_exact()
    assert [t.shape[0] for t in st.theta] == d_per_node
    ys = jnp.concatenate([t.y for t in test])
    pred = jnp.concatenate(
        [solver.predict(st.theta, test[j].x, node=j) for j in range(len(test))])
    assert rse(pred, ys) < 0.9


def test_centralized_krr_reference(setup):
    ds, topo, train, test = setup
    tr, te = pooled(train), pooled(test)
    model = CentralizedKRR(SIGMA, LAM).fit(tr.x, tr.y)
    assert rse(model.predict(te.x), te.y) < 0.3


def test_dekrr_ddrf_beats_dkla_noniid():
    """The paper's headline claim (Tab. 2 direction) on the stand-in data,
    following the paper's protocol: c_nei selected from a grid, DKLA averaged
    over feature draws. J=10 circulant(1,2) — the paper's exact topology."""
    ds, train, test = cached_split("houses", 10, subsample=2000, seed=0)
    topo = circulant(10, (1, 2))
    n = sum(t.num_samples for t in train)
    D = 20
    ys = jnp.concatenate([t.y for t in test])

    fmaps_ddrf = cached_fmaps("houses", 10, (D,) * 10, sigma=SIGMA,
                              method="energy", candidate_ratio=20,
                              subsample=2000, seed=0)
    rse_ours = np.inf
    for c in (0.002, 0.01, 0.05):
        solver = DeKRRSolver(topo, fmaps_ddrf, train,
                             DeKRRConfig(lam=LAM, c_nei=c * n))
        st = solver.solve_exact()
        pred = jnp.concatenate(
            [solver.predict(st.theta, test[j].x, node=j) for j in range(10)])
        rse_ours = min(rse_ours, rse(pred, ys))

    rs = []
    for s in range(3):
        fm = sample_rff(jax.random.PRNGKey(50 + s), ds.dim, D, SIGMA)
        dkla = DKLA(topo, fm, train, DKLAConfig(lam=LAM, num_iters=400))
        th = dkla.solve()
        pred_d = jnp.concatenate(
            [dkla.predict(th, test[j].x, node=j) for j in range(10)])
        rs.append(rse(pred_d, ys))
    assert rse_ours < np.mean(rs)


def test_chebyshev_acceleration_fewer_rounds(setup):
    """Beyond-paper: Chebyshev semi-iteration reaches the Eq. 19 limit in
    ≥3× fewer communication rounds (identical per-round exchange)."""
    import jax.numpy as jnp

    from repro.core.acceleration import (power_iteration_mu_max,
                                         rounds_to_tolerance)
    from repro.dist import pack_problem

    ds, topo, train, _ = setup
    fmaps = _maps(ds, train, 16)
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=LAM, c_nei=0.02 * n))
    packed = pack_problem(solver)
    exact = solver.solve_exact()
    dmax = packed.d.shape[1]
    theta_star = jnp.stack(
        [jnp.pad(t, (0, dmax - t.shape[0])) for t in exact.theta])
    plain, cheb = rounds_to_tolerance(
        packed, theta_star, tol=1e-6, max_rounds=4000)
    assert cheb < plain / 3, (plain, cheb)


def test_chebyshev_reaches_same_solution(setup):
    import jax.numpy as jnp
    import numpy as np

    from repro.core.acceleration import (chebyshev_solve_packed,
                                         estimate_spectral_interval)
    from repro.dist import pack_problem

    ds, topo, train, _ = setup
    fmaps = _maps(ds, train, 12)
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=LAM, c_nei=0.01 * n))
    packed = pack_problem(solver)
    exact = solver.solve_exact()
    lo, hi = estimate_spectral_interval(packed)
    theta = chebyshev_solve_packed(packed, hi, mu_min=lo, num_iters=300)
    dmax = packed.d.shape[1]
    theta_star = jnp.stack(
        [jnp.pad(t, (0, dmax - t.shape[0])) for t in exact.theta])
    np.testing.assert_allclose(np.asarray(theta), np.asarray(theta_star),
                               rtol=1e-4, atol=1e-7)
