"""Parity sweeps for the fused packed DeKRR round kernel (interpret mode).

Three layers are pinned to each other at rtol 1e-9 under x64, all on CPU:

  ragged reference (`DeKRRSolver.step`)
    == batched XLA round (`step_batched(backend="xla")`)
    == fused Pallas round (`step_batched(backend="pallas")`,
       `repro.kernels.dekrr_step` in interpret mode)

sweeping ragged D_j sets, circulant and arbitrary graphs, and the J=1 /
single-neighbor / full-graph edge cases; plus the raw kernel against its
pure-jnp oracle on random shapes (θ-table indirection, masked slots), the
solve-level backend agreement, and the SPMD backend="pallas" path.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from conftest import REPO_ROOT, cached_fmaps, cached_split, subprocess_env
from repro.core import (DeKRRConfig, DeKRRSolver, Topology, circulant,
                        complete, erdos_renyi, star)
from repro.dist import pack_problem, solve_batched, step_batched
from repro.kernels import ops
from repro.kernels.dekrr_step import dekrr_step_reference

TOL = dict(rtol=1e-9, atol=1e-12)


def _solver(topo, dims, sub=400, seed=0):
    j = topo.num_nodes
    ds, train, _ = cached_split("air_quality", j, subsample=sub, seed=seed)
    fmaps = cached_fmaps("air_quality", j, tuple(dims),
                         subsample=sub, seed=seed)
    n = sum(t.num_samples for t in train)
    return DeKRRSolver(topo, fmaps, train,
                       DeKRRConfig(lam=1e-6, c_nei=0.02 * n))


def _single_node_topology():
    return Topology(adjacency=np.zeros((1, 1), dtype=bool))


CASES = [
    # (topology, ragged D_j set) — the kernel must be exact under both slot
    # layouts (circulant ppermute order and generic padded adjacency) and
    # at every degree extreme.
    (circulant(10, (1, 2)), [8, 12, 16, 20, 24, 8, 12, 16, 20, 24]),
    (circulant(6, (1,)), [10, 14, 10, 14, 10, 14]),
    (star(5), [6, 8, 10, 12, 14]),                  # worst degree imbalance
    (erdos_renyi(7, 0.5, seed=1), [9, 13, 9, 13, 9, 13, 9]),
    (complete(5), [7, 9, 11, 9, 7]),                # full graph
    (circulant(2, (1,)), [8, 12]),                  # single neighbor
    (_single_node_topology(), [10]),                # J=1, no neighbors
]


@pytest.mark.parametrize("topo,dims", CASES,
                         ids=[f"J{t.num_nodes}_deg{t.max_degree}"
                              for t, _ in CASES])
def test_fused_kernel_matches_xla_and_ragged_reference(topo, dims):
    solver = _solver(topo, dims)
    packed = pack_problem(solver)
    state = solver.init_state()
    th_xla = jnp.zeros_like(packed.d)
    th_pal = jnp.zeros_like(packed.d)
    for _ in range(5):
        state = solver.step(state)
        th_xla = step_batched(packed, th_xla, backend="xla")
        th_pal = step_batched(packed, th_pal, backend="pallas")
    for j in range(topo.num_nodes):
        ref = np.asarray(state.theta[j])
        np.testing.assert_allclose(np.asarray(th_pal[j][:dims[j]]),
                                   ref, **TOL)
        np.testing.assert_allclose(np.asarray(th_pal[j]),
                                   np.asarray(th_xla[j]), **TOL)
        # padding must stay identically zero through the fused kernel too
        assert not np.any(np.asarray(th_pal[j][dims[j]:]))


@given(j_nodes=st.integers(1, 6), k_slots=st.integers(0, 4),
       d_feat=st.integers(1, 40), extra_rows=st.integers(0, 3),
       seed=st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_raw_kernel_matches_oracle_random_shapes(j_nodes, k_slots, d_feat,
                                                 extra_rows, seed):
    """Property: the fused kernel equals the jnp oracle for arbitrary
    (unaligned) shapes, arbitrary θ-table indirection (T ≥ J rows,
    self_idx a permutation) and arbitrary slot masks."""
    rng = np.random.default_rng(seed)
    t_rows = j_nodes + extra_rows
    g = jnp.asarray(rng.normal(size=(j_nodes, d_feat, d_feat)))
    d = jnp.asarray(rng.normal(size=(j_nodes, d_feat)))
    s = jnp.asarray(rng.normal(size=(j_nodes, d_feat, d_feat)))
    p = jnp.asarray(rng.normal(size=(j_nodes, k_slots, d_feat, d_feat)))
    theta = jnp.asarray(rng.normal(size=(t_rows, d_feat)))
    nbr_idx = jnp.asarray(
        rng.integers(0, t_rows, (j_nodes, k_slots)), jnp.int32)
    self_idx = jnp.asarray(rng.permutation(t_rows)[:j_nodes], jnp.int32)
    nbr_mask = jnp.asarray(
        rng.integers(0, 2, (j_nodes, k_slots)), jnp.int32)

    got = ops.dekrr_step(g, d, s, p, theta, nbr_idx, self_idx, nbr_mask,
                         interpret=True)
    want = dekrr_step_reference(g, d, s, p, theta, nbr_idx, self_idx,
                                nbr_mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-10, atol=1e-10)


def test_solve_batched_backends_agree():
    topo = circulant(8, (1, 2))
    solver = _solver(topo, [10, 12, 14, 16, 10, 12, 14, 16])
    packed = pack_problem(solver)
    th_xla = solve_batched(packed, 30, backend="xla")
    th_pal = solve_batched(packed, 30, backend="pallas")
    np.testing.assert_allclose(np.asarray(th_pal), np.asarray(th_xla),
                               **TOL)


def test_backends_reach_same_round_count():
    """Convergence: iterating to a fixed tolerance must take the *same*
    number of rounds under both backends (the fused kernel cannot change
    the iteration's contraction)."""
    topo = circulant(6, (1,))
    solver = _solver(topo, [10, 14, 10, 14, 10, 14])
    packed = pack_problem(solver)

    def rounds_to_tol(backend, tol=1e-8, max_rounds=2000):
        theta = jnp.zeros_like(packed.d)
        for k in range(max_rounds):
            new = step_batched(packed, theta, backend=backend)
            delta = float(jnp.max(jnp.abs(new - theta)))
            theta = new
            if delta < tol:
                return k + 1
        return max_rounds

    assert rounds_to_tol("xla") == rounds_to_tol("pallas")


def test_step_batched_rejects_unknown_backend():
    topo = circulant(2, (1,))
    solver = _solver(topo, [8, 12])
    packed = pack_problem(solver)
    with pytest.raises(ValueError, match="backend"):
        step_batched(packed, jnp.zeros_like(packed.d), backend="cuda")


SPMD_PALLAS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={J}"
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh
    from repro.core import DeKRRConfig, DeKRRSolver, circulant, select_features
    from repro.data.synthetic import make_dataset, partition, train_test_split_nodes
    from repro.dist import make_spmd_solver, pack_problem, solve_batched

    J = {J}
    ds = make_dataset("air_quality", subsample=300, seed=0)
    topo = circulant(J, (1,))
    train, _ = train_test_split_nodes(partition(ds, J, mode="noniid_y"))
    keys = jax.random.split(jax.random.PRNGKey(0), J)
    dims = [8 + 2 * (j % 2) for j in range(J)]
    fmaps = [select_features(keys[j], ds.dim, dims[j], 1.0, train[j].x,
                             train[j].y, method="energy", candidate_ratio=5)
             for j in range(J)]
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=1e-6, c_nei=0.02 * n))
    packed = pack_problem(solver)
    want = solve_batched(packed, 25)

    mesh = Mesh(np.array(jax.devices()), ("nodes",))
    for mode in ("ppermute", "allgather"):
        for backend in ("pallas", "pallas_fused"):
            got = make_spmd_solver(mesh, "nodes", mode, backend=backend)(
                packed, 25)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-9, atol=1e-12)
    print("SPMD-PALLAS-PARITY-OK")
""")


def test_spmd_pallas_backend_parity_on_4_devices():
    """The SPMD per-device node program runs the same fused kernel on its
    local [1 + K, D_max] θ table (backend="pallas_fused" routes through
    the same switch — per-device rounds are bounded by the collective, so
    it runs the per-round kernel too); subprocess so the forced device
    count does not leak into this session."""
    proc = subprocess.run(
        [sys.executable, "-c", SPMD_PALLAS_SCRIPT.format(J=4)],
        capture_output=True, text=True, timeout=600,
        env=subprocess_env(),
        cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SPMD-PALLAS-PARITY-OK" in proc.stdout
