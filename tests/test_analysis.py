"""Static-analysis suite: lints the live codebase (a violation anywhere in
src/tests/benchmarks fails tier-1), pins the dispatch-count contract per
backend, property-tests the VMEM estimator against the kernel docstring
formulas, and seeds one violation of every lint class to prove the passes
actually detect what they claim to.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from conftest import REPO_ROOT, subprocess_env

from repro.analysis import (VMEM_BUDGET_BYTES, VmemBudgetError,
                            check_index_table, estimate_dekrr_async_solve,
                            estimate_dekrr_cheb_solve, estimate_dekrr_solve,
                            estimate_dekrr_step, estimate_flash_decode,
                            estimate_rff_gram, render_json, render_report)
from repro.analysis import conventions
from repro.analysis import jaxpr_lint as JL
from repro.analysis.report import Finding
from repro.kernels import ops


# ---------------------------------------------------------------------------
# VMEM estimator: docstring anchors, monotonicity, budget gate
# ---------------------------------------------------------------------------
def test_vmem_docstring_anchors():
    # dekrr_solve: "J ≤ 256, D ≤ 512, K = 4 at f32 that is ~13.7 MB"
    est = estimate_dekrr_solve(t_rows=256, d_feat=512, k_slots=4)
    assert est.bytes == 13637632 and est.fits
    # dekrr_step at the same point holds one θ table + single buffers
    st = estimate_dekrr_step(t_rows=16, d_feat=512, k_slots=4)
    assert st.bytes == 6330368 and st.fits
    # rff_gram: "D ≤ 512, d ≤ 160, Bn = 1024 that is < 5 MB"
    rg = estimate_rff_gram(d_feat=512, d_in=160, block_n=1024)
    assert rg.bytes == 4132864 and rg.bytes < 5 * 2**20
    # flash_decode: "G ≤ 8, dh = 128, block_s = 512: < 1 MB"
    fd = estimate_flash_decode(g_heads=8, head_dim=128, block_s=512)
    assert fd.bytes == 544864 and fd.bytes < 2**20
    # dekrr_async_solve: two θ tables + sent + working/init buffer tables
    av = estimate_dekrr_async_solve(t_rows=128, b_rows=512, d_feat=512,
                                    k_slots=4)
    assert av.bytes == 15996928 and av.fits
    # dekrr_cheb_solve: two θ tables + direction table
    cv = estimate_dekrr_cheb_solve(t_rows=256, j_rows=256, d_feat=512,
                                   k_slots=4)
    assert cv.bytes == 15210496 and cv.fits


def test_vmem_monotone_in_shape():
    def solve_bytes(d, k):
        return estimate_dekrr_solve(t_rows=64, d_feat=d, k_slots=k).bytes

    prev = 0
    for d in (128, 256, 384, 512, 1024):
        cur = solve_bytes(d, 4)
        assert cur > prev
        prev = cur
    prev = 0
    for k in (1, 2, 4, 8):
        cur = solve_bytes(256, k)
        assert cur > prev
        prev = cur


def test_vmem_f64_itemsize_capped():
    # x64 callers run interpret-mode or downcast — budgeting 8 B/elem
    # would spuriously reject deployable shapes.
    a = estimate_dekrr_step(t_rows=64, d_feat=512, k_slots=4, itemsize=8)
    b = estimate_dekrr_step(t_rows=64, d_feat=512, k_slots=4, itemsize=4)
    assert a.bytes == b.bytes


def test_vmem_over_budget_raises_with_formula():
    est = estimate_dekrr_solve(t_rows=1024, d_feat=1024, k_slots=8)
    assert not est.fits
    with pytest.raises(VmemBudgetError) as exc:
        est.check()
    msg = str(exc.value)
    assert "2*T*D + 2*(2+K)*D^2 + 3*D" in msg
    assert str(VMEM_BUDGET_BYTES) in msg


def test_ops_dekrr_solve_rejects_over_budget_before_dispatch():
    # eval_shape runs the wrapper body with tracers only — nothing is
    # allocated and no pallas_call is built, so a raise here IS "before
    # dispatch".
    f32 = jnp.float32
    d_feat, j, k = 1024, 2, 8
    spec = jax.ShapeDtypeStruct
    args = (spec((j, d_feat, d_feat), f32), spec((j, d_feat), f32),
            spec((j, d_feat, d_feat), f32),
            spec((j, k, d_feat, d_feat), f32), spec((j, d_feat), f32),
            spec((j, k), jnp.int32), spec((j,), jnp.int32),
            spec((j, k), f32))
    with pytest.raises(VmemBudgetError, match=r"2\*T\*D"):
        jax.eval_shape(lambda *a: ops.dekrr_solve(*a, num_rounds=3), *args)


def test_ops_rff_gram_rejects_over_budget_concrete():
    d_feat, d_in, n = 2048, 160, 256
    omega = jnp.zeros((d_feat, d_in))
    with pytest.raises(VmemBudgetError, match=r"D\*d \+ d\*Bn"):
        ops.rff_gram(omega, jnp.zeros(d_feat), jnp.zeros((d_in, n)),
                     jnp.zeros(n), scale=1.0)


# ---------------------------------------------------------------------------
# Scalar-prefetch index-table bounds checks
# ---------------------------------------------------------------------------
def _tiny_dekrr_operands(j=2, d_feat=4, k=1):
    g = jnp.tile(jnp.eye(d_feat), (j, 1, 1))
    d = jnp.ones((j, d_feat))
    s = jnp.zeros((j, d_feat, d_feat))
    p = jnp.zeros((j, k, d_feat, d_feat))
    theta = jnp.zeros((j, d_feat))
    nbr_idx = jnp.zeros((j, k), jnp.int32)
    self_idx = jnp.arange(j, dtype=jnp.int32)
    nbr_mask = jnp.ones((j, k))
    return g, d, s, p, theta, nbr_idx, self_idx, nbr_mask


def test_check_index_table():
    check_index_table("t", np.array([0, 3, 1]), 4)
    with pytest.raises(ValueError, match="scalar-prefetched"):
        check_index_table("t", np.array([0, 4]), 4)
    with pytest.raises(ValueError, match="integer"):
        check_index_table("t", np.array([0.5]), 4)


def test_ops_rejects_out_of_range_slot_index():
    g, d, s, p, theta, nbr_idx, self_idx, nbr_mask = _tiny_dekrr_operands()
    bad = nbr_idx.at[0, 0].set(7)           # θ table has 2 rows
    with pytest.raises(ValueError, match="scalar-prefetched"):
        ops.dekrr_step(g, d, s, p, theta, bad, self_idx, nbr_mask)
    with pytest.raises(ValueError, match="scalar-prefetched"):
        ops.dekrr_solve(g, d, s, p, theta, bad, self_idx, nbr_mask,
                        num_rounds=2)
    # masked slots may carry any in-range-irrelevant garbage? No — but an
    # out-of-range index under a ZERO mask is never gathered with effect,
    # and the staging layer pads with the self index; the ops wrapper
    # therefore only validates LIVE slots:
    masked = nbr_mask.at[0, 0].set(0.0)
    out = ops.dekrr_step(g, d, s, p, theta, bad, self_idx, masked)
    assert out.shape == d.shape
    # self_idx is unmasked — always validated
    with pytest.raises(ValueError, match="self_idx"):
        ops.dekrr_step(g, d, s, p, theta, nbr_idx,
                       jnp.array([0, 9], jnp.int32), nbr_mask)


def test_pack_staging_rejects_out_of_range_slot_index():
    from repro.dist.dekrr_spmd import _validate_slot_table

    idx = np.array([[1], [0]], np.int32)
    mask = np.ones((2, 1))
    assert _validate_slot_table(idx, mask, 2) == 2
    with pytest.raises(ValueError, match="scalar-prefetched"):
        _validate_slot_table(np.array([[2], [0]], np.int32), mask, 2)
    with pytest.raises(ValueError, match="shape mismatch"):
        _validate_slot_table(idx, np.ones((2, 3)), 2)


def test_async_mask_table_guard():
    from repro.dist.async_gossip import _check_mask_table, init_async_state
    from repro.dist import async_gossip as AG

    _check_mask_table("t", np.ones((5, 3), bool), 5, 3)
    with pytest.raises(ValueError, match="activation-mask"):
        _check_mask_table("t", np.ones((5, 4), bool), 5, 3)
    # async_step_batched rejects a mis-sized per-round mask row
    packed = JL.synthetic_packed(j_nodes=4, d_feat=8)
    state = init_async_state(packed)
    with pytest.raises(ValueError, match="activation-mask"):
        AG.async_step_batched(packed, state, jnp.ones(5, bool))


# ---------------------------------------------------------------------------
# comm_bytes_per_round: static edge count, no device read-back
# ---------------------------------------------------------------------------
class _PoisonArray:
    """Fails the test if anything tries to materialize it on the host."""
    def __array__(self, *a, **k):
        raise AssertionError("comm_bytes_per_round read nbr_mask off "
                             "the device")


def test_comm_bytes_static_edge_count():
    from repro.dist.dekrr_spmd import comm_bytes_per_round

    packed = JL.synthetic_packed(j_nodes=4, d_feat=8)
    assert packed.num_edges_directed == int(
        np.count_nonzero(np.asarray(packed.nbr_mask)))
    want = comm_bytes_per_round(packed, "ppermute")
    # with the static count recorded, the mask array is never touched
    poisoned = dataclasses.replace(packed, nbr_mask=_PoisonArray())
    assert comm_bytes_per_round(poisoned, "ppermute") == want
    # NumPy fallback for hand-built problems matches
    legacy = dataclasses.replace(packed, num_edges_directed=None)
    assert comm_bytes_per_round(legacy, "ppermute") == want


def test_packed_static_fields_survive_jit():
    packed = JL.synthetic_packed(j_nodes=4, d_feat=8)
    out = jax.jit(lambda p: p)(packed)
    assert out.num_edges_directed == packed.num_edges_directed
    assert out.offsets == packed.offsets


# ---------------------------------------------------------------------------
# jaxpr lint: live entry points clean + dispatch-count pins
# ---------------------------------------------------------------------------
def _entry_point_map():
    return {ep.label: ep for ep in JL.batched_entry_points()}


def test_live_jaxpr_lint_clean():
    findings = JL.run_pass(spmd=False)
    assert findings == [], render_report(findings)


@pytest.mark.parametrize("backend,sync_n,async_n,cheb_n", [
    ("xla", 0, 0, 0), ("pallas", 5, 5, 5), ("pallas_fused", 1, 1, 1)])
def test_dispatch_count_contract(backend, sync_n, async_n, cheb_n):
    eps = _entry_point_map()
    for label, want in (
            (f"solve_batched[backend={backend},tol=0]", sync_n),
            (f"async_solve_batched[backend={backend},tol=0]", async_n),
            (f"chebyshev_solve_packed[backend={backend}]", cheb_n)):
        ep = eps[label]
        assert ep.expected_dispatches == want
        count, exact = JL.count_pallas_dispatches(ep.trace())
        assert exact and count == want


def test_ops_wrappers_dispatch_once():
    eps = _entry_point_map()
    for label in ("ops.dekrr_step", "ops.dekrr_solve"):
        count, exact = JL.count_pallas_dispatches(eps[label].trace())
        assert exact and count == 1
    count, exact = JL.count_pallas_dispatches(
        eps["StreamingDeKRR.ingest"].trace())
    assert exact and count == 0


# ---------------------------------------------------------------------------
# jaxpr lint: seeded violations (one per rule)
# ---------------------------------------------------------------------------
def _rules(findings):
    return [f.rule for f in findings]


def test_seeded_callback_in_loop_detected():
    def bad(x):
        def body(c, _):
            v = jax.pure_callback(
                lambda a: np.asarray(a),
                jax.ShapeDtypeStruct((), x.dtype), c)
            return c + v, None
        return lax.scan(body, x, None, length=3)[0]

    cj = jax.make_jaxpr(bad)(jnp.float64(1.0))
    assert "J001" in _rules(JL.lint_program(cj, "seed"))


def test_seeded_loop_downcast_detected():
    def bad(x):
        def body(c):
            return (c[0].astype(jnp.float32).astype(jnp.float64),
                    c[1] + 1)
        return lax.while_loop(lambda c: c[1] < 3, body, (x, 0))

    cj = jax.make_jaxpr(bad)(jnp.float64(1.0))
    assert "J004" in _rules(JL.lint_program(cj, "seed"))


def test_ppermute_bijection_helper():
    # identity-free ring shift is a bijection
    assert JL.ppermute_perm_errors([(i, (i + 1) % 4)
                                    for i in range(4)], 4) == []
    # duplicated destination
    assert JL.ppermute_perm_errors([(0, 1), (1, 1)], 4)
    # partial coverage over the axis
    assert JL.ppermute_perm_errors([(0, 1), (1, 0)], 4)
    # out-of-range endpoint
    assert JL.ppermute_perm_errors([(0, 4)], 4)


def test_seeded_dispatch_contract_violation_detected():
    eps = _entry_point_map()
    ep = eps["solve_batched[backend=pallas_fused,tol=0]"]
    findings = JL.lint_program(ep.trace(), ep.label,
                               expected_dispatches=3)   # truth is 1
    assert "J002" in _rules(findings)


SPMD_ANALYSIS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    import jax.numpy as jnp
    from jax import lax
    from jax.sharding import Mesh, PartitionSpec
    from repro.analysis import jaxpr_lint as JL
    from repro.dist.dekrr_spmd import shard_map

    # live repo: all entry points (incl. SPMD ppermute/allgather) clean
    findings = JL.run_pass()
    assert not findings, [f.render() for f in findings]

    mesh = Mesh(np.array(jax.devices()[:4]), ("nodes",))
    P = PartitionSpec

    # seeded J003: non-bijective ppermute under shard_map
    def bad_perm(x):
        def prog(x):
            return lax.ppermute(x, "nodes", [(0, 1), (1, 2)])
        return shard_map(prog, mesh=mesh, in_specs=P("nodes"),
                         out_specs=P("nodes"), check_rep=False)(x)
    cj = jax.make_jaxpr(bad_perm)(jnp.zeros((4, 2)))
    rules = [f.rule for f in JL.lint_program(cj, "seed")]
    assert "J003" in rules, rules

    # seeded J005: device-varying while predicate gating a collective
    ring = [(i, (i + 1) % 4) for i in range(4)]
    def unreplicated_loop(x):
        def prog(x):
            me = lax.axis_index("nodes")
            def cond(c):
                return c[1] < me + 1
            def body(c):
                return (c[0] + lax.ppermute(c[0], "nodes", ring),
                        c[1] + 1)
            return lax.while_loop(cond, body, (x, 0))[0]
        return shard_map(prog, mesh=mesh, in_specs=P("nodes"),
                         out_specs=P("nodes"), check_rep=False)(x)
    cj = jax.make_jaxpr(unreplicated_loop)(jnp.zeros((4, 2)))
    rules = [f.rule for f in JL.lint_program(cj, "seed")]
    assert "J005" in rules, rules

    # negative: pmax-derived (replicated) predicate must stay clean
    def replicated_loop(x):
        def prog(x):
            def cond(c):
                return c[1] < 3
            def body(c):
                d = lax.pmax(jnp.max(c[0]), "nodes")
                return (c[0] + lax.ppermute(c[0], "nodes", ring)
                        + d * 0, c[1] + 1)
            return lax.while_loop(cond, body, (x, 0))[0]
        return shard_map(prog, mesh=mesh, in_specs=P("nodes"),
                         out_specs=P("nodes"), check_rep=False)(x)
    cj = jax.make_jaxpr(replicated_loop)(jnp.zeros((4, 2)))
    rules = [f.rule for f in JL.lint_program(cj, "seed")]
    assert "J005" not in rules, rules
    print("SPMD-ANALYSIS-OK")
""")


def test_spmd_lint_and_replication_seeds():
    proc = subprocess.run(
        [sys.executable, "-c", SPMD_ANALYSIS_SCRIPT],
        capture_output=True, text=True, timeout=600,
        cwd=REPO_ROOT, env=subprocess_env())
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SPMD-ANALYSIS-OK" in proc.stdout


# ---------------------------------------------------------------------------
# conventions: live repo clean + one seeded violation per rule
# ---------------------------------------------------------------------------
def test_live_conventions_clean():
    paths = [os.path.join(REPO_ROOT, p)
             for p in ("src", "tests", "benchmarks")]
    findings = conventions.run_pass(paths, repo_root=REPO_ROOT)
    assert findings == [], render_report(findings)


def _lint_src(source, filename="seed.py", tmp_path=None):
    path = filename if tmp_path is None else str(tmp_path / filename)
    return [f.rule for f in conventions.lint_file(
        path, source=source,
        repo_root=None if tmp_path is None else str(tmp_path))]


def test_seeded_missing_backend_detected():
    src = "def solve_batched(packed, num_iters):\n    return None\n"
    assert _lint_src(src) == ["R001"]


def test_seeded_tracer_cast_detected():
    src = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from functools import partial

        @partial(jax.jit, static_argnames=("n",))
        def f(x, n):
            m = int(num_iters)            # bare name: static arg, exempt
            v = float(jnp.sum(x))         # tracer cast: flagged
            w = x.max().item()            # device sync: flagged
            k = int(x.shape[0])           # static metadata: exempt
            return v + w + m + k
    """)
    assert _lint_src(src) == ["R002", "R002"]


def test_seeded_tracer_cast_outside_jit_clean():
    src = textwrap.dedent("""
        import jax.numpy as jnp

        def host_loop(x):
            return float(jnp.max(x))      # not a jit context
    """)
    assert _lint_src(src) == []


def test_seeded_tight_rtol_without_x64_detected(tmp_path):
    src = textwrap.dedent("""
        import numpy as np

        def test_parity():
            np.testing.assert_allclose(1.0, 1.0, rtol=1e-9)
    """)
    assert _lint_src(src, "test_seed.py", tmp_path) == ["R003"]
    fixed = 'import jax\njax.config.update("jax_enable_x64", True)\n' + src
    assert _lint_src(fixed, "test_seed.py", tmp_path) == []
    # an ancestor conftest enabling x64 also satisfies the rule
    (tmp_path / "conftest.py").write_text(
        'import jax\njax.config.update("jax_enable_x64", True)\n')
    assert _lint_src(src, "test_seed.py", tmp_path) == []


def test_seeded_raw_interpret_detected():
    src = textwrap.dedent("""
        from repro.kernels.rff_gram import rff_gram_pallas

        def direct(a, b, x, y, m):
            return rff_gram_pallas(a, b, x, y, m, scale=1.0,
                                   block_n=128, interpret=True)
    """)
    assert _lint_src(src) == ["R004"]


def test_seeded_bare_except_detected():
    src = "try:\n    pass\nexcept:\n    pass\n"
    assert _lint_src(src) == ["R005"]
    waived = "try:\n    pass\nexcept:  # analysis: ignore[R005]\n    pass\n"
    assert _lint_src(waived) == []


# ---------------------------------------------------------------------------
# report + CLI
# ---------------------------------------------------------------------------
def test_report_rendering():
    import json

    fs = [Finding("vmem", "V001", "k", "over budget"),
          Finding("jaxpr", "J005", "ep", "not provably replicated",
                  severity="warning")]
    doc = json.loads(render_json(fs))
    assert doc["num_errors"] == 1 and doc["num_warnings"] == 1
    assert doc["findings"][0]["rule"] == "V001"
    text = render_report(fs)
    assert "[V001]" in text and "[J005]" in text
    assert "clean" in render_report([])


def test_cli_conventions_json():
    import json

    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--pass", "conventions",
         "--format", "json", "src", "tests", "benchmarks"],
        capture_output=True, text=True, timeout=300,
        cwd=REPO_ROOT, env=subprocess_env())
    assert proc.returncode == 0, proc.stdout + proc.stderr
    doc = json.loads(proc.stdout)
    assert doc["num_errors"] == 0
    assert "conventions" in doc["timings_s"]
