"""Checkpoint round-trip tests (msgpack pytree serialization)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.train import AdamWConfig, train_state_init
from repro.train.checkpoint import load_checkpoint, save_checkpoint


def test_roundtrip_nested_pytree(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2,), jnp.bfloat16), "d": 3, "e": "tag"},
        "f": [jnp.zeros((1,), jnp.int32), 2.5, None],
        "g": (jnp.full((2, 2), 7, jnp.int8),),
    }
    path = save_checkpoint(str(tmp_path / "ck.msgpack"), tree, step=42)
    loaded, step = load_checkpoint(path)
    assert step == 42
    np.testing.assert_array_equal(np.asarray(loaded["a"]),
                                  np.asarray(tree["a"]))
    assert loaded["b"]["c"].dtype == jnp.bfloat16
    assert loaded["b"]["d"] == 3 and loaded["b"]["e"] == "tag"
    assert isinstance(loaded["f"], list) and loaded["f"][2] is None
    assert isinstance(loaded["g"], tuple)
    np.testing.assert_array_equal(np.asarray(loaded["g"][0]),
                                  np.asarray(tree["g"][0]))


def test_roundtrip_train_state(tmp_path):
    cfg = get_arch("smollm_135m").config.reduced()
    opt = AdamWConfig(total_steps=10)
    state = train_state_init(cfg, opt, jax.random.PRNGKey(0))
    path = save_checkpoint(str(tmp_path / "state.msgpack"),
                           {"params": state.params, "opt": state.opt},
                           step=7)
    loaded, step = load_checkpoint(path)
    assert step == 7
    for a, b in zip(jax.tree.leaves(loaded["params"]),
                    jax.tree.leaves(state.params)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(
            np.asarray(a, dtype=np.float32), np.asarray(b, np.float32))


def test_atomic_overwrite(tmp_path):
    p = str(tmp_path / "ck.msgpack")
    save_checkpoint(p, {"x": jnp.zeros(3)}, step=1)
    save_checkpoint(p, {"x": jnp.ones(3)}, step=2)
    loaded, step = load_checkpoint(p)
    assert step == 2
    np.testing.assert_array_equal(np.asarray(loaded["x"]), np.ones(3))
