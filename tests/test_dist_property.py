"""Property tests for the packed runtime: pack/unpack round-trips, slot-table
invariants, the §II-C comm cost model (incl. the async expected-bytes
extension), the batched `pack_problem` regression (no per-node tracing;
bit-identical to the per-node replay), the pack downgrade warn/raise
contract, and the async-gossip schedule/staleness invariants."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from conftest import cached_fmaps, cached_split
from repro.core import (DeKRRConfig, DeKRRSolver, activation_mask,
                        activation_masks, circulant, edge_list,
                        erdos_renyi)
from repro.dist import (PackedProblem, async_step_batched,
                        comm_bytes_per_round, init_async_state,
                        pack_problem, pack_theta, unpack_theta)
from repro.dist.dekrr_spmd import (_pack_problem_pernode, _slot_table,
                                   pack_trace_count)


def _synthetic_packed(node_dims, topo, dtype=np.float64) -> PackedProblem:
    """A structurally valid PackedProblem (zero matrices) for a topology."""
    fake = types.SimpleNamespace(
        topology=topo, data=[types.SimpleNamespace(x=np.zeros(1, dtype))])
    nbr_idx, nbr_mask, offsets = _slot_table(fake)
    j, k = nbr_idx.shape
    d_max = max(node_dims)
    theta_mask = (np.arange(d_max)[None, :]
                  < np.asarray(node_dims)[:, None]).astype(dtype)
    return PackedProblem(
        g=jnp.zeros((j, d_max, d_max), dtype),
        d=jnp.zeros((j, d_max), dtype),
        s=jnp.zeros((j, d_max, d_max), dtype),
        p=jnp.zeros((j, k, d_max, d_max), dtype),
        theta_mask=jnp.asarray(theta_mask),
        nbr_idx=jnp.asarray(nbr_idx), nbr_mask=jnp.asarray(nbr_mask),
        offsets=offsets, node_dims=tuple(int(v) for v in node_dims),
    )


# --------------------------------------------------------------------------
# pack_theta / unpack_theta round-trips
# --------------------------------------------------------------------------
@given(j_nodes=st.integers(3, 12), d_lo=st.integers(1, 6),
       d_hi=st.integers(7, 20), seed=st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_theta_pack_unpack_round_trip(j_nodes, d_lo, d_hi, seed):
    rng = np.random.default_rng(seed)
    dims = rng.integers(d_lo, d_hi + 1, j_nodes)
    packed = _synthetic_packed(dims, circulant(j_nodes, (1,)))

    ragged = [jnp.asarray(rng.normal(size=dj)) for dj in dims]
    theta = pack_theta(packed, ragged)
    assert theta.shape == (j_nodes, max(dims))
    # padded slots are exact zeros == theta_mask complement
    assert not np.any(np.asarray(theta)[np.asarray(packed.theta_mask) == 0])
    back = unpack_theta(packed, theta)
    for a, b in zip(ragged, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and the other direction: unpack → pack is the identity on padded θ
    np.testing.assert_array_equal(
        np.asarray(pack_theta(packed, back)), np.asarray(theta))


@given(j_nodes=st.integers(3, 10), seed=st.integers(0, 2**16),
       grow=st.integers(1, 8))
@settings(max_examples=15, deadline=None)
def test_theta_roundtrip_after_dims_refresh_property(j_nodes, seed, grow):
    """A per-node feature refresh changes node_dims (possibly D_max): θ
    from the old packing must re-pad losslessly into the new one when
    dims only grew, and raise a CLEAR error — never silently truncate —
    when a stale θ meets shrunken dims or a re-padded width."""
    rng = np.random.default_rng(seed)
    old_dims = rng.integers(4, 12, j_nodes)
    topo = circulant(j_nodes, (1,))
    old_packed = _synthetic_packed(old_dims, topo)
    ragged = [jnp.asarray(rng.normal(size=dj)) for dj in old_dims]
    theta_old = pack_theta(old_packed, ragged)

    # refresh node 0 with MORE features (D_max may grow): lossless re-pad
    new_dims = old_dims.copy()
    new_dims[0] = old_dims[0] + grow
    new_packed = _synthetic_packed(new_dims, topo)
    carried = list(ragged)
    carried[0] = jnp.zeros(int(new_dims[0]))    # refreshed node: new basis
    repacked = pack_theta(new_packed, carried)
    back = unpack_theta(new_packed, repacked)
    for j in range(1, j_nodes):
        np.testing.assert_array_equal(np.asarray(back[j]),
                                      np.asarray(ragged[j]))
    np.testing.assert_array_equal(
        np.asarray(pack_theta(new_packed, back)), np.asarray(repacked))

    # refresh node 0 with FEWER features: the stale θ is rejected loudly
    shrunk = old_dims.copy()
    shrunk[0] = max(1, old_dims[0] - 1)
    shrunk_packed = _synthetic_packed(shrunk, topo)
    with pytest.raises(ValueError, match="stale"):
        pack_theta(shrunk_packed, ragged)

    # a packed θ of the wrong width never truncates silently
    if max(new_dims) != max(old_dims):
        with pytest.raises(ValueError, match="different packing"):
            unpack_theta(new_packed, theta_old)


# --------------------------------------------------------------------------
# Slot-table invariants
# --------------------------------------------------------------------------
def _slot_table_for(topo):
    fake = types.SimpleNamespace(
        topology=topo, data=[types.SimpleNamespace(x=np.zeros(1))])
    return _slot_table(fake)


@given(j_nodes=st.integers(5, 14), p_edge=st.sampled_from([0.3, 0.5, 0.8]),
       seed=st.integers(0, 2**10))
@settings(max_examples=10, deadline=None)
def test_generic_slot_table_invariants(j_nodes, p_edge, seed):
    """Live slots enumerate each node's true neighbors exactly once; padded
    slots are masked and point at the node itself (harmless gather)."""
    topo = erdos_renyi(j_nodes, p_edge, seed=seed)
    nbr_idx, nbr_mask, offsets = _slot_table_for(topo)
    if offsets is not None:     # an ER draw can happen to be circulant
        return
    for j in range(j_nodes):
        live = nbr_mask[j] != 0
        assert sorted(nbr_idx[j][live].tolist()) == topo.neighbors(j)
        assert np.all(nbr_idx[j][~live] == j)
        # mask is a prefix: live slots first, padding after
        assert not np.any(np.diff(live.astype(int)) > 0)


@given(j_nodes=st.integers(5, 16), use_two=st.sampled_from([False, True]))
@settings(max_examples=10, deadline=None)
def test_circulant_slot_table_is_ppermute_ordered(j_nodes, use_two):
    offsets = (1, 2) if use_two and j_nodes >= 5 else (1,)
    topo = circulant(j_nodes, offsets)
    nbr_idx, nbr_mask, got_offsets = _slot_table_for(topo)
    assert got_offsets == offsets
    assert np.all(nbr_mask == 1)            # circulant layout has no padding
    for j in range(j_nodes):
        want = []
        for s in offsets:
            want.extend([(j + s) % j_nodes, (j - s) % j_nodes])
        assert nbr_idx[j].tolist() == want


def test_packed_masked_slots_carry_zero_p_blocks():
    """The iteration's padding closure relies on masked slots having
    *zero* P blocks, not merely a mask bit."""
    topo = erdos_renyi(6, 0.4, seed=3)
    ds, train, _ = cached_split("air_quality", 6, subsample=400, seed=0)
    fmaps = cached_fmaps("air_quality", 6, (8, 10, 12, 8, 10, 12),
                         subsample=400, seed=0)
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=1e-6, c_nei=0.02 * n))
    packed = pack_problem(solver)
    mask = np.asarray(packed.nbr_mask)
    p = np.asarray(packed.p)
    for j in range(6):
        for k in range(mask.shape[1]):
            if not mask[j, k]:
                assert not np.any(p[j, k])
        # padded θ coordinates: zero rows/cols everywhere
        dj = packed.node_dims[j]
        assert not np.any(np.asarray(packed.g)[j, dj:, :])
        assert not np.any(np.asarray(packed.g)[j, :, dj:])
        assert not np.any(np.asarray(packed.d)[j, dj:])


# --------------------------------------------------------------------------
# §II-C comm cost model: ppermute vs allgather consistency
# --------------------------------------------------------------------------
@given(j_nodes=st.integers(5, 16), use_two=st.sampled_from([False, True]),
       d_max=st.sampled_from([8, 24, 64]))
@settings(max_examples=12, deadline=None)
def test_comm_bytes_consistency_on_circulant_graphs(j_nodes, use_two, d_max):
    offsets = (1, 2) if use_two and j_nodes >= 5 else (1,)
    topo = circulant(j_nodes, offsets)
    dims = [d_max - (j % 3) for j in range(j_nodes)]
    packed = _synthetic_packed(dims, topo)
    itemsize = np.dtype(packed.d.dtype).itemsize

    pp = comm_bytes_per_round(packed, "ppermute")
    ag = comm_bytes_per_round(packed, "allgather")
    # ppermute moves exactly the paper's Σ_j |N_j| padded words…
    assert pp == int(topo.degrees.sum()) * max(dims) * itemsize
    # …allgather moves the full network state minus the own shard…
    assert ag == j_nodes * (j_nodes - 1) * max(dims) * itemsize
    # …and the two models agree on the shared factors: for a circulant
    # graph ppermute/allgather == degree/(J−1) exactly.
    assert pp * (j_nodes - 1) == ag * int(topo.degrees[0])


def test_comm_bytes_equal_on_complete_circulant():
    """On a complete graph both exchanges move the same bytes."""
    from repro.core import complete
    topo = complete(7)
    packed = _synthetic_packed([16] * 7, topo)
    assert (comm_bytes_per_round(packed, "ppermute")
            == comm_bytes_per_round(packed, "allgather"))


# --------------------------------------------------------------------------
# Batched pack_problem regression (the removed per-node Python loop)
# --------------------------------------------------------------------------
def _regression_solver():
    topo = circulant(8, (1, 2))
    dims = (8, 12, 16, 20, 8, 12, 16, 20)
    ds, train, _ = cached_split("air_quality", 8, subsample=400, seed=0)
    fmaps = cached_fmaps("air_quality", 8, dims, subsample=400, seed=0)
    n = sum(t.num_samples for t in train)
    return DeKRRSolver(topo, fmaps, train,
                       DeKRRConfig(lam=1e-6, c_nei=0.02 * n),
                       build_aux=False)


def test_batched_pack_traces_once_and_matches_pernode_loop_bitwise():
    """The batched Eq. 17 build must (a) trace one program per problem
    shape — never once per node, and not again on repeat packing — and
    (b) produce bit-identical PackedProblem contents to the removed
    per-node Python loop (`_pack_problem_pernode`, batch-of-1 replay of
    the same program) on a fixed seed."""
    solver = _regression_solver()

    t0 = pack_trace_count()
    packed = pack_problem(solver)
    traced_first = pack_trace_count() - t0
    assert traced_first <= 1, \
        f"batched pack traced {traced_first}× (per-node tracing?)"

    t1 = pack_trace_count()
    repacked = pack_problem(solver)
    assert pack_trace_count() - t1 == 0, "repeat packing re-traced"

    loop = _pack_problem_pernode(solver)
    for name in ("g", "d", "s", "p", "theta_mask", "nbr_idx", "nbr_mask"):
        batched = np.asarray(getattr(packed, name))
        np.testing.assert_array_equal(
            batched, np.asarray(getattr(repacked, name)),
            err_msg=f"{name}: repeat packing changed bits")
        np.testing.assert_array_equal(
            batched, np.asarray(getattr(loop, name)),
            err_msg=f"{name}: batched != per-node loop")
    assert packed.offsets == loop.offsets
    assert packed.node_dims == loop.node_dims
    # the batched path must never materialize the ragged reference aux
    assert solver._aux is None


def test_batched_pack_matches_reference_aux_pack():
    """Same contents as the legacy `method="aux"` pack (which copies the
    ragged reference build) at solver-parity tolerance — different
    summation orders make bitwise equality impossible across the two
    computations, rtol 1e-9 is the module's contract."""
    solver = _regression_solver()
    batched = pack_problem(solver)
    legacy = pack_problem(solver, method="aux")
    for name in ("d", "s", "p"):
        np.testing.assert_allclose(
            np.asarray(getattr(batched, name)),
            np.asarray(getattr(legacy, name)), rtol=1e-9, atol=1e-15,
            err_msg=name)
    # g is an inverse, so its entrywise agreement degrades with cond(A):
    # looser rtol plus an atol scaled to ||g||_max instead of the 1e-9 used
    # for the directly-computed d/s/p blocks
    g_b, g_l = np.asarray(batched.g), np.asarray(legacy.g)
    np.testing.assert_allclose(g_b, g_l, rtol=1e-6,
                               atol=1e-9 * np.max(np.abs(g_l)))


# --------------------------------------------------------------------------
# pack_problem downgrade contract: warn, never silently ignore gram_backend
# --------------------------------------------------------------------------
def _gram_fn_solver():
    """A solver the batched build cannot honor (custom gram_fn)."""
    topo = circulant(4, (1,))
    ds, train, _ = cached_split("air_quality", 4, subsample=300, seed=0)
    fmaps = cached_fmaps("air_quality", 4, (8, 10, 8, 10),
                         subsample=300, seed=0)
    n = sum(t.num_samples for t in train)
    gram_fn = lambda fm, x: (lambda z: z @ z.T)(fm(x))
    return DeKRRSolver(topo, fmaps, train,
                       DeKRRConfig(lam=1e-6, c_nei=0.02 * n),
                       gram_fn=gram_fn)


def test_pack_problem_warns_on_silent_aux_downgrade():
    """method="batched" with a gram_fn solver must fall back to the aux
    build LOUDLY — the downgrade swaps a vmapped one-trace program for a
    per-node Python loop."""
    solver = _gram_fn_solver()
    with pytest.warns(UserWarning, match="downgraded to method='aux'"):
        packed = pack_problem(solver)
    # the downgrade itself still works and records layout metadata
    assert packed.node_dims == (8, 10, 8, 10)


def test_pack_problem_aux_explicitly_requested_does_not_warn():
    import warnings as _w
    solver = _gram_fn_solver()
    with _w.catch_warnings(record=True) as caught:
        _w.simplefilter("always")
        pack_problem(solver, method="aux")
    assert not [c for c in caught if "downgraded" in str(c.message)], \
        "explicit method='aux' is not a downgrade and must not warn"


def test_pack_problem_raises_when_pallas_gram_would_be_ignored():
    """gram_backend="pallas" on a path that cannot run the streaming Gram
    kernel must raise, never silently compute the blocks elsewhere."""
    solver = _gram_fn_solver()
    with pytest.raises(ValueError, match="gram_fn"):
        pack_problem(solver, gram_backend="pallas")
    with pytest.raises(ValueError, match="ignores gram_backend"):
        pack_problem(solver, method="aux", gram_backend="pallas")


# --------------------------------------------------------------------------
# Async gossip: activation-mask determinism from the PRNG key
# --------------------------------------------------------------------------
@given(j_nodes=st.integers(3, 12), seed=st.integers(0, 2**16),
       prob=st.sampled_from([0.25, 0.5, 0.75, 1.0]),
       num_rounds=st.integers(1, 12))
@settings(max_examples=10, deadline=None)
def test_bernoulli_activation_masks_deterministic(j_nodes, seed, prob,
                                                  num_rounds):
    """The precomputed [R, J] schedule must be a pure function of the key
    — recomputation is bit-identical, and row r equals the single-round
    spec `activation_mask(key, r, …)` every layer is defined against."""
    key = jax.random.PRNGKey(seed)
    masks = activation_masks(key, num_rounds, j_nodes, prob=prob)
    again = activation_masks(key, num_rounds, j_nodes, prob=prob)
    np.testing.assert_array_equal(np.asarray(masks), np.asarray(again))
    for r in range(num_rounds):
        np.testing.assert_array_equal(
            np.asarray(masks[r]),
            np.asarray(activation_mask(key, r, j_nodes, prob=prob)),
            err_msg=f"round {r}")
    if prob == 1.0:
        assert np.all(np.asarray(masks))


@given(j_nodes=st.integers(4, 10), seed=st.integers(0, 2**10),
       num_rounds=st.integers(1, 10))
@settings(max_examples=10, deadline=None)
def test_edge_activation_masks_are_single_edges(j_nodes, seed, num_rounds):
    """Every edge-gossip round activates exactly the two endpoints of one
    existing edge, deterministically in the key."""
    topo = erdos_renyi(j_nodes, 0.5, seed=seed % 7)
    edges = edge_list(topo)
    key = jax.random.PRNGKey(seed)
    masks = np.asarray(activation_masks(key, num_rounds, j_nodes,
                                        gossip="edge", edges=edges))
    edge_set = {tuple(e) for e in edges.tolist()}
    for r in range(num_rounds):
        active = np.nonzero(masks[r])[0]
        assert len(active) == 2
        assert tuple(active.tolist()) in edge_set
    np.testing.assert_array_equal(
        masks, np.asarray(activation_masks(key, num_rounds, j_nodes,
                                           gossip="edge", edges=edges)))


# --------------------------------------------------------------------------
# Async gossip: staleness-buffer invariants on the packed runtime
# --------------------------------------------------------------------------
def _random_packed(topo, d_max, seed, dtype=np.float64) -> PackedProblem:
    """A random nonzero PackedProblem (spectra bounded so iterates stay
    finite) on a real slot table — enough structure for wire-traffic
    properties without an Eq. 17 build."""
    fake = types.SimpleNamespace(
        topology=topo, data=[types.SimpleNamespace(x=np.zeros(1, dtype))])
    nbr_idx, nbr_mask, offsets = _slot_table(fake)
    j, k = nbr_idx.shape
    rng = np.random.default_rng(seed)
    scale = 0.3 / d_max
    return PackedProblem(
        g=jnp.asarray(rng.normal(size=(j, d_max, d_max)) * scale),
        d=jnp.asarray(rng.normal(size=(j, d_max))),
        s=jnp.asarray(rng.normal(size=(j, d_max, d_max)) * scale),
        p=jnp.asarray(rng.normal(size=(j, k, d_max, d_max)) * scale
                      * np.asarray(nbr_mask)[:, :, None, None]),
        theta_mask=jnp.ones((j, d_max), dtype),
        nbr_idx=jnp.asarray(nbr_idx), nbr_mask=jnp.asarray(nbr_mask),
        offsets=offsets, node_dims=tuple([d_max] * j),
    )


@given(seed=st.integers(0, 2**10), prob=st.sampled_from([0.25, 0.5, 0.75]),
       censored=st.sampled_from([False, True]))
@settings(max_examples=8, deadline=None)
def test_staleness_buffer_invariant(seed, prob, censored):
    """An inactive (or censored) node's broadcast θ never changes: its
    `sent` vector and every receive buffer fed by it stay bit-identical
    until the node actually broadcasts again — and under bernoulli
    delivery a buffer always equals its sender's last broadcast."""
    topo = erdos_renyi(7, 0.5, seed=seed % 5)
    packed = _random_packed(topo, 8, seed)
    key = jax.random.PRNGKey(seed)
    masks = activation_masks(key, 10, 7, prob=prob)
    nbr_idx = np.asarray(packed.nbr_idx)
    live = np.asarray(packed.nbr_mask) != 0

    state = init_async_state(packed)
    for r in range(10):
        new, info = async_step_batched(
            packed, state, masks[r], threshold=0.05 * 0.9 ** r,
            censored=censored)
        bcast = np.asarray(info.bcast)
        received = np.asarray(info.received)
        # broadcasts are a subset of activations; deliveries of broadcasts
        assert not np.any(bcast & ~np.asarray(masks[r]))
        np.testing.assert_array_equal(received, live & bcast[nbr_idx])
        for j in range(7):
            if not bcast[j]:        # silent node: wire state frozen …
                np.testing.assert_array_equal(
                    np.asarray(new.sent[j]), np.asarray(state.sent[j]),
                    err_msg=f"round {r}: silent node {j} changed sent")
            slots = ~received[j]
            np.testing.assert_array_equal(      # … and so are its buffers
                np.asarray(new.buffers[j][slots]),
                np.asarray(state.buffers[j][slots]),
                err_msg=f"round {r}: undelivered buffer changed")
        # bernoulli delivery: buffer == sender's last broadcast, always
        np.testing.assert_array_equal(
            np.asarray(new.buffers)[live],
            np.asarray(new.sent)[nbr_idx][live],
            err_msg=f"round {r}: buffer diverged from sender's sent")
        state = new


def test_async_state_init_matches_synchronous_view():
    """Round-0 buffers must present θ0 exactly as the synchronous gather
    would — anything else breaks the p = 1 bitwise equivalence."""
    packed = _random_packed(circulant(6, (1, 2)), 8, seed=0)
    theta0 = jnp.asarray(np.random.default_rng(1).normal(size=(6, 8)))
    state = init_async_state(packed, theta0)
    np.testing.assert_array_equal(np.asarray(state.buffers),
                                  np.asarray(theta0)[packed.nbr_idx])
    np.testing.assert_array_equal(np.asarray(state.sent),
                                  np.asarray(theta0))


# --------------------------------------------------------------------------
# Async gossip: expected comm bytes monotone in activation probability
# --------------------------------------------------------------------------
@given(j_nodes=st.integers(5, 14), d_max=st.sampled_from([8, 24, 64]),
       censor=st.sampled_from([0.0, 0.2, 0.6]),
       mode=st.sampled_from(["ppermute", "allgather"]))
@settings(max_examples=12, deadline=None)
def test_expected_comm_bytes_monotone_in_activation_prob(j_nodes, d_max,
                                                         censor, mode):
    """E[bytes/round] is non-decreasing in p, non-increasing in the censor
    fraction, and collapses to the exact synchronous int at the defaults."""
    topo = circulant(j_nodes, (1, 2) if j_nodes >= 5 else (1,))
    packed = _synthetic_packed([d_max] * j_nodes, topo)
    grid = [0.1, 0.25, 0.5, 0.75, 1.0]
    got = [comm_bytes_per_round(packed, mode, activation_prob=p,
                                censor_fraction=censor) for p in grid]
    assert all(a <= b for a, b in zip(got, got[1:])), got
    base = comm_bytes_per_round(packed, mode)
    assert isinstance(base, int)
    assert got[-1] == pytest.approx(base * (1.0 - censor))
    # more censoring, fewer expected bytes (p fixed)
    heavier = comm_bytes_per_round(packed, mode, activation_prob=0.5,
                                   censor_fraction=min(censor + 0.3, 1.0))
    assert heavier <= comm_bytes_per_round(packed, mode,
                                           activation_prob=0.5,
                                           censor_fraction=censor)


def test_expected_comm_bytes_edge_gossip_and_validation():
    packed = _synthetic_packed([16] * 6, circulant(6, (1,)))
    itemsize = np.dtype(np.asarray(packed.d).dtype).itemsize
    # one edge per round: two directed deliveries, independent of p
    assert comm_bytes_per_round(packed, "ppermute", gossip="edge") \
        == 2 * 16 * itemsize
    assert comm_bytes_per_round(
        packed, "ppermute", gossip="edge", activation_prob=0.25,
        censor_fraction=0.5) == pytest.approx(16 * itemsize)
    with pytest.raises(ValueError, match="activation_prob"):
        comm_bytes_per_round(packed, "ppermute", activation_prob=0.0)
    with pytest.raises(ValueError, match="censor_fraction"):
        comm_bytes_per_round(packed, "ppermute", censor_fraction=1.5)
    with pytest.raises(ValueError, match="gossip"):
        comm_bytes_per_round(packed, "ppermute", gossip="pairwise")
