import numpy as np
import pytest

from repro.core.graph import (Topology, circulant, complete, erdos_renyi,
                              ring, star)


def test_circulant_paper_topology():
    t = circulant(10, (1, 2))
    assert t.num_nodes == 10
    assert all(t.degree(j) == 4 for j in range(10))
    assert t.circulant_offsets == (1, 2)
    assert sorted(t.neighbors(0)) == [1, 2, 8, 9]


def test_ring_and_complete():
    assert all(ring(6).degree(j) == 2 for j in range(6))
    assert all(complete(5).degree(j) == 4 for j in range(5))


def test_star_degrees():
    t = star(7)
    assert t.degree(0) == 6
    assert all(t.degree(j) == 1 for j in range(1, 7))
    assert t.max_degree == 6


def test_erdos_renyi_connected_symmetric():
    t = erdos_renyi(12, 0.3, seed=3)
    a = t.adjacency
    assert np.array_equal(a, a.T)
    assert not np.any(np.diag(a))


def test_rejects_disconnected():
    a = np.zeros((4, 4), dtype=bool)
    a[0, 1] = a[1, 0] = True
    a[2, 3] = a[3, 2] = True
    with pytest.raises(ValueError, match="connected"):
        Topology(adjacency=a)


def test_rejects_asymmetric():
    a = np.zeros((3, 3), dtype=bool)
    a[0, 1] = True
    with pytest.raises(ValueError, match="undirected"):
        Topology(adjacency=a)


def test_neighbor_table_padding():
    t = star(5)
    idx, mask = t.neighbor_table()
    assert idx.shape == (5, 4) and mask.shape == (5, 4)
    assert mask[0].all()                      # hub has 4 neighbors
    assert mask[1].sum() == 1                 # leaves have 1
    assert (idx[1][~mask[1]] == 1).all()      # padded with self-index
