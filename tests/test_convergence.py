"""Proposition 1: monotone descent of the Eq. 13 objective."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st
from conftest import cached_fmaps, cached_split

from repro.core import (DeKRRConfig, DeKRRSolver, circulant,
                        prop1_required_c_self)


def _small_problem(J=5, D=10, n_sub=400, seed=0, method="energy"):
    topo = circulant(J, (1, 2))
    _, train, _ = cached_split("air_quality", J, subsample=n_sub, seed=seed)
    fmaps = cached_fmaps("air_quality", J, (D,) * J, method=method,
                         candidate_ratio=10, subsample=n_sub, seed=seed)
    return topo, fmaps, train


def test_objective_monotone_under_prop1_condition():
    topo, fmaps, train = _small_problem()
    n = sum(t.num_samples for t in train)
    # pick c_self comfortably above the Prop. 1 bound
    base = DeKRRSolver(topo, fmaps, train,
                       DeKRRConfig(lam=1e-6, c_nei=0.05 * n, c_self_ratio=1.0))
    req = prop1_required_c_self(base)
    ratio = float(np.max(req / (0.05 * n))) * 1.2 + 1.0
    solver = DeKRRSolver(
        topo, fmaps, train,
        DeKRRConfig(lam=1e-6, c_nei=0.05 * n, c_self_ratio=ratio))
    state = solver.init_state()
    prev = float(solver.objective(state.theta))
    for _ in range(25):
        state = solver.step(state)
        cur = float(solver.objective(state.theta))
        assert cur <= prev + 1e-10, "objective increased under Prop. 1"
        prev = cur


def test_paper_default_ratio_5_descends_in_practice():
    """Paper §IV: c_self = 5 c_nei is used in practice (below the worst-case
    bound) and still descends on real-ish problems."""
    topo, fmaps, train = _small_problem(seed=3)
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=1e-6, c_nei=0.02 * n,
                                     c_self_ratio=5.0))
    state = solver.init_state()
    prev = float(solver.objective(state.theta))
    descents = 0
    for _ in range(30):
        state = solver.step(state)
        cur = float(solver.objective(state.theta))
        descents += cur <= prev + 1e-10
        prev = cur
    assert descents == 30


@given(seed=st.integers(0, 50))
@settings(max_examples=8, deadline=None)
def test_objective_descent_property(seed):
    """Property: for random problems, Prop. 1-satisfying c_self descends."""
    topo, fmaps, train = _small_problem(J=4, D=6, n_sub=300, seed=seed,
                                        method="plain")
    n = sum(t.num_samples for t in train)
    base = DeKRRSolver(topo, fmaps, train,
                       DeKRRConfig(lam=1e-5, c_nei=0.05 * n, c_self_ratio=1.0))
    req = prop1_required_c_self(base)
    ratio = float(np.max(req / (0.05 * n))) * 1.1 + 1.0
    if not np.isfinite(ratio) or ratio > 1e6:
        pytest.skip("degenerate Z_jj (λ_min ≈ 0): bound vacuous")
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=1e-5, c_nei=0.05 * n,
                                     c_self_ratio=ratio))
    state = solver.init_state()
    prev = float(solver.objective(state.theta))
    for _ in range(10):
        state = solver.step(state)
        cur = float(solver.objective(state.theta))
        assert cur <= prev + 1e-9
        prev = cur


def test_spectral_radius_below_one():
    topo, fmaps, train = _small_problem()
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=1e-6, c_nei=0.02 * n))
    assert solver.spectral_radius() < 1.0
