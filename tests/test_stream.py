"""repro.stream conformance: the online runtime against the batch rebuild.

The acceptance contract: after ANY ingest/refresh sequence the stream
state must match a from-scratch `pack_problem` + solve on the accumulated
data at rtol 1e-9 under x64 (the ridge pinned at stream start —
`reference_lam` gives the from-scratch λ), on every backend the runtime
claims. Covers:

  * rank-b Woodbury ingest parity after k minibatches, over
    {circulant, star, Erdős–Rényi, J=1} × both DDRF score families;
  * refresh-then-solve == solve-from-scratch on the refreshed features
    (D_j growing past the old D_max and shrinking below it);
  * StreamingDeKRR backend × gossip conformance and warm-start economics;
  * drift detection (stationary quiet / shifted loud) and the auto
    refresh trigger;
  * the serving path (wave batching, kernel vs XLA featurize parity,
    staleness bounds);
  * θ re-padding across refreshes and the SPMD tol/warm-start satellites.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from conftest import cached_fmaps, cached_split
from repro.core import (AsyncGossipConfig, DeKRRConfig, DeKRRSolver,
                        Topology, circulant, erdos_renyi, select_features,
                        star)
from repro.dist import (async_solve_batched, make_async_spmd_solver,
                        make_spmd_solver, pack_problem, pack_theta,
                        solve_batched, unpack_theta)
from repro.serve import DeKRRServeEngine, KernelQuery
from repro.stream import (DriftConfig, DriftDetector, StreamConfig,
                          StreamingDeKRR, ingest, init_stream_aux,
                          reference_lam, repad_theta)

LAM = 1e-3          # keeps cond(A) ≲ 1e5 so Woodbury vs direct inversion
                    # agree far below the rtol 1e-9 gate


def _single_node() -> Topology:
    return Topology(adjacency=np.zeros((1, 1), dtype=bool))


def _solver(topo, dims, method="energy", sub=300, seed=0):
    j = topo.num_nodes
    ds, train, _ = cached_split("air_quality", j, subsample=sub, seed=seed)
    fmaps = cached_fmaps("air_quality", j, tuple(dims), method=method,
                         subsample=sub, seed=seed)
    n = sum(t.num_samples for t in train)
    return DeKRRSolver(topo, fmaps, train,
                       DeKRRConfig(lam=LAM, c_nei=0.02 * n),
                       build_aux=False), ds


def _reference(rt: StreamingDeKRR) -> DeKRRSolver:
    return rt.reference_solver()


def _assert_packed_close(got, want, rtol=1e-9):
    assert got.node_dims == want.node_dims
    assert got.offsets == want.offsets
    np.testing.assert_array_equal(np.asarray(got.nbr_idx),
                                  np.asarray(want.nbr_idx))
    for name in ("g", "d", "s", "p", "theta_mask"):
        np.testing.assert_allclose(
            np.asarray(getattr(got, name)), np.asarray(getattr(want, name)),
            rtol=rtol, atol=1e-12, err_msg=name)


# --------------------------------------------------------------------------
# Woodbury ingest parity vs full rebuild
# --------------------------------------------------------------------------
@pytest.mark.parametrize("topo,dims", [
    (circulant(6, (1, 2)), [8, 12, 16, 8, 12, 16]),
    (star(5), [6, 8, 10, 12, 14]),
    (erdos_renyi(7, 0.5, seed=1), [9] * 7),
    (_single_node(), [10]),
])
@pytest.mark.parametrize("method", ["energy", "leverage"])
def test_ingest_parity_vs_full_rebuild(topo, dims, method):
    """After k minibatches the Woodbury-maintained packed state equals a
    from-scratch pack_problem on the accumulated data, rtol 1e-9 x64."""
    solver, ds = _solver(topo, dims, method=method)
    rt = StreamingDeKRR(solver)
    rng = np.random.default_rng(7)
    j = topo.num_nodes
    plan = [(0, 5), (j - 1, 17), (j // 2, 3), (0, 9)]
    for node, b in plan:
        rt.ingest(node, rng.normal(size=(ds.dim, b)), rng.normal(size=b))
    _assert_packed_close(rt.packed, pack_problem(_reference(rt)))
    # …and the solve from that state is the from-scratch solve
    want = solve_batched(pack_problem(_reference(rt)), 50)
    got = solve_batched(rt.packed, 50)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-9, atol=1e-12)


def test_reference_lam_tracks_pinned_ridge():
    solver, ds = _solver(circulant(6, (1, 2)), [8] * 6)
    rt = StreamingDeKRR(solver)
    n0 = rt.aux.n_live
    assert reference_lam(rt.aux) == pytest.approx(LAM)
    rng = np.random.default_rng(0)
    rt.ingest(0, rng.normal(size=(ds.dim, 50)), rng.normal(size=50))
    assert reference_lam(rt.aux) == pytest.approx(LAM * n0 / (n0 + 50))


def test_empty_minibatch_is_identity():
    solver, ds = _solver(_single_node(), [10])
    aux = init_stream_aux(solver)
    aux2 = ingest(aux, 0, np.zeros((ds.dim, 0)), np.zeros(0))
    assert aux2.n_live == aux.n_live
    np.testing.assert_array_equal(np.asarray(aux2.binv),
                                  np.asarray(aux.binv))


# --------------------------------------------------------------------------
# Feature refresh
# --------------------------------------------------------------------------
@pytest.mark.parametrize("new_d", [18, 6])     # grows past D_max / shrinks
def test_refresh_then_solve_matches_scratch(new_d):
    solver, ds = _solver(circulant(6, (1, 2)), [8, 12, 10, 8, 12, 10])
    rt = StreamingDeKRR(solver)
    rng = np.random.default_rng(3)
    rt.ingest(1, rng.normal(size=(ds.dim, 11)), rng.normal(size=11))
    old_dims = rt.aux.node_dims
    rep = rt.refresh(1, num_features=new_d)
    assert rep.new_features == new_d
    assert rep.repadded == (max(rt.aux.node_dims) != max(old_dims))
    # packed parity on the refreshed features
    want_packed = pack_problem(_reference(rt))
    _assert_packed_close(rt.packed, want_packed)
    # refresh-then-solve == solve-from-scratch on the refreshed features
    want = solve_batched(want_packed, 60)
    got = solve_batched(rt.packed, 60)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-9, atol=1e-12)
    # the refreshed node's carried θ reset to the new basis
    assert not np.any(np.asarray(rt.theta[1]))
    # further ingests stay exact after the refresh
    rt.ingest(1, rng.normal(size=(ds.dim, 6)), rng.normal(size=6))
    rt.ingest(2, rng.normal(size=(ds.dim, 4)), rng.normal(size=4))
    _assert_packed_close(rt.packed, pack_problem(_reference(rt)))


def test_cos_sin_refresh_keeps_feature_count():
    """Regression: `num_features` counts packed features (D_j), but
    select_features counts frequencies — a cos_sin default refresh must
    NOT double the node (D_j = 2·F_j)."""
    topo = circulant(5, (1,))
    ds, train, _ = cached_split("air_quality", 5, subsample=300, seed=0)
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    fmaps = [select_features(keys[j], ds.dim, 6, 1.0, train[j].x,
                             train[j].y, method="energy",
                             candidate_ratio=5, kind="cos_sin")
             for j in range(5)]
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=LAM, c_nei=0.02 * n),
                         build_aux=False)
    rt = StreamingDeKRR(solver)
    assert rt.aux.node_dims == (12,) * 5          # 2 features / frequency
    rep = rt.refresh(2)                           # default: keep the size
    assert rep.old_features == rep.new_features == 12
    assert rt.aux.node_dims == (12,) * 5
    _assert_packed_close(rt.packed, pack_problem(_reference(rt)))
    with pytest.raises(ValueError, match="even"):
        rt.refresh(2, num_features=7)
    rng = np.random.default_rng(0)
    rt.ingest(2, rng.normal(size=(ds.dim, 6)), rng.normal(size=6))
    _assert_packed_close(rt.packed, pack_problem(_reference(rt)))


def test_refresh_preserves_other_nodes_bits():
    """Only the refreshed node's slot (and the neighbor P̃ blocks that
    couple against it) may change — every other inverse is bit-identical."""
    solver, ds = _solver(circulant(6, (1, 2)), [10] * 6)
    rt = StreamingDeKRR(solver)
    before = np.asarray(rt.aux.binv).copy()
    rt.refresh(2, num_features=10)
    after = np.asarray(rt.aux.binv)
    for j in range(6):
        if j == 2:
            continue
        np.testing.assert_array_equal(before[j], after[j])


# --------------------------------------------------------------------------
# θ re-padding across refreshes (satellite: pack/unpack round-trip)
# --------------------------------------------------------------------------
def test_theta_roundtrip_across_growing_refresh():
    solver, _ = _solver(circulant(6, (1, 2)), [8, 12, 10, 8, 12, 10])
    rt = StreamingDeKRR(solver)
    rt.solve(rounds=30, tol=0.0)
    old_packed = rt.packed
    ragged_old = unpack_theta(old_packed, rt.theta)
    rt.refresh(0, num_features=20)             # D_max 12 → 20
    new_packed = rt.packed
    # non-refreshed nodes' θ re-pads losslessly into the new layout
    carried = list(ragged_old)
    carried[0] = jnp.zeros(new_packed.node_dims[0],
                           np.asarray(rt.theta).dtype)
    repacked = pack_theta(new_packed, carried)
    np.testing.assert_allclose(np.asarray(repacked), np.asarray(rt.theta),
                               rtol=0, atol=0)
    # and the full round-trip is the identity in the new layout
    np.testing.assert_array_equal(
        np.asarray(pack_theta(new_packed,
                              unpack_theta(new_packed, repacked))),
        np.asarray(repacked))


def test_stale_theta_raises_clear_errors():
    solver, _ = _solver(circulant(6, (1, 2)), [8, 12, 10, 8, 12, 10])
    rt = StreamingDeKRR(solver)
    rt.solve(rounds=10, tol=0.0)
    old_packed = rt.packed
    theta_old = rt.theta
    ragged_old = unpack_theta(old_packed, theta_old)
    rt.refresh(1, num_features=4)              # node 1: 12 → 4 features
    new_packed = rt.packed
    # stale ragged θ against refreshed dims → loud, names the refresh
    with pytest.raises(ValueError, match="stale"):
        pack_theta(new_packed, ragged_old)
    # stale packed θ of the wrong width → loud (no silent truncation)
    rt2 = StreamingDeKRR(_solver(circulant(6, (1, 2)),
                                 [8, 12, 10, 8, 12, 10])[0])
    rt2.refresh(0, num_features=20)
    with pytest.raises(ValueError, match="different packing"):
        unpack_theta(rt2.packed, theta_old)
    # repad_theta is the sanctioned carry: reset the refreshed node
    carried = repad_theta(theta_old, old_packed.node_dims,
                          new_packed.node_dims, reset=(1,))
    assert carried.shape == (6, new_packed.max_features)
    assert not np.any(np.asarray(carried[1]))
    with pytest.raises(ValueError, match="stale"):
        repad_theta(theta_old, old_packed.node_dims, new_packed.node_dims)


# --------------------------------------------------------------------------
# StreamingDeKRR: backend × gossip conformance + warm-start economics
# --------------------------------------------------------------------------
def _stream_epochs(backend, gossip, seed=0):
    solver, ds = _solver(circulant(5, (1,)), [8, 10, 12, 8, 10])
    cfg = StreamConfig(backend=backend, gossip=gossip,
                       async_config=AsyncGossipConfig(prob=0.5),
                       rounds_per_epoch=40, tol=0.0, seed=seed)
    rt = StreamingDeKRR(solver, cfg)
    rng = np.random.default_rng(11)
    for _ in range(2):
        batches = [(j, rng.normal(size=(ds.dim, 6)), rng.normal(size=6))
                   for j in (0, 3)]
        rt.step_epoch(batches)
    return rt.theta


@pytest.mark.parametrize("gossip", ["sync", "async"])
def test_streaming_backend_conformance(gossip):
    """θ after interleaved ingest/solve epochs agrees across every backend
    the runtime claims (xla / pallas / pallas_fused), sync and async."""
    want = _stream_epochs("xla", gossip)
    for backend in ("pallas", "pallas_fused"):
        got = _stream_epochs(backend, gossip)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-9, atol=1e-12)


def test_streaming_state_matches_scratch_solve_all_backends():
    """Acceptance: after an ingest/refresh sequence, StreamingDeKRR's
    packed state + solve match from-scratch pack_problem + solve on the
    accumulated data, on every backend."""
    solver, ds = _solver(circulant(5, (1,)), [8, 10, 12, 8, 10])
    rt = StreamingDeKRR(solver, StreamConfig(rounds_per_epoch=30, tol=0.0))
    rng = np.random.default_rng(5)
    rt.ingest(0, rng.normal(size=(ds.dim, 8)), rng.normal(size=8))
    rt.ingest(2, rng.normal(size=(ds.dim, 12)), rng.normal(size=12))
    rt.refresh(4, num_features=14)
    rt.ingest(4, rng.normal(size=(ds.dim, 5)), rng.normal(size=5))
    scratch = pack_problem(_reference(rt))
    _assert_packed_close(rt.packed, scratch)
    for backend in ("xla", "pallas", "pallas_fused"):
        got = solve_batched(rt.packed, 40, backend=backend)
        want = solve_batched(scratch, 40)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-9, atol=1e-12)


def test_warm_start_reaches_tol_in_fewer_rounds():
    solver, ds = _solver(circulant(6, (1, 2)), [10] * 6)
    rt = StreamingDeKRR(solver, StreamConfig(rounds_per_epoch=600,
                                             tol=1e-9))
    cold = rt.solve()
    assert cold.converged and cold.rounds_run < 600
    rng = np.random.default_rng(2)
    rt.ingest(1, rng.normal(size=(ds.dim, 10)), rng.normal(size=10))
    warm = rt.solve()
    assert warm.converged
    assert warm.rounds_run < cold.rounds_run
    # the warm continuation still lands on the from-scratch fixed point
    # (within the tol-ball: residual/(1−ρ) with ρ bounded away from 1)
    star_ = solve_batched(pack_problem(_reference(rt)), 5000, tol=1e-13)
    np.testing.assert_allclose(np.asarray(rt.theta), np.asarray(star_),
                               rtol=0, atol=5e-7)


def test_staleness_bound_tracks_ingest_and_solve():
    solver, ds = _solver(circulant(5, (1,)), [8] * 5)
    rt = StreamingDeKRR(solver, StreamConfig(rounds_per_epoch=300,
                                             tol=1e-9))
    rt.solve()
    s0 = rt.staleness()
    assert s0.theta_version == 1 and s0.ingests_behind == 0
    assert s0.residual < 1e-8
    rng = np.random.default_rng(4)
    rt.ingest(0, rng.normal(size=(ds.dim, 20)), rng.normal(size=20))
    s1 = rt.staleness()
    assert s1.ingests_behind == 1 and s1.samples_behind == 20
    assert s1.residual > s0.residual     # the fixed point moved under θ
    rt.solve()
    assert rt.staleness().ingests_behind == 0


# --------------------------------------------------------------------------
# Drift detection
# --------------------------------------------------------------------------
def test_drift_quiet_on_stationary_loud_on_shift():
    solver, ds = _solver(circulant(5, (1,)), [10] * 5)
    det = DriftDetector(solver.feature_maps, solver.data,
                        DriftConfig(threshold=0.3, min_samples=24))
    x0 = np.asarray(solver.data[0].x)
    y0 = np.asarray(solver.data[0].y).reshape(-1)
    # stationary window: re-feed the node's own training data
    quiet = det.observe(0, x0[:, :30], y0[:30])
    assert quiet.stat is not None and quiet.stat < 0.3
    # shifted window: scaled/translated inputs with unrelated labels
    rng = np.random.default_rng(0)
    loud = det.observe(0, rng.normal(size=(ds.dim, 30)) * 6.0 + 4.0,
                       rng.normal(size=30) * 10.0)
    assert loud.stat is not None and loud.stat > quiet.stat
    # windows below min_samples never issue a verdict
    pending = det.observe(1, x0[:, :4], y0[:4])
    assert pending.stat is None and not pending.refresh


def test_runtime_auto_refresh_on_drift():
    solver, ds = _solver(circulant(5, (1,)), [10] * 5)
    cfg = StreamConfig(drift=DriftConfig(threshold=0.05, min_samples=16),
                       rounds_per_epoch=30, tol=0.0)
    rt = StreamingDeKRR(solver, cfg)
    rng = np.random.default_rng(1)
    rep = rt.ingest(3, rng.normal(size=(ds.dim, 24)) * 8.0 + 5.0,
                    rng.normal(size=24) * 10.0)
    assert rep.drift is not None and rep.drift.stat is not None
    assert rep.refreshed and rt.refresh_count == 1
    # the refreshed state is still exactly rebuildable
    _assert_packed_close(rt.packed, pack_problem(_reference(rt)))


# --------------------------------------------------------------------------
# Serving path
# --------------------------------------------------------------------------
def test_serve_engine_matches_predict_with_staleness():
    solver, ds = _solver(circulant(5, (1,)), [10] * 5)
    _, _, test = cached_split("air_quality", 5, subsample=300, seed=0)
    rt = StreamingDeKRR(solver, StreamConfig(rounds_per_epoch=300,
                                             tol=1e-9))
    rt.solve()
    xs = np.asarray(test[0].x)[:, :9]
    want_mean = np.asarray(rt.predict(jnp.asarray(xs)))
    want_node = np.asarray(rt.predict(jnp.asarray(xs), node=2))
    for backend in ("xla", "pallas"):
        eng = DeKRRServeEngine(rt, batch_size=4, backend=backend)
        queries = [KernelQuery(uid=i, x=xs[:, i]) for i in range(9)]
        queries.append(KernelQuery(uid=99, x=xs, node=2))
        out = eng.run(queries)
        got = np.array([q.prediction for q in out[:9]])
        np.testing.assert_allclose(got, want_mean, rtol=1e-9, atol=1e-12,
                                   err_msg=backend)
        np.testing.assert_allclose(np.asarray(out[9].prediction),
                                   want_node, rtol=1e-9, atol=1e-12)
        for q in out:
            assert q.done and q.staleness is not None
            assert q.staleness.theta_version == 1
            assert q.staleness.residual < 1e-8


def test_serve_staleness_reflects_unsolved_ingest():
    solver, ds = _solver(circulant(5, (1,)), [8] * 5)
    rt = StreamingDeKRR(solver, StreamConfig(rounds_per_epoch=300,
                                             tol=1e-9))
    rt.solve()
    rng = np.random.default_rng(9)
    rt.ingest(0, rng.normal(size=(ds.dim, 16)), rng.normal(size=16))
    out = DeKRRServeEngine(rt, batch_size=8).run(
        [KernelQuery(uid=0, x=np.zeros(ds.dim))])
    bound = out[0].staleness
    assert bound.ingests_behind == 1 and bound.samples_behind == 16


# --------------------------------------------------------------------------
# SPMD satellites: tol early-stop + warm start (single-device exact case;
# the multi-device sweep lives in the dekrr_spmd subprocess test and the
# CI multidevice smoke below)
# --------------------------------------------------------------------------
def _spmd_mesh_1():
    return Mesh(np.array(jax.devices()[:1]), ("nodes",))


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_spmd_tol_and_warm_start_single_node(backend):
    solver, _ = _solver(_single_node(), [12])
    packed = pack_problem(solver)
    want, want_rounds = solve_batched(packed, 600, tol=1e-8,
                                      chunk_rounds=1, return_rounds=True)
    run = make_spmd_solver(_spmd_mesh_1(), "nodes", mode="allgather",
                          backend=backend)
    got, got_rounds = run(packed, 600, tol=1e-8, return_rounds=True)
    assert int(got_rounds) == int(want_rounds) < 600
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-9, atol=1e-12)
    # warm start: from the converged θ the solve stops immediately
    _, rounds2 = run(packed, 600, got, tol=1e-8, return_rounds=True)
    assert int(rounds2) <= 1
    # tol=0 path unchanged: full budget, pinned to solve_batched
    base = run(packed, 50)
    np.testing.assert_allclose(np.asarray(base),
                               np.asarray(solve_batched(packed, 50)),
                               rtol=1e-9, atol=1e-12)


def test_async_spmd_tol_and_warm_start_single_node():
    solver, _ = _solver(_single_node(), [12])
    packed = pack_problem(solver)
    key = jax.random.PRNGKey(3)
    config = AsyncGossipConfig(prob=0.5)
    want, want_rounds = async_solve_batched(
        packed, 1000, key, config=config, tol=1e-8, return_rounds=True)
    run = make_async_spmd_solver(_spmd_mesh_1(), "nodes", mode="allgather")
    got, got_rounds = run(packed, 1000, key, config, tol=1e-8,
                          return_rounds=True)
    assert int(got_rounds) == int(want_rounds) < 1000
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-9, atol=1e-12)
    # warm start parity against the batched async warm start
    theta0 = jnp.ones_like(packed.d) * packed.theta_mask
    want_w = async_solve_batched(packed, 30, key, config=config,
                                 theta0=theta0)
    got_w = run(packed, 30, key, config, theta0)
    np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w),
                               rtol=1e-9, atol=1e-12)


@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="needs >=4 devices (CI multidevice smoke)")
def test_spmd_tol_multidevice_smoke():
    topo = circulant(4, (1,))
    solver, _ = _solver(topo, [8, 10, 12, 8])
    packed = pack_problem(solver)
    mesh = Mesh(np.array(jax.devices()[:4]), ("nodes",))
    want, want_rounds = solve_batched(packed, 300, tol=1e-8,
                                      chunk_rounds=1, return_rounds=True)
    for mode, backend in (("ppermute", "xla"), ("allgather", "xla"),
                          ("ppermute", "pallas")):
        got, got_rounds = make_spmd_solver(mesh, "nodes", mode, backend)(
            packed, 300, tol=1e-8, return_rounds=True)
        assert int(got_rounds) == int(want_rounds) < 300
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-9, atol=1e-12)
    key = jax.random.PRNGKey(0)
    config = AsyncGossipConfig(prob=0.5)
    want_a, rounds_a = async_solve_batched(packed, 300, key, config=config,
                                           tol=1e-8, return_rounds=True)
    got_a, got_rounds_a = make_async_spmd_solver(mesh, "nodes",
                                                 "allgather")(
        packed, 300, key, config, tol=1e-8, return_rounds=True)
    assert int(got_rounds_a) == int(rounds_a)
    np.testing.assert_allclose(np.asarray(got_a), np.asarray(want_a),
                               rtol=1e-9, atol=1e-12)
