"""Observability suite (`repro.obs`): on-device traces, host metrics.

The on-device half pins the `return_trace=` contract of every solver:

  * exactness — residuals[r] = max|θ_{r+1} − θ_r| matches a per-round
    host recomputation (via the public single-round steps) at rtol 1e-9
    over {circulant, star, Erdős–Rényi, J=1} × {xla, pallas,
    pallas_fused} × {sync, async}, with the async wire series (active /
    broadcasts / deliveries / bytes) matching the recomputation EXACTLY
    (integer counts) and summing to `AsyncGossipStats`;
  * chunk invariance — `chunk_rounds` ∈ {1, 7, 64} never changes the
    series (bit-for-bit on the fused kernel), and on tol>0 paths every
    executed round's entry equals the tol=0 series with frozen rounds
    recording exactly 0;
  * zero cost — `return_trace=True` adds no pallas_call dispatch
    (`repro.obs.dispatch_count` pins the J002 counts unchanged) and no
    host callback in any loop body (J001), proven by tracing only.

Cross-program comparisons (trace vs a separately compiled
recomputation) use atol=1e-12 alongside rtol=1e-9: deep in convergence
the deltas sit at ~1e-14 where independent compilations differ by ulps.
Same-program claims (fused chunking) are asserted bit-for-bit.

The host-side half unit-tests the metrics/spans/export/report layers
with a `FakeClock` (bit-identical reports), checks the serve-tier
re-exports stayed aliases, and lints the R006 clock chokepoint.
"""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import REPO_ROOT, cached_fmaps, cached_split, subprocess_env
from repro.core import (AsyncGossipConfig, DeKRRConfig, DeKRRSolver,
                        Topology, circulant, erdos_renyi, star)
from repro.core.acceleration import chebyshev_solve_packed
from repro.core.async_gossip import activation_masks, censor_schedule
from repro.dist import (async_solve_batched, async_step_batched,
                        init_async_state, pack_problem, solve_batched,
                        step_batched)
from repro.obs import (AsyncSolveTrace, FakeClock, Registry, SolveTrace,
                       dispatch_count)
from repro.obs import export as obs_export
from repro.obs import spans as obs_spans

TOL = dict(rtol=1e-9, atol=1e-12)
ROUNDS = 10
KEY = jax.random.PRNGKey(7)
BACKENDS = ("xla", "pallas", "pallas_fused")
CENSOR = dict(censor_tau=2e-2, censor_decay=0.9)

TOPOLOGIES = {
    "circulant": (circulant(6, (1, 2)), [8, 10, 12, 8, 10, 12]),
    "star": (star(5), [6, 8, 10, 12, 14]),
    "er": (erdos_renyi(6, 0.5, seed=2), [9, 11, 9, 11, 9, 11]),
    "j1": (Topology(adjacency=np.zeros((1, 1), dtype=bool)), [10]),
}

_CACHE: dict = {}


@pytest.fixture(scope="module", autouse=True)
def _release_executables():
    """Drop the global executable caches once this module finishes.

    The trace-exactness matrix below compiles the whole solver surface
    — topologies x backends x sync/async x {plain, trace, stats} — on
    top of everything the preceding tier-1 modules already cached.  In
    one long pytest process that pushes the CPU JIT past its code
    budget and LLVM segfaults compiling an unrelated program a few
    files later (tests/test_stream.py).  Clearing here keeps the full
    run inside the budget; later modules recompile what they need.
    """
    yield
    _CACHE.clear()
    jax.clear_caches()


def _packed(name):
    if name not in _CACHE:
        topo, dims = TOPOLOGIES[name]
        j = topo.num_nodes
        ds, train, _ = cached_split("air_quality", j, subsample=300, seed=0)
        fmaps = cached_fmaps("air_quality", j, tuple(dims),
                             subsample=300, seed=0)
        n = sum(t.num_samples for t in train)
        _CACHE[name] = pack_problem(DeKRRSolver(
            topo, fmaps, train, DeKRRConfig(lam=1e-6, c_nei=0.02 * n)))
    return _CACHE[name]


def _per_bcast_bytes(packed):
    return (packed.max_features * packed.num_outputs
            * np.dtype(packed.d.dtype).itemsize)


def _sync_recompute(packed, rounds):
    """Per-round reference series from the public single-round step."""
    theta, res = jnp.zeros_like(packed.d), []
    for _ in range(rounds):
        new = step_batched(packed, theta)
        res.append(float(jnp.max(jnp.abs(new - theta))))
        theta = new
    return theta, np.asarray(res)


def _async_recompute(packed, rounds, key, config):
    """Per-round reference: drive `async_step_batched` one round at a
    time from the same precomputed schedule the solver consumes."""
    masks = activation_masks(key, rounds, packed.num_nodes,
                             prob=config.prob, gossip=config.gossip)
    thresholds = censor_schedule(config.censor_tau, config.censor_decay,
                                 rounds, dtype=packed.d.dtype)
    state = init_async_state(packed)
    res, active, bcasts, delivs = [], [], [], []
    for r in range(rounds):
        new, info = async_step_batched(
            packed, state, masks[r], thresholds[r], gossip=config.gossip,
            censored=config.censored)
        res.append(float(jnp.max(jnp.abs(new.theta - state.theta))))
        active.append(int(jnp.sum(masks[r] != 0)))
        bcasts.append(int(jnp.sum(info.bcast)))
        delivs.append(int(jnp.sum(info.received)))
        state = new
    return (state.theta, np.asarray(res), np.asarray(active),
            np.asarray(bcasts), np.asarray(delivs))


# --------------------------------------------------------------------------
# Synchronous traces
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_sync_trace_matches_recompute(name, backend):
    packed = _packed(name)
    theta, trace = solve_batched(packed, ROUNDS, backend=backend,
                                 return_trace=True)
    assert isinstance(trace, SolveTrace)
    want_theta, want_res = _sync_recompute(packed, ROUNDS)
    assert trace.residuals.shape == (ROUNDS,)
    np.testing.assert_allclose(np.asarray(theta), np.asarray(want_theta),
                               **TOL)
    np.testing.assert_allclose(np.asarray(trace.residuals), want_res,
                               **TOL)


@pytest.mark.parametrize("chunk", [1, 7, 64])
def test_sync_trace_chunk_invariance(chunk):
    packed = _packed("circulant")
    base = solve_batched(packed, ROUNDS, backend="pallas_fused",
                         return_trace=True)[1]
    got = solve_batched(packed, ROUNDS, backend="pallas_fused",
                        chunk_rounds=chunk, return_trace=True)[1]
    # same kernel, chunk boundaries chain the state bit-exactly
    np.testing.assert_array_equal(np.asarray(got.residuals),
                                  np.asarray(base.residuals))
    got_xla = solve_batched(packed, ROUNDS, backend="xla",
                            chunk_rounds=chunk, return_trace=True)[1]
    np.testing.assert_allclose(np.asarray(got_xla.residuals),
                               np.asarray(base.residuals), **TOL)


@pytest.mark.parametrize("chunk", [1, 7, 64])
def test_sync_tol_trace_frozen_rounds(chunk):
    packed = _packed("circulant")
    iters = 200
    full = solve_batched(packed, iters, backend="xla",
                         return_trace=True)[1]
    theta, rounds, trace = solve_batched(
        packed, iters, backend="xla", tol=1e-4, chunk_rounds=chunk,
        return_rounds=True, return_trace=True)
    rd = int(rounds)
    assert 0 < rd < iters, "tol must actually stop the solve early"
    assert trace.residuals.shape == (iters,)
    # every executed round recorded exactly what the tol=0 run recorded;
    # rounds that never ran are exactly 0
    np.testing.assert_allclose(np.asarray(trace.residuals[:rd]),
                               np.asarray(full.residuals[:rd]), **TOL)
    np.testing.assert_array_equal(np.asarray(trace.residuals[rd:]),
                                  np.zeros(iters - rd))


# --------------------------------------------------------------------------
# Asynchronous traces
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name", sorted(TOPOLOGIES))
def test_async_trace_matches_recompute(name, backend):
    packed = _packed(name)
    config = AsyncGossipConfig(prob=0.5, **CENSOR)
    theta, stats, trace = async_solve_batched(
        packed, ROUNDS, KEY, config=config, backend=backend,
        return_stats=True, return_trace=True)
    assert isinstance(trace, AsyncSolveTrace)
    want = _async_recompute(packed, ROUNDS, KEY, config)
    np.testing.assert_allclose(np.asarray(theta), np.asarray(want[0]),
                               **TOL)
    np.testing.assert_allclose(np.asarray(trace.residuals), want[1], **TOL)
    for got, ref, label in ((trace.active, want[2], "active"),
                            (trace.broadcasts, want[3], "broadcasts"),
                            (trace.deliveries, want[4], "deliveries")):
        np.testing.assert_array_equal(np.asarray(got), ref, err_msg=label)
    np.testing.assert_array_equal(
        np.asarray(trace.bytes),
        np.asarray(trace.broadcasts) * _per_bcast_bytes(packed))
    # summing the series reproduces the cumulative stats — in particular
    # on "pallas_fused", where return_stats used to silently fall back
    # to the per-round path and now reads the kernel's trace blocks
    assert int(stats.broadcasts) == int(np.sum(want[3]))
    assert int(stats.deliveries) == int(np.sum(want[4]))
    assert int(stats.rounds) == ROUNDS


def test_async_fused_trace_chunk_invariance():
    packed = _packed("circulant")
    config = AsyncGossipConfig(prob=0.5, **CENSOR)
    base = async_solve_batched(packed, ROUNDS, KEY, config=config,
                               backend="pallas_fused",
                               return_trace=True)[1]
    for chunk in (1, 7, 64):
        got = async_solve_batched(packed, ROUNDS, KEY, config=config,
                                  backend="pallas_fused",
                                  chunk_rounds=chunk,
                                  return_trace=True)[1]
        for f in AsyncSolveTrace._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(got, f)), np.asarray(getattr(base, f)),
                err_msg=f"{f} chunk={chunk}")


@pytest.mark.parametrize("chunk", [1, 7, 64])
def test_async_tol_trace_frozen_rounds(chunk):
    packed = _packed("circulant")
    config = AsyncGossipConfig(prob=0.5, **CENSOR)
    iters = 200
    full = async_solve_batched(packed, iters, KEY, config=config,
                               return_trace=True)[1]
    theta, rounds, trace = async_solve_batched(
        packed, iters, KEY, config=config, tol=1e-4, chunk_rounds=chunk,
        return_rounds=True, return_trace=True)
    rd = int(rounds)
    assert 0 < rd < iters, "tol must actually stop the solve early"
    for f in AsyncSolveTrace._fields:
        got, ref = np.asarray(getattr(trace, f)), getattr(full, f)
        assert got.shape == (iters,), f
        kw = TOL if f == "residuals" else dict(rtol=0, atol=0)
        np.testing.assert_allclose(got[:rd], np.asarray(ref)[:rd],
                                   err_msg=f, **kw)
        np.testing.assert_array_equal(got[rd:], np.zeros(iters - rd),
                                      err_msg=f)


def test_async_degenerate_matches_sync_trace():
    """prob=1 bernoulli uncensored: the async residual series IS the
    synchronous one (same program shape ⇒ bit-for-bit on xla)."""
    packed = _packed("circulant")
    sync = solve_batched(packed, ROUNDS, return_trace=True)[1]
    got = async_solve_batched(packed, ROUNDS, KEY,
                              config=AsyncGossipConfig(),
                              return_trace=True)[1]
    np.testing.assert_array_equal(np.asarray(got.residuals),
                                  np.asarray(sync.residuals))
    j, k = packed.nbr_mask.shape
    live = int(jnp.sum(packed.nbr_mask != 0))
    np.testing.assert_array_equal(np.asarray(got.active), np.full(ROUNDS, j))
    np.testing.assert_array_equal(np.asarray(got.broadcasts),
                                  np.full(ROUNDS, j))
    np.testing.assert_array_equal(np.asarray(got.deliveries),
                                  np.full(ROUNDS, live))


def test_censored_fraction():
    packed = _packed("circulant")
    trace = async_solve_batched(
        packed, ROUNDS, KEY, config=AsyncGossipConfig(prob=0.5, **CENSOR),
        return_trace=True)[1]
    active = np.asarray(trace.active)
    censored = active - np.asarray(trace.broadcasts)
    assert censored.sum() > 0, "censor threshold never fired — vacuous"
    cf = np.asarray(trace.censored_fraction())
    assert ((cf >= 0) & (cf <= 1)).all()
    np.testing.assert_array_equal(cf[active == 0],
                                  np.zeros((active == 0).sum()))
    # list round-trip (what trace_event exports) agrees — the device cf
    # divides in f32 (int32 promotion), the list path in f64
    cf_lists = AsyncSolveTrace(**{
        k: v for k, v in trace.as_lists().items()}).censored_fraction()
    np.testing.assert_allclose(np.asarray(cf_lists), cf, rtol=1e-6)


# --------------------------------------------------------------------------
# Chebyshev traces
# --------------------------------------------------------------------------
def test_chebyshev_trace():
    packed = _packed("circulant")
    iters, mu = 8, 0.9
    base = chebyshev_solve_packed(packed, mu, num_iters=iters,
                                  return_trace=True)
    theta, trace = base
    assert trace.residuals.shape == (iters,)
    # per-round recomputation: Δ_k = θ_{k+1} − θ_k from prefix solves
    prefixes = [np.asarray(chebyshev_solve_packed(packed, mu,
                                                  num_iters=k))
                for k in range(iters + 1)]
    want = np.asarray([np.max(np.abs(prefixes[k + 1] - prefixes[k]))
                       for k in range(iters)])
    np.testing.assert_allclose(np.asarray(trace.residuals), want, **TOL)
    for backend in ("pallas", "pallas_fused"):
        got = chebyshev_solve_packed(packed, mu, num_iters=iters,
                                     backend=backend, return_trace=True)[1]
        np.testing.assert_allclose(np.asarray(got.residuals),
                                   np.asarray(trace.residuals),
                                   err_msg=backend, **TOL)
    fused = chebyshev_solve_packed(packed, mu, num_iters=iters,
                                   backend="pallas_fused",
                                   return_trace=True)[1]
    for chunk in (1, 3, 64):
        got = chebyshev_solve_packed(packed, mu, num_iters=iters,
                                     backend="pallas_fused",
                                     chunk_rounds=chunk,
                                     return_trace=True)[1]
        np.testing.assert_array_equal(np.asarray(got.residuals),
                                      np.asarray(fused.residuals),
                                      err_msg=f"chunk={chunk}")


# --------------------------------------------------------------------------
# Zero-cost proofs (tracing only — nothing executes)
# --------------------------------------------------------------------------
def test_trace_adds_zero_dispatches():
    """J002: return_trace/return_stats pin the SAME pallas_call counts as
    the plain solve on every backend."""
    packed = _packed("j1")
    pins = {"xla": 0, "pallas": ROUNDS, "pallas_fused": 1}
    for b, pin in pins.items():
        for kw in ({}, {"return_trace": True}):
            n, exact = dispatch_count(solve_batched, packed,
                                      num_iters=ROUNDS, backend=b, **kw)
            assert (n, exact) == (pin, True), (b, kw)
            n, exact = dispatch_count(
                lambda pk, k, b=b, kw=kw: async_solve_batched(
                    pk, ROUNDS, k, backend=b,
                    config=AsyncGossipConfig(prob=0.5, **CENSOR),
                    return_stats=True, **kw),
                packed, KEY)
            assert (n, exact) == (pin, True), (b, kw)
        n, exact = dispatch_count(
            lambda pk, b=b: chebyshev_solve_packed(
                pk, 0.9, num_iters=ROUNDS, backend=b, return_trace=True),
            packed)
        assert (n, exact) == (pin, True), b


def test_trace_no_host_callbacks_and_shapes():
    """J001 on every traced program, plus eval_shape of the trace pytree
    — both pure tracing."""
    from repro.analysis.jaxpr_lint import check_no_callbacks_in_loops

    packed = _packed("circulant")
    config = AsyncGossipConfig(prob=0.5, **CENSOR)
    for b in BACKENDS:
        for tol in (0.0, 1e-4):
            closed = jax.make_jaxpr(
                lambda pk, b=b, tol=tol: solve_batched(
                    pk, ROUNDS, backend=b, tol=tol,
                    return_trace=True))(packed)
            assert check_no_callbacks_in_loops(closed, f"sync:{b}") == []
            closed = jax.make_jaxpr(
                lambda pk, k, b=b, tol=tol: async_solve_batched(
                    pk, ROUNDS, k, config=config, backend=b, tol=tol,
                    return_trace=True))(packed, KEY)
            assert check_no_callbacks_in_loops(closed, f"async:{b}") == []
    shapes = jax.eval_shape(
        lambda pk, k: async_solve_batched(pk, ROUNDS, k, config=config,
                                          return_trace=True)[1],
        packed, KEY)
    assert shapes.residuals.shape == (ROUNDS,)
    for f in ("active", "broadcasts", "deliveries", "bytes"):
        assert getattr(shapes, f).shape == (ROUNDS,)
        assert getattr(shapes, f).dtype == jnp.int32


# --------------------------------------------------------------------------
# SPMD traces (subprocess: forced 4-device CPU platform)
# --------------------------------------------------------------------------
OBS_SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from jax.sharding import Mesh
    from repro.core import (AsyncGossipConfig, DeKRRConfig, DeKRRSolver,
                            circulant, select_features)
    from repro.data.synthetic import (make_dataset, partition,
                                      train_test_split_nodes)
    from repro.dist import (async_solve_batched, make_async_spmd_solver,
                            make_spmd_solver, pack_problem, solve_batched)

    ROUNDS = 10
    KEY = jax.random.PRNGKey(7)
    TOL = dict(rtol=1e-9, atol=1e-12)
    ds = make_dataset("air_quality", subsample=300, seed=0)
    dims = [8, 10, 8, 10]
    train, _ = train_test_split_nodes(partition(ds, 4, mode="noniid_y"))
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    fmaps = [select_features(keys[j], ds.dim, dims[j], 1.0, train[j].x,
                             train[j].y, method="energy",
                             candidate_ratio=5) for j in range(4)]
    n = sum(t.num_samples for t in train)
    packed = pack_problem(DeKRRSolver(circulant(4, (1,)), fmaps, train,
                                      DeKRRConfig(lam=1e-6,
                                                  c_nei=0.02 * n)))
    mesh = Mesh(np.array(jax.devices()[:4]), ("nodes",))
    cfg = AsyncGossipConfig(prob=0.5, censor_tau=2e-2, censor_decay=0.9)
    for mode in ("ppermute", "allgather"):
        for tol in (0.0, 1e-4):
            got = make_spmd_solver(mesh, "nodes", mode)(
                packed, ROUNDS, tol=tol, return_rounds=True,
                return_trace=True)
            want = solve_batched(packed, ROUNDS, tol=tol,
                                 return_rounds=True, return_trace=True)
            assert int(got[1]) == int(want[1]), (mode, tol)
            np.testing.assert_allclose(np.asarray(got[2].residuals),
                                       np.asarray(want[2].residuals),
                                       err_msg=f"sync {mode} {tol}", **TOL)
            g = make_async_spmd_solver(mesh, "nodes", mode)(
                packed, ROUNDS, KEY, cfg, tol=tol, return_trace=True)
            w = async_solve_batched(packed, ROUNDS, KEY, config=cfg,
                                    tol=tol, return_trace=True)
            np.testing.assert_allclose(np.asarray(g[1].residuals),
                                       np.asarray(w[1].residuals),
                                       err_msg=f"async {mode} {tol}",
                                       **TOL)
            for f in ("active", "broadcasts", "deliveries", "bytes"):
                np.testing.assert_array_equal(
                    np.asarray(getattr(g[1], f)),
                    np.asarray(getattr(w[1], f)),
                    err_msg=f"async {mode} {tol} {f}")
    print("OBS-SPMD-TRACE-OK")
""")


def test_spmd_trace_subprocess():
    """SPMD traces (sync + async, both exchange modes, tol ∈ {0, >0})
    match the batched traces — in a subprocess so the forced 4-device
    platform does not leak into this session."""
    proc = subprocess.run(
        [sys.executable, "-c", OBS_SPMD_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=subprocess_env(), cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OBS-SPMD-TRACE-OK" in proc.stdout


def test_spmd_trace_multidevice_smoke():
    """In-process SPMD trace smoke for CI's forced-4-device jobs;
    skipped in the normal 1-device tier-1 session."""
    if jax.device_count() < 4:
        pytest.skip("needs >= 4 devices (CI kernels job forces 4)")
    from jax.sharding import Mesh

    from repro.dist import make_spmd_solver

    topo = circulant(4, (1,))
    dims = [8, 10, 8, 10]
    ds, train, _ = cached_split("air_quality", 4, subsample=300, seed=0)
    fmaps = cached_fmaps("air_quality", 4, tuple(dims), subsample=300,
                         seed=0)
    n = sum(t.num_samples for t in train)
    packed = pack_problem(DeKRRSolver(topo, fmaps, train,
                                      DeKRRConfig(lam=1e-6,
                                                  c_nei=0.02 * n)))
    mesh = Mesh(np.array(jax.devices()[:4]), ("nodes",))
    got = make_spmd_solver(mesh, "nodes", "ppermute")(
        packed, ROUNDS, return_trace=True)[1]
    want = solve_batched(packed, ROUNDS, return_trace=True)[1]
    np.testing.assert_allclose(np.asarray(got.residuals),
                               np.asarray(want.residuals), **TOL)


# --------------------------------------------------------------------------
# Host-side metrics / spans
# --------------------------------------------------------------------------
def test_registry_metrics_with_fake_clock():
    clock = FakeClock()
    reg = Registry(clock=clock)
    reg.counter("c", help="a counter").inc()
    reg.counter("c").inc(2.5)
    assert reg.counter("c").value == 3.5
    with pytest.raises(ValueError):
        reg.counter("c").inc(-1)
    reg.gauge("g").set(4.0)
    reg.gauge("g").add(-1.5)
    assert reg.gauge("g").value == 2.5
    h = reg.histogram("h")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    with h.time():
        clock.advance(0.5)
    s = h.summary()
    assert s["count"] == 5 and s["max"] == 4.0
    assert s["p50"] == np.percentile([1, 2, 3, 4, 0.5], 50)
    with pytest.raises(TypeError):
        reg.gauge("c")  # name already registered as a Counter
    ev = reg.record_event("trace", label="x")
    assert ev["event"] == "trace" and ev["t"] == clock()


def test_spans_nest_and_noop_without_recorder():
    # library-side span with no recorder installed: pure pass-through
    with obs_spans.span("orphan", x=1):
        pass
    reg = Registry(clock=FakeClock())
    clock = FakeClock()
    with obs_spans.recording(reg, clock=clock) as rec:
        with obs_spans.span("outer", nodes=6):
            clock.advance(1.0)
            with obs_spans.span("inner"):
                clock.advance(0.25)
    assert obs_spans._installed is None, "recorder must uninstall on exit"
    assert [sp.name for sp in rec.spans] == ["inner", "outer"]
    inner, outer = rec.spans
    assert (inner.depth, inner.parent) == (1, "outer")
    assert (outer.depth, outer.parent) == (0, None)
    assert inner.duration == 0.25 and outer.duration == 1.25
    assert outer.attrs == {"nodes": 6}
    assert [sp.name for sp in reg.spans] == ["inner", "outer"]


def test_instrumented_pack_problem_emits_span():
    topo, dims = TOPOLOGIES["j1"]
    ds, train, _ = cached_split("air_quality", 1, subsample=300, seed=0)
    fmaps = cached_fmaps("air_quality", 1, tuple(dims), subsample=300,
                         seed=0)
    solver = DeKRRSolver(topo, fmaps, train, DeKRRConfig(lam=1e-6))
    reg = Registry()
    with obs_spans.recording(reg):
        pack_problem(solver)
    names = [sp.name for sp in reg.spans]
    assert "pack_problem" in names
    sp = reg.spans[names.index("pack_problem")]
    assert sp.attrs["nodes"] == 1


def test_latency_recorder_lives_in_obs():
    from repro.obs.metrics import LatencyRecorder, LatencyReport
    from repro.serve import admission

    assert admission.LatencyRecorder is LatencyRecorder
    assert admission.LatencyReport is LatencyReport
    clock = FakeClock()
    rec = LatencyRecorder(clock=clock)
    assert rec.report() == LatencyReport.empty()
    rec.record(0.0, 1.0)
    rec.record(1.0, 1.5)
    with pytest.raises(ValueError):
        rec.record(2.0, 1.0)
    rep = rec.report()
    assert rep.count == 2 and rep.max == 1.0
    assert rep.qps == 2 / 1.5


# --------------------------------------------------------------------------
# Exporters + report CLI
# --------------------------------------------------------------------------
def _loaded_registry():
    reg = Registry(clock=FakeClock())
    reg.counter("bench.suites_run").inc(2)
    reg.gauge("queue depth").set(3)
    reg.histogram("wave_s").observe(0.25)
    trace = async_solve_batched(
        _packed("j1"), 4, KEY, config=AsyncGossipConfig(),
        return_trace=True)[1]
    obs_export.trace_event(reg, "j1/xla", trace)
    from repro.obs.metrics import LatencyRecorder

    lat = LatencyRecorder(clock=FakeClock())
    lat.record(0.0, 0.5)
    obs_export.latency_event(reg, "serve", lat.report())
    with obs_spans.recording(reg, clock=FakeClock()):
        with obs_spans.span("stage"):
            pass
    return reg


def test_jsonl_and_prometheus_exports(tmp_path):
    reg = _loaded_registry()
    prov = obs_export.provenance(interpret=True, extra={"fast": True})
    assert prov["interpret"] is True and prov["fast"] is True
    path = obs_export.write_jsonl(reg, str(tmp_path / "run.jsonl"), prov)
    records = [json.loads(ln) for ln in
               open(path).read().splitlines()]
    kinds = {r["kind"] for r in records}
    assert kinds == {"provenance", "counter", "gauge", "histogram",
                     "span", "event"}
    tr = next(r for r in records
              if r["kind"] == "event" and r["event"] == "trace")
    assert tr["label"] == "j1/xla" and len(tr["residuals"]) == 4
    assert all(f in tr for f in ("active", "broadcasts", "deliveries",
                                 "bytes"))
    prom = obs_export.to_prometheus(reg)
    assert "bench.suites_run 2" in prom.replace("bench_suites_run",
                                                "bench.suites_run")
    assert "queue_depth 3" in prom          # name sanitized
    assert 'wave_s{quantile="0.5"} 0.25' in prom
    assert "span" not in prom               # traces are JSONL-only


def test_stamp_provenance(tmp_path):
    prov = {"git_sha": "abc", "t_wall": 0.0}
    d = tmp_path / "BENCH_dict.json"
    d.write_text(json.dumps({"results": [1, 2]}))
    assert obs_export.stamp_provenance(str(d), prov)
    assert json.loads(d.read_text())["provenance"]["git_sha"] == "abc"
    lst = tmp_path / "BENCH_list.json"
    lst.write_text(json.dumps([{"a": 1}]))
    assert obs_export.stamp_provenance(str(lst), prov)
    payload = json.loads(lst.read_text())
    assert payload["provenance"]["git_sha"] == "abc"
    assert payload["results"] == [{"a": 1}]
    assert not obs_export.stamp_provenance(str(tmp_path / "missing.json"),
                                           prov)
    bad = tmp_path / "BENCH_bad.json"
    bad.write_text("not json")
    assert not obs_export.stamp_provenance(str(bad), prov)


def test_report_cli(tmp_path, capsys):
    from repro.obs.__main__ import main

    reg = _loaded_registry()
    path = obs_export.write_jsonl(
        reg, str(tmp_path / "run.jsonl"),
        obs_export.provenance(interpret=True))
    assert main([path]) == 0
    out = capsys.readouterr().out
    for needle in ("provenance", "convergence", "j1/xla", "stage",
                   "bench.suites_run", "serve"):
        assert needle in out, needle


# --------------------------------------------------------------------------
# R006 — the clock chokepoint lint
# --------------------------------------------------------------------------
def test_r006_clock_lint():
    import os

    from repro.analysis.conventions import lint_file

    src = ("import time\n"
           "t0 = time.perf_counter()\n"
           "w = time.time()\n"
           "time.sleep(0.1)\n"
           "ok = time.time()  # analysis: ignore[R006]\n")
    found = lint_file(os.path.join(REPO_ROOT, "src/repro/train/fake.py"),
                      source=src, repo_root=REPO_ROOT)
    assert [f.rule for f in found] == ["R006", "R006"]
    assert "perf_clock" in found[0].message
    assert "wall_clock" in found[1].message
    # repro/obs/ is the sanctioned home of the raw clocks
    assert lint_file(os.path.join(REPO_ROOT, "src/repro/obs/fake.py"),
                     source=src, repo_root=REPO_ROOT) == []
    # outside src/repro/ (tests, benchmarks) the rule does not apply
    assert lint_file(os.path.join(REPO_ROOT, "benchmarks/fake.py"),
                     source=src, repo_root=REPO_ROOT) == []
