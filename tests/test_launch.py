"""Unit tests for the launch layer: sharding rules, HLO analyzer, specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_arch
from repro.launch.hlo_analysis import (HloCosts, analyze_hlo_text,
                                       model_flops_per_step)
from repro.launch.mesh import make_abstract_mesh
from repro.launch.sharding import ShardingRules
from repro.models.model import Model


def _rules(multi_pod=False):
    if multi_pod:
        mesh = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    else:
        mesh = make_abstract_mesh((16, 16), ("data", "model"))
    return ShardingRules(mesh)


def test_param_specs_dense():
    rules = _rules()
    cfg = get_arch("granite_3_8b").config
    params = jax.eval_shape(lambda: Model(cfg).init(jax.random.PRNGKey(0)))
    specs = rules.params_specs(params)
    assert specs["embed"] == P(None, "data")       # 49155 % 16 != 0 → None
    assert specs["lm_head"] == P("data", None)
    assert specs["slot0"]["wq"] == P(None, "data", "model")
    assert specs["slot0"]["wo"] == P(None, "model", "data")
    assert specs["slot0"]["w_down"] == P(None, "model", "data")
    assert specs["slot0"]["norm_mix"] == P(None, None)


def test_param_specs_divisibility_fallback():
    """smollm: 9 heads · 64 = 576 flat — not divisible by 16 → replicated."""
    rules = _rules()
    cfg = get_arch("smollm_135m").config
    params = jax.eval_shape(lambda: Model(cfg).init(jax.random.PRNGKey(0)))
    specs = rules.params_specs(params)
    assert specs["slot0"]["wq"] == P(None, "data", "model")  # 576%16==0
    assert specs["embed"] == P("model", "data")              # 49152%16==0


def test_param_specs_moe_expert_parallel():
    rules = _rules()
    cfg = get_arch("deepseek_moe_16b").config
    params = jax.eval_shape(lambda: Model(cfg).init(jax.random.PRNGKey(0)))
    specs = rules.params_specs(params)
    assert specs["slot0"]["moe_gate"] == P(None, "model", "data", None)
    assert specs["slot0"]["moe_down"] == P(None, "model", None, "data")


def test_multi_pod_fsdp_uses_both_axes():
    rules = _rules(multi_pod=True)
    assert rules.dp_size == 32
    cfg = get_arch("jamba_1_5_large_398b").config
    params = jax.eval_shape(lambda: Model(cfg).init(jax.random.PRNGKey(0)))
    specs = rules.params_specs(params)
    # d_model 8192 % 32 == 0 → fsdp over (pod, data)
    assert specs["slot1"]["in_x"] == P(None, ("pod", "data"), "model")


def test_cache_specs_batch_vs_seq_sharding():
    rules = _rules()
    cfg = get_arch("granite_3_8b").config
    model = Model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(128, 32768))
    specs = rules.cache_specs(cache, 128)
    # batch 128 % 16 == 0 → batch on data; kv heads 8 < 16 → the cache
    # seq dim takes the model axis (flash-decode layout; §Perf pair 2)
    assert specs["slot0"]["k"] == P(None, "data", "model", None, None)
    # batch 1 → sequence-sharded (context parallelism)
    cache1 = jax.eval_shape(lambda: model.init_cache(1, 524288))
    specs1 = rules.cache_specs(cache1, 1)
    assert specs1["slot0"]["k"] == P(None, None, "data", None, None)


# ---------------------------------------------------------------- HLO parser
SAMPLE_HLO = """
HloModule test

%region_body (p: (s32[], f32[16,128])) -> (s32[], f32[16,128]) {
  %p = (s32[], /*index=1*/f32[16,128]{1,0}) parameter(0)
  %g0 = s32[] get-tuple-element(%p), index=0
  %g1 = f32[16,128]{1,0} get-tuple-element(%p), index=1
  %w = f32[128,128]{1,0} constant({...})
  %d = f32[16,128]{1,0} dot(%g1, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[16,128]{1,0} all-reduce(%d), replica_groups={}, to_apply=%sum
  %one = s32[] constant(1)
  %next = s32[] add(%g0, %one)
  ROOT %t = (s32[], f32[16,128]{1,0}) tuple(%next, %ar)
}

%region_cond (p2: (s32[], f32[16,128])) -> pred[] {
  %p2 = (s32[], /*index=1*/f32[16,128]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[16,128]) -> f32[16,128] {
  %x = f32[16,128]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %init = (s32[], f32[16,128]{1,0}) tuple(%c0, %x)
  %w8 = (s32[], f32[16,128]{1,0}) while(%init), condition=%region_cond, body=%region_body
  ROOT %out = f32[16,128]{1,0} get-tuple-element(%w8), index=1
}
"""


def test_hlo_parser_trip_count_multiplies_costs():
    costs = analyze_hlo_text(SAMPLE_HLO)
    assert costs.num_whiles == 1
    assert costs.unknown_trip_counts == 0
    # dot flops = 2·16·128·128 per iteration × 12 iterations
    expected = 12 * 2 * 16 * 128 * 128
    assert abs(costs.flops - expected) / expected < 0.05
    # all-reduce bytes = 16·128·4 × 12
    assert costs.coll_bytes["all-reduce"] == 12 * 16 * 128 * 4


def test_hlo_parser_known_trip_count_config():
    txt = SAMPLE_HLO.replace(
        "body=%region_body",
        'body=%region_body, backend_config={"known_trip_count":{"n":"7"}}')
    costs = analyze_hlo_text(txt)
    assert costs.coll_bytes["all-reduce"] == 7 * 16 * 128 * 4


def test_model_flops_per_step():
    cfg = get_arch("qwen1_5_0_5b").config
    shape = INPUT_SHAPES["train_4k"]
    mf = model_flops_per_step(cfg, shape, 6.2e8)
    assert abs(mf - 6 * 6.2e8 * 256 * 4096) < 1e6


def test_variant_config_swa_transform():
    from repro.launch.specs import variant_config

    spec = get_arch("granite_3_8b")
    cfg = variant_config(spec, "long_500k")
    assert all(s.mixer == "swa" for s in cfg.slots)
    assert cfg.sliding_window == 8192
    assert cfg.param_dtype == "bfloat16"
    # jamba runs long-context natively — attn slots unchanged
    jcfg = variant_config(get_arch("jamba_1_5_large_398b"), "long_500k")
    assert jcfg.slots[0].mixer == "attn"


def test_variant_config_rejects_skips():
    with pytest.raises(ValueError, match="skips"):
        variant = get_arch("hubert_xlarge")
        from repro.launch.specs import variant_config as vc
        vc(variant, "decode_32k")
