"""Offline fallback for `hypothesis` so test collection never errors.

The container has no network access, so `hypothesis` may not be
installable. Property tests import `given`/`settings`/`strategies` from
this module instead of from `hypothesis` directly: when the real library
is present it is re-exported unchanged; when it is absent, a minimal
deterministic stand-in runs each property as a plain pytest function over
`max_examples` pseudo-random draws (seeded per test name, so failures
reproduce). Only the strategy surface the suite uses is implemented:
`st.integers(lo, hi)` and `st.sampled_from(seq)`.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies
    HAVE_HYPOTHESIS = True
except ImportError:
    import random
    import warnings
    import zlib

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 10

    # Surface the downgrade at collection time: the fallback silently
    # narrows what the property tests exercise (fixed pseudo-random draws,
    # no shrinking, no coverage-guided search), which must be visible in
    # the pytest warnings summary rather than discovered after a missed
    # bug. CI runs the property suite under real hypothesis separately.
    warnings.warn(
        "hypothesis is not installed: property tests run under the "
        "deterministic _hypothesis_compat fallback (fixed draws, no "
        "shrinking/coverage) — install hypothesis for full property "
        "checking", UserWarning)

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        """The subset of `hypothesis.strategies` this suite uses."""

        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements) -> _Strategy:
            pool = list(elements)
            return _Strategy(lambda rng: rng.choice(pool))

    strategies = _Strategies()

    def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
        """Record max_examples on the function; other knobs are ignored."""

        def decorate(fn):
            fn._compat_max_examples = max_examples
            return fn

        return decorate

    def given(**strats):
        """Run the property over deterministic draws of each strategy."""

        def decorate(fn):
            # NOTE: no functools.wraps — it would expose the property's
            # argument signature (via __wrapped__) and make pytest hunt
            # for fixtures named after the strategy arguments.
            def runner():
                n = runner._compat_max_examples
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = random.Random(seed)
                for example in range(n):
                    kwargs = {name: strat.draw(rng)
                              for name, strat in strats.items()}
                    try:
                        fn(**kwargs)
                    except Exception as exc:  # annotate the failing draw
                        raise AssertionError(
                            f"property failed on example {example} with "
                            f"arguments {kwargs!r}") from exc

            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            runner._compat_max_examples = getattr(
                fn, "_compat_max_examples", _DEFAULT_MAX_EXAMPLES)
            return runner

        return decorate
