"""Property tests for the model-zoo substrates: numerical invariants that
must hold across tiling/grouping choices (the knobs the sharding layer and
§Perf iterations turn)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib


# ---------------------------------------------------------------- attention
@given(sq=st.integers(1, 24), skv=st.integers(1, 48),
       chunk=st.sampled_from([4, 8, 16, 64]), seed=st.integers(0, 999))
@settings(max_examples=20, deadline=None)
def test_chunked_attention_chunk_size_invariance(sq, skv, chunk, seed):
    """Online-softmax chunking must not change the result."""
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    b, h, kh, dh = 2, 4, 2, 16
    q = jax.random.normal(kq, (b, sq, h, dh), jnp.float32)
    k = jax.random.normal(kk, (b, skv, kh, dh), jnp.float32)
    v = jax.random.normal(kv, (b, skv, kh, dh), jnp.float32)
    qp = jnp.arange(sq, dtype=jnp.int32) + (skv - sq if skv >= sq else 0)
    kp = jnp.arange(skv, dtype=jnp.int32)
    ref = L.chunked_attention(q, k, v, qp, kp, causal=True,
                              chunk_kv=max(skv, 1))
    got = L.chunked_attention(q, k, v, qp, kp, causal=True, chunk_kv=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_sliding_window_equals_truncated_context():
    """Window-w attention over a long context == full attention over the
    last w keys (for the final query position)."""
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    b, h, dh, s, w = 1, 2, 16, 40, 8
    q = jax.random.normal(kq, (b, 1, h, dh))
    k = jax.random.normal(kk, (b, s, h, dh))
    v = jax.random.normal(kv, (b, s, h, dh))
    qp = jnp.asarray([s - 1], jnp.int32)
    kp = jnp.arange(s, dtype=jnp.int32)
    win = L.chunked_attention(q, k, v, qp, kp, causal=True, window=w,
                              chunk_kv=16)
    trunc = L.chunked_attention(q, k[:, s - w:], v[:, s - w:], qp,
                                kp[s - w:], causal=True, chunk_kv=16)
    np.testing.assert_allclose(np.asarray(win), np.asarray(trunc),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------- MoE
@pytest.mark.parametrize("groups", [1, 2, 4])
def test_moe_groups_equivalence_without_drops(groups):
    """Grouped dispatch (the data-sharded layout) must equal single-group
    dispatch when capacity never binds — drops are the only legitimate
    difference."""
    key = jax.random.PRNGKey(0)
    t, d, e, f, k = 32, 16, 4, 24, 2
    dims = moe_lib.MoEDims(num_experts=e, experts_per_token=k, d_model=d,
                           d_ff=f, capacity_factor=16.0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (t, d))
    router = jax.random.normal(ks[1], (d, e)) * 0.5
    wg = jax.random.normal(ks[2], (e, d, f)) / np.sqrt(d)
    wu = jax.random.normal(ks[3], (e, d, f)) / np.sqrt(d)
    wd = jax.random.normal(ks[4], (e, f, d)) / np.sqrt(f)
    out1, aux1 = moe_lib.moe_forward(x, router, wg, wu, wd, dims, groups=1)
    outg, auxg = moe_lib.moe_forward(x, router, wg, wu, wd, dims,
                                     groups=groups)
    np.testing.assert_allclose(np.asarray(outg), np.asarray(out1),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(auxg["load_balance_loss"]),
                               float(aux1["load_balance_loss"]), rtol=1e-6)


def test_moe_capacity_drops_reduce_output_norm():
    """With a tiny capacity factor, some tokens must be dropped (their
    routed contribution is zero) — output norm strictly below no-drop."""
    key = jax.random.PRNGKey(1)
    t, d, e, f, k = 64, 8, 4, 16, 2
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (t, d))
    router = jax.random.normal(ks[1], (d, e)) * 2.0   # concentrated routing
    wg = jax.random.normal(ks[2], (e, d, f)) / np.sqrt(d)
    wu = jax.random.normal(ks[3], (e, d, f)) / np.sqrt(d)
    wd = jax.random.normal(ks[4], (e, f, d)) / np.sqrt(f)
    big = moe_lib.MoEDims(e, k, d, f, capacity_factor=16.0)
    small = dataclasses.replace(big, capacity_factor=0.25)
    out_big, _ = moe_lib.moe_forward(x, router, wg, wu, wd, big)
    out_small, _ = moe_lib.moe_forward(x, router, wg, wu, wd, small)
    assert float(jnp.linalg.norm(out_small)) < \
        float(jnp.linalg.norm(out_big))


# ------------------------------------------------------------------ scans
@given(s=st.integers(1, 70), chunk=st.sampled_from([4, 16, 64]),
       seed=st.integers(0, 999))
@settings(max_examples=15, deadline=None)
def test_wkv6_chunk_invariance_and_step_consistency(s, chunk, seed):
    """Chunked WKV6 == per-step recurrence, for any chunk size; the final
    scan state equals sequential wkv6_step application."""
    key = jax.random.PRNGKey(seed)
    b, h, dh = 2, 2, 8
    kr, kk, kv, kw = jax.random.split(key, 4)
    r = jax.random.normal(kr, (b, s, h, dh))
    k = jax.random.normal(kk, (b, s, h, dh))
    v = jax.random.normal(kv, (b, s, h, dh))
    w = jax.nn.sigmoid(jax.random.normal(kw, (b, s, h, dh))) * 0.9 + 0.05
    u = jnp.zeros((h, dh)) + 0.1
    st0 = jnp.zeros((b, h, dh, dh))
    out_c, state_c = rwkv_lib.wkv6_chunk_scan(r, k, v, w, u, st0,
                                              chunk=chunk)
    state = st0
    outs = []
    for t in range(s):
        o, state = rwkv_lib.wkv6_step(r[:, t], k[:, t], v[:, t], w[:, t],
                                      u, state)
        outs.append(o)
    out_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_seq),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state_c), np.asarray(state),
                               rtol=1e-4, atol=1e-5)


@given(s=st.integers(1, 60), chunk=st.sampled_from([4, 16]),
       seed=st.integers(0, 999))
@settings(max_examples=15, deadline=None)
def test_ssm_chunk_invariance_and_step_consistency(s, chunk, seed):
    key = jax.random.PRNGKey(seed)
    b, di, n = 2, 6, 4
    kx, kd, kb, kc, ka = jax.random.split(key, 5)
    x = jax.random.normal(kx, (b, s, di))
    delta = jax.nn.softplus(jax.random.normal(kd, (b, s, di)))
    b_t = jax.random.normal(kb, (b, s, n))
    c_t = jax.random.normal(kc, (b, s, n))
    a_log = jax.random.normal(ka, (di, n)) * 0.3
    d_skip = jnp.ones((di,)) * 0.5
    st0 = jnp.zeros((b, di, n))
    y_c, state_c = ssm_lib.ssm_chunk_scan(x, delta, a_log, b_t, c_t,
                                          d_skip, st0, chunk=chunk)
    state = st0
    ys = []
    for t in range(s):
        y, state = ssm_lib.ssm_step(x[:, t], delta[:, t], a_log, b_t[:, t],
                                    c_t[:, t], d_skip, state)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state_c), np.asarray(state),
                               rtol=1e-4, atol=1e-5)


def test_data_dependent_decay_in_unit_interval():
    key = jax.random.PRNGKey(0)
    b, s, d, r, h = 2, 10, 16, 4, 2
    x = jax.random.normal(key, (b, s, d)) * 3
    w0 = jnp.full((d,), -0.6)
    wa = jax.random.normal(jax.random.PRNGKey(1), (d, r))
    wb = jax.random.normal(jax.random.PRNGKey(2), (r, d))
    w = rwkv_lib.data_dependent_decay(x, w0, wa, wb, h)
    assert float(w.min()) > 0.0 and float(w.max()) < 1.0
    # decay must actually depend on the data (Finch's headline feature)
    x2 = x.at[:, 0].set(-x[:, 0])
    w2 = rwkv_lib.data_dependent_decay(x2, w0, wa, wb, h)
    assert float(jnp.max(jnp.abs(w[:, 0] - w2[:, 0]))) > 1e-6
