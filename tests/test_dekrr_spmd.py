"""Parity tests: packed/batched and SPMD runtimes vs the ragged reference."""
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import REPO_ROOT, cached_fmaps, cached_split, subprocess_env
from repro.core import DeKRRConfig, DeKRRSolver, circulant, erdos_renyi, star
from repro.dist import (comm_bytes_per_round, pack_problem, solve_batched,
                        step_batched)


def _problem(topo, D_per_node, sub=400, seed=0):
    """Parity is exact algebra, so a small cached subsample loses nothing."""
    j = topo.num_nodes
    ds, train, _ = cached_split("air_quality", j, subsample=sub, seed=seed)
    fmaps = cached_fmaps("air_quality", j, tuple(D_per_node),
                         subsample=sub, seed=seed)
    n = sum(t.num_samples for t in train)
    return DeKRRSolver(topo, fmaps, train,
                       DeKRRConfig(lam=1e-6, c_nei=0.02 * n))


@pytest.mark.parametrize("topo,dims", [
    (circulant(10, (1, 2)), [8, 12, 16, 20, 24, 8, 12, 16, 20, 24]),
    (circulant(6, (1,)), [10] * 6),
    (star(5), [6, 8, 10, 12, 14]),
    (erdos_renyi(7, 0.5, seed=1), [9, 9, 9, 9, 9, 9, 9]),
])
def test_packed_step_matches_ragged_reference(topo, dims):
    solver = _problem(topo, dims)
    packed = pack_problem(solver)
    state = solver.init_state()
    theta = jnp.zeros_like(packed.d)
    for _ in range(5):
        state = solver.step(state)
        theta = step_batched(packed, theta)
    for j in range(topo.num_nodes):
        np.testing.assert_allclose(
            np.asarray(theta[j][:dims[j]]), np.asarray(state.theta[j]),
            rtol=1e-9, atol=1e-12)
        # padding must stay identically zero
        assert not np.any(np.asarray(theta[j][dims[j]:]))


def test_solve_batched_scan_matches_python_loop():
    topo = circulant(8, (1, 2))
    solver = _problem(topo, [10] * 8)
    packed = pack_problem(solver)
    theta_scan = solve_batched(packed, 30)
    theta = jnp.zeros_like(packed.d)
    for _ in range(30):
        theta = step_batched(packed, theta)
    np.testing.assert_allclose(np.asarray(theta_scan), np.asarray(theta),
                               rtol=1e-9, atol=1e-12)


def test_circulant_packing_slot_order():
    topo = circulant(10, (1, 2))
    solver = _problem(topo, [8] * 10)
    packed = pack_problem(solver)
    assert packed.offsets == (1, 2)
    # slots: [(+1), (−1), (+2), (−2)]
    idx = np.asarray(packed.nbr_idx)
    for j in range(10):
        assert list(idx[j]) == [(j + 1) % 10, (j - 1) % 10,
                                (j + 2) % 10, (j - 2) % 10]


def test_comm_bytes_cost_model():
    topo = circulant(10, (1, 2))
    solver = _problem(topo, [16] * 10)
    packed = pack_problem(solver)
    # Σ_j |N_j| · D_max · 8 bytes = 10·4·16·8
    assert comm_bytes_per_round(packed, "ppermute") == 10 * 4 * 16 * 8


SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={J}"
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh
    from repro.core import DeKRRConfig, DeKRRSolver, circulant, select_features
    from repro.data.synthetic import make_dataset, partition, train_test_split_nodes
    from repro.dist import make_spmd_solver, pack_problem, solve_batched

    J = {J}
    ds = make_dataset("air_quality", subsample=400, seed=0)
    topo = circulant(J, (1, 2))
    train, _ = train_test_split_nodes(partition(ds, J, mode="noniid_y"))
    keys = jax.random.split(jax.random.PRNGKey(0), J)
    dims = [8 + 2 * (j % 4) for j in range(J)]
    fmaps = [select_features(keys[j], ds.dim, dims[j], 1.0, train[j].x,
                             train[j].y, method="energy", candidate_ratio=5)
             for j in range(J)]
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=1e-6, c_nei=0.02 * n))
    packed = pack_problem(solver)
    want = solve_batched(packed, 40)

    mesh = Mesh(np.array(jax.devices()), ("nodes",))
    for mode in ("ppermute", "allgather"):
        got = make_spmd_solver(mesh, "nodes", mode)(packed, 40)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-9, atol=1e-12)

    # tol early-stop (fused pmax; all devices agree on the stop round) +
    # warm start: must match the batched per-round tol check exactly
    want_t, want_rounds = solve_batched(packed, 600, tol=1e-8,
                                        chunk_rounds=1, return_rounds=True)
    run = make_spmd_solver(mesh, "nodes", "ppermute")
    got_t, got_rounds = run(packed, 600, tol=1e-8, return_rounds=True)
    assert int(got_rounds) == int(want_rounds) < 600, (
        int(got_rounds), int(want_rounds))
    np.testing.assert_allclose(np.asarray(got_t), np.asarray(want_t),
                               rtol=1e-9, atol=1e-12)
    _, rounds2 = run(packed, 600, got_t, tol=1e-8, return_rounds=True)
    assert int(rounds2) <= 1, int(rounds2)
    print("SPMD-PARITY-OK")
""")


@pytest.mark.parametrize("num_nodes", [10])
def test_spmd_parity_on_10_devices(num_nodes):
    """Runs in a subprocess so the forced 10-device CPU platform does not
    leak into this test session (smoke tests must see 1 device)."""
    proc = subprocess.run(
        [sys.executable, "-c", SPMD_SCRIPT.format(J=num_nodes)],
        capture_output=True, text=True, timeout=600,
        env=subprocess_env(),
        cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SPMD-PARITY-OK" in proc.stdout
