"""Parity + regression suite for the fused multi-round DeKRR solve kernel
(interpret mode) and the bugfixes that rode along with it.

The solve-level pins, all on CPU at rtol 1e-9 under x64:

  ragged reference (`DeKRRSolver.step` iterated)
    == batched XLA solve (`solve_batched(backend="xla")`)
    == fused multi-round Pallas solve (`solve_batched(backend="pallas_fused")`,
       ONE `repro.kernels.dekrr_solve` pallas_call for all rounds)

across circulant/star/ER/complete/J=1 graphs, plus the raw kernel against
its pure-jnp oracle (θ-table indirection, unowned static rows, masked
slots, round parity), round-chunked execution and tol early-stop
equivalence, and regressions for the backend plumbing in
`repro.core.acceleration`, the `DeKRRSolver.solve` fused tol delta, and
the `pack_theta` length validation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from conftest import cached_fmaps, cached_split
from repro.core import (DeKRRConfig, DeKRRSolver, Topology, circulant,
                        complete, erdos_renyi, star)
from repro.core.acceleration import (chebyshev_solve_packed,
                                     estimate_spectral_interval,
                                     power_iteration_mu_max,
                                     power_iteration_mu_min,
                                     rounds_to_tolerance)
from repro.dist import pack_problem, pack_theta, solve_batched
from repro.kernels import ops
from repro.kernels.dekrr_solve import dekrr_solve_reference

TOL = dict(rtol=1e-9, atol=1e-12)


def _solver(topo, dims, sub=400, seed=0, tol=0.0, num_iters=300):
    j = topo.num_nodes
    ds, train, _ = cached_split("air_quality", j, subsample=sub, seed=seed)
    fmaps = cached_fmaps("air_quality", j, tuple(dims),
                         subsample=sub, seed=seed)
    n = sum(t.num_samples for t in train)
    return DeKRRSolver(topo, fmaps, train,
                       DeKRRConfig(lam=1e-6, c_nei=0.02 * n, tol=tol,
                                   num_iters=num_iters))


def _single_node_topology():
    return Topology(adjacency=np.zeros((1, 1), dtype=bool))


CASES = [
    # (topology, ragged D_j set) — same sweep as the per-round kernel suite:
    # both slot layouts (circulant ppermute order, generic padded adjacency)
    # and every degree extreme, now iterated for a whole solve.
    (circulant(10, (1, 2)), [8, 12, 16, 20, 24, 8, 12, 16, 20, 24]),
    (star(5), [6, 8, 10, 12, 14]),                  # worst degree imbalance
    (erdos_renyi(7, 0.5, seed=1), [9, 13, 9, 13, 9, 13, 9]),
    (complete(5), [7, 9, 11, 9, 7]),                # full graph
    (circulant(2, (1,)), [8, 12]),                  # single neighbor
    (_single_node_topology(), [10]),                # J=1, no neighbors
]

ROUNDS = 25


@pytest.mark.parametrize("topo,dims", CASES,
                         ids=[f"J{t.num_nodes}_deg{t.max_degree}"
                              for t, _ in CASES])
def test_fused_solve_matches_xla_and_ragged_reference(topo, dims):
    solver = _solver(topo, dims)
    packed = pack_problem(solver)
    th_xla = solve_batched(packed, ROUNDS, backend="xla")
    th_fused = solve_batched(packed, ROUNDS, backend="pallas_fused")
    np.testing.assert_allclose(np.asarray(th_fused), np.asarray(th_xla),
                               **TOL)
    state = solver.init_state()
    for _ in range(ROUNDS):
        state = solver.step(state)
    for j in range(topo.num_nodes):
        np.testing.assert_allclose(np.asarray(th_fused[j][:dims[j]]),
                                   np.asarray(state.theta[j]), **TOL)
        # padding must stay identically zero through the fused solve too
        assert not np.any(np.asarray(th_fused[j][dims[j]:]))


@given(j_nodes=st.integers(1, 5), k_slots=st.integers(0, 3),
       d_feat=st.integers(1, 24), extra_rows=st.integers(0, 3),
       num_rounds=st.integers(0, 6), seed=st.integers(0, 2**16))
@settings(max_examples=12, deadline=None)
def test_raw_solve_kernel_matches_oracle_random_shapes(
        j_nodes, k_slots, d_feat, extra_rows, num_rounds, seed):
    """Property: the fused solve equals the scanned single-round oracle
    for arbitrary (unaligned) shapes, arbitrary θ-table indirection
    (T ≥ J rows, self_idx a permutation — unowned rows must stay at θ0
    under either round parity), arbitrary slot masks, and any round
    count including 0."""
    rng = np.random.default_rng(seed)
    t_rows = j_nodes + extra_rows
    scale = 0.5 / max(d_feat, 1)        # keep iterates from blowing up
    g = jnp.asarray(rng.normal(size=(j_nodes, d_feat, d_feat))) * scale
    d = jnp.asarray(rng.normal(size=(j_nodes, d_feat)))
    s = jnp.asarray(rng.normal(size=(j_nodes, d_feat, d_feat))) * scale
    p = jnp.asarray(
        rng.normal(size=(j_nodes, k_slots, d_feat, d_feat))) * scale
    theta = jnp.asarray(rng.normal(size=(t_rows, d_feat)))
    nbr_idx = jnp.asarray(
        rng.integers(0, t_rows, (j_nodes, k_slots)), jnp.int32)
    self_idx = jnp.asarray(rng.permutation(t_rows)[:j_nodes], jnp.int32)
    nbr_mask = jnp.asarray(
        rng.integers(0, 2, (j_nodes, k_slots)), jnp.int32)

    got = ops.dekrr_solve(g, d, s, p, theta, nbr_idx, self_idx, nbr_mask,
                          num_rounds=num_rounds, interpret=True)
    want = dekrr_solve_reference(g, d, s, p, theta, nbr_idx, self_idx,
                                 nbr_mask, num_rounds=num_rounds)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-9, atol=1e-10)


def test_chunked_solve_is_bit_identical_to_unchunked():
    """Round-chunking only changes WHERE the pallas_call boundaries fall,
    never the per-round arithmetic — chunked and unchunked fused solves
    must agree bit-for-bit (incl. a chunk size that does not divide the
    round count), and the per-round backends must stay at rtol 1e-9."""
    topo = circulant(8, (1, 2))
    solver = _solver(topo, [10, 12, 14, 16, 10, 12, 14, 16])
    packed = pack_problem(solver)
    fused = solve_batched(packed, 30, backend="pallas_fused")
    for chunk in (1, 7, 30, 64):
        chunked = solve_batched(packed, 30, backend="pallas_fused",
                                chunk_rounds=chunk)
        np.testing.assert_array_equal(np.asarray(chunked),
                                      np.asarray(fused),
                                      err_msg=f"chunk_rounds={chunk}")
    th_xla = solve_batched(packed, 30, backend="xla", chunk_rounds=7)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(th_xla), **TOL)


def test_tol_early_stop_agrees_across_backends():
    """With the same check cadence all three backends must stop after the
    SAME number of rounds and land on the same θ (the fused kernel cannot
    change the iteration's contraction)."""
    topo = circulant(6, (1,))
    solver = _solver(topo, [10, 14, 10, 14, 10, 14])
    packed = pack_problem(solver)
    results = {
        backend: solve_batched(packed, 2000, backend=backend, tol=1e-8,
                               chunk_rounds=10, return_rounds=True)
        for backend in ("xla", "pallas", "pallas_fused")
    }
    th_ref, rounds_ref = results["xla"]
    assert 0 < int(rounds_ref) < 2000, "tol never triggered — bad test"
    for backend, (th, rounds) in results.items():
        assert int(rounds) == int(rounds_ref), backend
        np.testing.assert_allclose(np.asarray(th), np.asarray(th_ref),
                                   err_msg=backend, **TOL)


def test_tol_early_stop_matches_reference_solver():
    """`solve_batched(tol=…, chunk_rounds=1)` checks max|Δθ| every round —
    exactly `DeKRRSolver.solve`'s (fixed) early-stop loop: same round
    count, same θ."""
    topo = circulant(6, (1,))
    dims = [10, 14, 10, 14, 10, 14]
    tol = 1e-7
    solver = _solver(topo, dims, tol=tol, num_iters=2000)
    packed = pack_problem(solver)
    state = solver.solve()
    assert 0 < state.iteration < 2000, "tol never triggered — bad test"
    theta, rounds = solve_batched(packed, 2000, backend="pallas_fused",
                                  tol=tol, chunk_rounds=1,
                                  return_rounds=True)
    assert int(rounds) == state.iteration
    for j in range(topo.num_nodes):
        np.testing.assert_allclose(np.asarray(theta[j][:dims[j]]),
                                   np.asarray(state.theta[j]), **TOL)


def test_solve_batched_without_tol_runs_all_rounds():
    topo = circulant(2, (1,))
    solver = _solver(topo, [8, 12])
    packed = pack_problem(solver)
    _, rounds = solve_batched(packed, 12, backend="pallas_fused",
                              return_rounds=True)
    assert int(rounds) == 12


def test_solve_batched_rejects_bad_arguments():
    topo = circulant(2, (1,))
    solver = _solver(topo, [8, 12])
    packed = pack_problem(solver)
    with pytest.raises(ValueError, match="backend"):
        solve_batched(packed, 5, backend="cuda_fused")
    with pytest.raises(ValueError, match="tol"):
        solve_batched(packed, 5, tol=-1e-6)
    with pytest.raises(ValueError, match="chunk_rounds"):
        solve_batched(packed, 5, chunk_rounds=0)


# --------------------------------------------------------------------------
# Bugfix regressions: acceleration backend plumbing
# --------------------------------------------------------------------------
def test_acceleration_kernels_honor_backend_switch():
    """`power_iteration_mu_max` / `power_iteration_mu_min` /
    `chebyshev_solve_packed` / `rounds_to_tolerance` used to hardcode the
    default XLA round — the backend switch was dead. Every one of them
    must now route through `step_batched(backend=…)` and agree with the
    XLA path at solver parity."""
    topo = circulant(6, (1,))
    solver = _solver(topo, [10, 14, 10, 14, 10, 14])
    packed = pack_problem(solver)

    mu_hi_x = power_iteration_mu_max(packed, iters=15)
    mu_hi_p = power_iteration_mu_max(packed, iters=15, backend="pallas")
    np.testing.assert_allclose(mu_hi_p, mu_hi_x, rtol=1e-9)

    mu_lo_x = power_iteration_mu_min(packed, mu_hi_x, iters=15)
    mu_lo_p = power_iteration_mu_min(packed, mu_hi_x, iters=15,
                                     backend="pallas")
    np.testing.assert_allclose(mu_lo_p, mu_lo_x, rtol=1e-9, atol=1e-12)

    lo, hi = estimate_spectral_interval(packed, iters=15)
    cheb_x = chebyshev_solve_packed(packed, hi, lo, num_iters=30)
    cheb_p = chebyshev_solve_packed(packed, hi, lo, num_iters=30,
                                    backend="pallas")
    np.testing.assert_allclose(np.asarray(cheb_p), np.asarray(cheb_x),
                               **TOL)

    theta_star = solve_batched(packed, 3000)
    plain_x, cheb_rounds_x = rounds_to_tolerance(
        packed, theta_star, tol=1e-5, max_rounds=800,
        mu_max=hi, mu_min=lo)
    plain_p, cheb_rounds_p = rounds_to_tolerance(
        packed, theta_star, tol=1e-5, max_rounds=800,
        mu_max=hi, mu_min=lo, backend="pallas")
    assert (plain_p, cheb_rounds_p) == (plain_x, cheb_rounds_x)


def test_acceleration_rejects_unknown_backend():
    topo = circulant(2, (1,))
    solver = _solver(topo, [8, 12])
    packed = pack_problem(solver)
    with pytest.raises(ValueError, match="backend"):
        power_iteration_mu_max(packed, iters=2, backend="cuda")


# --------------------------------------------------------------------------
# Bugfix regressions: DeKRRSolver.solve fused tol delta
# --------------------------------------------------------------------------
def test_solver_tol_computes_one_fused_delta(monkeypatch):
    """The tol check must force a single host sync per round (one fused
    max-of-maxes), not one per node: count device→host scalar pulls by
    intercepting float() conversions via jnp.max's return value."""
    topo = circulant(4, (1,))
    dims = [8, 10, 8, 10]
    solver = _solver(topo, dims, tol=1e-7, num_iters=500)

    import repro.core.dekrr as dekrr_mod
    pulls = 0
    real_float = float

    def counting_float(x):
        nonlocal pulls
        if isinstance(x, jax.Array):
            pulls += 1
        return real_float(x)

    monkeypatch.setattr(dekrr_mod, "float", counting_float, raising=False)
    state = solver.solve()
    assert 0 < state.iteration < 500, "tol never triggered — bad test"
    assert pulls == state.iteration, \
        f"{pulls} host syncs for {state.iteration} rounds (J={topo.num_nodes})"

    # and the early-stopped answer still matches the run-all-rounds answer
    ref = _solver(topo, dims, tol=0.0).solve(num_iters=state.iteration)
    for a, b in zip(state.theta, ref.theta):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# Bugfix regressions: pack_theta length validation
# --------------------------------------------------------------------------
def test_pack_theta_raises_clear_error_on_oversized_theta():
    topo = circulant(4, (1,))
    dims = [8, 10, 8, 10]
    solver = _solver(topo, dims)
    packed = pack_problem(solver)

    good = [jnp.zeros(dj) for dj in dims]
    assert pack_theta(packed, good).shape == (4, 10)

    bad = list(good)
    bad[2] = jnp.zeros(11)                      # exceeds even D_max
    with pytest.raises(ValueError, match=r"theta\[2\].*11.*D_j = 8"):
        pack_theta(packed, bad)

    sneaky = list(good)
    sneaky[0] = jnp.zeros(10)                   # fits D_max, exceeds D_0
    with pytest.raises(ValueError, match=r"theta\[0\].*D_j = 8"):
        pack_theta(packed, sneaky)

    with pytest.raises(ValueError, match="3 θ vectors.*4 nodes"):
        pack_theta(packed, good[:3])
