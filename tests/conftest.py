"""Test configuration.

x64 is enabled for the paper-faithful numerics (KRR solves); all model-zoo
code uses explicit dtypes so this does not affect the transformer substrate.
Do NOT set XLA_FLAGS device-count here — smoke tests must see 1 device; only
launch/dryrun.py forces 512 placeholder devices (in its own process).
"""
import jax

jax.config.update("jax_enable_x64", True)
