"""Test configuration.

x64 is enabled for the paper-faithful numerics (KRR solves); all model-zoo
code uses explicit dtypes so this does not affect the transformer substrate.
Do NOT set XLA_FLAGS device-count here — smoke tests must see 1 device; only
launch/dryrun.py forces 512 placeholder devices (in its own process).

Cached problem builders: constructing a DeKRR problem (synthetic dataset →
non-IID split → per-node DDRF feature selection → O(J²) Eq. 17 aux build)
dominates the suite's runtime, and many parametrized cases rebuild identical
pieces. The `cached_*` helpers below memoize each stage on hashable keys
for the whole session; test modules import them directly
(`from conftest import cached_split`). Everything built from them is
treated as read-only by the tests.
"""
import functools
import os

import jax

jax.config.update("jax_enable_x64", True)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def subprocess_env(**extra: str) -> dict[str, str]:
    """Minimal env for tests that re-exec python with forced device counts.

    JAX_PLATFORMS=cpu is load-bearing: without it, a TPU-enabled jaxlib
    probes for TPU hardware (minutes of metadata-server retries) before
    falling back to CPU.
    """
    env = {
        "PYTHONPATH": "src",
        "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "HOME": os.environ.get("HOME", "/root"),
        "JAX_PLATFORMS": "cpu",
    }
    env.update(extra)
    return env


@functools.lru_cache(maxsize=None)
def cached_dataset(name: str, subsample: int, seed: int = 0):
    from repro.data.synthetic import make_dataset
    return make_dataset(name, subsample=subsample, seed=seed)


@functools.lru_cache(maxsize=None)
def cached_split(name: str, num_nodes: int, mode: str = "noniid_y",
                 subsample: int = 600, seed: int = 0):
    """(dataset, train, test) for a node-partitioned synthetic dataset."""
    from repro.data.synthetic import partition, train_test_split_nodes
    ds = cached_dataset(name, subsample, seed)
    train, test = train_test_split_nodes(
        partition(ds, num_nodes, mode=mode))
    return ds, train, test


@functools.lru_cache(maxsize=None)
def cached_fmaps(name: str, num_nodes: int, dims: tuple,
                 sigma: float = 1.0, method: str = "energy",
                 candidate_ratio: int = 5, mode: str = "noniid_y",
                 subsample: int = 600, seed: int = 0,
                 split_seed: int | None = None):
    """Per-node DDRF feature maps for a cached split (dims: one D_j each).

    `seed` drives the feature draw; the dataset/split uses `split_seed`
    (defaults to `seed`). Pass `split_seed` explicitly when the caller's
    training data comes from a fixed split but the feature draw varies.
    """
    from repro.core import select_features
    if split_seed is None:
        split_seed = seed
    ds, train, _ = cached_split(name, num_nodes, mode=mode,
                                subsample=subsample, seed=split_seed)
    keys = jax.random.split(jax.random.PRNGKey(seed), num_nodes)
    return [
        select_features(keys[j], ds.dim, dims[j], sigma, train[j].x,
                        train[j].y, method=method,
                        candidate_ratio=candidate_ratio)
        for j in range(num_nodes)
    ]
