"""Multi-output (Dy) conformance web + aggregate-observation satellites.

The Eq. 17 auxiliaries are label-free, so a Dy-output problem is the SAME
linear Eq. 19 iteration per output column — which gives two independent
oracles the fused Dy-batched runtimes must match at rtol 1e-9 under x64:

* the Dy=1 pin: a [N, 1] trailing-axis problem takes the multi-output
  code paths but must reproduce the scalar [N] layout exactly, on every
  backend × sync/async × tol∈{0, >0};
* the per-output loop: a Dy>1 solve must equal Dy scalar solves of the
  column-sliced problems, stacked — over {circulant, star, Erdős–Rényi,
  J=1} × Dy∈{1, 3, 8} and all three backends, plus async gossip and
  Chebyshev acceleration.

Satellites pinned here: `pack_theta`/`unpack_theta` reject a θ whose
output width disagrees with the packing (regression for the silent
reshape-scramble), and singleton bags (ids 0…N_j−1) reproduce the
un-bagged reference build exactly (Agg = identity).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DeKRRConfig, DeKRRSolver, circulant, erdos_renyi, star
from repro.core.acceleration import (chebyshev_solve_packed,
                                     estimate_spectral_interval)
from repro.core.dekrr import NodeData
from repro.core.graph import Topology
from repro.core.rff import sample_rff
from repro.dist import (pack_problem, pack_theta, solve_batched,
                        unpack_theta)
from repro.dist.async_gossip import async_solve_batched

BACKENDS = ("xla", "pallas", "pallas_fused")
ROUNDS = 20


def _single_node_topology():
    return Topology(adjacency=np.zeros((1, 1), dtype=bool))


TOPOLOGIES = {
    "circulant": lambda: circulant(4, (1,)),
    "star": lambda: star(4),
    "er": lambda: erdos_renyi(5, 0.6, seed=2),
    "single": _single_node_topology,
}


def _solver(topo, ys, seed=0):
    """Random-data solver; `ys` is the per-node label list (any target
    shape — parity is exact algebra, so tiny random problems lose
    nothing)."""
    j_nodes = topo.num_nodes
    rng = np.random.default_rng(seed)
    fmaps = [sample_rff(jax.random.PRNGKey(seed + j), 3, 6 + 2 * j, 1.0)
             for j in range(j_nodes)]
    data = [NodeData(x=jnp.asarray(rng.normal(size=(3, y.shape[0]))),
                     y=jnp.asarray(y))
            for y in ys]
    return DeKRRSolver(topo, fmaps, data,
                       DeKRRConfig(lam=0.2, c_nei=1.0))


@functools.lru_cache(maxsize=None)
def _packs(topo_name: str, dy: int, seed: int = 0):
    """(multi-output pack, per-output scalar packs) on identical data."""
    topo = TOPOLOGIES[topo_name]()
    rng = np.random.default_rng(100 + seed)
    ys = [rng.normal(size=(10 + j, dy)) for j in range(topo.num_nodes)]
    multi = pack_problem(_solver(topo, ys, seed=seed))
    scalars = tuple(
        pack_problem(_solver(topo, [y[:, o] for y in ys], seed=seed))
        for o in range(dy))
    return multi, scalars


# --------------------------------------------------------------------------
# Dy=1 pin: the trailing-axis layout reproduces the scalar layout
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("gossip", ["sync", "async"])
@pytest.mark.parametrize("tol", [0.0, 1e-8])
def test_dy1_pins_scalar_layout(backend, gossip, tol):
    multi, (scalar,) = _packs("circulant", 1)
    assert multi.d.shape == scalar.d.shape + (1,)
    key = jax.random.PRNGKey(3)
    if gossip == "sync":
        th_m = solve_batched(multi, ROUNDS, backend=backend, tol=tol)
        th_s = solve_batched(scalar, ROUNDS, backend=backend, tol=tol)
    else:
        th_m = async_solve_batched(multi, ROUNDS, key, backend=backend,
                                   tol=tol)
        th_s = async_solve_batched(scalar, ROUNDS, key, backend=backend,
                                   tol=tol)
    assert th_m.shape == th_s.shape + (1,)
    np.testing.assert_allclose(np.asarray(th_m[..., 0]), np.asarray(th_s),
                               rtol=1e-9, atol=1e-12)


# --------------------------------------------------------------------------
# Dy>1: fused Dy-batched solves == per-output scalar loop
# --------------------------------------------------------------------------
@pytest.mark.parametrize("topo_name", sorted(TOPOLOGIES))
@pytest.mark.parametrize("dy", [1, 3, 8])
@pytest.mark.parametrize("backend", BACKENDS)
def test_solve_matches_per_output_loop(topo_name, dy, backend):
    multi, scalars = _packs(topo_name, dy)
    assert multi.d.shape[2:] == (dy,) and multi.num_outputs == dy
    th = solve_batched(multi, ROUNDS, backend=backend)
    for o, scalar in enumerate(scalars):
        th_o = solve_batched(scalar, ROUNDS, backend=backend)
        np.testing.assert_allclose(np.asarray(th[:, :, o]),
                                   np.asarray(th_o),
                                   rtol=1e-9, atol=1e-12)


@pytest.mark.parametrize("backend", BACKENDS)
def test_async_matches_per_output_loop(backend):
    multi, scalars = _packs("circulant", 3)
    key = jax.random.PRNGKey(11)
    th = async_solve_batched(multi, ROUNDS, key, backend=backend)
    for o, scalar in enumerate(scalars):
        th_o = async_solve_batched(scalar, ROUNDS, key, backend=backend)
        np.testing.assert_allclose(np.asarray(th[:, :, o]),
                                   np.asarray(th_o),
                                   rtol=1e-9, atol=1e-12)


@pytest.mark.parametrize("backend", BACKENDS)
def test_chebyshev_matches_per_output_loop(backend):
    multi, scalars = _packs("circulant", 3)
    mu_lo, mu_hi = estimate_spectral_interval(multi, backend="xla")
    th = chebyshev_solve_packed(multi, mu_hi, mu_lo, ROUNDS,
                                backend=backend)
    for o, scalar in enumerate(scalars):
        th_o = chebyshev_solve_packed(scalar, mu_hi, mu_lo, ROUNDS,
                                      backend=backend)
        np.testing.assert_allclose(np.asarray(th[:, :, o]),
                                   np.asarray(th_o),
                                   rtol=1e-9, atol=1e-12)


@pytest.mark.parametrize("backend", BACKENDS)
def test_tol_stop_reduces_over_outputs(backend):
    """tol>0 on a Dy problem must stop on max|Δθ| over features AND
    outputs: the early-stopped θ equals the tol=0 solve run for exactly
    the rounds the tol path reports."""
    multi, _ = _packs("circulant", 3)
    th_t, rounds = solve_batched(multi, 200, backend=backend, tol=1e-6,
                                 return_rounds=True)
    rounds = int(rounds)
    assert 0 < rounds < 200
    th_0 = solve_batched(multi, rounds, backend=backend)
    np.testing.assert_allclose(np.asarray(th_t), np.asarray(th_0),
                               rtol=1e-9, atol=1e-12)


# --------------------------------------------------------------------------
# pack_theta / unpack_theta output-width validation (regression)
# --------------------------------------------------------------------------
def test_pack_unpack_theta_dy_mismatch():
    multi, scalars = _packs("circulant", 3)
    th = solve_batched(multi, 5)
    ragged = unpack_theta(multi, th)
    assert all(t.ndim == 2 and t.shape[1] == 3 for t in ragged)
    np.testing.assert_array_equal(np.asarray(pack_theta(multi, ragged)),
                                  np.asarray(th))

    # scalar θ into a Dy=3 packing: rejected, names the output width
    th_s = solve_batched(scalars[0], 5)
    with pytest.raises(ValueError, match="Dy"):
        pack_theta(multi, unpack_theta(scalars[0], th_s))
    # wrong-Dy θ: reshaping would scramble output columns — rejected
    with pytest.raises(ValueError, match="Dy"):
        pack_theta(multi, [t[:, :2] for t in ragged])
    with pytest.raises(ValueError, match="Dy"):
        unpack_theta(multi, th[:, :, :2])
    with pytest.raises(ValueError, match="different packing"):
        unpack_theta(multi, th[..., 0])
    # and the mirror image: multi-output θ into a scalar packing
    with pytest.raises(ValueError, match="scalar"):
        pack_theta(scalars[0], ragged)


# --------------------------------------------------------------------------
# Aggregate observations: singleton bags == per-sample labels
# --------------------------------------------------------------------------
@pytest.mark.parametrize("dy", [None, 3])
def test_singleton_bags_match_per_sample(dy):
    """bags = (0…N_j−1) makes Agg the identity, so the bagged build and
    solve must reproduce the un-bagged reference exactly — for scalar and
    multi-output targets alike."""
    topo = circulant(4, (1,))
    rng = np.random.default_rng(7)
    shapes = [(10 + j,) if dy is None else (10 + j, dy)
              for j in range(topo.num_nodes)]
    ys = [rng.normal(size=s) for s in shapes]
    plain = _solver(topo, ys, seed=1)
    bagged = DeKRRSolver(
        topo, plain.feature_maps,
        [NodeData(x=nd.x, y=nd.y,
                  bags=jnp.arange(nd.num_samples, dtype=jnp.int32))
         for nd in plain.data],
        plain.config)
    for j in range(topo.num_nodes):
        np.testing.assert_allclose(np.asarray(bagged.aux.g[j]),
                                   np.asarray(plain.aux.g[j]),
                                   rtol=1e-9, atol=1e-12)
        np.testing.assert_allclose(np.asarray(bagged.aux.d[j]),
                                   np.asarray(plain.aux.d[j]),
                                   rtol=1e-9, atol=1e-12)
    st_b = bagged.solve(num_iters=10)
    st_p = plain.solve(num_iters=10)
    for tb, tp in zip(st_b.theta, st_p.theta):
        np.testing.assert_allclose(np.asarray(tb), np.asarray(tp),
                                   rtol=1e-9, atol=1e-12)


def test_bagged_pack_downgrades_to_aux_build():
    """Bag aggregation lives in the ragged aux build: `pack_problem` on a
    bagged solver must downgrade LOUDLY to the aux-based packing (never
    silently drop the Agg operator) and still agree with the reference
    iteration."""
    topo = circulant(4, (1,))
    rng = np.random.default_rng(9)
    ys = [rng.normal(size=(4,)) for _ in range(topo.num_nodes)]
    plain = _solver(topo, ys, seed=2)
    bagged = DeKRRSolver(
        topo, plain.feature_maps,
        [NodeData(x=nd.x,
                  y=jnp.asarray(rng.normal(size=(2,))),
                  bags=jnp.asarray(
                      np.arange(nd.num_samples, dtype=np.int32) % 2))
         for nd in plain.data],
        plain.config)
    with pytest.warns(UserWarning, match="bagged"):
        packed = pack_problem(bagged)
    th = unpack_theta(packed, solve_batched(packed, 10))
    st = bagged.solve(num_iters=10)
    for tb, tp in zip(th, st.theta):
        np.testing.assert_allclose(np.asarray(tb), np.asarray(tp),
                                   rtol=1e-9, atol=1e-12)
