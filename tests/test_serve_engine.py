"""Serving engine: batched greedy decode must equal sequential decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models.model import Model
from repro.serve import Request, ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_arch("qwen1_5_0_5b").config.reduced()
    return ServeEngine(cfg, batch_size=3, max_seq=64, seed=0)


def _sequential_greedy(engine, prompt, n_new):
    model, params = engine.model, engine.params
    cache = model.init_cache(engine.batch_size, engine.max_seq)
    step = jax.jit(model.decode_step)
    toks = list(prompt)
    out = []
    for t in range(len(prompt) + n_new - 1):
        cur = toks[t] if t < len(prompt) else out[-1]
        batch_tok = jnp.zeros((engine.batch_size, 1), jnp.int32
                              ).at[0, 0].set(cur)
        logits, cache = step(params, cache, batch_tok,
                             jnp.asarray(t, jnp.int32))
        if t >= len(prompt) - 1:
            out.append(int(jnp.argmax(logits[0])))
    return out


def test_single_request_matches_sequential(engine):
    prompt = [5, 17, 256, 3]
    want = _sequential_greedy(engine, prompt, 8)
    [req] = engine.run([Request(uid=0, prompt=prompt, max_new_tokens=8)])
    assert req.done
    assert req.output == want


def test_batch_of_requests_all_complete(engine):
    reqs = [Request(uid=i, prompt=[i + 1, i + 2, i + 3],
                    max_new_tokens=6) for i in range(7)]
    done = engine.run(reqs)
    assert len(done) == 7
    assert all(r.done and len(r.output) == 6 for r in done)
    # deterministic: re-running the same prompts gives the same outputs
    again = engine.run([Request(uid=i, prompt=[i + 1, i + 2, i + 3],
                                max_new_tokens=6) for i in range(7)])
    for a, b in zip(done, again):
        assert a.output == b.output


def test_eos_stops_generation(engine):
    prompt = [5, 17, 256, 3]
    free = engine.run([Request(uid=0, prompt=prompt, max_new_tokens=12)])[0]
    if len(set(free.output)) < 2:
        pytest.skip("degenerate random model output")
    eos = free.output[2]
    stopped = engine.run([Request(uid=1, prompt=prompt, max_new_tokens=12,
                                  eos_id=eos)])[0]
    assert len(stopped.output) <= 3 or stopped.output[-1] == eos
