import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.ddrf import (energy_scores, leverage_scores, select_features)
from repro.core.rff import (featurize, gaussian_kernel, sample_rff)


@pytest.mark.parametrize("kind", ["cos_sin", "cos_bias"])
def test_rff_approximates_gaussian_kernel(kind):
    key = jax.random.PRNGKey(0)
    d, n, D, sigma = 5, 40, 4096, 1.5
    x = jax.random.uniform(jax.random.PRNGKey(1), (d, n))
    fmap = sample_rff(key, d, D, sigma, kind=kind)
    z = featurize(fmap, x)
    k_hat = z.T @ z
    k_true = gaussian_kernel(x, x, sigma)
    err = jnp.max(jnp.abs(k_hat - k_true))
    assert err < 0.06, f"max kernel approx error {err}"


def test_cos_sin_has_double_features():
    fmap = sample_rff(jax.random.PRNGKey(0), 3, 10, 1.0, kind="cos_sin")
    assert fmap.num_features == 20
    z = featurize(fmap, jnp.zeros((3, 7)))
    assert z.shape == (20, 7)


@given(d=st.integers(1, 8), n=st.integers(1, 30), D=st.integers(1, 16),
       seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_featurize_shapes_and_norm_property(d, n, D, seed):
    """z(x)ᵀz(x) ≈ k(x,x) = 1 for the Gaussian kernel (unbiased in expectation,
    and exactly 1 for the cos_sin construction)."""
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (d, n))
    fmap = sample_rff(key, d, D, 1.0, kind="cos_sin")
    z = featurize(fmap, x)
    assert z.shape == (2 * D, n)
    diag = jnp.sum(z * z, axis=0)
    np.testing.assert_allclose(np.asarray(diag), 1.0, atol=1e-6)


def test_kernel_estimate_unbiased_monte_carlo():
    """Average of many independent D=1 estimates converges to k(x,x')."""
    d = 3
    x = jnp.array([[0.3], [0.1], [-0.2]])
    x2 = jnp.array([[-0.5], [0.4], [0.2]])
    k_true = float(gaussian_kernel(x, x2, 1.0)[0, 0])

    def one_estimate(key):
        fm = sample_rff(key, d, 4, 1.0, kind="cos_bias")
        return (featurize(fm, x) * featurize(fm, x2)).sum()

    keys = jax.random.split(jax.random.PRNGKey(42), 4000)
    ests = jax.vmap(one_estimate)(keys)
    assert abs(float(jnp.mean(ests)) - k_true) < 0.02


def test_energy_scores_prefer_signal_frequency():
    """Labels built from one known frequency → that frequency scores highest."""
    key = jax.random.PRNGKey(0)
    d, n = 4, 512
    x = jax.random.uniform(jax.random.PRNGKey(1), (d, n))
    omega_star = jnp.array([3.0, -2.0, 1.0, 0.5])
    y = jnp.cos(omega_star @ x + 0.7)
    fmap = sample_rff(key, d, 2000, 2.0, kind="cos_bias")
    # plant the true frequency among the candidates
    omega = fmap.omega.at[17].set(omega_star)
    bias = fmap.bias.at[17].set(0.7)
    planted = type(fmap)(omega=omega, bias=bias, kind=fmap.kind)
    scores = energy_scores(planted, x, y)
    assert int(jnp.argmax(scores)) == 17


def test_leverage_scores_in_unit_interval():
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(jax.random.PRNGKey(1), (6, 200))
    fmap = sample_rff(key, 6, 64, 1.0, kind="cos_bias")
    tau = leverage_scores(fmap, x, lam=1e-4)
    assert jnp.all(tau >= -1e-8) and jnp.all(tau <= 1.0 + 1e-8)


@pytest.mark.parametrize("method", ["plain", "energy", "leverage",
                                    "leverage_resample"])
def test_select_features_returns_requested_count(method):
    key = jax.random.PRNGKey(3)
    x = jax.random.uniform(jax.random.PRNGKey(4), (5, 128))
    y = jnp.sin(x.sum(axis=0))
    fmap = select_features(key, 5, 12, 1.0, x, y, method=method,
                           candidate_ratio=10)
    assert fmap.num_frequencies == 12
    assert featurize(fmap, x).shape == (12, 128)


def test_ddrf_improves_over_plain_on_structured_target():
    """The paper's core premise: at equal D, energy-selected features fit a
    structured target better than data-independent RFF."""
    d, n, D, sigma, lam = 6, 800, 8, 1.0, 1e-6

    errs_plain, errs_ddrf = [], []
    for s in range(8):
        x = jax.random.uniform(jax.random.PRNGKey(s), (d, n))
        xe = jax.random.uniform(jax.random.PRNGKey(300 + s), (d, 400))
        omega_t = jax.random.normal(jax.random.PRNGKey(100 + s), (4, d)) * 1.5
        y = jnp.cos(omega_t @ x).sum(axis=0) / 4.0
        ye = jnp.cos(omega_t @ xe).sum(axis=0) / 4.0

        def fit_eval(fmap):
            z = featurize(fmap, x)
            g = z @ z.T + lam * n * jnp.eye(z.shape[0])
            th = jnp.linalg.solve(g, z @ y)
            pred = th @ featurize(fmap, xe)
            return float(jnp.mean((pred - ye) ** 2))

        k = jax.random.PRNGKey(200 + s)
        errs_plain.append(fit_eval(sample_rff(k, d, D, sigma)))
        errs_ddrf.append(fit_eval(select_features(
            k, d, D, sigma, x, y, method="energy", candidate_ratio=20)))
    assert np.mean(errs_ddrf) < np.mean(errs_plain)
