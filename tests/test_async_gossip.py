"""Cross-backend conformance suite for the async gossip DeKRR runtime.

The async runtime is the first workload where the three execution layers
(ragged reference, packed batched, SPMD nodes-on-devices) can disagree
*silently*: a mask sampled differently, a buffer refreshed on the wrong
round, or a censor decision flipped produces a perfectly plausible — and
wrong — trajectory. This suite pins, under x64 at rtol 1e-9:

  ragged reference (`repro.core.async_gossip_solve`)
    == packed XLA    (`async_solve_batched(backend="xla")`)
    == packed Pallas (`backend="pallas"`, interpret mode on CPU)
    == SPMD subprocess (`make_async_spmd_solver`, forced CPU devices)

swept over {circulant, star, Erdős–Rényi, complete, J=1} ×
{p ∈ 0.25, 0.5, 1.0} × {censored, uncensored}, with the p = 1.0
uncensored column additionally pinned BIT-FOR-BIT against the synchronous
`solve_batched` of the same backend, plus the chunk-size seed-stability
regression for the tol early stop (the chunk-boundary bug class PR 3
fixed for the sync path).
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import REPO_ROOT, cached_fmaps, cached_split, subprocess_env
from repro.core import (AsyncGossipConfig, DeKRRConfig, DeKRRSolver,
                        Topology, async_gossip_solve, circulant, complete,
                        edge_list, edges_from_slot_table, erdos_renyi, star)
from repro.dist import async_solve_batched, pack_problem, solve_batched

TOL = dict(rtol=1e-9, atol=1e-12)
ROUNDS = 15
KEY = jax.random.PRNGKey(7)
# Decaying COKE threshold sized to the test problems' broadcast deltas
# (~1e-2): large enough to censor real broadcasts within ROUNDS (asserted
# below, so the censored column can never go vacuously green), small
# enough that trajectories stay informative.
CENSOR = dict(censor_tau=2e-2, censor_decay=0.9)


def _single_node_topology():
    return Topology(adjacency=np.zeros((1, 1), dtype=bool))


# Same graph sweep as the kernel parity suites: both slot layouts
# (circulant ppermute order, generic padded adjacency) and every degree
# extreme, now under randomized activation.
TOPOLOGIES = {
    "circulant": (circulant(6, (1, 2)), [8, 10, 12, 8, 10, 12]),
    "star": (star(5), [6, 8, 10, 12, 14]),
    "er": (erdos_renyi(6, 0.5, seed=2), [9, 11, 9, 11, 9, 11]),
    "complete": (complete(4), [7, 9, 11, 9]),
    "j1": (_single_node_topology(), [10]),
}

_CACHE: dict = {}


def _problem(name):
    """(solver, packed, dims) for a topology — cached across the matrix
    (parity is exact algebra; every cell reuses the same auxiliaries)."""
    if name not in _CACHE:
        topo, dims = TOPOLOGIES[name]
        j = topo.num_nodes
        ds, train, _ = cached_split("air_quality", j, subsample=300, seed=0)
        fmaps = cached_fmaps("air_quality", j, tuple(dims),
                             subsample=300, seed=0)
        n = sum(t.num_samples for t in train)
        solver = DeKRRSolver(topo, fmaps, train,
                             DeKRRConfig(lam=1e-6, c_nei=0.02 * n))
        _CACHE[name] = (solver, pack_problem(solver), dims)
    return _CACHE[name]


# --------------------------------------------------------------------------
# The conformance matrix: ragged reference vs packed XLA vs packed Pallas
# --------------------------------------------------------------------------
@pytest.mark.parametrize("topo_name", list(TOPOLOGIES))
@pytest.mark.parametrize("prob", [0.25, 0.5, 1.0])
def test_async_conformance_matrix(topo_name, prob):
    """Every (topology, p, censoring) cell: the ragged reference, the
    packed XLA path and the packed Pallas (interpret) path agree at
    rtol 1e-9 under x64 — identical masks, identical censor decisions,
    identical wire traffic, near-identical θ."""
    solver, packed, dims = _problem(topo_name)
    for censored in (False, True):
        config = AsyncGossipConfig(
            prob=prob, **(CENSOR if censored else {}))
        ref = async_gossip_solve(solver, KEY, ROUNDS, config)
        th_xla, stats = async_solve_batched(
            packed, ROUNDS, KEY, config=config, return_stats=True)
        th_pal = async_solve_batched(
            packed, ROUNDS, KEY, config=config, backend="pallas")
        for j in range(solver.J):
            np.testing.assert_allclose(
                np.asarray(th_xla[j][:dims[j]]), np.asarray(ref.theta[j]),
                err_msg=f"xla vs ragged, censored={censored}", **TOL)
            # padding must stay identically zero through pass-throughs too
            assert not np.any(np.asarray(th_xla[j][dims[j]:]))
        np.testing.assert_allclose(
            np.asarray(th_pal), np.asarray(th_xla),
            err_msg=f"pallas vs xla, censored={censored}", **TOL)
        # wire accounting must agree exactly (discrete decisions)
        assert int(stats.broadcasts) == ref.broadcasts
        assert int(stats.deliveries) == ref.deliveries
        assert int(stats.rounds) == ref.rounds == ROUNDS


def test_censoring_actually_suppresses_broadcasts():
    """Guard against a vacuously green censored column: at the matrix's
    threshold schedule, censoring must drop the broadcast count."""
    _, packed, _ = _problem("circulant")
    _, on = async_solve_batched(
        packed, ROUNDS, KEY, config=AsyncGossipConfig(**CENSOR),
        return_stats=True)
    _, off = async_solve_batched(
        packed, ROUNDS, KEY, config=AsyncGossipConfig(), return_stats=True)
    assert int(on.broadcasts) < int(off.broadcasts)
    assert int(on.deliveries) < int(off.deliveries)


@pytest.mark.parametrize("topo_name", ["circulant", "star"])
@pytest.mark.parametrize("censored", [False, True])
def test_async_conformance_edge_gossip(topo_name, censored):
    """Pairwise edge gossip (one uniform edge per round, delivery along
    that edge only) — the mode where per-edge staleness buffers genuinely
    diverge from the senders' last-broadcast vectors."""
    solver, packed, dims = _problem(topo_name)
    config = AsyncGossipConfig(gossip="edge",
                               **(CENSOR if censored else {}))
    ref = async_gossip_solve(solver, KEY, ROUNDS, config)
    th_xla, stats = async_solve_batched(
        packed, ROUNDS, KEY, config=config, return_stats=True)
    th_pal = async_solve_batched(
        packed, ROUNDS, KEY, config=config, backend="pallas")
    for j in range(solver.J):
        np.testing.assert_allclose(
            np.asarray(th_xla[j][:dims[j]]), np.asarray(ref.theta[j]),
            **TOL)
    np.testing.assert_allclose(np.asarray(th_pal), np.asarray(th_xla),
                               **TOL)
    assert int(stats.broadcasts) == ref.broadcasts
    assert int(stats.deliveries) == ref.deliveries
    # edge gossip delivers point-to-point: one delivery per broadcast
    assert ref.deliveries == ref.broadcasts


def test_packed_edge_list_matches_topology_edge_list():
    """`gossip="edge"` draws stay consistent across layers only if the
    packed slot-table edge derivation reproduces the topology's canonical
    edge enumeration bit-for-bit."""
    for name in TOPOLOGIES:
        solver, packed, _ = _problem(name)
        np.testing.assert_array_equal(
            edge_list(solver.topology),
            edges_from_slot_table(np.asarray(packed.nbr_idx),
                                  np.asarray(packed.nbr_mask)),
            err_msg=name)


# --------------------------------------------------------------------------
# p = 1.0, censoring off: bit-for-bit the synchronous solve, per backend
# --------------------------------------------------------------------------
@pytest.mark.parametrize("topo_name", list(TOPOLOGIES))
@pytest.mark.parametrize("backend", ["xla", "pallas", "pallas_fused"])
def test_p1_uncensored_is_bitwise_synchronous(topo_name, backend):
    """The async schedule at full activation IS the Jacobi iteration: the
    async runtime must reproduce `solve_batched` of the SAME backend
    bit-for-bit — any jnp.where, buffer plumbing or mask arithmetic that
    perturbs a single ulp fails this. (backend="pallas_fused" pins the
    fused multi-round async chain against the sync multi-round fused
    kernel — same dot_general sequence, one dispatch each.)
    """
    _, packed, _ = _problem(topo_name)
    sync = solve_batched(packed, ROUNDS, backend=backend)
    asynchronous = async_solve_batched(packed, ROUNDS, KEY,
                                       config=AsyncGossipConfig(),
                                       backend=backend)
    np.testing.assert_array_equal(np.asarray(sync),
                                  np.asarray(asynchronous))


# --------------------------------------------------------------------------
# Fused async chain: bit-parity with the per-round kernel, chunk-invariant
# --------------------------------------------------------------------------
@pytest.mark.parametrize("gossip", ["bernoulli", "edge"])
@pytest.mark.parametrize("censored", [False, True])
def test_fused_async_chain_conformance(gossip, censored):
    """`backend="pallas_fused"` runs the whole schedule (masks, censor
    thresholds, delivery parity) inside one kernel chain. It must be
    BIT-identical to the per-round masked kernel (`backend="pallas"`) —
    both execute the same dot_general sequence at precision=HIGHEST —
    allclose to the XLA path, and invariant to chunk_rounds ∈
    {1, 7, 64} bit for bit."""
    _, packed, dims = _problem("circulant")
    config = AsyncGossipConfig(prob=0.6, gossip=gossip,
                               **(CENSOR if censored else {}))
    th_fused = async_solve_batched(packed, ROUNDS, KEY, config=config,
                                   backend="pallas_fused")
    th_pal = async_solve_batched(packed, ROUNDS, KEY, config=config,
                                 backend="pallas")
    th_xla = async_solve_batched(packed, ROUNDS, KEY, config=config)
    np.testing.assert_array_equal(np.asarray(th_fused), np.asarray(th_pal))
    np.testing.assert_allclose(np.asarray(th_fused), np.asarray(th_xla),
                               **TOL)
    for chunk in (1, 7, 64):
        chunked = async_solve_batched(packed, ROUNDS, KEY, config=config,
                                      backend="pallas_fused",
                                      chunk_rounds=chunk)
        np.testing.assert_array_equal(np.asarray(chunked),
                                      np.asarray(th_fused),
                                      err_msg=f"chunk_rounds={chunk}")


def test_fused_async_stats_fall_back_to_per_round():
    """return_stats=True keeps the per-round accounting path even under
    backend="pallas_fused" — its θ and wire counts must match XLA's."""
    _, packed, _ = _problem("circulant")
    config = AsyncGossipConfig(prob=0.6, **CENSOR)
    th_fused, stats_fused = async_solve_batched(
        packed, ROUNDS, KEY, config=config, backend="pallas_fused",
        return_stats=True)
    th_xla, stats_xla = async_solve_batched(
        packed, ROUNDS, KEY, config=config, return_stats=True)
    np.testing.assert_allclose(np.asarray(th_fused), np.asarray(th_xla),
                               **TOL)
    assert int(stats_fused.broadcasts) == int(stats_xla.broadcasts)
    assert int(stats_fused.deliveries) == int(stats_xla.deliveries)
    assert int(stats_fused.rounds) == int(stats_xla.rounds) == ROUNDS


# --------------------------------------------------------------------------
# Seed-stability regression: tol early stop vs chunk_rounds (async path)
# --------------------------------------------------------------------------
def test_async_tol_rounds_identical_across_chunk_sizes():
    """`async_solve_batched(tol=…, return_rounds=True)` evaluates
    convergence after EVERY round and freezes converged solves, so the
    reported rounds-run AND θ must be identical across chunk_rounds ∈
    {1, 7, 64} — the chunk-boundary early-stop bug class PR 3 fixed for
    the sync path must not re-enter through the async scan."""
    _, packed, _ = _problem("circulant")
    config = AsyncGossipConfig(prob=0.5)
    results = {
        chunk: async_solve_batched(packed, 500, KEY, config=config,
                                   tol=1e-8, chunk_rounds=chunk,
                                   return_rounds=True)
        for chunk in (1, 7, 64)
    }
    theta_ref, rounds_ref = results[1]
    assert 0 < int(rounds_ref) < 500, "tol never triggered — bad test"
    for chunk, (theta, rounds) in results.items():
        assert int(rounds) == int(rounds_ref), f"chunk_rounds={chunk}"
        np.testing.assert_array_equal(np.asarray(theta),
                                      np.asarray(theta_ref),
                                      err_msg=f"chunk_rounds={chunk}")


def test_async_tol_ignores_all_silent_rounds():
    """Regression: a round whose Bernoulli draw activates NO nodes has
    Δθ ≡ 0 by construction — the tol stop must not mistake that idle
    round for convergence and return θ = 0 after one round. (At p = 0.25,
    J = 6 an all-silent round occurs with probability (1−p)^J ≈ 18% per
    round, so this key's schedule opens with one.)"""
    from repro.core import activation_masks

    _, packed, _ = _problem("circulant")
    prob = 0.25
    masks = np.asarray(activation_masks(KEY, 3, packed.num_nodes,
                                        prob=prob))
    assert not masks[0].any(), "precondition: round 0 must be all-silent"
    theta, rounds = async_solve_batched(
        packed, 500, KEY, config=AsyncGossipConfig(prob=prob), tol=1e-8,
        return_rounds=True)
    assert int(rounds) > 1, "stopped on the idle round"
    assert np.any(np.asarray(theta)), "converged to the θ0 = 0 iterate"


def test_async_tol_agrees_with_ragged_reference_early_stop():
    """The per-round freeze must stop on the same round as the reference
    solver's break (the converging round is counted in both)."""
    solver, packed, dims = _problem("circulant")
    config = AsyncGossipConfig(prob=0.5)
    ref = async_gossip_solve(solver, KEY, 500, config, tol=1e-8)
    theta, rounds = async_solve_batched(packed, 500, KEY, config=config,
                                        tol=1e-8, return_rounds=True)
    assert int(rounds) == ref.rounds
    for j in range(solver.J):
        np.testing.assert_allclose(np.asarray(theta[j][:dims[j]]),
                                   np.asarray(ref.theta[j]), **TOL)


# --------------------------------------------------------------------------
# Argument validation
# --------------------------------------------------------------------------
def test_async_gossip_rejects_bad_arguments():
    _, packed, _ = _problem("j1")
    with pytest.raises(ValueError, match="prob"):
        AsyncGossipConfig(prob=0.0)
    with pytest.raises(ValueError, match="gossip"):
        AsyncGossipConfig(gossip="ring")
    with pytest.raises(ValueError, match="censor_tau"):
        AsyncGossipConfig(censor_tau=-1.0)
    with pytest.raises(ValueError, match="censor_decay"):
        AsyncGossipConfig(censor_decay=1.5)
    with pytest.raises(ValueError, match="backend"):
        async_solve_batched(packed, 5, KEY, backend="cuda")
    with pytest.raises(ValueError, match="tol"):
        async_solve_batched(packed, 5, KEY, tol=-1e-6)
    with pytest.raises(ValueError, match="chunk_rounds"):
        async_solve_batched(packed, 5, KEY, chunk_rounds=0)
    # edge gossip needs at least one edge; J=1 has none
    with pytest.raises(ValueError, match="edge"):
        async_solve_batched(packed, 5, KEY,
                            config=AsyncGossipConfig(gossip="edge"))


# --------------------------------------------------------------------------
# SPMD conformance (subprocess: forced CPU device counts must not leak)
# --------------------------------------------------------------------------
SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    import jax
    jax.config.update("jax_enable_x64", True)
    import numpy as np
    from jax.sharding import Mesh
    from repro.core import (AsyncGossipConfig, DeKRRConfig, DeKRRSolver,
                            Topology, circulant, complete, select_features,
                            star)
    from repro.data.synthetic import (make_dataset, partition,
                                      train_test_split_nodes)
    from repro.dist import (async_solve_batched, make_async_spmd_solver,
                            make_spmd_solver, pack_problem)

    ROUNDS = 10
    KEY = jax.random.PRNGKey(7)
    ds = make_dataset("air_quality", subsample=300, seed=0)

    def build(topo, dims):
        j = topo.num_nodes
        train, _ = train_test_split_nodes(partition(ds, j, mode="noniid_y"))
        keys = jax.random.split(jax.random.PRNGKey(0), j)
        fmaps = [select_features(keys[jj], ds.dim, dims[jj], 1.0,
                                 train[jj].x, train[jj].y, method="energy",
                                 candidate_ratio=5) for jj in range(j)]
        n = sum(t.num_samples for t in train)
        return pack_problem(DeKRRSolver(
            topo, fmaps, train, DeKRRConfig(lam=1e-6, c_nei=0.02 * n)))

    single = Topology(adjacency=np.zeros((1, 1), dtype=bool))
    SWEEP = [
        ("circulant", circulant(6, (1, 2)), [8, 10, 12, 8, 10, 12],
         "ppermute"),
        ("star", star(5), [6, 8, 10, 12, 14], "allgather"),
        ("complete", complete(4), [7, 9, 11, 9], "allgather"),
        ("j1", single, [10], "allgather"),
    ]
    CENSOR = dict(censor_tau=2e-2, censor_decay=0.9)

    for name, topo, dims, mode in SWEEP:
        packed = build(topo, dims)
        mesh = Mesh(np.array(jax.devices()[:topo.num_nodes]), ("nodes",))
        for backend in ("xla", "pallas"):
            runner = make_async_spmd_solver(mesh, "nodes", mode,
                                            backend=backend)
            for prob in (0.25, 0.5, 1.0):
                for censored in (False, True):
                    config = AsyncGossipConfig(
                        prob=prob, **(CENSOR if censored else {}))
                    got = runner(packed, ROUNDS, KEY, config)
                    want = async_solve_batched(packed, ROUNDS, KEY,
                                               config=config)
                    np.testing.assert_allclose(
                        np.asarray(got), np.asarray(want),
                        rtol=1e-9, atol=1e-12,
                        err_msg=f"{name} {backend} p={prob} "
                                f"censored={censored}")
            # p=1 uncensored: bit-for-bit the SYNC SPMD solver, same
            # backend and exchange wiring
            sync = make_spmd_solver(mesh, "nodes", mode,
                                    backend=backend)(packed, ROUNDS)
            got = runner(packed, ROUNDS, KEY, AsyncGossipConfig())
            np.testing.assert_array_equal(np.asarray(sync),
                                          np.asarray(got),
                                          err_msg=f"{name} {backend}")
        if name == "circulant":
            # edge gossip: flag exchange rides the collective
            runner = make_async_spmd_solver(mesh, "nodes", mode)
            for censored in (False, True):
                config = AsyncGossipConfig(
                    gossip="edge", **(CENSOR if censored else {}))
                got = runner(packed, ROUNDS, KEY, config)
                want = async_solve_batched(packed, ROUNDS, KEY,
                                           config=config)
                np.testing.assert_allclose(
                    np.asarray(got), np.asarray(want),
                    rtol=1e-9, atol=1e-12,
                    err_msg=f"edge censored={censored}")
    print("SPMD-ASYNC-CONFORMANCE-OK")
""")


def test_spmd_async_conformance_subprocess():
    """The SPMD column of the conformance matrix, in a subprocess so the
    forced 6-device CPU platform does not leak into this session."""
    proc = subprocess.run(
        [sys.executable, "-c", SPMD_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=subprocess_env(),
        cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SPMD-ASYNC-CONFORMANCE-OK" in proc.stdout


def test_spmd_async_multidevice_smoke():
    """In-process SPMD async smoke for CI's 4-device kernels job
    (XLA_FLAGS=--xla_force_host_platform_device_count=4); skipped in the
    normal 1-device tier-1 session."""
    if jax.device_count() < 4:
        pytest.skip("needs >= 4 devices (CI kernels job forces 4)")
    from jax.sharding import Mesh
    from repro.dist import make_async_spmd_solver

    topo = circulant(4, (1,))
    dims = [8, 10, 8, 10]
    ds, train, _ = cached_split("air_quality", 4, subsample=300, seed=0)
    fmaps = cached_fmaps("air_quality", 4, tuple(dims),
                         subsample=300, seed=0)
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=1e-6, c_nei=0.02 * n))
    packed = pack_problem(solver)
    mesh = Mesh(np.array(jax.devices()[:4]), ("nodes",))
    config = AsyncGossipConfig(prob=0.5, **CENSOR)
    got = make_async_spmd_solver(mesh, "nodes", "ppermute")(
        packed, 10, KEY, config)
    want = async_solve_batched(packed, 10, KEY, config=config)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOL)
