"""Dry-run integration smoke: one (arch × shape) pair lowers + compiles on
the 512-placeholder-device platform, in a subprocess so the forced device
count never leaks into this session."""
import json
import subprocess
import sys

import pytest


@pytest.mark.parametrize("arch,shape", [
    ("qwen1_5_0_5b", "decode_32k"),
    ("smollm_135m", "train_4k"),
])
def test_dryrun_pair_compiles(arch, shape, tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape, "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.load(open(tmp_path / f"16x16_{arch}_{shape}.json"))
    assert out["status"] == "ok"
    assert out["chips"] == 256
    assert out["flops_per_device"] > 0
    assert out["dominant"] in ("compute", "memory", "collective")


def test_dryrun_skip_recorded(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "hubert_xlarge", "--shape", "decode_32k",
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        cwd="/root/repo",
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.load(open(tmp_path / "16x16_hubert_xlarge_decode_32k.json"))
    assert out["status"] == "skip"
