"""Dry-run integration smoke: one (arch × shape) pair lowers + compiles on a
forced-placeholder-device platform, in a subprocess so the forced device
count never leaks into this session.

The smoke runs the production pipeline (sharding rules → lower → compile →
HLO/memory analysis) on a 4×4 mesh over 16 placeholder devices: identical
code path to the 16×16 deployment mesh at a small fraction of the XLA SPMD
partitioning cost (the 256-chip compile takes 10+ minutes on CPU).
"""
import json
import subprocess
import sys

import pytest
from conftest import REPO_ROOT, subprocess_env

SMOKE_ENV = subprocess_env(
    DRYRUN_XLA_FLAGS="--xla_force_host_platform_device_count=16")


@pytest.mark.parametrize("arch,shape", [
    ("qwen1_5_0_5b", "decode_32k"),
    ("smollm_135m", "train_4k"),
])
def test_dryrun_pair_compiles(arch, shape, tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape, "--mesh", "4x4",
         "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=900,
        env=SMOKE_ENV, cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.load(open(tmp_path / f"4x4_{arch}_{shape}.json"))
    assert out["status"] == "ok"
    assert out["chips"] == 16
    assert out["flops_per_device"] > 0
    assert out["dominant"] in ("compute", "memory", "collective")


def test_dryrun_skip_recorded(tmp_path):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "hubert_xlarge", "--shape", "decode_32k",
         "--mesh", "4x4", "--out", str(tmp_path)],
        capture_output=True, text=True, timeout=300,
        env=SMOKE_ENV, cwd=REPO_ROOT,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.load(open(tmp_path / "4x4_hubert_xlarge_decode_32k.json"))
    assert out["status"] == "skip"
