"""Flash-decode Pallas kernel: allclose sweeps vs the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.ref import chunked_decode_attention_ref

CASES = [
    # (B, H, K, dh, S, cur)
    (2, 8, 8, 64, 256, 200),        # MHA
    (2, 8, 2, 64, 512, 512),        # GQA 4:1, full cache
    (1, 16, 16, 128, 1024, 37),     # qwen-ish heads, short valid prefix
    (4, 4, 1, 80, 300, 123),        # MQA, unaligned dh & S
    (3, 6, 3, 32, 96, 50),          # small everything
]


def _oracle(q, k, v, cur):
    s = k.shape[1]
    mask = (jnp.arange(s) < cur)[None, :]
    mask = jnp.broadcast_to(mask, (q.shape[0], s))
    # GQA: repeat kv heads
    g = q.shape[2] // k.shape[2]
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    return chunked_decode_attention_ref(
        q[:, 0], kk, vv, scale=q.shape[-1] ** -0.5, mask=mask)[:, None]


@pytest.mark.parametrize("b,h,kh,dh,s,cur", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_flash_decode_matches_oracle(b, h, kh, dh, s, cur, dtype):
    key = jax.random.PRNGKey(b * 1000 + s)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, 1, h, dh), dtype)
    k = jax.random.normal(kk, (b, s, kh, dh), dtype)
    v = jax.random.normal(kv, (b, s, kh, dh), dtype)
    got = ops.flash_decode(q, k, v, jnp.asarray(cur, jnp.int32),
                           interpret=True)
    want = _oracle(q, k, v, cur)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@given(b=st.integers(1, 3), kh=st.integers(1, 4), g=st.integers(1, 4),
       dh=st.sampled_from([16, 32, 64]), s=st.integers(8, 400),
       seed=st.integers(0, 2**16))
@settings(max_examples=15, deadline=None)
def test_flash_decode_property(b, kh, g, dh, s, seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, kc = jax.random.split(key, 4)
    h = kh * g
    cur = int(jax.random.randint(kc, (), 1, s + 1))
    q = jax.random.normal(kq, (b, 1, h, dh))
    k = jax.random.normal(kk, (b, s, kh, dh))
    v = jax.random.normal(kv, (b, s, kh, dh))
    got = ops.flash_decode(q, k, v, jnp.asarray(cur, jnp.int32),
                           interpret=True)
    want = _oracle(q, k, v, cur)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_flash_decode_ignores_stale_cache_tail():
    """Entries beyond cur_index must not affect the output."""
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (1, 1, 4, 32))
    k = jax.random.normal(kk, (1, 128, 4, 32))
    v = jax.random.normal(kv, (1, 128, 4, 32))
    cur = jnp.asarray(64, jnp.int32)
    out1 = ops.flash_decode(q, k, v, cur, interpret=True)
    k2 = k.at[:, 64:].set(999.0)
    v2 = v.at[:, 64:].set(-999.0)
    out2 = ops.flash_decode(q, k2, v2, cur, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=1e-6, atol=1e-6)
