"""Chebyshev acceleration: closed-form optimality pin and fused-kernel
parity.

The β₁ = ½(c/d)² special case is what makes the two-term recurrence THE
Chebyshev method: each iterate's error must equal the degree-k Chebyshev
error polynomial σ_k(A) = T_k((d−A)/c)/T_k(d/c) applied to e₀, which a
dense eigendecomposition evaluates in closed form.  The pin here fails
for the pre-fix generic-β₁ table (¼(c/d)²) from k = 2 on, and
`rounds_to_tolerance` must report strictly fewer Chebyshev rounds than
the pre-fix recurrence on the bench problem.  The fused single-dispatch
kernel path (`chebyshev_solve_packed(backend="pallas_fused")`) is pinned
against the shared host scan at rtol 1e-9 and must be chunk-invariant
bit for bit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from conftest import cached_fmaps, cached_split
from repro.core import DeKRRConfig, DeKRRSolver, circulant
from repro.core.acceleration import (chebyshev_coefficients,
                                     chebyshev_scan, chebyshev_solve,
                                     chebyshev_solve_packed,
                                     power_iteration_mu_max,
                                     power_iteration_mu_min,
                                     rounds_to_tolerance)
from repro.dist import pack_problem, solve_batched, step_batched

TOL = dict(rtol=1e-9, atol=1e-12)
MU_MAX, MU_MIN = 0.9, -0.05


def _dense_problem(n=24, seed=0):
    """F(θ) = Mθ + b with a known eigendecomposition M = QΛQᵀ,
    spec(M) ⊂ [−0.05, 0.9] (a strictly sub-unit but sign-indefinite
    spectrum, like the DeKRR fixed-point map)."""
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.standard_normal((n, n)))
    eigs = np.linspace(MU_MIN, MU_MAX, n)
    m = q @ np.diag(eigs) @ q.T
    b = rng.standard_normal(n)
    theta_star = np.linalg.solve(np.eye(n) - m, b)
    return q, eigs, jnp.asarray(m), jnp.asarray(b), theta_star


def _cheb_t(k, x):
    """T_k(x) elementwise by the scalar recurrence."""
    t_prev, t = np.ones_like(x), np.asarray(x, np.float64)
    if k == 0:
        return t_prev
    for _ in range(k - 1):
        t_prev, t = t, 2.0 * x * t - t_prev
    return t


def _closed_form_iterate(q, eigs, theta_star, k):
    """θ_k = θ* + Q σ_k(Λ_A) Qᵀ (θ₀ − θ*) for θ₀ = 0, A = I − M."""
    a_lo, b_hi = 1.0 - MU_MAX, 1.0 - MU_MIN
    d0, c0 = (a_lo + b_hi) / 2.0, (b_hi - a_lo) / 2.0
    lam_a = 1.0 - eigs
    sigma = _cheb_t(k, (d0 - lam_a) / c0) / _cheb_t(
        k, np.full_like(lam_a, d0 / c0))
    return theta_star + q @ (sigma * (q.T @ (-theta_star)))


def _buggy_coefficients(mu_max, mu_min, num_iters):
    """The pre-fix table: generic β_k = (c·α_{k−1}/2)² applied at k = 1
    too, which evaluates to ¼(c/d)² instead of ½(c/d)²."""
    a_lo, b_hi = 1.0 - mu_max, 1.0 - mu_min
    d0, c0 = (a_lo + b_hi) / 2.0, (b_hi - a_lo) / 2.0
    alphas = np.empty(num_iters, np.float64)
    betas = np.empty(num_iters, np.float64)
    alpha_prev = None
    for k in range(num_iters):
        if k == 0:
            alpha, beta = 1.0 / d0, 0.0
        else:
            beta = (c0 * alpha_prev / 2.0) ** 2
            alpha = 1.0 / (d0 - beta / alpha_prev)
        alphas[k] = alpha
        betas[k] = beta
        alpha_prev = alpha
    return alphas, betas


# --------------------------------------------------------------------------
# Closed-form pin: the fixed recurrence IS Chebyshev; the buggy one is not
# --------------------------------------------------------------------------
@pytest.mark.parametrize("k", [1, 2, 3, 5, 8, 12])
def test_chebyshev_matches_dense_closed_form(k):
    q, eigs, m, b, theta_star = _dense_problem()
    theta = chebyshev_solve(lambda th: m @ th + b, jnp.zeros_like(b),
                            MU_MAX, MU_MIN, num_iters=k)
    expect = _closed_form_iterate(q, eigs, theta_star, k)
    np.testing.assert_allclose(np.asarray(theta), expect, **TOL)


def test_buggy_beta1_breaks_closed_form():
    # teeth for the pin above: ¼(c/d)² at k = 1 matches T₁ trivially but
    # diverges from the optimal polynomial from k = 2 on
    q, eigs, m, b, theta_star = _dense_problem()
    for k, should_match in ((1, True), (2, False), (5, False)):
        al, be = _buggy_coefficients(MU_MAX, MU_MIN, k)
        theta, _, _ = chebyshev_scan(lambda th: m @ th + b,
                                     jnp.zeros_like(b), jnp.asarray(al),
                                     jnp.asarray(be))
        expect = _closed_form_iterate(q, eigs, theta_star, k)
        close = np.allclose(np.asarray(theta), expect, **TOL)
        assert close == should_match, f"k={k}"


def test_beta1_coefficient_value():
    al, be = chebyshev_coefficients(0.9, 0.0, 3)
    a_lo, b_hi = 1.0 - 0.9, 1.0
    d0, c0 = (a_lo + b_hi) / 2.0, (b_hi - a_lo) / 2.0
    assert be[0] == 0.0 and al[0] == 1.0 / d0
    np.testing.assert_allclose(be[1], 0.5 * (c0 / d0) ** 2, rtol=1e-15)
    np.testing.assert_allclose(be[2], (c0 * al[1] / 2.0) ** 2, rtol=1e-15)


# --------------------------------------------------------------------------
# Packed-problem paths: fewer rounds than pre-fix, backend/chunk parity
# --------------------------------------------------------------------------
def _packed_problem():
    topo, dims = circulant(6, (1, 2)), [8, 10, 12, 8, 10, 12]
    j = topo.num_nodes
    _, train, _ = cached_split("air_quality", j, subsample=300, seed=0)
    fmaps = cached_fmaps("air_quality", j, tuple(dims), subsample=300,
                         seed=0)
    n = sum(t.num_samples for t in train)
    solver = DeKRRSolver(topo, fmaps, train,
                         DeKRRConfig(lam=1e-6, c_nei=0.02 * n))
    return pack_problem(solver)


def test_fixed_recurrence_needs_strictly_fewer_rounds():
    packed = _packed_problem()
    hi = power_iteration_mu_max(packed, iters=15)
    lo = power_iteration_mu_min(packed, hi, iters=15)
    theta_star = solve_batched(packed, 3000)
    tol, max_rounds = 1e-5, 800
    plain, cheb_fixed = rounds_to_tolerance(
        packed, theta_star, tol=tol, max_rounds=max_rounds, mu_max=hi,
        mu_min=lo)
    assert cheb_fixed < plain < max_rounds

    # emulate the pre-fix code exactly: Δ-form body driven by the
    # generic-β₁ table
    al, be = _buggy_coefficients(hi, lo, max_rounds)

    def body(carry, ab):
        theta, delta = carry
        alpha, beta = ab
        resid = step_batched(packed, theta) - theta
        delta = alpha * resid + beta * delta
        theta = theta + delta
        return (theta, delta), jnp.linalg.norm(theta - theta_star)

    z = jnp.zeros_like(packed.d)
    _, errs = lax.scan(body, (z, z), (jnp.asarray(al), jnp.asarray(be)))
    hit = np.asarray(errs) <= tol * float(jnp.linalg.norm(theta_star))
    cheb_old = int(np.argmax(hit)) + 1 if hit.any() else max_rounds
    assert cheb_fixed < cheb_old


def test_fused_chebyshev_matches_host_scan():
    packed = _packed_problem()
    hi = power_iteration_mu_max(packed, iters=15)
    lo = power_iteration_mu_min(packed, hi, iters=15)
    th_xla = chebyshev_solve_packed(packed, hi, lo, num_iters=30)
    th_pal = chebyshev_solve_packed(packed, hi, lo, num_iters=30,
                                    backend="pallas")
    th_fused = chebyshev_solve_packed(packed, hi, lo, num_iters=30,
                                      backend="pallas_fused")
    np.testing.assert_allclose(np.asarray(th_pal), np.asarray(th_xla),
                               **TOL)
    np.testing.assert_allclose(np.asarray(th_fused), np.asarray(th_xla),
                               **TOL)


def test_fused_chebyshev_chunk_invariant_bitwise():
    packed = _packed_problem()
    hi = power_iteration_mu_max(packed, iters=15)
    lo = power_iteration_mu_min(packed, hi, iters=15)
    fused = chebyshev_solve_packed(packed, hi, lo, num_iters=30,
                                   backend="pallas_fused")
    for chunk in (1, 7, 30, 64):
        chunked = chebyshev_solve_packed(packed, hi, lo, num_iters=30,
                                         backend="pallas_fused",
                                         chunk_rounds=chunk)
        np.testing.assert_array_equal(np.asarray(chunked),
                                      np.asarray(fused),
                                      err_msg=f"chunk_rounds={chunk}")


def test_chebyshev_solve_packed_rejects_bad_arguments():
    packed = _packed_problem()
    with pytest.raises(ValueError, match="backend"):
        chebyshev_solve_packed(packed, 0.9, backend="cuda_fused")
    with pytest.raises(ValueError, match="chunk_rounds"):
        chebyshev_solve_packed(packed, 0.9, chunk_rounds=0)
    zero = chebyshev_solve_packed(packed, 0.9, num_iters=0,
                                  backend="pallas_fused")
    assert not np.asarray(zero).any()
