"""Per-architecture smoke tests: REDUCED variants (≤2 periods of layers,
d_model ≤ 256, ≤4 experts) run one forward + one train step on CPU with
shape and no-NaN asserts; decode parity pins cache semantics to the full
forward. FULL configs are only shape-checked analytically (allocation-free)
— they are exercised via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_arch, list_archs
from repro.models.model import (Model, ModelConfig, SlotSpec,
                                active_param_count, analytic_param_count,
                                param_count)
from repro.train import AdamWConfig, make_train_step, train_state_init
from repro.train.step import lm_loss

ALL_ARCHS = list_archs()

# nominal sizes (±30%) from the assignment / model cards
NOMINAL_PARAMS = {
    "qwen1_5_0_5b": 0.62e9,          # 0.5b class (untied head included)
    "llava_next_mistral_7b": 7.2e9,
    "hubert_xlarge": 1.0e9,
    "granite_3_8b": 8.0e9,
    "smollm_135m": 0.135e9,
    "rwkv6_7b": 7.5e9,
    "qwen1_5_32b": 33e9,
    "deepseek_moe_16b": 16.4e9,
    "jamba_1_5_large_398b": 398e9,
    "phi3_5_moe_42b": 42e9,
}


def _smoke_batch(spec, cfg, key, batch=2, seq=32):
    kt, ke = jax.random.split(key)
    if spec.input_kind == "audio":
        return {
            "embeds": jax.random.normal(ke, (batch, seq, cfg.d_model),
                                        jnp.float32),
            "targets": jax.random.randint(kt, (batch, seq), 0,
                                          cfg.vocab_size),
            "loss_mask": (jax.random.uniform(ke, (batch, seq)) < 0.5)
            .astype(jnp.float32),   # HuBERT-style masked prediction
        }
    if spec.input_kind == "vlm":
        s_img = seq // 4
        return {
            "embeds": jax.random.normal(ke, (batch, s_img, cfg.d_model),
                                        jnp.float32),
            "tokens": jax.random.randint(kt, (batch, seq - s_img), 0,
                                         cfg.vocab_size),
            "targets": jax.random.randint(kt, (batch, seq - s_img), 0,
                                          cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size),
        "targets": jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_forward_shapes_and_no_nans(arch):
    spec = get_arch(arch)
    cfg = spec.config.reduced()
    assert cfg.d_model <= 512 and cfg.moe_num_experts <= 4
    assert cfg.num_layers <= 2 * spec.config.period
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(spec, cfg, jax.random.PRNGKey(1))
    logits, aux = model.forward(params, tokens=batch.get("tokens"),
                                embeds=batch.get("embeds"))
    s_total = (batch["tokens"].shape[1] if "tokens" in batch else 0) + \
        (batch["embeds"].shape[1] if "embeds" in batch else 0)
    assert logits.shape == (2, s_total, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    if cfg.moe_num_experts:
        assert "load_balance_loss" in aux
        assert float(aux["load_balance_loss"]) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_train_step(arch):
    spec = get_arch(arch)
    cfg = spec.config.reduced()
    opt = AdamWConfig(total_steps=10, warmup_steps=2)
    state = train_state_init(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt))
    batch = _smoke_batch(spec, cfg, jax.random.PRNGKey(1))
    before = float(jax.tree.leaves(state.params)[0].astype(jnp.float32).sum())
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    after = float(jax.tree.leaves(state.params)[0].astype(jnp.float32).sum())
    assert before != after, "params did not update"


@pytest.mark.parametrize("arch",
                         [a for a in ALL_ARCHS
                          if get_arch(a).supports_decode])
def test_decode_matches_forward(arch):
    """Teacher-forcing parity: step-by-step decode == full forward.
    MoE capacity factor is raised so no tokens drop (drops are the one
    legitimate train/decode divergence of dropping MoE)."""
    spec = get_arch(arch)
    cfg = dataclasses.replace(spec.config.reduced(),
                              moe_capacity_factor=8.0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    logits_full, _ = model.forward(params, tokens=toks)
    cache = model.init_cache(b, 32)
    dec = jax.jit(model.decode_step)
    for t in range(s):
        lg, cache = dec(params, cache, toks[:, t:t + 1],
                        jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, t]),
            rtol=2e-3, atol=2e-4,
            err_msg=f"{arch} decode diverges at t={t}")


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_matches_nominal_size(arch):
    cfg = get_arch(arch).config
    n = analytic_param_count(cfg)
    nominal = NOMINAL_PARAMS[arch]
    assert 0.7 * nominal < n < 1.3 * nominal, \
        f"{arch}: {n/1e9:.2f}B vs nominal {nominal/1e9:.2f}B"


def test_phi_moe_active_params_match_a6_6b():
    cfg = get_arch("phi3_5_moe_42b").config
    assert abs(active_param_count(cfg) / 1e9 - 6.6) < 0.7


def test_jamba_slot_pattern():
    cfg = get_arch("jamba_1_5_large_398b").config
    assert cfg.period == 8 and cfg.num_groups == 9
    mixers = [s.mixer for s in cfg.slots]
    assert mixers == ["attn"] + ["mamba"] * 7          # 1:7 interleave
    assert sum(s.ffn == "moe" for s in cfg.slots) == 4  # MoE every other


def test_shape_plan_skips():
    """Documented skips: encoder-only has no decode; dense archs run
    long_500k only through the sliding-window variant."""
    hubert = get_arch("hubert_xlarge")
    assert hubert.shape_plan("decode_32k") == "skip"
    assert hubert.shape_plan("long_500k") == "skip"
    assert hubert.shape_plan("train_4k") == "run"
    assert hubert.shape_plan("prefill_32k") == "run"

    assert get_arch("rwkv6_7b").shape_plan("long_500k") == "run"
    assert get_arch("jamba_1_5_large_398b").shape_plan("long_500k") == "run"
    for dense in ["qwen1_5_0_5b", "granite_3_8b", "qwen1_5_32b",
                  "smollm_135m", "llava_next_mistral_7b",
                  "deepseek_moe_16b", "phi3_5_moe_42b"]:
        assert get_arch(dense).shape_plan("long_500k") == "run-swa"


def test_sliding_window_variant_decode():
    """SWA ring-buffer decode: output must depend only on the last W
    tokens — parity against a full-attention model fed the same window."""
    base = get_arch("qwen1_5_0_5b").config.reduced()
    w = 8
    cfg_swa = dataclasses.replace(
        base, slots=(SlotSpec("swa", "dense"),), sliding_window=w,
        num_layers=2)
    model = Model(cfg_swa)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 20
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              base.vocab_size)
    # forward pass with window masking is the reference
    logits_full, _ = model.forward(params, tokens=toks)
    cache = model.init_cache(b, 32)
    dec = jax.jit(model.decode_step)
    for t in range(s):
        lg, cache = dec(params, cache, toks[:, t:t + 1],
                        jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, t]),
            rtol=2e-3, atol=2e-4, err_msg=f"swa decode diverges at t={t}")


def test_encoder_is_bidirectional():
    """HuBERT: flipping future frames must change past-frame logits."""
    cfg = get_arch("hubert_xlarge").config.reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    e = jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
    l1, _ = model.forward(params, embeds=e)
    e2 = e.at[:, -1].set(-e[:, -1])
    l2, _ = model.forward(params, embeds=e2)
    assert float(jnp.max(jnp.abs(l1[:, 0] - l2[:, 0]))) > 1e-6


def test_causal_lm_is_causal():
    """Flipping future tokens must NOT change past logits."""
    cfg = get_arch("qwen1_5_0_5b").config.reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                              cfg.vocab_size)
    l1, _ = model.forward(params, tokens=toks)
    toks2 = toks.at[:, -1].set((toks[:, -1] + 7) % cfg.vocab_size)
    l2, _ = model.forward(params, tokens=toks2)
    np.testing.assert_allclose(np.asarray(l1[:, :-1]),
                               np.asarray(l2[:, :-1]),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("arch", ["qwen1_5_0_5b", "granite_3_8b"])
def test_int8_kv_cache_decode_close_to_bf16(arch):
    """Serving int8 KV quantization (per-token, per-head scales): logits
    within ~5% relative of the unquantized cache path."""
    spec = get_arch(arch)
    base = spec.config.reduced()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              base.vocab_size)
    logits = {}
    for kvd in ("bfloat16", "int8"):
        cfg = dataclasses.replace(base, kv_cache_dtype=kvd)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        cache = model.init_cache(2, 16)
        dec = jax.jit(model.decode_step)
        outs = []
        for t in range(12):
            lg, cache = dec(params, cache, toks[:, t:t + 1],
                            jnp.asarray(t, jnp.int32))
            outs.append(lg)
        logits[kvd] = jnp.stack(outs)
    err = float(jnp.max(jnp.abs(logits["int8"] - logits["bfloat16"])))
    rel = err / float(jnp.max(jnp.abs(logits["bfloat16"])))
    assert rel < 0.05, rel
