"""Per-kernel allclose sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.ref import rff_features_ref, rff_gram_ref

SHAPES = [
    # (D, d, N)
    (8, 8, 128),
    (30, 13, 300),       # paper-sized: D_j=30, air_quality d=13
    (64, 77, 1024),      # twitter d=77
    (100, 8, 2000),      # houses
    (128, 148, 512),     # wave d=148
    (17, 5, 100),        # deliberately unaligned everything
    (256, 96, 4096),     # toms_hardware, large-N streaming
]


@pytest.mark.parametrize("d_feat,d_in,n", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_rff_gram_matches_oracle(d_feat, d_in, n, dtype):
    key = jax.random.PRNGKey(d_feat + d_in + n)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    omega = jax.random.normal(k1, (d_feat, d_in), dtype)
    bias = jax.random.uniform(k2, (d_feat,), dtype, maxval=2 * np.pi)
    x = jax.random.uniform(k3, (d_in, n), dtype)
    y = jax.random.normal(k4, (n,), dtype)
    scale = float(np.sqrt(2.0 / d_feat))

    g, zy = ops.rff_gram(omega, bias, x, y, scale=scale, interpret=True)
    g_ref, zy_ref = rff_gram_ref(omega, bias, x, y, scale=scale)

    tol = dict(rtol=2e-5, atol=2e-5) if dtype == jnp.float32 \
        else dict(rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), **tol)
    np.testing.assert_allclose(np.asarray(zy), np.asarray(zy_ref), **tol)


@pytest.mark.parametrize("d_feat,d_in,n", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_rff_features_matches_oracle(d_feat, d_in, n, dtype):
    key = jax.random.PRNGKey(7 * d_feat + d_in + n)
    k1, k2, k3 = jax.random.split(key, 3)
    omega = jax.random.normal(k1, (d_feat, d_in), dtype)
    bias = jax.random.uniform(k2, (d_feat,), dtype, maxval=2 * np.pi)
    x = jax.random.uniform(k3, (d_in, n), dtype)
    scale = float(np.sqrt(2.0 / d_feat))

    z = ops.rff_features(omega, bias, x, scale=scale, interpret=True)
    z_ref = rff_features_ref(omega, bias, x, scale=scale)
    tol = dict(rtol=2e-6, atol=2e-6) if dtype == jnp.float32 \
        else dict(rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), **tol)


@given(d_feat=st.integers(1, 48), d_in=st.integers(1, 32),
       n=st.integers(1, 700), seed=st.integers(0, 2**16))
@settings(max_examples=20, deadline=None)
def test_rff_gram_property_random_shapes(d_feat, d_in, n, seed):
    """Property: the fused kernel equals the oracle for arbitrary shapes
    (padding/masking exactness), and G is symmetric PSD."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    omega = jax.random.normal(k1, (d_feat, d_in))
    bias = jax.random.uniform(k2, (d_feat,), maxval=2 * np.pi)
    x = jax.random.uniform(k3, (d_in, n))
    y = jax.random.normal(k4, (n,))
    scale = float(np.sqrt(2.0 / d_feat))

    g, zy = ops.rff_gram(omega, bias, x, y, scale=scale, interpret=True)
    g_ref, zy_ref = rff_gram_ref(omega, bias, x, y, scale=scale)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(np.asarray(zy), np.asarray(zy_ref),
                               rtol=1e-10, atol=1e-10)
    evals = np.linalg.eigvalsh(np.asarray(g))
    assert evals.min() > -1e-8


def test_gram_fn_for_solver_integration():
    """The fused kernel slots into DeKRRSolver as its gram_fn."""
    from repro.core import (DeKRRConfig, DeKRRSolver, circulant,
                            select_features)
    from repro.data.synthetic import (make_dataset, partition,
                                      train_test_split_nodes)

    ds = make_dataset("houses", subsample=400, seed=0)
    topo = circulant(4, (1,))
    train, _ = train_test_split_nodes(partition(ds, 4, mode="iid"))
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    fmaps = [select_features(keys[j], ds.dim, 10, 1.0, train[j].x,
                             train[j].y, method="energy", candidate_ratio=5)
             for j in range(4)]
    n = sum(t.num_samples for t in train)
    ref = DeKRRSolver(topo, fmaps, train,
                      DeKRRConfig(lam=1e-6, c_nei=0.02 * n))
    fused = DeKRRSolver(topo, fmaps, train,
                        DeKRRConfig(lam=1e-6, c_nei=0.02 * n),
                        gram_fn=ops.gram_fn_for_solver)
    th_ref = ref.solve_exact().theta
    th_fused = fused.solve_exact().theta
    for a, b in zip(th_ref, th_fused):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
