"""Distributed runtimes for the paper's solvers.

`repro.core` holds the ragged, auditably paper-faithful reference
implementations; this package holds their production counterparts — packed
batched execution and SPMD nodes-on-devices execution — pinned to the
reference by parity tests. See `repro.dist.dekrr_spmd` for the design.
"""
from repro.dist.dekrr_spmd import (PackedProblem, comm_bytes_per_round,
                                   make_spmd_solver, pack_problem, pack_theta,
                                   solve_batched, step_batched, unpack_theta)

__all__ = [
    "PackedProblem",
    "comm_bytes_per_round",
    "make_spmd_solver",
    "pack_problem",
    "pack_theta",
    "solve_batched",
    "step_batched",
    "unpack_theta",
]
