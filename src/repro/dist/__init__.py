"""Distributed runtimes for the paper's solvers.

`repro.core` holds the ragged, auditably paper-faithful reference
implementations; this package holds their production counterparts — packed
batched execution (with a ``backend="xla" | "pallas" | "pallas_fused"``
switch between the vmapped-GEMM round, the fused per-round
`repro.kernels.dekrr_step` kernel, and the multi-round
`repro.kernels.dekrr_solve` kernel that keeps θ VMEM-resident across the
whole solve), SPMD nodes-on-devices execution, and the asynchronous
randomized-activation gossip runtime (`repro.dist.async_gossip`, COKE-style
per-edge staleness + communication censoring) — all pinned to the
references by parity tests.

Backend × sync-mode support (how each combination executes, and how it is
pinned):

  ======================  ==================  =================================
  runtime                 synchronous Jacobi  async gossip (activation mask)
  ======================  ==================  =================================
  batched, xla            exact (vmap round)  exact (masked vmap round)
  batched, pallas         exact (round        exact (activation-masked round
                          kernel)             kernel; buffers as θ-table rows)
  batched, pallas_fused   exact (multi-round  exact (fused async chain: the
                          fused kernel)       [R, J] mask table + censor
                                              thresholds prefetch into one
                                              multi-round kernel — one
                                              dispatch per chunk; only tol>0
                                              keeps the per-round path:
                                              return_stats / return_trace
                                              read the kernel's on-device
                                              trace blocks, staying fused)
  accelerated (Chebyshev  exact (shared (α,β)-table `lax.scan` on xla /
  `repro.core.            per-round kernel on pallas;
  acceleration`)          `chebyshev_solve_packed(backend="pallas_fused")`
                          runs the whole schedule — θ and the search
                          direction p VMEM-resident — in ONE kernel
                          dispatch per chunk, pinned to the host scan at
                          rtol 1e-9)
  SPMD, xla               exact               exact (shared-key masks
                                              replicated; dense collectives
                                              every round)
  SPMD, pallas(_fused)    exact (per-round    exact (masked per-round kernel;
                          kernel; no cross-   no cross-round fusion over the
                          device fusion)      collective)
  solve-level tol stop    supported           supported (per-round freeze;
                          (batched + SPMD     all-silent rounds never latch;
                          via fused pmax)     batched + SPMD via fused pmax)
  warm start (theta0)     supported           supported (θ0 also seeds the
                          (batched + SPMD)    censor reference + staleness
                                              buffers, batched + SPMD)
  ======================  ==================  =================================

"exact" = agrees with the corresponding reference at rtol 1e-9 under x64,
and bit-for-bit with the synchronous path of the same backend when the
async schedule degenerates to it (prob = 1, bernoulli, censoring off).

Multi-output targets (Dy > 1): EVERY cell above also supports a trailing
output axis. Nodes with labels [N_j, Dy] pack into `d`/θ of shape
[J, D_max, Dy] (the Eq. 17 feature-space auxiliaries are label-free, so
G/S/P are unchanged), and every runtime — batched rounds, fused kernels,
async gossip (censor thresholds max over features AND outputs), Chebyshev
acceleration, SPMD collectives, tol stops (max|Δθ| over both axes), warm
starts — carries the axis through as extra fused row blocks. Dy-batched
solves match a per-output scalar loop at rtol 1e-9 on every backend
(tests/test_multioutput.py), dispatch counts are UNCHANGED (the Dy axis
folds into kernel rows, never into extra launches — `repro.analysis`
pins the Dy=3 entry points to the same J002 contract), and a Dy=1
problem takes the scalar code paths verbatim.

Streaming modes (`repro.stream`, warm-start × backend × sync/async): the
online runtime folds minibatches into the Eq. 17 auxiliaries by rank-b
Woodbury updates and re-enters the SAME solvers above — every cell of the
table is reachable with a carried θ0:

  ==========================  =============================================
  streaming entry point       executes as
  ==========================  =============================================
  StreamingDeKRR.solve,       `solve_batched(packed, R, theta0=θ,
  sync (any backend)          backend=..., tol=...)` — fused-kernel rounds
                              included ("pallas"/"pallas_fused")
  StreamingDeKRR.solve,       `async_solve_batched(..., theta0=θ)` with the
  async (any backend)         per-solve folded PRNG key; same tol freeze
  SPMD deployment             `make_spmd_solver(...)(packed, R, theta0=θ,
                              tol=...)` / `make_async_spmd_solver` — warm
                              start and tol stop added for exactly this
  ==========================  =============================================

After a per-node DDRF feature refresh changes `node_dims`, carried θ must
be re-padded (`repro.stream.repad_theta`); `pack_theta`/`unpack_theta`
validate against the live layout and reject stale iterates loudly.

`pack_problem` builds the Eq. 17 auxiliaries batched (one vmapped program
over the padded [J, D_max, …] layout). See `repro.dist.dekrr_spmd` for the
design and memory layout, `repro.dist.async_gossip` for the async round
and its delivery semantics.
"""
from repro.dist.async_gossip import (AsyncGossipState, AsyncGossipStats,
                                     AsyncRoundInfo, async_solve_batched,
                                     async_step_batched, init_async_state,
                                     make_async_spmd_solver)
from repro.dist.dekrr_spmd import (PackedProblem, comm_bytes_per_round,
                                   make_spmd_solver, pack_problem, pack_theta,
                                   solve_batched, step_batched, unpack_theta)

__all__ = [
    "AsyncGossipState",
    "AsyncGossipStats",
    "AsyncRoundInfo",
    "PackedProblem",
    "async_solve_batched",
    "async_step_batched",
    "comm_bytes_per_round",
    "init_async_state",
    "make_async_spmd_solver",
    "make_spmd_solver",
    "pack_problem",
    "pack_theta",
    "solve_batched",
    "step_batched",
    "unpack_theta",
]
