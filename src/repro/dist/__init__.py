"""Distributed runtimes for the paper's solvers.

`repro.core` holds the ragged, auditably paper-faithful reference
implementations; this package holds their production counterparts — packed
batched execution (with a ``backend="xla" | "pallas" | "pallas_fused"``
switch between the vmapped-GEMM round, the fused per-round
`repro.kernels.dekrr_step` kernel, and the multi-round
`repro.kernels.dekrr_solve` kernel that keeps θ VMEM-resident across the
whole solve) and SPMD nodes-on-devices execution — pinned to the reference
by parity tests.
`pack_problem` builds the Eq. 17 auxiliaries batched (one vmapped program
over the padded [J, D_max, …] layout). See `repro.dist.dekrr_spmd` for the
design and memory layout.
"""
from repro.dist.dekrr_spmd import (PackedProblem, comm_bytes_per_round,
                                   make_spmd_solver, pack_problem, pack_theta,
                                   solve_batched, step_batched, unpack_theta)

__all__ = [
    "PackedProblem",
    "comm_bytes_per_round",
    "make_spmd_solver",
    "pack_problem",
    "pack_theta",
    "solve_batched",
    "step_batched",
    "unpack_theta",
]
