"""Asynchronous gossip DeKRR runtime — packed batched and SPMD layers.

`repro.core.async_gossip` defines the semantics (randomized activation,
per-edge staleness buffers, COKE communication censoring) and holds the
ragged ground-truth solver; this module is the production counterpart on
the packed [J, D_max] layout, in the same two shapes as the synchronous
runtime it extends:

1. **Batched single-host execution** (`async_step_batched` /
   `async_solve_batched`): the async round over all nodes at once. The
   Eq. 19 arithmetic routes through `repro.dist.step_batched` with the
   two async extras it grew for this runtime — ``active`` (inactive nodes
   pass θ through untouched; jnp.where on the XLA path, the
   activation-masked `repro.kernels.dekrr_step` variant on the Pallas
   paths) and ``nbr_theta`` (the [J, K, D_max] staleness buffers instead
   of a fresh ``theta[nbr_idx]`` gather). ``backend="pallas_fused"`` runs
   the whole precomputed schedule — [R, J] activation table + [R] censor
   thresholds, scalar-prefetched like the slot tables — through the fused
   async chain kernel (`repro.kernels.ops.dekrr_async_solve`): one
   pallas_call per ``chunk_rounds`` chunk (default: one for the whole
   solve), bit-for-bit the scanned per-round masked kernel. Only one
   accounting mode keeps the per-round path on "pallas_fused":
   ``tol > 0`` (the per-round convergence freeze is host-orchestrated).
   ``return_stats=True`` and ``return_trace=True`` stay fused — the
   chain kernel emits per-(round, node) residual/broadcast trace blocks
   in the same dispatch, and the wire counts (broadcasts, deliveries,
   bytes) are derived from them in plain XLA (`repro.obs` convergence
   traces; this fixed a silent fused→per-round fallback that older
   ``return_stats=True`` calls paid for).

2. **SPMD nodes-on-devices execution** (`make_async_spmd_solver`): one
   node per device, same mesh/mode contract as `make_spmd_solver`. The
   activation masks are precomputed from the shared PRNG key and passed in
   *replicated*, so every device samples the identical schedule without
   coordination and the ppermute/all_gather exchanges stay collective-safe
   — every round runs the dense collective (a lock-step simulation of the
   asynchronous protocol), and the masks gate what lands in the buffers,
   not whether the collective runs. Devices exchange their post-censoring
   ``sent`` vectors: under "bernoulli" gossip a receive buffer always
   equals the sender's last-broadcast θ, so overwriting it with the
   exchanged ``sent`` every round is value-identical to conditional
   delivery and needs no flag traffic; "edge" gossip delivers along the
   sampled edge only, so the broadcast flag rides along as a 1-element
   ppermute/all_gather.

With ``AsyncGossipConfig()`` defaults (prob = 1, bernoulli, no censoring)
every layer reproduces the synchronous runtime bit-for-bit on its own
backend — pinned, along with the cross-layer rtol-1e-9 conformance matrix
over {circulant, star, ER, complete, J=1} × p × censoring, by
`tests/test_async_gossip.py`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec

from repro.analysis.vmem import check_index_table
from repro.core.async_gossip import (AsyncGossipConfig, activation_masks,
                                     censor_schedule, edges_from_slot_table)
from repro.dist.dekrr_spmd import (PackedProblem, _check_backend,
                                   _check_spmd_problem, _make_exchange,
                                   _node_step, _MODES, _PALLAS_BACKENDS,
                                   shard_map, step_batched)
from repro.obs.trace import AsyncSolveTrace

__all__ = [
    "AsyncGossipState",
    "AsyncGossipStats",
    "AsyncRoundInfo",
    "async_solve_batched",
    "async_step_batched",
    "init_async_state",
    "make_async_spmd_solver",
]

# Default tol-check chunking for the async solve: the per-round freeze
# makes rounds-run independent of the chunk size, so the chunk only sets
# how much work one while_loop iteration dispatches.
_ASYNC_CHUNK_DEFAULT = 16


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AsyncGossipState:
    """Per-round state of the packed async gossip iteration.

    theta:   [J, D_max]    current iterates (padding exactly zero).
    sent:    [J, D_max]    last θ each node actually broadcast (the COKE
                           censor reference).
    buffers: [J, K, D_max] per-edge receive buffers: buffers[j, k] is the
                           last θ node j *received* from the neighbor in
                           slot k — under "edge" gossip this can be staler
                           than that neighbor's own ``sent``.

    Multi-output packings append a trailing Dy axis to all three
    (θ/sent [J, D_max, Dy], buffers [J, K, D_max, Dy]); the censor
    decision then takes max|Δθ| over features AND outputs, so one
    broadcast carries all Dy columns or none.
    """

    theta: jax.Array
    sent: jax.Array
    buffers: jax.Array

    def tree_flatten(self):
        return (self.theta, self.sent, self.buffers), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


class AsyncRoundInfo(NamedTuple):
    """What one async round put on the wire (for stats and property tests).

    bcast:    [J] bool — nodes that transmitted this round (active and
              uncensored).
    received: [J, K] bool — receive-buffer slots refreshed by a fresh
              broadcast this round.
    """

    bcast: jax.Array
    received: jax.Array


class AsyncGossipStats(NamedTuple):
    """Cumulative communication accounting of an async solve (int32)."""

    rounds: jax.Array
    broadcasts: jax.Array
    deliveries: jax.Array


def init_async_state(packed: PackedProblem,
                     theta0: jax.Array | None = None) -> AsyncGossipState:
    """Round-0 state: every buffer holds its neighbor's θ0 and every node
    'sent' θ0 — exactly the synchronous iteration's view of round 0."""
    if theta0 is None:
        theta0 = jnp.zeros_like(packed.d)
    return AsyncGossipState(theta=theta0, sent=theta0,
                            buffers=theta0[packed.nbr_idx])


def _packed_edges(packed: PackedProblem) -> np.ndarray:
    """Canonical edge list for `gossip="edge"` sampling, derived host-side
    from the slot table (bit-identical to `repro.core.edge_list` on the
    originating topology — tested). Endpoints are bounds-checked against
    [0, J): the edge draw indexes the activation mask with them, and the
    mask feeds the scalar-prefetched activation table of the Pallas round
    kernel (no hardware bounds check there)."""
    edges = edges_from_slot_table(np.asarray(packed.nbr_idx),
                                  np.asarray(packed.nbr_mask))
    check_index_table("edges", edges, packed.num_nodes)
    return edges


def _check_mask_table(name: str, masks, num_rounds: int,
                      num_nodes: int) -> None:
    """Activation-mask schedules must be exactly [R, J] (or [J] for a
    single round): the Pallas round kernel scalar-prefetches the per-round
    [J] row, and a mis-shaped table would be silently broadcast or
    truncated by downstream indexing instead of erroring."""
    shape = tuple(masks.shape)
    want = (num_rounds, num_nodes) if num_rounds >= 0 else (num_nodes,)
    if shape != want:
        raise ValueError(
            f"{name}: activation-mask table has shape {list(shape)}, "
            f"expected {list(want)} — one row per round, one column per "
            f"node (the masked round kernel scalar-prefetches rows of "
            f"this table)")


def _async_round(packed: PackedProblem, state: AsyncGossipState,
                 active: jax.Array, threshold: jax.Array, *,
                 gossip: str, censored: bool,
                 backend: str) -> tuple[AsyncGossipState, AsyncRoundInfo]:
    """One async gossip round in the order every layer shares: update
    (against the staleness buffers) → censor → deliver."""
    new = step_batched(packed, state.theta, backend=backend,
                       active=active, nbr_theta=state.buffers)
    if censored:
        # per-node max|Δθ| over features AND (for multi-output) outputs
        delta = jnp.max(jnp.abs(new - state.sent),
                        axis=tuple(range(1, new.ndim)))      # [J]
        bcast = active & (delta > threshold)
    else:
        bcast = active
    live = packed.nbr_mask != 0
    received = live & bcast[packed.nbr_idx]                  # [J, K]
    if gossip == "edge":
        received = received & active[:, None]  # pairwise: endpoint only
    sent = jnp.where(jnp.reshape(bcast, (-1,) + (1,) * (new.ndim - 1)),
                     new, state.sent)
    buffers = jnp.where(
        jnp.reshape(received, received.shape + (1,) * (new.ndim - 1)),
        new[packed.nbr_idx], state.buffers)
    return (AsyncGossipState(theta=new, sent=sent, buffers=buffers),
            AsyncRoundInfo(bcast=bcast, received=received))


@partial(jax.jit, static_argnames=("gossip", "censored", "backend"))
def async_step_batched(packed: PackedProblem, state: AsyncGossipState,
                       active: jax.Array, threshold: jax.Array = 0.0, *,
                       gossip: str = "bernoulli", censored: bool = False,
                       backend: str = "xla"
                       ) -> tuple[AsyncGossipState, AsyncRoundInfo]:
    """One async gossip round over all nodes, from an explicit activation
    mask ([J] bool) and censor threshold (scalar; ignored unless
    ``censored``). The building block `async_solve_batched` scans — public
    so tests can drive rounds one at a time and inspect the state/wire
    traffic between them.
    """
    _check_backend(backend)
    _check_mask_table("async_step_batched", active, -1, packed.num_nodes)
    return _async_round(packed, state, active,
                        jnp.asarray(threshold, packed.d.dtype),
                        gossip=gossip, censored=censored, backend=backend)


def _count(mask: jax.Array) -> jax.Array:
    return jnp.sum(mask, dtype=jnp.int32)


def _wire_series(packed: PackedProblem, masks: jax.Array,
                 bcast_rj: jax.Array, *, gossip: str):
    """Per-round wire counts from per-(round, node) broadcast flags, in
    plain XLA (no extra kernel dispatch): [R] active / broadcasts /
    deliveries / bytes. Reproduces `_async_round`'s delivery rule —
    ``received = live & bcast[nbr_idx]`` (edge gossip additionally gates
    on the *receiver* being an endpoint) — so summing the series matches
    the per-round path's `AsyncGossipStats` exactly."""
    bc = bcast_rj != 0                                        # [R, J]
    active = jnp.sum(masks != 0, axis=1, dtype=jnp.int32)
    broadcasts = jnp.sum(bc, axis=1, dtype=jnp.int32)
    live = packed.nbr_mask != 0                               # [J, K]
    recv = live[None] & bc[:, packed.nbr_idx]                 # [R, J, K]
    if gossip == "edge":
        recv = recv & (masks != 0)[:, :, None]
    deliveries = jnp.sum(recv, axis=(1, 2), dtype=jnp.int32)
    per_bcast = (packed.max_features * packed.num_outputs
                 * np.dtype(packed.d.dtype).itemsize)
    return (active, broadcasts, deliveries,
            broadcasts * jnp.asarray(per_bcast, jnp.int32))


def _async_solve_fused(packed, state, masks, thresholds, *, gossip,
                       censored, chunk_rounds, trace=False):
    """tol = 0 fused chain: the whole precomputed schedule (or each
    `chunk_rounds` slice of it) runs as one async-chain pallas_call. The
    kernel returns the full `AsyncGossipState`, so chunk boundaries chain
    bit-exactly and the result is chunk-size bit-invariant. With
    ``trace`` the same dispatches also fill the per-(round, node)
    residual ([R, J] float) and broadcast-flag ([R, J] int32) blocks —
    returned alongside θ, concatenated across chunks."""
    from repro.kernels.ops import dekrr_async_solve

    num_iters = int(masks.shape[0])
    j_nodes = int(masks.shape[1])

    def call(st, mask_tab, thr_tab):
        outs = dekrr_async_solve(
            packed.g, packed.d, packed.s, packed.p, st.theta, st.sent,
            st.buffers, packed.nbr_idx, packed.nbr_mask, mask_tab,
            thr_tab, gossip=gossip, censored=censored, trace=trace)
        st = AsyncGossipState(theta=outs[0], sent=outs[1], buffers=outs[2])
        return st, (outs[3], outs[4]) if trace else None

    if chunk_rounds is None or chunk_rounds >= num_iters:
        state, tr = call(state, masks, thresholds)
        return (state.theta,) + tr if trace else state.theta

    n_full, rem = divmod(num_iters, chunk_rounds)
    cut = n_full * chunk_rounds

    def chunk_fn(st, xs):
        mask_tab, thr_tab = xs
        return call(st, mask_tab, thr_tab)

    state, trs = lax.scan(
        chunk_fn, state,
        (masks[:cut].reshape(n_full, chunk_rounds, masks.shape[1]),
         thresholds[:cut].reshape(n_full, chunk_rounds)))
    tr_rem = None
    if rem:
        state, tr_rem = call(state, masks[cut:], thresholds[cut:])
    if not trace:
        return state.theta
    res, bc = (t.reshape(-1, j_nodes) for t in trs)
    if tr_rem is not None:
        res = jnp.concatenate([res, tr_rem[0]])
        bc = jnp.concatenate([bc, tr_rem[1]])
    return state.theta, res, bc


@partial(jax.jit, static_argnames=("num_iters", "gossip", "censored",
                                   "backend", "tol", "chunk_rounds",
                                   "return_rounds", "return_stats",
                                   "return_trace"))
def _async_solve_impl(packed, masks, thresholds, theta0, *, num_iters,
                      gossip, censored, backend, tol, chunk_rounds,
                      return_rounds, return_stats, return_trace):
    state0 = init_async_state(packed, theta0)
    zero = jnp.asarray(0, jnp.int32)
    need_wire = return_stats or return_trace

    def finish(theta, rounds, nb, nd, series):
        out = (theta,)
        if return_rounds:
            out = out + (rounds,)
        if return_stats:
            out = out + (AsyncGossipStats(rounds=rounds, broadcasts=nb,
                                          deliveries=nd),)
        if return_trace:
            residuals, active, bcasts, delivs, wire_bytes = series
            out = out + (AsyncSolveTrace(residuals=residuals, active=active,
                                         broadcasts=bcasts,
                                         deliveries=delivs,
                                         bytes=wire_bytes),)
        return out[0] if len(out) == 1 else out

    if tol == 0.0 and backend == "pallas_fused":
        # Fused async chain: the whole schedule (or each chunk_rounds
        # slice) is one pallas_call. Only tol > 0 keeps the per-round
        # path (host-orchestrated convergence freeze): stats and traces
        # come from the kernel's own [R, J] residual/broadcast trace
        # blocks, with the wire series derived in plain XLA.
        rounds = jnp.asarray(num_iters, jnp.int32)
        if not need_wire:
            theta = _async_solve_fused(packed, state0, masks, thresholds,
                                       gossip=gossip, censored=censored,
                                       chunk_rounds=chunk_rounds)
            return finish(theta, rounds, None, None, None)
        theta, res, bc = _async_solve_fused(
            packed, state0, masks, thresholds, gossip=gossip,
            censored=censored, chunk_rounds=chunk_rounds, trace=True)
        active, bcasts, delivs, wire_bytes = _wire_series(
            packed, masks, bc, gossip=gossip)
        residuals = jnp.max(res, axis=1) if num_iters else \
            jnp.zeros((0,), theta.dtype)
        return finish(theta, rounds, jnp.sum(bcasts), jnp.sum(delivs),
                      (residuals, active, bcasts, delivs, wire_bytes))

    if tol == 0.0:
        def round_fn(carry, xs):
            state, nb, nd = carry
            mask_r, thr_r = xs
            new_state, info = _async_round(packed, state, mask_r, thr_r,
                                           gossip=gossip, censored=censored,
                                           backend=backend)
            ys = None
            if return_trace:
                ys = (jnp.max(jnp.abs(new_state.theta - state.theta)),
                      info.bcast.astype(jnp.int32))
            return (new_state, nb + _count(info.bcast),
                    nd + _count(info.received)), ys

        (state, nb, nd), ys = lax.scan(round_fn, (state0, zero, zero),
                                       (masks, thresholds))
        rounds = jnp.asarray(num_iters, jnp.int32)
        series = None
        if return_trace:
            residuals, bc = ys
            series = (residuals,) + _wire_series(packed, masks, bc,
                                                 gossip=gossip)
        return finish(state.theta, rounds, nb, nd, series)
    else:
        # tol > 0: per-round convergence freeze inside chunked execution.
        # Convergence is evaluated after EVERY round (not at chunk
        # boundaries) and a converged solve passes subsequent rounds
        # through unchanged, so rounds-run and θ are independent of
        # chunk_rounds — the chunk only sets how much work one while_loop
        # iteration dispatches (regression-tested).
        chunk = chunk_rounds if chunk_rounds is not None \
            else _ASYNC_CHUNK_DEFAULT
        chunk = min(chunk, max(num_iters, 1))
        n_chunks = -(-num_iters // chunk)
        pad = n_chunks * chunk - num_iters
        masks_p = jnp.pad(masks, ((0, pad), (0, 0)))
        thresholds_p = jnp.pad(thresholds, (0, pad))
        # Preallocated [num_iters] trace buffers, written in place at the
        # absolute round index inside the existing scan: frozen (and
        # never-run) rounds keep their 0, which is what makes tol-path
        # traces chunk-invariant. mode="drop" ignores the padded rounds'
        # out-of-range indices.
        buf0 = (jnp.zeros((num_iters,), state0.theta.dtype),
                jnp.zeros((num_iters,), jnp.int32),
                jnp.zeros((num_iters,), jnp.int32)) if return_trace else ()

        def round_fn(carry, xs):
            state, rounds, converged, nb, nd = carry[:5]
            mask_r, thr_r, r_abs = xs
            new_state, info = _async_round(packed, state, mask_r, thr_r,
                                           gossip=gossip,
                                           censored=censored,
                                           backend=backend)
            delta = jnp.max(jnp.abs(new_state.theta - state.theta))
            take = jnp.logical_not(converged) & (r_abs < num_iters)
            state = jax.tree_util.tree_map(
                lambda a, b: jnp.where(take, a, b), new_state, state)
            rounds = rounds + take.astype(jnp.int32)
            b = jnp.where(take, _count(info.bcast), 0)
            dv = jnp.where(take, _count(info.received), 0)
            nb = nb + b
            nd = nd + dv
            # A round the Bernoulli draw left all-silent has Δθ ≡ 0 by
            # construction — that is the schedule idling, not the
            # iteration converging, so it must not latch the stop.
            converged = converged | (take & jnp.any(mask_r)
                                     & (delta < tol))
            out = (state, rounds, converged, nb, nd)
            if return_trace:
                rbuf, bbuf, dbuf = carry[5:]
                out = out + (
                    rbuf.at[r_abs].set(jnp.where(take, delta, 0.0),
                                       mode="drop"),
                    bbuf.at[r_abs].set(b, mode="drop"),
                    dbuf.at[r_abs].set(dv, mode="drop"))
            return out, None

        def cond_fn(carry):
            converged, chunk_idx = carry[2], carry[-1]
            return jnp.logical_not(converged) & (chunk_idx < n_chunks)

        def body_fn(carry):
            chunk_idx = carry[-1]
            start = chunk_idx * chunk
            xs = (lax.dynamic_slice_in_dim(masks_p, start, chunk, 0),
                  lax.dynamic_slice_in_dim(thresholds_p, start, chunk, 0),
                  start + jnp.arange(chunk))
            carry, _ = lax.scan(round_fn, carry[:-1], xs)
            return carry + (chunk_idx + 1,)

        carry = lax.while_loop(
            cond_fn, body_fn,
            (state0, zero, jnp.asarray(False), zero, zero) + buf0 + (zero,))
        state, rounds, _, nb, nd = carry[:5]
        series = None
        if return_trace:
            residuals, bc_rounds, dv_rounds = carry[5:8]
            # broadcast-flag [R, J] is not materialized on this path (the
            # counts are), so active/bytes come from the schedule and the
            # per-round broadcast counts; frozen rounds record 0 across
            # every field.
            ran = (jnp.arange(num_iters, dtype=jnp.int32)
                   < rounds).astype(jnp.int32)
            active = jnp.sum(masks != 0, axis=1, dtype=jnp.int32) * ran
            per_bcast = (packed.max_features * packed.num_outputs
                         * np.dtype(packed.d.dtype).itemsize)
            series = (residuals, active, bc_rounds, dv_rounds,
                      bc_rounds * jnp.asarray(per_bcast, jnp.int32))
        return finish(state.theta, rounds, nb, nd, series)


def async_solve_batched(packed: PackedProblem, num_iters: int,
                        key: jax.Array, *,
                        config: AsyncGossipConfig = AsyncGossipConfig(),
                        theta0: jax.Array | None = None,
                        backend: str = "xla", tol: float = 0.0,
                        chunk_rounds: int | None = None,
                        return_rounds: bool = False,
                        return_stats: bool = False,
                        return_trace: bool = False):
    """Run up to `num_iters` async gossip rounds from θ = 0 (or theta0).

    The whole activation/censor schedule is precomputed from `key` via the
    shared `repro.core.async_gossip` helpers (round r uses
    ``fold_in(key, r)``), then the solve runs on the chosen ``backend``:
    "xla" and "pallas" scan the per-round (activation-masked) round;
    "pallas_fused" feeds the schedule through scalar prefetch and runs
    ALL rounds in one async-chain pallas_call — or one per
    ``chunk_rounds`` chunk, bit-invariant to the chunking — falling back
    to the scanned per-round masked kernel only for the one accounting
    mode the kernel cannot host (``tol > 0``; see module docstring —
    ``return_stats``/``return_trace`` used to force this fallback too,
    but now read the fused kernel's own trace blocks).

    ``tol > 0`` enables early stopping on max|Δθ| < tol, evaluated after
    every round on device — except rounds the activation draw left
    all-silent, whose Δθ ≡ 0 says nothing about convergence (a
    non-trivial hazard at small p·J). Once a round converges, later
    rounds pass through unchanged, so the reported round count and θ are
    independent of ``chunk_rounds`` (which only sets the while_loop
    dispatch granularity). ``return_rounds`` appends the rounds-run int32
    scalar; ``return_stats`` appends an `AsyncGossipStats` with the
    cumulative broadcast/delivery counts for communication accounting;
    ``return_trace`` appends a `repro.obs.trace.AsyncSolveTrace` of
    per-round [num_iters] device buffers — max|Δθ| residuals plus the
    scheduled/broadcast/delivery/bytes wire series (frozen and never-run
    rounds record 0; sums reproduce the stats exactly). Appended outputs
    keep that order: ``(theta[, rounds][, stats][, trace])``. Traces are
    filled inside the existing scan/while/kernel round structure — no
    host callback, no extra kernel dispatch (pinned by
    ``tests/test_obs.py``).

    With ``config.is_synchronous`` this reproduces
    ``solve_batched(packed, num_iters, backend=backend)`` bit-for-bit.
    """
    _check_backend(backend)
    if tol < 0:
        raise ValueError(f"tol must be >= 0, got {tol}")
    if chunk_rounds is not None and chunk_rounds < 1:
        raise ValueError(f"chunk_rounds must be >= 1, got {chunk_rounds}")
    num_iters = int(num_iters)
    edges = _packed_edges(packed) if config.gossip == "edge" else None
    masks = activation_masks(key, num_iters, packed.num_nodes,
                             prob=config.prob, gossip=config.gossip,
                             edges=edges)
    _check_mask_table("async_solve_batched", masks, num_iters,
                      packed.num_nodes)
    thresholds = censor_schedule(config.censor_tau, config.censor_decay,
                                 num_iters, dtype=packed.d.dtype)
    return _async_solve_impl(
        packed, masks, thresholds, theta0, num_iters=num_iters,
        gossip=config.gossip, censored=config.censored, backend=backend,
        tol=float(tol), chunk_rounds=chunk_rounds,
        return_rounds=return_rounds, return_stats=return_stats,
        return_trace=return_trace)


# --------------------------------------------------------------------------
# SPMD nodes-on-devices async runtime
# --------------------------------------------------------------------------
def make_async_spmd_solver(mesh: Mesh, axis_name: str,
                           mode: str = "ppermute", backend: str = "xla"):
    """Build ``run(packed, num_iters, key, config) -> [J, D_max]`` on a
    1-D node mesh — the async counterpart of `make_spmd_solver`.

    Same placement contract (device index along `axis_name` IS the node
    id) and the same exchange modes. The full [R, J] activation-mask
    schedule and [R] censor thresholds are sampled host-side from the
    shared `key` and enter the shard_map *replicated*, so every device
    walks the identical schedule and the per-slot ppermute ring shifts /
    all_gather stay collective-safe: the dense collective runs every
    round, and the masks decide what lands in the staleness buffers.

    Per round each device exchanges its post-censoring ``sent`` vector.
    Under "bernoulli" gossip that alone reproduces conditional delivery
    (a buffer always equals the sender's last broadcast, so the overwrite
    is value-identical — no flag traffic); under "edge" gossip the
    broadcast flag travels with the payload as a 1-element exchange and
    gates delivery to the sampled edge. ``backend`` picks the per-device
    arithmetic: "xla" runs `_node_step` + jnp.where, "pallas"/
    "pallas_fused" run the activation-masked round kernel on the local
    ``[own θ; buffers]`` table.

    With ``config.is_synchronous`` the returned runner reproduces
    ``make_spmd_solver(mesh, axis_name, mode, backend)`` bit-for-bit.

    The returned runner is ``run(packed, num_iters, key, config=...,
    theta0=None, tol=0.0, return_rounds=False)``: ``theta0`` warm-starts
    the iteration exactly like `init_async_state(packed, theta0)` (own θ,
    censor reference, and staleness buffers all seeded from it — the
    buffers via one pre-scan exchange); ``tol > 0`` enables the same
    per-round early stop as `async_solve_batched` — a fused `lax.pmax`
    of the per-device max|Δθ| gives every device the network-wide delta,
    so the per-device while_loops agree on the trip count and exit
    together after the converging round (a genuine stop: no further
    compute or exchange runs, unlike the batched solve's chunk-internal
    freeze), and all-silent rounds never latch the stop (their Δθ ≡ 0
    is the schedule idling, not convergence); θ and the round count
    match the batched async solve exactly. ``return_rounds=True``
    appends the rounds-run int32 scalar.

    ``return_trace=True`` appends a `repro.obs.trace.AsyncSolveTrace`
    with NO extra collective: each device records its LOCAL per-round
    max|Δθ| and its own broadcast flag into scan outputs / while-loop
    carry buffers, and the network-wide residual series (max over the
    device axis) plus the wire series (broadcasts, deliveries from the
    slot tables, bytes) are reduced *outside* the shard_map in plain
    XLA — matching the batched async trace at rtol 1e-9 and its wire
    counts exactly. Appended outputs keep the order
    ``(theta[, rounds][, trace])``.
    """
    if mode not in _MODES:
        raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
    _check_backend(backend)
    if axis_name not in mesh.shape:
        raise ValueError(f"mesh has no axis {axis_name!r}: {mesh.shape}")

    spec = PartitionSpec(axis_name)
    rep = PartitionSpec()

    @partial(jax.jit, static_argnames=("offsets", "gossip", "censored",
                                       "tol", "return_trace"))
    def _run(g, d, s, p, nbr_idx, nbr_mask, masks, thresholds, theta0, *,
             offsets, gossip, censored, tol, return_trace=False):
        j_nodes = d.shape[0]
        k_slots = p.shape[1]

        def node_program(g, d, s, p, nbr_idx, nbr_mask, masks, thresholds,
                         theta0):
            me = lax.axis_index(axis_name)
            live = nbr_mask[0] != 0                          # [K]
            # the sync solver's θ exchange, verbatim (shared helper)
            exchange = _make_exchange(mode, axis_name, j_nodes, offsets,
                                      nbr_idx)

            def one_round(theta, sent, buffers, mask_r, thr_r):
                active = mask_r[me]
                if backend in _PALLAS_BACKENDS:
                    from repro.kernels.ops import dekrr_step

                    # local θ table: row 0 = own θ, rows 1…K = buffers
                    table = jnp.concatenate([theta, buffers], axis=0)
                    local_idx = jnp.arange(
                        1, k_slots + 1, dtype=jnp.int32)[None]
                    new = dekrr_step(
                        g, d, s, p, table, local_idx,
                        jnp.zeros((1,), jnp.int32), nbr_mask,
                        jnp.reshape(active, (1,)))
                else:
                    new = _node_step(g[0], d[0], s[0], p[0], theta[0],
                                     buffers, nbr_mask[0])[None]
                    new = jnp.where(active, new, theta)
                if censored:
                    delta = jnp.max(jnp.abs(new - sent))
                    bcast = active & (delta > thr_r)
                else:
                    bcast = active
                sent_new = jnp.where(bcast, new, sent)
                payload = exchange(sent_new)                 # [K, D]
                if gossip == "edge":
                    flag = exchange(jnp.reshape(bcast, (1, 1))
                                    .astype(d.dtype))[:, 0] != 0
                    gate = active & mask_r[nbr_idx[0]] & flag & live
                else:
                    gate = live
                buffers = jnp.where(
                    jnp.reshape(gate, (-1,) + (1,) * (payload.ndim - 1)),
                    payload, buffers)
                return new, sent_new, buffers, bcast

            # round-0 staleness view: every buffer holds its neighbor's
            # θ0 (init_async_state semantics — masked slots carry the
            # node's own θ0, exactly like theta0[nbr_idx]), fetched with
            # one pre-scan exchange; exact zeros on the cold start
            buffers0 = exchange(theta0)

            if tol == 0.0:
                def round_fn(carry, xs):
                    theta, sent, buffers = carry
                    mask_r, thr_r = xs
                    new, sent_new, buf_new, bcast = one_round(
                        theta, sent, buffers, mask_r, thr_r)
                    # LOCAL per-round trace: own max|Δθ| + own broadcast
                    # flag — no collective; reduced outside the shard_map
                    ys = (jnp.max(jnp.abs(new - theta)),
                          bcast.astype(jnp.int32)) if return_trace \
                        else None
                    return (new, sent_new, buf_new), ys

                (theta, _, _), ys = lax.scan(
                    round_fn, (theta0, theta0, buffers0),
                    (masks, thresholds))
                rounds = jnp.full((1,), masks.shape[0], jnp.int32)
                if return_trace:
                    return theta, rounds, ys[0][None], ys[1][None]
                return theta, rounds

            # genuine early exit (matches the sync SPMD solver): the
            # pmax-fused delta keeps the per-device while_loop trip
            # counts identical, so the in-body collectives stay matched
            # and a converged solve stops paying for the budget's tail.
            def cond_fn(carry):
                converged, rounds = carry[3], carry[4]
                return jnp.logical_not(converged) & (rounds < masks.shape[0])

            def body_fn(carry):
                theta, sent, buffers, converged, rounds = carry[:5]
                mask_r = lax.dynamic_index_in_dim(masks, rounds, 0,
                                                  keepdims=False)
                thr_r = lax.dynamic_index_in_dim(thresholds, rounds, 0,
                                                 keepdims=False)
                new, sent_new, buf_new, bcast = one_round(
                    theta, sent, buffers, mask_r, thr_r)
                delta_local = jnp.max(jnp.abs(new - theta))
                delta = lax.pmax(delta_local, axis_name)
                # all-silent rounds have Δθ ≡ 0 by construction — the
                # schedule idling, not convergence (same latch rule as
                # the batched async solve)
                converged = converged | (jnp.any(mask_r) & (delta < tol))
                out = (new, sent_new, buf_new, converged, rounds + 1)
                if return_trace:
                    rbuf, bbuf = carry[5:]
                    out = out + (rbuf.at[rounds].set(delta_local),
                                 bbuf.at[rounds].set(
                                     bcast.astype(jnp.int32)))
                return out

            num_iters = masks.shape[0]
            buf0 = (jnp.zeros((num_iters,), theta0.dtype),
                    jnp.zeros((num_iters,), jnp.int32)) \
                if return_trace else ()
            carry = lax.while_loop(
                cond_fn, body_fn,
                (theta0, theta0, buffers0, jnp.asarray(False),
                 jnp.asarray(0, jnp.int32)) + buf0)
            theta, rounds = carry[0], jnp.reshape(carry[4], (1,))
            if return_trace:
                return theta, rounds, carry[5][None], carry[6][None]
            return theta, rounds

        out_spec = (spec, spec, spec, spec) if return_trace \
            else (spec, spec)
        sharded = shard_map(
            node_program, mesh=mesh,
            in_specs=(spec, spec, spec, spec, spec, spec, rep, rep, spec),
            out_specs=out_spec,
            # tol path: jax 0.4.x's scan rule rejects the pmax-derived
            # `converged` carry (replication changes across the carry);
            # the error text itself prescribes check_rep=False there.
            check_rep=(backend not in _PALLAS_BACKENDS and tol == 0.0),
        )
        return sharded(g, d, s, p, nbr_idx, nbr_mask, masks, thresholds,
                       theta0)

    def run(packed: PackedProblem, num_iters: int, key: jax.Array,
            config: AsyncGossipConfig = AsyncGossipConfig(),
            theta0: jax.Array | None = None, *, tol: float = 0.0,
            return_rounds: bool = False, return_trace: bool = False):
        _check_spmd_problem(packed, mesh, axis_name, mode)
        if tol < 0:
            raise ValueError(f"tol must be >= 0, got {tol}")
        num_iters = int(num_iters)
        edges = _packed_edges(packed) if config.gossip == "edge" else None
        masks = activation_masks(key, num_iters, packed.num_nodes,
                                 prob=config.prob, gossip=config.gossip,
                                 edges=edges)
        _check_mask_table("make_async_spmd_solver", masks, num_iters,
                          packed.num_nodes)
        thresholds = censor_schedule(
            config.censor_tau, config.censor_decay, num_iters,
            dtype=packed.d.dtype)
        if theta0 is None:
            theta0 = jnp.zeros_like(packed.d)
        outs = _run(packed.g, packed.d, packed.s, packed.p,
                    packed.nbr_idx, packed.nbr_mask, masks,
                    thresholds, theta0, offsets=packed.offsets,
                    gossip=config.gossip, censored=config.censored,
                    tol=float(tol), return_trace=return_trace)
        theta, rounds = outs[0], outs[1]
        out = (theta,)
        if return_rounds:
            out = out + (jnp.max(rounds),)
        if return_trace:
            # per-device [J, R] local residuals / broadcast flags →
            # network-wide series, reduced outside the shard_map
            res, bc = outs[2], outs[3]
            active, bcasts, delivs, wire_bytes = _wire_series(
                packed, masks, bc.T, gossip=config.gossip)
            ran = (jnp.arange(num_iters, dtype=jnp.int32)
                   < jnp.max(rounds)).astype(jnp.int32)
            out = out + (AsyncSolveTrace(
                residuals=jnp.max(res, axis=0), active=active * ran,
                broadcasts=bcasts, deliveries=delivs, bytes=wire_bytes),)
        return out[0] if len(out) == 1 else out

    return run
