"""SPMD nodes-on-devices runtime for the Eq. 19 DeKRR-DDRF iteration.

The reference solver (`repro.core.dekrr.DeKRRSolver`) is deliberately ragged:
a Python loop over nodes, each holding auxiliaries of its own size D_j. That
is the right shape for auditing Algorithm 1 against the paper, and exactly
the wrong shape for hardware. This module is the production counterpart, in
three layers that are pinned to the reference by parity tests
(`tests/test_dekrr_spmd.py`, rtol 1e-9 under x64):

1. **Packing** (`pack_problem`): pad every per-node auxiliary to the network
   maximum D_max and stack over nodes —

     G:  [J, D_max, D_max]      (Eq. 17 inverse, applied)
     d:  [J, D_max]             ((1/N) Z_jj Y_j)
     S:  [J, D_max, D_max]      (2 c̃_self Z_jj Z_jjᵀ)
     P:  [J, K, D_max, D_max]   (neighbor couplings, K slots per node)

   plus `theta_mask` ([J, D_max], 1.0 on live coordinates) and a neighbor
   slot table `nbr_idx`/`nbr_mask` ([J, K]). Because padding is *zero* in the
   matrices (not merely masked), one packed round maps padded inputs to
   padded outputs exactly: row i ≥ D_j of G_j is identically zero, so
   θ_j^{k+1} = G_j(…) has exact 0.0 in every padded coordinate. No masking
   is needed inside the iteration — the algebra is closed over the padding.

   The default `method="batched"` *computes* the Eq. 17 auxiliaries itself,
   directly in the padded [J, D_max, …] layout: one vmapped program
   (featurize → Gram blocks → coupling products → batched inverse) over
   numpy-staged padded inputs, traced once per problem shape regardless of
   J. The Z Zᵀ Gram blocks can optionally be routed through the fused Pallas
   streaming kernel (`repro.kernels.rff_gram`, ``gram_backend="pallas"``,
   the TPU default). `method="aux"` is the legacy path that copies the
   solver's ragged reference auxiliaries (a per-node Python loop) — kept
   for gram_fn-customized solvers and as the reference the batched build is
   regression-tested against.

2. **Batched single-host execution** (`step_batched` / `solve_batched`):
   the Eq. 19 round over all nodes at once, and the full solve over
   rounds. Three backends run the identical arithmetic:

     * ``backend="xla"``  — one `vmap` of `_node_step` over the node axis;
       XLA fuses it into a handful of batched GEMMs (gather of the [J, K,
       D_max] neighbor-θ tensor materialized between them); the solve is
       a `lax.scan` of that round.
     * ``backend="pallas"`` — the fused round kernel
       (`repro.kernels.dekrr_step`): grid over nodes, per step the [D_max,
       D_max] G/S/P blocks stream HBM→VMEM while the θ table stays
       VMEM-resident; the neighbor gather runs inside the kernel via the
       scalar-prefetched slot table. The solve is still a `lax.scan`, one
       kernel dispatch per round. Interpret-mode on CPU, compiled on
       TPU; pinned to the XLA path and the ragged reference at rtol 1e-9
       under x64 by `tests/test_kernels_dekrr_step.py`.
     * ``backend="pallas_fused"`` — the multi-round solve kernel
       (`repro.kernels.dekrr_solve`): the whole scan moves INSIDE one
       pallas_call with grid (rounds, nodes); two VMEM θ tables alternate
       by round parity so θ never round-trips HBM between rounds and the
       per-round dispatch overhead (the dominant cost at the paper's
       ρ(M) ≈ 0.95–0.999 round counts) disappears. With ``tol > 0`` the
       solve runs round-chunked — θ surfaces every `chunk_rounds` rounds
       for the on-device convergence check. Pinned by
       `tests/test_kernels_dekrr_solve.py`.

   Every beyond-paper acceleration (Chebyshev semi-iteration in
   `repro.core.acceleration`, its power-iteration spectral estimates)
   builds on this round via the same ``backend`` switch.

3. **SPMD nodes-on-devices execution** (`make_spmd_solver`): the same round
   under `shard_map` on a 1-D device mesh, one node per device, exchanging
   only θ per round — the paper's communication pattern made literal:

     * ``mode="ppermute"``: for circulant topologies C_J(s_1, s_2, …) the
       neighbor slots are laid out ``[(+s_1), (−s_1), (+s_2), (−s_2), …]``
       and each round issues one `lax.ppermute` ring shift per slot. This
       is the TPU/ICI-native exchange: Σ_j |N_j| · D_max words per round,
       nearest-neighbor only, no gather of the full network state.
     * ``mode="allgather"``: `lax.all_gather` of θ followed by a local
       slot-table gather. Works for arbitrary connected graphs (star,
       Erdős–Rényi, …) at the cost of J·(J−1)·D_max words per round.

   Both modes accept the same ``backend`` switch: "xla" runs `_node_step`
   per device; "pallas" runs the fused kernel on the device-local [1 + K,
   D_max] θ table ``[own θ; received neighbor θs]`` (the kernel's
   `self_idx` indirection exists exactly so the J-node and 1-node-per-
   device layouts share one kernel). Parity across all paths holds at near
   machine precision.

`comm_bytes_per_round` exposes the §II-C cost model for both modes so
benchmarks can report paper-comparable communication totals.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec

try:  # jax >= 0.5 promotes shard_map out of experimental
    from jax import shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map

from repro.analysis.vmem import check_index_table
from repro.obs.spans import span
from repro.obs.trace import SolveTrace

__all__ = [
    "PackedProblem",
    "pack_problem",
    "pack_theta",
    "unpack_theta",
    "step_batched",
    "solve_batched",
    "make_spmd_solver",
    "comm_bytes_per_round",
]


# --------------------------------------------------------------------------
# Packed problem container
# --------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedProblem:
    """Eq. 17 auxiliaries padded to [J, D_max, …] with a neighbor slot table.

    Attributes (array leaves; J nodes, K neighbor slots, D_max features):
      g:          [J, D_max, D_max]     padded G_j (Eq. 17 inverse, applied).
      d:          [J, D_max]            padded d_j — or [J, D_max, Dy] for
                                        multi-output targets; θ and every
                                        stage label share d's shape, with
                                        the trailing output axis riding
                                        through the iteration unchanged
                                        (the Eq. 17 matrices are
                                        features-only).
      s:          [J, D_max, D_max]     padded S_j.
      p:          [J, K, D_max, D_max]  padded P_{j, nbr_idx[j, k]}; the
                                        [k] slice is the zero matrix for
                                        masked (padding) slots.
      theta_mask: [J, D_max]            1.0 where coordinate i < D_j.
      nbr_idx:    [J, K] int32          global node id feeding slot k of
                                        node j (j itself on padded slots).
      nbr_mask:   [J, K]                1.0 on live slots.

    Static (hashable aux data — part of the jit cache key):
      offsets:    circulant shift set (s_1, s_2, …) when the slot table is
                  laid out in ppermute order [(+s_1), (−s_1), (+s_2), …];
                  None for the generic padded-adjacency layout.
      node_dims:  per-node feature counts (D_1, …, D_J) for unpacking.
      num_edges_directed: live (directed) slot count Σ_j |N_j|, recorded
                  from the NumPy-side nbr_mask at packing time so the
                  §II-C comm cost model never has to read it back off
                  the device (`comm_bytes_per_round`).
    """

    g: jax.Array
    d: jax.Array
    s: jax.Array
    p: jax.Array
    theta_mask: jax.Array
    nbr_idx: jax.Array
    nbr_mask: jax.Array
    offsets: tuple[int, ...] | None = None
    node_dims: tuple[int, ...] | None = None
    num_edges_directed: int | None = None

    # -- pytree plumbing (offsets / node_dims / edge count are static) ------
    def tree_flatten(self):
        children = (self.g, self.d, self.s, self.p, self.theta_mask,
                    self.nbr_idx, self.nbr_mask)
        return children, (self.offsets, self.node_dims,
                          self.num_edges_directed)

    @classmethod
    def tree_unflatten(cls, aux, children):
        offsets, node_dims, num_edges_directed = aux
        g, d, s, p, theta_mask, nbr_idx, nbr_mask = children
        return cls(g=g, d=d, s=s, p=p, theta_mask=theta_mask,
                   nbr_idx=nbr_idx, nbr_mask=nbr_mask,
                   offsets=offsets, node_dims=node_dims,
                   num_edges_directed=num_edges_directed)

    @property
    def num_nodes(self) -> int:
        return self.d.shape[0]

    @property
    def max_features(self) -> int:
        return self.d.shape[1]

    @property
    def num_slots(self) -> int:
        return self.nbr_idx.shape[1]

    @property
    def num_outputs(self) -> int:
        """Dy — trailing output width (1 for scalar-target packings)."""
        return self.d.shape[2] if self.d.ndim == 3 else 1


def _circulant_slot_table(
    offsets: Sequence[int], num_nodes: int
) -> np.ndarray:
    """Slot table in ppermute order [(+s_1), (−s_1), (+s_2), (−s_2), …]."""
    idx = np.zeros((num_nodes, 2 * len(offsets)), dtype=np.int32)
    for m, s in enumerate(offsets):
        for j in range(num_nodes):
            idx[j, 2 * m] = (j + s) % num_nodes
            idx[j, 2 * m + 1] = (j - s) % num_nodes
    return idx


def _validate_slot_table(nbr_idx, nbr_mask, num_nodes: int) -> int:
    """Static validation of a NumPy-staged slot table; returns the live
    directed-edge count Σ_j |N_j|.

    The Pallas kernels read `nbr_idx` through scalar prefetch, which has
    no hardware bounds check — an out-of-range entry silently gathers an
    arbitrary θ-table row. Every entry (padded slots carry an in-range
    self index by construction) must lie in [0, J).
    """
    idx = np.asarray(nbr_idx)
    mask = np.asarray(nbr_mask)
    if idx.shape != mask.shape:
        raise ValueError(
            f"slot table shape mismatch: nbr_idx {idx.shape} vs "
            f"nbr_mask {mask.shape}")
    check_index_table("nbr_idx", idx, num_nodes)
    return int(np.count_nonzero(mask))


def _slot_table(solver):
    """(nbr_idx [J, K] int32, nbr_mask [J, K] float, offsets | None).

    Circulant topologies get the ppermute slot layout (and `offsets`
    recorded) whenever every node's ±s neighbors are distinct, i.e. the
    uniform degree equals 2·|offsets|; anything else — star, Erdős–Rényi,
    or a circulant with an s = J/2 self-paired shift — falls back to the
    generic padded adjacency table from `Topology.neighbor_table()`.
    """
    topo = solver.topology
    dtype = np.asarray(solver.data[0].x).dtype
    offsets = topo.circulant_offsets
    if offsets is not None and topo.max_degree == 2 * len(offsets):
        nbr_idx = _circulant_slot_table(offsets, topo.num_nodes)
        nbr_mask = np.ones(nbr_idx.shape, dtype=dtype)
        return nbr_idx, nbr_mask, tuple(int(s) for s in offsets)
    nbr_idx, live = topo.neighbor_table()
    return nbr_idx, live.astype(dtype), None


_PACK_METHODS = ("batched", "aux")


def pack_problem(solver, *, method: str = "batched",
                 gram_backend: str | None = None) -> PackedProblem:
    """Build a `PackedProblem` from a `DeKRRSolver`.

    ``method="batched"`` (default) computes the Eq. 17 auxiliaries directly
    in the padded layout: one vmapped featurize→Gram→inverse program over
    numpy-staged [J, …] inputs, traced once per problem shape — no per-node
    Python iteration over traced computation, so packing scales to large J
    (construct the solver with ``build_aux=False`` to skip the ragged
    reference build entirely). ``gram_backend`` picks how the Z Zᵀ blocks
    are computed: "xla" (batched GEMM) or "pallas" (the fused streaming
    `repro.kernels.rff_gram` kernel; default on TPU, cos_bias maps only).

    ``method="aux"`` copies the solver's ragged reference auxiliaries
    (`solver.aux`, the per-node loop) — bit-identical to the reference, and
    the only path that honors a custom ``gram_fn`` or mixed feature kinds.
    """
    if method not in _PACK_METHODS:
        raise ValueError(f"method must be one of {_PACK_METHODS}, "
                         f"got {method!r}")
    if gram_backend not in (None, "xla", "pallas"):
        raise ValueError(f"unknown gram_backend {gram_backend!r}")
    kinds = {fm.kind for fm in solver.feature_maps}
    has_bags = any(nd.bags is not None for nd in solver.data)
    if method == "batched" and (
            len(kinds) > 1                       # mixed cos_sin/cos_bias
            or getattr(solver, "_gram_fn", None) is not None
            or has_bags):
        reason = ("the solver has a custom gram_fn"
                  if getattr(solver, "_gram_fn", None) is not None
                  else "the solver has aggregate-observation (bagged) "
                       "nodes, whose Agg operator only the ragged build "
                       "applies"
                  if has_bags
                  else f"the solver mixes feature kinds {sorted(kinds)}")
        if gram_backend == "pallas":
            raise ValueError(
                f"pack_problem(gram_backend='pallas') is impossible here: "
                f"{reason}, which only the ragged method='aux' build "
                f"honors — and the aux path computes its Gram blocks "
                f"through the solver, ignoring gram_backend. Drop "
                f"gram_backend or use a uniform cos_bias solver without "
                f"gram_fn.")
        warnings.warn(
            f"pack_problem(method='batched') downgraded to method='aux': "
            f"{reason}. The aux build runs a per-node Python loop over "
            f"traced computation (re-traces with J) — expect it to be "
            f"slow at scale.", UserWarning, stacklevel=2)
        method = "aux"          # only the ragged build honors those
    if method == "aux":
        if gram_backend == "pallas":
            raise ValueError(
                "pack_problem(method='aux') copies the solver's ragged "
                "reference auxiliaries and ignores gram_backend — "
                "gram_backend='pallas' would silently not be honored. "
                "Use method='batched' for the Pallas streaming Gram path.")
        with span("pack_problem", nodes=solver.J, method="aux"):
            return _pack_problem_from_aux(solver)
    with span("pack_problem", nodes=solver.J, method="batched"):
        staged = _stage_packed_inputs(solver, gram_backend=gram_backend)
        return _finish_packed(staged, _build_packed_aux(**staged))


def _pack_problem_from_aux(solver) -> PackedProblem:
    """Legacy packing: per-node Python loop copying `solver.aux` (ragged)."""
    j_nodes = solver.J
    dims = tuple(fm.num_features for fm in solver.feature_maps)
    d_max = max(dims)
    dtype = np.asarray(solver.aux.d[0]).dtype
    nbr_idx, nbr_mask, offsets = _slot_table(solver)
    num_edges = _validate_slot_table(nbr_idx, nbr_mask, j_nodes)
    k_slots = nbr_idx.shape[1]

    g = np.zeros((j_nodes, d_max, d_max), dtype=dtype)
    # d_j is [D_j] or [D_j, Dy]; the packed stage labels carry the same
    # trailing output axis.
    out_tail = np.asarray(solver.aux.d[0]).shape[1:]
    d = np.zeros((j_nodes, d_max) + out_tail, dtype=dtype)
    s = np.zeros((j_nodes, d_max, d_max), dtype=dtype)
    p = np.zeros((j_nodes, k_slots, d_max, d_max), dtype=dtype)
    theta_mask = np.zeros((j_nodes, d_max), dtype=dtype)

    for j in range(j_nodes):
        dj = dims[j]
        g[j, :dj, :dj] = np.asarray(solver.aux.g[j])
        d[j, :dj] = np.asarray(solver.aux.d[j])
        s[j, :dj, :dj] = np.asarray(solver.aux.s[j])
        theta_mask[j, :dj] = 1.0
        for k in range(k_slots):
            if not nbr_mask[j, k]:
                continue
            nb = int(nbr_idx[j, k])
            pjp = np.asarray(solver.aux.p[j][nb])      # [D_j, D_nb]
            p[j, k, :pjp.shape[0], :pjp.shape[1]] = pjp

    return PackedProblem(
        g=jnp.asarray(g), d=jnp.asarray(d), s=jnp.asarray(s),
        p=jnp.asarray(p), theta_mask=jnp.asarray(theta_mask),
        nbr_idx=jnp.asarray(nbr_idx), nbr_mask=jnp.asarray(nbr_mask),
        offsets=offsets, node_dims=dims, num_edges_directed=num_edges,
    )


# --------------------------------------------------------------------------
# Batched Eq. 17 aux build (default pack_problem path)
# --------------------------------------------------------------------------
# Number of times the batched builder has been *traced* (not called) — the
# regression test asserts this does not grow with J or with repeat packing.
_PACK_TRACE_COUNT = 0


def pack_trace_count() -> int:
    return _PACK_TRACE_COUNT


def _stage_feature_maps(fmaps, dtype) -> dict:
    """Numpy-stage a uniform-kind feature-map list into padded [J, …]
    arrays: omega [J, F_max, d], bias [J, F_max], feat_idx [J, D_max]
    (row map from raw featurize space — size F_max or 2·F_max — into the
    packed feature space: identity for cos_bias; for cos_sin node j's
    live rows are [0, F_j) ∪ [F_max, F_max + F_j) made contiguous),
    feat_mask [J, D_max], and the per-node scale (√(2/F_j) or 1/√F_j).

    Shared by `pack_problem`'s batched build and `repro.stream` — the
    stream's rtol-1e-9 parity contract depends on bit-identical staging,
    so there is exactly one copy of these conventions.
    """
    kinds = {fm.kind for fm in fmaps}
    if len(kinds) > 1:
        raise ValueError(
            f"feature-map staging requires a uniform kind across nodes "
            f"(got {sorted(kinds)}) — mixed kinds are only supported by "
            f"the ragged pack_problem(method='aux') path")
    kind = fmaps[0].kind
    j_nodes = len(fmaps)
    dim_in = fmaps[0].omega.shape[1]
    freqs = np.array([fm.num_frequencies for fm in fmaps])
    dims = np.array([fm.num_features for fm in fmaps])
    f_max, d_max = int(freqs.max()), int(dims.max())

    omega = np.zeros((j_nodes, f_max, dim_in), dtype=dtype)
    bias = np.zeros((j_nodes, f_max), dtype=dtype)
    for j, fm in enumerate(fmaps):
        omega[j, :freqs[j]] = np.asarray(fm.omega)
        if fm.bias is not None:
            bias[j, :freqs[j]] = np.asarray(fm.bias)
    feat_mask = (np.arange(d_max)[None, :] < dims[:, None]).astype(dtype)
    if kind == "cos_bias":
        feat_idx = np.broadcast_to(np.arange(d_max, dtype=np.int32),
                                   (j_nodes, d_max)).copy()
        scale = np.sqrt(2.0 / freqs).astype(dtype)
    else:
        feat_idx = np.zeros((j_nodes, d_max), dtype=np.int32)
        for j, fj in enumerate(freqs):
            feat_idx[j, :2 * fj] = np.concatenate(
                [np.arange(fj), f_max + np.arange(fj)])
        scale = (1.0 / np.sqrt(freqs)).astype(dtype)
    return dict(omega=omega, bias=bias, feat_idx=feat_idx,
                feat_mask=feat_mask, scale=scale, kind=kind,
                node_dims=tuple(int(v) for v in dims))


def _stage_packed_inputs(solver, *, gram_backend: str | None) -> dict:
    """Numpy-stage padded [J, …] inputs for the batched Eq. 17 build.

    All cross-node gathering (neighbor Ω/b/X/masks by slot) happens here
    with vectorized fancy indexing, so the traced builder is a pure vmap
    over the leading node axis — which is what makes the per-node batch-of-1
    replay in the regression test bit-identical to the batched call.
    """
    if gram_backend is None:
        gram_backend = "pallas" if jax.default_backend() == "tpu" else "xla"
    j_nodes = solver.J
    dtype = np.asarray(solver.data[0].x).dtype

    maps = _stage_feature_maps(solver.feature_maps, dtype)
    kind = maps["kind"]
    omega, bias = maps["omega"], maps["bias"]
    feat_idx, feat_mask = maps["feat_idx"], maps["feat_mask"]
    scale = maps["scale"]
    sizes = np.array([nd.num_samples for nd in solver.data])
    n_max = int(sizes.max())
    dim_in = solver.data[0].x.shape[0]

    x = np.zeros((j_nodes, dim_in, n_max), dtype=dtype)
    dy = solver.data[0].num_outputs if solver.data[0].y.ndim > 1 else None
    y = np.zeros((j_nodes, n_max) if dy is None else (j_nodes, n_max, dy),
                 dtype=dtype)
    for j, nd in enumerate(solver.data):
        x[j, :, :sizes[j]] = np.asarray(nd.x)
        if dy is None:
            y[j, :sizes[j]] = np.asarray(nd.y).reshape(-1)
        else:
            y[j, :sizes[j]] = np.asarray(nd.y)
    col_mask = (np.arange(n_max)[None, :] < sizes[:, None]).astype(dtype)

    ct_self, ct_nei = solver.coupling_coefficients()
    degs = solver.topology.degrees.astype(dtype)
    nbr_idx, nbr_mask, offsets = _slot_table(solver)

    gather = lambda a: a[nbr_idx]            # [J, K, …] by slot table
    staged = dict(
        omega=omega, bias=bias, x=x, y=y,
        col_mask=col_mask, feat_mask=feat_mask, feat_idx=feat_idx,
        scale=scale,
        omega_n=gather(omega), bias_n=gather(bias), x_n=gather(x),
        col_mask_n=gather(col_mask), feat_mask_n=gather(feat_mask),
        feat_idx_n=gather(feat_idx), scale_n=gather(scale),
        ct_self=ct_self.astype(dtype), ct_nei=ct_nei.astype(dtype),
        ct_nei_n=gather(ct_nei.astype(dtype)),
        degree=degs, nbr_mask=nbr_mask.astype(dtype),
        lam_over_j=np.full((j_nodes,),
                           solver.config.lam / solver.J, dtype=dtype),
        n_total=np.full((j_nodes,), float(solver.N), dtype=dtype),
        kind=kind,
    )
    if gram_backend == "pallas" and kind == "cos_bias" and j_nodes > 0:
        staged.update(_pallas_gram_blocks(staged))
    # bookkeeping for _finish_packed (not builder inputs)
    staged["_meta"] = (maps["node_dims"], nbr_idx, offsets)
    return staged


def _pallas_gram_blocks(staged: dict) -> dict:
    """Route the Eq. 17 Z Zᵀ blocks through the fused streaming Pallas
    kernel (`repro.kernels.ops.rff_gram_batched`), unit-scale frequency
    space: gram_jj/zy for every node and Gram(Z_{j,p}) for every slot.
    Per-node √(2/D_j) scaling and feature masking happen in `_node_aux`.
    """
    from repro.kernels.ops import rff_gram_batched

    omega, bias = staged["omega"], staged["bias"]
    x, y, cm = staged["x"], staged["y"], staged["col_mask"]
    j_nodes, k_slots = staged["nbr_mask"].shape
    # The streaming kernel's zy accumulator is scalar-target only; for
    # multi-output ([J, n_max, Dy]) y the label term is formed in
    # `_node_aux` from the packed features instead, and the kernel only
    # supplies the Gram blocks.
    y_kernel = y if y.ndim == 2 else np.zeros(y.shape[:2], x.dtype)
    graw, zyraw = rff_gram_batched(
        jnp.asarray(omega), jnp.asarray(bias), jnp.asarray(x),
        jnp.asarray(y_kernel), jnp.asarray(cm))
    f_max, dim_in = omega.shape[1:]
    if k_slots == 0:
        gcross = np.zeros((j_nodes, 0, f_max, f_max), x.dtype)
        return dict(gram_raw=np.asarray(graw), zy_raw=np.asarray(zyraw),
                    gram_cross_raw=gcross)
    # Z_{j,p}: node j's map on each slot-neighbor's data, flattened (j, k)
    om_rep = np.broadcast_to(omega[:, None], (j_nodes, k_slots) +
                             omega.shape[1:]).reshape(-1, f_max, dim_in)
    bi_rep = np.broadcast_to(bias[:, None],
                             (j_nodes, k_slots, f_max)).reshape(-1, f_max)
    x_n = staged["x_n"].reshape((-1,) + x.shape[1:])
    cm_n = staged["col_mask_n"].reshape(-1, cm.shape[1])
    gcross, _ = rff_gram_batched(
        jnp.asarray(om_rep), jnp.asarray(bi_rep), jnp.asarray(x_n),
        jnp.zeros(cm_n.shape, x.dtype), jnp.asarray(cm_n))
    return dict(
        gram_raw=np.asarray(graw), zy_raw=np.asarray(zyraw),
        gram_cross_raw=np.asarray(gcross).reshape(
            j_nodes, k_slots, f_max, f_max))


def _gauss_jordan_inv(a: jax.Array) -> jax.Array:
    """Unpivoted Gauss-Jordan inverse (safe: Eq. 17's matrix is SPD, and the
    padding is an identity block). Used instead of `jnp.linalg.inv` because
    LAPACK's blocked getrf rounds differently at different batch sizes —
    this form is built from batch-invariant elementwise ops, which is what
    lets the per-node regression replay match the batched build bit-for-bit
    (accuracy is Cholesky-grade on SPD inputs, ~1e-15 residual)."""
    dim = a.shape[0]
    aug = jnp.concatenate([a, jnp.eye(dim, dtype=a.dtype)], axis=1)

    def body(i, aug):
        piv = aug[i] / aug[i, i]
        aug = aug - jnp.outer(aug[:, i], piv)
        return aug.at[i].set(piv)

    aug = jax.lax.fori_loop(0, dim, body, aug)
    return aug[:, dim:]


def _featurize_raw(omega, bias, x, kind):
    """Unscaled raw features on one node: [F, dim] × [dim, N] → [R, N]."""
    proj = jnp.einsum("fd,dn->fn", omega, x,
                      precision=jax.lax.Precision.HIGHEST)
    if kind == "cos_bias":
        return jnp.cos(proj + bias[:, None])
    return jnp.concatenate([jnp.cos(proj), jnp.sin(proj)], axis=0)


def _node_aux(omega, bias, x, y, col_mask, feat_mask, feat_idx, scale,
              omega_n, bias_n, x_n, col_mask_n, feat_mask_n, feat_idx_n,
              scale_n, ct_self, ct_nei, ct_nei_n, degree, nbr_mask,
              lam_over_j, n_total, *, kind,
              gram_raw=None, zy_raw=None, gram_cross_raw=None):
    """Eq. 17 auxiliaries for ONE node in the padded layout (vmapped over
    the node axis by `_build_packed_aux`). All neighbor inputs arrive
    pre-gathered per slot ([K, …]); masked slots carry nbr_mask 0 and the
    node's own arrays, so their contributions cancel exactly.
    """
    hi = jax.lax.Precision.HIGHEST
    pack = lambda raw, idx, fm, sc, cm: (            # raw [R, N] → Z [D, N]
        jnp.take(raw, idx, axis=0) * sc * fm[:, None] * cm[None, :])

    z = pack(_featurize_raw(omega, bias, x, kind),
             feat_idx, feat_mask, scale, col_mask)          # Z_jj [D, N]
    # neighbor maps on own data / own map on neighbor data / neighbor-own
    raw_n_on_j = jax.vmap(
        lambda om, b: _featurize_raw(om, b, x, kind))(omega_n, bias_n)
    z_n_on_j = jax.vmap(pack)(
        raw_n_on_j, feat_idx_n, feat_mask_n, scale_n,
        jnp.broadcast_to(col_mask, (omega_n.shape[0],) + col_mask.shape))
    raw_j_on_n = jax.vmap(
        lambda xn: _featurize_raw(omega, bias, xn, kind))(x_n)
    z_j_on_n = jax.vmap(
        lambda raw, cm: pack(raw, feat_idx, feat_mask, scale, cm))(
            raw_j_on_n, col_mask_n)
    raw_nn = jax.vmap(
        lambda om, b, xn: _featurize_raw(om, b, xn, kind))(
            omega_n, bias_n, x_n)
    z_nn = jax.vmap(pack)(raw_nn, feat_idx_n, feat_mask_n, scale_n,
                          col_mask_n)                       # Z_pp [K, D, N]

    if y.ndim == 1:
        # mult+sum rather than a matvec: XLA's gemv rounds differently at
        # different batch sizes, this form is batch-invariant (regression
        # replay in tests/test_dist_property.py)
        d_vec_z = jnp.sum(z * y[None, :], axis=1) / n_total
    else:
        # multi-output: same batch-invariant mult+sum per output column
        d_vec_z = jnp.sum(z[:, :, None] * y[None, :, :], axis=1) / n_total

    if gram_raw is not None:
        # Pallas streaming kernel output (unit-scale frequency space ==
        # packed feature space for cos_bias); mask + scale here. The
        # kernel's zy accumulator only exists for scalar targets.
        fouter = feat_mask[:, None] * feat_mask[None, :]
        gram_jj = gram_raw * scale**2 * fouter
        d_vec = (zy_raw * scale * feat_mask / n_total
                 if y.ndim == 1 else d_vec_z)
        gram_cross = (gram_cross_raw * scale**2 * fouter[None])
    else:
        gram_jj = jnp.einsum("an,bn->ab", z, z, precision=hi)
        d_vec = d_vec_z
        gram_cross = jnp.einsum("kan,kbn->kab", z_j_on_n, z_j_on_n,
                                precision=hi)

    a = (1.0 / n_total + 2.0 * ct_self + degree * ct_nei) * gram_jj
    a = a + lam_over_j * jnp.diag(feat_mask)
    a = a + jnp.einsum("k,kab->ab", nbr_mask * ct_nei_n, gram_cross,
                       precision=hi)
    g = _gauss_jordan_inv(a + jnp.diag(1.0 - feat_mask))
    g = g * feat_mask[:, None] * feat_mask[None, :]

    s = 2.0 * ct_self * gram_jj
    p = (ct_nei * jnp.einsum("an,kbn->kab", z, z_n_on_j, precision=hi)
         + ct_nei_n[:, None, None]
         * jnp.einsum("kan,kbn->kab", z_j_on_n, z_nn, precision=hi))
    p = p * nbr_mask[:, None, None]
    return g, d_vec, s, p


@partial(jax.jit, static_argnames=("kind",))
def _vmapped_node_aux(kind, **arrays):
    global _PACK_TRACE_COUNT
    _PACK_TRACE_COUNT += 1          # Python side effect: counts traces only
    return jax.vmap(partial(_node_aux, kind=kind))(**arrays)


def _build_packed_aux(*, kind, _meta=None, **staged):
    """One traced program for the whole network (trace count independent of
    J) — see `_vmapped_node_aux` for the counter the regression test pins."""
    return _vmapped_node_aux(kind=kind, **{k: jnp.asarray(v)
                                           for k, v in staged.items()})


def _finish_packed(staged: dict, built) -> PackedProblem:
    g, d, s, p = built
    dims, nbr_idx, offsets = staged["_meta"]
    num_edges = _validate_slot_table(nbr_idx, staged["nbr_mask"], len(dims))
    return PackedProblem(
        g=g, d=d, s=s, p=p,
        theta_mask=jnp.asarray(staged["feat_mask"]),
        nbr_idx=jnp.asarray(nbr_idx),
        nbr_mask=jnp.asarray(staged["nbr_mask"]),
        offsets=offsets, node_dims=dims, num_edges_directed=num_edges,
    )


def _pack_problem_pernode(solver, *, gram_backend: str | None = None
                          ) -> PackedProblem:
    """The removed per-node Python loop, kept as the regression target: the
    same staged inputs and the same vmapped program, but replayed one
    batch-of-1 call per node. `pack_problem(method="batched")` must produce
    bit-identical contents (tests/test_dist_property.py)."""
    staged = _stage_packed_inputs(solver, gram_backend=gram_backend)
    meta, kind = staged.pop("_meta"), staged.pop("kind")
    parts = [
        _build_packed_aux(kind=kind, **{k: v[j:j + 1]
                                        for k, v in staged.items()})
        for j in range(solver.J)
    ]
    built = tuple(jnp.concatenate(col, axis=0) for col in zip(*parts))
    staged.update(_meta=meta, kind=kind)
    return _finish_packed(staged, built)


def pack_theta(packed: PackedProblem,
               theta: Sequence[jax.Array]) -> jax.Array:
    """Ragged per-node θ list → padded [J, D_max] (or [J, D_max, Dy]).

    Vectors shorter than their node's D_j re-pad with exact zeros, so a θ
    taken from a packing whose dims have since *grown* (e.g. a per-node
    DDRF feature refresh in `repro.stream` that enlarged D_j) round-trips
    cleanly. Vectors *longer* than D_j (from `packed.node_dims`, or D_max
    when dims were not recorded) are rejected with a clear error — such a
    θ is stale against this layout, and padding it would either crash
    deep in `jnp.pad` with a negative pad width or silently put mass on
    padded coordinates the iteration treats as dead. The output width is
    validated the same way: every θ_j must be [D_j]-shaped for a
    scalar-target packing and [D_j, Dy]-shaped (with THIS packing's Dy)
    for a multi-output one — a θ from a packing with a different Dy is
    stale, and reshaping it would silently scramble output columns.
    """
    theta = list(theta)
    if len(theta) != packed.num_nodes:
        raise ValueError(
            f"pack_theta got {len(theta)} θ vectors for a packed problem "
            f"with {packed.num_nodes} nodes")
    d_max = packed.max_features
    out_tail = packed.d.shape[2:]            # () scalar, (Dy,) multi-output
    for j, t in enumerate(theta):
        if t.shape[1:] != out_tail:
            want = (f"[D_j, Dy={out_tail[0]}]" if out_tail
                    else "[D_j] (scalar targets)")
            raise ValueError(
                f"theta[{j}] has shape {tuple(t.shape)} but this packing "
                f"carries {want} per-node θ — this θ was packed under a "
                f"different output width Dy and cannot be re-laid-out "
                f"silently. Re-derive it for the current targets.")
        limit = (packed.node_dims[j] if packed.node_dims is not None
                 else d_max)
        if t.shape[0] > limit:
            raise ValueError(
                f"theta[{j}] has {t.shape[0]} coordinates but node {j} "
                f"has D_j = {limit} (D_max = {d_max}) — this θ is stale "
                f"against the packed layout (was node {j}'s feature map "
                f"refreshed to fewer features?). Re-derive it for the "
                f"current dims (repro.stream.repad_theta re-pads carried "
                f"iterates across a refresh).")
    pad_tail = ((0, 0),) * len(out_tail)
    return jnp.stack([jnp.pad(t, ((0, d_max - t.shape[0]),) + pad_tail)
                      for t in theta])


def unpack_theta(packed: PackedProblem,
                 theta: jax.Array) -> list[jax.Array]:
    """Padded [J, D_max] (or [J, D_max, Dy]) θ → ragged per-node list.

    Validates θ against the packed layout — BOTH the feature width and
    the output width: a θ from a different packing (carried across a
    `repro.stream` feature refresh that changed D_max, or packed under a
    different Dy) must not be sliced silently — slicing a too-narrow θ
    would truncate node vectors, and reinterpreting a different Dy would
    scramble output columns, without any error.
    """
    if packed.node_dims is None:
        raise ValueError("packed problem has no node_dims recorded")
    want = packed.d.shape                # (J, D_max) or (J, D_max, Dy)
    if theta.shape != want:
        raise ValueError(
            f"unpack_theta got θ of shape {theta.shape} for a packed "
            f"problem of θ-shape {want} (Dy = {packed.num_outputs}) — "
            f"this θ belongs to a different packing (stale across a "
            f"feature refresh that re-padded D_max, or packed under a "
            f"different output width Dy?). Unpack it with ITS packing, "
            f"then re-pack (or use repro.stream.repad_theta).")
    return [theta[j, :dj] for j, dj in enumerate(packed.node_dims)]


# --------------------------------------------------------------------------
# One Eq. 19 round — the single arithmetic kernel shared by every runtime
# --------------------------------------------------------------------------
def _node_step(g: jax.Array, d: jax.Array, s: jax.Array, p: jax.Array,
               theta: jax.Array, nbr_theta: jax.Array,
               nbr_mask: jax.Array) -> jax.Array:
    """θ_j ← G_j (d_j + S_j θ_j + Σ_k P_{j,k} θ_{nbr(j,k)})  for one node.

    Shapes: g/s [D, D], d/theta [D] (or [D, Dy]), p [K, D, D], nbr_theta
    [K, D] (or [K, D, Dy]), nbr_mask [K]. Masked slots carry zero P
    blocks, so the mask multiply is belt-and-braces; padded coordinates
    come out exactly 0.0 because the corresponding rows of g are zero.
    The multi-output branch is the same contraction per output column —
    scalar targets keep the exact original trace.
    """
    if theta.ndim == 1:
        coupled = jnp.einsum("kab,kb->a", p, nbr_theta * nbr_mask[:, None])
    else:
        coupled = jnp.einsum("kab,kbo->ao", p,
                             nbr_theta * nbr_mask[:, None, None])
    return g @ (d + s @ theta + coupled)


_BACKENDS = ("xla", "pallas", "pallas_fused")
# Backends whose per-round arithmetic is the fused Pallas round kernel.
_PALLAS_BACKENDS = ("pallas", "pallas_fused")
# Default tol-check cadence for the fused solve: surfacing θ every round
# would defeat the whole point of fusing the scan into the kernel.
_FUSED_CHUNK_DEFAULT = 32


def _check_backend(backend: str) -> None:
    if backend not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}, "
                         f"got {backend!r}")


@partial(jax.jit, static_argnames=("backend",))
def step_batched(packed: PackedProblem, theta: jax.Array,
                 backend: str = "xla", *,
                 active: jax.Array | None = None,
                 nbr_theta: jax.Array | None = None) -> jax.Array:
    """One Jacobi round of Eq. 19 over all nodes (synchronous by default).

    theta: [J, D_max] → [J, D_max] (or [J, D_max, Dy] → [J, D_max, Dy]
    for multi-output packings — the trailing output axis batches through
    the same GEMMs). Padding is preserved exactly (zero in, zero out) —
    see the module docstring for why no mask is needed.

    ``backend="xla"`` is the vmapped-GEMM round; ``backend="pallas"`` the
    fused `repro.kernels.dekrr_step` kernel (in-kernel slot-table gather, θ
    VMEM-resident; interpret-mode on CPU). ``backend="pallas_fused"`` only
    differs from "pallas" at the *solve* level (rounds fused into one
    kernel); a single step runs the same per-round kernel. All run the
    same arithmetic and agree at rtol 1e-9 under x64.

    The async-gossip runtime (`repro.dist.async_gossip`) threads two
    keyword extras through the same entry point:

      * ``active`` ([J], any dtype): nodes with active[j] == 0 pass their
        θ row through untouched — jnp.where on the XLA path, the
        activation-masked kernel variant on the Pallas paths. With
        ``active`` omitted or all-ones the synchronous arithmetic runs
        bit-for-bit.
      * ``nbr_theta`` ([J, K, D_max]): per-slot neighbor θ to couple
        against *instead of* gathering ``theta[packed.nbr_idx]`` — the
        async per-edge staleness buffers. On the Pallas paths the buffers
        are appended below θ as extra table rows ([J·(1+K), D_max]) and
        the slot table is re-pointed at them, so the kernel's gather
        semantics are unchanged.
    """
    _check_backend(backend)
    if backend in _PALLAS_BACKENDS:
        from repro.kernels.ops import dekrr_step

        j_nodes, k_slots = packed.num_nodes, packed.num_slots
        self_idx = jnp.arange(j_nodes, dtype=jnp.int32)
        if nbr_theta is None:
            table, nbr_idx = theta, packed.nbr_idx
        else:
            table = jnp.concatenate(
                [theta, nbr_theta.reshape((j_nodes * k_slots,)
                                          + theta.shape[1:])], axis=0)
            nbr_idx = j_nodes + jnp.arange(
                j_nodes * k_slots, dtype=jnp.int32).reshape(j_nodes,
                                                            k_slots)
        return dekrr_step(packed.g, packed.d, packed.s, packed.p, table,
                          nbr_idx, self_idx, packed.nbr_mask, active)
    if nbr_theta is None:
        nbr_theta = theta[packed.nbr_idx]              # [J, K, D_max]
    new = jax.vmap(_node_step)(
        packed.g, packed.d, packed.s, packed.p, theta, nbr_theta,
        packed.nbr_mask)
    if active is not None:
        gate = jnp.reshape(active != 0, (-1,) + (1,) * (theta.ndim - 1))
        new = jnp.where(gate, new, theta)
    return new


def _run_rounds(packed: PackedProblem, theta: jax.Array, num_rounds: int,
                backend: str) -> jax.Array:
    """`num_rounds` Eq. 19 rounds from `theta` — the one place the solve
    backends diverge: "pallas_fused" runs them as ONE pallas_call of the
    multi-round kernel (θ VMEM-resident across rounds, one dispatch);
    "xla"/"pallas" scan the per-round step (one dispatch per round)."""
    if num_rounds == 0:
        return theta
    if backend == "pallas_fused":
        from repro.kernels.ops import dekrr_solve

        self_idx = jnp.arange(packed.num_nodes, dtype=jnp.int32)
        return dekrr_solve(packed.g, packed.d, packed.s, packed.p, theta,
                           packed.nbr_idx, self_idx, packed.nbr_mask,
                           num_rounds=num_rounds)

    def round_fn(th, _):
        return step_batched(packed, th, backend=backend), None

    theta, _ = lax.scan(round_fn, theta, None, length=num_rounds)
    return theta


def _run_rounds_traced(packed: PackedProblem, theta: jax.Array,
                       num_rounds: int, backend: str
                       ) -> tuple[jax.Array, jax.Array]:
    """`_run_rounds` emitting the per-round residuals [num_rounds] too:
    residuals[r] = max|θ^{r+1} − θ^r| over every coordinate (padded slots
    are identically zero on both sides, so no masking is needed). On
    "pallas_fused" the per-(round, node) residual comes out of the SAME
    pallas_call as an extra [R, J] output block — still one dispatch; the
    per-round backends fold the same max into the existing scan."""
    if num_rounds == 0:
        return theta, jnp.zeros((0,), theta.dtype)
    if backend == "pallas_fused":
        from repro.kernels.ops import dekrr_solve

        self_idx = jnp.arange(packed.num_nodes, dtype=jnp.int32)
        theta, res = dekrr_solve(
            packed.g, packed.d, packed.s, packed.p, theta,
            packed.nbr_idx, self_idx, packed.nbr_mask,
            num_rounds=num_rounds, trace=True)
        return theta, jnp.max(res, axis=1)

    def round_fn(th, _):
        new = step_batched(packed, th, backend=backend)
        return new, jnp.max(jnp.abs(new - th))

    return lax.scan(round_fn, theta, None, length=num_rounds)


@partial(jax.jit, static_argnames=("num_iters", "backend", "tol",
                                   "chunk_rounds", "return_rounds",
                                   "return_trace"))
def solve_batched(packed: PackedProblem, num_iters: int,
                  theta0: jax.Array | None = None,
                  backend: str = "xla", *, tol: float = 0.0,
                  chunk_rounds: int | None = None,
                  return_rounds: bool = False,
                  return_trace: bool = False) -> jax.Array:
    """Run up to `num_iters` batched rounds from θ = 0 (or theta0).

    ``backend="xla"|"pallas"`` scans the per-round step (`lax.scan`, one
    kernel dispatch per round); ``backend="pallas_fused"`` runs whole
    blocks of rounds inside one `repro.kernels.dekrr_solve` pallas_call —
    the θ table stays VMEM-resident across rounds and per-round dispatch
    overhead disappears. All three agree at rtol 1e-9 under x64.

    ``tol > 0`` enables early stopping on max|θ^{k+c} − θ^k| < tol, checked
    every `chunk_rounds` rounds (default: 1 for the per-round backends —
    matching `DeKRRSolver.solve`'s per-round check — and
    ``_FUSED_CHUNK_DEFAULT`` for "pallas_fused", which only surfaces θ at
    chunk boundaries). The delta is computed on device inside the scan:
    no host synchronization per round, one device→host transfer total.
    ``chunk_rounds`` without tol forces the same round-chunked scan (used
    by the chunk-equivalence tests and benchmarks).

    ``return_rounds=True`` additionally returns the number of rounds
    actually run (an int32 scalar array; == num_iters unless tol stopped
    the solve early).

    ``return_trace=True`` appends a `repro.obs.SolveTrace` whose
    ``residuals`` is the on-device [num_iters] per-round convergence
    series residuals[r] = max|θ^{r+1} − θ^r|, recorded inside the
    existing scan/while/kernel round structure — zero host callbacks and
    zero extra kernel dispatches ("pallas_fused" reads it off an extra
    output block of the same pallas_call). Chunk-invariant: the series is
    identical for every `chunk_rounds`. On tol-stopped solves the rounds
    after the stop (frozen rounds) record 0. Return order is
    ``(theta[, rounds][, trace])``.
    """
    _check_backend(backend)
    if tol < 0:
        raise ValueError(f"tol must be >= 0, got {tol}")
    if chunk_rounds is not None and chunk_rounds < 1:
        raise ValueError(f"chunk_rounds must be >= 1, got {chunk_rounds}")
    if theta0 is None:
        theta0 = jnp.zeros_like(packed.d)
    num_iters = int(num_iters)

    def finish(theta, rounds, residuals):
        out = (theta,)
        if return_rounds:
            out += (rounds,)
        if return_trace:
            out += (SolveTrace(residuals=residuals),)
        return out if len(out) > 1 else theta

    if tol == 0.0:
        # No early stop: straight-line rounds (chunked only on request).
        run = _run_rounds_traced if return_trace else (
            lambda *a: (_run_rounds(*a), None))
        if chunk_rounds is None or chunk_rounds >= max(num_iters, 1):
            theta, res = run(packed, theta0, num_iters, backend)
        else:
            n_full, rem = divmod(num_iters, chunk_rounds)

            def chunk_fn(th, _):
                return run(packed, th, chunk_rounds, backend)

            theta, res = lax.scan(chunk_fn, theta0, None, length=n_full)
            theta, res_rem = run(packed, theta, rem, backend)
            if return_trace:
                res = jnp.concatenate([res.reshape(-1), res_rem])
        return finish(theta, jnp.asarray(num_iters, jnp.int32), res)

    chunk = chunk_rounds if chunk_rounds is not None else (
        _FUSED_CHUNK_DEFAULT if backend == "pallas_fused" else 1)
    chunk = min(chunk, max(num_iters, 1))
    n_full, rem = divmod(num_iters, chunk)

    def cond_fn(carry):
        _, rounds, converged = carry[:3]
        return jnp.logical_not(converged) & (rounds < n_full * chunk)

    def body_fn(carry):
        th, rounds = carry[0], carry[1]
        if return_trace:
            new, chunk_res = _run_rounds_traced(packed, th, chunk, backend)
            # Preallocated [num_iters] buffer; frozen rounds stay 0.
            buf = lax.dynamic_update_slice(carry[3], chunk_res, (rounds,))
            tail = (buf,)
        else:
            new = _run_rounds(packed, th, chunk, backend)
            tail = ()
        delta = jnp.max(jnp.abs(new - th))       # one fused on-device delta
        return (new, rounds + chunk, delta < tol) + tail

    init = (theta0, jnp.asarray(0, jnp.int32), jnp.asarray(False))
    if return_trace:
        init += (jnp.zeros((num_iters,), theta0.dtype),)
    carry = lax.while_loop(cond_fn, body_fn, init)
    theta, rounds, converged = carry[:3]
    res_buf = carry[3] if return_trace else None
    if rem:
        if return_trace:
            def rem_fn(op):
                th, buf, rd = op
                new, r = _run_rounds_traced(packed, th, rem, backend)
                return new, lax.dynamic_update_slice(buf, r, (rd,))

            theta, res_buf = lax.cond(
                converged, lambda op: (op[0], op[1]), rem_fn,
                (theta, res_buf, rounds))
        else:
            theta = lax.cond(
                converged, lambda th: th,
                lambda th: _run_rounds(packed, th, rem, backend), theta)
        rounds = jnp.where(converged, rounds, rounds + rem)
    return finish(theta, rounds, res_buf)


# --------------------------------------------------------------------------
# SPMD nodes-on-devices runtime
# --------------------------------------------------------------------------
_MODES = ("ppermute", "allgather")


def _make_exchange(mode: str, axis_name: str, j_nodes: int,
                   offsets: tuple[int, ...] | None, nbr_idx: jax.Array):
    """Per-device neighbor exchange ``vec [1, W] → [K, W]`` for a
    shard_map node program — the one collective wiring the sync and async
    SPMD solvers share (their bit-for-bit equivalence at full activation
    rests on it, so there is exactly one copy).

    ``"ppermute"``: one fwd + one bwd ring shift per circulant offset, in
    the packed slot order [(+s_1), (−s_1), (+s_2), (−s_2), …].
    ``"allgather"``: gather every device's row 0, then take this node's
    slots. ``nbr_idx`` is the device-local [1, K] slot-table operand.
    """
    def exchange(vec):
        if mode == "ppermute":
            recvs = []
            for shift in offsets:
                # receive from node (j+shift): source (i+shift) -> dest i
                fwd = lax.ppermute(
                    vec, axis_name,
                    [(i, (i - shift) % j_nodes) for i in range(j_nodes)])
                # receive from node (j-shift): source (i-shift) -> dest i
                bwd = lax.ppermute(
                    vec, axis_name,
                    [(i, (i + shift) % j_nodes) for i in range(j_nodes)])
                recvs.extend((fwd, bwd))
            return jnp.concatenate(recvs, axis=0)
        everyone = lax.all_gather(vec[0], axis_name)         # [J, W]
        return jnp.take(everyone, nbr_idx[0], axis=0)

    return exchange


def _check_spmd_problem(packed: PackedProblem, mesh: Mesh, axis_name: str,
                        mode: str) -> None:
    """Shared launch-time validation for the sync and async SPMD solvers:
    one node per device along the axis, and circulant slot layout when the
    exchange is ppermute ring shifts."""
    j_nodes = packed.num_nodes
    if mesh.shape[axis_name] != j_nodes:
        raise ValueError(
            f"mesh axis {axis_name!r} has {mesh.shape[axis_name]} "
            f"devices but the problem has {j_nodes} nodes")
    if mode == "ppermute":
        if packed.offsets is None:
            raise ValueError(
                "ppermute mode needs a circulant-packed problem "
                "(packed.offsets is None — use mode='allgather')")
        if packed.num_slots != 2 * len(packed.offsets):
            raise ValueError("slot table is not in circulant layout")


def make_spmd_solver(mesh: Mesh, axis_name: str, mode: str = "ppermute",
                     backend: str = "xla"):
    """Build `run(packed, num_iters) -> [J, D_max]` on a 1-D node mesh.

    One node per device along `axis_name`; device index along the axis IS
    the node id, so `pack_problem`'s slot table and the mesh agree by
    construction. Per round only θ moves between devices:

      * ``"ppermute"``  — one `lax.ppermute` ring shift per circulant slot
        (requires a circulant-packed problem, `packed.offsets` not None);
        Σ_j |N_j| · D_max words per round.
      * ``"allgather"`` — `lax.all_gather` θ then gather slots locally;
        any topology; J·(J−1)·D_max words per round.

    ``backend`` picks the per-device arithmetic through the same switch as
    `step_batched`/`solve_batched`: "xla" runs `_node_step` (identical to
    `step_batched`); "pallas" runs the fused `repro.kernels.dekrr_step`
    kernel on the local θ table ``[own θ; received neighbor θs]`` with
    `self_idx = [0]` — the same kernel as the batched runtime, which is
    what makes rtol-1e-9 parity hold everywhere. "pallas_fused" is
    accepted for plumbing uniformity but runs the per-round kernel too:
    each SPMD round is bounded by the inter-device θ exchange
    (ppermute/all_gather), so rounds cannot be fused across the
    collective — cross-round fusion exists only in the single-core
    batched runtime (`solve_batched(backend="pallas_fused")`).

    The returned runner is
    ``run(packed, num_iters, theta0=None, *, tol=0.0,
    return_rounds=False)``:

      * ``theta0`` ([J, D_max], sharded like θ) warm-starts the iteration
        — the `repro.stream` runtime's carried iterate; None runs from
        zeros exactly as before.
      * ``tol > 0`` enables per-round early stopping: each device reduces
        its local max|Δθ| and a fused `lax.pmax` over the node axis makes
        every device see the NETWORK-wide delta, so every per-device
        `lax.while_loop` takes the same trip decision and the in-body
        collectives stay matched. The exit is genuine — after the
        converging round no further compute OR exchange runs, so a
        converged solve stops paying for the budget's tail; θ and the
        round count exactly match
        ``solve_batched(..., tol=tol, chunk_rounds=1)``.
      * ``return_rounds=True`` appends the rounds-run int32 scalar.
      * ``return_trace=True`` appends a `repro.obs.SolveTrace` with the
        [num_iters] per-round network-wide max|Δθ| series. Each device
        records its LOCAL per-round delta into the scan/while carry (no
        extra collective); the network-wide max is reduced over the
        device axis outside the shard_map. Frozen rounds (after a tol
        stop) record 0. Return order: ``(theta[, rounds][, trace])``.
    """
    if mode not in _MODES:
        raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
    _check_backend(backend)
    if axis_name not in mesh.shape:
        raise ValueError(f"mesh has no axis {axis_name!r}: {mesh.shape}")

    spec = PartitionSpec(axis_name)

    # One jitted program per (shapes, num_iters, offsets, tol) — repeat
    # calls of the returned `run` hit the jit cache instead of re-tracing
    # shard_map.
    @partial(jax.jit, static_argnames=("num_iters", "offsets", "tol",
                                       "return_trace"))
    def _run(g, d, s, p, nbr_idx, nbr_mask, theta0, *, num_iters, offsets,
             tol, return_trace=False):
        j_nodes = d.shape[0]
        k_slots = p.shape[1]

        def node_program(g, d, s, p, nbr_idx, nbr_mask, theta0):
            # Every operand arrives with a leading per-device axis of 1.
            exchange = _make_exchange(mode, axis_name, j_nodes, offsets,
                                      nbr_idx)

            def one_round(theta):
                nbr_theta = exchange(theta)
                if backend in _PALLAS_BACKENDS:
                    from repro.kernels.ops import dekrr_step

                    # local θ table: row 0 = own θ, rows 1…K = neighbors
                    table = jnp.concatenate([theta, nbr_theta], axis=0)
                    local_idx = jnp.arange(
                        1, k_slots + 1, dtype=jnp.int32)[None]
                    return dekrr_step(
                        g, d, s, p, table, local_idx,
                        jnp.zeros((1,), jnp.int32), nbr_mask)
                return _node_step(g[0], d[0], s[0], p[0], theta[0],
                                  nbr_theta, nbr_mask[0])[None]

            if tol == 0.0:
                if return_trace:
                    # Record the LOCAL per-round delta; the network-wide
                    # max is a device-axis reduction outside the
                    # shard_map, so tracing adds no collective.
                    def round_fn(theta, _):
                        new = one_round(theta)
                        return new, jnp.max(jnp.abs(new - theta))

                    theta, res = lax.scan(round_fn, theta0, None,
                                          length=num_iters)
                    rounds = jnp.full((1,), num_iters, jnp.int32)
                    return theta, rounds, res[None]

                def round_fn(theta, _):
                    return one_round(theta), None

                theta, _ = lax.scan(round_fn, theta0, None,
                                    length=num_iters)
                rounds = jnp.full((1,), num_iters, jnp.int32)
                return theta, rounds

            # genuine early exit: the pmax-fused delta makes the trip
            # decision identical on every device, so the per-device
            # while_loops run the same number of rounds and the
            # collectives inside the body stay matched — converged solves
            # stop paying for the rest of the budget (the warm-start
            # common case).
            def cond_fn(carry):
                _, converged, rounds = carry[:3]
                return jnp.logical_not(converged) & (rounds < num_iters)

            def body_fn(carry):
                theta, converged, rounds = carry[:3]
                new = one_round(theta)
                local = jnp.max(jnp.abs(new - theta))
                delta = lax.pmax(local, axis_name)
                state = (new, converged | (delta < tol), rounds + 1)
                if return_trace:
                    # Preallocated [num_iters] buffer in the carry;
                    # frozen rounds after the stop stay 0.
                    state += (carry[3].at[rounds].set(local),)
                return state

            init = (theta0, jnp.asarray(False), jnp.asarray(0, jnp.int32))
            if return_trace:
                init += (jnp.zeros((num_iters,), theta0.dtype),)
            carry = lax.while_loop(cond_fn, body_fn, init)
            theta, rounds = carry[0], jnp.reshape(carry[2], (1,))
            if return_trace:
                return theta, rounds, carry[3][None]
            return theta, rounds

        sharded = shard_map(
            node_program, mesh=mesh,
            in_specs=(spec, spec, spec, spec, spec, spec, spec),
            out_specs=(spec, spec, spec) if return_trace else (spec, spec),
            # jax 0.4.x has no replication rule for pallas_call, and its
            # scan rule rejects the pmax-derived `converged` carry of the
            # tol path (replication changes across the carry — the error
            # text itself prescribes check_rep=False); every operand and
            # output here is explicitly sharded anyway (the per-device
            # round counts are pmax-synchronized copies).
            check_rep=(backend not in _PALLAS_BACKENDS and tol == 0.0),
        )
        return sharded(g, d, s, p, nbr_idx, nbr_mask, theta0)

    def run(packed: PackedProblem, num_iters: int,
            theta0: jax.Array | None = None, *, tol: float = 0.0,
            return_rounds: bool = False, return_trace: bool = False):
        _check_spmd_problem(packed, mesh, axis_name, mode)
        if tol < 0:
            raise ValueError(f"tol must be >= 0, got {tol}")
        if theta0 is None:
            theta0 = jnp.zeros_like(packed.d)
        outs = _run(packed.g, packed.d, packed.s, packed.p,
                    packed.nbr_idx, packed.nbr_mask, theta0,
                    num_iters=int(num_iters),
                    offsets=packed.offsets, tol=float(tol),
                    return_trace=return_trace)
        theta, rounds = outs[0], outs[1]
        out = (theta,)
        if return_rounds:
            out += (jnp.max(rounds),)
        if return_trace:
            # [J, R] per-device local deltas → network-wide series.
            out += (SolveTrace(residuals=jnp.max(outs[2], axis=0)),)
        return out if len(out) > 1 else theta

    return run


# --------------------------------------------------------------------------
# §II-C communication cost model
# --------------------------------------------------------------------------
def comm_bytes_per_round(packed: PackedProblem, mode: str, *,
                         activation_prob: float = 1.0,
                         censor_fraction: float = 0.0,
                         gossip: str = "bernoulli") -> int | float:
    """(Expected) bytes moved across the network per Eq. 19 round.

    Synchronous base cost (``activation_prob=1``, ``censor_fraction=0``,
    ``gossip="bernoulli"`` — the defaults, returned as an exact int):

    ``"ppermute"``:  Σ_j |N_j| · D_max · itemsize — each node receives one
    padded θ vector from each neighbor (the paper's Σ_j |N_j| D_j metric,
    evaluated at the packed width D_max).
    ``"allgather"``: J · (J−1) · D_max · itemsize — each node receives the
    full network state minus its own shard.

    Multi-output packings ship Dy columns per θ exchange, so every
    formula above carries an extra ·Dy factor (`packed.num_outputs`).

    Async gossip (`repro.dist.async_gossip`) scales the base cost to the
    *expected* payload under randomized activation and COKE censoring:

      * ``gossip="bernoulli"``: a node transmits iff it is active
        (probability ``activation_prob``) and uncensored (probability
        ``1 − censor_fraction``; censoring decisions are data-dependent,
        so callers pass the observed or assumed censor rate) — expected
        bytes = p · (1 − c) · base. Monotone non-decreasing in p and
        non-increasing in c (property-tested).
      * ``gossip="edge"``: exactly one edge gossips per round — two
        directed θ deliveries, censored at rate c, independent of p.

    Note the SPMD *simulation* still moves every collective lane each
    round (ppermute/all_gather are dense); this model prices the payload
    a deployment with point-to-point transport would ship.
    """
    if mode not in _MODES:
        raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
    if not 0.0 < activation_prob <= 1.0:
        raise ValueError(f"activation_prob must be in (0, 1], "
                         f"got {activation_prob}")
    if not 0.0 <= censor_fraction <= 1.0:
        raise ValueError(f"censor_fraction must be in [0, 1], "
                         f"got {censor_fraction}")
    if gossip not in ("bernoulli", "edge"):
        raise ValueError(f"gossip must be 'bernoulli' or 'edge', "
                         f"got {gossip!r}")
    j_nodes = packed.num_nodes
    # multi-output payloads ship Dy columns per θ exchange
    d_max = packed.max_features * packed.num_outputs
    itemsize = np.dtype(packed.d.dtype).itemsize
    if gossip == "edge":
        return 2 * d_max * itemsize * (1.0 - censor_fraction)
    if mode == "ppermute":
        # Static count recorded at packing time — reading it off
        # packed.nbr_mask here would force a device→host sync on a
        # quantity that never changes after packing. The NumPy fallback
        # covers hand-built PackedProblems that skipped pack_problem.
        num_edges_directed = packed.num_edges_directed
        if num_edges_directed is None:
            num_edges_directed = int(
                np.count_nonzero(np.asarray(packed.nbr_mask)))
        base = num_edges_directed * d_max * itemsize
    else:
        base = j_nodes * (j_nodes - 1) * d_max * itemsize
    if activation_prob == 1.0 and censor_fraction == 0.0:
        return base                      # synchronous: exact int contract
    return base * activation_prob * (1.0 - censor_fraction)
