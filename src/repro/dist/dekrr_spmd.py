"""SPMD nodes-on-devices runtime for the Eq. 19 DeKRR-DDRF iteration.

The reference solver (`repro.core.dekrr.DeKRRSolver`) is deliberately ragged:
a Python loop over nodes, each holding auxiliaries of its own size D_j. That
is the right shape for auditing Algorithm 1 against the paper, and exactly
the wrong shape for hardware. This module is the production counterpart, in
three layers that are pinned to the reference by parity tests
(`tests/test_dekrr_spmd.py`, rtol 1e-9 under x64):

1. **Packing** (`pack_problem`): pad every per-node auxiliary to the network
   maximum D_max and stack over nodes —

     G:  [J, D_max, D_max]      (Eq. 17 inverse, applied)
     d:  [J, D_max]             ((1/N) Z_jj Y_j)
     S:  [J, D_max, D_max]      (2 c̃_self Z_jj Z_jjᵀ)
     P:  [J, K, D_max, D_max]   (neighbor couplings, K slots per node)

   plus `theta_mask` ([J, D_max], 1.0 on live coordinates) and a neighbor
   slot table `nbr_idx`/`nbr_mask` ([J, K]). Because padding is *zero* in the
   matrices (not merely masked), one packed round maps padded inputs to
   padded outputs exactly: row i ≥ D_j of G_j is identically zero, so
   θ_j^{k+1} = G_j(…) has exact 0.0 in every padded coordinate. No masking
   is needed inside the iteration — the algebra is closed over the padding.

2. **Batched single-host execution** (`step_batched` / `solve_batched`):
   the Eq. 19 round as one `vmap` over the node axis, and the full solve as
   one `lax.scan` over rounds. This is the form XLA fuses into a handful of
   batched GEMMs; it is also the form every beyond-paper acceleration
   (Chebyshev semi-iteration in `repro.core.acceleration`) builds on.

3. **SPMD nodes-on-devices execution** (`make_spmd_solver`): the same round
   under `shard_map` on a 1-D device mesh, one node per device, exchanging
   only θ per round — the paper's communication pattern made literal:

     * ``mode="ppermute"``: for circulant topologies C_J(s_1, s_2, …) the
       neighbor slots are laid out ``[(+s_1), (−s_1), (+s_2), (−s_2), …]``
       and each round issues one `lax.ppermute` ring shift per slot. This
       is the TPU/ICI-native exchange: Σ_j |N_j| · D_max words per round,
       nearest-neighbor only, no gather of the full network state.
     * ``mode="allgather"``: `lax.all_gather` of θ followed by a local
       slot-table gather. Works for arbitrary connected graphs (star,
       Erdős–Rényi, …) at the cost of J·(J−1)·D_max words per round.

   Both modes run the identical per-node arithmetic (`_node_step`) as the
   batched runtime, so parity holds at near machine precision.

`comm_bytes_per_round` exposes the §II-C cost model for both modes so
benchmarks can report paper-comparable communication totals.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, PartitionSpec

try:  # jax >= 0.5 promotes shard_map out of experimental
    from jax import shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map

__all__ = [
    "PackedProblem",
    "pack_problem",
    "pack_theta",
    "unpack_theta",
    "step_batched",
    "solve_batched",
    "make_spmd_solver",
    "comm_bytes_per_round",
]


# --------------------------------------------------------------------------
# Packed problem container
# --------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedProblem:
    """Eq. 17 auxiliaries padded to [J, D_max, …] with a neighbor slot table.

    Attributes (array leaves; J nodes, K neighbor slots, D_max features):
      g:          [J, D_max, D_max]     padded G_j (Eq. 17 inverse, applied).
      d:          [J, D_max]            padded d_j.
      s:          [J, D_max, D_max]     padded S_j.
      p:          [J, K, D_max, D_max]  padded P_{j, nbr_idx[j, k]}; the
                                        [k] slice is the zero matrix for
                                        masked (padding) slots.
      theta_mask: [J, D_max]            1.0 where coordinate i < D_j.
      nbr_idx:    [J, K] int32          global node id feeding slot k of
                                        node j (j itself on padded slots).
      nbr_mask:   [J, K]                1.0 on live slots.

    Static (hashable aux data — part of the jit cache key):
      offsets:    circulant shift set (s_1, s_2, …) when the slot table is
                  laid out in ppermute order [(+s_1), (−s_1), (+s_2), …];
                  None for the generic padded-adjacency layout.
      node_dims:  per-node feature counts (D_1, …, D_J) for unpacking.
    """

    g: jax.Array
    d: jax.Array
    s: jax.Array
    p: jax.Array
    theta_mask: jax.Array
    nbr_idx: jax.Array
    nbr_mask: jax.Array
    offsets: tuple[int, ...] | None = None
    node_dims: tuple[int, ...] | None = None

    # -- pytree plumbing (offsets / node_dims are static) -------------------
    def tree_flatten(self):
        children = (self.g, self.d, self.s, self.p, self.theta_mask,
                    self.nbr_idx, self.nbr_mask)
        return children, (self.offsets, self.node_dims)

    @classmethod
    def tree_unflatten(cls, aux, children):
        offsets, node_dims = aux
        g, d, s, p, theta_mask, nbr_idx, nbr_mask = children
        return cls(g=g, d=d, s=s, p=p, theta_mask=theta_mask,
                   nbr_idx=nbr_idx, nbr_mask=nbr_mask,
                   offsets=offsets, node_dims=node_dims)

    @property
    def num_nodes(self) -> int:
        return self.d.shape[0]

    @property
    def max_features(self) -> int:
        return self.d.shape[1]

    @property
    def num_slots(self) -> int:
        return self.nbr_idx.shape[1]


def _circulant_slot_table(
    offsets: Sequence[int], num_nodes: int
) -> np.ndarray:
    """Slot table in ppermute order [(+s_1), (−s_1), (+s_2), (−s_2), …]."""
    idx = np.zeros((num_nodes, 2 * len(offsets)), dtype=np.int32)
    for m, s in enumerate(offsets):
        for j in range(num_nodes):
            idx[j, 2 * m] = (j + s) % num_nodes
            idx[j, 2 * m + 1] = (j - s) % num_nodes
    return idx


def pack_problem(solver) -> PackedProblem:
    """Pack a `DeKRRSolver`'s ragged auxiliaries into a `PackedProblem`.

    Circulant topologies get the ppermute slot layout (and `offsets`
    recorded) whenever every node's ±s neighbors are distinct, i.e. the
    uniform degree equals 2·|offsets|; anything else — star, Erdős–Rényi,
    or a circulant with an s = J/2 self-paired shift — falls back to the
    generic padded adjacency table from `Topology.neighbor_table()`.
    """
    topo = solver.topology
    j_nodes = solver.J
    dims = tuple(fm.num_features for fm in solver.feature_maps)
    d_max = max(dims)
    dtype = np.asarray(solver.aux.d[0]).dtype

    offsets = topo.circulant_offsets
    if offsets is not None and topo.max_degree == 2 * len(offsets):
        nbr_idx = _circulant_slot_table(offsets, j_nodes)
        nbr_mask = np.ones(nbr_idx.shape, dtype=dtype)
        offsets = tuple(int(s) for s in offsets)
    else:
        nbr_idx, live = topo.neighbor_table()
        nbr_mask = live.astype(dtype)
        offsets = None
    k_slots = nbr_idx.shape[1]

    g = np.zeros((j_nodes, d_max, d_max), dtype=dtype)
    d = np.zeros((j_nodes, d_max), dtype=dtype)
    s = np.zeros((j_nodes, d_max, d_max), dtype=dtype)
    p = np.zeros((j_nodes, k_slots, d_max, d_max), dtype=dtype)
    theta_mask = np.zeros((j_nodes, d_max), dtype=dtype)

    for j in range(j_nodes):
        dj = dims[j]
        g[j, :dj, :dj] = np.asarray(solver.aux.g[j])
        d[j, :dj] = np.asarray(solver.aux.d[j])
        s[j, :dj, :dj] = np.asarray(solver.aux.s[j])
        theta_mask[j, :dj] = 1.0
        for k in range(k_slots):
            if not nbr_mask[j, k]:
                continue
            nb = int(nbr_idx[j, k])
            pjp = np.asarray(solver.aux.p[j][nb])      # [D_j, D_nb]
            p[j, k, :pjp.shape[0], :pjp.shape[1]] = pjp

    return PackedProblem(
        g=jnp.asarray(g), d=jnp.asarray(d), s=jnp.asarray(s),
        p=jnp.asarray(p), theta_mask=jnp.asarray(theta_mask),
        nbr_idx=jnp.asarray(nbr_idx), nbr_mask=jnp.asarray(nbr_mask),
        offsets=offsets, node_dims=dims,
    )


def pack_theta(packed: PackedProblem,
               theta: Sequence[jax.Array]) -> jax.Array:
    """Ragged per-node θ list → padded [J, D_max] (inverse of unpack)."""
    d_max = packed.max_features
    return jnp.stack([jnp.pad(t, (0, d_max - t.shape[0])) for t in theta])


def unpack_theta(packed: PackedProblem,
                 theta: jax.Array) -> list[jax.Array]:
    """Padded [J, D_max] θ → ragged per-node list (reference layout)."""
    if packed.node_dims is None:
        raise ValueError("packed problem has no node_dims recorded")
    return [theta[j, :dj] for j, dj in enumerate(packed.node_dims)]


# --------------------------------------------------------------------------
# One Eq. 19 round — the single arithmetic kernel shared by every runtime
# --------------------------------------------------------------------------
def _node_step(g: jax.Array, d: jax.Array, s: jax.Array, p: jax.Array,
               theta: jax.Array, nbr_theta: jax.Array,
               nbr_mask: jax.Array) -> jax.Array:
    """θ_j ← G_j (d_j + S_j θ_j + Σ_k P_{j,k} θ_{nbr(j,k)})  for one node.

    Shapes: g/s [D, D], d/theta [D], p [K, D, D], nbr_theta [K, D],
    nbr_mask [K]. Masked slots carry zero P blocks, so the mask multiply is
    belt-and-braces; padded coordinates come out exactly 0.0 because the
    corresponding rows of g are zero.
    """
    coupled = jnp.einsum("kab,kb->a", p, nbr_theta * nbr_mask[:, None])
    return g @ (d + s @ theta + coupled)


@jax.jit
def step_batched(packed: PackedProblem, theta: jax.Array) -> jax.Array:
    """One synchronous Jacobi round of Eq. 19, vmapped over nodes.

    theta: [J, D_max] → [J, D_max]. Padding is preserved exactly (zero in,
    zero out) — see the module docstring for why no mask is needed.
    """
    nbr_theta = theta[packed.nbr_idx]                  # [J, K, D_max]
    return jax.vmap(_node_step)(
        packed.g, packed.d, packed.s, packed.p, theta, nbr_theta,
        packed.nbr_mask)


@partial(jax.jit, static_argnames=("num_iters",))
def solve_batched(packed: PackedProblem, num_iters: int,
                  theta0: jax.Array | None = None) -> jax.Array:
    """Run `num_iters` batched rounds from θ = 0 (or theta0) via lax.scan."""
    if theta0 is None:
        theta0 = jnp.zeros_like(packed.d)

    def round_fn(theta, _):
        return step_batched(packed, theta), None

    theta, _ = lax.scan(round_fn, theta0, None, length=num_iters)
    return theta


# --------------------------------------------------------------------------
# SPMD nodes-on-devices runtime
# --------------------------------------------------------------------------
_MODES = ("ppermute", "allgather")


def make_spmd_solver(mesh: Mesh, axis_name: str, mode: str = "ppermute"):
    """Build `run(packed, num_iters) -> [J, D_max]` on a 1-D node mesh.

    One node per device along `axis_name`; device index along the axis IS
    the node id, so `pack_problem`'s slot table and the mesh agree by
    construction. Per round only θ moves between devices:

      * ``"ppermute"``  — one `lax.ppermute` ring shift per circulant slot
        (requires a circulant-packed problem, `packed.offsets` not None);
        Σ_j |N_j| · D_max words per round.
      * ``"allgather"`` — `lax.all_gather` θ then gather slots locally;
        any topology; J·(J−1)·D_max words per round.

    The per-node arithmetic is `_node_step`, identical to `step_batched`,
    which is what makes rtol-1e-9 parity with the batched runtime hold.
    """
    if mode not in _MODES:
        raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
    if axis_name not in mesh.shape:
        raise ValueError(f"mesh has no axis {axis_name!r}: {mesh.shape}")

    spec = PartitionSpec(axis_name)

    # One jitted program per (shapes, num_iters, offsets) — repeat calls of
    # the returned `run` hit the jit cache instead of re-tracing shard_map.
    @partial(jax.jit, static_argnames=("num_iters", "offsets"))
    def _run(g, d, s, p, nbr_idx, nbr_mask, *, num_iters, offsets):
        j_nodes = d.shape[0]

        def node_program(g, d, s, p, nbr_idx, nbr_mask):
            # Every operand arrives with a leading per-device axis of 1.
            def exchange(theta):
                """Collect [K, D_max] neighbor θ for this device's node."""
                if mode == "ppermute":
                    recvs = []
                    for shift in offsets:
                        # receive θ_{j+shift}: source (i+shift) -> dest i
                        fwd = lax.ppermute(
                            theta, axis_name,
                            [(i, (i - shift) % j_nodes)
                             for i in range(j_nodes)])
                        # receive θ_{j-shift}: source (i-shift) -> dest i
                        bwd = lax.ppermute(
                            theta, axis_name,
                            [(i, (i + shift) % j_nodes)
                             for i in range(j_nodes)])
                        recvs.extend((fwd, bwd))
                    return jnp.concatenate(recvs, axis=0)
                everyone = lax.all_gather(theta[0], axis_name)  # [J, D_max]
                return jnp.take(everyone, nbr_idx[0], axis=0)

            def round_fn(theta, _):
                nbr_theta = exchange(theta)
                new = _node_step(g[0], d[0], s[0], p[0], theta[0],
                                 nbr_theta, nbr_mask[0])
                return new[None], None

            theta0 = jnp.zeros_like(d)
            theta, _ = lax.scan(round_fn, theta0, None, length=num_iters)
            return theta

        sharded = shard_map(
            node_program, mesh=mesh,
            in_specs=(spec, spec, spec, spec, spec, spec),
            out_specs=spec,
        )
        return sharded(g, d, s, p, nbr_idx, nbr_mask)

    def run(packed: PackedProblem, num_iters: int) -> jax.Array:
        j_nodes = packed.num_nodes
        if mesh.shape[axis_name] != j_nodes:
            raise ValueError(
                f"mesh axis {axis_name!r} has {mesh.shape[axis_name]} "
                f"devices but the problem has {j_nodes} nodes")
        if mode == "ppermute":
            if packed.offsets is None:
                raise ValueError(
                    "ppermute mode needs a circulant-packed problem "
                    "(packed.offsets is None — use mode='allgather')")
            if packed.num_slots != 2 * len(packed.offsets):
                raise ValueError("slot table is not in circulant layout")
        return _run(packed.g, packed.d, packed.s, packed.p, packed.nbr_idx,
                    packed.nbr_mask, num_iters=int(num_iters),
                    offsets=packed.offsets)

    return run


# --------------------------------------------------------------------------
# §II-C communication cost model
# --------------------------------------------------------------------------
def comm_bytes_per_round(packed: PackedProblem, mode: str) -> int:
    """Bytes moved across the network per Eq. 19 round.

    ``"ppermute"``:  Σ_j |N_j| · D_max · itemsize — each node receives one
    padded θ vector from each neighbor (the paper's Σ_j |N_j| D_j metric,
    evaluated at the packed width D_max).
    ``"allgather"``: J · (J−1) · D_max · itemsize — each node receives the
    full network state minus its own shard.
    """
    if mode not in _MODES:
        raise ValueError(f"mode must be one of {_MODES}, got {mode!r}")
    j_nodes = packed.num_nodes
    d_max = packed.max_features
    itemsize = np.dtype(packed.d.dtype).itemsize
    if mode == "ppermute":
        num_edges_directed = int(round(float(jnp.sum(packed.nbr_mask))))
        return num_edges_directed * d_max * itemsize
    return j_nodes * (j_nodes - 1) * d_max * itemsize
