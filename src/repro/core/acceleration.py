"""Beyond-paper optimization: Chebyshev semi-iterative acceleration of the
Eq. 19 fixed-point iteration.

The paper's solver is the stationary iteration θ^{k+1} = F(θ^k) = Mθ^k + b,
whose error contracts at ρ(M) — measured ≈0.95–0.999 on the paper's own
operating points, i.e. hundreds-to-thousands of communication rounds. Since
communication rounds are the paper's cost metric (Σ_j |N_j| D_j per round),
accelerating the *iteration count* at identical per-round communication is
a direct improvement on the paper's own objective.

Chebyshev iteration on A·θ = b with A = I − M and spec(M) ⊂ [μ_min, μ_max]
(hence spec(A) ⊂ [1−μ_max, 1−μ_min]) achieves the optimal polynomial rate
  r_cheb = (√κ − 1)/(√κ + 1),  κ = (1 − μ_min)/(1 − μ_max),
vs r_plain = μ_max: e.g. μ_max = 0.95, μ_min = 0 → ≈45 rounds/decade → ≈5
rounds/decade (≈9×), and the advantage grows as ρ(M) → 1 (√ of the
iteration count). Each Chebyshev step applies F exactly once — one θ
exchange with one-hop neighbors — so per-round cost, privacy and topology
are identical to Algorithm 1. The residual r = F(θ) − θ is local to each
node; the scalar recurrence (α_k, β_k) is precomputed offline from the
spectral-interval estimate (`chebyshev_coefficients` — note the first
step is special: β₁ = ½(c/d)², NOT the generic (c·α₀/2)² = ¼(c/d)²; the
latter is a classic transcription bug that silently costs rounds), so no
extra consensus is needed. All consumers — the host recurrence, the
packed XLA/Pallas paths, and the fused multi-round kernel — share ONE
precomputed (α, β) table (`chebyshev_scan` / scalar prefetch), so the
recurrence exists in exactly one place.

``backend="pallas_fused"`` on `chebyshev_solve_packed` runs the whole
accelerated solve (or each `chunk_rounds` slice) as ONE
`repro.kernels.dekrr_solve` Chebyshev pallas_call: the (α, β) table rides
scalar prefetch like the slot tables, the two-term recurrence direction
state lives in a VMEM table, and θ never touches HBM between rounds.

Both interval ends are estimated by distributed power iteration on F
(itself only neighbor exchanges): μ_max directly, μ_min via the shifted
operator μ_max·I − M. The spectrum is real (M is similar to a symmetric
matrix) but NOT nonnegative in general — a small negative tail
(min eig ≈ −0.06 measured on the houses stand-in) makes a [0, μ_max]
interval diverge, because the acceleration polynomial grows exponentially
outside its interval. ``estimate_spectral_interval`` adds outward safety
margins on both ends (over-covering only costs a slightly weaker rate).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.dist.dekrr_spmd import (PackedProblem, _check_backend,
                                   step_batched)
from repro.obs.trace import SolveTrace


def safe_mu(mu_est: float, margin: float = 0.02) -> float:
    """Safety-inflate a power-iteration estimate of ρ(M): Chebyshev is
    robust to OVER-estimating μ_max (slightly slower rate) but stalls or
    diverges if the true top eigenvalue lies outside [μ_min, μ_max]
    (power iteration converges from below when the eigen-gap is small)."""
    return min(mu_est * (1.0 + margin) + 0.002, 0.99999)


def _mask_like(packed: PackedProblem, v: jax.Array) -> jax.Array:
    """theta_mask broadcast against a θ-shaped array: [J, D_max] for
    scalar targets, [J, D_max, 1] against multi-output [J, D_max, Dy]."""
    mask = packed.theta_mask
    return mask if v.ndim == mask.ndim else mask[..., None]


@partial(jax.jit, static_argnames=("iters", "backend", "shifted"))
def _power_iteration_lam(packed, v0, shift, *, iters, backend, shifted):
    """Jitted power iteration on the homogeneous part of F (b cancels in
    differences): ONE device program for all `iters` rounds, one norm per
    round. v is normalized before the loop, so ‖v‖ = 1 on every iterate
    and λ = ‖M v‖ directly — no redundant ‖v‖ recompute, no per-round
    host sync (the caller pulls the final scalar once)."""
    zero = jnp.zeros_like(packed.d)
    b = step_batched(packed, zero, backend=backend)      # F(0) = b
    v0 = v0 / jnp.maximum(jnp.linalg.norm(v0), 1e-30)

    def body(_, carry):
        v, _ = carry
        mv = step_batched(packed, v, backend=backend) - b    # M v
        fv = shift * v - mv if shifted else mv
        lam = jnp.linalg.norm(fv)
        return fv / jnp.maximum(lam, 1e-30), lam

    _, lam = lax.fori_loop(0, iters, body,
                           (v0, jnp.zeros((), packed.d.dtype)))
    return lam


def power_iteration_mu_max(packed: PackedProblem, iters: int = 50,
                           seed: int = 0, backend: str = "xla") -> float:
    """Estimate ρ(M) with power iteration on the *homogeneous* part of F
    (b cancels in differences). Decentralized: each step is one Eq. 19
    round; the normalization uses a global norm (one scalar all-reduce —
    available in-network via gossip in practice). ``backend`` picks the
    round implementation (`step_batched`'s switch). Runs as one jitted
    `lax.fori_loop` with a single device→host transfer at the end."""
    _check_backend(backend)
    v = jax.random.normal(jax.random.PRNGKey(seed), packed.d.shape,
                          packed.d.dtype)
    v = v * _mask_like(packed, v)
    return float(_power_iteration_lam(
        packed, v, jnp.zeros((), packed.d.dtype), iters=iters,
        backend=backend, shifted=False))


def power_iteration_mu_min(packed: PackedProblem, mu_max: float,
                           iters: int = 50, seed: int = 1,
                           backend: str = "xla") -> float:
    """Estimate the BOTTOM of spec(M) via power iteration on the shifted
    operator μ_max·I − M (its top eigenvalue is μ_max − μ_min). The Eq. 19
    operator is similar to a symmetric matrix (real spectrum) but not PSD
    in general — a small negative tail is typical, and Chebyshev diverges
    if the interval excludes it (the acceleration polynomial grows
    exponentially outside [μ_min, μ_max]). Same single-program /
    single-transfer shape as `power_iteration_mu_max`."""
    _check_backend(backend)
    v = jax.random.normal(jax.random.PRNGKey(seed), packed.d.shape,
                          packed.d.dtype)
    v = v * _mask_like(packed, v)
    lam = _power_iteration_lam(
        packed, v, jnp.asarray(mu_max, packed.d.dtype), iters=iters,
        backend=backend, shifted=True)
    return mu_max - float(lam)


def estimate_spectral_interval(packed: PackedProblem, iters: int = 60,
                               backend: str = "xla"
                               ) -> tuple[float, float]:
    """Safe (μ_min, μ_max) for Chebyshev: power-iteration estimates with
    outward safety margins on both ends."""
    mu_hi = safe_mu(power_iteration_mu_max(packed, iters, backend=backend))
    mu_lo_est = power_iteration_mu_min(packed, mu_hi, iters,
                                       backend=backend)
    spread = mu_hi - mu_lo_est
    mu_lo = mu_lo_est - 0.05 * spread - 0.002
    return mu_lo, mu_hi


def chebyshev_coefficients(mu_max: float, mu_min: float,
                           num_iters: int
                           ) -> tuple[np.ndarray, np.ndarray]:
    """The (α_k, β_k) schedule for `num_iters` Chebyshev steps, as float64
    NumPy tables — the SINGLE source of the recurrence (Golub & Van Loan
    §10.1.5) consumed by the host scan, the packed XLA/Pallas paths, and
    the fused kernel's scalar-prefetch tables:

      α₀ = 1/d,   β₀ = 0,
      β₁ = ½(c/d)²          ← first step is special (T₁(μ) = μ, not the
                              generic 2μT_k − T_{k−1} recurrence); using
                              the generic formula here gives ¼(c/d)² and
                              a measurably slower — no longer optimal —
                              error polynomial
      α_k = 1/(d − β_k/α_{k−1}),  β_k = (c·α_{k−1}/2)²   for k ≥ 2

    with d = (a+b)/2, c = (b−a)/2 on [a, b] = [1−μ_max, 1−μ_min].
    """
    a_lo, b_hi = 1.0 - float(mu_max), 1.0 - float(mu_min)
    d = (a_lo + b_hi) / 2.0
    c = (b_hi - a_lo) / 2.0
    alphas = np.empty(num_iters, np.float64)
    betas = np.empty(num_iters, np.float64)
    alpha_prev = None
    for k in range(num_iters):
        if k == 0:
            alpha, beta = 1.0 / d, 0.0
        else:
            beta = 0.5 * (c / d) ** 2 if k == 1 \
                else (c * alpha_prev / 2.0) ** 2
            alpha = 1.0 / (d - beta / alpha_prev)
        alphas[k] = alpha
        betas[k] = beta
        alpha_prev = alpha
    return alphas, betas


def chebyshev_scan(apply_f: Callable[[jax.Array], jax.Array],
                   theta0: jax.Array, alphas: jax.Array,
                   betas: jax.Array, *, theta_star: jax.Array | None = None,
                   p0: jax.Array | None = None,
                   record_deltas: bool = False):
    """The shared (α, β)-table `lax.scan` every host/XLA Chebyshev path
    runs: one F-application per step, two-term recurrence on the search
    direction p (θ_{k+1} = θ_k + α_k p_k with p_k = r_k + β_k p_{k−1},
    i.e. Δ_k = α_k p_k), coefficients consumed from the precomputed
    tables (`chebyshev_coefficients`). Returns ``(theta, p, errs)`` —
    ``errs`` is the per-step ‖θ_k − θ*‖ trace when ``theta_star`` is
    given (how `rounds_to_tolerance` counts rounds without per-round
    host syncs), else None. ``p0`` resumes the recurrence mid-schedule
    (chunked callers); the cold start is p₀ = 0 (β₀ = 0 makes the first
    step pure residual descent either way). ``record_deltas=True``
    appends a fourth output: the per-step max|Δθ| = max|α_k p_k| trace
    (the `repro.obs` convergence-residual convention — the actual step
    taken, not the F-residual), folded into the same scan."""
    if p0 is None:
        p0 = jnp.zeros_like(theta0)

    def body(carry, ab):
        theta, p = carry
        alpha, beta = ab
        resid = apply_f(theta) - theta
        p = resid + beta * p
        theta_new = theta + alpha * p
        err = None if theta_star is None \
            else jnp.linalg.norm(theta_new - theta_star)
        delta = jnp.max(jnp.abs(theta_new - theta)) if record_deltas \
            else None
        return (theta_new, p), (err, delta)

    (theta, p), (errs, deltas) = lax.scan(body, (theta0, p0),
                                          (alphas, betas))
    if record_deltas:
        return theta, p, errs, deltas
    return theta, p, errs


def chebyshev_solve(
    apply_f: Callable[[jax.Array], jax.Array],
    theta0: jax.Array,
    mu_max: float,
    mu_min: float = 0.0,
    num_iters: int = 100,
) -> jax.Array:
    """Chebyshev iteration for θ = F(θ), F(θ) = Mθ + b, spec(M)⊂[μmin,μmax].

    Standard two-term recurrence (Golub & Van Loan §10.1.5) on A = I − M
    with eigenvalue interval [a, b] = [1−μ_max, 1−μ_min]:
      r_k = b − Aθ_k = F(θ_k) − θ_k
      p_k = r_k + β_k p_{k−1},   θ_{k+1} = θ_k + α_k p_k
      α_0 = 1/d, β_1 = ½(c/d)², α_k = 1/(d − β_k/α_{k−1}),
      β_k = (c·α_{k−1}/2)²   with d = (a+b)/2, c = (b−a)/2.
    The schedule comes from `chebyshev_coefficients` and runs through the
    shared `chebyshev_scan` (k iterates match the closed-form Chebyshev
    error polynomial T_k((d−λ)/c)/T_k(d/c) — pinned at rtol 1e-9 by
    `tests/test_acceleration_chebyshev.py`).
    """
    if num_iters == 0:
        return theta0
    alphas, betas = chebyshev_coefficients(mu_max, mu_min, num_iters)
    theta, _, _ = chebyshev_scan(apply_f, theta0,
                                 jnp.asarray(alphas, theta0.dtype),
                                 jnp.asarray(betas, theta0.dtype))
    return theta


def _chebyshev_fused(packed: PackedProblem, alphas: np.ndarray,
                     betas: np.ndarray,
                     chunk_rounds: int | None, trace: bool = False):
    """backend="pallas_fused": run the whole (α, β) schedule — or each
    `chunk_rounds` slice of it — as one Chebyshev `dekrr_solve`
    pallas_call (coefficients via scalar prefetch, the direction state
    in a VMEM table; chunk boundaries chain (θ, p) bit-exactly). With
    ``trace`` the same dispatches also fill the per-(round, node)
    max|Δθ| block — returned as [R, J] alongside θ, concatenated across
    chunks."""
    from repro.kernels import ops

    dtype = packed.d.dtype
    theta = jnp.zeros_like(packed.d)
    p_dir = jnp.zeros_like(packed.d)
    self_idx = jnp.arange(packed.num_nodes, dtype=jnp.int32)
    a = jnp.asarray(alphas, dtype)
    b = jnp.asarray(betas, dtype)
    num_iters = int(a.shape[0])

    def call(th, pv, aa, bb):
        return ops.dekrr_cheb_solve(
            packed.g, packed.d, packed.s, packed.p, th, pv,
            packed.nbr_idx, self_idx, packed.nbr_mask, aa, bb,
            trace=trace)

    if chunk_rounds is None or chunk_rounds >= num_iters:
        outs = call(theta, p_dir, a, b)
        return (outs[0], outs[2]) if trace else outs[0]

    n_full, rem = divmod(num_iters, chunk_rounds)

    def chunk_fn(carry, xs):
        th, pv = carry
        aa, bb = xs
        outs = call(th, pv, aa, bb)
        return (outs[0], outs[1]), (outs[2] if trace else None)

    cut = n_full * chunk_rounds
    (theta, p_dir), trs = lax.scan(
        chunk_fn, (theta, p_dir),
        (a[:cut].reshape(n_full, chunk_rounds),
         b[:cut].reshape(n_full, chunk_rounds)))
    outs_rem = None
    if rem:
        outs_rem = call(theta, p_dir, a[cut:], b[cut:])
        theta = outs_rem[0]
    if not trace:
        return theta
    res = trs.reshape(-1, packed.num_nodes)
    if outs_rem is not None:
        res = jnp.concatenate([res, outs_rem[2]])
    return theta, res


def chebyshev_solve_packed(packed: PackedProblem, mu_max: float,
                           mu_min: float = 0.0,
                           num_iters: int = 100,
                           backend: str = "xla",
                           chunk_rounds: int | None = None,
                           return_trace: bool = False):
    """Chebyshev on the packed batched runtime (same exchange as Alg. 1).

    ``backend`` routes each F-application through `step_batched`'s switch:
    "xla" / "pallas" scan the shared (α, β) table one round kernel at a
    time; "pallas_fused" feeds the precomputed table through scalar
    prefetch and runs ALL rounds (or each ``chunk_rounds`` slice — one
    pallas_call per chunk, default one for the whole schedule) inside the
    fused multi-round kernel, with the Δ recurrence state VMEM-resident
    (`repro.kernels.dekrr_solve`). The fused path matches the host
    recurrence at rtol 1e-9 under x64 and is chunk-size bit-invariant;
    ``chunk_rounds`` is ignored on the per-round backends.

    ``return_trace=True`` returns ``(theta, SolveTrace)`` with the
    per-round max|Δθ| = max|α_k p_k| residual trace — the actual
    Chebyshev step, not the F-residual — recorded inside the existing
    scan (per-round backends) or the kernel's own trace block (fused):
    no host callback, no extra dispatch, chunk-invariant."""
    _check_backend(backend)
    if chunk_rounds is not None and chunk_rounds < 1:
        raise ValueError(f"chunk_rounds must be >= 1, got {chunk_rounds}")
    num_iters = int(num_iters)
    if num_iters == 0:
        theta = jnp.zeros_like(packed.d)
        if return_trace:
            return theta, SolveTrace(
                residuals=jnp.zeros((0,), packed.d.dtype))
        return theta
    alphas, betas = chebyshev_coefficients(mu_max, mu_min, num_iters)
    if backend == "pallas_fused":
        if not return_trace:
            return _chebyshev_fused(packed, alphas, betas, chunk_rounds)
        theta, res = _chebyshev_fused(packed, alphas, betas, chunk_rounds,
                                      trace=True)
        return theta, SolveTrace(residuals=jnp.max(res, axis=1))
    apply_f = lambda th: step_batched(packed, th, backend=backend)
    dtype = packed.d.dtype
    if return_trace:
        theta, _, _, deltas = chebyshev_scan(
            apply_f, jnp.zeros_like(packed.d), jnp.asarray(alphas, dtype),
            jnp.asarray(betas, dtype), record_deltas=True)
        return theta, SolveTrace(residuals=deltas)
    theta, _, _ = chebyshev_scan(apply_f, jnp.zeros_like(packed.d),
                                 jnp.asarray(alphas, dtype),
                                 jnp.asarray(betas, dtype))
    return theta


@partial(jax.jit, static_argnames=("max_rounds", "backend"))
def _plain_error_curve(packed, theta_star, *, max_rounds, backend):
    """‖θ_k − θ*‖ for k = 1…max_rounds of the plain Eq. 19 iteration —
    one scanned device program, errors pulled to the host in a single
    transfer (the old per-round float() loop cost 2·rounds dispatches)."""
    def body(theta, _):
        theta = step_batched(packed, theta, backend=backend)
        return theta, jnp.linalg.norm(theta - theta_star)

    _, errs = lax.scan(body, jnp.zeros_like(packed.d), None,
                       length=max_rounds)
    return errs


@partial(jax.jit, static_argnames=("backend",))
def _cheb_error_curve(packed, theta_star, alphas, betas, *, backend):
    """Chebyshev counterpart of `_plain_error_curve` — the SAME shared
    scan as `chebyshev_solve` (the β₁ fix lands exactly once)."""
    apply_f = lambda th: step_batched(packed, th, backend=backend)
    _, _, errs = chebyshev_scan(apply_f, jnp.zeros_like(packed.d),
                                alphas, betas, theta_star=theta_star)
    return errs


def rounds_to_tolerance(packed: PackedProblem, theta_star: jax.Array,
                        tol: float = 1e-6, max_rounds: int = 5000,
                        mu_max: float | None = None,
                        mu_min: float | None = None,
                        backend: str = "xla"
                        ) -> tuple[int, int]:
    """(plain rounds, chebyshev rounds) to reach relative error ≤ tol.

    Both curves run as single scanned device programs emitting the
    per-round error trace; the first tol crossing is found host-side from
    one transfer. The Chebyshev curve consumes the same
    `chebyshev_coefficients` table as every other consumer — this
    function no longer carries its own copy of the recurrence."""
    _check_backend(backend)
    if mu_max is None or mu_min is None:
        lo, hi = estimate_spectral_interval(packed, backend=backend)
        mu_max = hi if mu_max is None else mu_max
        mu_min = lo if mu_min is None else mu_min
    norm_star = float(jnp.linalg.norm(theta_star))
    target = tol * norm_star

    def first_crossing(errs: np.ndarray) -> int:
        hit = errs <= target
        return int(np.argmax(hit)) + 1 if hit.any() else max_rounds

    plain_errs = np.asarray(_plain_error_curve(
        packed, theta_star, max_rounds=max_rounds, backend=backend))
    alphas, betas = chebyshev_coefficients(mu_max, mu_min, max_rounds)
    dtype = packed.d.dtype
    cheb_errs = np.asarray(_cheb_error_curve(
        packed, theta_star, jnp.asarray(alphas, dtype),
        jnp.asarray(betas, dtype), backend=backend))
    return first_crossing(plain_errs), first_crossing(cheb_errs)
