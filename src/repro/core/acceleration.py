"""Beyond-paper optimization: Chebyshev semi-iterative acceleration of the
Eq. 19 fixed-point iteration.

The paper's solver is the stationary iteration θ^{k+1} = F(θ^k) = Mθ^k + b,
whose error contracts at ρ(M) — measured ≈0.95–0.999 on the paper's own
operating points, i.e. hundreds-to-thousands of communication rounds. Since
communication rounds are the paper's cost metric (Σ_j |N_j| D_j per round),
accelerating the *iteration count* at identical per-round communication is
a direct improvement on the paper's own objective.

Chebyshev iteration on A·θ = b with A = I − M and spec(M) ⊂ [μ_min, μ_max]
(hence spec(A) ⊂ [1−μ_max, 1−μ_min]) achieves the optimal polynomial rate
  r_cheb = (√κ − 1)/(√κ + 1),  κ = (1 − μ_min)/(1 − μ_max),
vs r_plain = μ_max: e.g. μ_max = 0.95, μ_min = 0 → 28 rounds/decade → 7
rounds/decade (≈4×), and the advantage grows as ρ(M) → 1 (√ of the
iteration count). Each Chebyshev step applies F exactly once — one θ
exchange with one-hop neighbors — so per-round cost, privacy and topology
are identical to Algorithm 1. The residual r = F(θ) − θ is local to each
node; the scalar recurrence (α_k, β_k) is precomputed offline from the
spectral-interval estimate, so no extra consensus is needed.

Both interval ends are estimated by distributed power iteration on F
(itself only neighbor exchanges): μ_max directly, μ_min via the shifted
operator μ_max·I − M. The spectrum is real (M is similar to a symmetric
matrix) but NOT nonnegative in general — a small negative tail
(min eig ≈ −0.06 measured on the houses stand-in) makes a [0, μ_max]
interval diverge, because the acceleration polynomial grows exponentially
outside its interval. ``estimate_spectral_interval`` adds outward safety
margins on both ends (over-covering only costs a slightly weaker rate).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.dist.dekrr_spmd import PackedProblem, step_batched


def safe_mu(mu_est: float, margin: float = 0.02) -> float:
    """Safety-inflate a power-iteration estimate of ρ(M): Chebyshev is
    robust to OVER-estimating μ_max (slightly slower rate) but stalls or
    diverges if the true top eigenvalue lies outside [μ_min, μ_max]
    (power iteration converges from below when the eigen-gap is small)."""
    return min(mu_est * (1.0 + margin) + 0.002, 0.99999)


def power_iteration_mu_max(packed: PackedProblem, iters: int = 50,
                           seed: int = 0, backend: str = "xla") -> float:
    """Estimate ρ(M) with power iteration on the *homogeneous* part of F
    (b cancels in differences). Decentralized: each step is one Eq. 19
    round; the normalization uses a global norm (one scalar all-reduce —
    available in-network via gossip in practice). ``backend`` picks the
    round implementation (`step_batched`'s switch)."""
    v = jax.random.normal(jax.random.PRNGKey(seed), packed.d.shape,
                          packed.d.dtype)
    v = v * packed.theta_mask
    zero = jnp.zeros_like(packed.d)
    b = step_batched(packed, zero, backend=backend)  # F(0) = b
    lam = 0.0
    for _ in range(iters):
        fv = step_batched(packed, v, backend=backend) - b      # M v
        lam = float(jnp.linalg.norm(fv) / jnp.maximum(
            jnp.linalg.norm(v), 1e-30))
        v = fv / jnp.maximum(jnp.linalg.norm(fv), 1e-30)
    return lam


def power_iteration_mu_min(packed: PackedProblem, mu_max: float,
                           iters: int = 50, seed: int = 1,
                           backend: str = "xla") -> float:
    """Estimate the BOTTOM of spec(M) via power iteration on the shifted
    operator μ_max·I − M (its top eigenvalue is μ_max − μ_min). The Eq. 19
    operator is similar to a symmetric matrix (real spectrum) but not PSD
    in general — a small negative tail is typical, and Chebyshev diverges
    if the interval excludes it (the acceleration polynomial grows
    exponentially outside [μ_min, μ_max])."""
    v = jax.random.normal(jax.random.PRNGKey(seed), packed.d.shape,
                          packed.d.dtype)
    v = v * packed.theta_mask
    zero = jnp.zeros_like(packed.d)
    b = step_batched(packed, zero, backend=backend)
    lam = 0.0
    for _ in range(iters):
        mv = step_batched(packed, v, backend=backend) - b
        fv = mu_max * v - mv
        lam = float(jnp.linalg.norm(fv) / jnp.maximum(
            jnp.linalg.norm(v), 1e-30))
        v = fv / jnp.maximum(jnp.linalg.norm(fv), 1e-30)
    return mu_max - lam


def estimate_spectral_interval(packed: PackedProblem, iters: int = 60,
                               backend: str = "xla"
                               ) -> tuple[float, float]:
    """Safe (μ_min, μ_max) for Chebyshev: power-iteration estimates with
    outward safety margins on both ends."""
    mu_hi = safe_mu(power_iteration_mu_max(packed, iters, backend=backend))
    mu_lo_est = power_iteration_mu_min(packed, mu_hi, iters,
                                       backend=backend)
    spread = mu_hi - mu_lo_est
    mu_lo = mu_lo_est - 0.05 * spread - 0.002
    return mu_lo, mu_hi


def chebyshev_solve(
    apply_f: Callable[[jax.Array], jax.Array],
    theta0: jax.Array,
    mu_max: float,
    mu_min: float = 0.0,
    num_iters: int = 100,
) -> jax.Array:
    """Chebyshev iteration for θ = F(θ), F(θ) = Mθ + b, spec(M)⊂[μmin,μmax].

    Standard two-term recurrence (Golub & Van Loan §10.1.5) on A = I − M
    with eigenvalue interval [a, b] = [1−μ_max, 1−μ_min]:
      r_k = b − Aθ_k = F(θ_k) − θ_k
      Δ_k = α_k r_k + β_k Δ_{k−1},   θ_{k+1} = θ_k + Δ_k
      α_0 = 1/d, β_1 = ½(c/d)², α_k = 1/(d − β_k/α_{k−1}),
      β_k = (c·α_{k−1}/2)²   with d = (a+b)/2, c = (b−a)/2.
    """
    a_lo, b_hi = 1.0 - mu_max, 1.0 - mu_min
    d = (a_lo + b_hi) / 2.0
    c = (b_hi - a_lo) / 2.0

    theta = theta0
    delta = jnp.zeros_like(theta0)
    alpha_prev = None
    for k in range(num_iters):
        r = apply_f(theta) - theta
        if k == 0:
            alpha, beta = 1.0 / d, 0.0
        else:
            beta = (c * alpha_prev / 2.0) ** 2
            alpha = 1.0 / (d - beta / alpha_prev)
        delta = alpha * r + beta * delta
        theta = theta + delta
        alpha_prev = alpha
    return theta


def chebyshev_solve_packed(packed: PackedProblem, mu_max: float,
                           mu_min: float = 0.0,
                           num_iters: int = 100,
                           backend: str = "xla") -> jax.Array:
    """Chebyshev on the packed batched runtime (same exchange as Alg. 1).
    ``backend`` routes each F-application through `step_batched`'s switch
    — "pallas" runs the fused round kernel per Chebyshev step (the
    recurrence needs every residual r_k = F(θ_k) − θ_k, so rounds cannot
    be fused past the α/β update; the fused-solve kernel applies to the
    plain iteration only)."""
    apply_f = lambda th: step_batched(packed, th, backend=backend)
    return chebyshev_solve(apply_f, jnp.zeros_like(packed.d), mu_max,
                           mu_min, num_iters)


def rounds_to_tolerance(packed: PackedProblem, theta_star: jax.Array,
                        tol: float = 1e-6, max_rounds: int = 5000,
                        mu_max: float | None = None,
                        mu_min: float | None = None,
                        backend: str = "xla"
                        ) -> tuple[int, int]:
    """(plain rounds, chebyshev rounds) to reach relative error ≤ tol."""
    if mu_max is None or mu_min is None:
        lo, hi = estimate_spectral_interval(packed, backend=backend)
        mu_max = hi if mu_max is None else mu_max
        mu_min = lo if mu_min is None else mu_min
    norm_star = float(jnp.linalg.norm(theta_star))

    # plain Eq. 19
    theta = jnp.zeros_like(packed.d)
    plain = max_rounds
    for k in range(max_rounds):
        theta = step_batched(packed, theta, backend=backend)
        if float(jnp.linalg.norm(theta - theta_star)) <= tol * norm_star:
            plain = k + 1
            break

    # chebyshev
    apply_f = lambda th: step_batched(packed, th, backend=backend)
    a_lo, b_hi = 1.0 - mu_max, 1.0 - mu_min
    d = (a_lo + b_hi) / 2.0
    c = (b_hi - a_lo) / 2.0
    theta = jnp.zeros_like(packed.d)
    delta = jnp.zeros_like(packed.d)
    alpha_prev = None
    cheb = max_rounds
    for k in range(max_rounds):
        r = apply_f(theta) - theta
        if k == 0:
            alpha, beta = 1.0 / d, 0.0
        else:
            beta = (c * alpha_prev / 2.0) ** 2
            alpha = 1.0 / (d - beta / alpha_prev)
        delta = alpha * r + beta * delta
        theta = theta + delta
        alpha_prev = alpha
        if float(jnp.linalg.norm(theta - theta_star)) <= tol * norm_star:
            cheb = k + 1
            break
    return plain, cheb
