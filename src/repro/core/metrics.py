"""Evaluation metrics (paper §IV-A)."""
from __future__ import annotations

import jax.numpy as jnp


def rse(pred, y) -> float:
    """Relative square error: Σ(f(x)−y)² / Σ(y−ȳ)²."""
    pred = jnp.asarray(pred).reshape(-1)
    y = jnp.asarray(y).reshape(-1)
    num = jnp.sum((pred - y) ** 2)
    den = jnp.sum((y - jnp.mean(y)) ** 2)
    return float(num / den)


def mse(pred, y) -> float:
    pred = jnp.asarray(pred).reshape(-1)
    y = jnp.asarray(y).reshape(-1)
    return float(jnp.mean((pred - y) ** 2))
