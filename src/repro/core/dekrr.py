"""DeKRR-DDRF: decentralized KRR with per-node data-dependent random features.

Faithful implementation of Algorithm 1. Notation matches the paper:

  Z_{i,j} := Z_i(X_j) ∈ R^{D_i × N_j}   (node i's features on node j's data)
  c̃_{j,p} := c_{j,p} / (N |N̂_j|),  split into c̃_{j,self} (p=j) and
  c̃_{j,nei} (p ∈ N_j);  λ_j := λN/(J N_j) so the local ridge term is (λ/J)I.

Pre-iteration (one round of one-hop exchange, Alg. 1 lines 3–7):
  G_j = [ (1/N + 2c̃_{j,self} + |N_j| c̃_{j,nei}) Z_{j,j} Z_{j,j}ᵀ + (λ/J) I
          + Σ_{p∈N_j} c̃_{p,nei} Z_{j,p} Z_{j,p}ᵀ ]⁻¹                (Eq. 17)
  d_j = (1/N) Z_{j,j} Y_jᵀ
  S_j = 2 c̃_{j,self} Z_{j,j} Z_{j,j}ᵀ
  P_{j,p} = c̃_{j,nei} Z_{j,j} Z_{p,j}ᵀ + c̃_{p,nei} Z_{j,p} Z_{p,p}ᵀ

Iteration (communicates only θ, Alg. 1 lines 9–14):
  θ_j^{k+1} = G_j ( d_j + S_j θ_j^k + Σ_{p∈N_j} P_{j,p} θ_p^k )      (Eq. 19)

This module is the *reference* (ragged, per-node loop) implementation; the
packed/batched and SPMD nodes-on-devices runtimes live in
repro/dist/dekrr_spmd.py (`pack_problem` / `step_batched` /
`make_spmd_solver`) and are pinned to this one by the parity tests in
tests/test_dekrr_spmd.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Topology
from repro.core.rff import FeatureMap, featurize


@dataclasses.dataclass
class NodeData:
    """One node's shard.

    x: [d, N_j] inputs (paper layout, columns are samples).
    y: [N_j] scalar targets, or [N_j, Dy] multi-output targets — the
       trailing output axis threads through every layer (θ becomes
       [D_j, Dy]; the Eq. 17 auxiliaries depend only on the features, so
       the iteration is unchanged per output column).
    bags: optional [N_j] int bag ids for aggregate-observation KRR
       (aodisaggregation style): only bag-level label sums are observed,
       so y then has one row per BAG (B_j = y.shape[0]) and every feature
       block on this node's data is column-aggregated within bags before
       entering the Eq. 17 build — β = (Agg(Z)Agg(Z)ᵀ + nλI)⁻¹Agg(Z)z.
       With singleton bags (ids 0…N_j−1) Agg is the identity and the
       standard build is recovered exactly.
    """

    x: jax.Array              # [d, N_j]
    y: jax.Array              # [N_j] or [N_j, Dy] (bag-level when bagged)
    bags: jax.Array | None = None   # [N_j] int bag ids, or None

    @property
    def num_samples(self) -> int:
        return self.x.shape[1]

    @property
    def num_bags(self) -> int:
        """Observation count: bags when aggregated, samples otherwise."""
        return self.y.shape[0] if self.bags is not None \
            else self.num_samples

    @property
    def num_outputs(self) -> int:
        """Dy — trailing output width (1 for scalar [N] targets)."""
        return 1 if self.y.ndim == 1 else self.y.shape[1]


@dataclasses.dataclass(frozen=True)
class DeKRRConfig:
    lam: float = 1e-6            # global ridge λ
    c_nei: float = 1.0           # c_{j,nei} (paper: grid {2^i N}, i=-1..3)
    c_self_ratio: float = 5.0    # c_{j,self} = ratio · c_{j,nei} (paper: 5)
    num_iters: int = 300
    tol: float = 0.0             # early stop on max ‖Δθ‖∞ (0 = run all iters)


@dataclasses.dataclass
class AuxMatrices:
    """Per-node auxiliary matrices (Eq. 17), ragged lists over nodes."""

    g: list[jax.Array]                 # [D_j, D_j] (the inverse, applied)
    d: list[jax.Array]                 # [D_j]
    s: list[jax.Array]                 # [D_j, D_j]
    p: list[dict[int, jax.Array]]      # p[j][nb] : [D_j, D_nb]


@dataclasses.dataclass
class DeKRRState:
    theta: list[jax.Array]             # [D_j] (or [D_j, Dy]) per node
    iteration: int = 0


def _c_tilde(c: float, n_total: int, degree: int) -> float:
    """c̃ = c / (N |N̂_j|) with |N̂_j| = degree + 1."""
    return c / (n_total * (degree + 1))


class DeKRRSolver:
    """Builds Eq. 17 auxiliaries and runs the Eq. 19 fixed-point iteration."""

    def __init__(
        self,
        topology: Topology,
        feature_maps: Sequence[FeatureMap],
        data: Sequence[NodeData],
        config: DeKRRConfig = DeKRRConfig(),
        *,
        c_nei_per_node: Sequence[float] | None = None,
        gram_fn: Callable[[FeatureMap, jax.Array], jax.Array] | None = None,
        build_aux: bool = True,
    ):
        if len(feature_maps) != topology.num_nodes:
            raise ValueError("one feature map per node required")
        if len(data) != topology.num_nodes:
            raise ValueError("one data shard per node required")
        self.topology = topology
        self.feature_maps = list(feature_maps)
        self.data = list(data)
        self.config = config
        self.J = topology.num_nodes
        self.N = sum(nd.num_samples for nd in data)
        out_widths = {nd.num_outputs for nd in self.data}
        if len(out_widths) > 1:
            raise ValueError(
                f"all nodes must share one output width Dy, got "
                f"{sorted(out_widths)} — mixed scalar/multi-output shards "
                f"cannot reach network consensus on one θ layout")
        for j, nd in enumerate(self.data):
            if nd.bags is None:
                if nd.y.shape[0] != nd.num_samples:
                    raise ValueError(
                        f"node {j}: y has {nd.y.shape[0]} rows but x has "
                        f"{nd.num_samples} samples")
            else:
                bags = np.asarray(nd.bags)
                if bags.shape != (nd.num_samples,):
                    raise ValueError(
                        f"node {j}: bags must be [N_j]={nd.num_samples} "
                        f"int bag ids, got shape {bags.shape}")
                if not np.issubdtype(bags.dtype, np.integer):
                    raise ValueError(f"node {j}: bags must be integer "
                                     f"ids, got dtype {bags.dtype}")
                if bags.size and (bags.min() < 0
                                  or bags.max() >= nd.y.shape[0]):
                    raise ValueError(
                        f"node {j}: bag ids must lie in [0, B_j) with "
                        f"B_j = y.shape[0] = {nd.y.shape[0]}, got range "
                        f"[{bags.min()}, {bags.max()}]")
        if gram_fn is not None and any(nd.bags is not None
                                       for nd in self.data):
            raise ValueError(
                "gram_fn bypasses featurization, so the bag-aggregation "
                "operator cannot be applied to its Gram blocks — "
                "aggregate-observation nodes require the default "
                "featurize path")
        self.c_nei = (
            list(c_nei_per_node)
            if c_nei_per_node is not None
            else [config.c_nei] * self.J
        )
        self.c_self = [config.c_self_ratio * c for c in self.c_nei]
        self._gram_fn = gram_fn
        # build_aux=False defers the O(J·|N_j|) ragged per-node reference
        # build — callers heading straight to the batched packed runtime
        # (repro.dist.pack_problem, which recomputes Eq. 17 vmapped over
        # nodes) never pay for it. `solver.aux` still works lazily.
        self._aux: AuxMatrices | None = self._build_aux() if build_aux \
            else None

    @property
    def aux(self) -> AuxMatrices:
        """Ragged Eq. 17 auxiliaries, built lazily when deferred."""
        if self._aux is None:
            self._aux = self._build_aux()
        return self._aux

    def coupling_coefficients(self) -> tuple[np.ndarray, np.ndarray]:
        """Normalized (c̃_self [J], c̃_nei [J]) — c̃ = c / (N |N̂_j|).

        The coefficient arrays of Eq. 17 in batch layout; consumed by the
        batched `repro.dist.pack_problem` aux build.
        """
        hood = self.topology.degrees.astype(np.float64) + 1.0
        ct_self = np.asarray(self.c_self, np.float64) / (self.N * hood)
        ct_nei = np.asarray(self.c_nei, np.float64) / (self.N * hood)
        return ct_self, ct_nei

    # -- pre-iteration communication + auxiliary construction ---------------
    def cross_features(self, i: int, j: int) -> jax.Array:
        """Z_{i,j} = Z_i(X_j) ∈ R^{D_i × N_j}."""
        return featurize(self.feature_maps[i], self.data[j].x)

    def _agg_cols(self, z: jax.Array, j: int) -> jax.Array:
        """Apply node j's bag-aggregation operator to the columns of a
        feature block on node j's data: [D, N_j] → [D, B_j] with column b
        the sum over samples in bag b. Identity (the very same array) for
        un-bagged nodes, so the standard build is untouched."""
        bags = self.data[j].bags
        if bags is None:
            return z
        return jax.ops.segment_sum(
            z.T, jnp.asarray(bags), num_segments=self.data[j].num_bags).T

    def obs_features(self, i: int, j: int) -> jax.Array:
        """Observation-space feature block Agg_j(Z_{i,j}) — what the aux
        build and objective actually consume; equals `cross_features` for
        un-bagged nodes."""
        return self._agg_cols(self.cross_features(i, j), j)

    def _gram(self, i: int, j: int) -> jax.Array:
        """Agg_j(Z_{i,j}) Agg_j(Z_{i,j})ᵀ ∈ R^{D_i × D_i}; hot-spot
        (Pallas kernel path for un-bagged solvers)."""
        if self._gram_fn is not None:
            return self._gram_fn(self.feature_maps[i], self.data[j].x)
        z = self.obs_features(i, j)
        return z @ z.T

    def _build_aux(self) -> AuxMatrices:
        cfg, topo = self.config, self.topology
        g_list, d_list, s_list, p_list = [], [], [], []
        for j in range(self.J):
            deg = topo.degree(j)
            ct_self = _c_tilde(self.c_self[j], self.N, deg)
            ct_nei = _c_tilde(self.c_nei[j], self.N, deg)
            z_jj = self.obs_features(j, j)
            dj_feat = z_jj.shape[0]
            gram_jj = z_jj @ z_jj.T

            a = (1.0 / self.N + 2.0 * ct_self + deg * ct_nei) * gram_jj
            a = a + (cfg.lam / self.J) * jnp.eye(dj_feat, dtype=z_jj.dtype)
            for p in topo.neighbors(j):
                ct_p_nei = _c_tilde(self.c_nei[p], self.N, topo.degree(p))
                a = a + ct_p_nei * self._gram(j, p)
            g_list.append(jnp.linalg.inv(a))

            y_j = self.data[j].y
            if y_j.ndim == 1:
                d_list.append((z_jj @ y_j.reshape(-1)) / self.N)
            else:
                d_list.append((z_jj @ y_j) / self.N)      # [D_j, Dy]
            s_list.append(2.0 * ct_self * gram_jj)

            pj: dict[int, jax.Array] = {}
            for p in topo.neighbors(j):
                ct_p_nei = _c_tilde(self.c_nei[p], self.N, topo.degree(p))
                z_pj = self.obs_features(p, j)        # [D_p, B_j]
                z_jp = self.obs_features(j, p)        # [D_j, B_p]
                z_pp = self.obs_features(p, p)        # [D_p, B_p]
                pj[p] = ct_nei * (z_jj @ z_pj.T) + ct_p_nei * (z_jp @ z_pp.T)
            p_list.append(pj)
        return AuxMatrices(g=g_list, d=d_list, s=s_list, p=p_list)

    # -- iteration ------------------------------------------------------------
    def init_state(self) -> DeKRRState:
        # d_j is [D_j] for scalar targets and [D_j, Dy] for multi-output —
        # θ shares that shape, so zeros_like the aux keeps both cases on
        # one code path.
        return DeKRRState(
            theta=[jnp.zeros(self.aux.d[j].shape,
                             dtype=self.aux.d[j].dtype)
                   for j in range(self.J)]
        )

    def step(self, state: DeKRRState) -> DeKRRState:
        """One synchronous (Jacobi) round of Eq. 19 across all nodes."""
        new_theta = []
        for j in range(self.J):
            rhs = self.aux.d[j] + self.aux.s[j] @ state.theta[j]
            for p, pjp in self.aux.p[j].items():
                rhs = rhs + pjp @ state.theta[p]
            new_theta.append(self.aux.g[j] @ rhs)
        return DeKRRState(theta=new_theta, iteration=state.iteration + 1)

    def solve(self, state: DeKRRState | None = None,
              num_iters: int | None = None) -> DeKRRState:
        state = state or self.init_state()
        iters = num_iters if num_iters is not None else self.config.num_iters
        for _ in range(iters):
            new = self.step(state)
            if self.config.tol > 0:
                # One fused on-device reduction, ONE host sync per round —
                # float() inside a per-node loop would block on the device
                # J times per round.
                delta = float(jnp.max(jnp.stack([
                    jnp.max(jnp.abs(a - b))
                    for a, b in zip(new.theta, state.theta)
                ])))
                state = new
                if delta < self.config.tol:
                    break
            else:
                state = new
        return state

    def solve_exact(self) -> DeKRRState:
        """Infinite-iteration reference: solve (I − M)Θ = b directly, where
        θ^{k+1} = M θ^k + b is the Eq. 19 iteration. Requires assembling the
        global system (fusion-center only) — used for tests/benches as the
        limit point of Algorithm 1, never in the decentralized runtime."""
        dims = [fm.num_features for fm in self.feature_maps]
        off = np.concatenate([[0], np.cumsum(dims)])
        dt = int(off[-1])
        m = np.zeros((dt, dt))
        # trailing output axis (empty tuple for scalar targets) rides the
        # RHS: np.linalg.solve handles [dt] and [dt, Dy] alike.
        b = np.zeros((dt,) + np.asarray(self.aux.d[0]).shape[1:])
        for j in range(self.J):
            g = np.asarray(self.aux.g[j])
            b[off[j]:off[j + 1]] = g @ np.asarray(self.aux.d[j])
            m[off[j]:off[j + 1], off[j]:off[j + 1]] = g @ np.asarray(self.aux.s[j])
            for p, pjp in self.aux.p[j].items():
                m[off[j]:off[j + 1], off[p]:off[p + 1]] += g @ np.asarray(pjp)
        theta = np.linalg.solve(np.eye(dt) - m, b)
        return DeKRRState(
            theta=[jnp.asarray(theta[off[j]:off[j + 1]]) for j in range(self.J)],
            iteration=-1,
        )

    def spectral_radius(self) -> float:
        """ρ(M) of the iteration matrix — convergence rate diagnostic."""
        dims = [fm.num_features for fm in self.feature_maps]
        off = np.concatenate([[0], np.cumsum(dims)])
        dt = int(off[-1])
        m = np.zeros((dt, dt))
        for j in range(self.J):
            g = np.asarray(self.aux.g[j])
            m[off[j]:off[j + 1], off[j]:off[j + 1]] = g @ np.asarray(self.aux.s[j])
            for p, pjp in self.aux.p[j].items():
                m[off[j]:off[j + 1], off[p]:off[p + 1]] += g @ np.asarray(pjp)
        return float(np.max(np.abs(np.linalg.eigvals(m))))

    # -- objective (Eq. 13) ----------------------------------------------------
    def objective(self, theta: Sequence[jax.Array]) -> jax.Array:
        cfg, topo = self.config, self.topology
        total = jnp.asarray(0.0, dtype=theta[0].dtype)
        for j in range(self.J):
            deg = topo.degree(j)
            ct_self = _c_tilde(self.c_self[j], self.N, deg)
            ct_nei = _c_tilde(self.c_nei[j], self.N, deg)
            z_jj = self.obs_features(j, j)
            if theta[j].ndim == 1:
                resid = theta[j] @ z_jj - self.data[j].y.reshape(-1)
            else:
                resid = theta[j].T @ z_jj - self.data[j].y.T   # [Dy, B_j]
            total = total + jnp.sum(resid**2) / self.N
            total = total + (cfg.lam / self.J) * jnp.sum(theta[j] ** 2)
            # consensus penalties over N̂_j (p = j contributes 0)
            for p in topo.neighbors(j):
                z_pj = self.obs_features(p, j)
                if theta[j].ndim == 1:
                    gap = theta[j] @ z_jj - theta[p] @ z_pj
                else:
                    gap = theta[j].T @ z_jj - theta[p].T @ z_pj
                total = total + ct_nei * jnp.sum(gap**2)
            del ct_self  # self-term is identically zero in L (kept for clarity)
        return total

    # -- prediction -------------------------------------------------------------
    def predict(self, theta: Sequence[jax.Array], x: jax.Array,
                node: int | None = None) -> jax.Array:
        """f_j(x) for one node, or the network-average prediction.

        Scalar θ [D_j] → [Q]; multi-output θ [D_j, Dy] → [Q, Dy] via
        Z(x)ᵀ θ (queries lead, outputs trail)."""
        if node is not None:
            z = featurize(self.feature_maps[node], x)
            return theta[node] @ z if theta[node].ndim == 1 \
                else z.T @ theta[node]
        if theta[0].ndim == 1:
            preds = [theta[j] @ featurize(self.feature_maps[j], x)
                     for j in range(self.J)]
        else:
            preds = [featurize(self.feature_maps[j], x).T @ theta[j]
                     for j in range(self.J)]
        return jnp.mean(jnp.stack(preds), axis=0)


# -- Prop. 1 convergence bound -------------------------------------------------
def prop1_required_c_self(solver: DeKRRSolver) -> np.ndarray:
    """Per-node lower bound on c̃_{j,self} (Eq. 20), returned as the
    *unnormalized* c_{j,self} so it is directly comparable to config values."""
    topo, n = solver.topology, solver.N
    req = np.zeros(solver.J)
    for j in range(solver.J):
        deg = topo.degree(j)
        ct_nei = _c_tilde(solver.c_nei[j], n, deg)
        z_jj = solver.obs_features(j, j)
        gram_jj = z_jj @ z_jj.T
        acc = jnp.zeros_like(gram_jj)
        for p in topo.neighbors(j):
            ct_p = _c_tilde(solver.c_nei[p], n, topo.degree(p))
            acc = acc + ct_p * solver._gram(j, p)
        lam_max = jnp.linalg.eigvalsh(acc)[-1]
        lam_min = jnp.linalg.eigvalsh(gram_jj)[0]
        # dtype-aware floor: a 1e-300 literal flushes to 0.0 in float32,
        # turning a degenerate λ_min into inf/NaN instead of a huge bound
        tiny = jnp.finfo(lam_min.dtype).tiny
        ct_req = deg * ct_nei / 2.0 + lam_max / (2.0 * jnp.maximum(lam_min, tiny))
        req[j] = float(ct_req) * n * (deg + 1)   # un-normalize c̃ → c
    return req
