"""Random Fourier features (Rahimi & Recht, 2007) for shift-invariant kernels.

Feature-matrix convention follows the paper: Z(X) ∈ R^{D_feat × N} with
columns z(x_i). Two real-valued constructions for the Gaussian kernel
k(x, x') = exp(-||x - x'||² / (2σ²)):

  cos_sin  (Eq. 9):  ψ(ω, x) = 1/√D [cos(ωᵀx); sin(ωᵀx)]      (D_feat = 2D)
  cos_bias (Eq. 10): ψ(ω, x) = √(2/D) cos(ωᵀx + b), b ~ U[0,2π) (D_feat = D)

The scale is folded into the feature map so that z(x)ᵀz(x') ≈ k(x, x').
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class FeatureMap:
    """A concrete RFF map: frozen frequencies (and biases)."""

    omega: jax.Array          # [D, d]
    bias: jax.Array | None    # [D] for cos_bias, None for cos_sin
    kind: str                 # "cos_sin" | "cos_bias"

    # -- pytree plumbing (kind is static) ------------------------------------
    def tree_flatten(self):
        return (self.omega, self.bias), self.kind

    @classmethod
    def tree_unflatten(cls, kind, children):
        omega, bias = children
        return cls(omega=omega, bias=bias, kind=kind)

    @property
    def num_frequencies(self) -> int:
        return self.omega.shape[0]

    @property
    def num_features(self) -> int:
        d = self.omega.shape[0]
        return 2 * d if self.kind == "cos_sin" else d

    def __call__(self, x: jax.Array) -> jax.Array:
        """Featurize. x: [d, N] (paper layout) → Z: [num_features, N]."""
        return featurize(self, x)

    def subset(self, idx: jax.Array) -> "FeatureMap":
        """Select a subset of frequencies (DDRF top-D selection)."""
        return FeatureMap(
            omega=self.omega[idx],
            bias=None if self.bias is None else self.bias[idx],
            kind=self.kind,
        )


def sample_rff(key: jax.Array, dim: int, num_frequencies: int,
               sigma: float, kind: str = "cos_bias") -> FeatureMap:
    """Sample ω ~ N(0, σ⁻² I_d) (Gaussian kernel spectral density)."""
    if kind not in ("cos_sin", "cos_bias"):
        raise ValueError(f"unknown RFF kind {kind!r}")
    k_w, k_b = jax.random.split(key)
    omega = jax.random.normal(k_w, (num_frequencies, dim)) / sigma
    bias = None
    if kind == "cos_bias":
        bias = jax.random.uniform(k_b, (num_frequencies,), maxval=2 * jnp.pi)
    return FeatureMap(omega=omega, bias=bias, kind=kind)


@partial(jax.jit, static_argnames=())
def _featurize_cos_sin(omega: jax.Array, x: jax.Array) -> jax.Array:
    d = omega.shape[0]
    proj = omega @ x                                   # [D, N]
    scale = jnp.asarray(1.0 / jnp.sqrt(d), proj.dtype)
    return jnp.concatenate([jnp.cos(proj), jnp.sin(proj)], axis=0) * scale


@partial(jax.jit, static_argnames=())
def _featurize_cos_bias(omega: jax.Array, bias: jax.Array,
                        x: jax.Array) -> jax.Array:
    d = omega.shape[0]
    proj = omega @ x + bias[:, None]                   # [D, N]
    scale = jnp.sqrt(jnp.asarray(2.0 / d, proj.dtype))
    return jnp.cos(proj) * scale


def featurize(fmap: FeatureMap, x: jax.Array) -> jax.Array:
    """Z(X) ∈ R^{D_feat × N} for X ∈ R^{d × N}."""
    if x.ndim != 2:
        raise ValueError(f"x must be [d, N], got {x.shape}")
    if fmap.kind == "cos_sin":
        return _featurize_cos_sin(fmap.omega, x)
    return _featurize_cos_bias(fmap.omega, fmap.bias, x)


def gaussian_kernel(x: jax.Array, x2: jax.Array, sigma: float) -> jax.Array:
    """Exact Gaussian Gram matrix K ∈ R^{N×M} for X [d,N], X2 [d,M]."""
    sq = (
        jnp.sum(x * x, axis=0)[:, None]
        + jnp.sum(x2 * x2, axis=0)[None, :]
        - 2.0 * x.T @ x2
    )
    return jnp.exp(-jnp.maximum(sq, 0.0) / (2.0 * sigma**2))
