"""Data-dependent random feature (DDRF) selection.

Implements the two families the paper cites:

* energy / kernel-polarization score (Shahrampour et al., AAAI 2018 [33]):
  sample D0 candidate frequencies, score each by its alignment with the
  labels, keep the top-D. For a single cosine feature with bias,
      S(ω) = ( (1/N) Σ_i y_i ψ(ω, x_i) )²
  and for the paired cos/sin construction
      S(ω) = ( Σ_i y_i cos(ωᵀx_i) )² + ( Σ_i y_i sin(ωᵀx_i) )²  (scaled).
  This is the empirical estimate of E_{x,y}E_{x',y'}[y y' ψω(x) ψω(x')].

* ridge leverage scores (Li et al. 2021 [35]; Liu et al. 2020 [36]):
  with candidate feature matrix Φ ∈ R^{D0×N} (rows = features over data),
  the (primal, feature-space) ridge leverage of feature k is
      τ_k = [ Φ Φᵀ (Φ Φᵀ + λ N I)⁻¹ ]_{kk},
  computed from the D0×D0 Gram — O(D0² N + D0³). Features are then either
  taken top-D by τ or resampled with probability ∝ τ.

Because the scores are computed on *local* data, each node ends up with its
own feature map — the regime DeKRR-DDRF is designed for.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.rff import FeatureMap, featurize, sample_rff


def _fold_paired(per_feature: jax.Array, fmap: FeatureMap) -> jax.Array:
    """Collapse per-feature values to per-frequency scores.

    A cos_sin map carries two feature channels per frequency ω (the cos row
    and the sin row, stacked [cos; sin]); both score families assign ω the
    SUM of its two channels' values. cos_bias maps are one channel per
    frequency, so this is the identity there. [num_features] →
    [num_frequencies].
    """
    if fmap.kind == "cos_sin":
        d = fmap.num_frequencies
        return per_feature[:d] + per_feature[d:]
    return per_feature


def _channels(fmap: FeatureMap, x: jax.Array) -> jax.Array:
    """Unscaled per-feature channel matrix [num_features, N]: the rows of
    the feature map before the 1/√D (or √(2/D)) normalization — the layout
    `_fold_paired` folds back to frequencies."""
    proj = fmap.omega @ x                              # [D, N]
    if fmap.kind == "cos_sin":
        return jnp.concatenate([jnp.cos(proj), jnp.sin(proj)], axis=0)
    return jnp.cos(proj + fmap.bias[:, None])


def energy_scores(fmap: FeatureMap, x: jax.Array, y: jax.Array) -> jax.Array:
    """Per-frequency energy score on data (X [d,N], Y [1,N] or [N]).

    Multi-output labels Y [N, Dy] score each frequency by the SUM of its
    per-output alignments — the natural extension of the polarization
    objective to a vector target (and identical to the scalar score at
    Dy=1)."""
    if y.ndim == 2 and y.shape[0] != 1:                # [N, Dy] labels
        n = y.shape[0]
        align = _channels(fmap, x) @ y                 # [num_features, Dy]
        return _fold_paired(jnp.sum(align**2, axis=1), fmap) / (n**2)
    y = y.reshape(-1)
    n = y.shape[0]
    align = _channels(fmap, x) @ y                     # [num_features]
    return _fold_paired(align**2, fmap) / (n**2)


def leverage_scores(fmap: FeatureMap, x: jax.Array,
                    lam: float = 1e-6) -> jax.Array:
    """Ridge leverage score per frequency (paired features are summed)."""
    z = featurize(fmap, x)                             # [D_feat, N]
    n = z.shape[1]
    g = z @ z.T                                        # [D_feat, D_feat]
    reg = lam * n * jnp.eye(g.shape[0], dtype=g.dtype)
    # τ = diag(G (G + λN I)^{-1}) via Cholesky solve.
    sol = jax.scipy.linalg.cho_solve(
        jax.scipy.linalg.cho_factor(g + reg), g)
    return _fold_paired(jnp.diag(sol), fmap)


def select_features(
    key: jax.Array,
    dim: int,
    num_features: int,
    sigma: float,
    x: jax.Array,
    y: jax.Array | None = None,
    *,
    method: Literal["plain", "energy", "leverage",
                    "leverage_resample"] = "energy",
    candidate_ratio: int = 20,
    kind: str = "cos_bias",
    leverage_lam: float = 1e-6,
) -> FeatureMap:
    """DDRF pipeline: sample D0 = ratio·D candidates, score, select D.

    ``method="plain"`` returns data-independent RFF (the DKLA setting).
    The paper follows [33] with D0/D = 20 (candidate_ratio).
    """
    if method == "plain":
        return sample_rff(key, dim, num_features, sigma, kind=kind)

    d0 = candidate_ratio * num_features
    k_cand, k_res = jax.random.split(key)
    cand = sample_rff(k_cand, dim, d0, sigma, kind=kind)

    if method == "energy":
        if y is None:
            raise ValueError("energy scoring requires labels y")
        scores = energy_scores(cand, x, y)
        idx = jnp.argsort(-scores)[:num_features]
    elif method == "leverage":
        scores = leverage_scores(cand, x, lam=leverage_lam)
        idx = jnp.argsort(-scores)[:num_features]
    elif method == "leverage_resample":
        scores = leverage_scores(cand, x, lam=leverage_lam)
        p = jnp.maximum(scores, 0.0)
        p = p / jnp.sum(p)
        idx = jax.random.choice(k_res, d0, shape=(num_features,),
                                replace=False, p=p)
    else:
        raise ValueError(f"unknown DDRF method {method!r}")
    return cand.subset(idx)
