"""Network topologies for decentralized learning.

The paper (§IV) uses a connected undirected graph with J=10 nodes, each with
4 neighbors — i.e. the circulant graph C_10(1, 2). Circulant graphs are the
TPU-native case: one-hop exchange maps onto ``lax.ppermute`` ring shifts of
offsets ±1, ±2 (``repro.dist.make_spmd_solver(mode="ppermute")``). Arbitrary
connected graphs are supported through ``neighbor_table()`` + the masked
all-gather fallback (``mode="allgather"``).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    """A symmetric, connected communication graph.

    Attributes:
      adjacency: [J, J] boolean numpy array, symmetric, zero diagonal.
      circulant_offsets: for circulant graphs, the positive shift set s such
        that node j is connected to (j ± s) mod J; None for general graphs.
    """

    adjacency: np.ndarray
    circulant_offsets: tuple[int, ...] | None = None

    def __post_init__(self):
        a = self.adjacency
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"adjacency must be square, got {a.shape}")
        if not np.array_equal(a, a.T):
            raise ValueError("graph must be undirected (symmetric adjacency)")
        if np.any(np.diag(a)):
            raise ValueError("no self-loops")
        if not self._connected():
            raise ValueError("graph must be connected")

    # -- basic structure ----------------------------------------------------
    @property
    def num_nodes(self) -> int:
        return self.adjacency.shape[0]

    def neighbors(self, j: int) -> list[int]:
        return list(np.nonzero(self.adjacency[j])[0])

    def degree(self, j: int) -> int:
        return int(self.adjacency[j].sum())

    @property
    def degrees(self) -> np.ndarray:
        return self.adjacency.sum(axis=1).astype(np.int32)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max())

    @property
    def edges(self) -> list[tuple[int, int]]:
        i, j = np.nonzero(np.triu(self.adjacency))
        return list(zip(i.tolist(), j.tolist()))

    def _connected(self) -> bool:
        J = self.adjacency.shape[0]
        seen = np.zeros(J, dtype=bool)
        stack = [0]
        seen[0] = True
        while stack:
            u = stack.pop()
            for v in np.nonzero(self.adjacency[u])[0]:
                if not seen[v]:
                    seen[v] = True
                    stack.append(int(v))
        return bool(seen.all())

    # -- padded neighbor table (for SPMD execution) --------------------------
    def neighbor_table(self) -> tuple[np.ndarray, np.ndarray]:
        """Returns (idx [J, max_degree], mask [J, max_degree]).

        idx[j, m] is the m-th neighbor of node j (or j itself where masked).
        Zero-padded rows are masked out; solver algebra must be exact under
        the mask (tested).
        """
        J, md = self.num_nodes, self.max_degree
        idx = np.zeros((J, md), dtype=np.int32)
        mask = np.zeros((J, md), dtype=bool)
        for j in range(J):
            nb = self.neighbors(j)
            idx[j, : len(nb)] = nb
            idx[j, len(nb):] = j  # self index as harmless padding
            mask[j, : len(nb)] = True
        return idx, mask


def circulant(num_nodes: int, offsets: Sequence[int] = (1, 2)) -> Topology:
    """Circulant graph C_J(offsets): node j ~ (j ± s) mod J for s in offsets.

    The paper's J=10, |N_j|=4 network is ``circulant(10, (1, 2))``.
    """
    offsets = tuple(sorted(set(int(s) for s in offsets)))
    if any(s <= 0 or s >= num_nodes for s in offsets):
        raise ValueError(f"offsets must be in (0, J), got {offsets}")
    a = np.zeros((num_nodes, num_nodes), dtype=bool)
    for j in range(num_nodes):
        for s in offsets:
            a[j, (j + s) % num_nodes] = True
            a[j, (j - s) % num_nodes] = True
    return Topology(adjacency=a, circulant_offsets=offsets)


def ring(num_nodes: int) -> Topology:
    return circulant(num_nodes, (1,))


def complete(num_nodes: int) -> Topology:
    a = ~np.eye(num_nodes, dtype=bool)
    offsets = tuple(range(1, num_nodes // 2 + 1))
    return Topology(adjacency=a, circulant_offsets=offsets)


def erdos_renyi(num_nodes: int, p: float, seed: int = 0,
                max_tries: int = 200) -> Topology:
    """Random connected G(J, p) graph (retry until connected)."""
    rng = np.random.default_rng(seed)
    for _ in range(max_tries):
        a = rng.random((num_nodes, num_nodes)) < p
        a = np.triu(a, 1)
        a = a | a.T
        try:
            return Topology(adjacency=a)
        except ValueError:
            continue
    raise RuntimeError(f"could not sample a connected G({num_nodes},{p})")


def star(num_nodes: int) -> Topology:
    """Star graph — worst-case degree imbalance (stress test)."""
    a = np.zeros((num_nodes, num_nodes), dtype=bool)
    a[0, 1:] = True
    a[1:, 0] = True
    return Topology(adjacency=a)
