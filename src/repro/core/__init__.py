"""Paper core: DeKRR-DDRF (Yang et al., TNNLS 2024)."""
from repro.core.async_gossip import (AsyncGossipConfig, AsyncGossipResult,
                                     activation_mask, activation_masks,
                                     async_gossip_solve, censor_schedule,
                                     edge_list, edges_from_slot_table)
from repro.core.baselines import (CentralizedKRR, CentralizedRF, DKLA,
                                  DKLAConfig, dkla_ddrf_feature_map)
from repro.core.ddrf import (energy_scores, leverage_scores, select_features)
from repro.core.dekrr import (AuxMatrices, DeKRRConfig, DeKRRSolver,
                              DeKRRState, NodeData, prop1_required_c_self)
from repro.core.graph import (Topology, circulant, complete, erdos_renyi,
                              ring, star)
from repro.core.metrics import mse, rse
from repro.core.rff import (FeatureMap, featurize, gaussian_kernel,
                            sample_rff)

__all__ = [
    "AsyncGossipConfig", "AsyncGossipResult", "AuxMatrices",
    "CentralizedKRR", "CentralizedRF", "DKLA", "DKLAConfig",
    "DeKRRConfig", "DeKRRSolver", "DeKRRState", "FeatureMap", "NodeData",
    "Topology", "activation_mask", "activation_masks",
    "async_gossip_solve", "censor_schedule", "circulant", "complete",
    "dkla_ddrf_feature_map", "edge_list", "edges_from_slot_table",
    "energy_scores", "erdos_renyi", "featurize", "gaussian_kernel",
    "leverage_scores", "mse", "prop1_required_c_self", "ring", "rse",
    "sample_rff", "select_features", "star",
]
