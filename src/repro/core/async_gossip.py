"""Asynchronous gossip DeKRR — randomized activation, staleness, censoring.

The paper's Eq. 19 consensus solve is a synchronous Jacobi iteration: every
node updates every round behind a global barrier. COKE (arXiv:2001.10133)
shows the barrier is not load-bearing — randomized node activation plus
communication censoring preserves convergence at a fraction of the
communication. This module is the *reference* layer of that variant: the
shared randomness (activation masks, censor thresholds) every runtime must
sample identically, and a ragged per-node ground-truth solver mirroring
`DeKRRSolver`'s auditable style. The packed/batched and SPMD production
counterparts live in `repro.dist.async_gossip` and are pinned to this one
by `tests/test_async_gossip.py` (rtol 1e-9 under x64).

One asynchronous round r (all runtimes, exactly this order):

  1. **Activate.** Sample the round's activation mask from the PRNG key:
     ``gossip="bernoulli"`` draws each node iid Bernoulli(prob);
     ``gossip="edge"`` draws ONE edge uniformly and activates its two
     endpoints (classic pairwise gossip). The mask depends only on
     (key, r), so every layer — and every device of the SPMD runtime —
     sees the same draw.
  2. **Update.** Active nodes run the Eq. 19 update against their
     *receive buffers* — the last θ each neighbor actually broadcast,
     NOT the neighbor's current iterate (per-edge staleness). Inactive
     nodes keep θ unchanged.
  3. **Censor.** An active node broadcasts its new θ unless censoring is
     on (``censor_tau > 0``) and ‖θ_j^new − θ_j^sent‖_∞ ≤ τ_r, where
     θ_j^sent is the last value j put on the wire and
     τ_r = censor_tau · censor_decay^r is the decaying COKE threshold.
  4. **Deliver.** A broadcast lands in the receive buffers of the
     sender's neighbors — all of them under "bernoulli", only the other
     edge endpoint under "edge". Buffers of non-broadcasting senders are
     untouched (the staleness invariant the property suite pins).

With prob = 1.0, gossip="bernoulli" and censoring off, every node is
active and broadcasts every round, every buffer holds the previous
round's iterate, and the recursion IS the synchronous Jacobi iteration —
the runtimes reproduce `repro.dist.solve_batched` bit-for-bit there.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

_GOSSIP_MODES = ("bernoulli", "edge")


@dataclasses.dataclass(frozen=True)
class AsyncGossipConfig:
    """Randomized-activation schedule shared by every async runtime.

    Attributes:
      prob:         per-node activation probability (``gossip="bernoulli"``
                    only; 1.0 = every node active, the synchronous limit).
      gossip:       "bernoulli" (iid node activation, COKE-style broadcast
                    delivery) or "edge" (one uniform edge per round,
                    pairwise delivery along that edge only).
      censor_tau:   initial communication-censoring threshold τ_0; 0.0
                    disables censoring (every active node broadcasts).
      censor_decay: geometric decay of the threshold, τ_r = τ_0 · decay^r.

    Frozen and hashable so the packed/SPMD solvers can take it as a static
    jit argument.
    """

    prob: float = 1.0
    gossip: str = "bernoulli"
    censor_tau: float = 0.0
    censor_decay: float = 1.0

    def __post_init__(self):
        if not 0.0 < self.prob <= 1.0:
            raise ValueError(f"prob must be in (0, 1], got {self.prob}")
        if self.gossip not in _GOSSIP_MODES:
            raise ValueError(f"gossip must be one of {_GOSSIP_MODES}, "
                             f"got {self.gossip!r}")
        if self.censor_tau < 0.0:
            raise ValueError(f"censor_tau must be >= 0, "
                             f"got {self.censor_tau}")
        if not 0.0 < self.censor_decay <= 1.0:
            raise ValueError(f"censor_decay must be in (0, 1], "
                             f"got {self.censor_decay}")

    @property
    def censored(self) -> bool:
        return self.censor_tau > 0.0

    @property
    def is_synchronous(self) -> bool:
        """True iff the schedule degenerates to the Jacobi iteration."""
        return (self.prob == 1.0 and self.gossip == "bernoulli"
                and not self.censored)


# --------------------------------------------------------------------------
# Shared randomness: every layer (ragged / packed / SPMD) samples THESE
# --------------------------------------------------------------------------
def edge_list(topology) -> np.ndarray:
    """Canonical undirected edge list [E, 2] with i < j, lexicographically
    sorted — the enumeration `gossip="edge"` sampling indexes into. The
    packed runtime derives the identical list from its slot table
    (`edges_from_slot_table`), which is what keeps edge draws consistent
    across layers."""
    edges = np.asarray(topology.edges, dtype=np.int32).reshape(-1, 2)
    return edges[np.lexsort((edges[:, 1], edges[:, 0]))]


def edges_from_slot_table(nbr_idx: np.ndarray,
                          nbr_mask: np.ndarray) -> np.ndarray:
    """`edge_list` reconstructed from a packed neighbor slot table.

    np.unique sorts rows lexicographically, so this matches `edge_list`'s
    ordering bit-for-bit for the same topology — required for identical
    `gossip="edge"` draws between the core reference (which holds the
    Topology) and the packed/SPMD runtimes (which hold only the table).
    """
    nbr_idx = np.asarray(nbr_idx)
    nbr_mask = np.asarray(nbr_mask)
    j_nodes, k_slots = nbr_idx.shape
    senders = np.broadcast_to(
        np.arange(j_nodes, dtype=np.int32)[:, None], (j_nodes, k_slots))
    live = nbr_mask != 0
    pairs = np.stack([senders[live], nbr_idx[live].astype(np.int32)],
                     axis=1)
    pairs = np.sort(pairs, axis=1)          # undirected: (min, max)
    if pairs.size == 0:
        return np.zeros((0, 2), dtype=np.int32)
    return np.unique(pairs, axis=0)


def activation_mask(key: jax.Array, round_idx, num_nodes: int, *,
                    prob: float = 1.0, gossip: str = "bernoulli",
                    edges: np.ndarray | None = None) -> jax.Array:
    """The round-r activation mask [J] bool — THE spec all layers share.

    Deterministic in (key, round_idx): the round key is
    `jax.random.fold_in(key, round_idx)`, so any runtime (and any device
    of the SPMD mesh) recomputes the same mask from the same key without
    coordination. "bernoulli" draws iid node activations; "edge" draws one
    edge index uniformly from the canonical `edges` list and activates its
    endpoints.
    """
    if gossip not in _GOSSIP_MODES:
        raise ValueError(f"gossip must be one of {_GOSSIP_MODES}, "
                         f"got {gossip!r}")
    k = jax.random.fold_in(key, round_idx)
    if gossip == "bernoulli":
        return jax.random.bernoulli(k, prob, (num_nodes,))
    if edges is None or len(edges) == 0:
        raise ValueError("gossip='edge' needs a non-empty edge list")
    e = jax.random.randint(k, (), 0, len(edges))
    uv = jnp.asarray(edges, dtype=jnp.int32)[e]
    return jnp.zeros((num_nodes,), bool).at[uv].set(True)


def activation_masks(key: jax.Array, num_rounds: int, num_nodes: int, *,
                     prob: float = 1.0, gossip: str = "bernoulli",
                     edges: np.ndarray | None = None) -> jax.Array:
    """All rounds' masks [R, J] bool; row r == `activation_mask(key, r, …)`
    exactly (the determinism property the test suite pins). Precomputed so
    the packed scan and the SPMD shard_map consume the same array instead
    of re-deriving per-round randomness inside traced code."""
    if num_rounds == 0:
        return jnp.zeros((0, num_nodes), bool)
    rounds = jnp.arange(num_rounds)
    return jax.vmap(
        lambda r: activation_mask(key, r, num_nodes, prob=prob,
                                  gossip=gossip, edges=edges))(rounds)


def censor_schedule(censor_tau: float, censor_decay: float,
                    num_rounds: int, dtype=jnp.float64) -> jax.Array:
    """τ_r = τ_0 · decay^r for r = 0 … R−1, as one [R] array. Every layer
    compares its broadcast deltas against THIS array (same bits), so a
    threshold crossing lands on the same round everywhere."""
    r = jnp.arange(num_rounds, dtype=dtype)
    return jnp.asarray(censor_tau, dtype) * \
        jnp.asarray(censor_decay, dtype) ** r


# --------------------------------------------------------------------------
# Ragged per-node reference solver (ground truth)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class AsyncGossipResult:
    """What the reference solve hands back for conformance pinning.

    theta:      ragged per-node iterates after the last executed round.
    rounds:     rounds actually executed (< num_rounds iff tol stopped it).
    broadcasts: total θ transmissions (post-censoring) over the run.
    deliveries: total per-edge buffer refreshes — equals broadcasts ×
                degree under "bernoulli", broadcasts × 1 under "edge".
    """

    theta: list[jax.Array]
    rounds: int
    broadcasts: int
    deliveries: int


def async_gossip_solve(solver, key: jax.Array, num_rounds: int,
                       config: AsyncGossipConfig = AsyncGossipConfig(),
                       *, tol: float = 0.0) -> AsyncGossipResult:
    """Ragged ground-truth async gossip solve on a `DeKRRSolver`.

    Deliberately written in `DeKRRSolver.step`'s auditable per-node style:
    Python loops over ragged auxiliaries, one matvec chain per active
    node, explicit per-edge receive buffers `buf[receiver][sender]` and
    per-node last-sent vectors. The packed (`repro.dist.async_gossip
    .async_solve_batched`) and SPMD (`make_async_spmd_solver`) runtimes
    are pinned to this function at rtol 1e-9 under x64.

    ``tol > 0`` stops after the first round with max_j ‖Δθ_j‖_∞ < tol —
    ignoring all-silent rounds, whose Δθ ≡ 0 is the schedule idling, not
    convergence (the converging round is counted, matching the packed
    solver's per-round freeze semantics).
    """
    topo, aux = solver.topology, solver.aux
    j_nodes = solver.J
    edges = edge_list(topo)
    masks = np.asarray(activation_masks(
        key, num_rounds, j_nodes, prob=config.prob, gossip=config.gossip,
        edges=edges if config.gossip == "edge" else None))
    thresholds = np.asarray(censor_schedule(
        config.censor_tau, config.censor_decay, num_rounds))

    theta = [jnp.zeros_like(aux.d[j]) for j in range(j_nodes)]
    sent = list(theta)
    buf = [{p: jnp.zeros_like(aux.d[p]) for p in topo.neighbors(j)}
           for j in range(j_nodes)]

    rounds = broadcasts = deliveries = 0
    for r in range(num_rounds):
        mask = masks[r]
        # 2. update — active nodes read their (possibly stale) buffers
        new_theta = []
        for j in range(j_nodes):
            if not mask[j]:
                new_theta.append(theta[j])
                continue
            rhs = aux.d[j] + aux.s[j] @ theta[j]
            for p, pjp in aux.p[j].items():
                rhs = rhs + pjp @ buf[j][p]
            new_theta.append(aux.g[j] @ rhs)
        # 3. censor — compare against the last value actually sent
        bcast = []
        for j in range(j_nodes):
            if not mask[j]:
                bcast.append(False)
            elif not config.censored:
                bcast.append(True)
            else:
                delta = jnp.max(jnp.abs(new_theta[j] - sent[j]))
                bcast.append(bool(delta > thresholds[r]))
        # 4. deliver — Jacobi-simultaneous: all updates computed above
        for j in range(j_nodes):
            if not bcast[j]:
                continue
            for rcv in topo.neighbors(j):
                if config.gossip == "edge" and not mask[rcv]:
                    continue        # pairwise: only the other endpoint
                buf[rcv][j] = new_theta[j]
                deliveries += 1
            sent[j] = new_theta[j]
            broadcasts += 1
        rounds += 1
        if tol > 0:
            delta_round = max(
                (float(jnp.max(jnp.abs(a - b)))
                 for a, b in zip(new_theta, theta)), default=0.0)
            theta = new_theta
            # all-silent rounds have Δθ ≡ 0 by construction: the schedule
            # idled, the iteration did not converge — don't stop on them
            if mask.any() and delta_round < tol:
                break
        else:
            theta = new_theta
    return AsyncGossipResult(theta=theta, rounds=rounds,
                             broadcasts=broadcasts, deliveries=deliveries)
