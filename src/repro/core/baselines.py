"""Baselines the paper compares against.

* CentralizedKRR — exact kernel ridge regression on pooled data (upper
  reference; §IV "Centralized KRR"). O(N³), so benches subsample.
* CentralizedRF — centralized ridge in a shared RF space (sanity midpoint).
* DKLA — decentralized kernel learning via consensus ADMM with *identical*
  features on every node (Xu et al., JMLR 2021 [22]; model (3) in the paper).
* DKLA-DDRF — DKLA where the shared features are DDRF-selected using a
  single node's data and broadcast (the paper's second baseline).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.dekrr import NodeData
from repro.core.graph import Topology
from repro.core.rff import FeatureMap, featurize, gaussian_kernel


# ---------------------------------------------------------------- centralized
@dataclasses.dataclass
class CentralizedKRR:
    """Exact KRR: α = (K + λN I)⁻¹ yᵀ, f(x) = K(x, X) α."""

    sigma: float
    lam: float

    def fit(self, x: jax.Array, y: jax.Array) -> "CentralizedKRR":
        self.x_train = x
        k = gaussian_kernel(x, x, self.sigma)
        n = x.shape[1]
        reg = self.lam * n * jnp.eye(n, dtype=k.dtype)
        self.alpha = jax.scipy.linalg.cho_solve(
            jax.scipy.linalg.cho_factor(k + reg), y.reshape(-1))
        return self

    def predict(self, x: jax.Array) -> jax.Array:
        return gaussian_kernel(x, self.x_train, self.sigma) @ self.alpha


@dataclasses.dataclass
class CentralizedRF:
    """Ridge regression in a shared random-feature space (pooled data)."""

    fmap: FeatureMap
    lam: float

    def fit(self, x: jax.Array, y: jax.Array) -> "CentralizedRF":
        z = featurize(self.fmap, x)                    # [D, N]
        n = z.shape[1]
        g = z @ z.T + self.lam * n * jnp.eye(z.shape[0], dtype=z.dtype)
        self.theta = jax.scipy.linalg.cho_solve(
            jax.scipy.linalg.cho_factor(g), z @ y.reshape(-1))
        return self

    def predict(self, x: jax.Array) -> jax.Array:
        return self.theta @ featurize(self.fmap, x)


# ----------------------------------------------------------------------- DKLA
@dataclasses.dataclass(frozen=True)
class DKLAConfig:
    lam: float = 1e-6
    rho: float = 1e-4          # augmented coefficient (paper: 1e-4 ...
    rho_doubling_every: int = 200   # ... doubled every 200 iterations)
    num_iters: int = 600


class DKLA:
    """Decentralized consensus ADMM with one shared feature map.

    Local objective g_j(θ) = (1/N)‖θᵀZ_j − Y_j‖² + (λ/J)‖θ‖², consensus
    enforced with edge variables eliminated (DLM-style):

      θ_j^{k+1} = (2/N Z_jZ_jᵀ + 2λ/J I + 2ρ|N_j| I)⁻¹
                  (2/N Z_jY_jᵀ − γ_j^k + ρ Σ_{p∈N_j}(θ_j^k + θ_p^k))
      γ_j^{k+1} = γ_j^k + ρ Σ_{p∈N_j}(θ_j^{k+1} − θ_p^{k+1})
    """

    def __init__(self, topology: Topology, fmap: FeatureMap,
                 data: Sequence[NodeData], config: DKLAConfig = DKLAConfig()):
        self.topology = topology
        self.fmap = fmap
        self.data = list(data)
        self.config = config
        self.J = topology.num_nodes
        self.N = sum(nd.num_samples for nd in data)
        self.dfeat = fmap.num_features
        # local precomputations (fixed across iterations)
        self._zz = []
        self._zy = []
        for nd in self.data:
            z = featurize(fmap, nd.x)
            self._zz.append(z @ z.T)
            self._zy.append(z @ nd.y.reshape(-1))

    def solve(self, num_iters: int | None = None) -> list[jax.Array]:
        cfg = self.config
        iters = num_iters if num_iters is not None else cfg.num_iters
        theta = [jnp.zeros(self.dfeat, dtype=self._zy[0].dtype)
                 for _ in range(self.J)]
        gamma = [jnp.zeros_like(t) for t in theta]
        rho = cfg.rho
        eye = jnp.eye(self.dfeat, dtype=self._zy[0].dtype)
        for k in range(iters):
            if k > 0 and cfg.rho_doubling_every > 0 \
                    and k % cfg.rho_doubling_every == 0:
                rho *= 2.0
            new_theta = []
            for j in range(self.J):
                deg = self.topology.degree(j)
                lhs = (2.0 / self.N) * self._zz[j] \
                    + (2.0 * cfg.lam / self.J + 2.0 * rho * deg) * eye
                nb_sum = sum((theta[j] + theta[p]
                              for p in self.topology.neighbors(j)),
                             jnp.zeros_like(theta[j]))
                rhs = (2.0 / self.N) * self._zy[j] - gamma[j] + rho * nb_sum
                new_theta.append(jnp.linalg.solve(lhs, rhs))
            for j in range(self.J):
                resid = sum((new_theta[j] - new_theta[p]
                             for p in self.topology.neighbors(j)),
                            jnp.zeros_like(new_theta[j]))
                gamma[j] = gamma[j] + rho * resid
            theta = new_theta
        return theta

    def predict(self, theta: Sequence[jax.Array], x: jax.Array,
                node: int | None = None) -> jax.Array:
        z = featurize(self.fmap, x)
        if node is not None:
            return theta[node] @ z
        return jnp.mean(jnp.stack([t @ z for t in theta]), axis=0)


def dkla_ddrf_feature_map(
    key: jax.Array, dim: int, num_features: int, sigma: float,
    data: Sequence[NodeData], *, node: int | None = None,
    method: str = "energy", candidate_ratio: int = 20,
    kind: str = "cos_bias",
) -> FeatureMap:
    """DKLA-DDRF: select shared features on ONE node's data and broadcast.

    The paper uses the node with the most data in the imbalanced setting.
    """
    from repro.core.ddrf import select_features

    if node is None:
        node = max(range(len(data)), key=lambda j: data[j].num_samples)
    return select_features(
        key, dim, num_features, sigma, data[node].x, data[node].y,
        method=method, candidate_ratio=candidate_ratio, kind=kind)
