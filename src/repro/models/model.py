"""Composable model assembly covering all assigned architecture families.

A model is ``num_groups`` repetitions of a *period* of slots (``cfg.slots``),
scanned with stacked parameters (one jax.lax.scan over groups keeps the HLO
size independent of depth and enables per-group remat). Each slot is a
(mixer, ffn) pair:

  mixer ∈ {attn, swa, mamba, rwkv}      ffn ∈ {dense, moe, rwkv_cmix, none}

Examples:
  dense llama-style:   slots = ((attn, dense),)
  deepseek-moe:        slots = ((attn, moe),)
  rwkv6:               slots = ((rwkv, rwkv_cmix),)
  jamba (1:7 + MoE/2): slots = 8 entries, slot0 attn, rest mamba,
                       odd slots moe, even slots dense

Two entry points per model:
  forward(...)     — full-sequence (training / prefill), chunked attention
  decode_step(...) — one token against a cache pytree (KV ring buffer for
                     swa, constant-size states for mamba/rwkv)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib

Params = dict
Cache = dict


@dataclasses.dataclass(frozen=True)
class SlotSpec:
    mixer: str          # attn | swa | mamba | rwkv
    ffn: str            # dense | moe | rwkv_cmix | none


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    slots: tuple[SlotSpec, ...] = (SlotSpec("attn", "dense"),)
    qkv_bias: bool = False
    is_encoder: bool = False
    act: str = "swiglu"               # swiglu | gelu
    rope_theta: float = 10000.0
    sliding_window: int | None = None
    # MoE
    moe_num_experts: int = 0
    moe_experts_per_token: int = 0
    moe_num_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    moe_groups: int = 1               # dispatch groups (= data shards)
    moe_shard: tuple | None = None    # (dp_axes, tp_axis) for MoE buffers
    # SSM
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    # RWKV
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 64
    # serving
    kv_cache_dtype: str = "bfloat16"   # "int8" = quantized KV cache with
    #                                     per-(token, head) bf16 scales
    # misc
    tie_embeddings: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    attn_chunk: int = 1024
    scan_chunk: int = 128             # time chunk for ssm/rwkv scans
    remat: bool = True
    # optional PartitionSpec-like tuple for the residual stream [B, S, d],
    # applied between layer groups (requires an ambient mesh context).
    # e.g. (("pod","data"), "model", None) = Megatron-SP sequence sharding
    # of stored activations.
    act_shard: tuple | None = None
    # analysis mode (dry-run): unroll the group scan and attention KV scans
    # so XLA's HloCostAnalysis counts their flops/collectives at full trip
    # count (while-loop bodies are otherwise counted once). The inner
    # SSM/RWKV per-step recurrences stay as loops — their flops are <0.2% of
    # the projections (noted in EXPERIMENTS.md §Dry-run).
    analysis_unroll: bool = False
    citation: str = ""

    # ---- derived -----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def period(self) -> int:
        return len(self.slots)

    @property
    def num_groups(self) -> int:
        assert self.num_layers % self.period == 0, \
            (self.num_layers, self.period)
        return self.num_layers // self.period

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(16, self.d_model // 32)

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def pdt(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdt(self):
        return jnp.dtype(self.compute_dtype)

    def moe_dims(self) -> moe_lib.MoEDims:
        return moe_lib.MoEDims(
            num_experts=self.moe_num_experts,
            experts_per_token=self.moe_experts_per_token,
            d_model=self.d_model, d_ff=self.d_ff,
            num_shared_experts=self.moe_num_shared_experts,
            capacity_factor=self.moe_capacity_factor)

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: 1 period of layers, d_model ≤ 512, ≤4 experts."""
        d_model = min(self.d_model, 256)
        hd = 32
        heads = max(2, min(4, self.num_heads))
        kv = max(1, heads // max(1, self.num_heads // self.num_kv_heads))
        kw = dict(
            num_layers=2 * self.period if self.period <= 4 else self.period,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 1024),
            moe_num_experts=min(self.moe_num_experts, 4),
            moe_experts_per_token=min(self.moe_experts_per_token, 2),
            rwkv_head_dim=32,
            rwkv_lora_rank=16,
            attn_chunk=64,
            scan_chunk=16,
        )
        kw.update(overrides)
        return dataclasses.replace(self, **kw)


# ============================================================== initialization
def _init(key, shape, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _slot_param_shapes(cfg: ModelConfig, slot: SlotSpec) -> dict:
    d, hd = cfg.d_model, cfg.hd
    h, k = cfg.num_heads, cfg.num_kv_heads
    shapes: dict[str, tuple] = {"norm_mix": (d,)}
    if slot.mixer in ("attn", "swa"):
        shapes.update(wq=(d, h * hd), wk=(d, k * hd), wv=(d, k * hd),
                      wo=(h * hd, d))
        if cfg.qkv_bias:
            shapes.update(bq=(h * hd,), bk=(k * hd,), bv=(k * hd,))
    elif slot.mixer == "mamba":
        di, n, r = cfg.d_inner, cfg.ssm_state_dim, cfg.dt_rank
        shapes.update(in_x=(d, di), in_z=(d, di),
                      conv_w=(cfg.ssm_conv_width, di),
                      dt_down=(di, r), dt_up=(r, di), dt_bias=(di,),
                      w_b=(di, n), w_c=(di, n), a_log=(di, n),
                      d_skip=(di,), out=(di, d))
    elif slot.mixer == "rwkv":
        hh, dh, r = cfg.rwkv_heads, cfg.rwkv_head_dim, cfg.rwkv_lora_rank
        shapes.update(mu_r=(d,), mu_k=(d,), mu_v=(d,), mu_w=(d,), mu_g=(d,),
                      wr=(d, d), wk_t=(d, d), wv_t=(d, d), wg=(d, d),
                      w0=(d,), wa=(d, r), wb=(r, d), u=(hh, dh),
                      gn=(d,), wo=(d, d))
    else:
        raise ValueError(slot.mixer)

    if slot.ffn == "dense":
        shapes["norm_ffn"] = (d,)
        if cfg.act == "swiglu":
            shapes.update(w_gate=(d, cfg.d_ff), w_up=(d, cfg.d_ff),
                          w_down=(cfg.d_ff, d))
        else:
            shapes.update(w_up=(d, cfg.d_ff), b_up=(cfg.d_ff,),
                          w_down=(cfg.d_ff, d), b_down=(d,))
    elif slot.ffn == "moe":
        e, f = cfg.moe_num_experts, cfg.d_ff
        shapes["norm_ffn"] = (d,)
        shapes.update(router=(d, e), moe_gate=(e, d, f), moe_up=(e, d, f),
                      moe_down=(e, f, d))
        if cfg.moe_num_shared_experts:
            fs = cfg.moe_num_shared_experts * f
            shapes.update(sh_gate=(d, fs), sh_up=(d, fs), sh_down=(fs, d))
    elif slot.ffn == "rwkv_cmix":
        shapes.update(norm_ffn=(d,), mu_c=(d,), cm_r=(d, d),
                      cm_k=(d, cfg.d_ff), cm_v=(cfg.d_ff, d))
    elif slot.ffn != "none":
        raise ValueError(slot.ffn)
    return shapes


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    keys = jax.random.split(key, 4 + cfg.period)
    d, v = cfg.d_model, cfg.vocab_size
    params: Params = {
        "embed": _init(keys[0], (v, d), cfg.pdt, scale=0.02),
        "final_norm": jnp.ones((d,), cfg.pdt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _init(keys[1], (d, v), cfg.pdt)

    g = cfg.num_groups
    for i, slot in enumerate(cfg.slots):
        shapes = _slot_param_shapes(cfg, slot)
        skeys = jax.random.split(keys[4 + i], len(shapes))
        slot_params = {}
        for (name, shape), sk in zip(sorted(shapes.items()), skeys):
            if name.startswith("norm") or name == "gn":
                p = jnp.ones((g,) + shape, cfg.pdt)
            elif name.startswith(("mu_", "b", "dt_bias", "d_skip")) \
                    and name not in ("b_up",):
                p = jnp.zeros((g,) + shape, cfg.pdt) \
                    if not name.startswith("mu_") \
                    else jnp.full((g,) + shape, 0.5, cfg.pdt)
            elif name == "a_log":
                a0 = jnp.log(jnp.broadcast_to(
                    jnp.arange(1, shape[1] + 1, dtype=jnp.float32),
                    shape))
                p = jnp.broadcast_to(a0, (g,) + shape).astype(cfg.pdt)
            elif name == "w0":
                p = jnp.full((g,) + shape, -0.6, cfg.pdt)   # decay ~ exp(-e^{-.6})
            elif name == "u":
                p = jnp.zeros((g,) + shape, cfg.pdt)
            else:
                p = _init(sk, (g,) + shape, cfg.pdt,
                          scale=1.0 / math.sqrt(shape[0]))
            slot_params[name] = p
        params[f"slot{i}"] = slot_params
    return params


def param_count(params: Params) -> int:
    return sum(p.size for p in jax.tree.leaves(params))


def analytic_param_count(cfg: ModelConfig) -> int:
    """Parameter count from shapes alone (no allocation) — used to sanity
    check the full-size assigned configs against their nominal sizes."""
    total = cfg.vocab_size * cfg.d_model + cfg.d_model       # embed + norm
    if not cfg.tie_embeddings:
        total += cfg.d_model * cfg.vocab_size
    for slot in cfg.slots:
        shapes = _slot_param_shapes(cfg, slot)
        total += cfg.num_groups * sum(
            math.prod(s) for s in shapes.values())
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Activated parameters per token (MoE: top-k of E experts)."""
    total = analytic_param_count(cfg)
    if cfg.moe_num_experts:
        for slot in cfg.slots:
            if slot.ffn == "moe":
                f = cfg.d_ff
                per_expert = 3 * cfg.d_model * f
                inactive = (cfg.moe_num_experts
                            - cfg.moe_experts_per_token) * per_expert
                total -= cfg.num_groups * inactive
    return total


# ================================================================= slot apply
def _attn_mixer(cfg: ModelConfig, slot: SlotSpec, p: dict, h: jax.Array,
                positions: jax.Array, window: int | None) -> jax.Array:
    b, s, d = h.shape
    nh, nk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    x = L.rms_norm(h, p["norm_mix"])
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, nh, hd)
    k = k.reshape(b, s, nk, hd)
    v = v.reshape(b, s, nk, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    out = L.chunked_attention(
        q, k, v, positions, positions,
        causal=not cfg.is_encoder, window=window,
        chunk_kv=min(cfg.attn_chunk, s), unroll=cfg.analysis_unroll)
    return (out.reshape(b, s, nh * hd) @ p["wo"])


def _mamba_mixer(cfg: ModelConfig, p: dict, h: jax.Array) -> jax.Array:
    x = L.rms_norm(h, p["norm_mix"])
    xi = x @ p["in_x"]                                  # [B, S, di]
    z = x @ p["in_z"]
    xi = jax.nn.silu(ssm_lib.causal_conv1d(xi, p["conv_w"]))
    delta = jax.nn.softplus((xi @ p["dt_down"]) @ p["dt_up"] + p["dt_bias"])
    b_t = xi @ p["w_b"]
    c_t = xi @ p["w_c"]
    b0 = h.shape[0]
    state0 = jnp.zeros((b0, cfg.d_inner, cfg.ssm_state_dim), jnp.float32)
    y, _ = ssm_lib.ssm_chunk_scan(xi, delta, p["a_log"], b_t, c_t,
                                  p["d_skip"], state0, chunk=cfg.scan_chunk)
    return (y * jax.nn.silu(z)) @ p["out"]


def _rwkv_mixer(cfg: ModelConfig, p: dict, h: jax.Array) -> jax.Array:
    b, s, d = h.shape
    hh, dh = cfg.rwkv_heads, cfg.rwkv_head_dim
    x = L.rms_norm(h, p["norm_mix"])
    xr = rwkv_lib.token_shift(x, p["mu_r"])
    xk = rwkv_lib.token_shift(x, p["mu_k"])
    xv = rwkv_lib.token_shift(x, p["mu_v"])
    xw = rwkv_lib.token_shift(x, p["mu_w"])
    xg = rwkv_lib.token_shift(x, p["mu_g"])
    r = (xr @ p["wr"]).reshape(b, s, hh, dh)
    k = (xk @ p["wk_t"]).reshape(b, s, hh, dh)
    v = (xv @ p["wv_t"]).reshape(b, s, hh, dh)
    g = jax.nn.silu(xg @ p["wg"])
    w = rwkv_lib.data_dependent_decay(xw, p["w0"], p["wa"], p["wb"], hh)
    state0 = jnp.zeros((b, hh, dh, dh), jnp.float32)
    out, _ = rwkv_lib.wkv6_chunk_scan(r, k, v, w, p["u"], state0,
                                      chunk=cfg.scan_chunk)
    out = out.reshape(b, s, d)
    out = L.rms_norm(out, p["gn"])          # stand-in for per-head group norm
    return (out * g) @ p["wo"]


def _ffn(cfg: ModelConfig, slot: SlotSpec, p: dict, h: jax.Array,
         aux: dict) -> jax.Array:
    if slot.ffn == "none":
        return jnp.zeros_like(h)
    x = L.rms_norm(h, p["norm_ffn"])
    if slot.ffn == "dense":
        if cfg.act == "swiglu":
            return L.swiglu_mlp(x, p["w_gate"], p["w_up"], p["w_down"])
        return L.gelu_mlp(x, p["w_up"], p["b_up"], p["w_down"], p["b_down"])
    if slot.ffn == "moe":
        b, s, d = x.shape
        out, losses = moe_lib.moe_forward(
            x.reshape(b * s, d), p["router"], p["moe_gate"], p["moe_up"],
            p["moe_down"], cfg.moe_dims(),
            shared_w_gate=p.get("sh_gate"), shared_w_up=p.get("sh_up"),
            shared_w_down=p.get("sh_down"),
            groups=cfg.moe_groups, shard=cfg.moe_shard)
        for key, val in losses.items():
            aux[key] = aux.get(key, 0.0) + val
        return out.reshape(b, s, d)
    if slot.ffn == "rwkv_cmix":
        return rwkv_lib.channel_mix(x, p["mu_c"], p["cm_r"], p["cm_k"],
                                    p["cm_v"])
    raise ValueError(slot.ffn)


def _apply_slot(cfg: ModelConfig, slot: SlotSpec, p: dict, h: jax.Array,
                positions: jax.Array, aux: dict) -> jax.Array:
    if slot.mixer in ("attn", "swa"):
        window = cfg.sliding_window if slot.mixer == "swa" else None
        h = h + _attn_mixer(cfg, slot, p, h, positions, window)
    elif slot.mixer == "mamba":
        h = h + _mamba_mixer(cfg, p, h)
    elif slot.mixer == "rwkv":
        h = h + _rwkv_mixer(cfg, p, h)
    else:
        raise ValueError(slot.mixer)
    h = h + _ffn(cfg, slot, p, h, aux)
    return h


# ==================================================================== forward
class Model:
    """Functional model wrapper bound to a ModelConfig."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key: jax.Array) -> Params:
        return init_params(self.cfg, key)

    # ---- full-sequence forward ----------------------------------------------
    def forward(self, params: Params, tokens: jax.Array | None = None,
                embeds: jax.Array | None = None,
                positions: jax.Array | None = None
                ) -> tuple[jax.Array, dict]:
        """tokens [B, S] int32 and/or embeds [B, S_e, d] (VLM/audio frontends
        supply embeds; if both given, embeds are prepended). Returns
        (logits [B, S_total, V], aux-loss dict)."""
        cfg = self.cfg
        if tokens is not None:
            h = params["embed"][tokens].astype(cfg.cdt)
            if embeds is not None:
                h = jnp.concatenate([embeds.astype(cfg.cdt), h], axis=1)
        else:
            h = embeds.astype(cfg.cdt)
        b, s, _ = h.shape
        if cfg.act_shard is not None:
            from jax.sharding import PartitionSpec
            # batch-dim constraint right after the (sharded-table) embedding
            # gather: GSPMD otherwise replicates the gather output and every
            # downstream per-token matmul runs at full global batch.
            h = jax.lax.with_sharding_constraint(
                h, PartitionSpec(cfg.act_shard[0], None, None))
        if positions is None:
            positions = jnp.arange(s, dtype=jnp.int32)

        aux_total: dict[str, jax.Array] = {}

        def group_body(carry, group_params):
            h = carry
            aux: dict[str, jax.Array] = {}
            for i, slot in enumerate(cfg.slots):
                h = _apply_slot(cfg, slot, group_params[f"slot{i}"], h,
                                positions, aux)
            if cfg.act_shard is not None:
                from jax.sharding import PartitionSpec
                h = jax.lax.with_sharding_constraint(
                    h, PartitionSpec(*cfg.act_shard))
            aux_arr = jnp.stack([aux[k] for k in sorted(aux)]) if aux \
                else jnp.zeros((0,))
            return h, aux_arr

        if cfg.remat:
            group_body = jax.checkpoint(
                group_body, policy=jax.checkpoint_policies.nothing_saveable)
        group_params = {f"slot{i}": params[f"slot{i}"]
                        for i in range(cfg.period)}
        h, aux_stack = jax.lax.scan(
            group_body, h, group_params,
            unroll=cfg.num_groups if cfg.analysis_unroll else 1)

        h = L.rms_norm(h, params["final_norm"])
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"]).astype(cfg.cdt)
        logits = h @ head
        aux_keys = sorted(
            {k for i, s_ in enumerate(cfg.slots)
             for k in (("load_balance_loss", "router_z_loss")
                       if s_.ffn == "moe" else ())})
        aux_total = {k: aux_stack[:, i].sum()
                     for i, k in enumerate(aux_keys)} if aux_keys else {}
        return logits, aux_total

    # ---- decode -------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int,
                   dtype=None) -> Cache:
        cfg = self.cfg
        dtype = dtype or cfg.cdt
        g = cfg.num_groups
        nk, hd = cfg.num_kv_heads, cfg.hd
        cache: Cache = {}
        for i, slot in enumerate(cfg.slots):
            c: dict[str, jax.Array] = {}
            if slot.mixer == "attn":
                if cfg.kv_cache_dtype == "int8":
                    c["k"] = jnp.zeros((g, batch, max_seq, nk, hd), jnp.int8)
                    c["v"] = jnp.zeros((g, batch, max_seq, nk, hd), jnp.int8)
                    c["k_scale"] = jnp.zeros((g, batch, max_seq, nk),
                                             jnp.bfloat16)
                    c["v_scale"] = jnp.zeros((g, batch, max_seq, nk),
                                             jnp.bfloat16)
                else:
                    c["k"] = jnp.zeros((g, batch, max_seq, nk, hd), dtype)
                    c["v"] = jnp.zeros((g, batch, max_seq, nk, hd), dtype)
            elif slot.mixer == "swa":
                w = cfg.sliding_window
                c["k"] = jnp.zeros((g, batch, w, nk, hd), dtype)
                c["v"] = jnp.zeros((g, batch, w, nk, hd), dtype)
                # unwritten ring slots get INT32_MAX: excluded by the causal
                # mask (q_pos >= kv_pos fails) and by the padding mask
                c["pos"] = jnp.full((g, w), jnp.iinfo(jnp.int32).max,
                                    jnp.int32)
            elif slot.mixer == "mamba":
                c["conv"] = jnp.zeros(
                    (g, batch, cfg.ssm_conv_width - 1, cfg.d_inner), dtype)
                c["state"] = jnp.zeros(
                    (g, batch, cfg.d_inner, cfg.ssm_state_dim), jnp.float32)
            elif slot.mixer == "rwkv":
                c["shift_t"] = jnp.zeros((g, batch, cfg.d_model), dtype)
                c["shift_c"] = jnp.zeros((g, batch, cfg.d_model), dtype)
                c["state"] = jnp.zeros(
                    (g, batch, cfg.rwkv_heads, cfg.rwkv_head_dim,
                     cfg.rwkv_head_dim), jnp.float32)
            cache[f"slot{i}"] = c
        return cache

    def decode_step(self, params: Params, cache: Cache, tokens: jax.Array,
                    pos: jax.Array) -> tuple[jax.Array, Cache]:
        """One decode step. tokens [B, 1] int32, pos [] int32 (current length,
        i.e. this token's position). Returns (logits [B, V], new cache)."""
        cfg = self.cfg
        h = params["embed"][tokens].astype(cfg.cdt)      # [B, 1, d]
        if cfg.act_shard is not None:
            from jax.sharding import PartitionSpec
            h = jax.lax.with_sharding_constraint(
                h, PartitionSpec(cfg.act_shard[0], None, None))
        positions = pos[None].astype(jnp.int32)

        def group_body(h, xs):
            group_params, group_cache = xs
            new_cache = {}
            for i, slot in enumerate(cfg.slots):
                h, new_cache[f"slot{i}"] = self._decode_slot(
                    slot, group_params[f"slot{i}"], group_cache[f"slot{i}"],
                    h, pos, positions)
            return h, new_cache

        group_params = {f"slot{i}": params[f"slot{i}"]
                        for i in range(cfg.period)}
        h, new_cache = jax.lax.scan(
            group_body, h, (group_params, cache),
            unroll=cfg.num_groups if cfg.analysis_unroll else 1)
        h = L.rms_norm(h, params["final_norm"])
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"]).astype(cfg.cdt)
        return (h[:, 0] @ head), new_cache

    def _decode_slot(self, slot: SlotSpec, p: dict, c: dict, h: jax.Array,
                     pos: jax.Array, positions: jax.Array
                     ) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        b = h.shape[0]
        nh, nk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
        new_c = dict(c)
        if slot.mixer in ("attn", "swa"):
            x = L.rms_norm(h, p["norm_mix"])
            q = x @ p["wq"]
            k = x @ p["wk"]
            v = x @ p["wv"]
            if cfg.qkv_bias:
                q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
            q = L.apply_rope(q.reshape(b, 1, nh, hd), positions,
                             cfg.rope_theta)
            k = L.apply_rope(k.reshape(b, 1, nk, hd), positions,
                             cfg.rope_theta)
            v = v.reshape(b, 1, nk, hd)
            if slot.mixer == "attn":
                if cfg.kv_cache_dtype == "int8":
                    def quantize(t):          # [B, 1, K, dh] → int8 + scale
                        amax = jnp.max(jnp.abs(t), axis=-1)
                        scale = jnp.maximum(amax, 1e-6) / 127.0
                        q8 = jnp.clip(jnp.round(
                            t / scale[..., None]), -127, 127).astype(jnp.int8)
                        return q8, scale.astype(jnp.bfloat16)
                    k8, ks = quantize(k)
                    v8, vs = quantize(v)
                    kc = jax.lax.dynamic_update_slice_in_dim(
                        c["k"], k8, pos, axis=1)
                    vc = jax.lax.dynamic_update_slice_in_dim(
                        c["v"], v8, pos, axis=1)
                    ksc = jax.lax.dynamic_update_slice_in_dim(
                        c["k_scale"], ks, pos, axis=1)
                    vsc = jax.lax.dynamic_update_slice_in_dim(
                        c["v_scale"], vs, pos, axis=1)
                    kd = kc.astype(cfg.cdt) * ksc[..., None].astype(cfg.cdt)
                    vd = vc.astype(cfg.cdt) * vsc[..., None].astype(cfg.cdt)
                    out = L.decode_attention(q, kd, vd, pos + 1,
                                             unroll=cfg.analysis_unroll)
                    new_c.update(k=kc, v=vc, k_scale=ksc, v_scale=vsc)
                else:
                    kc = jax.lax.dynamic_update_slice_in_dim(
                        c["k"], k.astype(c["k"].dtype), pos, axis=1)
                    vc = jax.lax.dynamic_update_slice_in_dim(
                        c["v"], v.astype(c["v"].dtype), pos, axis=1)
                    out = L.decode_attention(q, kc, vc, pos + 1,
                                             unroll=cfg.analysis_unroll)
                    new_c.update(k=kc, v=vc)
            else:                                        # sliding window ring
                w = cfg.sliding_window
                ring = pos % w
                kc = jax.lax.dynamic_update_slice_in_dim(
                    c["k"], k.astype(c["k"].dtype), ring, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(
                    c["v"], v.astype(c["v"].dtype), ring, axis=1)
                pc = jax.lax.dynamic_update_slice_in_dim(
                    c["pos"], positions, ring, axis=0)
                out = L.chunked_attention(
                    q, kc, vc, positions, pc, causal=True, window=w,
                    chunk_kv=min(cfg.attn_chunk, w),
                    unroll=cfg.analysis_unroll)
                new_c.update(k=kc, v=vc, pos=pc)
            h = h + (out.reshape(b, 1, nh * hd) @ p["wo"])
        elif slot.mixer == "mamba":
            x = L.rms_norm(h, p["norm_mix"])[:, 0]       # [B, d]
            xi = x @ p["in_x"]
            z = x @ p["in_z"]
            conv_win = jnp.concatenate(
                [c["conv"], xi[:, None, :].astype(c["conv"].dtype)], axis=1)
            xi = jax.nn.silu(
                sum(conv_win[:, i, :] * p["conv_w"][i][None, :]
                    for i in range(cfg.ssm_conv_width)))
            delta = jax.nn.softplus(
                (xi @ p["dt_down"]) @ p["dt_up"] + p["dt_bias"])
            y, state = ssm_lib.ssm_step(
                xi, delta, p["a_log"], xi @ p["w_b"], xi @ p["w_c"],
                p["d_skip"], c["state"])
            out = (y * jax.nn.silu(z)) @ p["out"]
            h = h + out[:, None, :]
            new_c.update(conv=conv_win[:, 1:], state=state)
        elif slot.mixer == "rwkv":
            hh, dh = cfg.rwkv_heads, cfg.rwkv_head_dim
            x = L.rms_norm(h, p["norm_mix"])[:, 0]
            prev = c["shift_t"]
            mix = lambda mu: x + mu * (prev - x)
            r = (mix(p["mu_r"]) @ p["wr"]).reshape(b, hh, dh)
            k = (mix(p["mu_k"]) @ p["wk_t"]).reshape(b, hh, dh)
            v = (mix(p["mu_v"]) @ p["wv_t"]).reshape(b, hh, dh)
            g = jax.nn.silu(mix(p["mu_g"]) @ p["wg"])
            xw = mix(p["mu_w"])
            w = rwkv_lib.data_dependent_decay(
                xw[:, None, :], p["w0"], p["wa"], p["wb"], hh)[:, 0]
            out, state = rwkv_lib.wkv6_step(r, k, v, w, p["u"], c["state"])
            out = L.rms_norm(out.reshape(b, -1), p["gn"])
            h = h + ((out * g) @ p["wo"])[:, None, :]
            new_c.update(shift_t=x.astype(c["shift_t"].dtype), state=state)
        # ---- ffn ----
        if slot.ffn != "none":
            if slot.ffn == "rwkv_cmix":
                x = L.rms_norm(h, p["norm_ffn"])[:, 0]
                prev = c["shift_c"]
                xs = x + p["mu_c"] * (prev - x)
                rg = jax.nn.sigmoid(xs @ p["cm_r"])
                val = jnp.square(jax.nn.relu(xs @ p["cm_k"])) @ p["cm_v"]
                h = h + (rg * val)[:, None, :]
                new_c["shift_c"] = x.astype(c["shift_c"].dtype)
            else:
                aux: dict = {}
                h = h + _ffn(self.cfg, slot, p, h, aux)
        return h, new_c
