"""Mixture-of-Experts with grouped sort-based capacity dispatch
(expert-parallel, data-sharded dispatch groups).

Covers the three assigned MoE configurations:
  * deepseek-moe-16b  — 64 routed experts top-6 + 2 shared experts,
                        fine-grained d_ff (arXiv:2401.06066)
  * phi3.5-moe        — 16 experts top-2
  * jamba-1.5-large   — 16 experts top-2, interleaved into the hybrid stack

Dispatch is the "dropping" scheme used by production JAX frameworks
(MaxText-style), with one dispatch group per data shard: tokens reshape to
[G, T/G, d]; the per-group dispatch (sort by expert id, rank-in-expert via
bincount/cumsum, drop beyond shard-local capacity, scatter to [E, C, d])
runs under ``jax.vmap`` so the scatters/gathers carry canonical batch
dimensions — GSPMD then partitions them over the data axis instead of
replicating (an explicit-index scatter was measured 6.5× worse on memory
and 24× worse on collective volume). Expert einsums shard E over the
``model`` axis (expert parallelism); cross-shard traffic is GSPMD's
all-to-all/all-gather on the [G, E, C, d] buffers. Compute cost is
O(E·C·d·f) — proportional to top-k, not E.

Aux losses: switch-style load-balance loss and router z-loss.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEDims:
    num_experts: int
    experts_per_token: int
    d_model: int
    d_ff: int                     # per (routed) expert
    num_shared_experts: int = 0
    capacity_factor: float = 1.25


def moe_capacity(dims: MoEDims, tokens_per_group: int) -> int:
    cap = tokens_per_group * dims.experts_per_token / dims.num_experts
    cap = int(cap * dims.capacity_factor) + 1
    cap = min(-(-cap // 8) * 8,
              tokens_per_group * dims.experts_per_token)
    return max(cap, 8)


def moe_forward(
    x: jax.Array,                  # [T, d] flattened tokens
    router_w: jax.Array,           # [d, E]
    w_gate: jax.Array,             # [E, d, f]
    w_up: jax.Array,               # [E, d, f]
    w_down: jax.Array,             # [E, f, d]
    dims: MoEDims,
    *,
    shared_w_gate: jax.Array | None = None,   # [d, f_shared]
    shared_w_up: jax.Array | None = None,
    shared_w_down: jax.Array | None = None,
    groups: int = 1,               # dispatch groups (= data shards)
    shard: tuple | None = None,    # (dp_axes, tp_axis) mesh axis names
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Returns (output [T, d], aux {load_balance_loss, router_z_loss})."""
    t, d = x.shape
    e, k = dims.num_experts, dims.experts_per_token
    g = groups if t % groups == 0 else 1
    tg = t // g
    cap = moe_capacity(dims, tg)
    f32 = jnp.float32
    dp, tp = shard if shard is not None else (None, None)

    def constrain(v, spec):
        if shard is None:
            return v
        from jax.sharding import PartitionSpec
        return jax.lax.with_sharding_constraint(v, PartitionSpec(*spec))

    # keep d sharded over `model` through dispatch: the per-token gathers
    # and capacity scatters then run on local d-slices (no replication /
    # combining all-reduce — measured 36 GiB AR per MoE layer otherwise);
    # GSPMD inserts the canonical expert-parallel all-to-all at the
    # d-sharded → expert-sharded boundary below.
    xg = constrain(x.reshape(g, tg, d), (dp, None, tp))

    def dispatch_one_group(xx):                      # xx: [Tg, d]
        logits = (xx @ router_w).astype(f32)         # [Tg, E]
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)       # [Tg, k]
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        flat_e = top_e.reshape(-1)                   # [Tg·k]
        flat_t = jnp.repeat(jnp.arange(tg), k)
        flat_p = top_p.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        s_e, s_t, s_p = flat_e[order], flat_t[order], flat_p[order]
        counts = jnp.bincount(s_e, length=e)
        starts = jnp.concatenate(
            [jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
        rank = jnp.arange(tg * k) - starts[s_e]
        keep = rank < cap
        slot_e = jnp.where(keep, s_e, e)             # e = drop bin
        slot_r = jnp.where(keep, rank, 0).astype(jnp.int32)

        buf = jnp.zeros((e + 1, cap, d), xx.dtype)
        buf = buf.at[slot_e, slot_r].add(
            jnp.where(keep[:, None], xx[s_t], 0))
        z_sq = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        meta = (slot_e, slot_r, s_t, s_p, keep, probs, counts, z_sq)
        return buf[:e], meta

    expert_in, meta = jax.vmap(dispatch_one_group)(xg)   # [G, E, C, d]
    expert_in = constrain(expert_in, (dp, None, None, tp))
    expert_in = constrain(expert_in, (dp, tp, None, None))   # ← all-to-all

    # ---- dense per-expert compute (expert dim sharded over `model`) ----------
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, w_gate))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, w_up)
    h = constrain(h, (dp, tp, None, None))
    expert_out = jnp.einsum("gecf,efd->gecd", h, w_down)
    expert_out = constrain(expert_out, (dp, tp, None, None))

    # ---- combine ---------------------------------------------------------------
    def combine_one_group(eo, m):                    # eo: [E, C, d]
        slot_e, slot_r, s_t, s_p, keep = m[:5]
        gathered = eo[jnp.minimum(slot_e, e - 1), slot_r]
        gathered = jnp.where(keep[:, None], gathered, 0)
        weighted = gathered * s_p[:, None].astype(eo.dtype)
        return jnp.zeros((tg, d), eo.dtype).at[s_t].add(weighted)

    expert_out = constrain(expert_out, (dp, None, None, tp))  # ← all-to-all
    out = jax.vmap(combine_one_group)(expert_out, meta)
    out = constrain(out, (dp, None, tp)).reshape(t, d)

    # ---- shared experts (DeepSeek-MoE) -----------------------------------------
    if shared_w_gate is not None:
        sh = jax.nn.silu(x @ shared_w_gate) * (x @ shared_w_up)
        out = out + sh @ shared_w_down

    # ---- aux losses --------------------------------------------------------------
    probs, counts, z_sq = meta[5], meta[6], meta[7]  # [G,Tg,E], [G,E], [G]
    me = probs.mean(axis=(0, 1))
    ce = counts.sum(0).astype(f32) / (t * k)
    load_balance = e * jnp.sum(me * ce)
    z_loss = jnp.mean(z_sq)
    return out, {"load_balance_loss": load_balance, "router_z_loss": z_loss}
