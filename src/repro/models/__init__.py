from repro.models.model import (Model, ModelConfig, SlotSpec)

__all__ = ["Model", "ModelConfig", "SlotSpec"]
