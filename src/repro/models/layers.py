"""Shared transformer layers: norms, RoPE, attention (chunked online-softmax
with GQA / sliding-window / bidirectional), MLPs.

All computations take explicit ``dtype`` (params) / ``compute_dtype``
(activations); nothing relies on the global x64 flag.

Attention is *chunked flash-style in pure JAX*: an online-softmax
``lax.scan`` over KV chunks so the S×S score matrix never materializes —
required to lower the 32k prefill shapes within HBM, and the natural
pure-JAX analogue of a flash kernel (the Pallas decode kernel in
repro/kernels/decode_attention.py shares its oracle).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

_NEG_INF = -1e30   # finite mask value: keeps fully-masked rows NaN-free


# ------------------------------------------------------------------- norms
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)


# -------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x [..., S, H, dh], positions [..., S] (broadcastable)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                        # [dh/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..,S,dh/2]
    cos = jnp.cos(angles)[..., :, None, :]               # [.., S, 1, dh/2]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------- attention
def chunked_attention(
    q: jax.Array,               # [B, Sq, H, dh]
    k: jax.Array,               # [B, Skv, K, dh]
    v: jax.Array,               # [B, Skv, K, dh]
    q_positions: jax.Array,     # [Sq] int32 (absolute positions of queries)
    kv_positions: jax.Array,    # [Skv] int32
    *,
    causal: bool = True,
    window: int | None = None,  # sliding-window size (None = full)
    chunk_kv: int = 1024,
    kv_valid_len: jax.Array | None = None,  # mask kv positions >= this
    unroll: bool = False,   # analysis mode: no while loop (HLO cost fidelity)
) -> jax.Array:
    """Online-softmax attention, scanning over KV chunks.

    GQA: H query heads share K kv heads (H % K == 0). Softmax statistics are
    carried in f32 regardless of input dtype. Peak live memory is
    O(B·Sq·H·chunk_kv) instead of O(B·Sq·H·Skv).
    """
    b, sq, h, dh = q.shape
    _, skv, kh, _ = k.shape
    assert h % kh == 0, (h, kh)
    g = h // kh
    scale = dh ** -0.5
    nkv = -(-skv // chunk_kv)
    pad = nkv * chunk_kv - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad),
                               constant_values=jnp.iinfo(jnp.int32).max)
    # [nkv, B, ckv, K, dh]
    k_chunks = k.reshape(b, nkv, chunk_kv, kh, dh).transpose(1, 0, 2, 3, 4)
    v_chunks = v.reshape(b, nkv, chunk_kv, kh, dh).transpose(1, 0, 2, 3, 4)
    pos_chunks = kv_positions.reshape(nkv, chunk_kv)

    qf = q.astype(jnp.float32)

    def body(carry, inputs):
        m, l, acc = carry                          # [B,Sq,H], [B,Sq,H], +dh
        kc, vc, pc = inputs                        # [B,ckv,K,dh], ..., [ckv]
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        # scores [B, Sq, H, ckv] via GQA grouping
        qg = qf.reshape(b, sq, kh, g, dh)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kc,
                       precision=jax.lax.Precision.DEFAULT)
        s = s.reshape(b, sq, h, chunk_kv) * scale
        mask = jnp.ones((sq, chunk_kv), dtype=bool)
        if causal:
            mask &= q_positions[:, None] >= pc[None, :]
        if window is not None:
            mask &= q_positions[:, None] - pc[None, :] < window
        if kv_valid_len is not None:
            mask &= (pc < kv_valid_len)[None, :]
        mask &= (pc < jnp.iinfo(jnp.int32).max)[None, :]   # chunk padding
        s = jnp.where(mask[None, :, None, :], s, _NEG_INF)

        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bqkgc,bckd->bqkgd",
                        p.reshape(b, sq, kh, g, chunk_kv), vc)
        acc_new = acc * alpha[..., None] + pv.reshape(b, sq, h, dh)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, h), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, h), jnp.float32)
    acc0 = jnp.zeros((b, sq, h, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                  (k_chunks, v_chunks, pos_chunks),
                                  unroll=nkv if unroll else 1)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,               # [B, 1, H, dh]
    k_cache: jax.Array,         # [B, S, K, dh]
    v_cache: jax.Array,         # [B, S, K, dh]
    cur_index: jax.Array,       # [] int32 — number of valid cache entries
    *,
    window: int | None = None,
    chunk_kv: int | None = None,
    unroll: bool = False,
) -> jax.Array:
    """Single-token decode attention against a (possibly seq-sharded) cache.

    Single-pass (one "chunk" spanning the whole cache): scores are only
    [B, 1, H, S], and with the cache sequence-sharded GSPMD partitions the
    softmax reductions into small all-reduces. A chunked scan here would
    dynamic-slice the sharded seq dim and all-gather the cache every chunk
    (measured 648 GiB/step on qwen-32B decode — §Perf iteration log)."""
    s = k_cache.shape[1]
    kv_pos = jnp.arange(s, dtype=jnp.int32)
    q_pos = jnp.full((1,), cur_index - 1, dtype=jnp.int32)
    return chunked_attention(
        q, k_cache, v_cache, q_pos, kv_pos, causal=True, window=window,
        chunk_kv=(chunk_kv or s), kv_valid_len=cur_index, unroll=unroll)


# --------------------------------------------------------------------- MLP
def swiglu_mlp(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
               w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x: jax.Array, w_up: jax.Array, b_up: jax.Array,
             w_down: jax.Array, b_down: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ w_up + b_up, approximate=True)
    return h @ w_down + b_down
