"""RWKV-6 ("Finch", arXiv:2404.05892) time-mix and channel-mix.

The architecture-defining feature implemented faithfully is the
**data-dependent decay**: the per-channel decay w_t is produced from the
token via a low-rank adapter, w_t = exp(−exp(w0 + tanh(x W_a) W_b)), so the
state update S_t = diag(w_t) S_{t−1} + k_t v_tᵀ forgets at a rate chosen by
the data. The matrix-valued state (per head: [dh_k, dh_v]) and the bonus-u
current-token path follow the paper. Simplification (documented in
DESIGN.md): the 5-way data-dependent token-shift interpolation of the full
Finch block is reduced to single learned-μ lerps; this does not change the
state recurrence, sharding, or cost model.

The recurrence runs as a chunked, remat'd ``lax.scan`` over time: the scan
carry is the O(B·H·dh²) state, and ``jax.checkpoint`` on each chunk bounds
the stored residuals to chunk boundaries (TPU adaptation: HBM-resident
[B,S,H,dh,dh] histories never materialize).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_chunk_scan(
    r: jax.Array,        # [B, S, H, dh]
    k: jax.Array,        # [B, S, H, dh]
    v: jax.Array,        # [B, S, H, dh]
    w: jax.Array,        # [B, S, H, dh] decay in (0, 1), data-dependent
    u: jax.Array,        # [H, dh] current-token bonus
    state: jax.Array,    # [B, H, dh, dh]  (key-dim × value-dim)
    *,
    chunk: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """Returns (out [B, S, H, dh], new_state)."""
    b, s, h, dh = r.shape
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zp(r), zp(k), zp(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)   # decay 1 = no-op on state

    def chunk_body(state, xs):
        rc, kc, vc, wc = xs               # [chunk, B, H, dh]

        def step(st, inp):
            rt, kt, vt, wt = inp          # [B, H, dh]
            kv = kt[..., :, None] * vt[..., None, :]       # [B,H,dh,dh]
            out = jnp.einsum("bhi,bhij->bhj", rt,
                             st + u[None, :, :, None] * kv)
            st = wt[..., :, None] * st + kv
            return st, out

        return jax.lax.scan(step, state, (rc, kc, vc, wc))

    chunk_body = jax.checkpoint(chunk_body)
    to_chunks = lambda a: a.astype(jnp.float32).reshape(
        b, nc, chunk, h, dh).transpose(1, 2, 0, 3, 4)
    state, outs = jax.lax.scan(
        chunk_body, state.astype(jnp.float32),
        (to_chunks(r), to_chunks(k), to_chunks(v), to_chunks(w)))
    out = outs.reshape(nc * chunk, b, h, dh).transpose(1, 0, 2, 3)[:, :s]
    return out.astype(r.dtype), state


def wkv6_step(
    r: jax.Array,        # [B, H, dh]
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,        # [B, H, dh]
    u: jax.Array,        # [H, dh]
    state: jax.Array,    # [B, H, dh, dh]
) -> tuple[jax.Array, jax.Array]:
    """One decode step (constant-size state — no KV cache growth)."""
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    st = state.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]
    out = jnp.einsum("bhi,bhij->bhj", rf, st + u[None, :, :, None] * kv)
    new_state = wf[..., :, None] * st + kv
    return out.astype(r.dtype), new_state


def data_dependent_decay(x: jax.Array, w0: jax.Array, w_a: jax.Array,
                         w_b: jax.Array, num_heads: int) -> jax.Array:
    """w_t = exp(−exp(w0 + tanh(x W_a) W_b)) ∈ (0,1).  x [B,S,d] → [B,S,H,dh]."""
    b, s, d = x.shape
    lora = jnp.tanh(x @ w_a) @ w_b                      # [B, S, d]
    log_w = w0[None, None, :] + lora
    # clamp the decay rate on both ends so w = exp(−rate) stays in the open
    # interval (0, 1) in the f32 compute dtype: rate ≥ 1e-6 keeps w < 1 when
    # exp(log_w) underflows to 0, rate ≤ 80 keeps w > 0 when it overflows
    # (casting to a lower-precision x.dtype may still round the endpoints)
    rate = jnp.clip(jnp.exp(log_w.astype(jnp.float32)), 1e-6, 80.0)
    w = jnp.exp(-rate)
    return w.reshape(b, s, num_heads, d // num_heads).astype(x.dtype)


def token_shift(x: jax.Array, mu: jax.Array,
                prev: jax.Array | None = None) -> jax.Array:
    """lerp(x, x_{t−1}, μ). prev [B, d] is the decode-time shift state."""
    if prev is None:
        shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        shifted = prev[:, None, :]
    return x + mu * (shifted - x)


def channel_mix(x: jax.Array, mu: jax.Array, w_r: jax.Array, w_k: jax.Array,
                w_v: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """RWKV channel-mix: sigmoid receptance gate on a squared-ReLU MLP."""
    xs = token_shift(x, mu, prev)
    rgate = jax.nn.sigmoid(xs @ w_r)
    h = jnp.square(jax.nn.relu(xs @ w_k))
    return rgate * (h @ w_v)
