"""Selective SSM (Mamba) block — the state-space mixer in Jamba
(arXiv:2403.19887 interleaves 1 attention : 7 Mamba layers).

Diagonal selective scan:
  Δ_t = softplus(x_t W_Δ + b_Δ)                 [B, S, d_inner]
  h_t = exp(Δ_t ⊗ A) ⊙ h_{t−1} + (Δ_t x_t) ⊗ B_t   (A diagonal, [d_inner, N])
  y_t = ⟨h_t, C_t⟩_N + D ⊙ x_t

Runs as a chunked remat'd ``lax.scan`` over time with the O(B·d_inner·N)
state as carry (same TPU adaptation rationale as rwkv.py: no [B,S,d_inner,N]
history in HBM). Decode keeps (conv window, ssm state) as a constant-size
cache — this is what makes the 500k-token decode shape tractable for the
hybrid architecture.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def causal_conv1d(x: jax.Array, w: jax.Array,
                  prev: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv. x [B,S,C], w [K,C]; prev [B,K−1,C] for decode."""
    ksz = w.shape[0]
    if prev is None:
        xp = jnp.pad(x, ((0, 0), (ksz - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([prev, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(ksz))
    return out


def ssm_chunk_scan(
    x: jax.Array,        # [B, S, d_inner] (post-conv, post-activation)
    delta: jax.Array,    # [B, S, d_inner]
    a_log: jax.Array,    # [d_inner, N]  (A = −exp(a_log))
    b_t: jax.Array,      # [B, S, N]
    c_t: jax.Array,      # [B, S, N]
    d_skip: jax.Array,   # [d_inner]
    state: jax.Array,    # [B, d_inner, N]
    *,
    chunk: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B, S, d_inner], new_state)."""
    b, s, d_inner = x.shape
    n = a_log.shape[1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        zp = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        x, delta, b_t, c_t = zp(x), zp(delta), zp(b_t), zp(c_t)

    a = -jnp.exp(a_log.astype(jnp.float32))            # [d_inner, N]

    def chunk_body(st, xs):
        xc, dc, bc, cc = xs                            # [chunk, B, ...]

        def step(h, inp):
            xt, dt, bt, ct = inp                       # [B,d_inner],[B,N]...
            da = jnp.exp(dt[..., None] * a[None])      # [B, d_inner, N]
            h = da * h + (dt * xt)[..., None] * bt[:, None, :]
            y = jnp.einsum("bdn,bn->bd", h, ct)
            return h, y

        return jax.lax.scan(step, st, (xc, dc, bc, cc))

    chunk_body = jax.checkpoint(chunk_body)
    to_chunks = lambda t: t.astype(jnp.float32).reshape(
        b, nc, chunk, -1).transpose(1, 2, 0, 3)
    state, ys = jax.lax.scan(
        chunk_body, state.astype(jnp.float32),
        (to_chunks(x), to_chunks(delta), to_chunks(b_t), to_chunks(c_t)))
    y = ys.reshape(nc * chunk, b, d_inner).transpose(1, 0, 2)[:, :s]
    y = y.astype(x.dtype) + x[:, :s] * d_skip[None, None, :].astype(x.dtype)
    return y, state


def ssm_step(
    x: jax.Array,        # [B, d_inner]
    delta: jax.Array,    # [B, d_inner]
    a_log: jax.Array,    # [d_inner, N]
    b_t: jax.Array,      # [B, N]
    c_t: jax.Array,      # [B, N]
    d_skip: jax.Array,   # [d_inner]
    state: jax.Array,    # [B, d_inner, N]
) -> tuple[jax.Array, jax.Array]:
    a = -jnp.exp(a_log.astype(jnp.float32))
    xf = x.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    da = jnp.exp(df[..., None] * a[None])
    st = da * state.astype(jnp.float32) \
        + (df * xf)[..., None] * b_t.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bdn,bn->bd", st, c_t.astype(jnp.float32))
    y = y.astype(x.dtype) + x * d_skip[None, :].astype(x.dtype)
    return y, st
