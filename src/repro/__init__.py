"""repro — DeKRR-DDRF (TNNLS 2024) reproduction + multi-pod JAX framework."""

__version__ = "1.0.0"
