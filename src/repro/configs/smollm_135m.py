"""smollm-135m [dense] — 30L d_model=576 9H (GQA kv=3) d_ff=1536
vocab=49152, llama-arch small, tied embeddings.
[hf:HuggingFaceTB/SmolLM-135M]"""
from repro.configs.registry import ArchSpec
from repro.models.model import ModelConfig, SlotSpec


def spec() -> ArchSpec:
    return ArchSpec(
        config=ModelConfig(
            name="smollm-135m",
            num_layers=30, d_model=576, num_heads=9, num_kv_heads=3,
            head_dim=64, d_ff=1536, vocab_size=49152,
            slots=(SlotSpec("attn", "dense"),),
            tie_embeddings=True,
            citation="hf:HuggingFaceTB/SmolLM-135M",
        ),
        long_context_mode="swa",
    )
