"""llava-next-mistral-7b [vlm] — 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000, anyres tiling. [hf:llava-hf/llava-v1.6-mistral-7b-hf]

The transformer BACKBONE only (Mistral-7B decoder). The vision frontend
(SigLIP/CLIP ViT + anyres tiling + projector) is the assignment's allowed
stub: input_specs() supplies pre-projected patch embeddings [B, S_img, d]
which are prepended to the text tokens.
"""
from repro.configs.registry import ArchSpec
from repro.models.model import ModelConfig, SlotSpec


def spec() -> ArchSpec:
    return ArchSpec(
        config=ModelConfig(
            name="llava-next-mistral-7b",
            num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
            head_dim=128, d_ff=14336, vocab_size=32000,
            slots=(SlotSpec("attn", "dense"),),
            citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
        ),
        input_kind="vlm",
        long_context_mode="swa",
    )
