"""qwen1.5-32b [dense] — 64L d_model=5120 40H (GQA kv=40) d_ff=27392
vocab=152064, QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""
from repro.configs.registry import ArchSpec
from repro.models.model import ModelConfig, SlotSpec


def spec() -> ArchSpec:
    return ArchSpec(
        config=ModelConfig(
            name="qwen1.5-32b",
            num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
            head_dim=128, d_ff=27392, vocab_size=152064, qkv_bias=True,
            slots=(SlotSpec("attn", "dense"),),
            citation="hf:Qwen/Qwen1.5-0.5B",
        ),
        long_context_mode="swa",
    )
