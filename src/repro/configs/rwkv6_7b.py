"""rwkv6-7b [ssm] — 32L d_model=4096 (attention-free) d_ff=14336
vocab=65536. Finch: data-dependent decay. [arXiv:2404.05892]

long_500k runs natively: the recurrent state is constant-size, decode cost
is O(1) in context length.
"""
from repro.configs.registry import ArchSpec
from repro.models.model import ModelConfig, SlotSpec


def spec() -> ArchSpec:
    return ArchSpec(
        config=ModelConfig(
            name="rwkv6-7b",
            num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
            d_ff=14336, vocab_size=65536,
            slots=(SlotSpec("rwkv", "rwkv_cmix"),),
            rwkv_head_dim=64,
            citation="arXiv:2404.05892",
        ),
        long_context_mode="native",
    )
