"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155. [hf:ibm-granite/granite-3.0-2b-base]"""
from repro.configs.registry import ArchSpec
from repro.models.model import ModelConfig, SlotSpec


def spec() -> ArchSpec:
    return ArchSpec(
        config=ModelConfig(
            name="granite-3-8b",
            num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
            head_dim=128, d_ff=12800, vocab_size=49155,
            slots=(SlotSpec("attn", "dense"),),
            citation="hf:ibm-granite/granite-3.0-2b-base",
        ),
        long_context_mode="swa",
    )
