"""Architecture registry: the 10 assigned architectures × 4 input shapes.

Each ``src/repro/configs/<id>.py`` exposes ``spec() -> ArchSpec`` with the
exact assigned configuration (citation in brackets) plus a reduced smoke
variant. ``--arch <id>`` in the launchers resolves through ``get_arch``.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.model import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


INPUT_SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    config: ModelConfig
    # tokens: plain LM. vlm: stub patch embeds + tokens. audio: stub frame
    # embeds only (encoder).
    input_kind: str = "tokens"
    supports_decode: bool = True        # False for encoder-only (hubert)
    # long_500k handling: native (ssm/hybrid) | swa (dense w/ sliding-window
    # variant, window below) | skip
    long_context_mode: str = "swa"
    long_context_window: int = 8192

    def shape_plan(self, shape: str) -> str:
        """'run' | 'run-swa' | 'skip' for a given input-shape name."""
        spec = INPUT_SHAPES[shape]
        if spec.kind == "decode" and not self.supports_decode:
            return "skip"
        if shape == "long_500k":
            if self.long_context_mode == "skip":
                return "skip"
            if self.long_context_mode == "swa":
                return "run-swa"
        return "run"


ARCH_IDS = [
    "qwen1_5_0_5b",
    "llava_next_mistral_7b",
    "hubert_xlarge",
    "granite_3_8b",
    "smollm_135m",
    "rwkv6_7b",
    "qwen1_5_32b",
    "deepseek_moe_16b",
    "jamba_1_5_large_398b",
    "phi3_5_moe_42b",
]

_ALIASES = {
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "hubert-xlarge": "hubert_xlarge",
    "granite-3-8b": "granite_3_8b",
    "smollm-135m": "smollm_135m",
    "rwkv6-7b": "rwkv6_7b",
    "qwen1.5-32b": "qwen1_5_32b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
}


def list_archs() -> list[str]:
    return list(ARCH_IDS)


def get_arch(name: str) -> ArchSpec:
    mod_name = _ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.spec()
