"""deepseek-moe-16b [moe] — 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64 routed experts top-6 + 2 shared experts
(fine-grained expert segmentation). [arXiv:2401.06066]"""
from repro.configs.registry import ArchSpec
from repro.models.model import ModelConfig, SlotSpec


def spec() -> ArchSpec:
    return ArchSpec(
        config=ModelConfig(
            name="deepseek-moe-16b",
            num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
            head_dim=128, d_ff=1408, vocab_size=102400,
            slots=(SlotSpec("attn", "moe"),),
            moe_num_experts=64, moe_experts_per_token=6,
            moe_num_shared_experts=2,
            citation="arXiv:2401.06066",
        ),
        long_context_mode="swa",
    )
