"""The paper's own experimental configurations (§IV), as data.

Usable directly:  from repro.configs.paper_dekrr import PAPER_EXPERIMENTS
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperExperiment:
    name: str
    datasets: tuple[str, ...]
    partition: str                 # noniid_y | noniid_xnorm | imbalanced
    num_nodes: int = 10
    neighbors: int = 4             # circulant(10, (1, 2))
    dbar: dict | int | None = None
    repetitions: int = 10
    notes: str = ""


# 5-fold CV grids from §IV-A
CV_LAMBDA = tuple(10.0 ** i for i in range(-8, -1))
CV_SIGMA = tuple(2.0 ** i for i in range(-2, 3))
# paper grid (c_nei ∈ {2^i N}); our synthetic stand-ins need the extended
# low end (DESIGN.md §8) — both are exposed
CV_C_NEI_PAPER = tuple(2.0 ** i for i in range(-1, 4))
CV_C_NEI_EXTENDED = (0.002, 0.01, 0.05, 0.5, 2.0)
C_SELF_RATIO = 5.0
D0_OVER_D = 20                     # [33]'s candidate ratio
DKLA_RHO = 1e-4                    # doubled every 200 iterations

PAPER_EXPERIMENTS = (
    PaperExperiment(
        name="table2_noniid_y",
        datasets=("houses", "air_quality", "energy", "twitter",
                  "toms_hardware", "wave"),
        partition="noniid_y",
        dbar={"houses": 70, "air_quality": 80, "energy": 100,
              "twitter": 130, "toms_hardware": 150, "wave": 200},
        notes="Tab. 2: mean RSE, paired t-test at 1%; ours wins 6/6",
    ),
    PaperExperiment(
        name="fig1_noniid_y_sweep",
        datasets=("houses", "air_quality", "energy", "twitter",
                  "toms_hardware", "wave"),
        partition="noniid_y",
        notes="RSE vs D̄ curves",
    ),
    PaperExperiment(
        name="fig2_noniid_xnorm_sweep",
        datasets=("houses", "air_quality", "energy", "twitter",
                  "toms_hardware", "wave"),
        partition="noniid_xnorm",
    ),
    PaperExperiment(
        name="fig3_imbalanced",
        datasets=("twitter",),
        partition="imbalanced",
        notes="N_j = (2j−1)N/100; D_j = √N_j·J·D̄/Σ√N_j variant; "
              "λ=1e-6, σ=4 in the paper",
    ),
    PaperExperiment(
        name="fig4_pernode",
        datasets=("twitter",),
        partition="imbalanced",
        dbar=100,
    ),
)
