"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2, Mamba:attention 7:1 interleave.
[arXiv:2403.19887]

Period of 8 slots: slot 0 is attention, slots 1–7 Mamba; MoE replaces the
dense FFN on every other slot. long_500k runs natively — only 9 of 72
layers hold a KV cache (sequence-sharded over the data axis); the Mamba
states are constant-size.
"""
from repro.configs.registry import ArchSpec
from repro.models.model import ModelConfig, SlotSpec


def spec() -> ArchSpec:
    slots = tuple(
        SlotSpec("attn" if i == 0 else "mamba",
                 "moe" if i % 2 == 1 else "dense")
        for i in range(8)
    )
    return ArchSpec(
        config=ModelConfig(
            name="jamba-1.5-large-398b",
            num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
            head_dim=128, d_ff=24576, vocab_size=65536,
            slots=slots,
            moe_num_experts=16, moe_experts_per_token=2,
            ssm_state_dim=16, ssm_conv_width=4, ssm_expand=2,
            citation="arXiv:2403.19887",
        ),
        long_context_mode="native",
    )
