from repro.configs.registry import (ArchSpec, INPUT_SHAPES, ShapeSpec,
                                    get_arch, list_archs)

__all__ = ["ArchSpec", "INPUT_SHAPES", "ShapeSpec", "get_arch", "list_archs"]
