"""hubert-xlarge [audio] — 48L d_model=1280 16H (GQA kv=16) d_ff=5120
vocab=504 (k-means cluster targets), encoder-only, same arch as wav2vec2.
[arXiv:2106.07447]

Backbone only: the mel-spectrogram + conv feature extractor frontend is the
assignment's allowed stub — input_specs() supplies frame embeddings
[B, S, d]. Training = masked prediction over the 504 cluster vocabulary.
Encoder-only ⇒ no autoregressive decode (decode shapes skipped, DESIGN.md §5).
"""
from repro.configs.registry import ArchSpec
from repro.models.model import ModelConfig, SlotSpec


def spec() -> ArchSpec:
    return ArchSpec(
        config=ModelConfig(
            name="hubert-xlarge",
            num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
            head_dim=80, d_ff=5120, vocab_size=504,
            slots=(SlotSpec("attn", "dense"),),
            is_encoder=True, act="gelu",
            citation="arXiv:2106.07447",
        ),
        input_kind="audio",
        supports_decode=False,
        long_context_mode="skip",
    )
