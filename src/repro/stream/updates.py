"""Incremental maintenance of the Eq. 17 auxiliaries under streaming data.

The batch runtime (`repro.dist.pack_problem`) builds the per-node
auxiliaries from ALL data at once — O(D² N) featurize/Gram work plus an
O(D³) inverse per node. When node j ingests a minibatch (X_b, Y_b) of b
samples, only low-rank pieces of the network state actually change, and
this module folds them in exactly:

  * Gram_jj              += Z_b,j Z_b,jᵀ      (node j's map on the batch)
  * Gram(Z_{p,j}), p∈N_j += Z_b,p Z_b,pᵀ      (each neighbor's map on it)
  * d̃_j                  += Z_b,j Y_bᵀ
  * S̃_j                  += (2c_self,j/|N̂_j|) Z_b,j Z_b,jᵀ
  * P̃_{j,p} / P̃_{p,j}    += rank-b cross terms Z_b,j Z_b,pᵀ / Z_b,p Z_b,jᵀ

so each Eq. 17 matrix A_i of the 1 + |N_j| affected nodes moves by a
rank-b symmetric update c·U Uᵀ, and its maintained inverse follows by the
Woodbury identity

    G ← G − (G U) (c⁻¹ I_b + Uᵀ G U)⁻¹ (G U)ᵀ            — O(D² b + b³)

instead of an O(D³) re-inversion. All 1 + |N_j| nodes update in one
vmapped program (`ingest`), gathered/scattered through the packed
[J, D_max, …] layout, so the per-ingest cost is O(deg · D² b) regardless
of J or of the accumulated sample count.

Normalization. Every data-dependent term of Eq. 17 carries a global 1/N
(N = network-wide sample count), which would couple EVERY node's matrix
to every ingest. The state therefore lives in *unnormalized* space, where
all coefficients are N-free:

    B_j = u_self,j Gram_jj + Σ_{p∈N_j} u_cross,p Gram(Z_{j,p})
    u_self,j  = 1 + (2 c_self,j + |N_j| c_nei,j) / |N̂_j|
    u_cross,j = c_nei,j / |N̂_j|

and `to_packed` re-applies the live 1/N when materializing a
`PackedProblem` (a pure elementwise rescale — the Eq. 19 round map is
invariant to it). The one term that is NOT a rescale is the ridge: the
paper's (λ/J) I sits outside the 1/N, so in unnormalized space it is
ν I with ν = λ N/J. A change of N shifts ν I — a full-rank perturbation
no low-rank update can track — so the stream pins ν at construction
(ν = λ n_ref / J, n_ref = the sample count at stream start). That is the
standard online-ridge convention (fixed absolute regularizer; per-sample
regularization decays as data accumulates), and it is exactly
reproducible from scratch: the stream state after any ingest sequence
equals `pack_problem` on the accumulated data with
λ_eff = λ · n_ref / n_live (`reference_lam`), at rtol 1e-9 under x64
(tests/test_stream.py; keep λ large enough that cond(A) ≲ 1e6 — Woodbury
and direct inversion agree to ~cond·eps).

A per-node DDRF feature *refresh* (new frequencies, possibly a new D_j)
is the one event that is not low-rank: every term involving the node's
feature map changes basis. `refresh_node` rebuilds exactly that node's
slot — its B_j/inverse/d̃_j/S̃_j/P̃_j row and the P̃_{p,·} slots of its
neighbors that couple against it — from the accumulated raw data, leaves
every other node's inverse untouched (their B_p do not involve fm_j),
and re-pads the packed layout when max(node_dims) changes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rff import FeatureMap
from repro.dist.dekrr_spmd import (PackedProblem, _featurize_raw,
                                   _gauss_jordan_inv, _stage_feature_maps,
                                   pack_problem)

__all__ = [
    "StreamAux",
    "init_stream_aux",
    "ingest",
    "refresh_node",
    "to_packed",
    "repad_theta",
    "reference_lam",
]


# --------------------------------------------------------------------------
# State container
# --------------------------------------------------------------------------
@dataclasses.dataclass
class StreamAux:
    """Streaming sufficient statistics in the packed [J, D_max, …] layout.

    Array state (jax arrays; unnormalized space — see module docstring):
      binv: [J, D_max, D_max]    (B_j + ν I)⁻¹, Woodbury-maintained; the
                                 padded diagonal block is the identity
                                 (masked off at materialization).
      zy:   [J, D_max]           d̃_j = Z_jj Y_jᵀ (multi-output streams
                                 carry [J, D_max, Dy] — one label column
                                 per output; every other auxiliary is
                                 features-only and keeps its shape).
      st:   [J, D_max, D_max]    S̃_j.
      pt:   [J, K, D_max, D_max] P̃_{j, nbr_idx[j,k]}.
      theta_mask / nbr_idx / nbr_mask: the packed layout tables
                                 (`repro.dist.PackedProblem` semantics).

    Staged feature maps (what lets ANY node featurize a minibatch in one
    uniform padded program): omega [J, F_max, dim], bias [J, F_max],
    feat_idx [J, D_max], scale [J] — `repro.dist._stage_packed_inputs`
    conventions exactly.

    Scalars / metadata: n_live (accumulated network sample count — the
    1/N used at materialization), nu (the pinned absolute ridge λ·n_ref/J),
    n_ref, node_dims, offsets, kind, and the N-free coupling coefficients
    u_self/u_cross/u_s [J] (host-side numpy — read per ingest without a
    device sync) plus two host-side slot-table derivatives that keep the
    per-minibatch hot path free of device→host transfers:
    ingest_tables = (idx [J, 1+K], gate [J, 1+K], cvec [J, 1+K]) — the
    affected-row indices, live-slot gates, and Woodbury coefficients of
    each node's ingest — and the reverse slot table rslot [J, K]
    (rslot[j, k] = the slot of node j inside nbr_idx[j, k]'s table).
    """

    binv: jax.Array
    zy: jax.Array
    st: jax.Array
    pt: jax.Array
    theta_mask: jax.Array
    nbr_idx: jax.Array
    nbr_mask: jax.Array
    omega: jax.Array
    bias: jax.Array
    feat_idx: jax.Array
    scale: jax.Array
    u_self: np.ndarray
    u_cross: np.ndarray
    u_s: np.ndarray
    ingest_tables: tuple
    rslot: np.ndarray
    n_live: int
    nu: float
    n_ref: int
    node_dims: tuple[int, ...]
    offsets: tuple[int, ...] | None
    kind: str

    @property
    def num_nodes(self) -> int:
        return int(self.zy.shape[0])

    @property
    def max_features(self) -> int:
        return int(self.zy.shape[1])

    @property
    def num_slots(self) -> int:
        return int(self.nbr_idx.shape[1])


def reference_lam(aux: StreamAux) -> float:
    """The ridge a from-scratch `DeKRRSolver` on the accumulated data must
    use to reproduce this stream state exactly: λ_eff = ν·J/N_live
    (= λ·n_ref/n_live — the pinned absolute ridge re-expressed at the live
    normalization)."""
    return aux.nu * aux.num_nodes / aux.n_live


# --------------------------------------------------------------------------
# Layout helpers (feature-map staging is shared with pack_problem —
# repro.dist._stage_feature_maps — so the two can never drift apart)
# --------------------------------------------------------------------------
def _ingest_tables(nbr_idx: np.ndarray, nbr_mask: np.ndarray,
                   u_self: np.ndarray, u_cross: np.ndarray,
                   dtype) -> tuple:
    """Host-side per-node (idx, gate, cvec) rows for `ingest` — constant
    between refreshes, precomputed so the hot path never touches device
    arrays or allocates."""
    j_nodes, k_slots = nbr_idx.shape
    idx = np.concatenate(
        [np.arange(j_nodes, dtype=np.int32)[:, None],
         nbr_idx.astype(np.int32)], axis=1)                 # [J, 1+K]
    gate = np.concatenate(
        [np.ones((j_nodes, 1)), (nbr_mask != 0).astype(np.float64)],
        axis=1).astype(dtype)
    cvec = np.concatenate(
        [u_self[:, None],
         np.broadcast_to(u_cross[:, None], (j_nodes, k_slots))],
        axis=1).astype(dtype)
    return idx, gate, cvec


def _reverse_slots(nbr_idx: np.ndarray, nbr_mask: np.ndarray) -> np.ndarray:
    """rslot[j, k] = slot index of node j inside node nbr_idx[j, k]'s
    table (0 on masked slots — their updates are exact zeros anyway)."""
    j_nodes, k_slots = nbr_idx.shape
    rslot = np.zeros((j_nodes, k_slots), dtype=np.int32)
    for j in range(j_nodes):
        for k in range(k_slots):
            if not nbr_mask[j, k]:
                continue
            p = int(nbr_idx[j, k])
            (hits,) = np.nonzero((np.asarray(nbr_idx[p]) == j)
                                 & (np.asarray(nbr_mask[p]) != 0))
            rslot[j, k] = int(hits[0])
    return rslot


def init_stream_aux(solver, packed: PackedProblem | None = None
                    ) -> StreamAux:
    """Seed the streaming state from a `DeKRRSolver` snapshot.

    Uses (or builds) the batched `pack_problem` of the solver and converts
    it to unnormalized space: binv = g/N (+ identity padding — exact, the
    packed g IS N·(B + νI)⁻¹ on live coordinates), d̃ = d·N, S̃ = s·N,
    P̃ = p·N. Pins the ridge at ν = λ·N/J (see module docstring).
    """
    if getattr(solver, "_gram_fn", None) is not None:
        raise ValueError("repro.stream cannot maintain auxiliaries built "
                         "through a custom gram_fn")
    if any(getattr(nd, "bags", None) is not None for nd in solver.data):
        raise ValueError(
            "repro.stream cannot maintain auxiliaries for "
            "aggregate-observation (bagged) nodes — a bag couples its "
            "members through the label term, so a minibatch fold is not "
            "rank-b in the bagged Gram")
    if packed is None:
        packed = pack_problem(solver)
    dtype = np.asarray(packed.d).dtype
    n = solver.N
    staged = _stage_feature_maps(solver.feature_maps, dtype)
    if staged["node_dims"] != packed.node_dims:
        raise ValueError("solver feature maps disagree with packed.node_dims")

    mask = packed.theta_mask
    pad_eye = jnp.eye(packed.max_features, dtype=dtype)[None] \
        * (1.0 - mask)[:, :, None] * (1.0 - mask)[:, None, :]
    binv = packed.g / n + pad_eye

    hood = solver.topology.degrees.astype(np.float64) + 1.0
    c_nei = np.asarray(solver.c_nei, np.float64)
    c_self = np.asarray(solver.c_self, np.float64)
    degs = solver.topology.degrees.astype(np.float64)
    u_self = 1.0 + (2.0 * c_self + degs * c_nei) / hood
    u_cross = c_nei / hood
    u_s = 2.0 * c_self / hood

    nbr_idx = np.asarray(packed.nbr_idx)
    nbr_mask = np.asarray(packed.nbr_mask)
    u_self = u_self.astype(dtype)
    u_cross = u_cross.astype(dtype)
    return StreamAux(
        binv=binv, zy=packed.d * n, st=packed.s * n, pt=packed.p * n,
        theta_mask=mask, nbr_idx=packed.nbr_idx, nbr_mask=packed.nbr_mask,
        omega=jnp.asarray(staged["omega"]), bias=jnp.asarray(staged["bias"]),
        feat_idx=jnp.asarray(staged["feat_idx"]),
        scale=jnp.asarray(staged["scale"].astype(dtype)),
        u_self=u_self, u_cross=u_cross, u_s=u_s.astype(dtype),
        ingest_tables=_ingest_tables(nbr_idx, nbr_mask, u_self, u_cross,
                                     dtype),
        rslot=_reverse_slots(nbr_idx, nbr_mask),
        n_live=int(n), nu=float(solver.config.lam * n / solver.J),
        n_ref=int(n), node_dims=packed.node_dims, offsets=packed.offsets,
        kind=staged["kind"],
    )


# --------------------------------------------------------------------------
# Rank-b Woodbury ingest — one vmapped program over the affected nodes
# --------------------------------------------------------------------------
def _packed_featurize(omega, bias, feat_idx, feat_mask, scale, x, col_mask,
                      kind):
    """One node's map on a minibatch, in packed feature space: [D_max, B].
    Identical arithmetic to `repro.dist._node_aux`'s featurize+pack
    (HIGHEST-precision einsum, take/scale/mask) so parity with the batch
    build holds at rtol 1e-9."""
    raw = _featurize_raw(omega, bias, x, kind)
    return (jnp.take(raw, feat_idx, axis=0) * scale * feat_mask[:, None]
            * col_mask[None, :])


@partial(jax.jit, static_argnames=("kind",))
def _ingest_kernel(binv, zy, st, pt, theta_mask, omega, bias, feat_idx,
                   scale, idx, gate, cvec, rslot_j, u_s_j, u_cross_j,
                   xb, yb, col_mask, *, kind):
    """Fold one minibatch at node idx[0] into (binv, zy, st, pt).

    idx [1+K]: the affected rows (the node, then its slot table); gate
    [1+K]: 1.0 for the node and live slots, 0.0 for padded slots (their
    contributions vanish exactly); cvec [1+K]: the rank-b coefficients
    (u_self of the node, then its u_cross for every neighbor row).
    """
    hi = jax.lax.Precision.HIGHEST
    feat_mask = theta_mask[idx]                        # [A, D_max]

    def feat(om, bi, fi, fm, sc):
        return _packed_featurize(om, bi, fi, fm, sc, xb, col_mask, kind)

    zb = jax.vmap(feat)(omega[idx], bias[idx], feat_idx[idx], feat_mask,
                        scale[idx])                    # [A, D_max, B]
    zb = zb * gate[:, None, None]

    # Woodbury: G += -(G U)(c⁻¹I + Uᵀ G U)⁻¹(G U)ᵀ per affected node
    g_rows = binv[idx]                                 # [A, D, D]
    gu = jnp.einsum("aij,ajb->aib", g_rows, zb, precision=hi)
    utgu = jnp.einsum("aib,aic->abc", zb, gu, precision=hi)
    safe_c = jnp.where(cvec != 0, cvec, 1.0)
    mid = (jnp.eye(zb.shape[-1], dtype=zb.dtype)[None]
           / safe_c[:, None, None] + utgu)
    sol = jnp.linalg.solve(mid, jnp.swapaxes(gu, 1, 2))  # [A, B, D]
    corr = -jnp.einsum("aib,abj->aij", gu, sol, precision=hi)
    corr = corr * (cvec != 0)[:, None, None]
    binv = binv.at[idx].add(corr)

    zbj, zbn = zb[0], zb[1:]
    if zy.ndim == 3:                         # multi-output: yb is [B, Dy]
        zy = zy.at[idx[0]].add(
            jnp.einsum("db,bo->do", zbj, yb, precision=hi))
    else:
        zy = zy.at[idx[0]].add(
            jnp.einsum("db,b->d", zbj, yb, precision=hi))
    gram_b = jnp.einsum("ab,cb->ac", zbj, zbj, precision=hi)
    st = st.at[idx[0]].add(u_s_j * gram_b)
    # P̃_{j,k} += u_cross[j]·Z_b,j Z_b,pᵀ ; P̃_{p,rslot} += u_cross[j]·Z_b,p Z_b,jᵀ
    pt = pt.at[idx[0]].add(
        u_cross_j * jnp.einsum("db,kcb->kdc", zbj, zbn, precision=hi))
    pt = pt.at[idx[1:], rslot_j].add(
        u_cross_j * jnp.einsum("kdb,cb->kdc", zbn, zbj, precision=hi))
    return binv, zy, st, pt


def _bucket(b: int) -> int:
    """Pad minibatches to power-of-two buckets (min 8) so the jitted
    ingest program compiles once per bucket, not once per batch size."""
    return max(8, 1 << (b - 1).bit_length())


def ingest(aux: StreamAux, node: int, xb, yb) -> StreamAux:
    """Fold minibatch (xb [d, b], yb [b] — or [b, Dy] when the stream
    state carries a multi-output `zy` [J, D_max, Dy]) arriving at `node`
    into the stream state — O(deg · D² b) exact rank-b updates, no O(D³)
    work. Returns a new `StreamAux` (the array state is functional)."""
    j = int(node)
    if not 0 <= j < aux.num_nodes:
        raise ValueError(f"node {j} out of range for J={aux.num_nodes}")
    dtype = aux.zy.dtype
    xb = np.asarray(xb, dtype=dtype)
    if aux.zy.ndim == 3:
        dy = aux.zy.shape[2]
        yb = np.asarray(yb, dtype=dtype)
        if yb.ndim != 2 or yb.shape[1] != dy:
            raise ValueError(f"multi-output stream (Dy={dy}) needs "
                             f"y [b, {dy}]; got {yb.shape}")
    else:
        yb = np.asarray(yb, dtype=dtype).reshape(-1)
    if xb.ndim != 2 or xb.shape[1] != yb.shape[0]:
        raise ValueError(f"minibatch must be x [d, b], y [b]; got "
                         f"{xb.shape} / {yb.shape}")
    b = xb.shape[1]
    if b == 0:
        return aux
    bb = _bucket(b)
    col_mask = (np.arange(bb) < b).astype(dtype)
    xb = np.pad(xb, ((0, 0), (0, bb - b)))
    yb = np.pad(yb, ((0, bb - b),) + ((0, 0),) * (yb.ndim - 1))

    idx_t, gate_t, cvec_t = aux.ingest_tables      # host-side, no syncs

    binv, zy, st, pt = _ingest_kernel(
        aux.binv, aux.zy, aux.st, aux.pt, aux.theta_mask, aux.omega,
        aux.bias, aux.feat_idx, aux.scale,
        jnp.asarray(idx_t[j]), jnp.asarray(gate_t[j]),
        jnp.asarray(cvec_t[j]), jnp.asarray(aux.rslot[j]),
        aux.u_s[j], aux.u_cross[j],
        jnp.asarray(xb), jnp.asarray(yb), jnp.asarray(col_mask),
        kind=aux.kind)
    return dataclasses.replace(aux, binv=binv, zy=zy, st=st, pt=pt,
                               n_live=aux.n_live + b)


# --------------------------------------------------------------------------
# Per-node feature refresh (DDRF re-selection after drift)
# --------------------------------------------------------------------------
def _resize_packed(arr, old_d, new_d, matrix_axes):
    """Grow or shrink trailing feature axes of a packed array. Shrinking
    is only legal when no live coordinate lives beyond new_d (true by
    construction: new_d = max(new node_dims))."""
    if new_d == old_d:
        return arr
    arr = np.asarray(arr)
    if new_d > old_d:
        widths = [(0, 0)] * arr.ndim
        for ax in matrix_axes:
            widths[ax] = (0, new_d - old_d)
        return np.pad(arr, widths)
    slicer = [slice(None)] * arr.ndim
    for ax in matrix_axes:
        slicer[ax] = slice(0, new_d)
    return arr[tuple(slicer)]


def refresh_node(aux: StreamAux, node: int, new_fmap: FeatureMap,
                 feature_maps: Sequence[FeatureMap],
                 data_x: Sequence, data_y) -> StreamAux:
    """Rebuild node `node`'s slot after a DDRF feature refresh.

    `feature_maps` is the post-refresh list (entry `node` == `new_fmap`);
    `data_x[i]` is node i's ACCUMULATED inputs [d, N_i] — only the node
    and its live neighbors are read, other entries may be None; `data_y`
    the node's accumulated labels.

    Only state involving the refreshed map is recomputed: the node's
    B/inverse/d̃/S̃/P̃ row and the neighbors' P̃ slots that couple against
    it. Neighbor B_p matrices do not involve fm_node (their cross terms
    are fm_p on X_node, and X_node is unchanged), so every other inverse
    is left bit-identical. When max(node_dims) changes the whole layout
    re-pads; carry per-node θ across with `repad_theta`.
    """
    j = int(node)
    dtype = aux.zy.dtype
    if feature_maps[j] is not new_fmap:
        raise ValueError(
            "feature_maps[node] must be the refreshed map itself — the "
            "slot is rebuilt from feature_maps, so a stale entry would "
            "silently rebuild with the OLD map")
    staged = _stage_feature_maps(feature_maps, dtype)
    new_dims = staged["node_dims"]
    if new_dims[:j] + new_dims[j + 1:] != \
            aux.node_dims[:j] + aux.node_dims[j + 1:]:
        raise ValueError("refresh_node may only change the refreshed "
                         "node's feature count")
    old_d = aux.max_features
    new_d = max(new_dims)
    hi = jax.lax.Precision.HIGHEST

    # Re-pad the packed arrays to the new D_max (identity padding of binv
    # is restored for the grown region; shrinking only ever cuts padding).
    binv = np.array(_resize_packed(aux.binv, old_d, new_d, (1, 2)))
    if new_d > old_d:
        for i in range(new_d - old_d):
            binv[:, old_d + i, old_d + i] = 1.0
    zy = np.array(_resize_packed(aux.zy, old_d, new_d, (1,)))
    st = np.array(_resize_packed(aux.st, old_d, new_d, (1, 2)))
    pt = np.array(_resize_packed(aux.pt, old_d, new_d, (2, 3)))
    theta_mask = (np.arange(new_d)[None, :]
                  < np.asarray(new_dims)[:, None]).astype(dtype)

    omega = jnp.asarray(staged["omega"])
    bias = jnp.asarray(staged["bias"])
    feat_idx = jnp.asarray(staged["feat_idx"])
    scale = jnp.asarray(staged["scale"].astype(dtype))
    fmask = jnp.asarray(theta_mask)

    def feats(i: int, x) -> jax.Array:
        x = jnp.asarray(np.asarray(x, dtype=dtype))
        ones = jnp.ones((x.shape[1],), dtype)
        return _packed_featurize(omega[i], bias[i], feat_idx[i], fmask[i],
                                 scale[i], x, ones, aux.kind)

    if aux.zy.ndim == 3:                    # multi-output: y_j is [N, Dy]
        y_j = jnp.asarray(np.asarray(data_y, dtype=dtype)
                          .reshape(-1, aux.zy.shape[2]))
    else:
        y_j = jnp.asarray(np.asarray(data_y, dtype=dtype).reshape(-1))
    z_self = feats(j, data_x[j])                       # [D', N_j]
    u_self = aux.u_self[j]
    u_cross = aux.u_cross
    gram_self = jnp.einsum("an,bn->ab", z_self, z_self, precision=hi)

    b_new = u_self * gram_self
    if y_j.ndim == 2:
        zy_new = jnp.einsum("dn,no->do", z_self, y_j, precision=hi)
    else:
        zy_new = jnp.einsum("dn,n->d", z_self, y_j, precision=hi)
    st_new = aux.u_s[j] * gram_self

    nbr_row = np.asarray(aux.nbr_idx[j])
    nbr_mask_row = np.asarray(aux.nbr_mask[j])
    pt_j = np.zeros((aux.num_slots, new_d, new_d), dtype=dtype)
    for k in range(aux.num_slots):
        if not nbr_mask_row[k]:
            continue
        p = int(nbr_row[k])
        z_jp = feats(j, data_x[p])                     # fm_new on X_p
        z_pj = feats(p, data_x[j])                     # fm_p on X_j
        z_pp = feats(p, data_x[p])                     # fm_p on X_p
        b_new = b_new + u_cross[p] * jnp.einsum(
            "an,bn->ab", z_jp, z_jp, precision=hi)
        pt_j[k] = np.asarray(
            u_cross[j] * jnp.einsum("an,bn->ab", z_self, z_pj,
                                    precision=hi)
            + u_cross[p] * jnp.einsum("an,bn->ab", z_jp, z_pp,
                                      precision=hi))
        pt[p, aux.rslot[j, k]] = np.asarray(
            u_cross[p] * jnp.einsum("an,bn->ab", z_pp, z_jp, precision=hi)
            + u_cross[j] * jnp.einsum("an,bn->ab", z_pj, z_self,
                                      precision=hi))
    pt[j] = pt_j

    mj = fmask[j]
    a_unnorm = (b_new + aux.nu * jnp.diag(mj)
                + jnp.diag(1.0 - mj))
    binv_j = _gauss_jordan_inv(a_unnorm)
    binv[j] = np.asarray(binv_j * mj[:, None] * mj[None, :]
                         + jnp.diag(1.0 - mj))
    zy[j] = np.asarray(zy_new)
    st[j] = np.asarray(st_new)

    return dataclasses.replace(
        aux,
        binv=jnp.asarray(binv), zy=jnp.asarray(zy),
        st=jnp.asarray(st), pt=jnp.asarray(pt),
        theta_mask=jnp.asarray(theta_mask),
        omega=omega, bias=bias, feat_idx=feat_idx, scale=scale,
        node_dims=new_dims,
    )


# --------------------------------------------------------------------------
# Materialization + θ carry
# --------------------------------------------------------------------------
@jax.jit
def _materialize(binv, zy, st, pt, mask, n):
    fouter = mask[:, :, None] * mask[:, None, :]
    return binv * fouter * n, zy / n, st / n, pt / n


def to_packed(aux: StreamAux) -> PackedProblem:
    """Materialize the live `PackedProblem` at the current normalization —
    a pure elementwise rescale (no inverses, no featurization). The result
    equals `pack_problem` on the accumulated data with
    λ_eff = `reference_lam(aux)` at rtol 1e-9 under x64, and plugs into
    every solver the packed runtime offers (`solve_batched`,
    `async_solve_batched`, the SPMD runners, `repro.core.acceleration`).
    """
    n = jnp.asarray(float(aux.n_live), aux.zy.dtype)
    g, d, s, p = _materialize(aux.binv, aux.zy, aux.st, aux.pt,
                              aux.theta_mask, n)
    num_edges = int(np.count_nonzero(np.asarray(aux.nbr_mask)))
    return PackedProblem(g=g, d=d, s=s, p=p, theta_mask=aux.theta_mask,
                         nbr_idx=aux.nbr_idx, nbr_mask=aux.nbr_mask,
                         offsets=aux.offsets, node_dims=aux.node_dims,
                         num_edges_directed=num_edges)


def repad_theta(theta, old_dims: Sequence[int], new_dims: Sequence[int],
                *, reset: Sequence[int] = ()) -> jax.Array:
    """Carry a packed θ across a node_dims change (feature refresh).

    Rows in `reset` (the refreshed nodes — their θ lives in the OLD
    feature basis) restart from zero; every other row re-pads into the
    new [J, max(new_dims)] layout (multi-output θ [J, max(old_dims), Dy]
    keeps its trailing Dy axis). A non-reset row whose D_j shrank is a
    stale iterate and raises — truncating it would silently drop live
    coordinates.
    """
    old_dims = tuple(int(v) for v in old_dims)
    new_dims = tuple(int(v) for v in new_dims)
    if len(old_dims) != len(new_dims):
        raise ValueError("node count cannot change across a refresh")
    theta = np.asarray(theta)
    lead = (len(old_dims), max(old_dims))
    if theta.shape[:2] != lead or theta.ndim not in (2, 3):
        raise ValueError(
            f"theta has shape {theta.shape} but old_dims describe "
            f"{lead} (+ an optional trailing Dy axis) — pass the θ that "
            f"belongs to the OLD packing")
    reset = {int(r) for r in reset}
    out = np.zeros((len(new_dims), max(new_dims)) + theta.shape[2:],
                   dtype=theta.dtype)
    for i, (do, dn) in enumerate(zip(old_dims, new_dims)):
        if i in reset:
            continue
        if do > dn:
            raise ValueError(
                f"node {i} shrank from D_j={do} to {dn} but is not in "
                f"reset — its θ is stale against the refreshed basis")
        out[i, :do] = theta[i, :do]
    return jnp.asarray(out)
