"""Per-node distribution-drift detection on the DDRF scores.

The paper's premise is that node data "varies significantly on the number
or distribution", so the frequencies worth keeping are data-dependent
(§III-B: energy / kernel-polarization scores [33], ridge leverage scores
[35, 36]). Under streaming ingest that premise cuts the other way too:
when a node's LOCAL distribution drifts, the scores that justified its
selected frequencies go stale, and the node should re-run DDRF selection.

The statistic here is deliberately cheap and lives entirely on the scores
the selection already uses: normalize the score vector of the node's
*selected* frequencies into a distribution, and compare the reference
distribution (scored on the data the features were selected against) with
the same frequencies re-scored on a sliding window of freshly ingested
samples, by total-variation distance

    drift(j) = ½ · Σ_k | ŝ_ref(k) − ŝ_window(k) |   ∈ [0, 1].

Energy scores cost O(F·b·d) per window — noise-robust against label scale
(the normalization divides it out) and sensitive to exactly the quantity
DDRF selection ranks by. Leverage scores (O(D²·b + D³) per window) are
offered for the unsupervised family. A `threshold` policy turns the
statistic into a refresh trigger; windows must reach `min_samples` before
a verdict so single tiny minibatches cannot fire it.

`DriftDetector` is pure bookkeeping — it never touches solver state. The
`repro.stream.runtime.StreamingDeKRR` event loop consumes its verdicts
and performs the actual `refresh_node` rebuild.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.ddrf import energy_scores, leverage_scores
from repro.core.rff import FeatureMap

__all__ = ["DriftConfig", "DriftDetector", "DriftVerdict"]

_SCORE_FAMILIES = ("energy", "leverage")


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """Threshold policy for score-drift refresh triggering.

    Attributes:
      score:        which DDRF score family to compare ("energy" uses the
                    labels, "leverage" is unsupervised).
      threshold:    total-variation trigger level in [0, 1]; a refresh is
                    recommended when the window statistic exceeds it.
      min_samples:  minimum window size before a verdict is issued —
                    smaller windows keep accumulating.
      leverage_lam: ridge for the leverage family.
    """

    score: str = "energy"
    threshold: float = 0.25
    min_samples: int = 64
    leverage_lam: float = 1e-6

    def __post_init__(self):
        if self.score not in _SCORE_FAMILIES:
            raise ValueError(f"score must be one of {_SCORE_FAMILIES}, "
                             f"got {self.score!r}")
        if not 0.0 < self.threshold <= 1.0:
            raise ValueError(f"threshold must be in (0, 1], "
                             f"got {self.threshold}")
        if self.min_samples < 1:
            raise ValueError(f"min_samples must be >= 1, "
                             f"got {self.min_samples}")


@dataclasses.dataclass(frozen=True)
class DriftVerdict:
    """One drift evaluation: the statistic (None while the window is still
    filling) and whether the policy recommends a refresh."""

    stat: float | None
    refresh: bool
    window_samples: int


class DriftDetector:
    """Tracks one score-distribution reference per node plus a window of
    pending ingested samples, and issues `DriftVerdict`s."""

    def __init__(self, feature_maps, data, config: DriftConfig):
        self.config = config
        self._fmaps = list(feature_maps)
        self._ref = [self._normalized_scores(fm, nd.x, nd.y)
                     for fm, nd in zip(self._fmaps, data)]
        j = len(self._fmaps)
        self._win_x: list[list[np.ndarray]] = [[] for _ in range(j)]
        self._win_y: list[list[np.ndarray]] = [[] for _ in range(j)]

    # -- scoring ------------------------------------------------------------
    def _normalized_scores(self, fmap: FeatureMap, x, y) -> np.ndarray:
        x = jnp.asarray(x)
        if self.config.score == "energy":
            s = energy_scores(fmap, x, jnp.asarray(y).reshape(-1))
        else:
            s = leverage_scores(fmap, x, lam=self.config.leverage_lam)
        s = np.maximum(np.asarray(s, np.float64), 0.0)
        total = s.sum()
        if total <= 0.0:
            return np.full(s.shape, 1.0 / s.shape[0])
        return s / total

    # -- event-loop hooks ---------------------------------------------------
    def observe(self, node: int, xb, yb) -> DriftVerdict:
        """Fold one ingested minibatch into node's window; evaluate the
        drift statistic once the window reaches `min_samples` (the window
        then resets, so successive verdicts use disjoint data)."""
        j = int(node)
        self._win_x[j].append(np.asarray(xb))
        self._win_y[j].append(np.asarray(yb).reshape(-1))
        n_win = sum(x.shape[1] for x in self._win_x[j])
        if n_win < self.config.min_samples:
            return DriftVerdict(stat=None, refresh=False,
                                window_samples=n_win)
        x = np.concatenate(self._win_x[j], axis=1)
        y = np.concatenate(self._win_y[j])
        self._win_x[j].clear()
        self._win_y[j].clear()
        win = self._normalized_scores(self._fmaps[j], x, y)
        stat = float(0.5 * np.abs(self._ref[j] - win).sum())
        return DriftVerdict(stat=stat,
                            refresh=stat > self.config.threshold,
                            window_samples=n_win)

    def rebase(self, node: int, fmap: FeatureMap, x, y) -> None:
        """Reset node's reference after a feature refresh: re-score the
        NEW frequencies on the accumulated data and clear the window."""
        j = int(node)
        self._fmaps[j] = fmap
        self._ref[j] = self._normalized_scores(fmap, x, y)
        self._win_x[j].clear()
        self._win_y[j].clear()
