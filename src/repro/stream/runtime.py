"""`StreamingDeKRR` — the online DeKRR-DDRF event loop.

Ties the streaming layers together around the packed runtime:

    ingest(j, Xb, Yb)  ──► rank-b Woodbury fold (`repro.stream.updates`)
          │                     O(deg · D² b), no O(D³), no data replay
          ├──► drift check (`repro.stream.drift`) ──► maybe refresh:
          │        DDRF re-selection on the node's accumulated data,
          │        single-slot rebuild, θ re-padded across the layout
          └──► solve(...): WARM-STARTED consensus continuation —
                   `repro.dist.solve_batched` (sync Jacobi) or
                   `repro.dist.async_solve_batched` (COKE-style gossip),
                   any backend ("xla" | "pallas" | "pallas_fused"),
                   θ carried across epochs, tol-based round budgeting

The runtime's packed problem is always materializable exactly: after any
ingest/refresh sequence, `self.packed` equals `pack_problem` on the
accumulated data at the stream's pinned-ridge normalization
(`reference_solver()` builds that from-scratch comparison; rtol 1e-9
under x64 — the acceptance contract of tests/test_stream.py). Because θ
is carried, each epoch's solve continues from the previous consensus
instead of re-running the full Eq. 19 round count — `benchmarks/
stream_bench.py` traces the warm-vs-cold rounds-to-tol gap.

`snapshot()` exports an immutable view (feature maps + ragged θ + a
staleness bound) for the query-serving path (`repro.serve.dekrr`).

θ shape contract. The carried θ mirrors the packed label block
`packed.d`: `[J, D_max]` for scalar targets, `[J, D_max, Dy]` for
multi-output streams (node j's live coefficients are `theta[j, :D_j]`,
one column per output). Every runtime path — warm starts, tol checks,
`repad_theta` across refreshes, staleness residuals (max|F(θ) − θ| over
features AND outputs), snapshots — carries the trailing axis through
unchanged, and a Dy=1 stream is bit-identical to the scalar layout.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.async_gossip import AsyncGossipConfig
from repro.core.ddrf import select_features
from repro.core.dekrr import DeKRRConfig, DeKRRSolver, NodeData
from repro.core.rff import FeatureMap, featurize
from repro.dist import async_solve_batched, solve_batched, step_batched
from repro.obs.spans import span
from repro.stream.drift import DriftConfig, DriftDetector, DriftVerdict
from repro.stream.updates import (StreamAux, ingest as _fold, init_stream_aux,
                                  reference_lam, refresh_node, repad_theta,
                                  to_packed)

__all__ = [
    "StreamConfig",
    "StreamingDeKRR",
    "IngestReport",
    "RefreshReport",
    "SnapshotRegistry",
    "SolveReport",
    "StalenessBound",
]

_GOSSIP = ("sync", "async")


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Streaming-runtime policy knobs.

    backend / gossip pick how the warm-started consensus continuation
    executes — every combination the packed runtime supports ("xla" |
    "pallas" | "pallas_fused" × "sync" | "async"). `rounds_per_epoch` is
    the per-solve round budget; with `tol > 0` the solve stops early on
    max|Δθ| < tol (warm starts make this the common case). `drift`
    enables automatic per-node feature refreshes; refreshed maps are
    re-selected with `refresh_method` on the node's accumulated data at
    kernel bandwidth `sigma` — None (default) recovers the bandwidth
    from the node's CURRENT frequencies (ω ~ N(0, σ⁻²I), so
    σ̂ = 1/std(ω) is the maximum-likelihood estimate), which keeps a
    drift-triggered refresh on the kernel the stream was built with
    instead of silently resetting to some fixed default.
    """

    backend: str = "xla"
    gossip: str = "sync"
    async_config: AsyncGossipConfig = AsyncGossipConfig()
    rounds_per_epoch: int = 200
    tol: float = 1e-8
    chunk_rounds: int | None = None
    drift: DriftConfig | None = None
    refresh_method: str = "energy"
    refresh_candidate_ratio: int = 10
    sigma: float | None = None
    seed: int = 0

    def __post_init__(self):
        if self.gossip not in _GOSSIP:
            raise ValueError(f"gossip must be one of {_GOSSIP}, "
                             f"got {self.gossip!r}")
        if self.rounds_per_epoch < 1:
            raise ValueError("rounds_per_epoch must be >= 1")
        if self.tol < 0:
            raise ValueError("tol must be >= 0")


@dataclasses.dataclass(frozen=True)
class StalenessBound:
    """How far an answer computed from a θ snapshot can be from the live
    full-precision prediction — a staleness term AND a precision term.

    theta_version:   increments on every solve.
    ingests_behind:  ingest events folded since θ was last solved.
    samples_behind:  samples those ingests carried.
    residual:        max|F(θ) − θ| of the snapshot θ under the CURRENT
                     packed operator (one extra Eq. 19 round) — the
                     contraction residual; θ is within
                     residual / (1 − ρ(M)) of the live fixed point. For
                     multi-output θ the max runs over features AND
                     outputs, so the bound holds for every output column
                     of every answer simultaneously.
    precision:       per-answer inference-precision bound, in ANSWER
                     units: |f_served − f_hi(θ)| ≤ precision, where f_hi
                     is the same Eq. 1 dot product evaluated at the
                     snapshot dtype (the solve's x64). 0.0 on the
                     full-precision path. On the mixed-precision serving
                     paths (`repro.serve.dekrr`, precision="bf16"/"int8")
                     it is max(analytic forward-error bound of the
                     low-precision featurize+GEMV for this answer,
                     |f_hi − f_lo| measured per wave on a calibration
                     stripe) — so every answer carries staleness and
                     quantization error through ONE contract, in the
                     communication/precision-budget spirit of COKE
                     (arXiv:2001.10133).
    """

    theta_version: int
    ingests_behind: int
    samples_behind: int
    residual: float
    precision: float = 0.0


@dataclasses.dataclass(frozen=True)
class IngestReport:
    node: int
    batch_size: int
    drift: DriftVerdict | None
    refreshed: bool


@dataclasses.dataclass(frozen=True)
class RefreshReport:
    node: int
    old_features: int
    new_features: int
    repadded: bool


@dataclasses.dataclass(frozen=True)
class SolveReport:
    rounds_run: int
    budget: int
    converged: bool
    residual: float
    theta_version: int


@dataclasses.dataclass(frozen=True)
class ServeSnapshot:
    """Immutable θ view for the serving path (`repro.serve.dekrr`).

    Construction validates the serving contract so malformed snapshots
    fail HERE, with the per-node facts named, instead of deep inside a
    wave's `jnp.stack`/GEMM with an anonymous shape error:

      * one θ per feature map, every θ either [D_j] (scalar targets) or
        [D_j, Dy] with ONE shared Dy (mixed scalar/multi-output θ is
        rejected with the per-node output widths listed);
      * every θ's feature count equals its map's `num_features`;
      * one shared θ dtype (the wave is cast to it — a lone f32 node
        would silently degrade every sibling's x64 answer);
      * one shared query input dim across the maps' Ω matrices.
    """

    feature_maps: tuple[FeatureMap, ...]
    theta: tuple[jax.Array, ...]
    staleness: StalenessBound

    def __post_init__(self):
        fmaps, theta = self.feature_maps, self.theta
        if len(fmaps) == 0 or len(fmaps) != len(theta):
            raise ValueError(
                f"snapshot needs one θ per feature map, got "
                f"{len(theta)} θ for {len(fmaps)} maps")
        widths = [1 if t.ndim == 1 else (t.shape[1] if t.ndim == 2 else -1)
                  for t in theta]
        if any(w < 0 for w in widths):
            raise ValueError(
                f"snapshot θ must be [D_j] or [D_j, Dy], got ndim "
                f"{[t.ndim for t in theta]}")
        ndims = {t.ndim for t in theta}
        if len(ndims) > 1 or (2 in ndims and len(set(widths)) > 1):
            raise ValueError(
                f"mixed scalar/multi-output θ snapshot: per-node output "
                f"widths {widths} (ndim {[t.ndim for t in theta]}) — "
                f"pack every node's θ as [D_j], or every node's as "
                f"[D_j, Dy] with one shared Dy")
        feats = [(int(t.shape[0]), fm.num_features)
                 for t, fm in zip(theta, fmaps)]
        if any(got != want for got, want in feats):
            raise ValueError(
                f"snapshot θ feature counts {[g for g, _ in feats]} do "
                f"not match the maps' num_features "
                f"{[w for _, w in feats]}")
        dtypes = [str(jnp.asarray(t).dtype) for t in theta]
        if len(set(dtypes)) > 1:
            raise ValueError(
                f"snapshot θ dtypes must agree (the wave is cast to one "
                f"dtype), got per-node {dtypes}")
        dims_in = {int(fm.omega.shape[1]) for fm in fmaps}
        if len(dims_in) > 1:
            raise ValueError(
                f"snapshot feature maps disagree on the query input dim: "
                f"{sorted(dims_in)}")

    @property
    def dtype(self):
        """The shared θ dtype waves are cast to."""
        return jnp.asarray(self.theta[0]).dtype

    @property
    def output_width(self) -> int | None:
        """Dy for multi-output snapshots, None for scalar targets."""
        t0 = self.theta[0]
        return None if t0.ndim == 1 else int(t0.shape[1])

    @property
    def input_dim(self) -> int:
        """Query input dim d shared by every node's Ω."""
        return int(self.feature_maps[0].omega.shape[1])


class SnapshotRegistry:
    """Versioned atomic-publish registry decoupling solvers from serving
    replicas.

    The solver side calls `publish(snapshot)` (or `publish_from(stream)`)
    after each solve; N serving replicas call `latest()` per wave and
    never block the solver — the published state is a single immutable
    `(version, ServeSnapshot)` tuple swapped by one reference assignment,
    so a reader sees either the whole previous snapshot or the whole new
    one, never a torn mix (the lock below only serializes *writers*'
    version bookkeeping). Registry versions increase by 1 per publish and
    are independent of `StalenessBound.theta_version` (re-publishing an
    unchanged θ bumps the registry version only).
    """

    def __init__(self):
        import threading

        self._write_lock = threading.Lock()
        self._published: tuple[int, ServeSnapshot] | None = None

    def publish(self, snapshot: ServeSnapshot) -> int:
        """Atomically publish `snapshot`; returns its registry version."""
        if not isinstance(snapshot, ServeSnapshot):
            raise TypeError(
                f"publish() takes a ServeSnapshot, got "
                f"{type(snapshot).__name__}")
        with span("stream.publish"):
            with self._write_lock:
                version = (0 if self._published is None
                           else self._published[0]) + 1
                self._published = (version, snapshot)
        return version

    def publish_from(self, stream: "StreamingDeKRR") -> int:
        """Snapshot a live `StreamingDeKRR` and publish it."""
        return self.publish(stream.snapshot())

    @property
    def version(self) -> int:
        """Latest published version (0 = nothing published yet)."""
        pub = self._published
        return 0 if pub is None else pub[0]

    def latest(self) -> ServeSnapshot:
        published = self._published
        if published is None:
            raise LookupError(
                "SnapshotRegistry is empty — publish() a ServeSnapshot "
                "before serving from it")
        return published[1]

    def latest_versioned(self) -> tuple[int, ServeSnapshot]:
        """(version, snapshot) read atomically as one tuple."""
        published = self._published
        if published is None:
            raise LookupError(
                "SnapshotRegistry is empty — publish() a ServeSnapshot "
                "before serving from it")
        return published


class StreamingDeKRR:
    """Online DeKRR runtime over a fixed topology with streaming node data.

    Construct from a `DeKRRSolver` snapshot (topology + per-node DDRF
    feature maps + initial data); the solver is only read, never mutated.
    """

    def __init__(self, solver: DeKRRSolver,
                 config: StreamConfig = StreamConfig()):
        self.config = config
        self.topology = solver.topology
        self.feature_maps = list(solver.feature_maps)
        self.aux: StreamAux = init_stream_aux(solver)
        # Accumulated raw data as per-node CHUNK lists (appended per
        # ingest, concatenated lazily by _node_data) — copying the whole
        # history on every minibatch would make ingest O(N) instead of
        # the O(D² b) the Woodbury fold delivers.
        self._x = [[np.array(np.asarray(nd.x))] for nd in solver.data]
        # Multi-output streams keep labels as [N, Dy] rows; scalar streams
        # keep the flat [N] convention (the Dy=1 pin).
        self._dy = self.aux.zy.shape[2] if self.aux.zy.ndim == 3 else None
        self._y = [[self._as_labels(np.asarray(nd.y))]
                   for nd in solver.data]
        self._c_nei = list(solver.c_nei)
        self._c_self_ratio = float(solver.config.c_self_ratio)
        self.theta = jnp.zeros_like(self.aux.zy)
        self._packed = None
        self._detector = (DriftDetector(self.feature_maps, solver.data,
                                        config.drift)
                          if config.drift is not None else None)
        self.theta_version = 0
        self.ingest_count = 0
        self.refresh_count = 0
        self._ingests_since_solve = 0
        self._samples_since_solve = 0
        self._residual = float("inf")
        self._staleness_cache: tuple | None = None

    # -- views --------------------------------------------------------------
    def _as_labels(self, y) -> np.ndarray:
        """Canonicalize one node's labels: [N] scalar streams,
        [N, Dy] multi-output streams."""
        y = np.array(np.asarray(y))
        return y.reshape(-1) if self._dy is None \
            else y.reshape(-1, self._dy)

    @property
    def num_nodes(self) -> int:
        return self.aux.num_nodes

    @property
    def packed(self):
        """The live `PackedProblem` (cached; invalidated by ingest/refresh)."""
        if self._packed is None:
            self._packed = to_packed(self.aux)
        return self._packed

    def _node_data(self, j: int) -> tuple[np.ndarray, np.ndarray]:
        """Node j's accumulated (x [d, N_j], y [N_j]); collapses the
        pending chunk list in place (amortized — reads are rare)."""
        if len(self._x[j]) > 1:
            self._x[j] = [np.concatenate(self._x[j], axis=1)]
            self._y[j] = [np.concatenate(self._y[j])]
        return self._x[j][0], self._y[j][0]

    def accumulated_data(self) -> list[NodeData]:
        pairs = [self._node_data(j) for j in range(self.num_nodes)]
        return [NodeData(x=jnp.asarray(x), y=jnp.asarray(y))
                for x, y in pairs]

    def reference_solver(self) -> DeKRRSolver:
        """From-scratch `DeKRRSolver` on the accumulated data that
        reproduces the stream state exactly (pinned-ridge normalization:
        λ_eff = λ·n_ref/n_live — see `repro.stream.updates`)."""
        return DeKRRSolver(
            self.topology, self.feature_maps, self.accumulated_data(),
            DeKRRConfig(lam=reference_lam(self.aux), c_nei=1.0,
                        c_self_ratio=self._c_self_ratio),
            c_nei_per_node=self._c_nei, build_aux=False)

    # -- event loop ---------------------------------------------------------
    def ingest(self, node: int, xb, yb) -> IngestReport:
        """Fold a minibatch into the Eq. 17 auxiliaries; run the drift
        policy; auto-refresh the node's features when it fires."""
        j = int(node)
        xb = np.asarray(xb)
        yb = self._as_labels(yb)
        with span("stream.ingest", node=j, batch=int(xb.shape[1])):
            self.aux = _fold(self.aux, j, xb, yb)
        if xb.shape[1]:
            self._x[j].append(xb.astype(self._x[j][0].dtype))
            self._y[j].append(yb.astype(self._y[j][0].dtype))
        self._packed = None
        self.ingest_count += 1
        self._ingests_since_solve += 1
        self._samples_since_solve += xb.shape[1]

        verdict = None
        refreshed = False
        if self._detector is not None:
            verdict = self._detector.observe(j, xb, yb)
            if verdict.refresh:
                self.refresh(j)
                refreshed = True
        return IngestReport(node=j, batch_size=int(xb.shape[1]),
                            drift=verdict, refreshed=refreshed)

    def refresh(self, node: int, num_features: int | None = None,
                key: jax.Array | None = None) -> RefreshReport:
        """Re-run DDRF selection for one node on its accumulated data and
        rebuild only that node's slot in the packed program. θ is carried
        across the (possibly re-padded) layout with the refreshed node
        reset to zero — its old iterate lives in the old feature basis."""
        j = int(node)
        cfg = self.config
        old_dims = self.aux.node_dims
        old_dj = old_dims[j]
        if key is None:
            key = jax.random.fold_in(
                jax.random.PRNGKey(cfg.seed), 1000 + self.refresh_count)
        # `num_features` counts packed FEATURES (D_j), but select_features
        # counts frequencies — a cos_sin map carries 2 features per
        # frequency, so a default refresh must pass F_j, not D_j = 2·F_j
        # (otherwise every drift-triggered refresh would double the node).
        want_features = num_features if num_features is not None else old_dj
        if self.aux.kind == "cos_sin":
            if want_features % 2:
                raise ValueError(
                    f"cos_sin maps carry 2 features per frequency — "
                    f"num_features must be even, got {want_features}")
            want_freqs = want_features // 2
        else:
            want_freqs = want_features
        if cfg.sigma is not None:
            sigma = cfg.sigma
        else:
            # recover the node's kernel bandwidth from its live map:
            # ω ~ N(0, σ⁻² I) ⇒ σ̂ = 1/std(ω) (MLE over all entries)
            spread = float(np.std(np.asarray(self.feature_maps[j].omega)))
            sigma = 1.0 / spread if spread > 0 else 1.0
        x_j, y_j = self._node_data(j)
        with span("stream.refresh", node=j):
            return self._refresh_impl(j, key, want_freqs, sigma, x_j, y_j,
                                      old_dims, old_dj)

    def _refresh_impl(self, j, key, want_freqs, sigma, x_j, y_j,
                      old_dims, old_dj) -> RefreshReport:
        cfg = self.config
        new_fmap = select_features(
            key, x_j.shape[0], want_freqs,
            sigma, jnp.asarray(x_j), jnp.asarray(y_j),
            method=cfg.refresh_method,
            candidate_ratio=cfg.refresh_candidate_ratio,
            kind=self.aux.kind)
        self.feature_maps[j] = new_fmap
        # only the node and its live neighbors are read by the rebuild —
        # collapse exactly those chunk lists
        needed = {j} | {int(p) for p, live in
                        zip(np.asarray(self.aux.nbr_idx[j]),
                            np.asarray(self.aux.nbr_mask[j])) if live}
        data_x: list = [None] * self.num_nodes
        for i in needed:
            data_x[i] = self._node_data(i)[0]
        self.aux = refresh_node(self.aux, j, new_fmap, self.feature_maps,
                                data_x, y_j)
        self.theta = repad_theta(self.theta, old_dims, self.aux.node_dims,
                                 reset=(j,))
        self._packed = None
        self.refresh_count += 1
        if self._detector is not None:
            self._detector.rebase(j, new_fmap, *self._node_data(j))
        return RefreshReport(node=j, old_features=old_dj,
                             new_features=new_fmap.num_features,
                             repadded=max(self.aux.node_dims)
                             != max(old_dims))

    def solve(self, rounds: int | None = None,
              tol: float | None = None) -> SolveReport:
        """Warm-started consensus continuation: up to `rounds` Eq. 19
        rounds from the carried θ, on the configured backend and gossip
        mode, stopping early at `tol`. Carries θ forward."""
        cfg = self.config
        budget = int(rounds if rounds is not None else cfg.rounds_per_epoch)
        tol = float(cfg.tol if tol is None else tol)
        packed = self.packed
        if cfg.gossip == "sync":
            theta, rounds_run = solve_batched(
                packed, budget, self.theta, backend=cfg.backend, tol=tol,
                chunk_rounds=cfg.chunk_rounds, return_rounds=True)
        else:
            key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed),
                                     self.theta_version)
            theta, rounds_run = async_solve_batched(
                packed, budget, key, config=cfg.async_config,
                theta0=self.theta, backend=cfg.backend, tol=tol,
                chunk_rounds=cfg.chunk_rounds, return_rounds=True)
        self.theta = theta
        self.theta_version += 1
        self._ingests_since_solve = 0
        self._samples_since_solve = 0
        self._residual = self._contraction_residual()
        # seed the staleness cache — the bound for this exact state is
        # already known, so the next snapshot() must not recompute it
        self._staleness_cache = (
            (self.theta_version, self.ingest_count, self.refresh_count),
            StalenessBound(theta_version=self.theta_version,
                           ingests_behind=0, samples_behind=0,
                           residual=self._residual))
        rounds_run = int(rounds_run)
        return SolveReport(rounds_run=rounds_run, budget=budget,
                           converged=rounds_run < budget
                           or self._residual < tol,
                           residual=self._residual,
                           theta_version=self.theta_version)

    def step_epoch(self, batches) -> tuple[list[IngestReport], SolveReport]:
        """One event-loop epoch: ingest every (node, xb, yb) in `batches`
        (drift-triggered refreshes included), then run the warm-started
        solve continuation."""
        reports = [self.ingest(node, xb, yb) for node, xb, yb in batches]
        return reports, self.solve()

    # -- staleness / serving ------------------------------------------------
    def _contraction_residual(self) -> float:
        new = step_batched(self.packed, self.theta,
                           backend=self.config.backend)
        return float(jnp.max(jnp.abs(new - self.theta)))

    def staleness(self) -> StalenessBound:
        """Live staleness bound of the carried θ against the CURRENT
        operator (ingests folded since the last solve shift the fixed
        point; the residual is recomputed against the live packed
        program). Cached per (solve, ingest, refresh) state, so a serve
        engine re-snapshotting every wave pays the extra Eq. 19 round
        only when something actually changed."""
        state_key = (self.theta_version, self.ingest_count,
                     self.refresh_count)
        if self._staleness_cache is None \
                or self._staleness_cache[0] != state_key:
            bound = StalenessBound(
                theta_version=self.theta_version,
                ingests_behind=self._ingests_since_solve,
                samples_behind=self._samples_since_solve,
                residual=self._contraction_residual(),
            )
            self._staleness_cache = (state_key, bound)
        return self._staleness_cache[1]

    def snapshot(self) -> ServeSnapshot:
        """Immutable view for the serving path."""
        theta = tuple(self.theta[j, :dj]
                      for j, dj in enumerate(self.aux.node_dims))
        return ServeSnapshot(feature_maps=tuple(self.feature_maps),
                             theta=theta, staleness=self.staleness())

    def predict(self, x, node: int | None = None) -> jax.Array:
        """f_j(x) for one node, or the network-average prediction, from
        the carried θ (convenience path; the batched serving engine is
        `repro.serve.dekrr.DeKRRServeEngine`). Scalar streams answer [Q];
        multi-output streams answer [Dy, Q] (one row per output)."""
        x = jnp.asarray(x)
        snap_theta = [self.theta[j, :dj]
                      for j, dj in enumerate(self.aux.node_dims)]

        def f_j(j: int) -> jax.Array:
            z = featurize(self.feature_maps[j], x)     # [D_j, Q]
            th = snap_theta[j]
            return th @ z if th.ndim == 1 else th.T @ z
        if node is not None:
            return f_j(int(node))
        preds = [f_j(j) for j in range(self.num_nodes)]
        return jnp.mean(jnp.stack(preds), axis=0)
