"""Online / streaming DeKRR-DDRF runtime.

The batch runtimes (`repro.core`, `repro.dist`) solve Algorithm 1 on
frozen node data. This package is the online layer: nodes ingest samples
over time, fold them into the paper's quantities incrementally, refresh
their data-dependent features when the local distribution drifts — the
"varies significantly on the number or distribution" regime the paper is
designed for — and continue the consensus solve from the carried iterate,
in the low-communication spirit of COKE (arXiv:2001.10133) and the
distributed online analyses of Richards et al. (arXiv:2007.00360).

Module → paper-equation map (what each module MAINTAINS):

  `updates.py` — Eq. 17, incrementally. The per-node auxiliaries
      G_j = A_j⁻¹, d_j, S_j, P_{j,p} as rank-b Woodbury updates per
      ingested minibatch (O(D² b) for the node and each neighbor instead
      of an O(D³ + D² N) rebuild), in the packed [J, D_max, …] layout of
      `repro.dist.PackedProblem`; `refresh_node` rebuilds exactly one
      node's Eq. 17 slot after a feature-map change; `to_packed`
      materializes the live packed program; `repad_theta` carries Eq. 19
      iterates across a layout change.

  `drift.py` — §III-B's DDRF selection scores, as a drift statistic. The
      energy / kernel-polarization score ([33], the S(ω) of Eq. 11-
      adjacent discussion) and ridge leverage score ([35, 36]) of the
      node's SELECTED frequencies, re-scored on a window of fresh samples
      and compared to the selection-time reference by total variation;
      a threshold policy turns it into a refresh trigger.

  `runtime.py` — Eq. 19, warm-started. `StreamingDeKRR` interleaves
      ingest → (maybe refresh) → consensus continuation: the carried θ
      seeds `repro.dist.solve_batched` / `async_solve_batched` (every
      backend: "xla", "pallas", "pallas_fused"; sync Jacobi or async
      gossip) with tol-based round budgeting, and exports θ snapshots
      with staleness bounds for serving.

  `repro.serve.dekrr` (sibling package) — Eq. 1's predictor
      f_j(x) = θ_jᵀ z_j(x), batched over queries with per-answer
      staleness bounds.

Exactness contract: after ANY ingest/refresh sequence, the stream state
equals a from-scratch `pack_problem` + solve on the accumulated data at
rtol 1e-9 under x64 (the ridge is pinned at stream start — see
`updates.py` for the normalization algebra and `reference_lam` for the
from-scratch comparison's λ).
"""
from repro.stream.drift import DriftConfig, DriftDetector, DriftVerdict
from repro.stream.runtime import (IngestReport, RefreshReport, ServeSnapshot,
                                  SnapshotRegistry, SolveReport,
                                  StalenessBound, StreamConfig,
                                  StreamingDeKRR)
from repro.stream.updates import (StreamAux, ingest, init_stream_aux,
                                  reference_lam, refresh_node, repad_theta,
                                  to_packed)

__all__ = [
    "DriftConfig",
    "DriftDetector",
    "DriftVerdict",
    "IngestReport",
    "RefreshReport",
    "ServeSnapshot",
    "SnapshotRegistry",
    "SolveReport",
    "StalenessBound",
    "StreamAux",
    "StreamConfig",
    "StreamingDeKRR",
    "ingest",
    "init_stream_aux",
    "reference_lam",
    "refresh_node",
    "repad_theta",
    "to_packed",
]
