"""Shared admission / batching / latency machinery for the serving tier.

Both engines in this package — the LLM continuous-batching reference
engine (`repro.serve.engine`) and the DeKRR query tier
(`repro.serve.dekrr`) — serve a stream of variably-sized requests
through fixed-size compute waves. This module is the engine-agnostic
part of that shape:

  * `AdmissionQueue` — a thread-safe FIFO of admitted requests, each
    carrying a *width* (query columns for DeKRR, 1 for LLM slots) and
    its admission timestamp. `take_wave(max_slots, max_columns)` pops
    the next wave under both budgets, so one queue serves slot-bounded
    engines (LLM: width ≡ 1) and column-bounded ones (DeKRR: a [d, m]
    block consumes m columns).
  * `pad_bucket` — the power-of-two padding buckets a wave's column
    count is rounded up to. Variable-width query streams would otherwise
    retrace/recompile the jitted wave program per distinct total width;
    bucketing caps the number of live compiled shapes at
    O(log(max wave width)).
  * `LatencyRecorder` / `LatencyReport` — per-request latency accounting
    with p50/p99 percentiles, not just aggregate qps. The clock is
    injectable so a seeded load trace produces bit-identical reports
    (tests/test_serve_tier.py pins this determinism). Both now LIVE in
    `repro.obs.metrics` (they are generic run accounting, not a serving
    concern) and are re-exported here unchanged for existing callers.

Thread-safety contract: `AdmissionQueue` and `LatencyRecorder` may be
driven from any number of submitter and replica threads; every public
method holds the instance lock for its whole critical section. Waves are
FIFO in admission order (a replica never reorders past another request —
width bucketing pads, it does not reshuffle).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any

from repro.obs.metrics import LatencyRecorder, LatencyReport

__all__ = [
    "Admitted",
    "AdmissionQueue",
    "LatencyRecorder",
    "LatencyReport",
    "pad_bucket",
]


def pad_bucket(n: int, *, min_bucket: int = 8) -> int:
    """Smallest power-of-two ≥ max(n, min_bucket) — the padded column
    count a wave of n live query columns is staged at."""
    n = int(n)
    if n < 0:
        raise ValueError(f"bucket size must be >= 0, got {n}")
    floor = max(int(min_bucket), 1)
    return max(floor, 1 << (max(n, 1) - 1).bit_length())


@dataclasses.dataclass
class Admitted:
    """One queue entry: the engine-specific request plus the admission
    metadata the wave scheduler and latency accounting need."""

    item: Any
    uid: int
    width: int
    t_arrival: float


class AdmissionQueue:
    """Thread-safe FIFO admission queue with slot- and column-budgeted
    wave formation."""

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: list[Admitted] = []

    def admit(self, item: Any, *, uid: int, width: int,
              now: float) -> Admitted:
        """Enqueue one request of `width` columns admitted at time
        `now`; returns its queue entry."""
        if int(width) < 1:
            raise ValueError(
                f"request {uid}: width must be >= 1, got {width}")
        entry = Admitted(item=item, uid=int(uid), width=int(width),
                         t_arrival=float(now))
        with self._lock:
            self._entries.append(entry)
        return entry

    def take_wave(self, max_slots: int,
                  max_columns: int | None = None) -> list[Admitted]:
        """Pop the next wave: up to `max_slots` requests, in FIFO order,
        whose total width stays within `max_columns` (None = unbounded).
        A head-of-line request wider than `max_columns` is returned alone
        (it can never co-batch, but it must not deadlock the queue).
        Returns [] when the queue is empty."""
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        with self._lock:
            wave: list[Admitted] = []
            cols = 0
            while self._entries and len(wave) < max_slots:
                nxt = self._entries[0]
                if (wave and max_columns is not None
                        and cols + nxt.width > max_columns):
                    break
                wave.append(self._entries.pop(0))
                cols += nxt.width
            return wave

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    @property
    def pending_columns(self) -> int:
        with self._lock:
            return sum(e.width for e in self._entries)
