"""Shared admission / batching / latency machinery for the serving tier.

Both engines in this package — the LLM continuous-batching reference
engine (`repro.serve.engine`) and the DeKRR query tier
(`repro.serve.dekrr`) — serve a stream of variably-sized requests
through fixed-size compute waves. This module is the engine-agnostic
part of that shape:

  * `AdmissionQueue` — a thread-safe FIFO of admitted requests, each
    carrying a *width* (query columns for DeKRR, 1 for LLM slots) and
    its admission timestamp. `take_wave(max_slots, max_columns)` pops
    the next wave under both budgets, so one queue serves slot-bounded
    engines (LLM: width ≡ 1) and column-bounded ones (DeKRR: a [d, m]
    block consumes m columns).
  * `pad_bucket` — the power-of-two padding buckets a wave's column
    count is rounded up to. Variable-width query streams would otherwise
    retrace/recompile the jitted wave program per distinct total width;
    bucketing caps the number of live compiled shapes at
    O(log(max wave width)).
  * `LatencyRecorder` / `LatencyReport` — per-request latency accounting
    with p50/p99 percentiles, not just aggregate qps. The clock is
    injectable so a seeded load trace produces bit-identical reports
    (tests/test_serve_tier.py pins this determinism).

Thread-safety contract: `AdmissionQueue` and `LatencyRecorder` may be
driven from any number of submitter and replica threads; every public
method holds the instance lock for its whole critical section. Waves are
FIFO in admission order (a replica never reorders past another request —
width bucketing pads, it does not reshuffle).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Iterable

import numpy as np

__all__ = [
    "Admitted",
    "AdmissionQueue",
    "LatencyRecorder",
    "LatencyReport",
    "pad_bucket",
]


def pad_bucket(n: int, *, min_bucket: int = 8) -> int:
    """Smallest power-of-two ≥ max(n, min_bucket) — the padded column
    count a wave of n live query columns is staged at."""
    n = int(n)
    if n < 0:
        raise ValueError(f"bucket size must be >= 0, got {n}")
    floor = max(int(min_bucket), 1)
    return max(floor, 1 << (max(n, 1) - 1).bit_length())


@dataclasses.dataclass
class Admitted:
    """One queue entry: the engine-specific request plus the admission
    metadata the wave scheduler and latency accounting need."""

    item: Any
    uid: int
    width: int
    t_arrival: float


class AdmissionQueue:
    """Thread-safe FIFO admission queue with slot- and column-budgeted
    wave formation."""

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: list[Admitted] = []

    def admit(self, item: Any, *, uid: int, width: int,
              now: float) -> Admitted:
        """Enqueue one request of `width` columns admitted at time
        `now`; returns its queue entry."""
        if int(width) < 1:
            raise ValueError(
                f"request {uid}: width must be >= 1, got {width}")
        entry = Admitted(item=item, uid=int(uid), width=int(width),
                         t_arrival=float(now))
        with self._lock:
            self._entries.append(entry)
        return entry

    def take_wave(self, max_slots: int,
                  max_columns: int | None = None) -> list[Admitted]:
        """Pop the next wave: up to `max_slots` requests, in FIFO order,
        whose total width stays within `max_columns` (None = unbounded).
        A head-of-line request wider than `max_columns` is returned alone
        (it can never co-batch, but it must not deadlock the queue).
        Returns [] when the queue is empty."""
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        with self._lock:
            wave: list[Admitted] = []
            cols = 0
            while self._entries and len(wave) < max_slots:
                nxt = self._entries[0]
                if (wave and max_columns is not None
                        and cols + nxt.width > max_columns):
                    break
                wave.append(self._entries.pop(0))
                cols += nxt.width
            return wave

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    @property
    def pending_columns(self) -> int:
        with self._lock:
            return sum(e.width for e in self._entries)


@dataclasses.dataclass(frozen=True)
class LatencyReport:
    """Latency/throughput summary of one serving run.

    Latency is completion − admission per request (queueing included —
    the open-loop number a caller actually experiences); `qps` is
    requests / (last completion − first admission). Percentiles use the
    linear-interpolation convention of `np.percentile` and are exact
    deterministic functions of the recorded trace.
    """

    count: int
    p50: float
    p99: float
    mean: float
    max: float
    qps: float

    @staticmethod
    def empty() -> "LatencyReport":
        return LatencyReport(count=0, p50=0.0, p99=0.0, mean=0.0, max=0.0,
                             qps=0.0)


class LatencyRecorder:
    """Thread-safe per-request latency accumulator."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self._lock = threading.Lock()
        self._arrivals: list[float] = []
        self._completions: list[float] = []

    def now(self) -> float:
        return float(self.clock())

    def record(self, t_arrival: float, t_done: float) -> None:
        if t_done < t_arrival:
            raise ValueError(
                f"completion {t_done} precedes admission {t_arrival}")
        with self._lock:
            self._arrivals.append(float(t_arrival))
            self._completions.append(float(t_done))

    def record_wave(self, entries: Iterable[Admitted],
                    t_done: float) -> None:
        for e in entries:
            self.record(e.t_arrival, t_done)

    def reset(self) -> None:
        with self._lock:
            self._arrivals.clear()
            self._completions.clear()

    def report(self) -> LatencyReport:
        with self._lock:
            arrivals = np.asarray(self._arrivals, dtype=np.float64)
            completions = np.asarray(self._completions, dtype=np.float64)
        if arrivals.size == 0:
            return LatencyReport.empty()
        lat = completions - arrivals
        span = float(completions.max() - arrivals.min())
        return LatencyReport(
            count=int(lat.size),
            p50=float(np.percentile(lat, 50)),
            p99=float(np.percentile(lat, 99)),
            mean=float(lat.mean()),
            max=float(lat.max()),
            qps=float(lat.size / span) if span > 0 else float("inf"),
        )
