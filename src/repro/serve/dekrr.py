"""Batched DeKRR query serving: waves, replicas, precision-bounded answers.

The LLM engine next door (`repro.serve.engine`) serves token requests
through a fixed pool of batch slots over one jitted step. This module is
the same slot-based shape for the kernel-regression workload, grown to
the production serving tier:

  `DeKRRServeEngine`    — one engine: queries are admitted through the
      shared `repro.serve.admission` queue into waves of at most
      `batch_size` slots (and `max_wave_columns` query columns), each
      wave is featurized ONCE per node at a power-of-two padded column
      bucket and answered with a handful of batched GEMVs. Per-request
      latency (p50/p99/qps) lands in `engine.latency`.

  `DeKRRReplicaServer`  — N engine replicas (threads) answering from the
      freshest `ServeSnapshot` published to a
      `repro.stream.SnapshotRegistry`. Readers never block the solver:
      the registry swaps one immutable (version, snapshot) tuple per
      publish, each replica stages the snapshot once per version (device
      θ, precision-bound constants) and serves waves from the shared
      admission queue while solves keep landing.

  mixed precision       — `precision="bf16"` (or `"int8"`) runs the
      query featurize+GEMV at low precision while the solve stays x64,
      and attaches a per-answer error bound through
      `StalenessBound.precision` (see below).

Per wave, for query matrix X ∈ R^{d×Q}:

    Z_j = z_j(X) ∈ R^{D_j × Q}      (node j's DDRF map on the queries)
    f_j(X) = θ_jᵀ Z_j               (the paper's Eq. 1 predictor)
    f(X)   = (1/J) Σ_j f_j(X)       (network-average answer)

θ shape contract: snapshot θ_j is [D_j] for scalar targets (answers are
scalars / [Q] rows) or [D_j, Dy] for multi-output models (answers are
[Dy] vectors / [Dy, Q] blocks). Malformed snapshots (mixed widths, mixed
dtypes) are rejected at `ServeSnapshot` construction; malformed queries
(wrong input dim, bad node index) are rejected at ADMISSION with the
offending `uid` named, before anything is featurized. Every prediction
handed out is an owned copy — callers may mutate answers freely without
corrupting wave siblings.

Precision bound (the `StalenessBound.precision` term, answer units):
every low-precision answer satisfies |f_served − f_hi(θ)| ≤ precision,
where f_hi is the same dot product at the snapshot dtype. The attached
value is max(analytic, measured):

  * analytic — a forward-error bound from the staged per-node constants
    V_j = |θ_j|ᵀ|Ω_j|, wb_j = |θ_j|ᵀ|b_j|, ‖θ_j‖₁. With u = 2⁻⁸ (bf16),
    u₃₂ = 2⁻²⁴, γ_n = n·u/(1 − n·u), the per-column node-j bound is

        s_j·(3u + γ_d)·(V_j|x| + wb_j)        cos argument: rounded
                                              Ω/b/x + bf16 GEMM, through
                                              cos's 1-Lipschitz bound
      + 3u·s_j·‖θ_j‖₁                         cos output + scale rounding
      + γ_{D_j}^{(32)}·s_j·(1+u)·‖θ_j‖₁       f32 GEMV accumulation

    (×2 safety), and int8 adds the symmetric-quantization terms
    ½c‖θ‖₁ + ½t‖z‖₁ + ¼D·t·c for per-column z scale c and per-output θ
    scale t (exact int32 accumulation). Network-mean answers get the
    mean of the per-node bounds.
  * measured — max|f_hi − f_lo| over a calibration stripe of the first
    `calib_columns` live columns of the wave, recomputed at the snapshot
    dtype. The analytic term guarantees soundness for every answer; the
    stripe keeps the attached number honest against the bound going
    slack.

Featurization routes through the fused Pallas kernels
(`repro.kernels.ops.rff_features` / `rff_features_lowp`, cos_bias maps)
when ``backend="pallas"`` — compiled on TPU, interpret-mode on CPU, with
the wave's working set pre-checked against the VMEM budget
(`repro.analysis.vmem.estimate_serve_wave`) — and through
`repro.core.rff.featurize` when ``backend="xla"``; the full-precision
paths agree at rtol 1e-9 under x64 (pinned by tests/test_stream.py).
cos_sin maps always take the XLA path (the kernel is cos_bias-only).

Because the θ a live system serves is generally BEHIND the stream (data
keeps arriving between consensus solves), every answer carries the
`StalenessBound` of the snapshot it was computed from — and on the
mixed-precision paths, the precision term above — so staleness AND
quantization error travel through one contract. Serving from a
`StreamingDeKRR` or `SnapshotRegistry` re-snapshots once per wave, so
long query streams pick up fresher θ as solves land; serving from a
frozen `ServeSnapshot` pins one version.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rff import FeatureMap, featurize
from repro.obs.metrics import perf_clock
from repro.obs.spans import span
from repro.serve.admission import (Admitted, AdmissionQueue, LatencyRecorder,
                                   LatencyReport, pad_bucket)
from repro.stream.runtime import (ServeSnapshot, SnapshotRegistry,
                                  StalenessBound)

__all__ = ["KernelQuery", "DeKRRServeEngine", "DeKRRReplicaServer",
           "stage_snapshot", "answer_wave"]

_BACKENDS = ("xla", "pallas")
_PRECISIONS = (None, "bf16", "int8")

# Unit roundoffs of the low-precision serve path: bf16 mantissa (8 bits
# incl. hidden) and f32 (24 bits). SAFETY doubles the analytic bound to
# absorb the model's slack (e.g. fused-multiply rounding differences
# between backends) — the bound stays answer-scale tight because every
# term is weighted by the actual |θ|/|Ω| magnitudes.
_U_BF16 = 2.0 ** -8
_U_F32 = 2.0 ** -24
_SAFETY = 2.0


@dataclasses.dataclass
class KernelQuery:
    """One prediction request.

    x: the query point [d] (or [d, m] for a small point block — answered
    as one slot of m columns). node: answer with that node's local
    predictor instead of the network average. Filled by the engine:
    prediction (an owned copy — never a view into wave-shared storage),
    staleness, done.
    """

    uid: int
    x: np.ndarray
    node: int | None = None
    prediction: np.ndarray | float | None = None
    staleness: StalenessBound | None = None
    done: bool = False


def _validate_query(q: KernelQuery, snap: ServeSnapshot) -> int:
    """Admission-time validation: shape/node errors name the offending
    query's uid HERE instead of surfacing as an anonymous GEMM shape
    error deep inside the wave. Returns the query's column width."""
    x = np.asarray(q.x)
    if x.ndim not in (1, 2):
        raise ValueError(
            f"query {q.uid}: x must be [d] or [d, m], got shape {x.shape}")
    d = int(x.shape[0])
    width = 1 if x.ndim == 1 else int(x.shape[1])
    if d != snap.input_dim:
        raise ValueError(
            f"query {q.uid}: x has input dim {d} but the snapshot's "
            f"feature maps expect d = {snap.input_dim} (Ω_j is "
            f"[D_j, {snap.input_dim}])")
    if width < 1:
        raise ValueError(
            f"query {q.uid}: x point block has no columns (shape {x.shape})")
    j_nodes = len(snap.feature_maps)
    if q.node is not None and not 0 <= int(q.node) < j_nodes:
        raise ValueError(
            f"query {q.uid}: node {q.node} out of range for the "
            f"{j_nodes}-node snapshot")
    return width


# -- snapshot staging --------------------------------------------------------
def _theta2d(theta: jax.Array) -> jax.Array:
    """θ as [D, Dyy] (Dyy = 1 for scalar targets) for uniform wave math."""
    return theta[:, None] if theta.ndim == 1 else theta


def _gamma(n: int, u: float) -> float:
    """Standard accumulated-rounding factor γ_n = n·u/(1 − n·u), clamped
    so absurdly long dots degrade gracefully instead of dividing by ≤ 0."""
    nu = min(n * u, 0.5)
    return nu / (1.0 - nu)


@dataclasses.dataclass(frozen=True)
class _NodeBound:
    """Per-node constants of the analytic precision bound (f32 on device;
    precomputed once per staged snapshot so the per-wave cost is one
    [Dyy, d] × [d, Q] GEMM on |x|)."""

    s: float            # feature-map scale s_j
    coef: float         # s_j·(3u + γ_d) — multiplies V|x| + wb
    v: jax.Array        # [Dyy, d]  |θ_j|ᵀ|Ω_j| (cos_sin: halves folded)
    wb: jax.Array       # [Dyy]     |θ_j|ᵀ|b_j| (0 for cos_sin)
    const: jax.Array    # [Dyy]     column-independent ‖θ‖₁ terms
    l1: jax.Array       # [Dyy]     ‖θ_j‖₁ (int8 terms)
    d_feat: int         # D_j


@dataclasses.dataclass(frozen=True)
class _QuantTheta:
    """Symmetric per-output int8 quantization of one node's θ."""

    qint: jax.Array     # [D, Dyy] int8
    tscale: jax.Array   # [Dyy]    f32 dequant scale t (θ ≈ t·qint)


@dataclasses.dataclass(frozen=True)
class _StagedSnapshot:
    """One snapshot staged for serving: device θ in the shapes the wave
    math wants, plus (on the low-precision paths) the bound constants and
    a full-precision twin for the calibration stripe. Immutable — safe to
    share across replica threads."""

    snap: ServeSnapshot
    backend: str
    precision: str | None
    dtype: np.dtype
    dy: int | None              # snapshot output width (None = scalar)
    dyy: int                    # max(dy, 1) — the staged trailing width
    theta2: tuple[jax.Array, ...]          # hi θ as [D_j, Dyy]
    theta32: tuple[jax.Array, ...] | None  # f32 θ (lo GEMV operand)
    theta_q: tuple[_QuantTheta, ...] | None
    bounds: tuple[_NodeBound, ...] | None
    hi: "_StagedSnapshot | None"           # full-precision twin (stripe)

    @property
    def input_dim(self) -> int:
        return self.snap.input_dim


def stage_snapshot(snap: ServeSnapshot, *, backend: str = "xla",
                   precision: str | None = None) -> _StagedSnapshot:
    """Stage `snap` for serving with the given backend/precision pair.

    Full precision stages only the [D_j, Dyy] θ views. Low precision
    additionally precomputes, per node, the f32 GEMV θ, the analytic
    bound constants (`_NodeBound`), the int8 quantized θ when asked for,
    and a full-precision twin used for the wave calibration stripe.
    """
    if backend not in _BACKENDS:
        raise ValueError(f"backend must be one of {_BACKENDS}, "
                         f"got {backend!r}")
    if precision not in _PRECISIONS:
        raise ValueError(f"precision must be one of {_PRECISIONS}, "
                         f"got {precision!r}")
    dy = snap.output_width
    dyy = 1 if dy is None else dy
    theta2 = tuple(_theta2d(jnp.asarray(t)) for t in snap.theta)
    if precision is None:
        return _StagedSnapshot(
            snap=snap, backend=backend, precision=None,
            dtype=np.dtype(snap.dtype), dy=dy, dyy=dyy, theta2=theta2,
            theta32=None, theta_q=None, bounds=None, hi=None)

    f32 = jnp.float32
    u, u32 = _U_BF16, _U_F32
    theta32, theta_q, bounds = [], [], []
    for fm, t2 in zip(snap.feature_maps, theta2):
        t32 = t2.astype(f32)
        at = jnp.abs(t32)                                # [D_j, Dyy]
        d_feat = int(t2.shape[0])
        d_in = int(fm.omega.shape[1])
        if fm.kind == "cos_bias":
            s = float(np.sqrt(2.0 / fm.num_frequencies))
            folded = at
            wb = at.T @ jnp.abs(jnp.asarray(fm.bias)).astype(f32)
        else:                                            # cos_sin: 2F rows
            s = float(1.0 / np.sqrt(fm.num_frequencies))
            half = fm.num_frequencies
            folded = at[:half] + at[half:]
            wb = jnp.zeros((at.shape[1],), f32)
        v = folded.T @ jnp.abs(jnp.asarray(fm.omega)).astype(f32)
        l1 = at.sum(axis=0)
        coef = s * (3.0 * u + _gamma(d_in, u))
        const = (3.0 * u) * s * l1 \
            + _gamma(d_feat, u32) * s * (1.0 + u) * l1
        theta32.append(t32)
        bounds.append(_NodeBound(s=s, coef=coef, v=v, wb=wb, const=const,
                                 l1=l1, d_feat=d_feat))
        if precision == "int8":
            tscale = jnp.maximum(jnp.max(at, axis=0), 1e-30) / 127.0
            qint = jnp.clip(jnp.round(t32 / tscale[None, :]),
                            -127, 127).astype(jnp.int8)
            theta_q.append(_QuantTheta(qint=qint, tscale=tscale))
    return _StagedSnapshot(
        snap=snap, backend=backend, precision=precision,
        dtype=np.dtype(snap.dtype), dy=dy, dyy=dyy, theta2=theta2,
        theta32=tuple(theta32),
        theta_q=tuple(theta_q) if precision == "int8" else None,
        bounds=tuple(bounds),
        hi=stage_snapshot(snap, backend=backend, precision=None))


# -- wave math (pure jnp — traceable for the jaxpr lint) ---------------------
def _features_hi(fmap: FeatureMap, x: jax.Array, backend: str) -> jax.Array:
    """Z_j(X) [D_j, Q] at the wave dtype."""
    if backend == "pallas" and fmap.kind == "cos_bias":
        from repro.kernels.ops import rff_features

        scale = float(np.sqrt(2.0 / fmap.num_frequencies))
        return rff_features(fmap.omega.astype(x.dtype),
                            fmap.bias.astype(x.dtype), x, scale=scale)
    return featurize(fmap, x)


def _features_lo(fmap: FeatureMap, x32: jax.Array, backend: str,
                 s: float) -> jax.Array:
    """Z_j(X) [D_j, Q] with the GEMM+cos in bf16, returned as f32 (the
    arrangement the analytic bound models)."""
    if backend == "pallas" and fmap.kind == "cos_bias":
        from repro.kernels.ops import rff_features_lowp

        return rff_features_lowp(fmap.omega, fmap.bias, x32, scale=s)
    lo = FeatureMap(omega=fmap.omega.astype(jnp.bfloat16),
                    bias=(None if fmap.bias is None
                          else fmap.bias.astype(jnp.bfloat16)),
                    kind=fmap.kind)
    return featurize(lo, x32.astype(jnp.bfloat16)).astype(jnp.float32)


def answer_wave(st: _StagedSnapshot,
                x: jax.Array) -> tuple[jax.Array, jax.Array | None]:
    """Answer one wave of query columns x [d, Q] from a staged snapshot.

    Returns (preds [J, Dyy, Q], bounds [J, Dyy, Q] | None): per-node
    Eq. 1 predictions, plus — on the low-precision paths — the analytic
    per-column precision bound (×SAFETY, answer units). Pure jnp on the
    staged constants, so `jax.make_jaxpr(lambda x: answer_wave(st, x))`
    traces it for the J002 dispatch pins.
    """
    if st.precision is None:
        preds = [t2.T @ _features_hi(fm, x, st.backend)
                 for fm, t2 in zip(st.snap.feature_maps, st.theta2)]
        return jnp.stack(preds), None

    x32 = jnp.asarray(x, jnp.float32)
    ax = jnp.abs(x32)
    q8s = st.theta_q or (None,) * len(st.theta2)
    preds, bounds = [], []
    for fm, t32, nb, q8 in zip(st.snap.feature_maps, st.theta32,
                               st.bounds, q8s):
        z = _features_lo(fm, x32, st.backend, nb.s)          # [D_j, Q] f32
        col = nb.coef * (nb.v @ ax + nb.wb[:, None]) + nb.const[:, None]
        if st.precision == "int8":
            c = jnp.maximum(jnp.max(jnp.abs(z), axis=0), 1e-30) / 127.0
            zi = jnp.clip(jnp.round(z / c[None, :]),
                          -127, 127).astype(jnp.int8)
            acc = q8.qint.T.astype(jnp.int32) @ zi.astype(jnp.int32)
            f = acc.astype(jnp.float32) * q8.tscale[:, None] * c[None, :]
            zl1 = jnp.sum(jnp.abs(z), axis=0)                # [Q]
            col = col + 0.5 * c[None, :] * nb.l1[:, None] \
                + 0.5 * q8.tscale[:, None] * zl1[None, :] \
                + 0.25 * nb.d_feat * q8.tscale[:, None] * c[None, :]
        else:
            f = t32.T @ z
        preds.append(f)
        bounds.append(col)
    return jnp.stack(preds), jnp.stack(bounds) * _SAFETY


def _check_wave_vmem(st: _StagedSnapshot, q_pad: int) -> None:
    """Pre-dispatch VMEM check for a pallas serve wave at the padded
    shapes the featurize kernels will run (`estimate_serve_wave`)."""
    from repro.analysis.vmem import estimate_serve_wave

    d_feat = max(int(t.shape[0]) for t in st.theta2)
    d_pad = max(128, -(-d_feat // 128) * 128)
    bd = min(256, max(8, 1 << (d_feat - 1).bit_length()))
    bn = min(512, max(128, 1 << (q_pad - 1).bit_length()))
    itemsize = 2 if st.precision is not None else st.dtype.itemsize
    estimate_serve_wave(
        block_d=bd, d_in=max(128, -(-st.input_dim // 128) * 128),
        block_n=bn, d_feat=d_pad, dy=st.dyy, itemsize=itemsize).check()


def _serve_wave(st: _StagedSnapshot, entries: list[Admitted], *,
                calib_columns: int = 8) -> None:
    """Answer one admitted wave in place: featurize once per node at the
    padded column bucket, slice per query, COPY per answer, attach the
    staleness(+precision) bound."""
    spans: list[tuple[int, int]] = []
    offset = 0
    for e in entries:
        spans.append((offset, e.width))
        offset += e.width
    q_live = offset
    q_pad = pad_bucket(q_live)

    fill_dtype = st.dtype if st.precision is None else np.float64
    x_np = np.zeros((st.input_dim, q_pad), dtype=fill_dtype)
    for e, (start, width) in zip(entries, spans):
        xq = np.asarray(e.item.x, dtype=fill_dtype)
        x_np[:, start:start + width] = xq[:, None] if xq.ndim == 1 else xq

    if st.backend == "pallas":
        _check_wave_vmem(st, q_pad)
    preds, bounds = answer_wave(st, jnp.asarray(x_np))
    preds_np = np.asarray(preds)                  # [J, Dyy, q_pad]
    bounds_np = None if bounds is None else np.asarray(bounds)

    measured = 0.0
    if st.precision is not None and calib_columns > 0:
        # stripe width comes from the PADDED column count so its shape is
        # one compiled program per bucket, not one per live wave width
        # (zero-padded stripe columns are legitimate x = 0 measurement
        # points — they can only raise the attached bound, never lower it)
        stripe = min(int(calib_columns), q_pad)
        x_hi = jnp.asarray(x_np[:, :stripe].astype(st.dtype))
        hi_preds, _ = answer_wave(st.hi, x_hi)
        diff = np.asarray(hi_preds, dtype=np.float64) \
            - preds_np[:, :, :stripe].astype(np.float64)
        measured = float(np.max(np.abs(diff)))

    mean_np = preds_np.mean(axis=0)               # [Dyy, q_pad]
    mean_bounds = None if bounds_np is None else bounds_np.mean(axis=0)
    snap = st.snap
    for e, (start, width) in zip(entries, spans):
        q = e.item
        sl = slice(start, start + width)
        block = mean_np[:, sl] if q.node is None else preds_np[q.node][:, sl]
        if st.dy is None:
            vals = block[0]
            if width == 1 and np.asarray(q.x).ndim == 1:
                q.prediction = float(vals[0])
            else:
                q.prediction = np.array(vals, copy=True)
        else:
            if width == 1 and np.asarray(q.x).ndim == 1:
                q.prediction = np.array(block[:, 0], copy=True)
            else:
                q.prediction = np.array(block, copy=True)
        if bounds_np is None:
            q.staleness = snap.staleness
        else:
            bq = mean_bounds[:, sl] if q.node is None \
                else bounds_np[q.node][:, sl]
            attached = max(float(np.max(bq)), measured)
            q.staleness = dataclasses.replace(snap.staleness,
                                              precision=attached)
        q.done = True


class _StageCache:
    """Tiny thread-safe cache of staged snapshots keyed by identity (the
    registry version, or the snapshot object id for direct sources) —
    replicas restage only when a new version is published."""

    def __init__(self, capacity: int = 4):
        self._lock = threading.Lock()
        self._entries: dict[object, _StagedSnapshot] = {}
        self._capacity = capacity

    def get(self, key, snap: ServeSnapshot, *, backend: str,
            precision: str | None) -> _StagedSnapshot:
        with self._lock:
            hit = self._entries.get(key)
            if hit is not None and hit.snap is snap:
                return hit
        staged = stage_snapshot(snap, backend=backend, precision=precision)
        with self._lock:
            self._entries[key] = staged
            while len(self._entries) > self._capacity:
                self._entries.pop(next(iter(self._entries)))
        return staged


class DeKRRServeEngine:
    """Wave/slot-batched query answering over a θ snapshot source.

    ``source`` is a live `repro.stream.StreamingDeKRR` (its `snapshot()`
    is taken once per wave), a `repro.stream.SnapshotRegistry` (its
    freshest published snapshot per wave), or a frozen
    `repro.stream.ServeSnapshot`. ``precision`` selects the answer path:
    None (snapshot dtype), "bf16", or "int8" — low-precision answers
    carry their error bound in `staleness.precision`.
    """

    def __init__(self, source, *, batch_size: int = 64,
                 backend: str | None = None, precision: str | None = None,
                 max_wave_columns: int | None = None,
                 calib_columns: int = 8):
        if backend is None:
            backend = "pallas" if jax.default_backend() == "tpu" else "xla"
        if backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {_BACKENDS}, "
                             f"got {backend!r}")
        if precision not in _PRECISIONS:
            raise ValueError(f"precision must be one of {_PRECISIONS}, "
                             f"got {precision!r}")
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.source = source
        self.batch_size = batch_size
        self.backend = backend
        self.precision = precision
        self.max_wave_columns = max_wave_columns
        self.calib_columns = calib_columns
        self.latency = LatencyRecorder()
        self._stages = _StageCache()

    def _snapshot(self) -> ServeSnapshot:
        if isinstance(self.source, ServeSnapshot):
            return self.source
        if isinstance(self.source, SnapshotRegistry):
            return self.source.latest()
        return self.source.snapshot()

    def _staged(self, snap: ServeSnapshot) -> _StagedSnapshot:
        return self._stages.get(id(snap), snap, backend=self.backend,
                                precision=self.precision)

    # -- serving ------------------------------------------------------------
    def run(self, queries: Iterable[KernelQuery]) -> list[KernelQuery]:
        """Serve all queries in admission order; returns them with
        `.prediction` and `.staleness` filled. Latency percentiles for
        the run are in `self.latency.report()`."""
        queue = AdmissionQueue()
        self.latency.reset()
        snap0 = self._snapshot()
        for q in queries:
            width = _validate_query(q, snap0)
            queue.admit(q, uid=q.uid, width=width, now=self.latency.now())
        finished: list[KernelQuery] = []
        while len(queue):
            wave = queue.take_wave(self.batch_size, self.max_wave_columns)
            with span("serve.wave", slots=len(wave),
                      columns=sum(e.width for e in wave)):
                st = self._staged(self._snapshot())
                _serve_wave(st, wave, calib_columns=self.calib_columns)
            self.latency.record_wave(wave, self.latency.now())
            finished.extend(e.item for e in wave)
        return finished


class DeKRRReplicaServer:
    """N serving replicas answering from the freshest published snapshot.

    Each replica is a thread running the wave loop of `DeKRRServeEngine`
    against a shared `AdmissionQueue`; per wave it reads
    `registry.latest_versioned()` — an atomic tuple read that never
    blocks the solver side — and serves from a per-version staged copy
    of the snapshot. XLA compute releases the GIL, so replicas overlap
    on multicore hosts; with bucketed column padding all replicas reuse
    one set of compiled wave shapes.

    Use `run(queries)` for closed-loop serving (submit-then-drain), or
    `start()` / `submit()` / `stop()` for open-loop load (the Poisson
    generator in benchmarks/serve_bench.py). `clock` is injectable for
    deterministic latency accounting in tests.
    """

    def __init__(self, registry: SnapshotRegistry, *, replicas: int = 2,
                 batch_size: int = 64, backend: str | None = None,
                 precision: str | None = None,
                 max_wave_columns: int | None = None,
                 calib_columns: int = 8,
                 clock: Callable[[], float] = perf_clock):
        if not isinstance(registry, SnapshotRegistry):
            raise TypeError(
                f"DeKRRReplicaServer serves from a SnapshotRegistry, got "
                f"{type(registry).__name__} — wrap frozen snapshots via "
                f"registry.publish(snap)")
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        if backend is None:
            backend = "pallas" if jax.default_backend() == "tpu" else "xla"
        if backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {_BACKENDS}, "
                             f"got {backend!r}")
        if precision not in _PRECISIONS:
            raise ValueError(f"precision must be one of {_PRECISIONS}, "
                             f"got {precision!r}")
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.registry = registry
        self.replicas = replicas
        self.batch_size = batch_size
        self.backend = backend
        self.precision = precision
        self.max_wave_columns = max_wave_columns
        self.calib_columns = calib_columns
        self.queue = AdmissionQueue()
        self.latency = LatencyRecorder(clock)
        self.waves_served = 0
        self._stages = _StageCache()
        self._count_lock = threading.Lock()
        self._threads: list[threading.Thread] = []
        self._draining = False
        self._errors: list[BaseException] = []

    # -- submission ---------------------------------------------------------
    def submit(self, q: KernelQuery, *, now: float | None = None) -> None:
        """Validate and admit one query (thread-safe). `now` overrides
        the admission timestamp for replayed load traces."""
        width = _validate_query(q, self.registry.latest())
        self.queue.admit(q, uid=q.uid, width=width,
                         now=self.latency.now() if now is None else now)

    # -- replica loop -------------------------------------------------------
    def _replica_loop(self) -> None:
        try:
            while True:
                wave = self.queue.take_wave(self.batch_size,
                                            self.max_wave_columns)
                if not wave:
                    if self._draining:
                        return
                    time.sleep(0.0005)
                    continue
                with span("serve.wave", slots=len(wave),
                          columns=sum(e.width for e in wave)):
                    version, snap = self.registry.latest_versioned()
                    st = self._stages.get(version, snap,
                                          backend=self.backend,
                                          precision=self.precision)
                    _serve_wave(st, wave,
                                calib_columns=self.calib_columns)
                self.latency.record_wave(wave, self.latency.now())
                with self._count_lock:
                    self.waves_served += 1
        except BaseException as exc:  # surfaced by stop()
            self._errors.append(exc)

    def start(self) -> None:
        """Spawn the replica threads (idle-polling until work arrives)."""
        if self._threads:
            raise RuntimeError("replica server already started")
        self._draining = False
        self._errors = []
        self._threads = [
            threading.Thread(target=self._replica_loop,
                             name=f"dekrr-replica-{i}", daemon=True)
            for i in range(self.replicas)]
        for t in self._threads:
            t.start()

    def stop(self) -> None:
        """Drain the queue, join every replica, re-raise replica errors."""
        self._draining = True
        for t in self._threads:
            t.join()
        self._threads = []
        if self._errors:
            raise self._errors[0]

    def run(self, queries: Iterable[KernelQuery],
            arrivals: Iterable[float] | None = None) -> list[KernelQuery]:
        """Closed-loop serve: submit every query, drain across all
        replicas, return the (mutated-in-place) queries. `arrivals`
        optionally pins per-query admission timestamps so a seeded load
        trace produces a deterministic latency report."""
        queries = list(queries)
        self.latency.reset()
        if arrivals is None:
            for q in queries:
                self.submit(q)
        else:
            arrivals = list(arrivals)
            if len(arrivals) != len(queries):
                raise ValueError(
                    f"got {len(arrivals)} arrival times for "
                    f"{len(queries)} queries")
            for q, t_arr in zip(queries, arrivals):
                self.submit(q, now=t_arr)
        self.start()
        self.stop()
        return queries

    def report(self) -> LatencyReport:
        return self.latency.report()
