"""Batched DeKRR query serving with per-answer staleness bounds.

The LLM engine next door (`repro.serve.engine`) serves token requests
through a fixed pool of batch slots over one jitted step. This module is
the same slot-based shape for the kernel-regression workload: queries
are admitted into waves of at most `batch_size` slots, each wave is
featurized ONCE per node and answered with a handful of batched GEMVs,
and the slots are recycled for the next wave — so the per-query cost is
amortized featurization, not J·Q separate feature computations.

Per wave, for query matrix X ∈ R^{d×Q}:

    Z_j = z_j(X) ∈ R^{D_j × Q}      (node j's DDRF map on the queries)
    f_j(X) = θ_jᵀ Z_j               (the paper's Eq. 1 predictor)
    f(X)   = (1/J) Σ_j f_j(X)       (network-average answer)

θ shape contract: snapshot θ_j is [D_j] for scalar targets (answers are
scalars / [Q] rows) or [D_j, Dy] for multi-output models (answers are
[Dy] vectors / [Dy, Q] blocks — θ_jᵀ Z_j with the same amortized
featurization; Dy only widens the final GEMM). The attached
`StalenessBound.residual` is the max over features AND outputs, so one
bound covers every component of a vector answer.

Featurization routes through the fused Pallas kernel
(`repro.kernels.ops.rff_features`, cos_bias maps) when
``backend="pallas"`` — compiled on TPU, interpret-mode on CPU — and
through `repro.core.rff.featurize` (one XLA GEMM + cos per node) when
``backend="xla"``; both paths agree at rtol 1e-9 under x64 (pinned by
tests/test_stream.py). cos_sin maps always take the XLA path (the kernel
is cos_bias-only).

Because the θ a live system serves is generally BEHIND the stream (data
keeps arriving between consensus solves), every answer carries the
`StalenessBound` of the snapshot it was computed from: the θ version,
how many ingests/samples arrived since that θ was solved, and the
contraction residual max|F(θ) − θ| under the *current* packed operator —
θ is within residual/(1 − ρ(M)) of the live fixed point. Serving from a
`StreamingDeKRR` re-snapshots once per wave, so long query streams pick
up fresher θ as solves land; serving from a frozen `ServeSnapshot` pins
one version.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rff import FeatureMap, featurize
from repro.stream.runtime import ServeSnapshot, StalenessBound

__all__ = ["KernelQuery", "DeKRRServeEngine"]

_BACKENDS = ("xla", "pallas")


@dataclasses.dataclass
class KernelQuery:
    """One prediction request.

    x: the query point [d] (or [d, m] for a small point block — answered
    as one slot). node: answer with that node's local predictor instead
    of the network average. Filled by the engine: prediction, staleness,
    done.
    """

    uid: int
    x: np.ndarray
    node: int | None = None
    prediction: np.ndarray | float | None = None
    staleness: StalenessBound | None = None
    done: bool = False


class DeKRRServeEngine:
    """Wave/slot-batched query answering over a θ snapshot source.

    ``source`` is either a live `repro.stream.StreamingDeKRR` (its
    `snapshot()` is taken once per wave) or a frozen
    `repro.stream.ServeSnapshot`.
    """

    def __init__(self, source, *, batch_size: int = 64,
                 backend: str | None = None):
        if backend is None:
            backend = "pallas" if jax.default_backend() == "tpu" else "xla"
        if backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {_BACKENDS}, "
                             f"got {backend!r}")
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        self.source = source
        self.batch_size = batch_size
        self.backend = backend

    # -- featurization ------------------------------------------------------
    def _features(self, fmap: FeatureMap, x: jax.Array) -> jax.Array:
        """Z_j(X) [D_j, Q] through the configured path."""
        if self.backend == "pallas" and fmap.kind == "cos_bias":
            from repro.kernels.ops import rff_features

            scale = float(np.sqrt(2.0 / fmap.num_frequencies))
            return rff_features(fmap.omega, fmap.bias, x, scale=scale)
        return featurize(fmap, x)

    def _answer_wave(self, snap: ServeSnapshot, x: jax.Array) -> np.ndarray:
        """Per-node predictions for one wave of queries: [J, Q] for
        scalar θ, [J, Dy, Q] for multi-output θ [D_j, Dy]."""
        preds = [theta @ self._features(fmap, x) if theta.ndim == 1
                 else theta.T @ self._features(fmap, x)
                 for fmap, theta in zip(snap.feature_maps, snap.theta)]
        return np.asarray(jnp.stack(preds))

    def _snapshot(self) -> ServeSnapshot:
        if isinstance(self.source, ServeSnapshot):
            return self.source
        return self.source.snapshot()

    # -- serving ------------------------------------------------------------
    def run(self, queries: Iterable[KernelQuery]) -> list[KernelQuery]:
        """Serve all queries in admission order; returns them with
        `.prediction` and `.staleness` filled."""
        queue = deque(queries)
        finished: list[KernelQuery] = []
        while queue:
            wave = [queue.popleft()
                    for _ in range(min(self.batch_size, len(queue)))]
            snap = self._snapshot()
            dtype = np.asarray(snap.theta[0]).dtype
            cols: list[np.ndarray] = []
            spans: list[tuple[int, int]] = []
            offset = 0
            for q in wave:
                xq = np.asarray(q.x, dtype=dtype)
                if xq.ndim == 1:
                    xq = xq[:, None]
                if xq.ndim != 2:
                    raise ValueError(
                        f"query {q.uid}: x must be [d] or [d, m], "
                        f"got shape {np.asarray(q.x).shape}")
                spans.append((offset, xq.shape[1]))
                offset += xq.shape[1]
                cols.append(xq)
            x = jnp.asarray(np.concatenate(cols, axis=1))
            preds = self._answer_wave(snap, x)    # [J, Q] or [J, Dy, Q]
            mean = preds.mean(axis=0)
            multi = preds.ndim == 3
            for q, (start, width) in zip(wave, spans):
                sl = slice(start, start + width)
                out = mean[..., sl] if q.node is None \
                    else preds[q.node][..., sl]
                if width == 1 and np.asarray(q.x).ndim == 1:
                    # single point: scalar for scalar θ, [Dy] vector for
                    # multi-output θ
                    q.prediction = out[:, 0] if multi else float(out[0])
                else:
                    q.prediction = out
                q.staleness = snap.staleness
                q.done = True
                finished.append(q)
        return finished
