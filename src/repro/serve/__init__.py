"""Serving tier: replicated, latency-accounted, precision-bounded answers.

Architecture map (who talks to whom):

    StreamingDeKRR ──snapshot()──▶ SnapshotRegistry ──latest()──▶ replicas
      (solver side: ingest/solve    (repro.stream: immutable        │
       keeps landing — writers      versioned ServeSnapshots,       │
       never blocked by readers)    atomic tuple publish)           ▼
                                                          DeKRRReplicaServer
    queries ──validate(uid)──▶ AdmissionQueue ──take_wave──▶ N replica
              at admission      (repro.serve.admission:      threads, each:
                                 FIFO, slot+column budgets,  stage snapshot
                                 pad_bucket shape reuse)     per version →
                                                             featurize once
    LatencyRecorder ◀──record_wave── answered queries ◀───── per node →
    (p50/p99/qps,                    (owned copies, never     batched GEMVs
     injectable clock)                wave-shared views)

Three serving shapes share the machinery:

  * `repro.serve.engine.ServeEngine` — the LLM continuous-batching
    reference engine (token slots, width ≡ 1).
  * `repro.serve.dekrr.DeKRRServeEngine` — one DeKRR engine over a
    snapshot source (frozen `ServeSnapshot`, live `StreamingDeKRR`, or a
    `SnapshotRegistry`), wave-batching variable-width [d, m] queries
    into power-of-two column buckets.
  * `repro.serve.dekrr.DeKRRReplicaServer` — N engine replicas (threads)
    off one registry + one admission queue: the production shape.

StalenessBound contract (extended): every answer carries the snapshot's
staleness terms (theta_version / ingests_behind / samples_behind /
residual) AND a `precision` term — 0.0 on full-precision paths; on the
mixed-precision paths (precision="bf16"/"int8", solve stays x64) it is
max(analytic forward-error bound for this answer, |f_hi − f_lo| measured
per wave on a calibration stripe), in answer units, so
|f_served − f_hi(θ)| ≤ precision holds for EVERY served answer. See
`repro.stream.runtime.StalenessBound` and the bound derivation in
`repro.serve.dekrr`.
"""
from repro.serve.admission import (Admitted, AdmissionQueue, LatencyRecorder,
                                   LatencyReport, pad_bucket)
from repro.serve.dekrr import (DeKRRReplicaServer, DeKRRServeEngine,
                               KernelQuery, answer_wave, stage_snapshot)
from repro.serve.engine import Request, ServeEngine

__all__ = [
    "Admitted",
    "AdmissionQueue",
    "DeKRRReplicaServer",
    "DeKRRServeEngine",
    "KernelQuery",
    "LatencyRecorder",
    "LatencyReport",
    "Request",
    "ServeEngine",
    "answer_wave",
    "pad_bucket",
    "stage_snapshot",
]
