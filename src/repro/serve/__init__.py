from repro.serve.dekrr import DeKRRServeEngine, KernelQuery
from repro.serve.engine import Request, ServeEngine

__all__ = ["DeKRRServeEngine", "KernelQuery", "Request", "ServeEngine"]
