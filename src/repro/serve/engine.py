"""Batched serving engine: slot-based continuous batching over the zoo's
decode step.

A fixed pool of ``batch_size`` slots shares one cache pytree; requests are
admitted into free slots, prefilled by teacher-forcing their prompt through
``decode_step`` (single jitted function — no separate prefill graph to
compile), and decoded greedily until EOS/max-new-tokens, at which point the
slot is recycled for the next queued request. Per-slot positions are carried
in the cache's own time axis; a per-slot validity mask keeps finished slots
inert.

This is the CPU-runnable reference engine; on the production mesh the same
step function is the one the dry-run lowers (cache sharded per
launch/sharding.py, serve-policy params).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import jax
import jax.numpy as jnp

from repro.models.model import Model, ModelConfig
from repro.serve.admission import AdmissionQueue, LatencyRecorder


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 32
    eos_id: int | None = None
    # filled by the engine
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    request: Request | None = None
    pos: int = 0                 # next cache position for this slot
    prompt_cursor: int = 0       # how much of the prompt is consumed


class ServeEngine:
    """Greedy continuous-batching engine over Model.decode_step.

    Note: the underlying decode_step uses one shared scalar position per
    call, so the engine steps slots in lockstep by padding fresh slots with
    their prompts; a production engine would carry per-slot positions (the
    cache layout already supports it — positions enter only through RoPE
    and masks).
    """

    def __init__(self, cfg: ModelConfig, params=None, *, batch_size: int = 4,
                 max_seq: int = 256, seed: int = 0):
        self.cfg = cfg
        self.model = Model(cfg)
        self.params = params if params is not None \
            else self.model.init(jax.random.PRNGKey(seed))
        self.batch_size = batch_size
        self.max_seq = max_seq
        self._step = jax.jit(self.model.decode_step)
        # shared serving-tier machinery (repro.serve.admission): FIFO
        # admission (one slot per request — width 1) + per-request
        # latency percentiles over the last run()
        self.latency = LatencyRecorder()

    def run(self, requests: Iterable[Request]) -> list[Request]:
        """Serve all requests; returns them with .output filled. Latency
        percentiles for the run are in `self.latency.report()`."""
        queue = AdmissionQueue()
        self.latency.reset()
        for r in requests:
            queue.admit(r, uid=r.uid, width=1, now=self.latency.now())
        finished: list[Request] = []
        b = self.batch_size

        while len(queue):
            # admit up to b requests into this generation wave
            admitted = queue.take_wave(b)
            wave = [a.item for a in admitted]
            cache = self.model.init_cache(b, self.max_seq)
            max_prompt = max(len(r.prompt) for r in wave)
            horizon = min(self.max_seq,
                          max_prompt + max(r.max_new_tokens for r in wave))

            # token plan: left-pad prompts with their own first token so all
            # slots march in lockstep; generation starts per slot when its
            # prompt is exhausted.
            toks = jnp.zeros((b, 1), jnp.int32)
            active = [i < len(wave) for i in range(b)]
            cursors = [0] * b
            for t in range(horizon):
                col = []
                for i in range(b):
                    if not active[i]:
                        col.append(0)
                        continue
                    r = wave[i]
                    if cursors[i] < len(r.prompt):
                        col.append(int(r.prompt[cursors[i]]))
                    elif r.output:
                        col.append(int(r.output[-1]))
                    else:
                        col.append(int(r.prompt[-1]))
                toks = jnp.asarray(col, jnp.int32)[:, None]
                logits, cache = self._step(self.params, cache, toks,
                                           jnp.asarray(t, jnp.int32))
                nxt = jnp.argmax(logits, axis=-1)
                for i in range(b):
                    if not active[i]:
                        continue
                    r = wave[i]
                    cursors[i] += 1
                    if cursors[i] >= len(r.prompt):
                        tok = int(nxt[i])
                        r.output.append(tok)
                        if ((r.eos_id is not None and tok == r.eos_id)
                                or len(r.output) >= r.max_new_tokens):
                            r.done = True
                            active[i] = False
                if not any(active):
                    break
            for r in wave:
                r.done = True
                finished.append(r)
            self.latency.record_wave(admitted, self.latency.now())
        return finished
