"""AdamW with decoupled weight decay, cosine schedule, global-norm clipping.

Implemented directly on pytrees (no optax dependency in this container).
``moment_dtype`` lets very large configs (jamba-398B) keep m/v in bf16 so the
optimizer state fits the per-device HBM budget — documented in DESIGN.md §6.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"


def adamw_init(cfg: AdamWConfig, params: Pytree) -> Pytree:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(cfg: AdamWConfig, params: Pytree, grads: Pytree,
                 state: Pytree) -> tuple[Pytree, Pytree, dict]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = _schedule(cfg, step)
    mdt = jnp.dtype(cfg.moment_dtype)

    bc1 = 1.0 - cfg.beta1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = cfg.beta1 * m.astype(jnp.float32) + (1 - cfg.beta1) * g
        v32 = cfg.beta2 * v.astype(jnp.float32) + (1 - cfg.beta2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
