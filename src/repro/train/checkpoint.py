"""Msgpack-based checkpointing with pytree structure preservation.

Arrays are serialized as (dtype, shape, raw bytes); the tree structure is
encoded as nested dicts/lists. Restore optionally re-shards onto a mesh
(sharding-aware restore for the distributed runtime).
"""
from __future__ import annotations

import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

Pytree = Any

_ARR = "__arr__"
_SCALAR_TYPES = (int, float, bool, str, type(None))


def _encode(node):
    if isinstance(node, (jax.Array, np.ndarray)):
        arr = np.asarray(node)
        return {_ARR: True, "dtype": str(arr.dtype),
                "shape": list(arr.shape), "data": arr.tobytes()}
    if isinstance(node, dict):
        return {k: _encode(v) for k, v in node.items()}
    if isinstance(node, (list, tuple)):
        return {"__list__": [_encode(v) for v in node],
                "__tuple__": isinstance(node, tuple)}
    if isinstance(node, _SCALAR_TYPES):
        return node
    raise TypeError(f"cannot checkpoint {type(node)}")


def _decode(node):
    if isinstance(node, dict):
        if node.get(_ARR):
            arr = np.frombuffer(node["data"], dtype=node["dtype"])
            return jnp.asarray(arr.reshape(node["shape"]))
        if "__list__" in node:
            items = [_decode(v) for v in node["__list__"]]
            return tuple(items) if node.get("__tuple__") else items
        return {k: _decode(v) for k, v in node.items()}
    return node


def save_checkpoint(path: str, tree: Pytree, step: int | None = None) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    payload = {"tree": _encode(jax.device_get(tree))}
    if step is not None:
        payload["step"] = int(step)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)
    return path


def load_checkpoint(path: str, shardings: Pytree | None = None
                    ) -> tuple[Pytree, int | None]:
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False, strict_map_key=False)
    tree = _decode(payload["tree"])
    if shardings is not None:
        tree = jax.tree.map(
            lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
    return tree, payload.get("step")
