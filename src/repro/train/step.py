"""Train / serve step builders shared by smoke tests, the launcher and the
dry-run.

The loss never materializes f32 logits for the full vocab: logits stay in
``compute_dtype`` (vocab-shardable over the ``model`` axis) and the
per-token logsumexp/gather run in f32 on the fly.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import Model, ModelConfig
from repro.train.optim import AdamWConfig, adamw_init, adamw_update

Pytree = Any


@partial(jax.tree_util.register_dataclass,
         data_fields=["params", "opt", "step"], meta_fields=[])
@dataclasses.dataclass
class TrainState:
    params: Pytree
    opt: Pytree
    step: jax.Array


def train_state_init(cfg: ModelConfig, opt_cfg: AdamWConfig,
                     key: jax.Array) -> TrainState:
    model = Model(cfg)
    params = model.init(key)
    return TrainState(params=params, opt=adamw_init(opt_cfg, params),
                      step=jnp.zeros((), jnp.int32))


def lm_loss(model: Model, params: Pytree, batch: dict) -> tuple[jax.Array,
                                                                dict]:
    """batch: tokens [B,S] (optional), embeds [B,Se,d] (optional),
    targets [B,St], loss_mask [B,St]. Targets align with the LAST St
    positions of the sequence (text tail for VLM, full seq for LM/audio)."""
    logits, aux = model.forward(params, tokens=batch.get("tokens"),
                                embeds=batch.get("embeds"))
    targets = batch["targets"]
    st = targets.shape[1]
    logits = logits[:, -st:, :]
    logits_f = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits_f, axis=-1)
    gold = jnp.take_along_axis(logits_f, targets[..., None],
                               axis=-1)[..., 0]
    nll = lse - gold
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss
    if "load_balance_loss" in aux:
        total = total + 0.01 * aux["load_balance_loss"] \
            + 0.001 * aux["router_z_loss"]
    metrics = {"ce_loss": loss, **aux}
    return total, metrics


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    grad_specs=None):
    """Returns train_step(state, batch) → (state, metrics).

    ``grad_specs`` (optional pytree of PartitionSpec) pins gradients to the
    parameter layout right after backward: GSPMD then lowers the cross-batch
    gradient reduction as reduce-scatter into the FSDP shards instead of a
    full-tensor all-reduce (§Perf — the CPU pipeline lacks XLA's
    reduce-scatter-creation pass)."""
    model = Model(cfg)

    def train_step(state: TrainState, batch: dict
                   ) -> tuple[TrainState, dict]:
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm_loss(model, p, batch), has_aux=True)(state.params)
        if grad_specs is not None:
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, grad_specs)
        params, opt, opt_metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return TrainState(params=params, opt=opt, step=state.step + 1), \
            metrics

    return train_step


def make_serve_step(cfg: ModelConfig):
    """Returns serve_step(params, cache, tokens [B,1], pos) →
    (logits [B,V], cache) — ONE new token against a seq_len cache."""
    model = Model(cfg)

    def serve_step(params: Pytree, cache: Pytree, tokens: jax.Array,
                   pos: jax.Array):
        return model.decode_step(params, cache, tokens, pos)

    return serve_step


def make_prefill(cfg: ModelConfig):
    """Returns prefill(params, tokens/embeds) → logits (encoder forward or
    prompt processing; inference, no grads)."""
    model = Model(cfg)

    def prefill(params: Pytree, batch: dict) -> jax.Array:
        logits, _ = model.forward(params, tokens=batch.get("tokens"),
                                  embeds=batch.get("embeds"))
        return logits

    return prefill
