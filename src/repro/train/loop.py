"""Training loop driver (CPU-runnable; the launcher adds mesh sharding)."""
from __future__ import annotations

from typing import Callable, Iterable

import jax
import numpy as np

from repro.models.model import ModelConfig
from repro.obs.metrics import perf_clock
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.optim import AdamWConfig
from repro.train.step import TrainState, make_train_step, train_state_init


def train_loop(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    batches: Iterable[dict],
    num_steps: int,
    *,
    seed: int = 0,
    log_every: int = 10,
    ckpt_path: str | None = None,
    ckpt_every: int = 200,
    log_fn: Callable[[str], None] = print,
    state: TrainState | None = None,
) -> tuple[TrainState, list[dict]]:
    if state is None:
        state = train_state_init(cfg, opt_cfg, jax.random.PRNGKey(seed))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))
    history: list[dict] = []
    t0 = perf_clock()
    it = iter(batches)
    for i in range(num_steps):
        batch = {k: jax.numpy.asarray(v) for k, v in next(it).items()}
        state, metrics = step_fn(state, batch)
        if (i + 1) % log_every == 0 or i == 0:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i + 1
            m["wall_s"] = perf_clock() - t0
            history.append(m)
            log_fn(f"step {i+1:5d}  loss {m['loss']:.4f}  "
                   f"gnorm {m['grad_norm']:.2f}  lr {m['lr']:.2e}  "
                   f"({m['wall_s']:.1f}s)")
        if ckpt_path and (i + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_path, {"params": state.params,
                                        "opt": state.opt},
                            step=i + 1)
    return state, history
