from repro.train.optim import AdamWConfig, adamw_init, adamw_update
from repro.train.step import (TrainState, lm_loss, make_serve_step,
                              make_train_step, train_state_init)

__all__ = [
    "AdamWConfig", "TrainState", "adamw_init", "adamw_update", "lm_loss",
    "make_serve_step", "make_train_step", "train_state_init",
]
