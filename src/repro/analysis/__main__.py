"""CLI for the static-analysis passes: ``python -m repro.analysis``.

Runs the jaxpr lint (tracing the live solver entry points), the
conventions AST linter, and reports VMEM budget findings surfaced by
both. Exits nonzero iff any error-severity finding is produced, so CI
can gate on it directly.

Environment setup happens HERE, before jax is imported anywhere: the
SPMD entry points need ≥ 4 host devices
(``--xla_force_host_platform_device_count=4``) and the lint must run on
CPU with x64 enabled to match the test suite's precision contract. That
is why ``repro.analysis.__init__`` never imports jax — importing it
first would freeze the platform config.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.obs.metrics import perf_clock

_PASSES = ("jaxpr", "conventions")


def _setup_jax_env() -> None:
    # Must run before the first jax import (jaxpr_lint imports jax at
    # module top). Appending preserves any flags the caller already set.
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + " --xla_force_host_platform_device_count=4").strip()


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static verification of solver programs.")
    parser.add_argument(
        "paths", nargs="*", default=None,
        help="files/dirs for the conventions pass (default: src/)")
    parser.add_argument(
        "--format", choices=("text", "json"), default="text")
    parser.add_argument(
        "--pass", dest="passes", action="append", choices=_PASSES,
        help="run only the named pass(es); default: all")
    parser.add_argument(
        "--no-spmd", action="store_true",
        help="skip the shard_map entry points (jaxpr pass)")
    parser.add_argument(
        "--repo-root", default=".",
        help="root for relative finding locations and conftest lookup")
    args = parser.parse_args(argv)

    passes = tuple(args.passes) if args.passes else _PASSES
    findings = []
    timings: dict[str, float] = {}

    if "jaxpr" in passes:
        _setup_jax_env()
        import jax

        jax.config.update("jax_enable_x64", True)
        from repro.analysis import jaxpr_lint

        t0 = perf_clock()
        spmd = False if args.no_spmd else None  # None = auto-detect
        findings += jaxpr_lint.run_pass(spmd=spmd)
        timings["jaxpr"] = perf_clock() - t0

    if "conventions" in passes:
        from repro.analysis import conventions

        root = os.path.abspath(args.repo_root)
        paths = args.paths or [os.path.join(root, "src")]
        t0 = perf_clock()
        findings += conventions.run_pass(paths, repo_root=root)
        timings["conventions"] = perf_clock() - t0

    from repro.analysis.report import render_json, render_report

    if args.format == "json":
        extra = {"passes": list(passes),
                 "timings_s": {k: round(v, 3) for k, v in timings.items()}}
        print(render_json(findings, extra=extra))
    else:
        print(render_report(findings))
        for name in passes:
            if name in timings:
                print(f"{name}: {timings[name]:.2f}s")

    return 1 if any(f.severity == "error" for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
