"""Static verification of solver programs — the hazards each pass guards.

The repo's correctness contract is otherwise enforced only dynamically:
a too-large ``D_max`` dies as an opaque Mosaic allocation crash, an
out-of-range slot-table index silently reads an arbitrary θ row through
scalar prefetch, and a stray ``float()`` on a tracer re-introduces the
per-round host syncs the fused solve removed. The three passes here make
those contracts static, checked on every CI push and pinned by
``tests/test_analysis.py``:

``jaxpr_lint`` — traces every solver entry point (``solve_batched``,
  ``async_solve_batched``, the shard_map SPMD solvers, the
  ``ops.dekrr_step``/``ops.dekrr_solve`` wrappers, and
  ``StreamingDeKRR.ingest``) to a closed jaxpr and verifies, per rule:

  J001  no host callbacks inside ``while``/``scan`` bodies — a callback
        in the solve loop serializes every round on the host;
  J002  ``pallas_call`` dispatch counts match the documented
        ``round_dispatches`` contract per backend (the fused kernel's
        whole reason to exist is dispatches=1);
  J003  every ``ppermute`` permutation is a bijection over the mesh
        axis — a dropped or duplicated edge deadlocks or corrupts the
        halo exchange;
  J004  loop carries never silently downcast f64→f32 — the rtol-1e-9
        parity contract dies quietly otherwise;
  J005  operands and control flow feeding collectives under
        ``check_rep=False`` are provably replicated — a device-varying
        ``while`` predicate gating a collective is a deadlock
        (the async mask-schedule hazard).

``vmem`` — executable versions of the four Pallas kernels' VMEM
  working-set formulas (consolidated table in the module docstring).
  The ``kernels/ops.py`` wrappers call these before dispatch so an
  over-budget ``(J, D_max, K)`` raises ``VmemBudgetError`` naming the
  formula and the 16 MiB limit instead of a Mosaic crash (rule V001),
  and the jaxpr lint re-budgets every traced ``pallas_call`` from its
  BlockSpecs (rule V002). Also hosts ``check_index_table`` — the static
  bounds check for scalar-prefetched slot/activation tables (scalar
  prefetch has no hardware bounds check).

``conventions`` — AST linter for the house contracts (rules R001–R005):
  solver entry points expose ``backend=``; no ``.item()``/``float()``/
  ``int()`` on tracers in jitted code; rtol ≤ 1e-6 tests enable x64;
  Pallas ``interpret=`` only through the ops wrappers; no bare
  ``except``.

Run all passes with ``python -m repro.analysis`` (text or ``--format
json``). This package root imports neither jax nor the jaxpr pass — the
CLI must configure ``JAX_PLATFORMS``/host-device-count env vars before
jax is first imported, and the conventions/vmem passes are useful in
environments with no accelerator runtime at all.
"""
from repro.analysis.report import (Finding, render_json,  # noqa: F401
                                   render_report)
from repro.analysis.vmem import (VMEM_BUDGET_BYTES,  # noqa: F401
                                 VmemBudgetError, VmemEstimate,
                                 check_index_table, effective_itemsize,
                                 estimate_blocks,
                                 estimate_dekrr_async_solve,
                                 estimate_dekrr_cheb_solve,
                                 estimate_dekrr_solve, estimate_dekrr_step,
                                 estimate_flash_decode,
                                 estimate_rff_features,
                                 estimate_rff_gram)

__all__ = [
    "Finding", "render_json", "render_report",
    "VMEM_BUDGET_BYTES", "VmemBudgetError", "VmemEstimate",
    "check_index_table", "effective_itemsize", "estimate_blocks",
    "estimate_dekrr_step", "estimate_dekrr_solve",
    "estimate_dekrr_async_solve", "estimate_dekrr_cheb_solve",
    "estimate_rff_gram", "estimate_rff_features",
    "estimate_flash_decode",
]
