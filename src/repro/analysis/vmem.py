"""Static VMEM-footprint estimator for the four Pallas kernels.

TPU cores have ~16 MiB of VMEM; a kernel whose working set exceeds it
dies at Mosaic compile time with an opaque allocation error — *after* the
operands were staged and (for the fused solve) after minutes of problem
packing. This module makes the working-set formulas in the kernel
docstrings executable so `kernels/ops.py` can reject over-budget shapes
with a `VmemBudgetError` naming the formula and the limit *before*
dispatch, and so `tests/test_analysis.py` can pin the formulas to the
docstrings.

Consolidated working-set table (elements; bytes = elements × itemsize).
This is the single source of truth — the per-kernel docstrings in
`repro.kernels.{dekrr_step,dekrr_solve,rff_gram,decode_attention}`
reference it:

  kernel        formula (elements)                      paper anchor
  ------------  --------------------------------------  -------------------
  dekrr_step    T·D + (2+K)·D² + 3·D·dy                 D=512, K=4 → ~6.3 MB
  dekrr_solve   2·T·D + 2·(2+K)·D² + 3·D·dy             T=256, D=512, K=4
                                                        → ~13.7 MB (ceiling)
  dekrr_async_  5·T·D + 2·B·D + 2·(2+K)·D² + 3·D·dy     T=128, B=512, D=512,
  solve                                                 K=4 → ~15.3 MB
                                                        (J=128 ceiling)
  dekrr_cheb_   3·T·D + 2·J'·D + 2·(2+K)·D² + 3·D·dy    T=J'=256, D=512,
  solve                                                 K=4 → ~14.5 MB
  rff_gram      D·d + d·Bn + D·Bn + D² (+ 2·D zy/bias)  D=512, d=160,
                                                        Bn=1024 → < 5 MB
  rff_features  Bd·d + Bd + d·Bn + Bd·Bn                Bd=256, d=160,
                                                        Bn=512 → < 1 MB
  serve_wave    Bd·d + Bd + d·Bn + Bd·Bn                Bd=256, d=160,
                + dy·D + dy·Bn                          Bn=512, D=2048,
                                                        dy=2 → < 1 MB
  flash_decode  G·dh + 2·Bs·dh + G·Bs (+ 3·G m/l state) G=8, dh=128, Bs=512
                                                        → < 1 MB

Terms: T = θ-table rows (padded to 8 sublanes), D = padded feature dim
(lane multiples of 128), K = padded neighbor-slot count (≥ 1), B =
staleness-buffer rows (J·K padded to 8), J' = Δ-table rows (J padded to
8), d = input dim, Bd/Bn/Bs = streaming block sizes, G = GQA query-group
size, dh = head dim, dy = output width Dy (1 for scalar targets).
dekrr_step holds one θ table and single-buffered blocks; dekrr_solve
holds two θ scratch tables (round-parity Jacobi) and double-buffered
block streams, hence the factor-2 terms. The async chain additionally
holds the θ0/sent0/buf0 inputs plus sent/buffer scratch (5·T·D + 2·B·D
total θ-shaped state); the Chebyshev chain holds the θ0 input plus the
Δ0 input and Δ scratch (3·T·D + 2·J'·D). Multi-output callers fold Dy
into the flattened T/B/J' row counts (the kernels' [rows·Dy, D] layout)
and pass ``dy`` to scale the per-step d/acc/out vector blocks; at dy = 1
every formula is byte-identical to the scalar-target one.

Itemsize: estimates use ``effective_itemsize`` = min(itemsize, 4). TPUs
have no f64 — x64-mode callers run the kernels in interpret mode on CPU
(no VMEM) or are downcast to f32 by the ops wrappers before dispatch, so
budgeting 8-byte elements would spuriously reject shapes that deploy
fine.

This module must stay importable without jax: the `repro.analysis` CLI
sets JAX_PLATFORMS / device-count env vars before jax is first imported.
"""
from __future__ import annotations

import dataclasses

# Mosaic's per-core VMEM budget. The guide value is ~16 MiB; compiler
# spill/temporary overhead eats into it, but the kernels' formulas already
# over-count slightly (padding, unfused vectors), so the raw budget is the
# contract the docstrings pin (J = 256 at D = 512 "is the ceiling").
VMEM_BUDGET_BYTES = 16 * 2**20


class VmemBudgetError(ValueError):
    """Raised before kernel dispatch when the static VMEM estimate for a
    Pallas call exceeds the per-core budget. The message names the kernel,
    the symbolic formula, the substituted byte count, and the budget."""


def effective_itemsize(itemsize: int) -> int:
    """Deployable element width: TPU kernels never run above f32 (no f64
    hardware; x64 callers are downcast or interpreted), so cap at 4."""
    return min(int(itemsize), 4)


@dataclasses.dataclass(frozen=True)
class VmemEstimate:
    """Static working-set estimate for one Pallas kernel call."""
    kernel: str
    formula: str        # symbolic, as documented in the table above
    detail: str         # formula with the shapes substituted
    elements: int
    bytes: int
    budget: int = VMEM_BUDGET_BYTES

    @property
    def fits(self) -> bool:
        return self.bytes <= self.budget

    def check(self) -> "VmemEstimate":
        """Return self, or raise `VmemBudgetError` if over budget."""
        if not self.fits:
            raise VmemBudgetError(
                f"{self.kernel}: VMEM working set {self.formula} = "
                f"{self.detail} = {self.bytes} bytes exceeds the "
                f"{self.budget}-byte per-core budget; shrink D_max/K or "
                f"block sizes, or use backend='pallas'/'xla' "
                f"(see repro.analysis.vmem)")
        return self


def estimate_dekrr_step(*, t_rows: int, d_feat: int, k_slots: int,
                        itemsize: int = 4, dy: int = 1,
                        budget: int = VMEM_BUDGET_BYTES) -> VmemEstimate:
    """Single-round kernel: θ table + G/S/P blocks + d/acc/out vectors.
    Multi-output callers pass the flattened T (table rows × Dy) and dy;
    dy scales only the per-step [dy, D] vector blocks."""
    size = effective_itemsize(itemsize)
    elements = (t_rows * d_feat + (2 + k_slots) * d_feat**2
                + 3 * d_feat * dy)
    return VmemEstimate(
        kernel="dekrr_step",
        formula="T*D + (2+K)*D^2 + 3*D*dy",
        detail=(f"{t_rows}*{d_feat} + (2+{k_slots})*{d_feat}^2 + "
                f"3*{d_feat}*{dy} elems @ {size} B"),
        elements=elements, bytes=elements * size, budget=budget)


def estimate_dekrr_solve(*, t_rows: int, d_feat: int, k_slots: int,
                         itemsize: int = 4, dy: int = 1,
                         budget: int = VMEM_BUDGET_BYTES) -> VmemEstimate:
    """Fused multi-round kernel: two parity θ scratch tables +
    double-buffered G/S/P block streams + d/acc/out vectors. T is the
    flattened (× Dy) table row count for multi-output callers."""
    size = effective_itemsize(itemsize)
    elements = (2 * t_rows * d_feat + 2 * (2 + k_slots) * d_feat**2
                + 3 * d_feat * dy)
    return VmemEstimate(
        kernel="dekrr_solve",
        formula="2*T*D + 2*(2+K)*D^2 + 3*D*dy",
        detail=(f"2*{t_rows}*{d_feat} + 2*(2+{k_slots})*{d_feat}^2 + "
                f"3*{d_feat}*{dy} elems @ {size} B"),
        elements=elements, bytes=elements * size, budget=budget)


def estimate_dekrr_async_solve(*, t_rows: int, b_rows: int, d_feat: int,
                               k_slots: int, itemsize: int = 4, dy: int = 1,
                               budget: int = VMEM_BUDGET_BYTES
                               ) -> VmemEstimate:
    """Fused async-gossip chain: two parity θ tables + sent table + the
    θ0/sent0/buf0 inputs + staleness-buffer scratch + double-buffered
    G/S/P streams + d/acc/out vectors (SMEM flag vectors excluded — they
    do not live in VMEM). T/B are flattened (× Dy) row counts for
    multi-output callers."""
    size = effective_itemsize(itemsize)
    elements = (5 * t_rows * d_feat + 2 * b_rows * d_feat
                + 2 * (2 + k_slots) * d_feat**2 + 3 * d_feat * dy)
    return VmemEstimate(
        kernel="dekrr_async_solve",
        formula="5*T*D + 2*B*D + 2*(2+K)*D^2 + 3*D*dy",
        detail=(f"5*{t_rows}*{d_feat} + 2*{b_rows}*{d_feat} + "
                f"2*(2+{k_slots})*{d_feat}^2 + 3*{d_feat}*{dy} elems "
                f"@ {size} B"),
        elements=elements, bytes=elements * size, budget=budget)


def estimate_dekrr_cheb_solve(*, t_rows: int, j_rows: int, d_feat: int,
                              k_slots: int, itemsize: int = 4, dy: int = 1,
                              budget: int = VMEM_BUDGET_BYTES
                              ) -> VmemEstimate:
    """Fused Chebyshev chain: two parity θ tables + the θ0 input + the
    Δ0 input and Δ scratch table + double-buffered G/S/P streams +
    d/acc/out vectors (the [R] α/β schedule prefetches to SMEM). T/J'
    are flattened (× Dy) row counts for multi-output callers."""
    size = effective_itemsize(itemsize)
    elements = (3 * t_rows * d_feat + 2 * j_rows * d_feat
                + 2 * (2 + k_slots) * d_feat**2 + 3 * d_feat * dy)
    return VmemEstimate(
        kernel="dekrr_cheb_solve",
        formula="3*T*D + 2*J'*D + 2*(2+K)*D^2 + 3*D*dy",
        detail=(f"3*{t_rows}*{d_feat} + 2*{j_rows}*{d_feat} + "
                f"2*(2+{k_slots})*{d_feat}^2 + 3*{d_feat}*{dy} elems "
                f"@ {size} B"),
        elements=elements, bytes=elements * size, budget=budget)


def estimate_rff_gram(*, d_feat: int, d_in: int, block_n: int,
                      itemsize: int = 4,
                      budget: int = VMEM_BUDGET_BYTES) -> VmemEstimate:
    """Streaming featurize+Gram: Ω + X tile + feature tile + Gram
    accumulator, plus the bias column and zy accumulator (2·D)."""
    size = effective_itemsize(itemsize)
    elements = (d_feat * d_in + d_in * block_n + d_feat * block_n
                + d_feat**2 + 2 * d_feat)
    return VmemEstimate(
        kernel="rff_gram",
        formula="D*d + d*Bn + D*Bn + D^2 + 2*D",
        detail=(f"{d_feat}*{d_in} + {d_in}*{block_n} + "
                f"{d_feat}*{block_n} + {d_feat}^2 + 2*{d_feat} elems "
                f"@ {size} B"),
        elements=elements, bytes=elements * size, budget=budget)


def estimate_rff_features(*, block_d: int, d_in: int, block_n: int,
                          itemsize: int = 4,
                          budget: int = VMEM_BUDGET_BYTES) -> VmemEstimate:
    """Tiled featurize Z = scale·cos(Ω X + b) (the serving path's
    `ops.rff_features`): per grid step an Ω tile [Bd, d] + bias column
    [Bd, 1] + X tile [d, Bn] + Z output tile [Bd, Bn]."""
    size = effective_itemsize(itemsize)
    elements = (block_d * d_in + block_d + d_in * block_n
                + block_d * block_n)
    return VmemEstimate(
        kernel="rff_features",
        formula="Bd*d + Bd + d*Bn + Bd*Bn",
        detail=(f"{block_d}*{d_in} + {block_d} + {d_in}*{block_n} + "
                f"{block_d}*{block_n} elems @ {size} B"),
        elements=elements, bytes=elements * size, budget=budget)


def estimate_serve_wave(*, block_d: int, d_in: int, block_n: int,
                        d_feat: int, dy: int = 1, itemsize: int = 4,
                        budget: int = VMEM_BUDGET_BYTES) -> VmemEstimate:
    """Serving answer wave (`repro.serve.dekrr`, backend="pallas"): the
    featurize tiles of `estimate_rff_features` plus the θᵀ GEMV operands
    kept resident per wave — a [dy, D] θ row block and a [dy, Bn] answer
    tile. D is the largest padded per-node feature count in the snapshot
    and Bn the padded (bucketed) query-column count, so one check covers
    every node of the wave."""
    size = effective_itemsize(itemsize)
    elements = (block_d * d_in + block_d + d_in * block_n
                + block_d * block_n + dy * d_feat + dy * block_n)
    return VmemEstimate(
        kernel="serve_wave",
        formula="Bd*d + Bd + d*Bn + Bd*Bn + dy*D + dy*Bn",
        detail=(f"{block_d}*{d_in} + {block_d} + {d_in}*{block_n} + "
                f"{block_d}*{block_n} + {dy}*{d_feat} + {dy}*{block_n} "
                f"elems @ {size} B"),
        elements=elements, bytes=elements * size, budget=budget)


def estimate_flash_decode(*, g_heads: int, head_dim: int, block_s: int,
                          itemsize: int = 4,
                          budget: int = VMEM_BUDGET_BYTES) -> VmemEstimate:
    """Flash decode: q tile + K/V blocks + score tile, plus the
    online-softmax state (m, l [G,1] and the acc rides in G·dh)."""
    size = effective_itemsize(itemsize)
    elements = (g_heads * head_dim + 2 * block_s * head_dim
                + g_heads * block_s + 3 * g_heads)
    return VmemEstimate(
        kernel="flash_decode",
        formula="G*dh + 2*Bs*dh + G*Bs + 3*G",
        detail=(f"{g_heads}*{head_dim} + 2*{block_s}*{head_dim} + "
                f"{g_heads}*{block_s} + 3*{g_heads} elems @ {size} B"),
        elements=elements, bytes=elements * size, budget=budget)


def estimate_blocks(kernel: str,
                    blocks: list[tuple[tuple[int, ...], int]],
                    *, budget: int = VMEM_BUDGET_BYTES) -> VmemEstimate:
    """Generic estimate from (block_shape, itemsize) pairs — used by the
    jaxpr lint to budget pallas_call eqns straight from their BlockSpecs
    (grid_mapping block shapes + VMEM scratch avals), independent of the
    closed-form per-kernel formulas above."""
    total_bytes = 0
    total_elems = 0
    parts = []
    for shape, itemsize in blocks:
        elems = 1
        for dim in shape:
            elems *= int(dim)
        size = effective_itemsize(itemsize)
        total_elems += elems
        total_bytes += elems * size
        parts.append(f"{'x'.join(str(d) for d in shape) or '1'}@{size}B")
    return VmemEstimate(
        kernel=kernel, formula="sum(block shapes + scratch)",
        detail=" + ".join(parts) if parts else "0",
        elements=total_elems, bytes=total_bytes, budget=budget)


def check_index_table(name: str, table, size: int, *,
                      lo: int = 0) -> None:
    """Static bounds check for a scalar-prefetched index table.

    Scalar prefetch reads SMEM indices with no hardware bounds check — an
    out-of-range slot silently gathers an arbitrary θ row. `table` is any
    array-like of integers (NumPy or concrete jax); every entry must lie
    in ``[lo, size)``. Raises ValueError naming the offending range.
    Callers must NOT pass tracers — check `hasattr(x, '__array__')` /
    concreteness first (the ops wrappers only check concrete inputs).
    """
    import numpy as np

    arr = np.asarray(table)
    if arr.size == 0:
        return
    if not np.issubdtype(arr.dtype, np.integer):
        raise ValueError(
            f"{name}: index table must be integer-typed, got {arr.dtype}")
    amin, amax = int(arr.min()), int(arr.max())
    if amin < lo or amax >= size:
        raise ValueError(
            f"{name}: scalar-prefetched indices must lie in [{lo}, {size})"
            f" but span [{amin}, {amax}] — an out-of-range slot would "
            f"silently gather an arbitrary table row")
