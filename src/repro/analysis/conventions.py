"""AST-based linter for the repo's house contracts.

Pure-Python (no jax import — safe to run before any device runtime is
configured, and fast enough for CI on every push). Rules:

  R001  Public solver entry points expose ``backend=`` — the xla/pallas/
        pallas_fused switch is the repo's central API contract; an entry
        point without it silently forks the backend matrix.
  R002  No ``.item()`` / ``float()`` / ``int()`` / ``bool()`` on
        tracer-typed values inside jitted code paths — each one is a
        device→host sync that re-introduces the per-round stalls PR 3
        removed. Applies inside functions decorated with ``jax.jit`` /
        ``partial(jax.jit, …)`` and their nested functions. Host-static
        expressions are exempt: bare names (static args, Python ints),
        constants, ``len(…)``, and ``.shape``/``.ndim``/``.size``-style
        property chains.
  R003  Test files asserting at rtol ≤ 1e-6 must enable x64 — the
        rtol-1e-9 parity contracts are meaningless at f32 (eps ≈ 1e-7),
        and a test that forgets x64 passes vacuously at loose precision
        or flakes. Satisfied by the file itself or an ancestor
        ``conftest.py`` enabling ``jax_enable_x64``.
  R004  Pallas ``interpret=`` is only set through the ops wrappers: raw
        ``*_pallas(…, interpret=…)`` / ``pallas_call(…, interpret=…)``
        outside ``src/repro/kernels/`` bypasses the padding/dispatch
        contract the wrappers enforce.
  R005  No bare ``except:`` — swallowing KeyboardInterrupt/SystemExit in
        long solver runs makes hangs unkillable.
  R006  No bare ``time.time()`` / ``time.perf_counter()`` in ``src/repro/``
        outside ``repro/obs/`` — host timing goes through
        `repro.obs.metrics.perf_clock` / `wall_clock` so spans, latency
        recorders and benches share one monotonic clock (and tests can
        swap in a `FakeClock`). ``time.sleep`` is not a timing read and
        stays allowed.

A finding can be waived on its line with ``# analysis: ignore[R00x]``
(or a blanket ``# analysis: ignore``) — every waiver is visible in the
diff, unlike a lint that was never run.
"""
from __future__ import annotations

import ast
import os
from typing import Iterable

from repro.analysis.report import Finding

# R001 — the packed/SPMD solver surface that must carry the backend switch.
SOLVER_ENTRY_POINTS = frozenset({
    "step_batched", "solve_batched",
    "async_step_batched", "async_solve_batched",
    "make_spmd_solver", "make_async_spmd_solver",
})

# R002 — attribute chains that read host-static metadata, never a tracer.
_STATIC_ATTRS = frozenset({
    "shape", "ndim", "size", "dtype", "itemsize",
    "num_features", "num_frequencies", "num_nodes", "num_samples",
    "num_slots", "max_features", "node_dims", "offsets",
})
_SYNC_CASTS = frozenset({"float", "int", "bool"})

# R003 — rtol at or below this demands x64 (f32 eps ≈ 1.2e-7).
_RTOL_X64_THRESHOLD = 1e-6
_X64_MARKERS = ("jax_enable_x64", "JAX_ENABLE_X64")


def _waived(source_lines: list[str], lineno: int, rule: str) -> bool:
    if not 1 <= lineno <= len(source_lines):
        return False
    line = source_lines[lineno - 1]
    return (f"analysis: ignore[{rule}]" in line
            or ("analysis: ignore" in line and "[" not in
                line.split("analysis: ignore", 1)[1][:1]))


def _is_jit_ref(node: ast.AST) -> bool:
    """`jax.jit` / bare `jit` reference."""
    return ((isinstance(node, ast.Attribute) and node.attr == "jit")
            or (isinstance(node, ast.Name) and node.id == "jit"))


def _is_jit_decorated(fn: ast.FunctionDef | ast.AsyncFunctionDef) -> bool:
    for dec in fn.decorator_list:
        if _is_jit_ref(dec):
            return True
        if isinstance(dec, ast.Call):
            f = dec.func
            if _is_jit_ref(f):                       # @jax.jit(...)
                return True
            is_partial = ((isinstance(f, ast.Name) and f.id == "partial")
                          or (isinstance(f, ast.Attribute)
                              and f.attr == "partial"))
            if is_partial and dec.args and _is_jit_ref(dec.args[0]):
                return True                          # @partial(jax.jit, …)
    return False


def _is_host_static(node: ast.AST) -> bool:
    """Expressions that can never be a traced value (so casting them is
    not a device sync): names, constants, len(), static-metadata
    attribute chains and indexing/arithmetic over them."""
    if isinstance(node, (ast.Name, ast.Constant)):
        return True
    if isinstance(node, ast.Call):
        return (isinstance(node.func, ast.Name) and node.func.id == "len"
                and len(node.args) == 1)
    if isinstance(node, ast.Attribute):
        return node.attr in _STATIC_ATTRS
    if isinstance(node, ast.Subscript):
        return _is_host_static(node.value)
    if isinstance(node, ast.BinOp):
        return _is_host_static(node.left) and _is_host_static(node.right)
    if isinstance(node, ast.UnaryOp):
        return _is_host_static(node.operand)
    return False


def _callee_name(call: ast.Call) -> str | None:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def _check_jit_host_syncs(tree: ast.Module, rel: str,
                          lines: list[str]) -> list[Finding]:
    findings = []

    def scan_jit_body(fn):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            if _waived(lines, node.lineno, "R002"):
                continue
            # x.item()
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item" and not node.args):
                findings.append(Finding(
                    "conventions", "R002", f"{rel}:{node.lineno}",
                    f"`.item()` inside jitted `{fn.name}` — a device→"
                    f"host sync per call (and a tracer error under jit)"))
                continue
            # float(...) / int(...) / bool(...) on a non-static expr
            if (isinstance(node.func, ast.Name)
                    and node.func.id in _SYNC_CASTS
                    and len(node.args) == 1
                    and not _is_host_static(node.args[0])):
                findings.append(Finding(
                    "conventions", "R002", f"{rel}:{node.lineno}",
                    f"`{node.func.id}(...)` on a computed value inside "
                    f"jitted `{fn.name}` — forces a device→host sync "
                    f"(per-iteration when inside the solve loop); keep "
                    f"it a jnp value or hoist to the host wrapper"))

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and _is_jit_decorated(node):
            scan_jit_body(node)
    return findings


def _check_backend_exposure(tree: ast.Module, rel: str,
                            lines: list[str]) -> list[Finding]:
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        if node.name not in SOLVER_ENTRY_POINTS:
            continue
        if _waived(lines, node.lineno, "R001"):
            continue
        a = node.args
        names = {x.arg for x in a.args + a.kwonlyargs + a.posonlyargs}
        if "backend" not in names:
            findings.append(Finding(
                "conventions", "R001", f"{rel}:{node.lineno}",
                f"solver entry point `{node.name}` does not expose "
                f"`backend=` — every public solver must carry the "
                f"xla/pallas/pallas_fused switch"))
    return findings


def _x64_enabled_for(path: str, source: str,
                     repo_root: str | None) -> bool:
    if any(m in source for m in _X64_MARKERS):
        return True
    d = os.path.dirname(os.path.abspath(path))
    root = os.path.abspath(repo_root) if repo_root else None
    while True:
        conftest = os.path.join(d, "conftest.py")
        if os.path.isfile(conftest):
            try:
                with open(conftest, encoding="utf-8") as f:
                    if any(m in f.read() for m in _X64_MARKERS):
                        return True
            except OSError:
                pass
        parent = os.path.dirname(d)
        if d == root or parent == d:
            return False
        d = parent


def _check_rtol_x64(tree: ast.Module, rel: str, path: str, source: str,
                    lines: list[str],
                    repo_root: str | None) -> list[Finding]:
    if not os.path.basename(path).startswith("test_"):
        return []
    tight = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if kw.arg != "rtol" or not isinstance(kw.value, ast.Constant):
                continue
            val = kw.value.value
            if isinstance(val, (int, float)) \
                    and 0 < val <= _RTOL_X64_THRESHOLD \
                    and not _waived(lines, node.lineno, "R003"):
                tight.append((node.lineno, val))
    if not tight or _x64_enabled_for(path, source, repo_root):
        return []
    lineno, val = tight[0]
    return [Finding(
        "conventions", "R003", f"{rel}:{lineno}",
        f"asserts rtol={val:g} (≤ {_RTOL_X64_THRESHOLD:g}) but neither "
        f"this file nor an ancestor conftest.py enables x64 — at f32 "
        f"(eps ≈ 1.2e-7) the assertion is vacuous or flaky")]


def _check_interpret_usage(tree: ast.Module, rel: str, path: str,
                           lines: list[str]) -> list[Finding]:
    norm = os.path.abspath(path).replace(os.sep, "/")
    if "/repro/kernels/" in norm:
        return []
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if not any(kw.arg == "interpret" for kw in node.keywords):
            continue
        callee = _callee_name(node)
        if callee is None:
            continue
        if (callee.endswith("_pallas") or callee == "pallas_call") \
                and not _waived(lines, node.lineno, "R004"):
            findings.append(Finding(
                "conventions", "R004", f"{rel}:{node.lineno}",
                f"raw Pallas call `{callee}(…, interpret=…)` outside "
                f"src/repro/kernels/ — route through the "
                f"repro.kernels.ops wrappers (they own padding, budget "
                f"checks and backend dispatch)"))
    return findings


# R006 — the two stdlib clock reads the obs clock shims wrap.
_CLOCK_READS = frozenset({"time", "perf_counter"})


def _check_clock_usage(tree: ast.Module, rel: str, path: str,
                       lines: list[str]) -> list[Finding]:
    norm = os.path.abspath(path).replace(os.sep, "/")
    if "/repro/" not in norm or "/repro/obs/" in norm:
        return []
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if not (isinstance(f, ast.Attribute) and f.attr in _CLOCK_READS
                and isinstance(f.value, ast.Name) and f.value.id == "time"):
            continue
        if _waived(lines, node.lineno, "R006"):
            continue
        shim = "wall_clock" if f.attr == "time" else "perf_clock"
        findings.append(Finding(
            "conventions", "R006", f"{rel}:{node.lineno}",
            f"bare `time.{f.attr}()` outside repro/obs/ — use "
            f"`repro.obs.metrics.{shim}` so spans/latency/bench share "
            f"one injectable clock"))
    return findings


def _check_bare_except(tree: ast.Module, rel: str,
                       lines: list[str]) -> list[Finding]:
    findings = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ExceptHandler) and node.type is None \
                and not _waived(lines, node.lineno, "R005"):
            findings.append(Finding(
                "conventions", "R005", f"{rel}:{node.lineno}",
                "bare `except:` — catches KeyboardInterrupt/SystemExit "
                "and makes long solver runs unkillable; catch Exception "
                "or narrower"))
    return findings


def lint_file(path: str, *, repo_root: str | None = None,
              source: str | None = None) -> list[Finding]:
    """Lint one Python file; `source` overrides reading from disk (used by
    the seeded-violation tests)."""
    if source is None:
        with open(path, encoding="utf-8") as f:
            source = f.read()
    rel = (os.path.relpath(path, repo_root) if repo_root
           else os.path.basename(path))
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Finding("conventions", "R000", f"{rel}:{exc.lineno}",
                        f"syntax error: {exc.msg}")]
    lines = source.splitlines()
    findings = []
    findings += _check_backend_exposure(tree, rel, lines)
    findings += _check_jit_host_syncs(tree, rel, lines)
    findings += _check_rtol_x64(tree, rel, path, source, lines, repo_root)
    findings += _check_interpret_usage(tree, rel, path, lines)
    findings += _check_bare_except(tree, rel, lines)
    findings += _check_clock_usage(tree, rel, path, lines)
    return findings


def iter_python_files(paths: Iterable[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def run_pass(paths: Iterable[str], *,
             repo_root: str | None = None) -> list[Finding]:
    findings = []
    for path in iter_python_files(paths):
        findings += lint_file(path, repo_root=repo_root)
    return findings
