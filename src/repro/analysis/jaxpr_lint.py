"""Jaxpr-level lint of the solver entry points.

Each public solver program (`solve_batched`, `async_solve_batched`, the
SPMD solvers, the `dekrr_step`/`dekrr_solve` ops wrappers, the streaming
ingest fold) is traced to a closed jaxpr on a tiny synthetic problem and
statically verified — no solver numerics run, only tracing. Rules:

  J001  No host callbacks (`pure_callback`/`io_callback`/`debug_callback`)
        inside `while`/`scan` bodies — one device→host sync per iteration
        is exactly the per-round stall PR 3 removed.
  J002  Kernel dispatch counts match the documented `round_dispatches`
        contract (BENCH_solve.json): sync solve {xla: 0, pallas: R,
        pallas_fused: 1}; async {xla: 0, pallas: R, pallas_fused: 1 —
        the [R, J] mask table prefetches into one multi-round kernel};
        the ops wrappers dispatch exactly once; `return_trace=True` /
        `return_stats=True` variants pin the SAME counts (telemetry
        never buys an extra launch). Counts are computed statically with
        `lax.scan` length multipliers; the counter itself lives in
        `repro.obs.dispatch` (re-exported here).
  J003  Every `ppermute` permutation is a bijection over its mesh axis:
        pairs in range, sources and destinations distinct, and full
        coverage (an uncovered receiver silently gets zeros).
  J004  No silent x64→f32 downcasts (`convert_element_type`) inside
        `while`/`scan` bodies — a downcast θ carry would quietly degrade
        the rtol-1e-9 parity contract round over round.
  J005  Under `shard_map(..., check_rep=False)` (which disables JAX's own
        replication checking — the Pallas and tol>0 paths), any
        `while_loop` predicate or `cond` branch index that gates
        collectives must be *provably replicated* across the mesh: a
        device-varying trip count deadlocks the in-body
        ppermute/all_gather (the PR 4 mask-schedule hazard). The issue
        phrases this as "operands entering collectives must be
        replicated"; operand *payloads* are intentionally sharded (that
        is the point of the exchange) — what must be replicated is the
        control deciding whether the collective executes, which is what
        this rule proves via a conservative dataflow analysis
        (`psum`/`pmax`/`pmin`/`all_gather` over the mesh axis produce
        replicated values; `axis_index`/`ppermute` device-varying ones;
        everything else propagates meet-over-inputs).
  V002  Every `pallas_call` in a traced program fits the 16 MiB VMEM
        budget, estimated generically from its BlockSpecs (grid-mapping
        block shapes + VMEM scratch avals) — reported under the vmem
        pass; the closed-form per-kernel formulas live in
        `repro.analysis.vmem` and guard the ops wrappers at call time.

The replication analysis is conservative: it proves replication, it does
not prove divergence — so a J005 finding means "not provably safe".

This module imports jax and must only be imported after the process has
fixed its platform/device-count environment (`repro.analysis.__main__`
sets JAX_PLATFORMS=cpu and a forced host device count before importing
it; tests inherit the tier-1 environment).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.report import Finding
from repro.analysis.vmem import VMEM_BUDGET_BYTES, estimate_blocks
from repro.obs.dispatch import count_pallas_dispatches

__all__ = [
    "EntryPoint", "batched_entry_points", "count_pallas_dispatches",
    "lint_program", "run_pass", "spmd_entry_points", "synthetic_packed",
    "walk_eqns",
]

# Rounds used for the dispatch-contract traces (any small R > 1 works; the
# contract is per-round structure, not a particular round count).
ROUNDS = 5
# Mesh size for the SPMD traces — requires
# XLA_FLAGS=--xla_force_host_platform_device_count>=4 (the CLI sets it).
SPMD_NODES = 4

_LOOP_FRAMES = ("scan", "while_body", "while_cond")
_CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call",
})
_COLLECTIVES = frozenset({
    "ppermute", "psum", "pmax", "pmin", "all_gather", "all_to_all",
    "reduce_scatter", "pgather",
})
# Collectives whose output is identical on every device when taken over
# the mesh axis (the basis of the replication dataflow analysis).
_REPLICATING = frozenset({"psum", "pmax", "pmin", "all_gather"})


# --------------------------------------------------------------------------
# Generic jaxpr walking
# --------------------------------------------------------------------------
def _is_jaxpr(v) -> bool:
    return type(v).__name__ in ("Jaxpr", "ClosedJaxpr")


def _inner(j):
    """Unwrap ClosedJaxpr → Jaxpr (ClosedJaxpr has .jaxpr + .consts)."""
    return j.jaxpr if hasattr(j, "consts") and hasattr(j, "jaxpr") else j


def _jaxpr_params(value):
    """Yield every jaxpr-valued leaf of one eqn param value."""
    if _is_jaxpr(value):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _jaxpr_params(v)


def _sub_jaxprs(eqn):
    """Yield (jaxpr, frame) for each sub-jaxpr of `eqn`. Frames:
    ("scan", length) | ("while_body"|"while_cond"|"cond_branch", None) |
    ("shard_map", eqn) | ("call", None). pallas_call kernel bodies are not
    descended into (their memory behavior is the vmem pass's job and
    their arithmetic is pinned dynamically by the parity suites)."""
    name = eqn.primitive.name
    p = eqn.params
    if name == "pallas_call":
        return
    if name == "scan":
        yield p["jaxpr"], ("scan", int(p.get("length", 1)))
    elif name == "while":
        yield p["cond_jaxpr"], ("while_cond", None)
        yield p["body_jaxpr"], ("while_body", None)
    elif name == "cond":
        for br in p["branches"]:
            yield br, ("cond_branch", None)
    elif name == "shard_map":
        yield p["jaxpr"], ("shard_map", eqn)
    else:
        for v in p.values():
            for sub in _jaxpr_params(v):
                yield sub, ("call", None)


def walk_eqns(closed):
    """Yield (eqn, frames) over the whole program, depth-first; `frames`
    is the tuple of enclosing frames from `_sub_jaxprs`."""
    def rec(jaxpr, frames):
        for eqn in jaxpr.eqns:
            yield eqn, frames
            for sub, frame in _sub_jaxprs(eqn):
                yield from rec(_inner(sub), frames + (frame,))

    yield from rec(_inner(closed), ())


def _contains_collective(jaxpr) -> bool:
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in _COLLECTIVES:
            return True
        for sub, _ in _sub_jaxprs(eqn):
            if _contains_collective(_inner(sub)):
                return True
    return False


# --------------------------------------------------------------------------
# J001 — host callbacks inside loop bodies
# --------------------------------------------------------------------------
def check_no_callbacks_in_loops(closed, where: str) -> list[Finding]:
    out = []
    for eqn, frames in walk_eqns(closed):
        if eqn.primitive.name not in _CALLBACK_PRIMS:
            continue
        loops = [f[0] for f in frames if f[0] in _LOOP_FRAMES]
        if loops:
            out.append(Finding(
                "jaxpr", "J001", where,
                f"host callback `{eqn.primitive.name}` inside a "
                f"{loops[-1]} — one device→host round-trip per "
                f"iteration"))
    return out


# --------------------------------------------------------------------------
# J002 — dispatch counting (the counter itself lives in repro.obs.dispatch,
# re-exported above — obs is the lower layer; this pass pins the contract)
# --------------------------------------------------------------------------
def check_dispatch_contract(closed, expected: int | None,
                            where: str) -> list[Finding]:
    if expected is None:
        return []
    count, exact = count_pallas_dispatches(closed)
    if not exact:
        return [Finding(
            "jaxpr", "J002", where,
            f"dispatch count is not statically bounded (pallas_call under "
            f"a while_loop) but the round_dispatches contract pins it to "
            f"{expected}")]
    if count != expected:
        return [Finding(
            "jaxpr", "J002", where,
            f"{count} pallas_call dispatch(es) traced but the "
            f"round_dispatches contract documents {expected}")]
    return []


# --------------------------------------------------------------------------
# J003 — ppermute bijections
# --------------------------------------------------------------------------
def ppermute_perm_errors(perm, axis_size: int) -> list[str]:
    """Pure checker (exposed for the seeded-violation tests): the perm of
    a ring exchange must be a bijection over the full axis."""
    perm = [(int(s), int(d)) for s, d in perm]
    errors = []
    for s, d in perm:
        if not (0 <= s < axis_size and 0 <= d < axis_size):
            errors.append(f"pair ({s}, {d}) outside [0, {axis_size})")
    srcs = [s for s, _ in perm]
    dsts = [d for _, d in perm]
    if len(set(srcs)) != len(srcs):
        errors.append("duplicate source devices")
    if len(set(dsts)) != len(dsts):
        errors.append("duplicate destination devices "
                      "(two sends to one receiver)")
    if not errors and (set(srcs) != set(range(axis_size))
                       or set(dsts) != set(range(axis_size))):
        errors.append(
            f"perm covers {len(set(srcs))}/{axis_size} devices — "
            f"uncovered receivers silently get zeros")
    return errors


def _axis_sizes(frames) -> dict:
    """axis name → size from the innermost enclosing shard_map mesh."""
    for kind, payload in reversed(frames):
        if kind == "shard_map":
            return dict(payload.params["mesh"].shape)
    return {}


def check_ppermute_bijections(closed, where: str) -> list[Finding]:
    out = []
    for eqn, frames in walk_eqns(closed):
        if eqn.primitive.name != "ppermute":
            continue
        axis_name = eqn.params.get("axis_name")
        if isinstance(axis_name, (tuple, list)):
            axis_name = axis_name[0]
        size = _axis_sizes(frames).get(axis_name)
        if size is None:
            continue  # not under shard_map here — axis size unknowable
        for msg in ppermute_perm_errors(eqn.params["perm"], size):
            out.append(Finding(
                "jaxpr", "J003", where,
                f"ppermute over axis {axis_name!r} (size {size}) is not "
                f"a bijection: {msg}"))
    return out


# --------------------------------------------------------------------------
# J004 — silent x64 downcasts in loop bodies
# --------------------------------------------------------------------------
def check_loop_downcasts(closed, where: str) -> list[Finding]:
    out = []
    for eqn, frames in walk_eqns(closed):
        if eqn.primitive.name != "convert_element_type":
            continue
        loops = [f[0] for f in frames if f[0] in _LOOP_FRAMES]
        if not loops:
            continue
        src = np.dtype(eqn.invars[0].aval.dtype)
        dst = np.dtype(eqn.params["new_dtype"])
        if src == np.float64 and dst == np.float32:
            out.append(Finding(
                "jaxpr", "J004", where,
                f"silent f64→f32 downcast inside a {loops[-1]} — an x64 "
                f"carry degraded mid-iteration breaks the rtol-1e-9 "
                f"parity contract"))
    return out


# --------------------------------------------------------------------------
# J005 — replication analysis under check_rep=False
# --------------------------------------------------------------------------
def _eqn_axis_names(eqn) -> set:
    names = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if not isinstance(names, (tuple, list)):
        names = (names,)
    return set(names)


def _rep_propagate(jaxpr, in_reps, axes, findings, where):
    """Forward replication dataflow over one (open) jaxpr. Returns the
    outvars' replication. Conservative: proves replication only."""
    rep = {}

    def read(v):
        return True if type(v).__name__ == "Literal" else rep.get(v, False)

    for v in jaxpr.constvars:
        rep[v] = True                 # trace-time constants: same everywhere
    for v, r in zip(jaxpr.invars, in_reps):
        rep[v] = bool(r)
    for eqn in jaxpr.eqns:
        ins = [read(v) for v in eqn.invars]
        outs = _rep_eqn(eqn, ins, axes, findings, where)
        for v, r in zip(eqn.outvars, outs):
            rep[v] = r
    return [read(v) for v in jaxpr.outvars]


def _rep_eqn(eqn, ins, axes, findings, where):
    name = eqn.primitive.name
    p = eqn.params
    n_out = len(eqn.outvars)
    if name == "axis_index":
        return [False]
    if name in ("ppermute", "all_to_all"):
        return [False] * n_out
    if name in _REPLICATING and (axes & _eqn_axis_names(eqn)):
        return [True] * n_out
    if name == "while":
        cn, bn = p["cond_nconsts"], p["body_nconsts"]
        cond_consts, body_consts = ins[:cn], ins[cn:cn + bn]
        carry = list(ins[cn + bn:])
        body, cond = p["body_jaxpr"], p["cond_jaxpr"]
        for _ in range(len(carry) + 1):         # monotone meet → converges
            outs = _rep_propagate(_inner(body), body_consts + carry,
                                  axes, findings, where)
            new = [a and b for a, b in zip(carry, outs)]
            if new == carry:
                break
            carry = new
        pred = _rep_propagate(_inner(cond), cond_consts + carry,
                              axes, findings, where)[0]
        if not pred and _contains_collective(_inner(body)):
            findings.append(Finding(
                "jaxpr", "J005", where,
                "while_loop predicate is not provably replicated across "
                "the mesh but the body issues collectives — under "
                "check_rep=False devices can disagree on the trip count "
                "and deadlock the exchange"))
        return carry
    if name == "scan":
        nc, ncar = p["num_consts"], p["num_carry"]
        consts, xs = ins[:nc], ins[nc + ncar:]
        carry = list(ins[nc:nc + ncar])
        body = _inner(p["jaxpr"])
        outs = None
        for _ in range(len(carry) + 1):
            outs = _rep_propagate(body, consts + carry + xs,
                                  axes, findings, where)
            new = [a and b for a, b in zip(carry, outs[:ncar])]
            if new == carry:
                break
            carry = new
        ys = outs[ncar:] if outs is not None else []
        return carry + list(ys)
    if name == "cond":
        pred, ops = ins[0], list(ins[1:])
        branch_outs = [
            _rep_propagate(_inner(b), ops, axes, findings, where)
            for b in p["branches"]]
        if not pred and any(_contains_collective(_inner(b))
                            for b in p["branches"]):
            findings.append(Finding(
                "jaxpr", "J005", where,
                "cond branch index is not provably replicated across the "
                "mesh but a branch issues collectives — under "
                "check_rep=False devices can take different branches and "
                "deadlock the exchange"))
        return [pred and all(col) for col in zip(*branch_outs)]
    # Generic call-like eqn (pjit, custom_jvp/vjp, remat, …): recurse when
    # exactly one sub-jaxpr matches the operand arity.
    subs = [s for v in p.values() for s in _jaxpr_params(v)]
    if len(subs) == 1 and len(_inner(subs[0]).invars) == len(ins):
        return _rep_propagate(_inner(subs[0]), ins, axes, findings, where)
    # Default: elementwise-style — replicated iff every input is.
    return [all(ins) if ins else True] * n_out


def check_replication(closed, where: str) -> list[Finding]:
    findings: list[Finding] = []
    for eqn, _frames in walk_eqns(closed):
        if eqn.primitive.name != "shard_map":
            continue
        if eqn.params.get("check_rep", True):
            continue                  # jax's own rewrite already checks
        axes = set(dict(eqn.params["mesh"].shape))
        in_reps = [len(names) == 0 for names in eqn.params["in_names"]]
        _rep_propagate(_inner(eqn.params["jaxpr"]), in_reps, axes,
                       findings, where)
    # Nested fixpoint iterations can emit duplicates — dedupe, keep order.
    return list(dict.fromkeys(findings))


# --------------------------------------------------------------------------
# V002 — generic VMEM budget from BlockSpecs of traced pallas_calls
# --------------------------------------------------------------------------
def check_traced_vmem(closed, where: str) -> list[Finding]:
    out = []
    for eqn, _frames in walk_eqns(closed):
        if eqn.primitive.name != "pallas_call":
            continue
        gm = eqn.params.get("grid_mapping")
        if gm is None:
            continue
        blocks = []
        for bm in getattr(gm, "block_mappings", ()) or ():
            shape = tuple(int(d) for d in bm.block_shape
                          if isinstance(d, int))
            aval = getattr(bm, "block_aval", None)
            dtype = getattr(aval, "dtype", None)
            itemsize = np.dtype(dtype).itemsize if dtype is not None else 4
            blocks.append((shape, itemsize))
        n_scratch = getattr(gm, "num_scratch_operands", 0)
        if n_scratch:
            kernel_invars = _inner(eqn.params["jaxpr"]).invars
            for v in kernel_invars[-n_scratch:]:
                aval = v.aval
                blocks.append((tuple(int(d) for d in aval.shape),
                               np.dtype(aval.dtype).itemsize))
        est = estimate_blocks(f"pallas_call@{where}", blocks)
        if not est.fits:
            out.append(Finding(
                "vmem", "V002", where,
                f"traced pallas_call working set {est.detail} = "
                f"{est.bytes} bytes exceeds the {VMEM_BUDGET_BYTES}-byte "
                f"VMEM budget (single-buffered lower bound)"))
    return out


# --------------------------------------------------------------------------
# Entry-point harness
# --------------------------------------------------------------------------
@dataclasses.dataclass
class EntryPoint:
    """One traceable solver program: `trace()` returns its closed jaxpr;
    `expected_dispatches` pins the J002 contract (None = not pinned, e.g.
    tol>0 paths whose while-loop makes counts dynamic)."""
    label: str
    trace: Callable[[], object]
    expected_dispatches: int | None = None


def synthetic_packed(j_nodes: int = SPMD_NODES, d_feat: int = 8,
                     dtype=np.float64, dy: int = 1):
    """Tiny circulant ring `PackedProblem` built from random arrays —
    shapes and slot layout are real, the numerics are irrelevant (entry
    points are traced, never executed). ``dy > 1`` builds the
    multi-output layout (`d` carries a trailing `[.., Dy]` axis)."""
    from repro.dist.dekrr_spmd import PackedProblem, _circulant_slot_table

    rng = np.random.default_rng(0)
    offsets = (1,)
    nbr_idx = _circulant_slot_table(offsets, j_nodes)
    k_slots = nbr_idx.shape[1]
    shp = dict(dtype=dtype)
    d_shape = (j_nodes, d_feat) if dy == 1 else (j_nodes, d_feat, dy)
    return PackedProblem(
        g=jnp.asarray(rng.standard_normal((j_nodes, d_feat, d_feat)),
                      **shp),
        d=jnp.asarray(rng.standard_normal(d_shape), **shp),
        s=jnp.asarray(rng.standard_normal((j_nodes, d_feat, d_feat)),
                      **shp),
        p=jnp.asarray(
            rng.standard_normal((j_nodes, k_slots, d_feat, d_feat)),
            **shp),
        theta_mask=jnp.ones((j_nodes, d_feat), dtype),
        nbr_idx=jnp.asarray(nbr_idx),
        nbr_mask=jnp.ones((j_nodes, k_slots), dtype),
        offsets=offsets,
        node_dims=(d_feat,) * j_nodes,
        num_edges_directed=j_nodes * k_slots,
    )


def _tiny_solver():
    """Smallest real `DeKRRSolver` (ring of 3, cos_bias) — needed only for
    the streaming-ingest trace, whose state layout `init_stream_aux`
    derives from a solver."""
    from repro.core.dekrr import DeKRRConfig, DeKRRSolver, NodeData
    from repro.core.graph import ring
    from repro.core.rff import FeatureMap

    j_nodes, dim_in, freqs, n_j = 3, 2, 4, 6
    rng = np.random.default_rng(0)
    fmaps = [FeatureMap(omega=jnp.asarray(rng.standard_normal((freqs,
                                                               dim_in))),
                        bias=jnp.asarray(rng.uniform(0, 2 * np.pi, freqs)),
                        kind="cos_bias")
             for _ in range(j_nodes)]
    data = [NodeData(x=jnp.asarray(rng.standard_normal((dim_in, n_j))),
                     y=jnp.asarray(rng.standard_normal(n_j)))
            for _ in range(j_nodes)]
    return DeKRRSolver(ring(j_nodes), fmaps, data, DeKRRConfig(),
                       build_aux=False)


def batched_entry_points() -> list[EntryPoint]:
    """Single-host entry points: `solve_batched`, `async_solve_batched`,
    `chebyshev_solve_packed` (every backend × {tol=0, tol>0} where
    applicable, at Dy=1 and the multi-output Dy=3 layout — the Dy axis
    folds into the kernel row dimension, so the dispatch pins are
    identical), the ops wrappers, streaming ingest."""
    from repro.core.acceleration import chebyshev_solve_packed
    from repro.dist.async_gossip import async_solve_batched
    from repro.dist.dekrr_spmd import _BACKENDS, solve_batched

    packed = synthetic_packed()
    packed_dy = synthetic_packed(dy=3)
    key = jax.random.PRNGKey(0)
    sync_expect = {"xla": 0, "pallas": ROUNDS, "pallas_fused": 1}
    async_expect = {"xla": 0, "pallas": ROUNDS, "pallas_fused": 1}
    cheb_expect = {"xla": 0, "pallas": ROUNDS, "pallas_fused": 1}
    eps = []
    for b in _BACKENDS:
        eps.append(EntryPoint(
            f"solve_batched[backend={b},tol=0]",
            lambda b=b: jax.make_jaxpr(
                lambda pk: solve_batched(pk, ROUNDS, backend=b))(packed),
            sync_expect[b]))
        eps.append(EntryPoint(
            f"solve_batched[backend={b},tol>0]",
            lambda b=b: jax.make_jaxpr(
                lambda pk: solve_batched(pk, ROUNDS, backend=b,
                                         tol=1e-3))(packed)))
        eps.append(EntryPoint(
            f"solve_batched[backend={b},tol=0,dy=3]",
            lambda b=b: jax.make_jaxpr(
                lambda pk: solve_batched(pk, ROUNDS,
                                         backend=b))(packed_dy),
            sync_expect[b]))
        # return_trace pins to the SAME dispatch count as the plain solve
        # — the convergence trace rides the existing scan/while carry and
        # must never add a kernel launch or a host callback.
        eps.append(EntryPoint(
            f"solve_batched[backend={b},tol=0,trace]",
            lambda b=b: jax.make_jaxpr(
                lambda pk: solve_batched(pk, ROUNDS, backend=b,
                                         return_trace=True))(packed),
            sync_expect[b]))
        eps.append(EntryPoint(
            f"solve_batched[backend={b},tol>0,trace]",
            lambda b=b: jax.make_jaxpr(
                lambda pk: solve_batched(pk, ROUNDS, backend=b, tol=1e-3,
                                         return_trace=True))(packed)))
        eps.append(EntryPoint(
            f"async_solve_batched[backend={b},tol=0]",
            lambda b=b: jax.make_jaxpr(
                lambda pk, k: async_solve_batched(pk, ROUNDS, k,
                                                  backend=b))(packed, key),
            async_expect[b]))
        eps.append(EntryPoint(
            f"async_solve_batched[backend={b},tol>0]",
            lambda b=b: jax.make_jaxpr(
                lambda pk, k: async_solve_batched(
                    pk, ROUNDS, k, backend=b, tol=1e-3))(packed, key)))
        eps.append(EntryPoint(
            f"async_solve_batched[backend={b},tol=0,dy=3]",
            lambda b=b: jax.make_jaxpr(
                lambda pk, k: async_solve_batched(
                    pk, ROUNDS, k, backend=b))(packed_dy, key),
            async_expect[b]))
        eps.append(EntryPoint(
            f"async_solve_batched[backend={b},tol=0,trace]",
            lambda b=b: jax.make_jaxpr(
                lambda pk, k: async_solve_batched(
                    pk, ROUNDS, k, backend=b,
                    return_trace=True))(packed, key),
            async_expect[b]))
        eps.append(EntryPoint(
            f"async_solve_batched[backend={b},tol=0,stats]",
            lambda b=b: jax.make_jaxpr(
                lambda pk, k: async_solve_batched(
                    pk, ROUNDS, k, backend=b,
                    return_stats=True))(packed, key),
            async_expect[b]))
        eps.append(EntryPoint(
            f"async_solve_batched[backend={b},tol>0,trace]",
            lambda b=b: jax.make_jaxpr(
                lambda pk, k: async_solve_batched(
                    pk, ROUNDS, k, backend=b, tol=1e-3,
                    return_trace=True))(packed, key)))
        eps.append(EntryPoint(
            f"chebyshev_solve_packed[backend={b}]",
            lambda b=b: jax.make_jaxpr(
                lambda pk: chebyshev_solve_packed(
                    pk, 0.9, 0.0, num_iters=ROUNDS, backend=b))(packed),
            cheb_expect[b]))
        eps.append(EntryPoint(
            f"chebyshev_solve_packed[backend={b},dy=3]",
            lambda b=b: jax.make_jaxpr(
                lambda pk: chebyshev_solve_packed(
                    pk, 0.9, 0.0, num_iters=ROUNDS,
                    backend=b))(packed_dy),
            cheb_expect[b]))
        eps.append(EntryPoint(
            f"chebyshev_solve_packed[backend={b},trace]",
            lambda b=b: jax.make_jaxpr(
                lambda pk: chebyshev_solve_packed(
                    pk, 0.9, 0.0, num_iters=ROUNDS, backend=b,
                    return_trace=True))(packed),
            cheb_expect[b]))
    eps.append(EntryPoint("ops.dekrr_step", _trace_ops_step, 1))
    eps.append(EntryPoint("ops.dekrr_solve", _trace_ops_solve, 1))
    eps.append(EntryPoint("ops.rff_features", _trace_ops_rff_features, 1))
    eps.append(EntryPoint("StreamingDeKRR.ingest", _trace_ingest, 0))
    # Serving answer wave (repro.serve.dekrr.answer_wave) on the tiny
    # 3-node cos_bias snapshot: xla paths emit no pallas_call; the pallas
    # paths dispatch one featurize kernel per node (J = 3) on both the
    # full-precision (rff_features) and bf16 (rff_features_lowp) routes.
    for backend, precision, pin in (("xla", None, 0), ("pallas", None, 3),
                                    ("xla", "bf16", 0),
                                    ("pallas", "bf16", 3)):
        label = (f"serve.answer_wave[backend={backend}"
                 + (f",precision={precision}" if precision else "") + "]")
        eps.append(EntryPoint(
            label,
            lambda backend=backend, precision=precision:
                _trace_serve_wave(backend, precision),
            pin))
    return eps


def _trace_ops_step():
    from repro.kernels import ops

    packed = synthetic_packed()
    self_idx = jnp.arange(packed.num_nodes, dtype=jnp.int32)
    return jax.make_jaxpr(
        lambda pk: ops.dekrr_step(pk.g, pk.d, pk.s, pk.p, pk.d * 0,
                                  pk.nbr_idx, self_idx, pk.nbr_mask)
    )(packed)


def _trace_ops_solve():
    from repro.kernels import ops

    packed = synthetic_packed()
    self_idx = jnp.arange(packed.num_nodes, dtype=jnp.int32)
    return jax.make_jaxpr(
        lambda pk: ops.dekrr_solve(pk.g, pk.d, pk.s, pk.p, pk.d * 0,
                                   pk.nbr_idx, self_idx, pk.nbr_mask,
                                   num_rounds=ROUNDS)
    )(packed)


def _trace_serve_wave(backend: str, precision: str | None):
    """Trace one serving answer wave: the staged snapshot's θ/bound
    constants are concrete (staged once per published version) and the
    query columns are the tracer — exactly the per-wave split
    `repro.serve.dekrr._serve_wave` dispatches."""
    from repro.serve.dekrr import answer_wave, stage_snapshot
    from repro.stream.runtime import ServeSnapshot, StalenessBound

    solver = _tiny_solver()
    rng = np.random.default_rng(3)
    fmaps = tuple(solver.feature_maps)
    theta = tuple(jnp.asarray(rng.standard_normal(fm.num_features))
                  for fm in fmaps)
    snap = ServeSnapshot(feature_maps=fmaps, theta=theta,
                         staleness=StalenessBound(0, 0, 0, 0.0))
    st = stage_snapshot(snap, backend=backend, precision=precision)
    dtype = st.dtype if precision is None else jnp.float32
    x = jnp.zeros((snap.input_dim, 8), dtype)
    return jax.make_jaxpr(lambda xx: answer_wave(st, xx))(x)


def _trace_ops_rff_features():
    from repro.kernels import ops

    fm = _tiny_solver().feature_maps[0]
    x = jnp.zeros((fm.omega.shape[1], 8), fm.omega.dtype)
    return jax.make_jaxpr(
        lambda om, b, xx: ops.rff_features(
            om, b, xx, scale=float(np.sqrt(2.0 / fm.num_frequencies)))
    )(fm.omega, fm.bias, x)


def _trace_ingest():
    """Trace the streaming minibatch fold (`StreamingDeKRR.ingest` →
    `repro.stream.updates.ingest`) with the array state as tracers and
    the host-side staging (tables, minibatch padding) concrete — exactly
    the split the runtime uses."""
    import dataclasses as dc

    from repro.stream.updates import ingest, init_stream_aux

    aux = init_stream_aux(_tiny_solver())
    rng = np.random.default_rng(1)
    xb = rng.standard_normal((2, 3))
    yb = rng.standard_normal(3)
    return jax.make_jaxpr(
        lambda binv, zy, st, pt: ingest(
            dc.replace(aux, binv=binv, zy=zy, st=st, pt=pt), 0, xb, yb
        ).binv
    )(aux.binv, aux.zy, aux.st, aux.pt)


def spmd_entry_points() -> list[EntryPoint]:
    """SPMD entry points — need `SPMD_NODES` devices (forced host devices
    on CPU). Dispatch pins follow the make_spmd_solver docstring: rounds
    never fuse across the per-round exchange, so the Pallas backends run
    one per-round kernel dispatch per round."""
    from jax.sharding import Mesh

    from repro.dist.async_gossip import make_async_spmd_solver
    from repro.dist.dekrr_spmd import make_spmd_solver

    if len(jax.devices()) < SPMD_NODES:
        raise RuntimeError(
            f"SPMD lint needs >= {SPMD_NODES} devices (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={SPMD_NODES})")
    mesh = Mesh(np.array(jax.devices()[:SPMD_NODES]), ("nodes",))
    packed = synthetic_packed(j_nodes=SPMD_NODES)
    key = jax.random.PRNGKey(0)
    sync_expect = {"xla": 0, "pallas": ROUNDS}
    eps = []
    for mode in ("ppermute", "allgather"):
        for backend in ("xla", "pallas"):
            for tol, pin in ((0.0, sync_expect[backend]), (1e-3, None)):
                run = make_spmd_solver(mesh, "nodes", mode=mode,
                                       backend=backend)
                eps.append(EntryPoint(
                    f"make_spmd_solver[mode={mode},backend={backend},"
                    f"tol{'>0' if tol else '=0'}]",
                    lambda run=run, tol=tol: jax.make_jaxpr(
                        lambda pk: run(pk, ROUNDS, tol=tol))(packed),
                    pin))
                arun = make_async_spmd_solver(mesh, "nodes", mode=mode,
                                              backend=backend)
                eps.append(EntryPoint(
                    f"make_async_spmd_solver[mode={mode},"
                    f"backend={backend},tol{'>0' if tol else '=0'}]",
                    lambda arun=arun, tol=tol: jax.make_jaxpr(
                        lambda pk, k: arun(pk, ROUNDS, k,
                                           tol=tol))(packed, key),
                    pin))
                # Trace variants pin the SAME counts — the per-device
                # residual/broadcast series rides the existing scan ys /
                # while carry; wire accounting reduces outside shard_map.
                eps.append(EntryPoint(
                    f"make_spmd_solver[mode={mode},backend={backend},"
                    f"tol{'>0' if tol else '=0'},trace]",
                    lambda run=run, tol=tol: jax.make_jaxpr(
                        lambda pk: run(pk, ROUNDS, tol=tol,
                                       return_trace=True))(packed),
                    pin))
                eps.append(EntryPoint(
                    f"make_async_spmd_solver[mode={mode},"
                    f"backend={backend},tol{'>0' if tol else '=0'},trace]",
                    lambda arun=arun, tol=tol: jax.make_jaxpr(
                        lambda pk, k: arun(pk, ROUNDS, k, tol=tol,
                                           return_trace=True))(packed, key),
                    pin))
    return eps


# --------------------------------------------------------------------------
# Driver
# --------------------------------------------------------------------------
def lint_program(closed, where: str, *,
                 expected_dispatches: int | None = None) -> list[Finding]:
    """Run every structural rule on one traced program."""
    findings = []
    findings += check_no_callbacks_in_loops(closed, where)
    findings += check_dispatch_contract(closed, expected_dispatches, where)
    findings += check_ppermute_bijections(closed, where)
    findings += check_loop_downcasts(closed, where)
    findings += check_replication(closed, where)
    findings += check_traced_vmem(closed, where)
    return findings


def run_pass(*, spmd: bool | None = None,
             entry_points: Iterable[EntryPoint] | None = None
             ) -> list[Finding]:
    """Trace and lint every solver entry point. ``spmd=None`` includes the
    SPMD programs iff enough devices are visible; a trace that itself
    crashes is reported as a J000 finding rather than aborting the pass."""
    if entry_points is None:
        entry_points = list(batched_entry_points())
        if spmd is None:
            spmd = len(jax.devices()) >= SPMD_NODES
        if spmd:
            entry_points = entry_points + spmd_entry_points()
    findings = []
    for ep in entry_points:
        try:
            closed = ep.trace()
        except Exception as exc:  # pragma: no cover - trace regression
            findings.append(Finding(
                "jaxpr", "J000", ep.label,
                f"entry point failed to trace: {type(exc).__name__}: "
                f"{exc}"))
            continue
        findings += lint_program(
            closed, ep.label, expected_dispatches=ep.expected_dispatches)
    return findings
