"""Findings model and rendering for the `repro.analysis` passes.

Every pass (jaxpr lint, VMEM budget, conventions) reports through the same
`Finding` record so the CLI can merge them into one machine-readable JSON
document or one human report, and so `tests/test_analysis.py` can assert
on them uniformly. A finding is a *static* claim about the code or about a
traced program — no pass ever executes solver numerics.

Severity is two-valued on purpose: everything the passes check is a hard
house contract (tier-1 fails on any ``error``), and ``warning`` is
reserved for checks that are conservative by construction (e.g. the
replication analysis proving "not provably replicated" rather than
"provably divergent").
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Iterable


@dataclasses.dataclass(frozen=True)
class Finding:
    """One static-analysis finding.

    ``pass_name``: which pass produced it ("jaxpr", "vmem", "conventions").
    ``rule``: stable rule identifier (e.g. "J001", "V001", "R003") so tests
    and CI can match findings without string-scraping messages.
    ``where``: what was analyzed — a ``file:line`` for AST findings, an
    entry-point label like ``solve_batched[backend=pallas,tol=0]`` for
    jaxpr findings, a kernel name for VMEM findings.
    """
    pass_name: str
    rule: str
    where: str
    message: str
    severity: str = "error"  # "error" | "warning"

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    def render(self) -> str:
        return f"[{self.rule}] {self.severity}: {self.where}: {self.message}"


def render_report(findings: Iterable[Finding], *,
                  title: str = "repro.analysis") -> str:
    """Human-readable report: findings grouped by pass, errors first."""
    findings = list(findings)
    lines = [f"== {title} =="]
    if not findings:
        lines.append("clean: no findings")
        return "\n".join(lines)
    order = {"error": 0, "warning": 1}
    by_pass: dict[str, list[Finding]] = {}
    for f in findings:
        by_pass.setdefault(f.pass_name, []).append(f)
    for pass_name in sorted(by_pass):
        group = sorted(by_pass[pass_name],
                       key=lambda f: (order.get(f.severity, 2), f.rule,
                                      f.where))
        lines.append(f"-- {pass_name} ({len(group)}) --")
        lines.extend(f.render() for f in group)
    num_err = sum(1 for f in findings if f.severity == "error")
    num_warn = len(findings) - num_err
    lines.append(f"total: {num_err} error(s), {num_warn} warning(s)")
    return "\n".join(lines)


def render_json(findings: Iterable[Finding], *,
                extra: dict[str, Any] | None = None) -> str:
    """Machine-readable report (the CI job parses this)."""
    findings = list(findings)
    doc: dict[str, Any] = {
        "findings": [f.to_json() for f in findings],
        "num_errors": sum(1 for f in findings if f.severity == "error"),
        "num_warnings": sum(1 for f in findings
                            if f.severity == "warning"),
    }
    if extra:
        doc.update(extra)
    return json.dumps(doc, indent=2, sort_keys=True)
