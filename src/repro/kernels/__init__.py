"""Pallas TPU kernels for the framework's compute hot-spots.

* rff_gram.py        — fused RFF featurize + streaming Gram (the paper's
                       Eq. 17 pre-iteration hot-spot; features never leave
                       VMEM)
* rff_features.py    — fused featurize for the cross-feature exchange
* dekrr_step.py      — fused packed Eq. 19 round for all J nodes (slot-table
                       neighbor gather + Σ P θ reduction + G GEMM, θ
                       VMEM-resident; the `repro.dist` backend="pallas" path)
* dekrr_solve.py     — fused MULTI-round Eq. 19 solve: the whole lax.scan in
                       one pallas_call, grid (rounds, nodes), two VMEM θ
                       tables alternating by round parity (the `repro.dist`
                       backend="pallas_fused" path)
* decode_attention.py— flash-decode for the serving path (§Perf pair 2)

ops.py holds the jit'd public wrappers (padding/alignment, backend
dispatch: interpret=True on non-TPU backends); ref.py the pure-jnp
oracles every kernel is allclose-tested against.
"""
from repro.kernels import ops
from repro.kernels.ops import (dekrr_solve, dekrr_step, flash_decode,
                               gram_fn_for_solver, rff_features, rff_gram,
                               rff_gram_batched)

__all__ = ["dekrr_solve", "dekrr_step", "flash_decode", "gram_fn_for_solver",
           "ops", "rff_features", "rff_gram", "rff_gram_batched"]
