"""Fused RFF featurize + streaming Gram accumulation — Pallas TPU kernel.

The paper's dominant pre-iteration compute is building the Gram blocks
Z_{j,p} Z_{j,p}ᵀ (Eq. 17) where Z = √(2/D)·cos(Ω X + b) ∈ R^{D×N}, N ≫ D.

A GEMM on a *materialized* Z reads/writes O(D·N) HBM twice (featurize write,
GEMM read) at O(1) arithmetic intensity for the trig stage. On TPU we instead
stream X tiles HBM→VMEM, featurize in-register, and let the MXU accumulate
the D×D Gram that never leaves VMEM until the end:

  grid = (N / block_n,)  — sequential reduction grid
  per step k:  P  = Ω · X_k + b          (MXU,   [D, Bn])
               Zk = scale · cos(P) · mask (VPU)
               G += Zk Zkᵀ               (MXU,   [D, D], VMEM-resident)
               zy += Zk y_k              (MXU)

VMEM working set: D·d (Ω) + d·Bn (X tile) + D·Bn (features) + D² (acc),
all f32 — for the paper's D ≤ 512, d ≤ 160, Bn = 1024 that is < 5 MB.
Executable as `repro.analysis.vmem.estimate_rff_gram` (consolidated table
in that module's docstring) and checked by the ops.py wrappers before
dispatch. D, d and Bn are padded to multiples of (8, 128) for MXU/VREG
alignment by the ops.py wrapper, with a validity mask so padded columns
contribute zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rff_gram_kernel(omega_ref, bias_ref, x_ref, y_ref, mask_ref,
                     gram_ref, zy_ref, *, scale: float):
    """One N-tile of the streaming featurize+Gram reduction."""
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        gram_ref[...] = jnp.zeros_like(gram_ref)
        zy_ref[...] = jnp.zeros_like(zy_ref)

    omega = omega_ref[...]                      # [D, d]
    x = x_ref[...]                              # [d, Bn]
    proj = jax.lax.dot(omega, x,
                       precision=jax.lax.Precision.HIGHEST)  # [D, Bn]
    z = jnp.cos(proj + bias_ref[...]) * scale   # [D, Bn]
    z = z * mask_ref[...]                       # zero out padded columns
    gram_ref[...] += jax.lax.dot(
        z, z.T, precision=jax.lax.Precision.HIGHEST)
    zy_ref[...] += jax.lax.dot(
        z, y_ref[...].T, precision=jax.lax.Precision.HIGHEST)


def rff_gram_pallas(omega: jax.Array, bias: jax.Array, x: jax.Array,
                    y: jax.Array, mask: jax.Array, *, scale: float,
                    block_n: int = 1024,
                    interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Raw pallas_call. All dims must already be padded/aligned:

      omega [D, d], bias [D, 1], x [d, N], y [1, N], mask [1, N],
      N % block_n == 0. Returns (gram [D, D], zy [D, 1]).
    """
    d_feat, d_in = omega.shape
    n = x.shape[1]
    assert n % block_n == 0, (n, block_n)
    grid = (n // block_n,)

    return pl.pallas_call(
        functools.partial(_rff_gram_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((d_feat, d_in), lambda k: (0, 0)),   # Ω resident
            pl.BlockSpec((d_feat, 1), lambda k: (0, 0)),      # bias
            pl.BlockSpec((d_in, block_n), lambda k: (0, k)),  # X tile stream
            pl.BlockSpec((1, block_n), lambda k: (0, k)),     # y tile
            pl.BlockSpec((1, block_n), lambda k: (0, k)),     # mask tile
        ],
        out_specs=[
            pl.BlockSpec((d_feat, d_feat), lambda k: (0, 0)),  # G accumulator
            pl.BlockSpec((d_feat, 1), lambda k: (0, 0)),       # zy accumulator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d_feat, d_feat), x.dtype),
            jax.ShapeDtypeStruct((d_feat, 1), x.dtype),
        ],
        interpret=interpret,
    )(omega, bias, x, y, mask)
