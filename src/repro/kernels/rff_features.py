"""Standalone fused RFF featurization — Pallas TPU kernel.

Z = scale · cos(Ω X + b) ∈ R^{D×N}, tiled (block_d × block_n) over a 2-D
grid. Used for the cross-feature evaluations Z_p(X_j) exchanged in the
pre-iteration phase (Alg. 1 line 6) when the Gram fusion does not apply
(the raw features themselves must be communicated).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rff_features_kernel(omega_ref, bias_ref, x_ref, z_ref, *, scale: float):
    proj = jax.lax.dot(omega_ref[...], x_ref[...],
                       precision=jax.lax.Precision.HIGHEST)
    z_ref[...] = (jnp.cos(proj + bias_ref[...]) * scale).astype(z_ref.dtype)


def rff_features_pallas(omega: jax.Array, bias: jax.Array, x: jax.Array, *,
                        scale: float, block_d: int = 256, block_n: int = 512,
                        interpret: bool = False) -> jax.Array:
    """Raw pallas_call; dims pre-padded: omega [D, d], bias [D, 1], x [d, N],
    D % block_d == 0, N % block_n == 0. Returns Z [D, N]."""
    d_feat, d_in = omega.shape
    n = x.shape[1]
    assert d_feat % block_d == 0 and n % block_n == 0
    grid = (d_feat // block_d, n // block_n)

    return pl.pallas_call(
        functools.partial(_rff_features_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_d, d_in), lambda i, k: (i, 0)),
            pl.BlockSpec((block_d, 1), lambda i, k: (i, 0)),
            pl.BlockSpec((d_in, block_n), lambda i, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((block_d, block_n), lambda i, k: (i, k)),
        out_shape=jax.ShapeDtypeStruct((d_feat, n), x.dtype),
        interpret=interpret,
    )(omega, bias, x)
