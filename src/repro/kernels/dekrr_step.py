"""Fused packed DeKRR round (Eq. 19) for all J nodes — Pallas TPU kernel.

One Eq. 19 round on the packed problem is, per node j,

    θ_j ← G_j (d_j + S_j θ_j + Σ_k m_{j,k} P_{j,k} θ_{nbr(j,k)})

with G/S [D, D], P [K, D, D] blocks padded to the network maximum D = D_max.
The XLA path (`repro.dist.step_batched`) expresses this as a gather plus a
vmapped chain of batched GEMMs; XLA materializes the gathered [J, K, D]
neighbor-θ tensor and the [J, D] intermediates in HBM between them. This
kernel fuses the whole round so that per grid step only node j's blocks move
HBM→VMEM and θ never leaves VMEM:

    grid = (J,)  — one program per node, blocks streamed by BlockSpec:
      θ table   [T, D]        VMEM-resident across the whole grid (the
                              reduction operand; T = J in batched mode)
      G_j, S_j  [1, D, D]     streamed per step
      P_j       [1, K, D, D]  streamed per step
      d_j       [1, D]        streamed per step
    per step j: acc  = d_j + S_j θ_{self(j)}            (MXU)
                acc += Σ_k m_{j,k} · P_{j,k} θ_{row(j,k)}   (MXU, K unrolled)
                out_j = G_j acc                         (MXU)

The neighbor gather is done *inside* the kernel with the slot table: the
int32 tables `nbr_idx` [J, K] / `self_idx` [J] arrive via scalar prefetch
(`PrefetchScalarGridSpec`, SMEM) and index dynamic [1, D] row reads of the
VMEM θ table — no one-hot matmul, no gathered [J, K, D] tensor in HBM.

Decoupling the θ-table row from the node id (`self_idx`) lets the SPMD
per-device node program reuse the identical kernel: a device holding one
node calls it with J = 1, the table [1 + K, D] = [own θ; received neighbor
θs], self_idx = [0] and nbr_idx = [[1 … K]] (see
`repro.dist.make_spmd_solver(backend="pallas")`).

Padding contract (same closure argument as `repro.dist.pack_problem`): rows
i ≥ D_j of G_j are zero, so padded coordinates of the output are *exact*
zeros; masked slots carry zero P blocks, so the `nbr_mask` multiply is
belt-and-braces. Vectors are kept as [1, D] rows and every product is a
dot_general contracting the matrix's second axis (computing (M v)ᵀ without
materializing any transpose).

Multi-output targets (Dy > 1) keep the same kernel: θ tables and d/out
rows arrive *flattened* along the sublane axis as [T·Dy, D] / [J·Dy, D],
with table row t owning the Dy consecutive rows [t·Dy, (t+1)·Dy) (θᵀ for
that node, laid out [Dy, D]). The kernel derives Dy from the d block's
sublane extent and scales every dynamic row read by it; at Dy = 1 the
index arithmetic degenerates to the scalar kernel's and the trace is
unchanged. A [Dy, D] row block through the same dot_generals is exactly
the per-output loop batched on the free axis — no arithmetic changes.

VMEM working set per step: T·D (θ, Dy folded into T) + (2 + K)·D²
(G, S, P) + 3·D·Dy (d, acc, out) floats — for the paper's D ≤ 512, K = 4
at f32 that is ~6.3 MB, within the 16 MB/core budget. This formula is executable as
`repro.analysis.vmem.estimate_dekrr_step` (the consolidated table for all
four kernels lives in that module's docstring); the `ops.dekrr_step`
wrapper checks it before dispatch and raises `VmemBudgetError` on
over-budget shapes. All dims must be padded by the wrapper: D to lane
multiples of 128, the θ table to sublane multiples of 8.

The async-gossip runtime (`repro.dist.async_gossip`) uses the
activation-masked variant (`active=` on `dekrr_step_pallas`): a fourth
scalar-prefetch vector gates each grid step, and inactive nodes copy their
θ row through instead of running the MXU chain — with `active` all-ones
the masked kernel is bit-for-bit the synchronous one (shared
`_eq19_update` body).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# (M v)ᵀ as a row vector: contract [1, D] with [D', D] over the second axis.
_ROW_TIMES_MAT_T = (((1,), (1,)), ((), ()))


def _eq19_update(j, nbr_idx_ref, self_idx_ref, nbr_mask_ref,
                 theta_ref, g_ref, d_ref, s_ref, p_ref):
    """Node j's Eq. 19 update as a [Dy, D] row block — the arithmetic
    shared by the unmasked and activation-masked round kernels (one body,
    so the masked variant's active branch can never drift from the
    synchronous kernel it must reproduce bit-for-bit at full activation).
    Dy is the d block's sublane extent (1 for scalar targets); θ-table
    row t lives at flat rows [t·Dy, (t+1)·Dy)."""
    num_slots = nbr_idx_ref.shape[1]
    dy = d_ref.shape[0]
    dtype = theta_ref.dtype

    def row_times(rows, mat):
        # rows [Dy, D] · mat [D', D]ᵀ → [Dy, D'] == (mat @ rows.T).T
        return jax.lax.dot_general(
            rows, mat, _ROW_TIMES_MAT_T,
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=dtype)

    theta_self = theta_ref[pl.ds(self_idx_ref[j] * dy, dy), :]   # [Dy, D]
    acc = d_ref[...] + row_times(theta_self, s_ref[0])           # d + S θ
    for k in range(num_slots):                               # K static unroll
        theta_k = theta_ref[pl.ds(nbr_idx_ref[j, k] * dy, dy), :]
        mask_k = nbr_mask_ref[j, k].astype(dtype)
        acc += row_times(theta_k, p_ref[0, k]) * mask_k      # Σ m P θ_nbr
    return row_times(acc, g_ref[0])                          # G (…)


def _dekrr_step_kernel(nbr_idx_ref, self_idx_ref, nbr_mask_ref,
                       theta_ref, g_ref, d_ref, s_ref, p_ref, out_ref):
    """One node's Eq. 19 update; grid position = node id.

    Scalar prefetch (SMEM): nbr_idx [J, K] int32, self_idx [J] int32,
    nbr_mask [J, K] int32. Tensor operands: theta [T, D] (full table,
    VMEM-resident), g/s [1, D, D], d [1, D], p [1, K, D, D]; out [1, D].
    """
    j = pl.program_id(0)
    out_ref[...] = _eq19_update(j, nbr_idx_ref, self_idx_ref, nbr_mask_ref,
                                theta_ref, g_ref, d_ref, s_ref, p_ref)


def _dekrr_step_masked_kernel(nbr_idx_ref, self_idx_ref, nbr_mask_ref,
                              active_ref, theta_ref, g_ref, d_ref, s_ref,
                              p_ref, out_ref):
    """Activation-masked Eq. 19 round (async gossip): grid position = node
    id; nodes with active[j] == 0 pass their θ row through untouched —
    the G/S/P block streams still flow (the Pallas pipeline's index maps
    are activation-oblivious) but no MXU work runs and no update lands.

    Scalar prefetch adds active [J] int32 after the shared slot tables.
    With active all-ones this is bit-for-bit `_dekrr_step_kernel` (same
    `_eq19_update` body).
    """
    j = pl.program_id(0)
    is_active = active_ref[j] != 0

    @pl.when(is_active)
    def _update():
        out_ref[...] = _eq19_update(j, nbr_idx_ref, self_idx_ref,
                                    nbr_mask_ref, theta_ref, g_ref, d_ref,
                                    s_ref, p_ref)

    @pl.when(jnp.logical_not(is_active))
    def _passthrough():
        dy = d_ref.shape[0]
        out_ref[...] = theta_ref[pl.ds(self_idx_ref[j] * dy, dy), :]


def dekrr_step_pallas(g: jax.Array, d: jax.Array, s: jax.Array,
                      p: jax.Array, theta: jax.Array, nbr_idx: jax.Array,
                      self_idx: jax.Array, nbr_mask: jax.Array, *,
                      active: jax.Array | None = None, dy: int = 1,
                      interpret: bool = False) -> jax.Array:
    """Raw pallas_call. All dims must already be padded/aligned:

      g/s [J, D, D], d [J·Dy, D], p [J, K, D, D] with K ≥ 1 and D a
      multiple of 128; theta [T·Dy, D] with T·Dy padded to a multiple of
      8; nbr_idx [J, K] int32 *table* rows (pre-flattening — the kernel
      scales by Dy); self_idx [J] int32; nbr_mask [J, K] int32.
    ``active`` ([J] int32, optional) selects the activation-masked async
    kernel: nodes with active[j] == 0 emit their own θ rows unchanged.
    ``dy`` is the output width (1 = scalar targets, today's layout).
    Returns the post-round θ rows, [J·Dy, D] (rows [r·Dy, (r+1)·Dy) for
    node r — callers with T ≠ J re-assemble their table themselves).
    """
    j_nodes = d.shape[0] // dy
    d_feat = d.shape[1]
    k_slots = p.shape[1]
    t_rows = theta.shape[0]
    assert d.shape[0] % dy == 0, (d.shape, dy)
    assert d_feat % 128 == 0 and t_rows % 8 == 0, (d_feat, t_rows)
    assert k_slots >= 1, "pad the slot axis to K >= 1 (zero P blocks)"

    scalar_args = (nbr_idx, self_idx, nbr_mask)
    kernel = _dekrr_step_kernel
    if active is not None:
        scalar_args = scalar_args + (active,)
        kernel = _dekrr_step_masked_kernel
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalar_args),
        grid=(j_nodes,),
        in_specs=[
            pl.BlockSpec((t_rows, d_feat), lambda j, *_: (0, 0)),   # θ table
            pl.BlockSpec((1, d_feat, d_feat), lambda j, *_: (j, 0, 0)),
            pl.BlockSpec((dy, d_feat), lambda j, *_: (j, 0)),
            pl.BlockSpec((1, d_feat, d_feat), lambda j, *_: (j, 0, 0)),
            pl.BlockSpec((1, k_slots, d_feat, d_feat),
                         lambda j, *_: (j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((dy, d_feat), lambda j, *_: (j, 0)),
    )
    flops_per_node = 2 * (2 + k_slots) * d_feat * d_feat * dy
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((j_nodes * dy, d_feat), theta.dtype),
        cost_estimate=pl.CostEstimate(
            flops=j_nodes * flops_per_node,
            bytes_accessed=(t_rows * d_feat
                            + j_nodes * (3 + k_slots) * d_feat * d_feat
                            ) * theta.dtype.itemsize,
            transcendentals=0,
        ),
        interpret=interpret,
    )(*scalar_args, theta, g, d, s, p)


def _table_rows(table: jax.Array, idx: jax.Array, dy: int) -> jax.Array:
    """Gather the dy consecutive flat rows of each table index: table
    [T·dy, D] + idx [...] → [..., dy, D] (row block [i·dy, (i+1)·dy) for
    index i)."""
    flat = idx[..., None] * dy + jnp.arange(dy)
    return table[flat]


@functools.partial(jax.jit, static_argnames=("dy", "interpret"))
def dekrr_step_reference(g, d, s, p, theta, nbr_idx, self_idx, nbr_mask,
                         *, dy: int = 1, interpret: bool = False):
    """Pure-jnp oracle with the raw kernel's exact contract (padded shapes,
    θ-table indirection, Dy-flattened rows) — what
    `tests/test_kernels_dekrr_step.py` pins the kernel against before any
    repro.dist plumbing is involved."""
    del interpret
    if dy == 1:
        nbr_theta = theta[nbr_idx]                    # [J, K, D]
        coupled = jnp.einsum(
            "jkab,jkb->ja", p,
            nbr_theta * nbr_mask[..., None].astype(theta.dtype))
        own = jnp.einsum("jab,jb->ja", s, theta[self_idx])
        return jnp.einsum("jab,jb->ja", g, d + own + coupled)
    nbr_theta = _table_rows(theta, nbr_idx, dy)       # [J, K, Dy, D]
    coupled = jnp.einsum(
        "jkab,jkob->joa", p,
        nbr_theta * nbr_mask[..., None, None].astype(theta.dtype))
    own = jnp.einsum("jab,job->joa", s, _table_rows(theta, self_idx, dy))
    d3 = d.reshape(-1, dy, d.shape[1])                # [J, Dy, D]
    out = jnp.einsum("jab,job->joa", g, d3 + own + coupled)
    return out.reshape(-1, d.shape[1])


@functools.partial(jax.jit, static_argnames=("dy", "interpret"))
def dekrr_step_masked_reference(g, d, s, p, theta, nbr_idx, self_idx,
                                nbr_mask, active, *, dy: int = 1,
                                interpret: bool = False):
    """Pure-jnp oracle for the activation-masked kernel: nodes with
    active == 0 return their own θ-table rows unchanged; active nodes run
    the unmasked oracle's arithmetic."""
    new = dekrr_step_reference(g, d, s, p, theta, nbr_idx, self_idx,
                               nbr_mask, dy=dy, interpret=interpret)
    if dy == 1:
        return jnp.where((active != 0)[:, None], new, theta[self_idx])
    own = _table_rows(theta, self_idx, dy).reshape(new.shape)
    gate = jnp.repeat(active != 0, dy)[:, None]
    return jnp.where(gate, new, own)
