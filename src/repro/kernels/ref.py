"""Pure-jnp oracles for the Pallas kernels (per-kernel allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rff_features_ref(omega: jax.Array, bias: jax.Array, x: jax.Array, *,
                     scale: float) -> jax.Array:
    """Z = scale · cos(Ω X + b)."""
    return jnp.cos(omega @ x + bias.reshape(-1, 1)) * scale


def rff_gram_ref(omega: jax.Array, bias: jax.Array, x: jax.Array,
                 y: jax.Array, *, scale: float
                 ) -> tuple[jax.Array, jax.Array]:
    """(Z Zᵀ, Z yᵀ) on materialized features."""
    z = rff_features_ref(omega, bias, x, scale=scale)
    return z @ z.T, z @ y.reshape(-1)


def chunked_decode_attention_ref(q, k, v, *, scale: float,
                                 mask=None) -> jax.Array:
    """Single-query attention oracle: q [B,H,dh], k/v [B,S,H,dh]."""
    s = jnp.einsum("bhd,bshd->bhs", q, k) * scale
    if mask is not None:
        s = jnp.where(mask[:, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", p, v)
