"""Flash-decode attention — Pallas TPU kernel for the serving hot-spot.

§Perf pair 2 established that single-token decode is memory-bound on KV
cache reads. This kernel streams the cache HBM→VMEM in sequence blocks and
keeps the online-softmax state (m, l, acc) in VMEM/registers, so HBM
traffic is exactly one pass over K and V per step (the roofline minimum)
with no [B, H, S] score materialization.

Layout: one (batch, kv-head) pair per grid row; GQA query groups ride along
in the q tile (rows = G query heads of that kv head).

  grid = (B·K, S / block_s)                (sequential reduction over s)
  per step s:  q_tile [G, dh]   (VMEM-resident across s steps)
               k_blk  [block_s, dh], v_blk [block_s, dh]  (streamed)
               scores = q_tile @ k_blkᵀ  (MXU, [G, block_s])
               online-softmax update of (m, l, acc[G, dh])

VMEM working set ≈ (G·dh + 2·block_s·dh + G·block_s) · 4 B — for G ≤ 8,
dh = 128, block_s = 512: < 1 MB. Executable as
`repro.analysis.vmem.estimate_flash_decode` (consolidated table in that
module's docstring) and checked by `ops.flash_decode` before dispatch.
dh is padded to 128 lanes, block_s to 8 sublanes by ops.py; positions ≥
cur_index are masked in-kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _flash_decode_kernel(q_ref, k_ref, v_ref, len_ref,
                         o_ref, m_ref, l_ref, acc_ref, *,
                         block_s: int, scale: float):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # [G, dh]
    k = k_ref[0]                                   # [block_s, dh]
    v = v_ref[0]
    valid_len = len_ref[0, 0]

    scores = jax.lax.dot(q, k.T,
                         precision=jax.lax.Precision.HIGHEST) * scale
    pos = s_idx * block_s + jax.lax.broadcasted_iota(
        jnp.int32, scores.shape, 1)
    scores = jnp.where(pos < valid_len, scores, _NEG_INF)

    m_prev = m_ref[...]                            # [G, 1]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                    # [G, block_s]
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, precision=jax.lax.Precision.HIGHEST)
    m_ref[...] = m_new

    @pl.when(s_idx == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                        cur_index: jax.Array, *, scale: float,
                        block_s: int = 512,
                        interpret: bool = False) -> jax.Array:
    """Raw pallas_call; dims pre-padded/aligned:

      q [BK, G, dh]  (one row per (batch, kv-head); G query heads each)
      k, v [BK, S, dh],  S % block_s == 0
      cur_index [BK, 1] int32 (valid cache length per row)
    Returns o [BK, G, dh].
    """
    bk, g, dh = q.shape
    s = k.shape[1]
    assert s % block_s == 0, (s, block_s)
    grid = (bk, s // block_s)

    return pl.pallas_call(
        functools.partial(_flash_decode_kernel, block_s=block_s,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, dh), lambda b, si: (b, 0, 0)),
            pl.BlockSpec((1, block_s, dh), lambda b, si: (b, si, 0)),
            pl.BlockSpec((1, block_s, dh), lambda b, si: (b, si, 0)),
            pl.BlockSpec((1, 1), lambda b, si: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, g, dh), lambda b, si: (b, 0, 0)),
            pl.BlockSpec((g, 1), lambda b, si: (0, 0)),    # m scratch
            pl.BlockSpec((g, 1), lambda b, si: (0, 0)),    # l scratch
            pl.BlockSpec((g, dh), lambda b, si: (0, 0)),   # acc scratch
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bk, g, dh), q.dtype),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
            jax.ShapeDtypeStruct((g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, cur_index)[0]
