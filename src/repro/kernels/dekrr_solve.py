"""Fused multi-round DeKRR solve (Eq. 19) — one Pallas TPU kernel.

`repro.kernels.dekrr_step` fuses one Eq. 19 round; the solve is still a
`lax.scan` around it, which means one kernel dispatch per round and one
HBM round-trip of the θ table per round. The paper's operating points have
ρ(M) ≈ 0.95–0.999, i.e. hundreds-to-thousands of rounds, so once the round
itself is fused the per-round launch/dispatch overhead is what's left on
the table. This kernel runs the *entire* solve in one `pallas_call`:

    grid = (R, J)  — rounds outer, nodes inner (row-major, j fastest):
      θ0 table    [T, D]        fetched once (constant index map)
      G_j, S_j    [1, D, D]     streamed per (r, j) step — the index map
      P_j         [1, K, D, D]  depends only on j, so the Pallas pipeline
      d_j         [1, D]        double-buffers the HBM→VMEM block streams
                                across steps and rounds
      scratch     2 × [T, D]    VMEM θ tables (even/odd round parity)

Jacobi needs two θ tables: every node in round r reads the table round
r−1 wrote. The two VMEM scratch tables alternate roles by round parity —
round r reads table r mod 2 and writes table (r+1) mod 2. Both are
initialized from θ0 at the first grid step so that table rows owned by no
node (T > J callers) stay at their θ0 values under either parity, exactly
as the pure-jnp oracle keeps them. θ never touches HBM between rounds;
the only per-round HBM traffic is the [J, D, D] block re-streaming, which
is inherent (the blocks do not fit in VMEM for production J·D²) and is
hidden behind the MXU by the pipeline.

The per-step arithmetic — scalar-prefetched slot-table neighbor gather,
row-vector dot_general contractions, zero-padding closure — is identical
to `dekrr_step._dekrr_step_kernel`; the parity suite pins this kernel to
`solve_batched(backend="xla")` and the ragged reference at rtol 1e-9
under x64 (`tests/test_kernels_dekrr_solve.py`).

VMEM working set: 2·T·D (θ tables) + 2·(2 + K)·D² (double-buffered
blocks) + 3·D vectors — for the paper's J ≤ 256, D ≤ 512, K = 4 at f32
that is ~13.7 MB, within the 16 MB/core budget (J = 256 at D = 512 is
the ceiling; larger tables need a block-sharded θ layout). This formula
is executable as `repro.analysis.vmem.estimate_dekrr_solve`
(consolidated table in that module's docstring); the `ops.dekrr_solve`
wrapper checks it before dispatch and raises `VmemBudgetError` instead
of a Mosaic allocation crash. All dims must be padded by the wrapper:
D to lane multiples of 128, T to sublane multiples of 8.

Two sibling kernels fuse the other two solve schedules the same way —
both are precomputable per chunk, so the per-round control flow that used
to force one dispatch per round rides scalar prefetch instead:

  * `_dekrr_async_solve_kernel` — the COKE async-gossip chain
    (`repro.dist.async_gossip`): the [R, J] activation table and [R]
    censor thresholds prefetch like the slot tables; sent/staleness-buffer
    state lives in VMEM scratch and broadcast flags in two round-parity
    [J] SMEM vectors. Bit-for-bit the scanned per-round masked kernel.
  * `_dekrr_cheb_solve_kernel` — the Chebyshev semi-iteration
    (`repro.core.acceleration`): the precomputed (α_k, β_k) recurrence
    tables prefetch as two [R] float vectors and the two-term Δ state is
    a VMEM table, so the accelerated O(√κ)-round solve is also one
    dispatch per chunk.

Their VMEM working sets are `estimate_dekrr_async_solve` /
`estimate_dekrr_cheb_solve` in `repro.analysis.vmem`.

Multi-output targets (Dy > 1) use the flattened-row layout of
`repro.kernels.dekrr_step`: θ/sent/Δ tables and d rows arrive as
[T·Dy, D] with table row t owning flat rows [t·Dy, (t+1)·Dy) (that
node's θᵀ as a [Dy, D] block), staleness buffers as [B·Dy, D] with slot
(j, k) at rows [(j·K + k)·Dy, ...). Every kernel derives Dy from the d
block's sublane extent and scales its dynamic row reads; at Dy = 1 the
traces are unchanged. The censor reduction max|new − sent| runs over the
[Dy, D] block, i.e. the max over features AND outputs the async runtime
documents.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dekrr_step import dekrr_step_reference

# (M v)ᵀ as a row vector: contract [1, D] with [D', D] over the second axis.
_ROW_TIMES_MAT_T = (((1,), (1,)), ((), ()))


def _dekrr_solve_kernel(nbr_idx_ref, self_idx_ref, nbr_mask_ref,
                        theta0_ref, g_ref, d_ref, s_ref, p_ref, *refs,
                        trace: bool = False):
    """One node's Eq. 19 update at grid position (round, node).

    Scalar prefetch (SMEM): nbr_idx [J, K] int32, self_idx [J] int32,
    nbr_mask [J, K] int32. Tensor operands: theta0 [T, D] (full table,
    fetched once), g/s [1, D, D], d [1, D], p [1, K, D, D]; out [1, D]
    (node j's θ row, overwritten every round — the last round wins).
    Scratch: tab_even/tab_odd [T, D] VMEM θ tables, alternating by round
    parity.

    With static ``trace`` set, a second output block res [1, 1] at grid
    index (r, j) records max|new − θ_self| over the node's [Dy, D] block
    — the per-(round, node) convergence residual, written by the same
    grid step that computes the round (zero extra dispatches). Padded
    coordinates are identically zero on both sides of the subtraction,
    so the max is exact over real coordinates.
    """
    if trace:
        (out_ref, out_res_ref, tab_even_ref, tab_odd_ref) = refs
    else:
        (out_ref, tab_even_ref, tab_odd_ref) = refs
        out_res_ref = None
    r = pl.program_id(0)
    j = pl.program_id(1)
    num_slots = nbr_idx_ref.shape[1]
    dy = d_ref.shape[0]
    dtype = theta0_ref.dtype

    @pl.when(jnp.logical_and(r == 0, j == 0))
    def _init():
        # Both parities start from θ0 so rows no node owns stay at θ0.
        tab_even_ref[...] = theta0_ref[...]
        tab_odd_ref[...] = theta0_ref[...]

    def row_times(rows, mat):
        # rows [Dy, D] · mat [D', D]ᵀ → [Dy, D'] == (mat @ rows.T).T
        return jax.lax.dot_general(
            rows, mat, _ROW_TIMES_MAT_T,
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=dtype)

    def round_body(read_ref, write_ref):
        theta_self = read_ref[pl.ds(self_idx_ref[j] * dy, dy), :]  # [Dy, D]
        acc = d_ref[...] + row_times(theta_self, s_ref[0])       # d + S θ
        for k in range(num_slots):                               # K unroll
            theta_k = read_ref[pl.ds(nbr_idx_ref[j, k] * dy, dy), :]
            mask_k = nbr_mask_ref[j, k].astype(dtype)
            acc += row_times(theta_k, p_ref[0, k]) * mask_k      # Σ m P θ
        new = row_times(acc, g_ref[0])                           # G (…)
        write_ref[pl.ds(self_idx_ref[j] * dy, dy), :] = new
        out_ref[...] = new
        if trace:
            out_res_ref[0, 0] = jnp.max(jnp.abs(new - theta_self))

    even_round = r % 2 == 0

    @pl.when(even_round)
    def _even():
        round_body(tab_even_ref, tab_odd_ref)

    @pl.when(jnp.logical_not(even_round))
    def _odd():
        round_body(tab_odd_ref, tab_even_ref)


def dekrr_solve_pallas(g: jax.Array, d: jax.Array, s: jax.Array,
                       p: jax.Array, theta: jax.Array, nbr_idx: jax.Array,
                       self_idx: jax.Array, nbr_mask: jax.Array, *,
                       num_rounds: int, dy: int = 1, trace: bool = False,
                       interpret: bool = False) -> jax.Array:
    """Raw pallas_call. All dims must already be padded/aligned:

      g/s [J, D, D], d [J·Dy, D], p [J, K, D, D] with K ≥ 1 and D a
      multiple of 128; theta [T·Dy, D] with T·Dy padded to a multiple of
      8; nbr_idx [J, K] int32 *table* rows (pre-flattening); self_idx [J]
      int32 (distinct rows); nbr_mask [J, K] int32; num_rounds ≥ 1 static;
      dy ≥ 1 static (1 = scalar targets, today's layout).
    Returns the θ rows after `num_rounds` Jacobi rounds, [J·Dy, D] (rows
    [r·Dy, (r+1)·Dy) for node r — callers with T ≠ J re-assemble their
    table themselves). With ``trace`` set, returns (θ rows, res [R, J])
    where res[r, j] = max|Δθ_j| of round r — same single dispatch.
    """
    j_nodes = d.shape[0] // dy
    d_feat = d.shape[1]
    k_slots = p.shape[1]
    t_rows = theta.shape[0]
    assert d.shape[0] % dy == 0, (d.shape, dy)
    assert d_feat % 128 == 0 and t_rows % 8 == 0, (d_feat, t_rows)
    assert k_slots >= 1, "pad the slot axis to K >= 1 (zero P blocks)"
    assert num_rounds >= 1, "num_rounds must be a positive static int"

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,          # nbr_idx, self_idx, nbr_mask
        grid=(num_rounds, j_nodes),
        in_specs=[
            pl.BlockSpec((t_rows, d_feat), lambda r, j, *_: (0, 0)),  # θ0
            pl.BlockSpec((1, d_feat, d_feat), lambda r, j, *_: (j, 0, 0)),
            pl.BlockSpec((dy, d_feat), lambda r, j, *_: (j, 0)),
            pl.BlockSpec((1, d_feat, d_feat), lambda r, j, *_: (j, 0, 0)),
            pl.BlockSpec((1, k_slots, d_feat, d_feat),
                         lambda r, j, *_: (j, 0, 0, 0)),
        ],
        out_specs=(
            (pl.BlockSpec((dy, d_feat), lambda r, j, *_: (j, 0)),
             pl.BlockSpec((1, 1), lambda r, j, *_: (r, j)))
            if trace else
            pl.BlockSpec((dy, d_feat), lambda r, j, *_: (j, 0))),
        scratch_shapes=[
            pltpu.VMEM((t_rows, d_feat), theta.dtype),   # even-round table
            pltpu.VMEM((t_rows, d_feat), theta.dtype),   # odd-round table
        ],
    )
    theta_shape = jax.ShapeDtypeStruct((j_nodes * dy, d_feat), theta.dtype)
    res_shape = jax.ShapeDtypeStruct((num_rounds, j_nodes), theta.dtype)
    flops_per_node = 2 * (2 + k_slots) * d_feat * d_feat * dy
    return pl.pallas_call(
        functools.partial(_dekrr_solve_kernel, trace=trace),
        grid_spec=grid_spec,
        out_shape=(theta_shape, res_shape) if trace else theta_shape,
        cost_estimate=pl.CostEstimate(
            flops=num_rounds * j_nodes * flops_per_node,
            bytes_accessed=(t_rows * d_feat            # θ0, fetched once
                            + num_rounds * j_nodes
                            * ((3 + k_slots) * d_feat * d_feat
                               + dy * d_feat)
                            ) * theta.dtype.itemsize,
            transcendentals=0,
        ),
        interpret=interpret,
    )(nbr_idx, self_idx, nbr_mask, theta, g, d, s, p)


# --------------------------------------------------------------- async chain
def _dekrr_async_solve_kernel(nbr_idx_ref, nbr_mask_ref, active_ref, thr_ref,
                              theta0_ref, sent0_ref, buf0_ref, g_ref, d_ref,
                              s_ref, p_ref, *refs, censored: bool,
                              edge_gossip: bool, num_rounds: int,
                              trace: bool = False):
    """R censored async-gossip rounds in one kernel; grid (R + 1, J).

    The whole COKE schedule is precomputed, so it rides scalar prefetch:
    nbr_idx [J, K] int32 (NODE ids, not table rows — self row of node j is
    row j), nbr_mask [J, K] int32, active [R, J] int32 activation table,
    thr [R] float censor thresholds. Tensor operands: theta0/sent0 [T, D]
    and buf0 [B, D] initial state (constant index maps, fetched once),
    g/s [1, D, D], d [1, D], p [1, K, D, D] streamed per (r, j).

    State lives in scratch across the whole grid: two round-parity θ
    tables (Jacobi semantics, as in the sync kernel), a sent table and a
    flattened staleness-buffer table (owner-only access — no parity
    needed), and two parity [J] SMEM broadcast-flag vectors (node j at
    step r can already have overwritten its round-r flag when a later
    node j' > j of the *same* step reads flags, so flags alternate parity
    exactly like θ).

    Step (r, j) replays `repro.dist.async_gossip._async_round` for node j
    with round r − 1's deliveries applied first:

      deliver (r ≥ 1): slot k receives iff the slot is live and
        broadcaster nbr_idx[j, k] raised its round r − 1 flag (edge
        gossip additionally requires receiver j active in round r − 1);
        the buffer row copies the broadcaster's post-round-(r−1) θ row.
      compute (r < R): active nodes run the exact `_eq19_update`
        arithmetic with neighbor rows read from the staleness buffer;
        censored mode broadcasts iff max|new − sent| > thr[r], updating
        sent on broadcast. Inactive nodes copy θ through and clear their
        flag. Round R is delivery-only (flush of the last broadcasts).

    The arithmetic sequence is identical to the per-round masked kernel
    on the [θ; buffers] concat table, so the chain is bit-for-bit the
    scanned per-round "pallas" backend.

    With static ``trace`` set, two more output blocks at grid index
    (r, j) — res [1, 1] float and bc [1, 1] int32, shapes [R + 1, J] —
    record max|new − θ_self| and the round's broadcast flag for active
    nodes (0/0 for inactive nodes and the delivery-flush step). Written
    by the same grid steps: zero extra dispatches. The caller slices off
    the flush row and derives the wire series (deliveries, bytes) from
    the bc flags + slot tables in plain XLA.
    """
    if trace:
        (out_theta_ref, out_sent_ref, out_buf_ref, out_res_ref, out_bc_ref,
         tab_even_ref, tab_odd_ref, sent_ref, buf_ref, fl_even_ref,
         fl_odd_ref) = refs
    else:
        (out_theta_ref, out_sent_ref, out_buf_ref, tab_even_ref,
         tab_odd_ref, sent_ref, buf_ref, fl_even_ref, fl_odd_ref) = refs
        out_res_ref = out_bc_ref = None
    r = pl.program_id(0)
    j = pl.program_id(1)
    num_slots = nbr_idx_ref.shape[1]
    dy = d_ref.shape[0]
    dtype = theta0_ref.dtype

    @pl.when(jnp.logical_and(r == 0, j == 0))
    def _init():
        tab_even_ref[...] = theta0_ref[...]
        tab_odd_ref[...] = theta0_ref[...]
        sent_ref[...] = sent0_ref[...]
        buf_ref[...] = buf0_ref[...]

    def row_times(rows, mat):
        # rows [Dy, D] · mat [D', D]ᵀ → [Dy, D'] == (mat @ rows.T).T
        return jax.lax.dot_general(
            rows, mat, _ROW_TIMES_MAT_T,
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=dtype)

    def deliver(read_tab, fl_read):
        for k in range(num_slots):
            nb = nbr_idx_ref[j, k]
            cond = jnp.logical_and(nbr_mask_ref[j, k] != 0,
                                   fl_read[nb] != 0)
            if edge_gossip:
                cond = jnp.logical_and(cond, active_ref[r - 1, j] != 0)

            @pl.when(cond)
            def _recv(k=k, nb=nb):
                buf_ref[pl.ds((j * num_slots + k) * dy, dy), :] = \
                    read_tab[pl.ds(nb * dy, dy), :]

    def compute(read_tab, write_tab, fl_write):
        is_active = active_ref[r, j] != 0

        @pl.when(is_active)
        def _update():
            theta_self = read_tab[pl.ds(j * dy, dy), :]          # [Dy, D]
            acc = d_ref[...] + row_times(theta_self, s_ref[0])   # d + S θ
            for k in range(num_slots):                           # K unroll
                theta_k = buf_ref[pl.ds((j * num_slots + k) * dy, dy), :]
                mask_k = nbr_mask_ref[j, k].astype(dtype)
                acc += row_times(theta_k, p_ref[0, k]) * mask_k  # Σ m P θ
            new = row_times(acc, g_ref[0])                       # G (…)
            write_tab[pl.ds(j * dy, dy), :] = new
            out_theta_ref[...] = new
            if trace:
                out_res_ref[0, 0] = jnp.max(jnp.abs(new - theta_self))
            if censored:
                # max over features AND outputs — the [Dy, D] block
                delta = jnp.max(jnp.abs(new - sent_ref[pl.ds(j * dy, dy), :]))
                bc = delta > thr_ref[r]
                fl_write[j] = bc.astype(jnp.int32)
                if trace:
                    out_bc_ref[0, 0] = bc.astype(jnp.int32)

                @pl.when(bc)
                def _bcast():
                    sent_ref[pl.ds(j * dy, dy), :] = new
            else:
                fl_write[j] = jnp.int32(1)
                if trace:
                    out_bc_ref[0, 0] = jnp.int32(1)
                sent_ref[pl.ds(j * dy, dy), :] = new

        @pl.when(jnp.logical_not(is_active))
        def _passthrough():
            cur = read_tab[pl.ds(j * dy, dy), :]
            write_tab[pl.ds(j * dy, dy), :] = cur
            out_theta_ref[...] = cur
            fl_write[j] = jnp.int32(0)

    def step(read_tab, write_tab, fl_read, fl_write):
        if trace:
            # Defaults every grid step (inactive nodes and the flush row
            # record 0); the active-node update overwrites both.
            out_res_ref[0, 0] = jnp.zeros((), dtype)
            out_bc_ref[0, 0] = jnp.int32(0)

        @pl.when(r >= 1)
        def _deliver():
            deliver(read_tab, fl_read)

        @pl.when(r < num_rounds)
        def _compute():
            compute(read_tab, write_tab, fl_write)

        @pl.when(r == num_rounds)
        def _flush():
            out_theta_ref[...] = read_tab[pl.ds(j * dy, dy), :]

        out_sent_ref[...] = sent_ref[pl.ds(j * dy, dy), :]
        out_buf_ref[...] = buf_ref[pl.ds(j * num_slots * dy,
                                         num_slots * dy), :]

    even_round = r % 2 == 0

    @pl.when(even_round)
    def _even():
        step(tab_even_ref, tab_odd_ref, fl_even_ref, fl_odd_ref)

    @pl.when(jnp.logical_not(even_round))
    def _odd():
        step(tab_odd_ref, tab_even_ref, fl_odd_ref, fl_even_ref)


def dekrr_async_solve_pallas(g: jax.Array, d: jax.Array, s: jax.Array,
                             p: jax.Array, theta: jax.Array,
                             sent: jax.Array, buffers: jax.Array,
                             nbr_idx: jax.Array, nbr_mask: jax.Array,
                             active_tab: jax.Array, thresholds: jax.Array,
                             *, censored: bool, edge_gossip: bool,
                             dy: int = 1, trace: bool = False,
                             interpret: bool = False
                             ) -> tuple[jax.Array, ...]:
    """Raw pallas_call. All dims must already be padded/aligned:

      g/s [J, D, D], d [J·Dy, D], p [J, K, D, D] with K ≥ 1 and D a
      multiple of 128; theta/sent [T·Dy, D] with T ≥ J and T·Dy padded to
      a multiple of 8 (rows [j·Dy, (j+1)·Dy) = node j); buffers [B·Dy, D]
      with B ≥ J·K, B·Dy a multiple of 8 (rows [(j·K + k)·Dy, ...) = slot
      (j, k)); nbr_idx/nbr_mask [J, K] int32 with entries < J;
      active_tab [R, J] int32 with R ≥ 1 static; thresholds [R] float;
      dy ≥ 1 static (1 = scalar targets, today's layout).
    Returns the post-schedule (θ rows [J·Dy, D], sent rows [J·Dy, D],
    buffer rows [J·K·Dy, D]). With ``trace`` set, appends
    (res [R + 1, J] float, bc [R + 1, J] int32) — per-(round, node)
    max|Δθ| and broadcast flags, last row (delivery flush) all-zero —
    still one dispatch.
    """
    j_nodes = d.shape[0] // dy
    d_feat = d.shape[1]
    k_slots = p.shape[1]
    t_rows = theta.shape[0]
    b_rows = buffers.shape[0]
    num_rounds = active_tab.shape[0]
    assert d.shape[0] % dy == 0, (d.shape, dy)
    assert d_feat % 128 == 0 and t_rows % 8 == 0 and b_rows % 8 == 0, \
        (d_feat, t_rows, b_rows)
    assert sent.shape == theta.shape, (sent.shape, theta.shape)
    assert b_rows >= j_nodes * k_slots * dy, (b_rows, j_nodes, k_slots, dy)
    assert k_slots >= 1, "pad the slot axis to K >= 1 (zero P blocks)"
    assert num_rounds >= 1, "schedule must cover >= 1 round"

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,    # nbr_idx, nbr_mask, active_tab, thresholds
        grid=(num_rounds + 1, j_nodes),       # final step: delivery flush
        in_specs=[
            pl.BlockSpec((t_rows, d_feat), lambda r, j, *_: (0, 0)),  # θ0
            pl.BlockSpec((t_rows, d_feat), lambda r, j, *_: (0, 0)),  # sent0
            pl.BlockSpec((b_rows, d_feat), lambda r, j, *_: (0, 0)),  # buf0
            pl.BlockSpec((1, d_feat, d_feat), lambda r, j, *_: (j, 0, 0)),
            pl.BlockSpec((dy, d_feat), lambda r, j, *_: (j, 0)),
            pl.BlockSpec((1, d_feat, d_feat), lambda r, j, *_: (j, 0, 0)),
            pl.BlockSpec((1, k_slots, d_feat, d_feat),
                         lambda r, j, *_: (j, 0, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((dy, d_feat), lambda r, j, *_: (j, 0)),      # θ
            pl.BlockSpec((dy, d_feat), lambda r, j, *_: (j, 0)),      # sent
            pl.BlockSpec((k_slots * dy, d_feat),
                         lambda r, j, *_: (j, 0)),                    # buf
        ) + ((
            pl.BlockSpec((1, 1), lambda r, j, *_: (r, j)),            # res
            pl.BlockSpec((1, 1), lambda r, j, *_: (r, j)),            # bc
        ) if trace else ()),
        scratch_shapes=[
            pltpu.VMEM((t_rows, d_feat), theta.dtype),   # even-round table
            pltpu.VMEM((t_rows, d_feat), theta.dtype),   # odd-round table
            pltpu.VMEM((t_rows, d_feat), theta.dtype),   # sent table
            pltpu.VMEM((b_rows, d_feat), theta.dtype),   # staleness buffers
            pltpu.SMEM((j_nodes,), jnp.int32),           # even-round flags
            pltpu.SMEM((j_nodes,), jnp.int32),           # odd-round flags
        ],
    )
    kernel = functools.partial(
        _dekrr_async_solve_kernel, censored=censored,
        edge_gossip=edge_gossip, num_rounds=num_rounds, trace=trace)
    flops_per_node = 2 * (2 + k_slots) * d_feat * d_feat * dy
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((j_nodes * dy, d_feat), theta.dtype),
            jax.ShapeDtypeStruct((j_nodes * dy, d_feat), theta.dtype),
            jax.ShapeDtypeStruct((j_nodes * k_slots * dy, d_feat),
                                 theta.dtype),
        ) + ((
            jax.ShapeDtypeStruct((num_rounds + 1, j_nodes), theta.dtype),
            jax.ShapeDtypeStruct((num_rounds + 1, j_nodes), jnp.int32),
        ) if trace else ()),
        cost_estimate=pl.CostEstimate(
            flops=num_rounds * j_nodes * flops_per_node,
            bytes_accessed=((2 * t_rows + b_rows) * d_feat
                            + (num_rounds + 1) * j_nodes
                            * ((3 + k_slots) * d_feat * d_feat
                               + dy * d_feat)
                            ) * theta.dtype.itemsize,
            transcendentals=0,
        ),
        interpret=interpret,
    )(nbr_idx, nbr_mask, active_tab, thresholds, theta, sent, buffers,
      g, d, s, p)


# ---------------------------------------------------------------- chebyshev
def _dekrr_cheb_solve_kernel(nbr_idx_ref, self_idx_ref, nbr_mask_ref,
                             alpha_ref, beta_ref, theta0_ref, delta0_ref,
                             g_ref, d_ref, s_ref, p_ref, *refs,
                             trace: bool = False):
    """R Chebyshev semi-iteration rounds in one kernel; grid (R, J).

    Identical layout to the plain fused solve — parity-alternating θ
    tables, scalar-prefetched slot tables — plus the precomputed (α, β)
    schedule (`repro.core.acceleration.chebyshev_coefficients`) as two
    [R] float prefetch vectors and a [J', D] VMEM table holding each
    node's two-term recurrence direction state p (owner-only access, no
    parity; Δ_k = α_k p_k):

        new  = eq19(θ_read)                      (the F-application)
        p_j  ← (new − θ_j) + β_r p_j
        θ_j  ← θ_j + α_r p_j

    θ and p rows are emitted every round (last round wins) so chunked
    callers can chain bit-exactly — the exact recurrence
    `repro.core.acceleration.chebyshev_scan` runs on the host/XLA paths.

    With static ``trace`` set, one more output block res [1, 1] at grid
    index (r, j) records max|θ_new − θ_j| (the accelerated update's
    actual step α_r p_j, not the F-residual) — shape [R, J], written by
    the same grid steps, zero extra dispatches.
    """
    if trace:
        (out_theta_ref, out_delta_ref, out_res_ref, tab_even_ref,
         tab_odd_ref, delta_ref) = refs
    else:
        (out_theta_ref, out_delta_ref, tab_even_ref, tab_odd_ref,
         delta_ref) = refs
        out_res_ref = None
    r = pl.program_id(0)
    j = pl.program_id(1)
    num_slots = nbr_idx_ref.shape[1]
    dy = d_ref.shape[0]
    dtype = theta0_ref.dtype

    @pl.when(jnp.logical_and(r == 0, j == 0))
    def _init():
        tab_even_ref[...] = theta0_ref[...]
        tab_odd_ref[...] = theta0_ref[...]
        delta_ref[...] = delta0_ref[...]

    def row_times(rows, mat):
        # rows [Dy, D] · mat [D', D]ᵀ → [Dy, D'] == (mat @ rows.T).T
        return jax.lax.dot_general(
            rows, mat, _ROW_TIMES_MAT_T,
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=dtype)

    def round_body(read_ref, write_ref):
        theta_self = read_ref[pl.ds(self_idx_ref[j] * dy, dy), :]  # [Dy, D]
        acc = d_ref[...] + row_times(theta_self, s_ref[0])       # d + S θ
        for k in range(num_slots):                               # K unroll
            theta_k = read_ref[pl.ds(nbr_idx_ref[j, k] * dy, dy), :]
            mask_k = nbr_mask_ref[j, k].astype(dtype)
            acc += row_times(theta_k, p_ref[0, k]) * mask_k      # Σ m P θ
        new = row_times(acc, g_ref[0])                           # F(θ)_j
        resid = new - theta_self
        p_new = resid + beta_ref[r] * delta_ref[pl.ds(j * dy, dy), :]
        th_new = theta_self + alpha_ref[r] * p_new
        write_ref[pl.ds(self_idx_ref[j] * dy, dy), :] = th_new
        delta_ref[pl.ds(j * dy, dy), :] = p_new
        out_theta_ref[...] = th_new
        out_delta_ref[...] = p_new
        if trace:
            out_res_ref[0, 0] = jnp.max(jnp.abs(th_new - theta_self))

    even_round = r % 2 == 0

    @pl.when(even_round)
    def _even():
        round_body(tab_even_ref, tab_odd_ref)

    @pl.when(jnp.logical_not(even_round))
    def _odd():
        round_body(tab_odd_ref, tab_even_ref)


def dekrr_cheb_solve_pallas(g: jax.Array, d: jax.Array, s: jax.Array,
                            p: jax.Array, theta: jax.Array,
                            delta: jax.Array, nbr_idx: jax.Array,
                            self_idx: jax.Array, nbr_mask: jax.Array,
                            alphas: jax.Array, betas: jax.Array, *,
                            dy: int = 1, trace: bool = False,
                            interpret: bool = False
                            ) -> tuple[jax.Array, ...]:
    """Raw pallas_call. Same operand contract as `dekrr_solve_pallas`
    (Dy-flattened θ/d rows when dy > 1), plus delta [J'·Dy, D] (J' ≥ J,
    J'·Dy a multiple of 8, rows [j·Dy, (j+1)·Dy) = node j's direction
    state p) and the [R] float (α, β) schedule with R ≥ 1 static.
    Returns the (θ rows [J·Dy, D], p rows [J·Dy, D]) after R Chebyshev
    rounds. With ``trace`` set, appends res [R, J] — per-(round, node)
    max|Δθ| of the accelerated update — same single dispatch.
    """
    j_nodes = d.shape[0] // dy
    d_feat = d.shape[1]
    k_slots = p.shape[1]
    t_rows = theta.shape[0]
    j_rows = delta.shape[0]
    num_rounds = alphas.shape[0]
    assert d.shape[0] % dy == 0, (d.shape, dy)
    assert d_feat % 128 == 0 and t_rows % 8 == 0 and j_rows % 8 == 0, \
        (d_feat, t_rows, j_rows)
    assert j_rows >= j_nodes * dy, (j_rows, j_nodes, dy)
    assert alphas.shape == betas.shape, (alphas.shape, betas.shape)
    assert k_slots >= 1, "pad the slot axis to K >= 1 (zero P blocks)"
    assert num_rounds >= 1, "schedule must cover >= 1 round"

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,   # nbr_idx, self_idx, nbr_mask, alphas, betas
        grid=(num_rounds, j_nodes),
        in_specs=[
            pl.BlockSpec((t_rows, d_feat), lambda r, j, *_: (0, 0)),  # θ0
            pl.BlockSpec((j_rows, d_feat), lambda r, j, *_: (0, 0)),  # Δ0
            pl.BlockSpec((1, d_feat, d_feat), lambda r, j, *_: (j, 0, 0)),
            pl.BlockSpec((dy, d_feat), lambda r, j, *_: (j, 0)),
            pl.BlockSpec((1, d_feat, d_feat), lambda r, j, *_: (j, 0, 0)),
            pl.BlockSpec((1, k_slots, d_feat, d_feat),
                         lambda r, j, *_: (j, 0, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((dy, d_feat), lambda r, j, *_: (j, 0)),      # θ
            pl.BlockSpec((dy, d_feat), lambda r, j, *_: (j, 0)),      # Δ
        ) + ((
            pl.BlockSpec((1, 1), lambda r, j, *_: (r, j)),            # res
        ) if trace else ()),
        scratch_shapes=[
            pltpu.VMEM((t_rows, d_feat), theta.dtype),   # even-round table
            pltpu.VMEM((t_rows, d_feat), theta.dtype),   # odd-round table
            pltpu.VMEM((j_rows, d_feat), theta.dtype),   # Δ table
        ],
    )
    flops_per_node = 2 * (2 + k_slots) * d_feat * d_feat * dy
    return pl.pallas_call(
        functools.partial(_dekrr_cheb_solve_kernel, trace=trace),
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((j_nodes * dy, d_feat), theta.dtype),
            jax.ShapeDtypeStruct((j_nodes * dy, d_feat), theta.dtype),
        ) + ((
            jax.ShapeDtypeStruct((num_rounds, j_nodes), theta.dtype),
        ) if trace else ()),
        cost_estimate=pl.CostEstimate(
            flops=num_rounds * j_nodes * flops_per_node,
            bytes_accessed=((t_rows + j_rows) * d_feat
                            + num_rounds * j_nodes
                            * ((3 + k_slots) * d_feat * d_feat
                               + dy * d_feat)
                            ) * theta.dtype.itemsize,
            transcendentals=0,
        ),
        interpret=interpret,
    )(nbr_idx, self_idx, nbr_mask, alphas, betas, theta, delta,
      g, d, s, p)


@functools.partial(jax.jit,
                   static_argnames=("num_rounds", "dy", "interpret"))
def dekrr_solve_reference(g, d, s, p, theta, nbr_idx, self_idx, nbr_mask,
                          *, num_rounds: int, dy: int = 1,
                          interpret: bool = False):
    """Pure-jnp oracle with the raw kernel's exact contract: scan the
    single-round oracle, scattering each round's new rows back into the
    θ table at `self_idx` (rows owned by no node stay at θ0) — what
    `tests/test_kernels_dekrr_solve.py` pins the kernel against before
    any repro.dist plumbing is involved."""
    del interpret
    if dy == 1:
        rows = self_idx
    else:
        rows = (self_idx[:, None] * dy + jnp.arange(dy)).reshape(-1)

    def one_round(table, _):
        new = dekrr_step_reference(g, d, s, p, table, nbr_idx, self_idx,
                                   nbr_mask, dy=dy)
        return table.at[rows].set(new), None

    table, _ = jax.lax.scan(one_round, theta, None, length=num_rounds)
    return table[rows]
