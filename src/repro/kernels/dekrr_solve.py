"""Fused multi-round DeKRR solve (Eq. 19) — one Pallas TPU kernel.

`repro.kernels.dekrr_step` fuses one Eq. 19 round; the solve is still a
`lax.scan` around it, which means one kernel dispatch per round and one
HBM round-trip of the θ table per round. The paper's operating points have
ρ(M) ≈ 0.95–0.999, i.e. hundreds-to-thousands of rounds, so once the round
itself is fused the per-round launch/dispatch overhead is what's left on
the table. This kernel runs the *entire* solve in one `pallas_call`:

    grid = (R, J)  — rounds outer, nodes inner (row-major, j fastest):
      θ0 table    [T, D]        fetched once (constant index map)
      G_j, S_j    [1, D, D]     streamed per (r, j) step — the index map
      P_j         [1, K, D, D]  depends only on j, so the Pallas pipeline
      d_j         [1, D]        double-buffers the HBM→VMEM block streams
                                across steps and rounds
      scratch     2 × [T, D]    VMEM θ tables (even/odd round parity)

Jacobi needs two θ tables: every node in round r reads the table round
r−1 wrote. The two VMEM scratch tables alternate roles by round parity —
round r reads table r mod 2 and writes table (r+1) mod 2. Both are
initialized from θ0 at the first grid step so that table rows owned by no
node (T > J callers) stay at their θ0 values under either parity, exactly
as the pure-jnp oracle keeps them. θ never touches HBM between rounds;
the only per-round HBM traffic is the [J, D, D] block re-streaming, which
is inherent (the blocks do not fit in VMEM for production J·D²) and is
hidden behind the MXU by the pipeline.

The per-step arithmetic — scalar-prefetched slot-table neighbor gather,
row-vector dot_general contractions, zero-padding closure — is identical
to `dekrr_step._dekrr_step_kernel`; the parity suite pins this kernel to
`solve_batched(backend="xla")` and the ragged reference at rtol 1e-9
under x64 (`tests/test_kernels_dekrr_solve.py`).

VMEM working set: 2·T·D (θ tables) + 2·(2 + K)·D² (double-buffered
blocks) + 3·D vectors — for the paper's J ≤ 256, D ≤ 512, K = 4 at f32
that is ~13.7 MB, within the 16 MB/core budget (J = 256 at D = 512 is
the ceiling; larger tables need a block-sharded θ layout). This formula
is executable as `repro.analysis.vmem.estimate_dekrr_solve`
(consolidated table in that module's docstring); the `ops.dekrr_solve`
wrapper checks it before dispatch and raises `VmemBudgetError` instead
of a Mosaic allocation crash. All dims must be padded by the wrapper:
D to lane multiples of 128, T to sublane multiples of 8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dekrr_step import dekrr_step_reference

# (M v)ᵀ as a row vector: contract [1, D] with [D', D] over the second axis.
_ROW_TIMES_MAT_T = (((1,), (1,)), ((), ()))


def _dekrr_solve_kernel(nbr_idx_ref, self_idx_ref, nbr_mask_ref,
                        theta0_ref, g_ref, d_ref, s_ref, p_ref, out_ref,
                        tab_even_ref, tab_odd_ref):
    """One node's Eq. 19 update at grid position (round, node).

    Scalar prefetch (SMEM): nbr_idx [J, K] int32, self_idx [J] int32,
    nbr_mask [J, K] int32. Tensor operands: theta0 [T, D] (full table,
    fetched once), g/s [1, D, D], d [1, D], p [1, K, D, D]; out [1, D]
    (node j's θ row, overwritten every round — the last round wins).
    Scratch: tab_even/tab_odd [T, D] VMEM θ tables, alternating by round
    parity.
    """
    r = pl.program_id(0)
    j = pl.program_id(1)
    num_slots = nbr_idx_ref.shape[1]
    dtype = theta0_ref.dtype

    @pl.when(jnp.logical_and(r == 0, j == 0))
    def _init():
        # Both parities start from θ0 so rows no node owns stay at θ0.
        tab_even_ref[...] = theta0_ref[...]
        tab_odd_ref[...] = theta0_ref[...]

    def row_times(row, mat):
        # row [1, D] · mat [D', D]ᵀ → [1, D'] == (mat @ row.T).T
        return jax.lax.dot_general(
            row, mat, _ROW_TIMES_MAT_T,
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=dtype)

    def round_body(read_ref, write_ref):
        theta_self = read_ref[pl.ds(self_idx_ref[j], 1), :]      # [1, D]
        acc = d_ref[...] + row_times(theta_self, s_ref[0])       # d + S θ
        for k in range(num_slots):                               # K unroll
            theta_k = read_ref[pl.ds(nbr_idx_ref[j, k], 1), :]
            mask_k = nbr_mask_ref[j, k].astype(dtype)
            acc += row_times(theta_k, p_ref[0, k]) * mask_k      # Σ m P θ
        new = row_times(acc, g_ref[0])                           # G (…)
        write_ref[pl.ds(self_idx_ref[j], 1), :] = new
        out_ref[...] = new

    even_round = r % 2 == 0

    @pl.when(even_round)
    def _even():
        round_body(tab_even_ref, tab_odd_ref)

    @pl.when(jnp.logical_not(even_round))
    def _odd():
        round_body(tab_odd_ref, tab_even_ref)


def dekrr_solve_pallas(g: jax.Array, d: jax.Array, s: jax.Array,
                       p: jax.Array, theta: jax.Array, nbr_idx: jax.Array,
                       self_idx: jax.Array, nbr_mask: jax.Array, *,
                       num_rounds: int,
                       interpret: bool = False) -> jax.Array:
    """Raw pallas_call. All dims must already be padded/aligned:

      g/s [J, D, D], d [J, D], p [J, K, D, D] with K ≥ 1 and D a multiple
      of 128; theta [T, D] with T a multiple of 8; nbr_idx [J, K] int32
      rows into theta; self_idx [J] int32 (distinct rows); nbr_mask [J, K]
      int32; num_rounds ≥ 1 static.
    Returns the θ rows after `num_rounds` Jacobi rounds, [J, D] (row r for
    node r — callers with T ≠ J re-assemble their table themselves).
    """
    j_nodes, d_feat = d.shape
    k_slots = p.shape[1]
    t_rows = theta.shape[0]
    assert d_feat % 128 == 0 and t_rows % 8 == 0, (d_feat, t_rows)
    assert k_slots >= 1, "pad the slot axis to K >= 1 (zero P blocks)"
    assert num_rounds >= 1, "num_rounds must be a positive static int"

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,          # nbr_idx, self_idx, nbr_mask
        grid=(num_rounds, j_nodes),
        in_specs=[
            pl.BlockSpec((t_rows, d_feat), lambda r, j, *_: (0, 0)),  # θ0
            pl.BlockSpec((1, d_feat, d_feat), lambda r, j, *_: (j, 0, 0)),
            pl.BlockSpec((1, d_feat), lambda r, j, *_: (j, 0)),
            pl.BlockSpec((1, d_feat, d_feat), lambda r, j, *_: (j, 0, 0)),
            pl.BlockSpec((1, k_slots, d_feat, d_feat),
                         lambda r, j, *_: (j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d_feat), lambda r, j, *_: (j, 0)),
        scratch_shapes=[
            pltpu.VMEM((t_rows, d_feat), theta.dtype),   # even-round table
            pltpu.VMEM((t_rows, d_feat), theta.dtype),   # odd-round table
        ],
    )
    flops_per_node = 2 * (2 + k_slots) * d_feat * d_feat
    return pl.pallas_call(
        _dekrr_solve_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((j_nodes, d_feat), theta.dtype),
        cost_estimate=pl.CostEstimate(
            flops=num_rounds * j_nodes * flops_per_node,
            bytes_accessed=(t_rows * d_feat            # θ0, fetched once
                            + num_rounds * j_nodes
                            * ((3 + k_slots) * d_feat * d_feat + d_feat)
                            ) * theta.dtype.itemsize,
            transcendentals=0,
        ),
        interpret=interpret,
    )(nbr_idx, self_idx, nbr_mask, theta, g, d, s, p)


@functools.partial(jax.jit, static_argnames=("num_rounds", "interpret"))
def dekrr_solve_reference(g, d, s, p, theta, nbr_idx, self_idx, nbr_mask,
                          *, num_rounds: int, interpret: bool = False):
    """Pure-jnp oracle with the raw kernel's exact contract: scan the
    single-round oracle, scattering each round's new rows back into the
    θ table at `self_idx` (rows owned by no node stay at θ0) — what
    `tests/test_kernels_dekrr_solve.py` pins the kernel against before
    any repro.dist plumbing is involved."""
    del interpret

    def one_round(table, _):
        new = dekrr_step_reference(g, d, s, p, table, nbr_idx, self_idx,
                                   nbr_mask)
        return table.at[self_idx].set(new), None

    table, _ = jax.lax.scan(one_round, theta, None, length=num_rounds)
    return table[self_idx]
