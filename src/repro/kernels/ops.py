"""Jit'd public wrappers for the Pallas kernels.

Handles padding/alignment (TPU tiles: sublane 8, lane 128), validity
masking, and backend dispatch: on non-TPU backends the kernels execute in
``interpret=True`` mode (Python evaluation of the kernel body — bit-accurate
semantics, used for CPU validation against ref.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.rff import FeatureMap
from repro.kernels.rff_features import rff_features_pallas
from repro.kernels.rff_gram import rff_gram_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("scale", "block_n", "interpret"))
def rff_gram(omega: jax.Array, bias: jax.Array, x: jax.Array, y: jax.Array,
             *, scale: float, block_n: int = 1024,
             interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Fused streaming (Z Zᵀ, Z yᵀ) for Z = scale·cos(Ω X + b).

    omega [D, d], bias [D], x [d, N], y [N] → (G [D, D], zy [D]).
    """
    if interpret is None:
        interpret = _interpret_default()
    d_feat, n = omega.shape[0], x.shape[1]
    dtype = x.dtype

    bn = min(block_n, max(128, 1 << (n - 1).bit_length()))
    omega_p = _pad_to(_pad_to(omega, 0, 8), 1, 128)
    bias_p = _pad_to(bias.reshape(-1, 1), 0, 8).astype(dtype)
    x_p = _pad_to(_pad_to(x, 0, 128), 1, bn)
    n_pad = x_p.shape[1]
    mask = (jnp.arange(n_pad) < n).astype(dtype).reshape(1, n_pad)
    y_p = _pad_to(y.reshape(1, -1).astype(dtype), 1, bn)

    gram, zy = rff_gram_pallas(
        omega_p.astype(dtype), bias_p, x_p, y_p, mask,
        scale=scale, block_n=bn, interpret=interpret)
    return gram[:d_feat, :d_feat], zy[:d_feat, 0]


@partial(jax.jit, static_argnames=("scale", "block_d", "block_n",
                                   "interpret"))
def rff_features(omega: jax.Array, bias: jax.Array, x: jax.Array, *,
                 scale: float, block_d: int = 256, block_n: int = 512,
                 interpret: bool | None = None) -> jax.Array:
    """Fused Z = scale·cos(Ω X + b): omega [D, d], x [d, N] → Z [D, N]."""
    if interpret is None:
        interpret = _interpret_default()
    d_feat, n = omega.shape[0], x.shape[1]
    dtype = x.dtype

    bd = min(block_d, max(8, 1 << (d_feat - 1).bit_length()))
    bn = min(block_n, max(128, 1 << (n - 1).bit_length()))
    omega_p = _pad_to(_pad_to(omega, 0, bd), 1, 128).astype(dtype)
    bias_p = _pad_to(bias.reshape(-1, 1), 0, bd).astype(dtype)
    x_p = _pad_to(_pad_to(x, 0, 128), 1, bn)

    z = rff_features_pallas(omega_p, bias_p, x_p, scale=scale,
                            block_d=bd, block_n=bn, interpret=interpret)
    return z[:d_feat, :n]


@partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 cur_index: jax.Array, *, block_s: int = 512,
                 interpret: bool | None = None) -> jax.Array:
    """Single-token decode attention with the flash-decode kernel.

    q [B, 1, H, dh], k/v [B, S, K, dh] (GQA: H % K == 0), cur_index [] —
    returns [B, 1, H, dh]. Rows are (batch, kv-head) pairs; dh pads to 128,
    S pads to block_s (padded positions are masked by cur_index).
    """
    from repro.kernels.decode_attention import flash_decode_pallas

    if interpret is None:
        interpret = _interpret_default()
    out_dtype = q.dtype
    if q.dtype == jnp.float64:          # no f64 on TPU; x64-mode callers
        q = q.astype(jnp.float32)
        k_cache = k_cache.astype(jnp.float32)
        v_cache = v_cache.astype(jnp.float32)
    b, _, h, dh = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    scale = dh ** -0.5
    bs = min(block_s, max(128, 1 << (s - 1).bit_length()))

    # [B, 1, H, dh] → [B·K, G, dh]
    qr = q[:, 0].reshape(b, kh, g, dh).reshape(b * kh, g, dh)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(b * kh, s, dh)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(b * kh, s, dh)
    qr = _pad_to(qr, 2, 128)
    kr = _pad_to(_pad_to(kr, 1, bs), 2, 128)
    vr = _pad_to(_pad_to(vr, 1, bs), 2, 128)
    lens = jnp.broadcast_to(cur_index.astype(jnp.int32),
                            (b * kh, 1))
    out = flash_decode_pallas(qr, kr, vr, lens, scale=scale,
                              block_s=bs, interpret=interpret)
    out = out[:, :, :dh].reshape(b, kh, g, dh).reshape(b, 1, h, dh)
    return out.astype(out_dtype)


# ---------------------------------------------------------------- integration
def gram_fn_for_solver(fmap: FeatureMap, x: jax.Array) -> jax.Array:
    """Drop-in ``gram_fn`` for DeKRRSolver: computes Z(Ω, X) Z(Ω, X)ᵀ with the
    fused kernel (cos_bias maps only; f32)."""
    if fmap.kind != "cos_bias":
        raise NotImplementedError("fused gram kernel supports cos_bias maps")
    scale = float(jnp.sqrt(2.0 / fmap.num_frequencies))
    dtype = jnp.float32
    g, _ = rff_gram(fmap.omega.astype(dtype), fmap.bias.astype(dtype),
                    x.astype(dtype), jnp.zeros(x.shape[1], dtype),
                    scale=scale)
    return g.astype(x.dtype)
