"""Jit'd public wrappers for the Pallas kernels.

Handles padding/alignment (TPU tiles: sublane 8, lane 128), validity
masking, and backend dispatch: on non-TPU backends the kernels execute in
``interpret=True`` mode (Python evaluation of the kernel body — bit-accurate
semantics, used for CPU validation against ref.py).

Every wrapper also runs the static VMEM budget check from
`repro.analysis.vmem` at the *padded* shapes it is about to dispatch:
an over-budget call raises `VmemBudgetError` naming the working-set
formula and the 16 MiB limit before the kernel is built, instead of an
opaque Mosaic allocation crash. The DeKRR wrappers additionally
bounds-check concrete slot-index tables (scalar prefetch reads SMEM
indices with no hardware bounds check — see `check_index_table`).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.analysis.vmem import (check_index_table,
                                 estimate_dekrr_async_solve,
                                 estimate_dekrr_cheb_solve,
                                 estimate_dekrr_solve, estimate_dekrr_step,
                                 estimate_flash_decode,
                                 estimate_rff_features, estimate_rff_gram)
from repro.core.rff import FeatureMap
from repro.kernels.dekrr_solve import (dekrr_async_solve_pallas,
                                       dekrr_cheb_solve_pallas,
                                       dekrr_solve_pallas)
from repro.kernels.dekrr_step import dekrr_step_pallas
from repro.kernels.rff_features import rff_features_pallas
from repro.kernels.rff_gram import rff_gram_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_dim(n: int, multiple: int) -> int:
    return max(multiple, -(-int(n) // multiple) * multiple)


def _dekrr_dy(d) -> int:
    """Output width Dy of a DeKRR operand set: d/theta are [.., D] for
    scalar targets or [.., D, Dy] for multi-output."""
    return 1 if d.ndim == 2 else int(d.shape[2])


def _flatten_dy(a: jax.Array) -> jax.Array:
    """[T, D, Dy] → [T·Dy, D] flat-row layout (table row t owns the Dy
    consecutive rows [t·Dy, (t+1)·Dy), i.e. that node's θᵀ); identity for
    2-D scalar-target operands so the Dy = 1 trace is unchanged."""
    if a.ndim == 2:
        return a
    t, d_feat, dy = a.shape
    return a.transpose(0, 2, 1).reshape(t * dy, d_feat)


def _unflatten_dy(out: jax.Array, dy: int, d_feat: int,
                  ndim: int = 2) -> jax.Array:
    """Invert `_flatten_dy` on a kernel output. Scalar-layout operands
    (ndim == 2) take the exact old [:, :d_feat] slice; trailing-axis
    operands (ndim == 3) restore [J, d_feat, dy] — even at dy == 1, so a
    [.., 1] multi-output layout round-trips with its axis intact."""
    if ndim == 2:
        return out[:, :d_feat]
    j_nodes = out.shape[0] // dy
    return out.reshape(j_nodes, dy, -1)[:, :, :d_feat].transpose(0, 2, 1)


def _check_dekrr_budget(kernel: str, d, p, theta) -> None:
    """Static VMEM check at the padded dispatch shapes. Shapes are always
    static (works on tracers), so under jit this runs once at trace time
    and is free at execution time. Multi-output operands fold Dy into the
    flattened table/buffer row counts and the per-step vector term."""
    dy = _dekrr_dy(d)
    d_pad = _pad_dim(d.shape[1], 128)
    t_pad = _pad_dim(theta.shape[0] * dy, 8)
    k_pad = max(int(p.shape[1]), 1)
    j_pad = _pad_dim(d.shape[0] * dy, 8)
    size = jnp.dtype(d.dtype).itemsize
    if kernel == "dekrr_step":
        est = estimate_dekrr_step(t_rows=t_pad, d_feat=d_pad,
                                  k_slots=k_pad, itemsize=size, dy=dy)
    elif kernel == "dekrr_solve":
        est = estimate_dekrr_solve(t_rows=t_pad, d_feat=d_pad,
                                   k_slots=k_pad, itemsize=size, dy=dy)
    elif kernel == "dekrr_async_solve":
        est = estimate_dekrr_async_solve(
            t_rows=t_pad, b_rows=_pad_dim(d.shape[0] * k_pad * dy, 8),
            d_feat=d_pad, k_slots=k_pad, itemsize=size, dy=dy)
    elif kernel == "dekrr_cheb_solve":
        est = estimate_dekrr_cheb_solve(t_rows=t_pad, j_rows=j_pad,
                                        d_feat=d_pad, k_slots=k_pad,
                                        itemsize=size, dy=dy)
    else:  # pragma: no cover - programming error
        raise ValueError(f"unknown DeKRR kernel {kernel!r}")
    est.check()


def _check_dekrr_indices(theta, nbr_idx, self_idx, nbr_mask) -> None:
    """Bounds-check concrete slot tables against the θ-table row count;
    traced tables are validated at the staging layer instead
    (`repro.dist.pack_problem` / `pack_theta`)."""
    t_rows = int(theta.shape[0])
    if not isinstance(self_idx, jax.core.Tracer):
        check_index_table("self_idx", self_idx, t_rows)
    if isinstance(nbr_idx, jax.core.Tracer):
        return
    idx = jnp.asarray(nbr_idx)
    if idx.size and not isinstance(nbr_mask, jax.core.Tracer):
        import numpy as np

        live = np.asarray(nbr_mask) != 0
        if not live.any():
            return
        idx = np.asarray(idx)[live]
    check_index_table("nbr_idx", idx, t_rows)


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("scale", "block_n", "interpret"))
def rff_gram(omega: jax.Array, bias: jax.Array, x: jax.Array, y: jax.Array,
             *, scale: float, block_n: int = 1024,
             interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Fused streaming (Z Zᵀ, Z yᵀ) for Z = scale·cos(Ω X + b).

    omega [D, d], bias [D], x [d, N], y [N] → (G [D, D], zy [D]).
    """
    if interpret is None:
        interpret = _interpret_default()
    d_feat, n = omega.shape[0], x.shape[1]
    dtype = x.dtype

    bn = min(block_n, max(128, 1 << (n - 1).bit_length()))
    estimate_rff_gram(d_feat=_pad_dim(d_feat, 8),
                      d_in=_pad_dim(omega.shape[1], 128), block_n=bn,
                      itemsize=jnp.dtype(dtype).itemsize).check()
    omega_p = _pad_to(_pad_to(omega, 0, 8), 1, 128)
    bias_p = _pad_to(bias.reshape(-1, 1), 0, 8).astype(dtype)
    x_p = _pad_to(_pad_to(x, 0, 128), 1, bn)
    n_pad = x_p.shape[1]
    mask = (jnp.arange(n_pad) < n).astype(dtype).reshape(1, n_pad)
    y_p = _pad_to(y.reshape(1, -1).astype(dtype), 1, bn)

    gram, zy = rff_gram_pallas(
        omega_p.astype(dtype), bias_p, x_p, y_p, mask,
        scale=scale, block_n=bn, interpret=interpret)
    return gram[:d_feat, :d_feat], zy[:d_feat, 0]


@partial(jax.jit, static_argnames=("scale", "block_d", "block_n",
                                   "interpret"))
def rff_features(omega: jax.Array, bias: jax.Array, x: jax.Array, *,
                 scale: float, block_d: int = 256, block_n: int = 512,
                 interpret: bool | None = None) -> jax.Array:
    """Fused Z = scale·cos(Ω X + b): omega [D, d], x [d, N] → Z [D, N].

    The serving path's featurize kernel: its tiled working set
    (`Bd·d + Bd + d·Bn + Bd·Bn` elements) is checked against the VMEM
    budget before dispatch — over-budget tilings raise `VmemBudgetError`
    instead of a Mosaic allocation crash (`estimate_rff_features`).
    """
    if interpret is None:
        interpret = _interpret_default()
    d_feat, n = omega.shape[0], x.shape[1]
    dtype = x.dtype

    bd = min(block_d, max(8, 1 << (d_feat - 1).bit_length()))
    bn = min(block_n, max(128, 1 << (n - 1).bit_length()))
    estimate_rff_features(block_d=bd, d_in=_pad_dim(omega.shape[1], 128),
                          block_n=bn,
                          itemsize=jnp.dtype(dtype).itemsize).check()
    omega_p = _pad_to(_pad_to(omega, 0, bd), 1, 128).astype(dtype)
    bias_p = _pad_to(bias.reshape(-1, 1), 0, bd).astype(dtype)
    x_p = _pad_to(_pad_to(x, 0, 128), 1, bn)

    z = rff_features_pallas(omega_p, bias_p, x_p, scale=scale,
                            block_d=bd, block_n=bn, interpret=interpret)
    return z[:d_feat, :n]


@partial(jax.jit, static_argnames=("scale", "compute_dtype", "block_d",
                                   "block_n", "interpret"))
def rff_features_lowp(omega: jax.Array, bias: jax.Array, x: jax.Array, *,
                      scale: float, compute_dtype: str = "bfloat16",
                      block_d: int = 256, block_n: int = 512,
                      interpret: bool | None = None) -> jax.Array:
    """Low-precision serving featurize: Z = scale·cos(Ω X + b) with the
    GEMM and cosine evaluated in ``compute_dtype`` (bf16 by default) and
    the √(2/D) scale applied afterwards in f32.

    This is the mixed-precision serving tier's featurize entry point
    (`repro.serve.dekrr`, precision="bf16"/"int8"): queries run the
    feature map at half width while the solve stays x64. Returns Z in
    float32 regardless of compute dtype; the serving tier's analytic
    forward-error bound assumes exactly this arrangement (low-precision
    Ω/b/X/GEMM/cos, f32 scale multiply), so do not fold the scale into
    the low-precision kernel. Same tiling and VMEM pre-check as
    `rff_features` — at 2-byte elements the working set is half the f32
    path's.
    """
    cdt = jnp.dtype(compute_dtype)
    z = rff_features(omega.astype(cdt), bias.astype(cdt), x.astype(cdt),
                     scale=1.0, block_d=block_d, block_n=block_n,
                     interpret=interpret)
    return z.astype(jnp.float32) * jnp.float32(scale)


@partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 cur_index: jax.Array, *, block_s: int = 512,
                 interpret: bool | None = None) -> jax.Array:
    """Single-token decode attention with the flash-decode kernel.

    q [B, 1, H, dh], k/v [B, S, K, dh] (GQA: H % K == 0), cur_index [] —
    returns [B, 1, H, dh]. Rows are (batch, kv-head) pairs; dh pads to 128,
    S pads to block_s (padded positions are masked by cur_index).
    """
    from repro.kernels.decode_attention import flash_decode_pallas

    if interpret is None:
        interpret = _interpret_default()
    out_dtype = q.dtype
    if q.dtype == jnp.float64:          # no f64 on TPU; x64-mode callers
        q = q.astype(jnp.float32)
        k_cache = k_cache.astype(jnp.float32)
        v_cache = v_cache.astype(jnp.float32)
    b, _, h, dh = q.shape
    s, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    scale = dh ** -0.5
    bs = min(block_s, max(128, 1 << (s - 1).bit_length()))
    estimate_flash_decode(g_heads=g, head_dim=_pad_dim(dh, 128),
                          block_s=bs, itemsize=4).check()

    # [B, 1, H, dh] → [B·K, G, dh]
    qr = q[:, 0].reshape(b, kh, g, dh).reshape(b * kh, g, dh)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(b * kh, s, dh)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(b * kh, s, dh)
    qr = _pad_to(qr, 2, 128)
    kr = _pad_to(_pad_to(kr, 1, bs), 2, 128)
    vr = _pad_to(_pad_to(vr, 1, bs), 2, 128)
    lens = jnp.broadcast_to(cur_index.astype(jnp.int32),
                            (b * kh, 1))
    out = flash_decode_pallas(qr, kr, vr, lens, scale=scale,
                              block_s=bs, interpret=interpret)
    out = out[:, :, :dh].reshape(b, kh, g, dh).reshape(b, 1, h, dh)
    return out.astype(out_dtype)


def _pad_dekrr_operands(g, d, s, p, theta, nbr_idx, nbr_mask):
    """Shared operand padding for the DeKRR round/solve kernels: D to lane
    multiples of 128, the θ table to sublane multiples of 8, the slot axis
    to K ≥ 1 (an all-masked zero-P slot for edgeless graphs), index/mask
    tables coerced to int32. Multi-output d/theta ([.., D, Dy]) are first
    flattened to the kernels' [rows·Dy, D] layout (identity at Dy = 1).
    One helper so `dekrr_step` and `dekrr_solve` can never drift apart on
    the operand layout."""
    j_nodes = d.shape[0]
    g_p = _pad_to(_pad_to(g, 1, 128), 2, 128)
    s_p = _pad_to(_pad_to(s, 1, 128), 2, 128)
    d_p = _pad_to(_flatten_dy(d), 1, 128)
    p_p = _pad_to(_pad_to(p, 2, 128), 3, 128)
    if p_p.shape[1] == 0:                       # K = 0 (edgeless graph)
        p_p = jnp.zeros((j_nodes, 1) + p_p.shape[2:], p_p.dtype)
        nbr_idx = jnp.zeros((j_nodes, 1), jnp.int32)
        nbr_mask = jnp.zeros((j_nodes, 1), jnp.int32)
    theta_p = _pad_to(_pad_to(_flatten_dy(theta), 1, 128), 0, 8)
    return (g_p, d_p, s_p, p_p, theta_p, nbr_idx.astype(jnp.int32),
            (nbr_mask != 0).astype(jnp.int32))


@partial(jax.jit, static_argnames=("interpret",))
def _dekrr_step_jit(g, d, s, p, theta, nbr_idx, self_idx, nbr_mask,
                    active=None, *, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    d_feat = d.shape[1]
    dy = _dekrr_dy(d)

    g_p, d_p, s_p, p_p, theta_p, nbr_idx_p, nbr_mask_p = \
        _pad_dekrr_operands(g, d, s, p, theta, nbr_idx, nbr_mask)
    active_p = None if active is None else (active != 0).astype(jnp.int32)
    out = dekrr_step_pallas(
        g_p, d_p, s_p, p_p, theta_p,
        nbr_idx_p, self_idx.astype(jnp.int32), nbr_mask_p,
        active=active_p, dy=dy, interpret=interpret)
    return _unflatten_dy(out, dy, d_feat, d.ndim)


def dekrr_step(g: jax.Array, d: jax.Array, s: jax.Array, p: jax.Array,
               theta: jax.Array, nbr_idx: jax.Array, self_idx: jax.Array,
               nbr_mask: jax.Array, active: jax.Array | None = None, *,
               interpret: bool | None = None) -> jax.Array:
    """Fused packed Eq. 19 round: θ_j ← G_j(d_j + S_j θ_sj + Σ m P_jk θ_rk).

    g/s [J, D, D], d [J, D], p [J, K, D, D], theta [T, D] (θ table),
    nbr_idx [J, K] / self_idx [J] rows into the table, nbr_mask [J, K]
    (any dtype; nonzero = live slot) → [J, D]. Multi-output targets add
    a trailing axis: d [J, D, Dy] / theta [T, D, Dy] → [J, D, Dy]
    (internally flattened to the kernel's [rows·Dy, D] layout; the Dy = 1
    trace is today's scalar path, bit-for-bit).

    ``active`` ([J], any dtype, optional) runs the activation-masked async
    variant: nodes with active[j] == 0 return their θ-table row unchanged
    (`repro.dist.async_gossip`); with active omitted or all-ones the
    synchronous kernel arithmetic runs bit-for-bit.

    Pads D to lane multiples of 128, the θ table to sublane multiples of 8
    and the slot axis to K ≥ 1 (an all-masked zero-P slot), then slices the
    padding back off. Zero padding is exact under the round's algebra (see
    `repro.dist.dekrr_spmd`), so this matches `step_batched` to the last
    ulp-scale rounding of the reordered contractions (rtol 1e-9 under x64).

    VMEM working set at the padded shapes is `T·D + (2+K)·D² + 3·D`
    elements (consolidated table: `repro.analysis.vmem`); over-budget
    shapes raise `VmemBudgetError` here, before dispatch. Concrete
    (non-traced) `nbr_idx`/`self_idx` tables are bounds-checked against
    the θ-table row count.
    """
    _check_dekrr_budget("dekrr_step", d, p, theta)
    _check_dekrr_indices(theta, nbr_idx, self_idx, nbr_mask)
    return _dekrr_step_jit(g, d, s, p, theta, nbr_idx, self_idx, nbr_mask,
                           active, interpret=interpret)


@partial(jax.jit, static_argnames=("num_rounds", "trace", "interpret"))
def _dekrr_solve_jit(g, d, s, p, theta, nbr_idx, self_idx, nbr_mask, *,
                     num_rounds, trace=False, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    d_feat = d.shape[1]
    dy = _dekrr_dy(d)
    self_idx = self_idx.astype(jnp.int32)
    if num_rounds == 0:
        out0 = theta[self_idx]
        if trace:
            return out0, jnp.zeros((0, d.shape[0]), theta.dtype)
        return out0

    g_p, d_p, s_p, p_p, theta_p, nbr_idx_p, nbr_mask_p = \
        _pad_dekrr_operands(g, d, s, p, theta, nbr_idx, nbr_mask)
    out = dekrr_solve_pallas(
        g_p, d_p, s_p, p_p, theta_p, nbr_idx_p, self_idx, nbr_mask_p,
        num_rounds=num_rounds, dy=dy, trace=trace, interpret=interpret)
    if trace:
        out, res = out
        return _unflatten_dy(out, dy, d_feat, d.ndim), res
    return _unflatten_dy(out, dy, d_feat, d.ndim)


def dekrr_solve(g: jax.Array, d: jax.Array, s: jax.Array, p: jax.Array,
                theta: jax.Array, nbr_idx: jax.Array, self_idx: jax.Array,
                nbr_mask: jax.Array, *, num_rounds: int,
                trace: bool = False, interpret: bool | None = None
                ) -> jax.Array | tuple[jax.Array, jax.Array]:
    """Fused multi-round Eq. 19 solve: `num_rounds` Jacobi rounds in ONE
    pallas_call, θ tables VMEM-resident across rounds (grid = (R, J),
    `repro.kernels.dekrr_solve`).

    Same operand contract as `dekrr_step` — g/s [J, D, D], d [J, D],
    p [J, K, D, D], theta [T, D] θ table, nbr_idx [J, K] / self_idx [J]
    rows into the table, nbr_mask [J, K] — plus static `num_rounds`.
    Returns the [J, D] θ rows after the last round; table rows owned by
    no node stay at their θ0 values throughout (oracle semantics).
    Multi-output: d [J, D, Dy] / theta [T, D, Dy] → [J, D, Dy].

    Pads exactly like `dekrr_step` (D to 128 lanes, table to 8 sublanes,
    slot axis to K ≥ 1) and slices the padding back off; `num_rounds=0`
    returns the `self_idx` rows of θ unchanged.

    Static ``trace`` appends a res [R, J] convergence-trace array —
    res[r, j] = max|Δθ_j| of round r, written by the same grid steps
    (zero extra dispatches; `num_rounds=0` returns an empty [0, J]).

    VMEM working set at the padded shapes is `2·T·D + 2·(2+K)·D² + 3·D`
    elements — double the step kernel's θ/block terms for the
    round-parity scratch tables and double-buffered streams
    (consolidated table: `repro.analysis.vmem`); over-budget shapes
    raise `VmemBudgetError` here, before dispatch. Concrete
    `nbr_idx`/`self_idx` tables are bounds-checked against the θ-table
    row count.
    """
    if num_rounds != 0:
        _check_dekrr_budget("dekrr_solve", d, p, theta)
    _check_dekrr_indices(theta, nbr_idx, self_idx, nbr_mask)
    return _dekrr_solve_jit(g, d, s, p, theta, nbr_idx, self_idx, nbr_mask,
                            num_rounds=num_rounds, trace=trace,
                            interpret=interpret)


def _check_async_nbr_indices(j_nodes, nbr_idx, nbr_mask) -> None:
    """Async variant of `_check_dekrr_indices`: nbr_idx entries are NODE
    ids — they index the [J] SMEM broadcast-flag vectors as well as θ
    rows — so live slots must lie in [0, J), not merely within the padded
    θ table. Concrete tables only; traced ones are validated at the
    staging layer (`repro.dist.pack_problem`)."""
    if isinstance(nbr_idx, jax.core.Tracer):
        return
    import numpy as np

    idx = np.asarray(nbr_idx)
    if idx.size and not isinstance(nbr_mask, jax.core.Tracer):
        live = np.asarray(nbr_mask) != 0
        if not live.any():
            return
        idx = idx[live]
    check_index_table("nbr_idx", idx, j_nodes)


@partial(jax.jit, static_argnames=("gossip", "censored", "trace",
                                   "interpret"))
def _dekrr_async_solve_jit(g, d, s, p, theta, sent, buffers, nbr_idx,
                           nbr_mask, active_tab, thresholds, *, gossip,
                           censored, trace=False, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    j_nodes, d_feat = d.shape[0], d.shape[1]
    dy = _dekrr_dy(d)
    k_in = buffers.shape[1]
    num_rounds = active_tab.shape[0]

    g_p, d_p, s_p, p_p, theta_p, nbr_idx_p, nbr_mask_p = \
        _pad_dekrr_operands(g, d, s, p, theta, nbr_idx, nbr_mask)
    k_pad = p_p.shape[1]
    sent_p = _pad_to(_pad_to(_flatten_dy(sent), 1, 128), 0, 8)
    if k_in:
        buf = buffers
    else:
        tail = (d_feat,) if d.ndim == 2 else (d_feat, dy)
        buf = jnp.zeros((j_nodes, k_pad) + tail, buffers.dtype)
    if buf.ndim == 3:
        buf_flat = buf.reshape(j_nodes * k_pad, d_feat)
    else:
        buf_flat = buf.transpose(0, 1, 3, 2).reshape(
            j_nodes * k_pad * dy, d_feat)
    buf_p = _pad_to(_pad_to(buf_flat, 1, 128), 0, 8)
    outs = dekrr_async_solve_pallas(
        g_p, d_p, s_p, p_p, theta_p, sent_p, buf_p, nbr_idx_p, nbr_mask_p,
        (active_tab != 0).astype(jnp.int32), thresholds.astype(d.dtype),
        censored=censored, edge_gossip=(gossip == "edge"), dy=dy,
        trace=trace, interpret=interpret)
    out_theta, out_sent, out_buf = outs[:3]
    if d.ndim == 2:
        out_buf = out_buf.reshape(j_nodes, k_pad, -1)[:, :k_in, :d_feat]
    else:
        out_buf = out_buf.reshape(j_nodes, k_pad, dy, -1)[
            :, :k_in, :, :d_feat].transpose(0, 1, 3, 2)
    state = (_unflatten_dy(out_theta, dy, d_feat, d.ndim),
             _unflatten_dy(out_sent, dy, d_feat, d.ndim), out_buf)
    if trace:
        # Drop the delivery-flush row — it computes no round.
        res, bc = outs[3], outs[4]
        return state + (res[:num_rounds], bc[:num_rounds])
    return state


def dekrr_async_solve(g: jax.Array, d: jax.Array, s: jax.Array,
                      p: jax.Array, theta: jax.Array, sent: jax.Array,
                      buffers: jax.Array, nbr_idx: jax.Array,
                      nbr_mask: jax.Array, active_tab: jax.Array,
                      thresholds: jax.Array, *, gossip: str = "bernoulli",
                      censored: bool = False, trace: bool = False,
                      interpret: bool | None = None
                      ) -> tuple[jax.Array, ...]:
    """Fused async-gossip chain: the whole R-round COKE schedule in ONE
    pallas_call (`repro.kernels.dekrr_solve._dekrr_async_solve_kernel`).

    Same block contract as `dekrr_step` — g/s [J, D, D], d [J, D],
    p [J, K, D, D], nbr_idx/nbr_mask [J, K] — but θ indexing is by node
    id (row j = node j, no self_idx indirection): theta/sent [J, D],
    buffers [J, K, D] staleness buffers (slot (j, k) holds the last θ
    received from nbr_idx[j, k]). The precomputed schedule is
    active_tab [R, J] (nonzero = node active in that round) and
    thresholds [R] (censor thresholds; ignored when ``censored`` is
    False). ``gossip`` ∈ {"bernoulli", "edge"} selects whether delivery
    additionally requires the receiver active (edge gossip).

    Returns the post-schedule (theta [J, D], sent [J, D],
    buffers [J, K, D]) — exactly the `AsyncGossipState` fields, so chunked
    callers chain bit-exactly. R = 0 returns the state unchanged.
    Multi-output: d/theta/sent gain a trailing Dy axis and buffers become
    [J, K, D, Dy]; the in-kernel censor reduction runs over features AND
    outputs, matching `repro.dist.async_gossip`.

    Static ``trace`` appends (res [R, J] float, bc [R, J] int32) —
    per-(round, node) max|Δθ| and broadcast flags (0/0 for inactive
    nodes), written by the same grid steps (zero extra dispatches;
    R = 0 returns empty [0, J] arrays). The caller derives the wire
    series (deliveries, bytes) from bc + the slot tables in plain XLA.

    The in-kernel round replays `repro.dist.async_gossip._async_round`'s
    operation sequence, so the chain is bit-for-bit the scanned per-round
    masked kernel (and, at p = 1 uncensored, the sync fused solve).

    VMEM working set at the padded shapes is
    `5·T·D + 2·B·D + 2·(2+K)·D² + 3·D` elements (B = J·K buffer rows;
    consolidated table: `repro.analysis.vmem`); over-budget shapes raise
    `VmemBudgetError` here, before dispatch.
    """
    if gossip not in ("bernoulli", "edge"):
        raise ValueError(f"gossip must be 'bernoulli' or 'edge', "
                         f"got {gossip!r}")
    _check_async_nbr_indices(int(d.shape[0]), nbr_idx, nbr_mask)
    if int(active_tab.shape[0]) == 0:
        if trace:
            j_nodes = int(d.shape[0])
            return (theta, sent, buffers,
                    jnp.zeros((0, j_nodes), theta.dtype),
                    jnp.zeros((0, j_nodes), jnp.int32))
        return theta, sent, buffers
    _check_dekrr_budget("dekrr_async_solve", d, p, theta)
    return _dekrr_async_solve_jit(
        g, d, s, p, theta, sent, buffers, nbr_idx, nbr_mask, active_tab,
        thresholds, gossip=gossip, censored=censored, trace=trace,
        interpret=interpret)


@partial(jax.jit, static_argnames=("trace", "interpret"))
def _dekrr_cheb_solve_jit(g, d, s, p, theta, delta, nbr_idx, self_idx,
                          nbr_mask, alphas, betas, *, trace=False,
                          interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    d_feat = d.shape[1]
    dy = _dekrr_dy(d)

    g_p, d_p, s_p, p_p, theta_p, nbr_idx_p, nbr_mask_p = \
        _pad_dekrr_operands(g, d, s, p, theta, nbr_idx, nbr_mask)
    delta_p = _pad_to(_pad_to(_flatten_dy(delta), 1, 128), 0, 8)
    outs = dekrr_cheb_solve_pallas(
        g_p, d_p, s_p, p_p, theta_p, delta_p, nbr_idx_p,
        self_idx.astype(jnp.int32), nbr_mask_p,
        alphas.astype(d.dtype), betas.astype(d.dtype), dy=dy,
        trace=trace, interpret=interpret)
    out = (_unflatten_dy(outs[0], dy, d_feat, d.ndim),
           _unflatten_dy(outs[1], dy, d_feat, d.ndim))
    if trace:
        return out + (outs[2],)
    return out


def dekrr_cheb_solve(g: jax.Array, d: jax.Array, s: jax.Array,
                     p: jax.Array, theta: jax.Array, delta: jax.Array,
                     nbr_idx: jax.Array, self_idx: jax.Array,
                     nbr_mask: jax.Array, alphas: jax.Array,
                     betas: jax.Array, *, trace: bool = False,
                     interpret: bool | None = None
                     ) -> tuple[jax.Array, ...]:
    """Fused Chebyshev semi-iteration: R accelerated Eq. 19 rounds in ONE
    pallas_call (`repro.kernels.dekrr_solve._dekrr_cheb_solve_kernel`).

    Same operand contract as `dekrr_solve` — g/s [J, D, D], d [J, D],
    p [J, K, D, D], theta [T, D] θ table, nbr_idx [J, K] / self_idx [J]
    rows into the table, nbr_mask [J, K] — plus delta [J, D] (each node's
    two-term recurrence direction state p, with Δ_k = α_k p_k) and the
    precomputed [R] (α, β) schedule from
    `repro.core.acceleration.chebyshev_coefficients` (R static via
    the schedule length). Returns the (θ rows [J, D], p rows [J, D])
    after the schedule, so chunked callers chain bit-exactly; R = 0
    returns (theta[self_idx], delta) unchanged. Multi-output:
    d/theta/delta gain a trailing Dy axis → ([J, D, Dy], [J, D, Dy]).

    Static ``trace`` appends res [R, J] — per-(round, node) max|Δθ| of
    the accelerated update (the actual step α_r p, not the F-residual),
    written by the same grid steps (zero extra dispatches; R = 0 returns
    an empty [0, J]).

    VMEM working set at the padded shapes is
    `3·T·D + 2·J'·D + 2·(2+K)·D² + 3·D` elements (consolidated table:
    `repro.analysis.vmem`); over-budget shapes raise `VmemBudgetError`
    here, before dispatch.
    """
    if int(alphas.shape[0]) == 0:
        if trace:
            return (theta[self_idx], delta,
                    jnp.zeros((0, int(d.shape[0])), theta.dtype))
        return theta[self_idx], delta
    _check_dekrr_budget("dekrr_cheb_solve", d, p, theta)
    _check_dekrr_indices(theta, nbr_idx, self_idx, nbr_mask)
    return _dekrr_cheb_solve_jit(g, d, s, p, theta, delta, nbr_idx,
                                 self_idx, nbr_mask, alphas, betas,
                                 trace=trace, interpret=interpret)


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def rff_gram_batched(omega: jax.Array, bias: jax.Array, x: jax.Array,
                     y: jax.Array, col_mask: jax.Array, *,
                     block_n: int = 1024,
                     interpret: bool | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """vmapped fused streaming Gram over a leading node axis (cos_bias, the
    unit-scale form): omega [J, F, d], bias [J, F], x [J, d, N], y [J, N],
    col_mask [J, N] → (gram [J, F, F], zy [J, F]) with Z = cos(Ω X + b).

    The per-node √(2/D_j) scale is *not* applied (it is a per-node constant,
    which a single pallas_call cannot close over) — callers fold it in as
    s_j²·gram / s_j·zy. Rows of padded frequencies come out as cos(0) = 1
    and must be masked by the caller; padded *columns* are masked here.
    Used by `repro.dist.pack_problem` for the batched Eq. 17 Z Zᵀ blocks.
    """
    if interpret is None:
        interpret = _interpret_default()
    f_feat, n = omega.shape[1], x.shape[2]

    bn = min(block_n, max(128, 1 << (n - 1).bit_length()))
    estimate_rff_gram(d_feat=_pad_dim(f_feat, 8),
                      d_in=_pad_dim(omega.shape[2], 128), block_n=bn,
                      itemsize=jnp.dtype(x.dtype).itemsize).check()
    omega_p = _pad_to(_pad_to(omega, 1, 8), 2, 128).astype(x.dtype)
    bias_p = _pad_to(bias[..., None], 1, 8).astype(x.dtype)
    x_p = _pad_to(_pad_to(x, 1, 128), 2, bn)
    y_p = _pad_to(y[:, None, :].astype(x.dtype), 2, bn)
    mask_p = _pad_to(col_mask[:, None, :].astype(x.dtype), 2, bn)

    gram, zy = jax.vmap(
        partial(rff_gram_pallas, scale=1.0, block_n=bn, interpret=interpret)
    )(omega_p, bias_p, x_p, y_p, mask_p)
    return gram[:, :f_feat, :f_feat], zy[:, :f_feat, 0]


# ---------------------------------------------------------------- integration
def gram_fn_for_solver(fmap: FeatureMap, x: jax.Array) -> jax.Array:
    """Drop-in ``gram_fn`` for DeKRRSolver: computes Z(Ω, X) Z(Ω, X)ᵀ with the
    fused kernel (cos_bias maps only; f32)."""
    if fmap.kind != "cos_bias":
        raise NotImplementedError("fused gram kernel supports cos_bias maps")
    scale = float(jnp.sqrt(2.0 / fmap.num_frequencies))
    dtype = jnp.float32
    g, _ = rff_gram(fmap.omega.astype(dtype), fmap.bias.astype(dtype),
                    x.astype(dtype), jnp.zeros(x.shape[1], dtype),
                    scale=scale)
    return g.astype(x.dtype)
