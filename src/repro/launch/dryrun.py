import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# The dry run compiles against placeholder *host* devices by construction;
# never let a TPU-enabled jaxlib spend minutes probing for real hardware.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, record memory/cost/collective analysis.

MUST be run as its own process (the two lines above force 512 placeholder
CPU devices BEFORE jax initializes — never import this module from tests).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1_5_0_5b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import traceback

import jax

from repro.configs import INPUT_SHAPES, get_arch, list_archs
from repro.launch.hlo_analysis import analyze_compiled, model_flops_per_step
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import ShardingRules
from repro.launch.specs import input_specs
from repro.models.model import active_param_count, analytic_param_count
from repro.obs.metrics import perf_clock


def run_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
             mesh_grid: tuple[int, int] = (16, 16),
             out_dir: str | None = None, verbose: bool = True) -> dict:
    spec = get_arch(arch)
    plan = spec.shape_plan(shape_name)
    data_ax, model_ax = mesh_grid
    mesh_name = (f"2x{data_ax}x{model_ax}" if multi_pod
                 else f"{data_ax}x{model_ax}")
    result: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "plan": plan}
    if plan == "skip":
        result["status"] = "skip"
        _write(result, out_dir)
        return result

    t0 = perf_clock()
    mesh = make_production_mesh(multi_pod=multi_pod, data=data_ax,
                                model=model_ax)
    rules = ShardingRules(mesh)
    shape = INPUT_SHAPES[shape_name]

    # ONE compile — the deployment artifact (scanned layers, buffer reuse).
    # memory_analysis proves it fits; flops/bytes/collectives come from the
    # trip-count-aware HLO analyzer (see hlo_analysis.py) so scanned layers
    # are counted at full depth.
    pair = input_specs(spec, shape_name, rules)
    cfg = pair["cfg"]
    with mesh:
        kw = {}
        if pair.get("out_shardings") is not None:
            kw["out_shardings"] = pair["out_shardings"]
        lowered = jax.jit(
            pair["fn"], in_shardings=pair["in_shardings"],
            donate_argnums=pair["donate_argnums"], **kw,
        ).lower(*pair["args"])
        t_lower = perf_clock() - t0
        compiled = lowered.compile()
        t_compile = perf_clock() - t0 - t_lower

    roof = analyze_compiled(compiled)
    mem_dep = compiled.memory_analysis()
    n_total = analytic_param_count(cfg)
    n_active = active_param_count(cfg)
    mf = model_flops_per_step(cfg, shape, n_active)
    chips = mesh.devices.size
    hlo_total_flops = roof.flops * chips

    result.update(
        status="ok",
        chips=chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        params_total=n_total,
        params_active=n_active,
        model_flops=mf,
        hlo_total_flops=hlo_total_flops,
        useful_flops_ratio=(mf / hlo_total_flops if hlo_total_flops else 0),
        **roof.as_dict(),
    )
    result["memory_analysis"] = {
        "argument_size": mem_dep.argument_size_in_bytes,
        "output_size": mem_dep.output_size_in_bytes,
        "temp_size": mem_dep.temp_size_in_bytes,
        "alias_size": mem_dep.alias_size_in_bytes,
        "generated_code_size": mem_dep.generated_code_size_in_bytes,
    }
    if verbose:
        print(f"[{mesh_name}] {arch} × {shape_name} ({plan}): "
              f"compile {t_compile:.0f}s  "
              f"mem/dev {(result['peak_memory_per_device'] or 0)/2**30:.2f}GiB  "
              f"compute {roof.compute_s*1e3:.2f}ms  "
              f"memory {roof.memory_s*1e3:.2f}ms  "
              f"collective {roof.collective_s*1e3:.2f}ms  "
              f"→ {roof.dominant}-bound  "
              f"useful-flops {result['useful_flops_ratio']:.2f}")
    _write(result, out_dir)
    return result


def _write(result: dict, out_dir: str | None):
    if not out_dir:
        return
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"{result['mesh']}_{result['arch']}_{result['shape']}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=2, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", default="16x16",
                    help="data x model grid, e.g. 16x16 (production) or 4x4 "
                         "(smoke; pair with DRYRUN_XLA_FLAGS device count)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    try:
        mesh_grid = tuple(int(v) for v in args.mesh.split("x"))
    except ValueError:
        mesh_grid = ()
    if len(mesh_grid) != 2:
        ap.error(f"--mesh must be DxM (e.g. 4x4), got {args.mesh!r}")
    if "DRYRUN_XLA_FLAGS" not in os.environ:
        # keep the placeholder platform in lockstep with --mesh; this runs
        # before any jax device query, so the module-top default is replaced
        need = (2 if args.multi_pod else 1) * mesh_grid[0] * mesh_grid[1]
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={need}")

    pairs: list[tuple[str, str]] = []
    if args.all:
        pairs = [(a, s) for a in list_archs() for s in INPUT_SHAPES]
    else:
        archs = [args.arch] if args.arch else list_archs()
        shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
        pairs = [(a, s) for a in archs for s in shapes]

    failures = []
    for arch, shape in pairs:
        try:
            run_pair(arch, shape, multi_pod=args.multi_pod,
                     mesh_grid=mesh_grid, out_dir=args.out)
        except Exception as e:  # noqa: BLE001 — report every pair
            failures.append((arch, shape, repr(e)))
            print(f"FAILED {arch} × {shape}: {e}")
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} pair(s) failed:")
        for a, s, e in failures:
            print(f"  {a} × {s}: {e}")
        raise SystemExit(1)
    print("\nall pairs lowered + compiled OK")


if __name__ == "__main__":
    main()
