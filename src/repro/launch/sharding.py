"""Sharding rules mapping every parameter / activation / cache tensor to a
PartitionSpec on the production mesh.

Logical axes:
  fsdp  — parameter shards over the data(-parallel) axes (pod, data): ZeRO-3
          style; required to fit jamba-398B optimizer state in 16 GB/chip.
  tp    — tensor parallel over the `model` axis: attention heads (flat
          head·dim), FFN hidden, vocab, MoE expert dim, SSM/RWKV inner dims.
  dp    — batch over (pod, data).

Divisibility fallback: any dim not divisible by its mesh axis size degrades
to replication for that dim (e.g. smollm's 9 heads on a 16-way model axis);
GSPMD then inserts the necessary collectives. This is the BASELINE policy —
§Perf iterates on it.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

# per-param logical axes, applied to the *trailing* dims (a leading group-
# stack axis is auto-prepended for slot params).
_PARAM_RULES: dict[str, tuple[str | None, ...]] = {
    "embed": ("tp", "fsdp"),
    "lm_head": ("fsdp", "tp"),
    "final_norm": (None,),
    # attention
    "wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    "bq": ("tp",), "bk": ("tp",), "bv": ("tp",),
    # dense ffn
    "w_gate": ("fsdp", "tp"), "w_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    "b_up": ("tp",), "b_down": (None,),
    # moe
    "router": ("fsdp", None),
    "moe_gate": ("tp", "fsdp", None), "moe_up": ("tp", "fsdp", None),
    "moe_down": ("tp", None, "fsdp"),
    "sh_gate": ("fsdp", "tp"), "sh_up": ("fsdp", "tp"),
    "sh_down": ("tp", "fsdp"),
    # mamba
    "in_x": ("fsdp", "tp"), "in_z": ("fsdp", "tp"), "out": ("tp", "fsdp"),
    "conv_w": (None, "tp"),
    "dt_down": ("tp", None), "dt_up": (None, "tp"),
    "dt_bias": ("tp",), "d_skip": ("tp",),
    "w_b": ("tp", None), "w_c": ("tp", None), "a_log": ("tp", None),
    # rwkv
    "wr": ("fsdp", "tp"), "wk_t": ("fsdp", "tp"), "wv_t": ("fsdp", "tp"),
    "wg": ("fsdp", "tp"),
    "wa": ("fsdp", None), "wb": (None, "tp"),
    "w0": (None,), "u": ("tp", None), "gn": (None,),
    "mu_r": (None,), "mu_k": (None,), "mu_v": (None,), "mu_w": (None,),
    "mu_g": (None,), "mu_c": (None,),
    "cm_r": ("fsdp", "tp"), "cm_k": ("fsdp", "tp"), "cm_v": ("tp", "fsdp"),
    # norms
    "norm_mix": (None,), "norm_ffn": (None,),
}


class ShardingRules:
    """policy:
      "tp"    — baseline: FSDP over (pod, data) + tensor parallel over model.
      "dp"    — pure data parallelism: the model axis joins the batch axes,
                weights replicate over it (FSDP still over (pod, data)).
                Wins for small models where TP output all-reduces dominate
                (§Perf iteration 2).
      "serve" — inference: params shard over `model` only (no FSDP — there
                is no optimizer state, and per-step FSDP all-gathers are
                pure overhead at decode batch sizes; §Perf iteration log,
                qwen-32B decode)."""

    def __init__(self, mesh: Mesh, policy: str = "tp"):
        self.mesh = mesh
        self.policy = policy
        names = mesh.axis_names
        dp = [a for a in ("pod", "data") if a in names]
        if policy == "dp" and "model" in names:
            dp.append("model")
            self.tp_axis = None
        else:
            self.tp_axis = "model" if "model" in names else None
        self.dp_axes = tuple(dp)
        self.fsdp_axes = () if policy == "serve" else tuple(
            a for a in ("pod", "data") if a in names)
        self.dp_size = int(np.prod([mesh.shape[a] for a in self.dp_axes])) \
            if self.dp_axes else 1
        self.fsdp_size = int(np.prod(
            [mesh.shape[a] for a in self.fsdp_axes])) if self.fsdp_axes \
            else 1
        self.tp_size = mesh.shape[self.tp_axis] if self.tp_axis else 1

    def with_policy(self, policy: str) -> "ShardingRules":
        return ShardingRules(self.mesh, policy=policy)

    # ---- helpers -------------------------------------------------------------
    def _resolve(self, logical: str | None, dim: int):
        if logical is None:
            return None
        if logical == "fsdp":
            if self.fsdp_axes and dim % self.fsdp_size == 0:
                return self.fsdp_axes if len(self.fsdp_axes) > 1 \
                    else self.fsdp_axes[0]
            return None
        if logical == "tp":
            if self.tp_axis and dim % self.tp_size == 0:
                return self.tp_axis
            return None
        raise ValueError(logical)

    def spec_for(self, rule: tuple, shape: tuple, stacked: bool) -> P:
        trailing = shape[1:] if stacked else shape
        assert len(rule) == len(trailing), (rule, shape)
        axes = [self._resolve(r, d) for r, d in zip(rule, trailing)]
        if stacked:
            axes = [None] + axes
        return P(*axes)

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # ---- params / optimizer ----------------------------------------------------
    def params_specs(self, params_shape: Pytree) -> Pytree:
        def leaf_spec(path, leaf):
            name = None
            stacked = False
            for part in path:
                key = getattr(part, "key", None)
                if key is not None:
                    name = key
                    stacked = str(path[0].key).startswith("slot") \
                        if hasattr(path[0], "key") else False
            stacked = str(getattr(path[0], "key", "")).startswith("slot")
            rule = _PARAM_RULES.get(name)
            if rule is None or len(rule) != len(
                    leaf.shape[1:] if stacked else leaf.shape):
                return P()          # replicate unknowns
            return self.spec_for(rule, leaf.shape, stacked)

        return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)

    def opt_specs(self, opt_shape: Pytree, params_specs_tree: Pytree
                  ) -> Pytree:
        return {
            "m": params_specs_tree,
            "v": params_specs_tree,
            "step": P(),
        }

    # ---- activations / batches ---------------------------------------------------
    def dp_spec(self) -> Any:
        if not self.dp_axes:
            return None
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def batch_specs(self, batch_shape: dict, global_batch: int) -> dict:
        dp = self.dp_spec() if global_batch % self.dp_size == 0 else None
        out = {}
        for k, v in batch_shape.items():
            if v.ndim == 2:
                out[k] = P(dp, None)
            elif v.ndim == 3:                     # embeds [B, S, d]
                out[k] = P(dp, None, None)
            else:
                out[k] = P()
        return out

    # ---- decode cache ----------------------------------------------------------
    def cache_specs(self, cache_shape: Pytree, batch: int) -> Pytree:
        """KV caches [g, B, S, K, dh]; states [g, B, ...]. Batch goes to dp
        when divisible, otherwise the sequence / inner dim is sharded
        (context parallelism for long_500k B=1)."""
        dp = self.dp_spec()
        batch_on_dp = dp is not None and batch % self.dp_size == 0

        def leaf_spec(path, leaf):
            name = None
            for part in path:
                key = getattr(part, "key", None)
                if key is not None:
                    name = key
            shape = leaf.shape
            if name in ("k", "v"):                 # [g, B, S, K, dh]
                kv_heads, seq = shape[3], shape[2]
                tp = self.tp_axis if (self.tp_axis and
                                      kv_heads % self.tp_size == 0) else None
                if batch_on_dp:
                    # kv heads not tp-divisible (MHA like qwen-32b, or
                    # kv < tp): shard the SEQUENCE dim over `model` instead
                    # of replicating the cache (flash-decode layout) —
                    # decode attention partitions cleanly over kv chunks.
                    seq_tp = (self.tp_axis
                              if (tp is None and self.tp_axis
                                  and seq % self.tp_size == 0) else None)
                    return P(None, dp, seq_tp, tp, None)
                seq_dp = dp if (dp and seq % self.dp_size == 0) else None
                return P(None, None, seq_dp, tp, None)
            if name in ("k_scale", "v_scale"):     # [g, B, S, K]
                seq = shape[2]
                if batch_on_dp:
                    seq_tp = (self.tp_axis if (self.tp_axis and
                              seq % self.tp_size == 0) else None)
                    # scales follow the cache's seq sharding when kv heads
                    # aren't tp-divisible (qwen-32b layout)
                    kv_tp = (self.tp_axis if shape[3] % self.tp_size == 0
                             else None)
                    return P(None, dp, None if kv_tp else seq_tp, kv_tp)
                seq_dp = dp if (dp and seq % self.dp_size == 0) else None
                return P(None, None, seq_dp, None)
            if name == "pos":                      # [g, W]
                return P(None, None)
            if name == "state" and len(shape) == 5:  # rwkv [g,B,H,dh,dh]
                tp = self.tp_axis if shape[2] % self.tp_size == 0 else None
                return P(None, dp if batch_on_dp else None, tp, None, None)
            if name == "state":                    # mamba [g, B, di, N]
                tp = self.tp_axis if shape[2] % self.tp_size == 0 else None
                return P(None, dp if batch_on_dp else None, tp, None)
            if name == "conv":                     # [g, B, K-1, di]
                tp = self.tp_axis if shape[3] % self.tp_size == 0 else None
                return P(None, dp if batch_on_dp else None, None, tp)
            if name in ("shift_t", "shift_c"):     # [g, B, d]
                return P(None, dp if batch_on_dp else None, None)
            return P()

        return jax.tree_util.tree_map_with_path(leaf_spec, cache_shape)


def make_shardings(rules: ShardingRules, spec_tree: Pytree) -> Pytree:
    return jax.tree.map(
        lambda s: NamedSharding(rules.mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
