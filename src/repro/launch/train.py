"""Training launcher.

CPU-scale run (default): a reduced variant of the selected architecture on
synthetic tokens — the end-to-end driver used by examples/train_lm.py.
Production mesh runs pass --mesh single|multi on real hardware (the same
code path the dry-run lowers).

  PYTHONPATH=src python -m repro.launch.train --arch smollm_135m \
      --steps 200 --batch 8 --seq 256 [--full] [--mesh single]
"""
from __future__ import annotations

import argparse

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (needs real accelerators)")
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.data.tokens import SyntheticTokenPipeline, TokenPipelineConfig
    from repro.train.loop import train_loop
    from repro.train.optim import AdamWConfig

    spec = get_arch(args.arch)
    cfg = spec.config if args.full else spec.config.reduced()
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"vocab={cfg.vocab_size} ({'full' if args.full else 'reduced'})")

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 5))
    pipe = SyntheticTokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, batch_size=args.batch,
        seq_len=args.seq, seed=args.seed))

    state, history = train_loop(
        cfg, opt_cfg, iter(pipe), args.steps, seed=args.seed,
        ckpt_path=args.ckpt)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.4f} → {last:.4f} "
          f"({100 * (first - last) / first:.1f}% reduction)")


if __name__ == "__main__":
    main()
