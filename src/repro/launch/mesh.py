"""Production meshes.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  512 chips as (pod=2, data=16, model=16) — the `pod` axis carries
data parallelism across the inter-pod (DCN/ICI-extended) links; parameters
FSDP over (pod, data).

Defined as functions so importing this module never touches jax device
state (the dry-run forces a 512-device host platform *before* jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False,
                         data: int = 16, model: int = 16):
    """(data, model) default to the production 16×16 pod; smoke tests pass a
    smaller grid (e.g. 4×4) to exercise the identical SPMD pipeline cheaply."""
    shape = (2, data, model) if multi_pod else (data, model)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_abstract_mesh(shape, axes):
    """Device-free AbstractMesh across jax versions: 0.4.x takes a tuple of
    (name, size) pairs, newer jax takes (axis_sizes, axis_names)."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def make_cpu_mesh(num_devices: int | None = None, axis: str = "nodes"):
    """1-D mesh over however many (host) devices exist — used by the
    decentralized DeKRR runtime."""
    import numpy as np

    devs = jax.devices()
    if num_devices is not None:
        devs = devs[:num_devices]
    from jax.sharding import Mesh
    return Mesh(np.array(devs), (axis,))


# TPU v5e hardware constants (roofline; per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BANDWIDTH = 819e9           # B/s
ICI_LINK_BANDWIDTH = 50e9       # B/s per link
