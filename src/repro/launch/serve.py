"""Serving launcher: batched greedy decoding with a KV/state cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1_5_0_5b \
      --batch 4 --prompt-len 32 --gen 64
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.obs.metrics import perf_clock


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_0_5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_arch
    from repro.models.model import Model

    spec = get_arch(args.arch)
    if not spec.supports_decode:
        raise SystemExit(f"{args.arch} is encoder-only — no decode path")
    cfg = spec.config if args.full else spec.config.reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)

    b = args.batch
    max_len = args.prompt_len + args.gen
    prompt = jax.random.randint(jax.random.PRNGKey(1), (b, args.prompt_len),
                                0, cfg.vocab_size)
    cache = model.init_cache(b, max_len)
    step = jax.jit(model.decode_step)

    # prefill via repeated decode (teacher forcing the prompt)
    t0 = perf_clock()
    tok = None
    for t in range(args.prompt_len):
        logits, cache = step(params, cache, prompt[:, t:t + 1],
                             jnp.asarray(t, jnp.int32))
    out = []
    tok = jnp.argmax(logits, axis=-1)[:, None]
    for t in range(args.prompt_len, max_len):
        out.append(tok)
        logits, cache = step(params, cache, tok, jnp.asarray(t, jnp.int32))
        tok = jnp.argmax(logits, axis=-1)[:, None]
    dt = perf_clock() - t0
    gen = jnp.concatenate(out, axis=1)
    toks_per_s = b * max_len / dt
    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"({toks_per_s:.1f} tok/s incl. prefill)")
    print("first sequence:", gen[0, :16].tolist(), "...")


if __name__ == "__main__":
    main()
