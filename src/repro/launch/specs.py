"""Input ShapeDtypeStruct builders for every (arch × input shape) pair —
weak-type-correct, shardable, zero allocation — plus the per-pair step
function and sharding assembly used by the dry-run and the launchers."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.registry import INPUT_SHAPES, ArchSpec, ShapeSpec
from repro.launch.sharding import ShardingRules, make_shardings
from repro.models.model import Model, ModelConfig, SlotSpec
from repro.train.optim import AdamWConfig
from repro.train.step import (TrainState, make_prefill, make_serve_step,
                              make_train_step, train_state_init)

Pytree = Any


def variant_config(spec: ArchSpec, shape_name: str, *,
                   rules: ShardingRules | None = None) -> ModelConfig:
    """The lowered configuration for a pair: bf16, optional SWA long-context
    variant, activation sharding for training shapes."""
    plan = spec.shape_plan(shape_name)
    if plan == "skip":
        raise ValueError(f"{spec.config.name} skips {shape_name}")
    cfg = spec.config
    overrides: dict[str, Any] = dict(
        param_dtype="bfloat16", compute_dtype="bfloat16")
    if plan == "run-swa":
        overrides["slots"] = tuple(
            SlotSpec("swa" if s.mixer == "attn" else s.mixer, s.ffn)
            for s in cfg.slots)
        overrides["sliding_window"] = spec.long_context_window
    shape = INPUT_SHAPES[shape_name]
    if rules is not None:
        dp = (rules.dp_spec()
              if shape.global_batch % max(rules.dp_size, 1) == 0 else None)
        if shape.kind in ("train", "prefill"):
            seq_ax = (rules.tp_axis
                      if shape.seq_len % max(rules.tp_size, 1) == 0 else None)
            overrides["act_shard"] = (dp, seq_ax, None)
        else:                                 # decode: [B, 1, d]
            overrides["act_shard"] = (dp, None, None)
            # int8 KV cache when the bf16 cache would not fit per device
            attn_layers = sum(s.mixer == "attn" for s in cfg.slots) \
                * cfg.num_layers // max(cfg.period, 1)
            cache_bytes = (2 * attn_layers * shape.global_batch
                           * shape.seq_len * cfg.num_kv_heads * cfg.hd * 2)
            per_dev = cache_bytes / (rules.dp_size * rules.tp_size)
            if per_dev > 8 * 2**30:
                overrides["kv_cache_dtype"] = "int8"
        if cfg.moe_num_experts:
            # one dispatch group per data shard (shard-local capacity);
            # falls back to 1 group when the token count doesn't divide
            overrides["moe_groups"] = rules.dp_size if dp is not None else 1
            overrides["moe_shard"] = (dp, rules.tp_axis)
    # moment dtype decided by the launcher (see opt_config_for)
    return dataclasses.replace(cfg, **overrides)


def opt_config_for(cfg: ModelConfig) -> AdamWConfig:
    """bf16 moments for the very largest config so optimizer state fits
    16 GB/chip (documented in DESIGN.md §6)."""
    from repro.models.model import analytic_param_count

    big = analytic_param_count(cfg) > 1e11
    return AdamWConfig(total_steps=10000,
                       moment_dtype="bfloat16" if big else "float32")


# --------------------------------------------------------------- input specs
def _tok(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def train_batch_struct(spec: ArchSpec, cfg: ModelConfig,
                       shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if spec.input_kind == "audio":
        return {
            "embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.cdt),
            "targets": _tok(b, s),
            "loss_mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
        }
    if spec.input_kind == "vlm":
        s_img = 1024                       # anyres tile budget (stub frontend)
        return {
            "embeds": jax.ShapeDtypeStruct((b, s_img, cfg.d_model), cfg.cdt),
            "tokens": _tok(b, s - s_img),
            "targets": _tok(b, s - s_img),
        }
    return {"tokens": _tok(b, s), "targets": _tok(b, s)}


def prefill_batch_struct(spec: ArchSpec, cfg: ModelConfig,
                         shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if spec.input_kind == "audio":
        return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.cdt)}
    if spec.input_kind == "vlm":
        s_img = 1024
        return {"embeds": jax.ShapeDtypeStruct((b, s_img, cfg.d_model),
                                               cfg.cdt),
                "tokens": _tok(b, s - s_img)}
    return {"tokens": _tok(b, s)}


def input_specs(arch_spec: ArchSpec, shape_name: str,
                rules: ShardingRules, *,
                analysis_unroll: bool = False) -> dict:
    """Returns {fn, args (ShapeDtypeStructs), in_shardings, donate_argnums}
    for one (arch × shape) pair on the mesh behind ``rules``."""
    shape = INPUT_SHAPES[shape_name]
    # §Perf: small models train pure-DP — the model axis joins the batch
    # axes; TP output all-reduces dominate otherwise (16× collective on
    # qwen-0.5b). Threshold 2B params.
    from repro.models.model import analytic_param_count
    if (shape.kind == "train" and rules.policy == "tp"
            and analytic_param_count(arch_spec.config) < 2e9):
        rules = rules.with_policy("dp")
    cfg = variant_config(arch_spec, shape_name, rules=rules)
    cfg = dataclasses.replace(cfg, analysis_unroll=analysis_unroll)
    model = Model(cfg)
    opt_cfg = opt_config_for(cfg)

    params_struct = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    p_specs = rules.params_specs(params_struct)
    p_shard = make_shardings(rules, p_specs)

    if shape.kind == "train":
        state_struct = jax.eval_shape(
            lambda: train_state_init(cfg, opt_cfg, jax.random.PRNGKey(0)))
        state_shard = TrainState(
            params=p_shard,
            opt=make_shardings(rules, rules.opt_specs(None, p_specs)),
            step=make_shardings(rules, P()))
        batch_struct = train_batch_struct(arch_spec, cfg, shape)
        b_shard = make_shardings(
            rules, rules.batch_specs(batch_struct, shape.global_batch))
        return dict(
            fn=make_train_step(cfg, opt_cfg, grad_specs=p_specs),
            args=(state_struct, batch_struct),
            in_shardings=(state_shard, b_shard),
            # pin the new state to the same shards so gradients lower to
            # reduce-scatter into the FSDP layout, not full all-reduce
            out_shardings=(state_shard, None),
            donate_argnums=(0,),
            cfg=cfg,
        )

    if shape.kind == "prefill":
        batch_struct = prefill_batch_struct(arch_spec, cfg, shape)
        b_shard = make_shardings(
            rules, rules.batch_specs(batch_struct, shape.global_batch))
        return dict(
            fn=make_prefill(cfg),
            args=(params_struct, batch_struct),
            in_shardings=(p_shard, b_shard),
            donate_argnums=(),
            cfg=cfg,
        )

    # decode: ONE new token against a seq_len cache. Serving has no
    # optimizer state — params live model-sharded only (no per-step FSDP
    # all-gathers; §Perf iteration log, qwen-32B decode) — UNLESS the
    # model-sharded residency alone exceeds the HBM budget (jamba-398B:
    # 49.8 GB/device), in which case params stay FSDP+TP sharded.
    from repro.models.model import analytic_param_count
    params_per_dev = analytic_param_count(cfg) * 2 / max(rules.tp_size, 1)
    if rules.policy == "tp" and params_per_dev <= 8 * 2**30:
        rules = rules.with_policy("serve")
        p_shard = make_shardings(rules, rules.params_specs(params_struct))
    b = shape.global_batch
    cache_len = (cfg.sliding_window
                 if any(s.mixer == "swa" for s in cfg.slots)
                 else shape.seq_len)
    cache_struct = jax.eval_shape(
        lambda: model.init_cache(b, cache_len))
    c_shard = make_shardings(rules, rules.cache_specs(cache_struct, b))
    tok_struct = _tok(b, 1)
    t_shard = make_shardings(
        rules, rules.batch_specs({"tokens": tok_struct}, b))["tokens"]
    pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
    return dict(
        fn=make_serve_step(cfg),
        args=(params_struct, cache_struct, tok_struct, pos_struct),
        in_shardings=(p_shard, c_shard, t_shard,
                      make_shardings(rules, P())),
        donate_argnums=(1,),
        cfg=cfg,
    )
