"""Post-SPMD HLO analysis with while-loop trip-count awareness.

XLA's built-in HloCostAnalysis counts a while body ONCE regardless of trip
count, which silently undercounts any scanned (layer-stacked) model by its
depth. This module re-derives per-device costs from ``compiled.as_text()``
(the partitioned module) by:

  1. splitting the module into computations and building the call graph
     (while → body/condition, fusion/call → subcomputations),
  2. parsing each while's trip count from the s32 bound constant in its
     condition computation,
  3. counting, per computation: exact dot FLOPs (2·|result|·|contracted|),
     elementwise FLOPs (|result| per arithmetic op), transcendentals,
     reduce FLOPs (|operand|), collective result bytes by kind,
  4. totalling with execution multipliers = product of enclosing trip counts.

Memory traffic uses a fusion-boundary model: operand + result bytes of every
op in non-fused computations (fusion internals never touch HBM); this is the
standard perfect-fusion HBM model and matches what a TPU kernel would stream.

Roofline terms (per-chip seconds):
  compute    = flops / PEAK_FLOPS_BF16
  memory     = hbm_bytes / HBM_BANDWIDTH
  collective = collective_bytes / ICI_LINK_BANDWIDTH
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

import numpy as np

from repro.launch.mesh import (HBM_BANDWIDTH, ICI_LINK_BANDWIDTH,
                               PEAK_FLOPS_BF16)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "u1": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "select", "compare", "and", "or", "xor", "not", "clamp",
    "remainder", "power", "floor", "ceil", "round-nearest-afz", "sign",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "cosine",
                   "sine", "logistic", "expm1", "log1p", "atan2", "erf",
                   "cbrt", "exponential-minus-one"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPLINE_RE = re.compile(
    r"^(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(
    r"^(?:ENTRY\s+)?(%[\w.\-]+)(?:\.clone)?\s*\((.*?)\)\s*->")
_PARAM_RE = re.compile(r"(%?[\w.\-]+)\s*:\s*(\(?[\w\[\],\{\} ]+\)?)")


def _prod(dims: str) -> int:
    if not dims:
        return 1
    return int(np.prod([int(x) for x in dims.split(",")]))


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    """Total (elements, bytes) over all array shapes in a string."""
    elems = nbytes = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = _prod(dims)
        elems += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return elems, nbytes


@dataclasses.dataclass
class _Comp:
    name: str
    lines: list
    is_entry: bool = False


def _split_computations(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    current: _Comp | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        # tuple types embed /*index=N*/ comments whose '=' breaks op parsing
        stripped = re.sub(r"/\*.*?\*/", "", line).strip()
        if current is None:
            m = _COMP_HDR_RE.match(stripped)
            if m and stripped.endswith("{"):
                current = _Comp(name=m.group(1), lines=[stripped],
                                is_entry=stripped.startswith("ENTRY"))
        else:
            current.lines.append(stripped)
            if stripped == "}":
                comps[current.name] = current
                current = None
    return comps


def _name_shapes(comps: dict[str, _Comp]) -> dict[str, str]:
    """Map %op-name → result-shape-string (module-wide; names are unique
    enough post-SPMD for our byte accounting)."""
    out: dict[str, str] = {}
    for comp in comps.values():
        hdr = comp.lines[0]
        m = _COMP_HDR_RE.match(hdr)
        if m:
            for pname, pshape in _PARAM_RE.findall(m.group(2)):
                key = pname if pname.startswith("%") else "%" + pname
                out[key] = pshape
        for line in comp.lines[1:]:
            om = _OPLINE_RE.match(line)
            if om:
                out[om.group(1)] = om.group(2)
    return out


def _trip_count(cond_comp: _Comp) -> int | None:
    """Trip count = the s32 bound constant in the condition computation."""
    consts = []
    for line in cond_comp.lines:
        m = re.search(r"=\s*s32\[\]\s*constant\((\d+)\)", line)
        if m:
            consts.append(int(m.group(1)))
    return max(consts) if consts else None


_SKIP_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "broadcast",
    "reshape", "transpose", "convert", "copy", "slice", "pad", "reverse",
    "concatenate", "dynamic-slice", "dynamic-update-slice", "gather",
    "scatter", "select-and-scatter", "rng", "rng-bit-generator", "domain",
    "opt-barrier", "custom-call", "while", "conditional", "call", "map",
    "sort", "bitcast-convert", "get-dimension-size", "send", "recv",
    "send-done", "recv-done", "infeed", "outfeed",
}

# HBM traffic is counted only at data-movement-significant ops — the
# perfect-fusion model a TPU backend would approach. Elementwise chains,
# converts, transposes and broadcasts are assumed fused into the adjacent
# matmul/reduce (CPU lowering materializes each as its own kLoop fusion,
# which would otherwise inflate the memory term ~100×). Residual-stream
# reads that a TPU would also fuse are therefore slightly undercounted.
_MEM_COUNTED = {"dot", "convolution", "reduce", "reduce-window",
                "dynamic-slice", "dynamic-update-slice", "gather",
                "scatter", "sort", "copy", "concatenate",
                *_COLLECTIVES, *(c + "-start" for c in _COLLECTIVES)}


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    transcendentals: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    coll_ops: dict = dataclasses.field(
        default_factory=lambda: {c: 0 for c in _COLLECTIVES})
    unknown_trip_counts: int = 0
    num_whiles: int = 0
    # (total_bytes, kind, result_shape, multiplier, metadata-op-name)
    top_collectives: list = dataclasses.field(default_factory=list)
    # (total_bytes, op, result_shape, multiplier)
    top_memory_ops: list = dataclasses.field(default_factory=list)


def analyze_hlo_text(text: str) -> HloCosts:
    comps = _split_computations(text)
    shapes = _name_shapes(comps)
    costs = HloCosts()

    # ---- call graph: (caller → [(callee, multiplier)]) ------------------------
    edges: dict[str, list[tuple[str, int]]] = defaultdict(list)
    fusion_bodies: set[str] = set()
    reduce_bodies: set[str] = set()
    for comp in comps.values():
        for line in comp.lines[1:]:
            om = _OPLINE_RE.match(line)
            if not om:
                continue
            op = om.group(3)
            if op == "while":
                bm = re.search(r"body=(%[\w.\-]+)", line)
                cm = re.search(r"condition=(%[\w.\-]+)", line)
                trip = None
                kt = re.search(r'known_trip_count[^\d]*(\d+)', line)
                if kt:
                    trip = int(kt.group(1))
                elif cm and cm.group(1) in comps:
                    trip = _trip_count(comps[cm.group(1)])
                if trip is None:
                    trip = 1
                    costs.unknown_trip_counts += 1
                costs.num_whiles += 1
                if bm:
                    edges[comp.name].append((bm.group(1), trip))
                if cm:
                    edges[comp.name].append((cm.group(1), trip))
            elif op in ("fusion",):
                fm = re.search(r"calls=(%[\w.\-]+)", line)
                if fm:
                    edges[comp.name].append((fm.group(1), 1))
                    fusion_bodies.add(fm.group(1))
            elif op in ("call", "conditional", "custom-call"):
                for fm in re.finditer(
                        r"(?:to_apply|calls|branch_computations=\{?)"
                        r"=?\s*(%[\w.\-]+)", line):
                    edges[comp.name].append((fm.group(1), 1))
            elif op in ("reduce", "reduce-window", "scatter", "map", "sort",
                        "select-and-scatter", "all-reduce",
                        "reduce-scatter"):
                fm = re.search(r"to_apply=(%[\w.\-]+)", line)
                if fm:
                    reduce_bodies.add(fm.group(1))

    # ---- execution multipliers via DFS from the entry --------------------------
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    mult: dict[str, float] = defaultdict(float)
    if entry is None:
        return costs

    def visit(name: str, m: float, depth: int = 0):
        if depth > 64:
            return
        mult[name] += m
        for callee, k in edges.get(name, ()):
            if callee in comps:
                visit(callee, m * k, depth + 1)

    visit(entry, 1.0)

    # ---- per-computation costs ---------------------------------------------------
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0 or comp.name in reduce_bodies:
            continue
        in_fusion = comp.name in fusion_bodies
        for line in comp.lines[1:]:
            om = _OPLINE_RE.match(line)
            if not om:
                continue
            _, res_shape, op, rest = om.groups()
            res_elems, res_bytes = _shape_elems_bytes(res_shape)

            # ---- flops ----
            if op in ("dot", "convolution"):
                k = 1
                lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                ops_m = re.match(r"([^)]*)\)", rest)
                if lc and ops_m:
                    first_operand = ops_m.group(1).split(",")[0].strip()
                    lhs_shape = shapes.get(first_operand, "")
                    sm = _SHAPE_RE.search(lhs_shape)
                    if sm and sm.group(2):
                        lhs_dims = [int(x) for x in sm.group(2).split(",")]
                        try:
                            k = int(np.prod(
                                [lhs_dims[int(i)]
                                 for i in lc.group(1).split(",") if i]))
                        except (IndexError, ValueError):
                            k = 1
                costs.flops += m * 2.0 * res_elems * k
            elif op in _ELEMENTWISE:
                costs.flops += m * res_elems
            elif op in _TRANSCENDENTAL:
                costs.transcendentals += m * res_elems
                costs.flops += m * res_elems
            elif op in ("reduce", "reduce-window"):
                # flops ≈ total input elements
                ops_m = re.match(r"([^)]*)\)", rest)
                if ops_m:
                    first = ops_m.group(1).split(",")[0].strip()
                    in_elems, _ = _shape_elems_bytes(shapes.get(first, ""))
                    costs.flops += m * in_elems
            # ---- collectives ----
            for coll in _COLLECTIVES:
                if op == coll or op == coll + "-start":
                    costs.coll_bytes[coll] += m * res_bytes
                    costs.coll_ops[coll] += int(m)
                    meta = re.search(r'op_name="([^"]*)"', line)
                    costs.top_collectives.append(
                        (m * res_bytes, coll, res_shape.strip(), m,
                         meta.group(1)[-120:] if meta else ""))
                    break

            # ---- memory (perfect-fusion model) ----
            if not in_fusion and op in _MEM_COUNTED:
                operand_bytes = 0
                ops_m = re.match(r"([^)]*)\)", rest)
                if ops_m:
                    for name in ops_m.group(1).split(","):
                        name = name.strip()
                        if name.startswith("%"):
                            _, b = _shape_elems_bytes(shapes.get(name, ""))
                            operand_bytes += b
                costs.hbm_bytes += m * (res_bytes + operand_bytes)
                costs.top_memory_ops.append(
                    (m * (res_bytes + operand_bytes), op,
                     res_shape.strip(), m))

    costs.top_collectives.sort(key=lambda t: -t[0])
    costs.top_collectives = costs.top_collectives[:40]
    costs.top_memory_ops.sort(key=lambda t: -t[0])
    costs.top_memory_ops = costs.top_memory_ops[:40]
    return costs


# ------------------------------------------------------------------ roofline
@dataclasses.dataclass
class Roofline:
    flops: float                  # per device
    bytes_accessed: float         # per device (fusion-boundary HBM model)
    coll_bytes: dict              # per device, by collective kind
    peak_memory: float | None     # per device, from memory_analysis
    transcendentals: float = 0.0
    num_whiles: int = 0
    unknown_trip_counts: int = 0

    @property
    def total_coll_bytes(self) -> float:
        return float(sum(self.coll_bytes.values()))

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_accessed / HBM_BANDWIDTH

    @property
    def collective_s(self) -> float:
        return self.total_coll_bytes / ICI_LINK_BANDWIDTH

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "bytes_per_device": self.bytes_accessed,
            "transcendentals_per_device": self.transcendentals,
            "collective_bytes_per_device": self.coll_bytes,
            "peak_memory_per_device": self.peak_memory,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "num_whiles": self.num_whiles,
            "unknown_trip_counts": self.unknown_trip_counts,
        }


def analyze_compiled(compiled) -> Roofline:
    text = compiled.as_text()
    costs = analyze_hlo_text(text)
    peak = None
    try:
        mem = compiled.memory_analysis()
        peak = float(mem.temp_size_in_bytes + mem.argument_size_in_bytes
                     + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    except Exception:
        pass
    return Roofline(
        flops=costs.flops, bytes_accessed=costs.hbm_bytes,
        coll_bytes=costs.coll_bytes, peak_memory=peak,
        transcendentals=costs.transcendentals,
        num_whiles=costs.num_whiles,
        unknown_trip_counts=costs.unknown_trip_counts)


def model_flops_per_step(cfg, shape, active_params: int) -> float:
    """MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens (fwd)."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * active_params * tokens


# legacy helpers kept for tests
def collective_bytes(hlo_text: str) -> dict[str, int]:
    costs = analyze_hlo_text(hlo_text)
    return {k: int(v) for k, v in costs.coll_bytes.items()}
