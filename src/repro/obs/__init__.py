"""Unified telemetry for every runtime: what is recorded where.

Architecture map
----------------

::

    ON DEVICE (zero host callbacks — J001; zero extra dispatches — J002)
      solve_batched / async_solve_batched / chebyshev_solve_packed /
      make_[async_]spmd_solver, all with `return_trace=True`
        └─▶ SolveTrace / AsyncSolveTrace  (repro.obs.trace)
            per-round max|Δθ| in a preallocated [R] carry inside the
            existing while/scan; async adds active / broadcasts /
            deliveries / bytes per round. Chunk-invariant, rtol-1e-9
            exact vs per-round recomputation (tests/test_obs.py).

    HOST SIDE (stdlib clocks, injectable — R006 chokepoint)
      pack_problem · stream ingest/refresh/publish · serve waves ·
      bench suites
        └─▶ spans (repro.obs.spans: nested context-manager intervals,
            recorded only while a SpanRecorder is installed)
      counters / gauges / histograms / LatencyRecorder
        └─▶ Registry (repro.obs.metrics: one named home per run;
            LatencyRecorder/LatencyReport live here — repro.serve
            re-exports them)

    STATIC (tracing only, nothing executes)
      dispatch_count(fn, *args) (repro.obs.dispatch)
        └─▶ (#pallas_call, exact?) — the J002 counter, promoted to a
            reusable hook; repro.analysis.jaxpr_lint re-imports it.

    EXPORT (repro.obs.export)
      Registry ──▶ JSONL (spans + metrics + trace/latency events +
                   provenance block) ──▶ `python -m repro.obs` report
                   (convergence table, comm frontier, span waterfall,
                   serve percentiles)
               ──▶ Prometheus text exposition (metrics only)
      provenance() / stamp_provenance() — git sha, jax version, device
      kind, interpret flag stamped into every BENCH_*.json by
      benchmarks/run.py.

On-device vs host is a hard line: device traces are arrays computed by
the solver program itself (exact, replayable, backend-agnostic); host
spans/metrics are wall-clock observations (machine-dependent, for
waterfalls and percentiles). The exporters carry both, tagged by kind.

Importing `repro.obs` (and `.metrics`/`.trace`/`.spans`/`.export`) does
NOT import jax — the analysis CLI configures the jax platform first and
times itself with obs clocks. Only `dispatch_count` touches jax, lazily.
"""
from repro.obs import export, spans
from repro.obs.dispatch import count_pallas_dispatches, dispatch_count
from repro.obs.metrics import (Counter, FakeClock, Gauge, Histogram,
                               LatencyRecorder, LatencyReport, Registry,
                               perf_clock, wall_clock)
from repro.obs.spans import Span, SpanRecorder, recording, span
from repro.obs.trace import AsyncSolveTrace, SolveTrace

__all__ = [
    "AsyncSolveTrace",
    "Counter",
    "FakeClock",
    "Gauge",
    "Histogram",
    "LatencyRecorder",
    "LatencyReport",
    "Registry",
    "SolveTrace",
    "Span",
    "SpanRecorder",
    "count_pallas_dispatches",
    "dispatch_count",
    "export",
    "perf_clock",
    "recording",
    "span",
    "spans",
    "wall_clock",
]
