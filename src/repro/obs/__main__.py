"""Run-report CLI: ``python -m repro.obs RUN.jsonl``.

Renders a human-readable report from a JSONL file produced by
`repro.obs.export.write_jsonl` (the bench runner writes one per run):
provenance header, convergence-curve table, comm frontier, span
waterfall, counters/gauges, and per-wave serve percentiles. Sections
with no matching records are omitted; an empty file still renders (and
exits 0) so the CI smoke is robust to reduced runs.

No jax import anywhere on this path — the report is pure text over
recorded data.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any

_BAR_WIDTH = 40


def load_records(path: str) -> list[dict[str, Any]]:
    records = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except ValueError as exc:
                raise SystemExit(
                    f"{path}:{lineno}: not a JSON record: {exc}")
    return records


def _fmt(v: float) -> str:
    return f"{v:.3e}"


def _section(title: str) -> str:
    return f"\n== {title} " + "=" * max(1, 60 - len(title))


def render_provenance(records: list[dict]) -> list[str]:
    provs = [r for r in records if r.get("kind") == "provenance"]
    if not provs:
        return []
    out = [_section("provenance")]
    for p in provs:
        for key in ("git_sha", "jax_version", "device_kind", "platform",
                    "interpret"):
            if p.get(key) is not None:
                out.append(f"  {key:<12} {p[key]}")
    return out


def _checkpoints(n: int) -> list[int]:
    """Round indices shown in the convergence table: first, quartiles,
    last (deduped, ordered)."""
    idx = [0, n // 4, n // 2, (3 * n) // 4, n - 1]
    return sorted({max(0, min(n - 1, i)) for i in idx})


def render_convergence(records: list[dict]) -> list[str]:
    traces = [r for r in records
              if r.get("kind") == "event" and r.get("event") == "trace"
              and r.get("residuals")]
    if not traces:
        return []
    out = [_section("convergence")]
    width = max(len(str(t.get("label", "?"))) for t in traces)
    for t in traces:
        res = [float(v) for v in t["residuals"]]
        cps = _checkpoints(len(res))
        cells = "  ".join(f"r{i + 1}={_fmt(res[i])}" for i in cps)
        out.append(f"  {str(t.get('label', '?')):<{width}}  "
                   f"rounds={len(res):<5d} {cells}")
    return out


def render_comm_frontier(records: list[dict]) -> list[str]:
    traces = [r for r in records
              if r.get("kind") == "event" and r.get("event") == "trace"
              and r.get("bytes")]
    if not traces:
        return []
    out = [_section("comm frontier"),
           f"  {'label':<28} {'rounds':>6} {'bytes':>12} "
           f"{'broadcasts':>10} {'deliveries':>10} {'final resid':>12}"]
    for t in traces:
        res = [float(v) for v in t.get("residuals", [])]
        out.append(
            f"  {str(t.get('label', '?')):<28} "
            f"{len(t['bytes']):>6d} {int(sum(t['bytes'])):>12d} "
            f"{int(sum(t.get('broadcasts', []))):>10d} "
            f"{int(sum(t.get('deliveries', []))):>10d} "
            f"{_fmt(res[-1]) if res else '-':>12}")
    return out


def render_spans(records: list[dict]) -> list[str]:
    spans = [r for r in records if r.get("kind") == "span"]
    if not spans:
        return []
    spans.sort(key=lambda s: (float(s["t_start"]), int(s.get("depth", 0))))
    t0 = min(float(s["t_start"]) for s in spans)
    t1 = max(float(s["t_end"]) for s in spans)
    total = max(t1 - t0, 1e-12)
    out = [_section("span waterfall"), f"  total {total:.4f}s"]
    for s in spans:
        start, end = float(s["t_start"]) - t0, float(s["t_end"]) - t0
        lo = int(_BAR_WIDTH * start / total)
        hi = max(lo + 1, int(_BAR_WIDTH * end / total))
        bar = " " * lo + "#" * (min(hi, _BAR_WIDTH) - lo)
        name = "  " * int(s.get("depth", 0)) + str(s["name"])
        out.append(f"  {name:<28.28} |{bar:<{_BAR_WIDTH}}| "
                   f"{end - start:>9.4f}s")
    return out


def render_metrics(records: list[dict]) -> list[str]:
    counters = [r for r in records if r.get("kind") == "counter"]
    gauges = [r for r in records if r.get("kind") == "gauge"]
    if not counters and not gauges:
        return []
    out = [_section("counters / gauges")]
    for r in counters + gauges:
        out.append(f"  {r['name']:<40} {r['value']:.6g}")
    return out


def render_latency(records: list[dict]) -> list[str]:
    evs = [r for r in records
           if r.get("kind") == "event" and r.get("event") == "latency"]
    hists = [r for r in records if r.get("kind") == "histogram"]
    if not evs and not hists:
        return []
    out = [_section("latency / percentiles"),
           f"  {'label':<32} {'count':>6} {'p50':>10} {'p99':>10} "
           f"{'mean':>10} {'max':>10} {'qps':>10}"]
    for r in evs:
        out.append(
            f"  {str(r.get('label', '?')):<32} {int(r['count']):>6d} "
            f"{_fmt(r['p50']):>10} {_fmt(r['p99']):>10} "
            f"{_fmt(r['mean']):>10} {_fmt(r['max']):>10} "
            f"{r['qps']:>10.2f}")
    for r in hists:
        out.append(
            f"  {str(r['name']):<32} {int(r['count']):>6d} "
            f"{_fmt(r['p50']):>10} {_fmt(r['p99']):>10} "
            f"{_fmt(r['mean']):>10} {_fmt(r['max']):>10} {'-':>10}")
    return out


def render_report(records: list[dict]) -> str:
    out: list[str] = ["obs run report"]
    out += render_provenance(records)
    out += render_convergence(records)
    out += render_comm_frontier(records)
    out += render_spans(records)
    out += render_metrics(records)
    out += render_latency(records)
    if len(out) == 1:
        out.append("  (no records)")
    return "\n".join(out)


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Render a run report from an obs JSONL file.")
    parser.add_argument("jsonl", help="path to a run JSONL "
                        "(repro.obs.export.write_jsonl output)")
    args = parser.parse_args(argv)
    print(render_report(load_records(args.jsonl)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
