"""On-device convergence-trace containers.

The solvers' `return_trace=` paths fill these with preallocated
``[num_rounds]`` device arrays written *inside* the existing
while/scan/kernel round structure — zero host callbacks, zero extra
kernel dispatches (the J001/J002 passes pin both). This module is
jax-free on purpose: the NamedTuples are plain containers (jax treats
them as pytrees structurally), so the analysis CLI and the report
renderer can import them before the process fixes its jax platform
config.

Semantics shared by every producer (and pinned at rtol 1e-9 by
``tests/test_obs.py`` against a per-round recomputation):

  * ``residuals[r]`` is ``max|θ_{r+1} − θ_r|`` over every real
    coordinate of round ``r`` (0-based). Padded slots contribute exactly
    0 — the packed layout's zero-padding algebra keeps padded
    coordinates identically zero, so no masking is needed.
  * On ``tol > 0`` paths the trace is still length ``num_rounds``:
    rounds after the stop (frozen rounds) record 0. This is what makes
    traces chunk-invariant — chunking changes *when* the stop check
    runs, never what each executed round wrote.
  * Async traces additionally record the per-round wire activity the
    comm frontier is made of; summing them reproduces
    ``AsyncGossipStats`` exactly (the fused backend builds its stats
    from these buffers).
"""
from __future__ import annotations

from typing import Any, NamedTuple

__all__ = ["AsyncSolveTrace", "SolveTrace"]


class SolveTrace(NamedTuple):
    """Synchronous-solver trace: per-round max|Δθ|, shape [R]."""

    residuals: Any

    def as_lists(self) -> dict[str, list[float]]:
        return {"residuals": [float(v) for v in self.residuals]}


class AsyncSolveTrace(NamedTuple):
    """Asynchronous-gossip trace, all fields shape [R].

    ``active``: scheduled transmitters this round (activated nodes, or
    2 endpoints for edge gossip). ``broadcasts``: transmissions that
    survived censoring. ``deliveries``: neighbor receipts (one per
    receiving directed edge). ``bytes``: wire bytes this round
    (broadcast payload actually sent — `d_max × Dy × itemsize` per
    broadcast, matching `AsyncGossipStats`-based accounting).
    """

    residuals: Any
    active: Any
    broadcasts: Any
    deliveries: Any
    bytes: Any

    def censored_fraction(self):
        """Per-round fraction of scheduled transmissions suppressed by
        the censor threshold (0 where nothing was scheduled). Pure
        arithmetic so it works on device arrays and numpy alike."""
        act, bc = self.active, self.broadcasts
        if isinstance(act, (list, tuple)):
            import numpy as np

            act, bc = np.asarray(act), np.asarray(bc)
        denom = act * (act > 0) + (act <= 0)
        return (act - bc) / denom

    def as_lists(self) -> dict[str, list[float]]:
        return {
            "residuals": [float(v) for v in self.residuals],
            "active": [int(v) for v in self.active],
            "broadcasts": [int(v) for v in self.broadcasts],
            "deliveries": [int(v) for v in self.deliveries],
            "bytes": [int(v) for v in self.bytes],
        }
