"""Dispatch accounting: static pallas_call counts of traced programs.

`count_pallas_dispatches` is the J002 primitive (it moved here from
`repro.analysis.jaxpr_lint`, which re-imports it — obs is the lower
layer): walk a closed jaxpr, count `pallas_call` equations with
`lax.scan` length multipliers, and report whether the count is exact
(a dispatch under `while` makes it a one-trip lower bound).

`dispatch_count(fn, *args, **kwargs)` is the user-facing hook: trace
`fn` on the given arguments (tracing only — no numerics run, no device
work) and count. This is how `tests/test_obs.py` proves that
`return_trace=True` adds zero extra kernel dispatches, and how any
harness can assert a program's dispatch contract without running it.

jax is imported lazily inside the functions: importing `repro.obs`
must not freeze the process's platform config (the analysis CLI sets
JAX_PLATFORMS/XLA_FLAGS before its first jax import).
"""
from __future__ import annotations

from typing import Any, Callable

__all__ = ["count_pallas_dispatches", "dispatch_count"]


def _is_jaxpr(v) -> bool:
    return type(v).__name__ in ("Jaxpr", "ClosedJaxpr")


def _inner(j):
    """Unwrap ClosedJaxpr → Jaxpr (ClosedJaxpr has .jaxpr + .consts)."""
    return j.jaxpr if hasattr(j, "consts") and hasattr(j, "jaxpr") else j


def _jaxpr_params(value):
    """Yield every jaxpr-valued leaf of one eqn param value."""
    if _is_jaxpr(value):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _jaxpr_params(v)


def _sub_jaxprs(eqn):
    """Yield (jaxpr, frame) for each sub-jaxpr of `eqn` — the same
    frame vocabulary `repro.analysis.jaxpr_lint` walks with."""
    name = eqn.primitive.name
    p = eqn.params
    if name == "pallas_call":
        return
    if name == "scan":
        yield p["jaxpr"], ("scan", int(p.get("length", 1)))
    elif name == "while":
        yield p["cond_jaxpr"], ("while_cond", None)
        yield p["body_jaxpr"], ("while_body", None)
    elif name == "cond":
        for br in p["branches"]:
            yield br, ("cond_branch", None)
    elif name == "shard_map":
        yield p["jaxpr"], ("shard_map", eqn)
    else:
        for v in p.values():
            for sub in _jaxpr_params(v):
                yield sub, ("call", None)


def count_pallas_dispatches(closed) -> tuple[int, bool]:
    """(#pallas_call dispatches, exact?) with `lax.scan` length
    multipliers. A dispatch under `while` makes the count inexact (trip
    count is dynamic); the returned count then assumes one trip."""
    def rec(jaxpr):
        count, exact = 0, True
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                count += 1
            for sub, frame in _sub_jaxprs(eqn):
                c, e = rec(_inner(sub))
                if frame[0] == "scan":
                    c *= frame[1]
                elif frame[0] in ("while_body", "while_cond"):
                    e = e and c == 0
                count += c
                exact = exact and e
        return count, exact

    return rec(_inner(closed))


def dispatch_count(fn: Callable, *args: Any,
                   **kwargs: Any) -> tuple[int, bool]:
    """Trace ``fn(*args, **kwargs)`` (abstractly — nothing executes)
    and return its static ``(pallas_call dispatches, exact?)``.

    Keyword arguments are closed over as static configuration, matching
    how the solvers take their ``backend=``/``tol=``/``return_trace=``
    knobs; positional arguments become tracers."""
    import functools

    import jax

    closed = jax.make_jaxpr(functools.partial(fn, **kwargs))(*args)
    return count_pallas_dispatches(closed)
