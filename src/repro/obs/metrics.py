"""Metric primitives: clocks, counters, gauges, histograms, latency.

This module is the jax-free floor of `repro.obs` — stdlib + numpy only,
importable before any process-level jax/platform configuration (the
analysis CLI times its passes with these clocks *before* importing jax).

Clock policy (conventions rule R006): host-side timing anywhere under
`src/repro/` must go through the clocks defined here (`perf_clock`,
`wall_clock`, or an injected callable defaulting to them) instead of
bare `time.time()` / `time.perf_counter()` — one chokepoint means every
latency number in the repo is faked the same way in tests (`FakeClock`)
and exported the same way by `repro.obs.export`.

`LatencyRecorder` / `LatencyReport` live here (moved from
`repro.serve.admission`, which re-exports them): they are generic
per-request latency accounting, not a serving-tier concern.

Thread-safety contract: every mutating public method on `Counter`,
`Gauge`, `Histogram`, `Registry`, and `LatencyRecorder` holds its
instance lock for the whole critical section.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Iterable

import numpy as np

__all__ = [
    "Counter",
    "FakeClock",
    "Gauge",
    "Histogram",
    "LatencyRecorder",
    "LatencyReport",
    "Registry",
    "perf_clock",
    "wall_clock",
]

# The only sanctioned raw-clock references in src/ (R006): monotonic for
# durations, wall for provenance timestamps.
perf_clock: Callable[[], float] = time.perf_counter
wall_clock: Callable[[], float] = time.time


class FakeClock:
    """Deterministic injectable clock: starts at `start`, advances only
    via `advance` — a seeded load trace replayed against it produces
    bit-identical latency reports."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)
        self._lock = threading.Lock()

    def __call__(self) -> float:
        with self._lock:
            return self._t

    def advance(self, dt: float) -> float:
        with self._lock:
            self._t += float(dt)
            return self._t


class Counter:
    """Monotonically-increasing count (dispatches, rounds, bytes)."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative inc {n}")
        with self._lock:
            self._value += float(n)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins instantaneous value (queue depth, VMEM bytes)."""

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, dv: float) -> None:
        with self._lock:
            self._value += float(dv)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Exact-sample histogram (bench/serve scale — samples are kept, so
    percentiles are the same `np.percentile` numbers `LatencyReport`
    uses, not bucket approximations)."""

    def __init__(self, name: str, help: str = "",
                 clock: Callable[[], float] = perf_clock):
        self.name = name
        self.help = help
        self.clock = clock
        self._lock = threading.Lock()
        self._samples: list[float] = []

    def observe(self, v: float) -> None:
        with self._lock:
            self._samples.append(float(v))

    def time(self):
        """Context manager observing the elapsed clock duration."""
        return _HistogramTimer(self)

    @property
    def count(self) -> int:
        with self._lock:
            return len(self._samples)

    def samples(self) -> list[float]:
        with self._lock:
            return list(self._samples)

    def summary(self) -> dict[str, float]:
        with self._lock:
            s = np.asarray(self._samples, dtype=np.float64)
        if s.size == 0:
            return {"count": 0, "sum": 0.0, "mean": 0.0, "max": 0.0,
                    "p50": 0.0, "p99": 0.0}
        return {
            "count": int(s.size),
            "sum": float(s.sum()),
            "mean": float(s.mean()),
            "max": float(s.max()),
            "p50": float(np.percentile(s, 50)),
            "p99": float(np.percentile(s, 99)),
        }


class _HistogramTimer:
    def __init__(self, hist: Histogram):
        self._hist = hist

    def __enter__(self):
        self._t0 = self._hist.clock()
        return self

    def __exit__(self, *exc):
        self._hist.observe(self._hist.clock() - self._t0)
        return False


class Registry:
    """One named home for every metric, span, and event of a run — the
    unit the exporters (`repro.obs.export`) serialize.

    Metrics are get-or-create by name (re-registering with a different
    type raises). `record_event` appends a free-form timestamped record
    (reserved event kinds the report CLI understands: ``trace`` for
    convergence traces, ``latency`` for serve percentiles,
    ``provenance`` for run provenance). `record_span` is the sink
    `repro.obs.spans` drains finished spans into.
    """

    def __init__(self, clock: Callable[[], float] = perf_clock):
        self.clock = clock
        self._lock = threading.Lock()
        self._metrics: dict[str, Any] = {}
        self._events: list[dict[str, Any]] = []
        self._spans: list[Any] = []

    def _get_or_create(self, cls, name: str, help: str, **kw):
        with self._lock:
            got = self._metrics.get(name)
            if got is None:
                got = self._metrics[name] = cls(name, help, **kw)
            elif not isinstance(got, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(got).__name__}, requested {cls.__name__}")
            return got

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "") -> Histogram:
        return self._get_or_create(Histogram, name, help,
                                   clock=self.clock)

    def record_event(self, kind: str, **fields: Any) -> dict[str, Any]:
        ev = {"event": str(kind), "t": float(self.clock()), **fields}
        with self._lock:
            self._events.append(ev)
        return ev

    def record_span(self, span: Any) -> None:
        with self._lock:
            self._spans.append(span)

    @property
    def metrics(self) -> dict[str, Any]:
        with self._lock:
            return dict(self._metrics)

    @property
    def events(self) -> list[dict[str, Any]]:
        with self._lock:
            return list(self._events)

    @property
    def spans(self) -> list[Any]:
        with self._lock:
            return list(self._spans)


@dataclasses.dataclass(frozen=True)
class LatencyReport:
    """Latency/throughput summary of one serving run.

    Latency is completion − admission per request (queueing included —
    the open-loop number a caller actually experiences); `qps` is
    requests / (last completion − first admission). Percentiles use the
    linear-interpolation convention of `np.percentile` and are exact
    deterministic functions of the recorded trace.
    """

    count: int
    p50: float
    p99: float
    mean: float
    max: float
    qps: float

    @staticmethod
    def empty() -> "LatencyReport":
        return LatencyReport(count=0, p50=0.0, p99=0.0, mean=0.0, max=0.0,
                             qps=0.0)


class LatencyRecorder:
    """Thread-safe per-request latency accumulator."""

    def __init__(self, clock: Callable[[], float] = perf_clock):
        self.clock = clock
        self._lock = threading.Lock()
        self._arrivals: list[float] = []
        self._completions: list[float] = []

    def now(self) -> float:
        return float(self.clock())

    def record(self, t_arrival: float, t_done: float) -> None:
        if t_done < t_arrival:
            raise ValueError(
                f"completion {t_done} precedes admission {t_arrival}")
        with self._lock:
            self._arrivals.append(float(t_arrival))
            self._completions.append(float(t_done))

    def record_wave(self, entries: Iterable[Any], t_done: float) -> None:
        """Record every entry of one wave (anything with a `t_arrival`
        attribute — `repro.serve.admission.Admitted` in production)."""
        for e in entries:
            self.record(e.t_arrival, t_done)

    def reset(self) -> None:
        with self._lock:
            self._arrivals.clear()
            self._completions.clear()

    def report(self) -> LatencyReport:
        with self._lock:
            arrivals = np.asarray(self._arrivals, dtype=np.float64)
            completions = np.asarray(self._completions, dtype=np.float64)
        if arrivals.size == 0:
            return LatencyReport.empty()
        lat = completions - arrivals
        span = float(completions.max() - arrivals.min())
        return LatencyReport(
            count=int(lat.size),
            p50=float(np.percentile(lat, 50)),
            p99=float(np.percentile(lat, 99)),
            mean=float(lat.mean()),
            max=float(lat.max()),
            qps=float(lat.size / span) if span > 0 else float("inf"),
        )
